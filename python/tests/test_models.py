"""L2 model zoo: shapes, geometry accounting, and quantization wiring."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import models


def batch_for(m, n=4, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n, *m.input_shape)).astype(np.float32))


@pytest.mark.parametrize("name", ["mlp", "alexnet_s", "resnet_s", "mobilenet_s"])
class TestModelZoo:
    def test_apply_shape(self, name):
        m = models.build(name)
        params = m.init(jax.random.PRNGKey(0))
        nl = m.num_quant_layers
        bits = jnp.full((nl,), 8.0)
        logits = m.apply(params, batch_for(m), bits, bits)
        assert logits.shape == (4, m.num_classes)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_infos_match_quant_calls(self, name):
        # Each quantized layer consumes exactly one bits index: the
        # gradient of the logits w.r.t. the bits vectors must touch
        # every entry (a layer that skipped its index would leave a
        # structurally-zero column).
        m = models.build(name)
        params = m.init(jax.random.PRNGKey(0))
        nl = m.num_quant_layers
        x = batch_for(m)

        def f(bw, ba):
            return jnp.sum(m.apply(params, x, bw, ba) ** 2)

        bw = jnp.full((nl,), 3.3)
        ba = jnp.full((nl,), 4.7)
        gw, ga = jax.grad(f, argnums=(0, 1))(bw, ba)
        assert gw.shape == (nl,) and ga.shape == (nl,)
        # every layer's weight bits participate
        assert np.count_nonzero(np.asarray(gw)) >= nl - 1, np.asarray(gw)
        assert np.count_nonzero(np.asarray(ga)) >= nl - 1, np.asarray(ga)

    def test_param_count_matches_geometry(self, name):
        # Total weight elements from LayerInfo equals actual quantized
        # weight tensor sizes.
        m = models.build(name)
        params = m.init(jax.random.PRNGKey(0))
        actual = sum(
            int(np.prod(p["w"].shape)) for p in params if isinstance(p, dict) and "w" in p
        )
        declared = sum(i.weight_elems for i in m.infos)
        assert actual == declared

    def test_init_deterministic(self, name):
        m = models.build(name)
        a = m.init(jax.random.PRNGKey(5))
        b = m.init(jax.random.PRNGKey(5))
        for pa, pb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(pa, pb)

    def test_bits_affect_output(self, name):
        # 1-bit quantization must change the logits vs 8-bit.
        m = models.build(name)
        params = m.init(jax.random.PRNGKey(0))
        nl = m.num_quant_layers
        x = batch_for(m)
        hi = m.apply(params, x, jnp.full((nl,), 8.0), jnp.full((nl,), 8.0))
        lo = m.apply(params, x, jnp.full((nl,), 1.0), jnp.full((nl,), 1.0))
        assert not np.allclose(hi, lo)


class TestWidthVariants:
    def test_width_mult_changes_channels(self):
        base = models.alexnet_s()
        wide = models.alexnet_s(width_mults={1: 4.0})
        narrow = models.alexnet_s(width_mults={1: 0.25})
        assert wide.infos[1].cout == base.infos[1].cout * 4
        assert narrow.infos[1].cout == base.infos[1].cout // 4
        # Downstream layer input channels follow.
        assert wide.infos[2].cin == base.infos[2].cin * 4

    def test_width_variant_trains_shape(self):
        m = models.alexnet_s(width_mults={0: 0.25})
        params = m.init(jax.random.PRNGKey(1))
        nl = m.num_quant_layers
        bits = jnp.full((nl,), 8.0)
        out = m.apply(params, batch_for(m), bits, bits)
        assert out.shape == (4, 10)


class TestGeometry:
    def test_macs_consistent_with_shapes(self):
        m = models.alexnet_s(input_size=16)
        conv0 = m.infos[0]
        # 16x16 output spatial, 3x3x3 kernel, 32 filters
        assert conv0.macs == 16 * 16 * 32 * 9 * 3
        assert conv0.act_in_elems == 16 * 16 * 3

    def test_depthwise_macs(self):
        m = models.mobilenet_s()
        dw = next(i for i in m.infos if i.kind == "dwconv")
        # depthwise: macs = out_spatial^2 * channels * k*k (no cin factor)
        assert dw.macs == dw.out_spatial**2 * dw.cout * dw.kernel**2

    def test_unknown_model_rejected(self):
        with pytest.raises(KeyError):
            models.build("vgg")
