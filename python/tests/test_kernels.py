"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps shapes/values/bitlengths; exact identities are checked
deterministically.  This is the CORE correctness signal for the
quantizer that every exported artifact embeds.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fake_quant import (
    fake_quant_pallas, minmax_pallas, pick_block, vmem_bytes,
)
from compile.kernels.quant_matmul import (
    quant_matmul_pallas, mxu_utilization_estimate,
)

settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")


def rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


# ---------------------------------------------------------------------------
# oracle self-checks
# ---------------------------------------------------------------------------

class TestReference:
    def test_integer_quant_levels(self):
        # n bits -> exactly 2^n representable values.
        x = jnp.linspace(-1.0, 1.0, 1001)
        for n in [1, 2, 3, 4]:
            q = ref.quantize_int(x, -1.0, 1.0, float(n))
            levels = np.unique(np.asarray(q))
            assert len(levels) <= 2 ** n
            # endpoints are representable
            np.testing.assert_allclose(levels[0], -1.0, atol=1e-6)
            np.testing.assert_allclose(levels[-1], 1.0, atol=1e-6)

    def test_interp_matches_integer_at_alpha_zero(self):
        x = rand((64,), 1)
        lmin, lmax = ref.group_minmax(x)
        for n in [1.0, 2.0, 5.0, 8.0]:
            np.testing.assert_allclose(
                ref.quantize_interp(x, lmin, lmax, n),
                ref.quantize_int(x, lmin, lmax, n),
                rtol=1e-6,
            )

    def test_interp_is_blend(self):
        x = rand((64,), 2)
        lmin, lmax = ref.group_minmax(x)
        q35 = ref.quantize_interp(x, lmin, lmax, 3.5)
        q3 = ref.quantize_int(x, lmin, lmax, 3.0)
        q4 = ref.quantize_int(x, lmin, lmax, 4.0)
        np.testing.assert_allclose(q35, 0.5 * q3 + 0.5 * q4, rtol=1e-6)

    def test_clip_bounds(self):
        assert float(ref.clip_bits(0.1)) == ref.N_MIN
        assert float(ref.clip_bits(99.0)) == ref.N_MAX

    def test_interp_delta_sign(self):
        # More bits => lower quantization error, so delta moves toward x.
        x = rand((256,), 3)
        lmin, lmax = ref.group_minmax(x)
        q3 = ref.quantize_int(x, lmin, lmax, 3.0)
        delta = ref.interp_delta(x, lmin, lmax, 3.2)
        q4 = ref.quantize_int(x, lmin, lmax, 4.0)
        np.testing.assert_allclose(delta, q4 - q3, rtol=1e-6)

    def test_degenerate_group(self):
        x = jnp.full((32,), 0.7)
        out = ref.fake_quant_ref(x, 4.0)
        np.testing.assert_allclose(out, x, atol=1e-6)

    def test_lambda_normalization(self):
        lam = ref.equal_layer_lambdas(6)
        bits = jnp.full((6,), 8.0)
        # weights + activations each contribute half when both use the
        # same lambda vector of num_groups entries
        assert float(ref.bit_loss(bits, lam)) == pytest.approx(1.0)

    def test_weighted_lambda_normalization(self):
        costs = [100.0, 10.0, 1.0]
        lam = ref.weighted_lambdas(costs)
        bits = jnp.full((3,), 8.0)
        assert float(ref.bit_loss(bits, lam)) == pytest.approx(1.0)
        # proportionality
        lam = np.asarray(lam)
        assert lam[0] / lam[1] == pytest.approx(10.0)


# ---------------------------------------------------------------------------
# pallas vs oracle
# ---------------------------------------------------------------------------

class TestFakeQuantPallas:
    @given(
        rows=st.integers(1, 65),
        cols=st.integers(1, 130),
        n=st.floats(1.0, 12.0),
        seed=st.integers(0, 2**16),
        scale=st.sampled_from([1e-3, 1.0, 100.0]),
    )
    def test_matches_reference(self, rows, cols, n, seed, scale):
        x = rand((rows, cols), seed, scale)
        got = fake_quant_pallas(x, n)
        want = ref.fake_quant_ref(x, n)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5 * scale)

    @given(size=st.integers(1, 10_000), seed=st.integers(0, 2**16))
    def test_minmax_matches(self, size, seed):
        x = rand((size,), seed)
        mn, mx = minmax_pallas(x)
        assert float(mn) == float(x.min())
        assert float(mx) == float(x.max())

    def test_explicit_minmax_override(self):
        x = rand((128,), 5)
        got = fake_quant_pallas(x, 4.0, lmin=-3.0, lmax=3.0)
        want = ref.quantize_interp(x, -3.0, 3.0, 4.0)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_bitlength_below_one_clips(self):
        x = rand((64,), 6)
        got = fake_quant_pallas(x, 0.25)
        want = ref.fake_quant_ref(x, 1.0)
        np.testing.assert_allclose(got, want, rtol=1e-6)

    def test_preserves_shape_and_dtype(self):
        x = rand((3, 5, 7), 7)
        out = fake_quant_pallas(x, 3.3)
        assert out.shape == x.shape
        assert out.dtype == x.dtype

    def test_block_picker(self):
        assert pick_block(100, 1 << 15) == 100      # fits entirely
        blk = pick_block(10_000_000, 32 * 1024)
        assert blk % 128 == 0 and blk <= 32 * 1024
        assert vmem_bytes(blk) == 2 * blk * 4


class TestQuantMatmulPallas:
    @given(
        m=st.integers(1, 40),
        k=st.sampled_from([8, 16, 64, 128]),
        n=st.integers(1, 40),
        na=st.floats(1.0, 8.0),
        nw=st.floats(1.0, 8.0),
        seed=st.integers(0, 2**16),
    )
    def test_matches_reference(self, m, k, n, na, nw, seed):
        a = rand((m, k), seed)
        w = rand((k, n), seed + 1)
        got = quant_matmul_pallas(a, w, na, nw)
        want = ref.quant_matmul_ref(a, w, na, nw)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)

    def test_rejects_untileable_k(self):
        a = rand((8, 130), 0)
        w = rand((130, 8), 1)
        with pytest.raises(ValueError, match="divisible"):
            quant_matmul_pallas(a, w, 4.0, 4.0, tile_k=128)

    def test_mxu_estimate(self):
        assert mxu_utilization_estimate(128, 128, 128) == 1.0
        assert mxu_utilization_estimate(64, 128, 128) == 0.5
        assert 0.0 < mxu_utilization_estimate(100, 100, 100) < 1.0


# ---------------------------------------------------------------------------
# gradients (custom_vjp STE)
# ---------------------------------------------------------------------------

class TestGradients:
    def test_value_gradient_is_ste(self):
        from compile import quant

        x = rand((32,), 11)
        g = jax.grad(lambda v: jnp.sum(quant.fake_quant(v, 3.7) * 2.0))(x)
        np.testing.assert_allclose(g, jnp.full_like(x, 2.0), rtol=1e-6)

    def test_bit_gradient_matches_fd(self):
        from compile import quant

        # float64 reference finite difference within one alpha segment
        x64 = np.random.default_rng(12).normal(size=(512,)).astype(np.float64)
        lmin, lmax = x64.min(), x64.max()

        def loss_np(n):
            b = np.floor(n)
            a = n - b

            def qi(bb):
                s = (lmax - lmin) / (2.0 ** bb - 1.0)
                # numpy rounds half-to-even, same as jnp
                return lmin + np.round((x64 - lmin) / s) * s

            q = (1 - a) * qi(b) + a * qi(b + 1)
            return np.sum(q ** 2)

        n0 = 3.6
        eps = 1e-4
        fd = (loss_np(n0 + eps) - loss_np(n0 - eps)) / (2 * eps)

        x = jnp.asarray(x64.astype(np.float32))
        g_n = jax.grad(
            lambda n: jnp.sum(quant.fake_quant(x, n) ** 2), argnums=0
        )(jnp.float32(n0))
        assert float(g_n) == pytest.approx(fd, rel=2e-3)

    def test_bit_gradient_gated_at_clip_boundary(self):
        from compile import quant

        x = rand((64,), 13)

        def gn(loss_sign, n):
            return float(
                jax.grad(
                    lambda nn: loss_sign * jnp.sum(quant.fake_quant(x, nn) ** 2)
                )(jnp.float32(n))
            )

        # SGD update is n - lr * dn. At n = N_MIN a positive dn would push
        # n below the clip, so the gate must zero it; negative dn (grow n)
        # is allowed.  Squared loss decreases with more bits => raw dn is
        # negative, so flip the sign to probe the forbidden direction.
        dn_forbidden = gn(-1.0, ref.N_MIN)  # raw dn would be positive
        assert dn_forbidden == 0.0
        dn_allowed = gn(1.0, ref.N_MIN)
        assert dn_allowed < 0.0

        # At n = N_MAX quantization error is ~0 so the raw gradient sign
        # is float noise; just check nothing meaningfully pulls n above
        # the cap in either direction.
        assert abs(gn(1.0, ref.N_MAX)) < 1e-2
        assert abs(gn(-1.0, ref.N_MAX)) < 1e-2

    def test_select_integer_bits(self):
        from compile.quant import select_integer_bits

        n = jnp.asarray([0.2, 1.0, 2.01, 7.5])
        np.testing.assert_allclose(
            select_integer_bits(n), [1.0, 1.0, 3.0, 8.0]
        )

    def test_frozen_quant_no_bit_gradient(self):
        from compile import quant

        x = rand((16,), 14)

        def loss(n):
            return jnp.sum(quant.fake_quant_frozen(x, n) ** 2)

        g = jax.grad(loss)(jnp.float32(4.0))
        assert float(g) == 0.0
