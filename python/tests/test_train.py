"""L2 train/eval graph semantics: loss composition, bit updates, masking,
momentum, and the flattened AOT signatures the rust side relies on."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import models, train
from compile.kernels import ref


@pytest.fixture(scope="module")
def tg():
    return train.TrainGraph(models.mlp(din=8, hidden=(16,), num_classes=3), batch_size=8)


def make_args(tg, *, gamma=1.0, lr=0.05, bits_lr=1.0, mask=1.0, bits=8.0, seed=0):
    rng = np.random.default_rng(seed)
    params = tg.init_params(0)
    mom = [jnp.zeros_like(p) for p in params]
    nl = tg.nl
    bw = jnp.full((nl,), bits)
    ba = jnp.full((nl,), bits)
    lam = jnp.full((nl,), 1.0 / (8.0 * 2 * nl), dtype=jnp.float32)
    x = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 3, 8).astype(np.int32))
    return (*params, *mom, bw, ba, lam, lam, x, y,
            jnp.float32(lr), jnp.float32(bits_lr), jnp.float32(gamma),
            jnp.float32(mask))


class TestTrainStep:
    def test_output_arity(self, tg):
        out = tg.train_step(*make_args(tg))
        assert len(out) == 2 * tg.num_params + 6

    def test_loss_composition(self, tg):
        out = tg.train_step(*make_args(tg, gamma=2.0))
        np_ = tg.num_params
        loss, task, bl = (float(v) for v in out[2 * np_ + 2: 2 * np_ + 5])
        assert loss == pytest.approx(task + 2.0 * bl, rel=1e-5)
        # 8-bit network with normalized lambdas -> bit loss 1.0
        assert bl == pytest.approx(1.0, rel=1e-5)

    def test_bits_move_only_when_unmasked(self, tg):
        np_ = tg.num_params
        out_on = tg.train_step(*make_args(tg, mask=1.0))
        out_off = tg.train_step(*make_args(tg, mask=0.0))
        bw_on = np.asarray(out_on[2 * np_])
        bw_off = np.asarray(out_off[2 * np_])
        assert not np.allclose(bw_on, 8.0)
        np.testing.assert_array_equal(bw_off, 8.0)

    def test_bits_clipped_to_range(self, tg):
        np_ = tg.num_params
        out = tg.train_step(*make_args(tg, bits_lr=1e6))
        for v in (out[2 * np_], out[2 * np_ + 1]):
            v = np.asarray(v)
            assert (v >= ref.N_MIN - 1e-6).all() and (v <= ref.N_MAX + 1e-6).all()

    def test_params_update_against_gradient(self, tg):
        np_ = tg.num_params
        args = make_args(tg, lr=0.05)
        out = tg.train_step(*args)
        moved = sum(
            float(jnp.sum(jnp.abs(new - old)))
            for new, old in zip(out[:np_], args[:np_])
        )
        assert moved > 0.0

    def test_momentum_accumulates(self, tg):
        np_ = tg.num_params
        args = make_args(tg)
        out1 = tg.train_step(*args)
        # second step from updated state: momentum tensors are non-zero
        mom1 = out1[np_:2 * np_]
        assert any(float(jnp.max(jnp.abs(m))) > 0 for m in mom1)

    def test_zero_lr_freezes_params(self, tg):
        np_ = tg.num_params
        args = make_args(tg, lr=0.0, bits_lr=0.0)
        out = tg.train_step(*args)
        for new, old in zip(out[:np_], args[:np_]):
            np.testing.assert_array_equal(np.asarray(new), np.asarray(old))

    def test_stronger_gamma_faster_bit_descent(self, tg):
        np_ = tg.num_params
        weak = tg.train_step(*make_args(tg, gamma=0.5, bits_lr=2.0))
        strong = tg.train_step(*make_args(tg, gamma=5.0, bits_lr=2.0))
        assert float(jnp.mean(strong[2 * np_])) < float(jnp.mean(weak[2 * np_]))


class TestEvalStep:
    def test_outputs(self, tg):
        params = tg.init_params(0)
        nl = tg.nl
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
        y = jnp.asarray(rng.integers(0, 3, 8).astype(np.int32))
        loss, correct, amn, amx = tg.eval_step(
            *params, jnp.full((nl,), 8.0), jnp.full((nl,), 8.0), x, y)
        assert amn.shape == (nl,)
        assert amx.shape == (nl,)
        assert bool(jnp.all(amn <= amx))
        assert 0 <= float(correct) <= 8
        assert float(loss) > 0

    def test_act_ranges_track_input(self, tg):
        # Layer-0 activation range is the input batch range.
        params = tg.init_params(0)
        nl = tg.nl
        x = jnp.asarray(np.linspace(-3, 5, 64).reshape(8, 8).astype(np.float32))
        y = jnp.zeros((8,), jnp.int32)
        _, _, amn, amx = tg.eval_step(
            *params, jnp.full((nl,), 8.0), jnp.full((nl,), 8.0), x, y)
        assert float(amn[0]) == pytest.approx(-3.0)
        assert float(amx[0]) == pytest.approx(5.0)


class TestSignatures:
    def test_specs_match_functions(self, tg):
        # Lowering with the declared specs must succeed (what aot.py does).
        jax.eval_shape(tg.train_step, *tg.train_specs())
        jax.eval_shape(tg.eval_step, *tg.eval_specs())
        jax.eval_shape(tg.init_params, *tg.init_specs())

    def test_meta_consistency(self, tg):
        meta = tg.meta()
        assert meta["num_params"] == tg.num_params == len(meta["param_names"])
        assert meta["num_quant_layers"] == tg.nl == len(meta["layers"])
        assert meta["train_outputs"]["then"][-1] == "correct"
        total_w = sum(l["weight_elems"] for l in meta["layers"])
        assert total_w > 0

    def test_wd_mask_targets_weights_only(self, tg):
        for name, wd in zip(tg.param_names, tg.wd_mask):
            assert wd == name.endswith("/w")
