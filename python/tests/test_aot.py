"""AOT exporter: HLO-text emission and metadata integrity."""

import json

import jax
import jax.numpy as jnp
import pytest

from compile import models, train
from compile.aot import to_hlo_text


class TestHloText:
    def test_simple_fn_lowering(self):
        def fn(x, y):
            return (x @ y + 1.0,)

        spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
        lowered = jax.jit(fn).lower(spec, spec)
        text = to_hlo_text(lowered)
        assert text.startswith("HloModule")
        assert "dot" in text
        assert "ENTRY" in text
        # return_tuple=True: root is a tuple
        assert "tuple(" in text

    def test_quantizer_lowering_contains_rounding(self):
        from compile.quant import fake_quant

        lowered = jax.jit(lambda x, n: (fake_quant(x, n),)).lower(
            jax.ShapeDtypeStruct((256,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.float32),
        )
        text = to_hlo_text(lowered)
        assert "round" in text  # the quantizer's Round op survives lowering

    def test_train_step_lowering_smoke(self):
        # The full train graph for the tiny MLP lowers to valid HLO text.
        tg = train.TrainGraph(models.mlp(din=4, hidden=(8,), num_classes=2),
                              batch_size=2)
        lowered = jax.jit(tg.train_step).lower(*tg.train_specs())
        text = to_hlo_text(lowered)
        assert text.startswith("HloModule")
        # fwd + bwd + update: several dots
        assert text.count(" dot(") >= 3


class TestMetaJson:
    @pytest.mark.parametrize("name", ["mlp", "resnet_s"])
    def test_meta_is_json_serializable_and_complete(self, name):
        m = models.build(name)
        tg = train.TrainGraph(m, batch_size=4)
        meta = tg.meta()
        text = json.dumps(meta)
        back = json.loads(text)
        assert back["num_params"] == len(back["param_names"])
        assert back["num_quant_layers"] == len(back["layers"])
        for layer in back["layers"]:
            for key in ("name", "kind", "weight_elems", "act_in_elems",
                        "macs", "cin", "cout", "kernel", "out_spatial"):
                assert key in layer
        assert back["train_inputs"]["then"][-1] == "bits_mask"
        assert back["eval_outputs"] == ["loss", "correct", "act_min", "act_max"]

    def test_param_names_sorted_dict_order(self):
        # tree_flatten sorts dict keys: 'b' before 'bn' before 'w'.
        m = models.build("alexnet_s")
        tg = train.TrainGraph(m, batch_size=2)
        assert tg.param_names[0].endswith("/b")
        # every weight leaf has a matching name
        assert all("/" in n for n in tg.param_names)
