"""Group-granularity (per-channel) quantization: fused rowwise kernel vs
oracle, layout round-trips, per-group bitlength vectors."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fake_quant_group import (
    fake_quant_groups_pallas,
    fake_quant_groups_ref,
    fake_quant_per_channel,
)

settings.register_profile("ci", max_examples=20, deadline=None)
settings.load_profile("ci")


def rand(shape, seed=0):
    return jnp.asarray(np.random.default_rng(seed).normal(size=shape).astype(np.float32))


class TestGroupKernel:
    @given(
        groups=st.integers(1, 24),
        elems=st.integers(1, 200),
        n=st.floats(1.0, 10.0),
        seed=st.integers(0, 2**16),
    )
    def test_matches_oracle_scalar_n(self, groups, elems, n, seed):
        x = rand((groups, elems), seed)
        got = fake_quant_groups_pallas(x, n)
        want = fake_quant_groups_ref(x, n)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    @given(groups=st.integers(1, 16), seed=st.integers(0, 2**16))
    def test_per_group_bit_vector(self, groups, seed):
        x = rand((groups, 64), seed)
        rng = np.random.default_rng(seed + 1)
        n = jnp.asarray(rng.uniform(1.0, 9.0, groups).astype(np.float32))
        got = fake_quant_groups_pallas(x, n)
        want = fake_quant_groups_ref(x, n)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_groups_are_independent(self):
        # Changing one row must not affect another row's output.
        x = rand((4, 32), 3)
        base = np.asarray(fake_quant_groups_pallas(x, 3.0))
        x2 = x.at[0].multiply(100.0)
        out2 = np.asarray(fake_quant_groups_pallas(x2, 3.0))
        np.testing.assert_array_equal(base[1:], out2[1:])
        assert not np.allclose(base[0], out2[0])

    def test_matches_layerwise_ref_axes(self, ):
        # Per-channel == fake_quant_ref with axes grouping.
        x = rand((8, 40), 5)
        got = fake_quant_groups_pallas(x, 4.0)
        want = ref.fake_quant_ref(x, 4.0, axes=(1,))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


class TestPerChannel:
    def test_conv_weight_layout_roundtrip(self):
        # HWIO conv weight, channel axis = -1 (cout).
        w = rand((3, 3, 16, 32), 7)
        q = fake_quant_per_channel(w, 4.0, channel_axis=-1)
        assert q.shape == w.shape
        # Each output channel independently spans its own min/max grid.
        w_moved = np.moveaxis(np.asarray(w), -1, 0).reshape(32, -1)
        q_moved = np.moveaxis(np.asarray(q), -1, 0).reshape(32, -1)
        want = np.asarray(fake_quant_groups_ref(jnp.asarray(w_moved), 4.0))
        np.testing.assert_allclose(q_moved, want, rtol=1e-5, atol=1e-5)

    def test_finer_granularity_lower_error(self):
        # Per-channel quantization error <= per-tensor at the same bits
        # (each group gets its own range).
        w = rand((3, 3, 8, 16), 9) * jnp.linspace(0.1, 10.0, 16)  # varied scales
        per_tensor = ref.fake_quant_ref(w, 4.0)
        per_chan = fake_quant_per_channel(w, 4.0, channel_axis=-1)
        err_t = float(jnp.sum((w - per_tensor) ** 2))
        err_c = float(jnp.sum((w - per_chan) ** 2))
        assert err_c < err_t

    def test_middle_axis(self):
        x = rand((4, 6, 8), 11)
        q = fake_quant_per_channel(x, 3.0, channel_axis=1)
        assert q.shape == x.shape
        moved = np.moveaxis(np.asarray(x), 1, 0).reshape(6, -1)
        want = np.asarray(fake_quant_groups_ref(jnp.asarray(moved), 3.0))
        got = np.moveaxis(np.asarray(q), 1, 0).reshape(6, -1)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
