"""L2 training/eval graphs and their flattened AOT-facing signatures.

The rust coordinator drives training through two compiled artifacts per
model:

  train_step(*params, *momenta, bits_w, bits_a, lam_w, lam_a,
             x, y, lr, bits_lr, gamma, bits_mask)
      -> (*new_params, *new_momenta, new_bits_w, new_bits_a,
          loss, task_loss, bit_loss, correct)

  eval_step(*params, bits_w, bits_a, x, y)
      -> (loss, correct, act_min[num_layers], act_max[num_layers])

Everything the paper's phases need is runtime-switchable without
re-export:
  * gamma, lam_w, lam_a     — regularizer strength / criterion weighting
                              (Tables II, IV)
  * bits_mask (0.0 / 1.0)   — gates the bitlength update: 1.0 in the
                              learning phase, 0.0 after integer selection
                              (paper §II-C) and for PACT-style fixed-
                              uniform baselines
  * lr, bits_lr             — one-cycle schedule is computed in rust and
                              fed per step
  * bits_w / bits_a         — state tensors; rust ceils them between
                              phases (select_integer_bits)

eval_step additionally reports per-layer activation ranges, feeding the
profiled post-training baseline (Table VII) without a separate artifact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .kernels import ref

MOMENTUM = 0.9
WEIGHT_DECAY = 5e-4


def _path_name(path):
    parts = []
    for p in path:
        if hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "key"):
            parts.append(str(p.key))
        else:
            parts.append(str(p))
    return "/".join(parts)


class TrainGraph:
    """Binds a Model to flattened, AOT-exportable train/eval functions."""

    def __init__(self, model, batch_size):
        self.model = model
        self.batch_size = batch_size
        example = model.init(jax.random.PRNGKey(0))
        leaves_with_path, self.treedef = jax.tree_util.tree_flatten_with_path(example)
        self.param_names = [_path_name(p) for p, _ in leaves_with_path]
        self.param_shapes = [tuple(v.shape) for _, v in leaves_with_path]
        self.num_params = len(self.param_names)
        # Weight decay only on the matmul/conv weights, not biases/norms.
        self.wd_mask = [name.endswith("/w") for name in self.param_names]
        self.nl = model.num_quant_layers

    # -- pytree plumbing ----------------------------------------------------

    def unflatten(self, leaves):
        return jax.tree_util.tree_unflatten(self.treedef, list(leaves))

    def flatten(self, tree):
        return jax.tree_util.tree_leaves(tree)

    # -- losses ---------------------------------------------------------------

    def task_loss_and_correct(self, params, bits_w, bits_a, x, y):
        logits = self.model.apply(params, x, bits_w, bits_a)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))
        correct = jnp.sum((jnp.argmax(logits, axis=1) == y).astype(jnp.float32))
        return nll, correct

    # -- exported functions ---------------------------------------------------

    def train_step(self, *args):
        np_ = self.num_params
        params = self.unflatten(args[:np_])
        mom = self.unflatten(args[np_:2 * np_])
        (bits_w, bits_a, lam_w, lam_a, x, y,
         lr, bits_lr, gamma, bits_mask) = args[2 * np_:]

        def loss_fn(params, bits_w, bits_a):
            task, correct = self.task_loss_and_correct(params, bits_w, bits_a, x, y)
            bl = ref.bit_loss(bits_w, lam_w) + ref.bit_loss(bits_a, lam_a)
            return task + gamma * bl, (task, bl, correct)

        grad_fn = jax.grad(loss_fn, argnums=(0, 1, 2), has_aux=True)
        (g_p, g_bw, g_ba), (task, bl, correct) = grad_fn(params, bits_w, bits_a)

        # SGD + momentum, decoupled weight decay on weight matrices.
        new_p, new_m = [], []
        for leaf, g, m, wd in zip(self.flatten(params), self.flatten(g_p),
                                  self.flatten(mom), self.wd_mask):
            if wd:
                g = g + WEIGHT_DECAY * leaf
            m2 = MOMENTUM * m + g
            new_p.append(leaf - lr * m2)
            new_m.append(m2)

        nbw = ref.clip_bits(bits_w - bits_lr * bits_mask * g_bw)
        nba = ref.clip_bits(bits_a - bits_lr * bits_mask * g_ba)
        loss = task + gamma * bl
        return (*new_p, *new_m, nbw, nba, loss, task, bl, correct)

    def eval_step(self, *args):
        np_ = self.num_params
        params = self.unflatten(args[:np_])
        bits_w, bits_a, x, y = args[np_:]
        with L.collect_act_ranges() as taps:
            task, correct = self.task_loss_and_correct(params, bits_w, bits_a, x, y)
        act_min = jnp.stack([t[0] for t in taps])
        act_max = jnp.stack([t[1] for t in taps])
        return task, correct, act_min, act_max

    def init_params(self, seed):
        """Exported init artifact: u32 seed -> flat param leaves."""
        params = self.model.init(jax.random.PRNGKey(seed))
        return tuple(self.flatten(params))

    # -- example args for lowering -------------------------------------------

    def _data_specs(self):
        xs = jax.ShapeDtypeStruct((self.batch_size, *self.model.input_shape), jnp.float32)
        ys = jax.ShapeDtypeStruct((self.batch_size,), jnp.int32)
        return xs, ys

    def train_specs(self):
        f32 = jnp.float32
        p = [jax.ShapeDtypeStruct(s, f32) for s in self.param_shapes]
        vec = jax.ShapeDtypeStruct((self.nl,), f32)
        sc = jax.ShapeDtypeStruct((), f32)
        xs, ys = self._data_specs()
        return (*p, *p, vec, vec, vec, vec, xs, ys, sc, sc, sc, sc)

    def eval_specs(self):
        f32 = jnp.float32
        p = [jax.ShapeDtypeStruct(s, f32) for s in self.param_shapes]
        vec = jax.ShapeDtypeStruct((self.nl,), f32)
        xs, ys = self._data_specs()
        return (*p, vec, vec, xs, ys)

    def init_specs(self):
        return (jax.ShapeDtypeStruct((), jnp.uint32),)

    # -- metadata for the rust side -------------------------------------------

    def meta(self):
        m = self.model
        return {
            "model": m.name,
            "batch_size": self.batch_size,
            "input_shape": list(m.input_shape),
            "num_classes": m.num_classes,
            "num_quant_layers": m.num_quant_layers,
            "num_params": self.num_params,
            "param_names": self.param_names,
            "param_shapes": [list(s) for s in self.param_shapes],
            "layers": [i.to_json() for i in m.infos],
            "momentum": MOMENTUM,
            "weight_decay": WEIGHT_DECAY,
            "n_min": ref.N_MIN,
            "n_max": ref.N_MAX,
            "train_inputs": {
                "params": self.num_params,
                "momenta": self.num_params,
                "then": ["bits_w", "bits_a", "lam_w", "lam_a", "x", "y",
                         "lr", "bits_lr", "gamma", "bits_mask"],
            },
            "train_outputs": {
                "params": self.num_params,
                "momenta": self.num_params,
                "then": ["bits_w", "bits_a", "loss", "task_loss", "bit_loss",
                         "correct"],
            },
            "eval_inputs": {"params": self.num_params,
                            "then": ["bits_w", "bits_a", "x", "y"]},
            "eval_outputs": ["loss", "correct", "act_min", "act_max"],
        }
