"""L1 Pallas kernel: fused quantize -> matmul.

The MXU-facing half of the hot path (DESIGN.md §4): both operand tiles
are fake-quantized on the VMEM load path and immediately fed to the
systolic array, so quantized activations/weights never round-trip to
HBM.  This is the TPU translation of a tensor-core GEMM with a
quantization prologue.

Grid is (M/bm, N/bn, K/bk) with accumulation over the k axis into the
output tile (revisited across k steps — standard Pallas accumulation
pattern).  Tiles default to 128x128, the MXU shape.

interpret=True: see fake_quant.py.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

MXU_TILE = 128


def _quant_tile(x, n, lmin, lmax):
    """In-register Q_r of one tile (same math as _fake_quant_kernel)."""
    n = jnp.clip(n, ref.N_MIN, ref.N_MAX)
    rng = jnp.maximum(lmax - lmin, ref._RANGE_EPS)
    b = jnp.floor(n)
    a = n - b
    s_b = rng / (jnp.exp2(b) - 1.0)
    s_b1 = rng / (jnp.exp2(b + 1.0) - 1.0)
    centred = x - lmin
    qb = lmin + jnp.round(centred / s_b) * s_b
    qb1 = lmin + jnp.round(centred / s_b1) * s_b1
    return (1.0 - a) * qb + a * qb1


def _qmm_kernel(na_ref, amn_ref, amx_ref, nw_ref, wmn_ref, wmx_ref,
                a_ref, w_ref, o_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    aq = _quant_tile(a_ref[...], na_ref[0, 0], amn_ref[0, 0], amx_ref[0, 0])
    wq = _quant_tile(w_ref[...], nw_ref[0, 0], wmn_ref[0, 0], wmx_ref[0, 0])
    # f32 accumulate on the MXU; bf16 inputs would use
    # preferred_element_type=jnp.float32 on real hardware.
    o_ref[...] += jnp.dot(aq, wq, preferred_element_type=jnp.float32)


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def quant_matmul_pallas(a, w, n_a, n_w, *, tile_m=MXU_TILE, tile_n=MXU_TILE,
                        tile_k=MXU_TILE):
    """Fused fake-quant + matmul: (M,K) @ (K,N) with per-tensor groups.

    Group min/max are computed with the pallas reduction from
    fake_quant.py, matching the training-time batch-min/max semantics.
    """
    from .fake_quant import minmax_pallas

    amn, amx = minmax_pallas(a)
    wmn, wmx = minmax_pallas(w)

    m, k = a.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {a.shape} @ {w.shape}"

    tm, tn, tk = min(tile_m, _ceil_to(m, 8)), min(tile_n, _ceil_to(n, 8)), min(tile_k, _ceil_to(k, 8))
    mp, np_, kp = _ceil_to(m, tm), _ceil_to(n, tn), _ceil_to(k, tk)
    # Zero padding is safe: padded K contributes 0 to the accumulation
    # *after* quantization only if lmin <= 0 <= lmax is not required —
    # we pad before min/max were taken (min/max already computed on the
    # unpadded tensors) and padded rows/cols are sliced away below, so
    # only the K padding matters; quantized zeros are Q(0), a constant
    # across the padded block, contributing Q_a(0)*Q_w(0)*pad_k equally
    # to all entries... To keep exactness we instead pad K with lmin==0
    # surrogate: simplest correct choice is to pad with zeros AND extend
    # the quantizer domain so Q(0)=0. That holds iff 0 in [lmin, lmax]
    # maps to a representable point, which is not guaranteed. So: pad K
    # only by quantizing first in the padded region = quantize(0) and
    # subtract the constant afterwards. In practice all our call sites
    # have K % tk == 0; enforce it.
    if kp != k:
        raise ValueError(
            f"quant_matmul_pallas requires K ({k}) divisible by tile_k ({tk}); "
            "pick tile_k to divide K (call sites use MXU-aligned shapes)")
    a_p = jnp.pad(a, ((0, mp - m), (0, 0)))
    w_p = jnp.pad(w, ((0, 0), (0, np_ - n)))

    as11 = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)
    scalar_spec = pl.BlockSpec((1, 1), lambda i, j, kk: (0, 0))
    out = pl.pallas_call(
        _qmm_kernel,
        grid=(mp // tm, np_ // tn, kp // tk),
        in_specs=[
            scalar_spec, scalar_spec, scalar_spec,   # n_a, amn, amx
            scalar_spec, scalar_spec, scalar_spec,   # n_w, wmn, wmx
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(as11(n_a), as11(amn), as11(amx), as11(n_w), as11(wmn), as11(wmx), a_p, w_p)
    return out[:m, :n]


def mxu_utilization_estimate(m: int, n: int, k: int,
                             tile=MXU_TILE) -> float:
    """Structural MXU utilization estimate for EXPERIMENTS.md §Perf:
    fraction of systolic-array slots doing useful work given edge tiles."""
    def eff(dim):
        tiles = max(1, -(-dim // tile))
        return dim / (tiles * tile)
    return eff(m) * eff(n) * eff(k)
