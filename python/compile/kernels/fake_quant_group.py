"""L1 Pallas kernel: group-granularity (e.g. per-channel) fake quant.

The paper's method applies at *any* statically-chosen granularity
(§I/§III).  This kernel implements the finer-than-layer case: the input
is reshaped to [groups, elems], each row is an independent quantization
group with its own Lmin/Lmax and (optionally) its own learned bitlength.

Unlike the per-tensor kernel (fake_quant.py) which needs a separate
min/max reduction pass, each row here fits one VMEM block, so the kernel
fuses reduce + quantize into a **single HBM read and write per element**
— the per-channel case is where the fusion win is largest on real
hardware (one pass instead of three).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _rowwise_kernel(n_ref, x_ref, o_ref):
    """One grid step = one group row: fused minmax + interpolated quant."""
    x = x_ref[...]
    lmin = jnp.min(x)
    lmax = jnp.max(x)
    rng = jnp.maximum(lmax - lmin, ref._RANGE_EPS)
    n = jnp.clip(n_ref[0, 0], ref.N_MIN, ref.N_MAX)
    b = jnp.floor(n)
    a = n - b
    s_b = rng / (jnp.exp2(b) - 1.0)
    s_b1 = rng / (jnp.exp2(b + 1.0) - 1.0)
    centred = x - lmin
    qb = lmin + jnp.round(centred / s_b) * s_b
    qb1 = lmin + jnp.round(centred / s_b1) * s_b1
    o_ref[...] = (1.0 - a) * qb + a * qb1


def fake_quant_groups_pallas(x2d, n):
    """Fake-quantize [groups, elems] rows independently.

    `n` is either a scalar (shared bitlength) or a [groups] vector (one
    learned bitlength per group).
    """
    groups, elems = x2d.shape
    n = jnp.asarray(n, jnp.float32)
    n_vec = jnp.broadcast_to(n.reshape(-1), (groups,)).reshape(groups, 1)
    return pl.pallas_call(
        _rowwise_kernel,
        grid=(groups,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),      # per-row n
            pl.BlockSpec((1, elems), lambda i: (i, 0)),  # row
        ],
        out_specs=pl.BlockSpec((1, elems), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((groups, elems), x2d.dtype),
        interpret=True,
    )(n_vec, x2d)


def fake_quant_per_channel(x, n, channel_axis=-1):
    """Per-channel fake quantization of an arbitrary tensor.

    Moves `channel_axis` to the front, groups the rest, runs the fused
    rowwise kernel, and restores the layout.  `n` may be scalar or a
    per-channel vector.
    """
    x_moved = jnp.moveaxis(x, channel_axis, 0)
    shape = x_moved.shape
    x2d = x_moved.reshape(shape[0], -1)
    q = fake_quant_groups_pallas(x2d, n)
    return jnp.moveaxis(q.reshape(shape), 0, channel_axis)


def fake_quant_groups_ref(x2d, n):
    """Oracle: per-row min/max + interpolated quantization in pure jnp."""
    lmin = jnp.min(x2d, axis=1, keepdims=True)
    lmax = jnp.max(x2d, axis=1, keepdims=True)
    n = jnp.broadcast_to(jnp.asarray(n, jnp.float32).reshape(-1), (x2d.shape[0],))
    return ref.quantize_interp(x2d, lmin, lmax, n.reshape(-1, 1))
