"""Pure-jnp reference oracle for the BitPruning quantizer.

This module is the *ground truth* for every other implementation in the
repo: the Pallas kernels (fake_quant.py, quant_matmul.py) are checked
against it in python/tests, and the rust mirror (rust/src/quant/) is
checked against the exported HLO of these functions in the rust
integration tests.

Math (paper §II-A), per value-group (layer by default):

    Scale(n)   = (Lmax - Lmin) / (2^n - 1)
    Int(V, n)  = Round((V - Lmin) / Scale(n))
    Q_i(V, n)  = Lmin + Int(V, n) * Scale(n)
    Q_r(V, b+a)= (1-a) * Q_i(V, b) + a * Q_i(V, b+1)      , 0 <= a < 1

with n clipped to [N_MIN, N_MAX].  Rounding is round-half-to-even
(jnp.round semantics); the rust mirror uses f32::round_ties_even to stay
bit-compatible.
"""

from __future__ import annotations

import jax.numpy as jnp

# Paper clips bitlengths at 1.0 from below; we also cap above.  16 bits is
# beyond any useful quantization target and keeps 2^n exactly
# representable in f32.
N_MIN = 1.0
N_MAX = 16.0

# Guards the degenerate all-equal group (Lmax == Lmin): the quantizer is
# the identity there and gradients w.r.t. n vanish.
_RANGE_EPS = 1e-12


def clip_bits(n):
    """Clip a (possibly learned, non-integer) bitlength into the valid range."""
    return jnp.clip(n, N_MIN, N_MAX)


def group_minmax(x, axes=None):
    """Lmin/Lmax of a value group.

    axes=None reduces over everything (per-tensor / per-layer group, the
    paper's reported granularity); an axes tuple keeps the remaining
    dimensions as independent groups (e.g. per-channel).
    """
    lmin = jnp.min(x, axis=axes, keepdims=axes is not None)
    lmax = jnp.max(x, axis=axes, keepdims=axes is not None)
    return lmin, lmax


def scale(lmin, lmax, n):
    """Smallest representable step for an n-bit group over [lmin, lmax]."""
    rng = jnp.maximum(lmax - lmin, _RANGE_EPS)
    return rng / (jnp.exp2(n) - 1.0)


def quantize_int(x, lmin, lmax, n):
    """Q_i: uniform min/max quantization with (float-typed) bitlength n.

    Valid for integer n; also evaluated at floor(n)/floor(n)+1 by the
    interpolated quantizer.  Returns the *dequantized* float value.
    """
    s = scale(lmin, lmax, n)
    q = jnp.round((x - lmin) / s)
    return lmin + q * s


def quantize_interp(x, lmin, lmax, n):
    """Q_r: interpolated non-integer-bitlength quantization (paper eq. 4).

    n may be a scalar or broadcastable against x; it is clipped to
    [N_MIN, N_MAX] here so callers can hand in raw learned parameters.
    """
    n = clip_bits(n)
    b = jnp.floor(n)
    a = n - b
    qb = quantize_int(x, lmin, lmax, b)
    qb1 = quantize_int(x, lmin, lmax, b + 1.0)
    return (1.0 - a) * qb + a * qb1


def interp_delta(x, lmin, lmax, n):
    """dQ_r/dn = Q_i(V, b+1) - Q_i(V, b): the bitlength gradient kernel.

    (The a-derivative of the interpolation; used by the custom_vjp in
    quant.py and finite-difference-checked in tests.)
    """
    n = clip_bits(n)
    b = jnp.floor(n)
    return quantize_int(x, lmin, lmax, b + 1.0) - quantize_int(x, lmin, lmax, b)


def fake_quant_ref(x, n, axes=None):
    """Full reference path: group min/max + interpolated quantization."""
    lmin, lmax = group_minmax(x, axes)
    return quantize_interp(x, lmin, lmax, n)


def quant_matmul_ref(a, w, n_a, n_w):
    """Reference for the fused kernel: quantize both operands (per-tensor
    groups), then matmul in f32."""
    aq = fake_quant_ref(a, n_a)
    wq = fake_quant_ref(w, n_w)
    return aq @ wq


def bit_loss(bits, lam):
    """Regularizer term: sum_i lambda_i * n_i (paper §II-B).

    `bits` and `lam` are flat vectors over all weight/activation groups.
    The total training loss is L_task + gamma * bit_loss.
    """
    return jnp.sum(clip_bits(bits) * lam)


def equal_layer_lambdas(num_groups):
    """lambda_i such that an all-8-bit network yields bit_loss == 1.0 with
    every group weighted equally (paper §II-B default)."""
    return jnp.full((num_groups,), 1.0 / (8.0 * num_groups), dtype=jnp.float32)


def weighted_lambdas(costs):
    """lambda_i proportional to a per-group cost (element count for memory
    footprint, MAC count for compute — paper §III-A5), normalized so an
    all-8-bit network yields bit_loss == 1.0."""
    costs = jnp.asarray(costs, dtype=jnp.float32)
    return costs / (8.0 * jnp.sum(costs))
