"""L1 Pallas kernels: interpolated-bitlength fake quantization.

Two kernels implement the BitPruning hot path:

  * ``minmax_pallas`` — grid reduction producing the group Lmin/Lmax.
  * ``fake_quant_pallas`` — single fused pass applying the interpolated
    quantizer Q_r to a VMEM-sized block: scale computation, round,
    dequant and interpolation all happen in-register, one HBM read and
    one HBM write per element.

TPU adaptation (DESIGN.md §4): the paper's CUDA-era mental model
(elementwise grid-stride loop) becomes a BlockSpec-tiled VMEM schedule.
Blocks are sized by ``pick_block`` to land in the 16-128 KiB VMEM sweet
spot.  ``interpret=True`` everywhere: the CPU PJRT plugin cannot run
Mosaic custom-calls, so the kernels lower to plain HLO and the real-TPU
numbers are estimated structurally (EXPERIMENTS.md §Perf).

Scalars (n, lmin, lmax) are passed as (1, 1) f32 arrays: on real TPU they
would live in SMEM; in interpret mode they are ordinary refs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# VMEM budget per operand block.  Real TPUv4 VMEM is ~16 MiB/core; we keep
# each block well under that so double-buffering and the output block fit.
_VMEM_BLOCK_BYTES = 128 * 1024
_LANE = 128  # TPU lane width; last dim of any block should be a multiple.


def pick_block(dim: int, max_elems: int) -> int:
    """Largest lane-aligned block <= max_elems that divides/covers `dim`."""
    if dim <= max_elems:
        return dim
    blk = (max_elems // _LANE) * _LANE
    return max(blk, _LANE)


def _pad_to(x, mult):
    """Pad trailing dim of a flat vector up to a multiple of `mult`."""
    n = x.shape[-1]
    rem = (-n) % mult
    if rem:
        # Padding with the first element keeps min/max unchanged.
        x = jnp.concatenate([x, jnp.broadcast_to(x[..., :1], x.shape[:-1] + (rem,))], -1)
    return x


# ---------------------------------------------------------------------------
# min/max grid reduction
# ---------------------------------------------------------------------------

def _minmax_kernel(x_ref, mn_ref, mx_ref):
    """Each grid step reduces one block to a partial (min, max) pair."""
    blk = x_ref[...]
    mn_ref[0, 0] = jnp.min(blk)
    mx_ref[0, 0] = jnp.max(blk)


def minmax_pallas(x):
    """Group min/max of an arbitrary tensor via a two-stage reduction:
    pallas block partials, then a tiny jnp reduce over the partial vector
    (the second stage is O(num_blocks) and fuses into the same HLO)."""
    flat = x.reshape(-1)
    blk = pick_block(flat.shape[0], _VMEM_BLOCK_BYTES // 4)
    flat = _pad_to(flat, blk)
    nblk = flat.shape[0] // blk
    mn, mx = pl.pallas_call(
        _minmax_kernel,
        grid=(nblk,),
        in_specs=[pl.BlockSpec((blk,), lambda i: (i,))],
        out_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblk, 1), x.dtype),
            jax.ShapeDtypeStruct((nblk, 1), x.dtype),
        ],
        interpret=True,
    )(flat)
    return jnp.min(mn), jnp.max(mx)


# ---------------------------------------------------------------------------
# fused interpolated quantizer
# ---------------------------------------------------------------------------

def _fake_quant_kernel(n_ref, mn_ref, mx_ref, x_ref, o_ref):
    """One fused VMEM pass of Q_r over a block.

    Everything is computed from three scalars; the per-element work is
    2 fma-class ops per Q_i plus the interpolation blend — bandwidth
    bound, which is why the single-pass fusion matters.
    """
    n = jnp.clip(n_ref[0, 0], ref.N_MIN, ref.N_MAX)
    lmin = mn_ref[0, 0]
    lmax = mx_ref[0, 0]
    rng = jnp.maximum(lmax - lmin, ref._RANGE_EPS)
    b = jnp.floor(n)
    a = n - b
    s_b = rng / (jnp.exp2(b) - 1.0)
    s_b1 = rng / (jnp.exp2(b + 1.0) - 1.0)

    x = x_ref[...]
    centred = x - lmin
    qb = lmin + jnp.round(centred / s_b) * s_b
    qb1 = lmin + jnp.round(centred / s_b1) * s_b1
    o_ref[...] = (1.0 - a) * qb + a * qb1


def fake_quant_pallas(x, n, lmin=None, lmax=None):
    """Interpolated fake-quantization of a whole tensor (per-tensor group).

    If lmin/lmax are not supplied they are computed by the pallas
    reduction above (training path: batch min/max, paper §II-A).
    `n` is a scalar (learned bitlength parameter, pre-clip).
    """
    if lmin is None or lmax is None:
        lmin, lmax = minmax_pallas(x)
    shape = x.shape
    flat = x.reshape(-1)
    orig = flat.shape[0]
    blk = pick_block(orig, _VMEM_BLOCK_BYTES // 4)
    flat = _pad_to(flat, blk)
    nblk = flat.shape[0] // blk

    as11 = lambda v: jnp.asarray(v, jnp.float32).reshape(1, 1)
    out = pl.pallas_call(
        _fake_quant_kernel,
        grid=(nblk,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # n
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # lmin
            pl.BlockSpec((1, 1), lambda i: (0, 0)),  # lmax
            pl.BlockSpec((blk,), lambda i: (i,)),    # x block
        ],
        out_specs=pl.BlockSpec((blk,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(flat.shape, x.dtype),
        interpret=True,
    )(as11(n), as11(lmin), as11(lmax), flat)
    return out[:orig].reshape(shape)


# Structural perf model (DESIGN.md §8): bytes moved per element for the
# fused kernel vs the unfused reference graph.  Used by EXPERIMENTS.md
# §Perf to report the expected TPU-side win of the fusion.
FUSED_HBM_BYTES_PER_ELEM = 8      # 1 read + 1 write (f32)
UNFUSED_HBM_BYTES_PER_ELEM = 28   # minmax read + qb rt + qb1 rt + blend w


def vmem_bytes(block_elems: int, dtype_bytes: int = 4) -> int:
    """VMEM footprint of one fake-quant grid step (in + out block)."""
    return 2 * block_elems * dtype_bytes
