"""L2 model zoo: scaled-down counterparts of the paper's architectures.

Each builder returns a `Model` with:
  * init(key)                      -> params pytree (list of layer dicts)
  * apply(params, x, bits_w, bits_a) -> logits
  * infos                          -> [LayerInfo] (one per quantized layer,
                                      index i consumes bits_w[i]/bits_a[i])

Architectures (DESIGN.md §3 substitutions):
  * mlp         — 3 dense layers, for blobs/spirals workloads
  * alexnet_s   — conv stack + fc head, AlexNet's role (plain deep CNN)
  * resnet_s    — residual blocks, ResNet18's role (skip connections)
  * mobilenet_s — depthwise-separable blocks, MobileNetV2's role

`alexnet_s` accepts per-layer width multipliers to regenerate the paper's
Table V channel-depth ablation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import layers as L
from .layers import LayerInfo


class Model:
    def __init__(self, name, init, apply, infos, input_shape, num_classes):
        self.name = name
        self.init = init
        self.apply = apply
        self.infos = infos            # list[LayerInfo]
        self.input_shape = input_shape  # (H, W, C) or (D,)
        self.num_classes = num_classes

    @property
    def num_quant_layers(self):
        return len(self.infos)


def _split(key, k):
    return list(jax.random.split(key, k))


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def mlp(din=32, hidden=(256, 128), num_classes=10):
    dims = [din, *hidden, num_classes]
    infos = []
    for i in range(len(dims) - 1):
        infos.append(LayerInfo(
            name=f"fc{i}", kind="dense",
            weight_elems=dims[i] * dims[i + 1],
            act_in_elems=dims[i], macs=dims[i] * dims[i + 1],
            cin=dims[i], cout=dims[i + 1], kernel=1, out_spatial=1))

    def init(key):
        ks = _split(key, len(dims) - 1)
        return [{"w": L.he_dense(k, dims[i], dims[i + 1]),
                 "b": jnp.zeros((dims[i + 1],), jnp.float32)}
                for i, k in enumerate(ks)]

    def apply(params, x, bits_w, bits_a):
        h = x
        last = len(params) - 1
        for i, p in enumerate(params):
            h = L.dense_q(h, p, bits_w[i], bits_a[i])
            if i != last:
                h = L.relu(h)
        return h

    return Model("mlp", init, apply, infos, (din,), num_classes)


# ---------------------------------------------------------------------------
# AlexNet-S
# ---------------------------------------------------------------------------

def alexnet_s(input_size=16, num_classes=10, width_mults=None, fc_width=256):
    """Plain conv stack. width_mults: optional {conv_index: multiplier}
    applied to that conv's output channels (Table V ablation)."""
    width_mults = width_mults or {}
    base = [32, 64, 128, 128]
    chans = [max(4, int(round(c * width_mults.get(i, 1.0)))) for i, c in enumerate(base)]
    pool_after = {0, 1, 3}          # halve spatial after these convs

    infos, spatial, cin = [], input_size, 3
    for i, cout in enumerate(chans):
        infos.append(LayerInfo(
            name=f"conv{i}", kind="conv",
            weight_elems=3 * 3 * cin * cout,
            act_in_elems=spatial * spatial * cin,
            macs=spatial * spatial * cout * 3 * 3 * cin,
            cin=cin, cout=cout, kernel=3, out_spatial=spatial))
        if i in pool_after:
            spatial //= 2
        cin = cout
    flat = spatial * spatial * cin
    for j, (di, do) in enumerate([(flat, fc_width), (fc_width, num_classes)]):
        infos.append(LayerInfo(
            name=f"fc{j}", kind="dense", weight_elems=di * do,
            act_in_elems=di, macs=di * do,
            cin=di, cout=do, kernel=1, out_spatial=1))

    def init(key):
        ks = _split(key, len(chans) + 2)
        params, ci = [], 3
        for i, co in enumerate(chans):
            params.append({"w": L.he_conv(ks[i], 3, 3, ci, co),
                           "b": jnp.zeros((co,), jnp.float32),
                           "bn": {"g": jnp.ones((co,), jnp.float32),
                                  "beta": jnp.zeros((co,), jnp.float32)}})
            ci = co
        params.append({"w": L.he_dense(ks[-2], flat, fc_width),
                       "b": jnp.zeros((fc_width,), jnp.float32)})
        params.append({"w": L.he_dense(ks[-1], fc_width, num_classes),
                       "b": jnp.zeros((num_classes,), jnp.float32)})
        return params

    def apply(params, x, bits_w, bits_a):
        h = x
        for i in range(len(chans)):
            p = params[i]
            h = L.conv2d_q(h, p, bits_w[i], bits_a[i])
            h = L.batch_norm(h, p["bn"])
            h = L.relu(h)
            if i in pool_after:
                h = L.max_pool(h)
        h = h.reshape(h.shape[0], -1)
        k = len(chans)
        h = L.relu(L.dense_q(h, params[k], bits_w[k], bits_a[k]))
        return L.dense_q(h, params[k + 1], bits_w[k + 1], bits_a[k + 1])

    return Model("alexnet_s", init, apply, infos,
                 (input_size, input_size, 3), num_classes)


# ---------------------------------------------------------------------------
# ResNet-S
# ---------------------------------------------------------------------------

def resnet_s(input_size=16, num_classes=10, stem=16, stages=((16, 2), (32, 2), (64, 2))):
    """ResNet-style: stem conv, residual stages (stride 2 between stages),
    global average pool, fc.  Projection shortcuts are quantized layers
    too (everything end-to-end)."""
    infos = []
    plan = []  # (kind, cin, cout, stride, spatial_in) in apply order

    spatial, cin = input_size, 3
    plan.append(("stem", cin, stem, 1, spatial)); cin = stem
    for si, (cout, blocks) in enumerate(stages):
        for bi in range(blocks):
            stride = 2 if (bi == 0 and si > 0) else 1
            proj = stride != 1 or cin != cout
            plan.append(("conv_a", cin, cout, stride, spatial))
            s_out = spatial // stride
            plan.append(("conv_b", cout, cout, 1, s_out))
            if proj:
                plan.append(("proj", cin, cout, stride, spatial))
            spatial, cin = s_out, cout
    plan.append(("fc", cin, num_classes, 1, 1))

    for kind, ci, co, stride, sp in plan:
        if kind == "fc":
            infos.append(LayerInfo("fc", "dense", ci * co, ci, ci * co,
                                   ci, co, 1, 1))
        else:
            k = 1 if kind == "proj" else 3
            so = sp // stride
            infos.append(LayerInfo(
                name=f"{kind}_{len(infos)}", kind="conv",
                weight_elems=k * k * ci * co,
                act_in_elems=sp * sp * ci,
                macs=so * so * co * k * k * ci,
                cin=ci, cout=co, kernel=k, out_spatial=so))

    def init(key):
        ks = _split(key, len(plan))
        params = []
        for (kind, ci, co, stride, sp), k in zip(plan, ks):
            if kind == "fc":
                params.append({"w": L.he_dense(k, ci, co),
                               "b": jnp.zeros((co,), jnp.float32)})
            else:
                ksz = 1 if kind == "proj" else 3
                params.append({"w": L.he_conv(k, ksz, ksz, ci, co),
                               "b": jnp.zeros((co,), jnp.float32),
                               "bn": {"g": jnp.ones((co,), jnp.float32),
                                      "beta": jnp.zeros((co,), jnp.float32)}})
        return params

    def apply(params, x, bits_w, bits_a):
        i = 0

        def step(h, stride):
            nonlocal i
            p = params[i]
            y = L.conv2d_q(h, p, bits_w[i], bits_a[i], stride=stride)
            y = L.batch_norm(y, p["bn"])
            i += 1
            return y

        h = L.relu(step(x, 1))                      # stem
        for si, (cout, blocks) in enumerate(stages):
            for bi in range(blocks):
                stride = 2 if (bi == 0 and si > 0) else 1
                cin_blk = h.shape[-1]
                proj = stride != 1 or cin_blk != cout
                y = L.relu(step(h, stride))          # conv_a
                y = step(y, 1)                       # conv_b
                sc = step(h, stride) if proj else h  # proj shortcut
                h = L.relu(y + sc)
        h = L.global_avg_pool(h)
        p = params[i]
        return L.dense_q(h, p, bits_w[i], bits_a[i])

    return Model("resnet_s", init, apply, infos,
                 (input_size, input_size, 3), num_classes)


# ---------------------------------------------------------------------------
# MobileNet-S
# ---------------------------------------------------------------------------

def mobilenet_s(input_size=16, num_classes=10,
                blocks=((16, 32, 2), (32, 64, 2), (64, 64, 1))):
    """Depthwise-separable stack: stem conv, then (dw3x3 + pw1x1) blocks.
    Each dw and pw conv is its own quantized layer (they stress the
    quantizer differently — dw convs are famously sensitive, mirroring
    the paper's MobileNetV2 needing more bits)."""
    infos, plan = [], []
    spatial, cin = input_size, 3
    plan.append(("stem", cin, 16, 1, spatial)); cin = 16
    for (ci, co, stride) in blocks:
        assert ci == cin, f"block chain mismatch {ci} != {cin}"
        plan.append(("dw", ci, ci, stride, spatial))
        spatial //= stride
        plan.append(("pw", ci, co, 1, spatial))
        cin = co
    plan.append(("fc", cin, num_classes, 1, 1))

    for kind, ci, co, stride, sp in plan:
        so = sp // stride
        if kind == "fc":
            infos.append(LayerInfo("fc", "dense", ci * co, ci, ci * co,
                                   ci, co, 1, 1))
        elif kind == "dw":
            infos.append(LayerInfo(
                f"dw_{len(infos)}", "dwconv",
                weight_elems=3 * 3 * ci,
                act_in_elems=sp * sp * ci,
                macs=so * so * ci * 3 * 3,
                cin=ci, cout=ci, kernel=3, out_spatial=so))
        else:  # stem or pw
            k = 3 if kind == "stem" else 1
            infos.append(LayerInfo(
                f"{kind}_{len(infos)}", "conv",
                weight_elems=k * k * ci * co,
                act_in_elems=sp * sp * ci,
                macs=so * so * co * k * k * ci,
                cin=ci, cout=co, kernel=k, out_spatial=so))

    def init(key):
        ks = _split(key, len(plan))
        params = []
        for (kind, ci, co, stride, sp), k in zip(plan, ks):
            if kind == "fc":
                params.append({"w": L.he_dense(k, ci, co),
                               "b": jnp.zeros((co,), jnp.float32)})
            elif kind == "dw":
                params.append({"w": L.he_conv(k, 3, 3, 1, ci),
                               "b": jnp.zeros((ci,), jnp.float32),
                               "bn": {"g": jnp.ones((ci,), jnp.float32),
                                      "beta": jnp.zeros((ci,), jnp.float32)}})
            else:
                ksz = 3 if kind == "stem" else 1
                params.append({"w": L.he_conv(k, ksz, ksz, ci, co),
                               "b": jnp.zeros((co,), jnp.float32),
                               "bn": {"g": jnp.ones((co,), jnp.float32),
                                      "beta": jnp.zeros((co,), jnp.float32)}})
        return params

    def apply(params, x, bits_w, bits_a):
        h = x
        for i, (kind, ci, co, stride, sp) in enumerate(plan):
            p = params[i]
            if kind == "fc":
                h = L.global_avg_pool(h)
                return L.dense_q(h, p, bits_w[i], bits_a[i])
            groups = ci if kind == "dw" else 1
            h = L.conv2d_q(h, p, bits_w[i], bits_a[i],
                           stride=stride, groups=groups)
            h = L.batch_norm(h, p["bn"])
            h = L.relu(h)
        raise AssertionError("unreachable: fc layer terminates the plan")

    return Model("mobilenet_s", init, apply, infos,
                 (input_size, input_size, 3), num_classes)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def build(name: str, **kw) -> Model:
    builders = {
        "mlp": mlp,
        "alexnet_s": alexnet_s,
        "resnet_s": resnet_s,
        "mobilenet_s": mobilenet_s,
    }
    if name not in builders:
        raise KeyError(f"unknown model '{name}'; have {sorted(builders)}")
    return builders[name](**kw)
