"""Differentiable BitPruning quantizer: custom_vjp STE wrappers.

Forward: the Pallas fused kernel (or the jnp reference — selectable, the
exported HLO is identical after interpret-mode lowering, but the pallas
path exercises the production kernel).

Backward (paper §II):
  * d/dV  — straight-through estimator: gradient passes unchanged through
    Round and through the (stop-gradiented) batch min/max.
  * d/dn  — through the interpolation weight alpha:
    dQ_r/dn = Q_i(V, b+1) - Q_i(V, b), reduced over the group.
    Recomputed in the backward pass (not stashed) to keep training-memory
    2x rather than 3x the fp32 baseline — matching the paper's §IV cost.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.fake_quant import fake_quant_pallas

# The exported artifacts use the pallas forward; tests flip this to check
# both paths produce identical HLO-level numerics.
USE_PALLAS_FORWARD = True


@jax.custom_vjp
def fake_quant(x, n):
    """Q_r(x, n) over a per-tensor group with batch min/max."""
    if USE_PALLAS_FORWARD:
        return fake_quant_pallas(x, n)
    return ref.fake_quant_ref(x, n)


def _fwd(x, n):
    return fake_quant(x, n), (x, n)


def _bwd(res, g):
    x, n = res
    lmin, lmax = ref.group_minmax(x)
    # STE for values; interpolation delta for the bitlength.
    dn = jnp.sum(g * ref.interp_delta(x, lmin, lmax, n))
    # Clip gating: outside [N_MIN, N_MAX] the clipped n is constant, so
    # the true derivative is 0 there (prevents n drifting ever lower
    # once pinned at 1 bit).
    gate = ((n > ref.N_MIN) | (dn < 0)) & ((n < ref.N_MAX) | (dn > 0))
    dn = jnp.where(gate, dn, 0.0)
    return g, dn.astype(jnp.float32).reshape(jnp.shape(n))


fake_quant.defvjp(_fwd, _bwd)


def fake_quant_frozen(x, n_int):
    """Inference/fine-tune-phase quantizer: integer bitlength, STE on
    values only (bitlength receives no gradient because it is passed as a
    constant/stop_gradient input)."""
    return fake_quant(x, jax.lax.stop_gradient(n_int))


def select_integer_bits(n):
    """Final bitlength selection (paper §II-C): smallest integer >= n,
    after clipping into the valid range."""
    return jnp.ceil(ref.clip_bits(n))
