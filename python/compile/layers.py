"""L2 quantized layers.

Every compute layer quantizes its input activations (one learned
bitlength per layer, `n_a`) and its weights (`n_w`) with the BitPruning
interpolated quantizer before the underlying op.  Biases and norm
parameters stay full precision (standard practice; the paper quantizes
weights and activations).

The network is end-to-end quantized — first layer input (the image) and
last layer included — matching the paper's "quantize all layers" stance.

Layers are pure functions over param dicts; models.py assembles them and
records per-layer geometry (element/MAC counts) for the loss weighting
and the rust accelerator models.
"""

from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp
from jax import lax

from .quant import fake_quant

# When set (by collect_act_ranges), every quantized layer appends the
# (min, max) of its input activations, in apply order — which matches the
# LayerInfo order for all models.  Feeds the eval artifact's per-layer
# range outputs, which the rust profiled baseline consumes.
_ACT_RANGE_COLLECTOR = None


@contextlib.contextmanager
def collect_act_ranges():
    global _ACT_RANGE_COLLECTOR
    prev = _ACT_RANGE_COLLECTOR
    _ACT_RANGE_COLLECTOR = taps = []
    try:
        yield taps
    finally:
        _ACT_RANGE_COLLECTOR = prev


def _tap_act(x):
    if _ACT_RANGE_COLLECTOR is not None:
        _ACT_RANGE_COLLECTOR.append((jnp.min(x), jnp.max(x)))


# ---------------------------------------------------------------------------
# init helpers (used by the exported init artifact)
# ---------------------------------------------------------------------------

def he_conv(key, kh, kw, cin, cout):
    fan_in = kh * kw * cin
    std = (2.0 / fan_in) ** 0.5
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * std


def he_dense(key, din, dout):
    std = (2.0 / din) ** 0.5
    return jax.random.normal(key, (din, dout), jnp.float32) * std


# ---------------------------------------------------------------------------
# quantized primitives
# ---------------------------------------------------------------------------

def conv2d_q(x, p, n_w, n_a, stride=1, padding="SAME", groups=1):
    """Quantized 2D conv, NHWC / HWIO. p = {'w': [kh,kw,cin/groups,cout], 'b': [cout]}."""
    _tap_act(x)
    xq = fake_quant(x, n_a)
    wq = fake_quant(p["w"], n_w)
    y = lax.conv_general_dilated(
        xq, wq,
        window_strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
    )
    return y + p["b"]


def dense_q(x, p, n_w, n_a):
    """Quantized fully-connected layer. p = {'w': [din,dout], 'b': [dout]}."""
    _tap_act(x)
    xq = fake_quant(x, n_a)
    wq = fake_quant(p["w"], n_w)
    return xq @ wq + p["b"]


# ---------------------------------------------------------------------------
# non-quantized support ops
# ---------------------------------------------------------------------------

def batch_norm(x, p, eps=1e-5):
    """Batch-statistics normalization (no running stats).

    Used identically in the train and eval graphs: statistics always come
    from the current batch, which keeps the exported eval artifact
    deterministic and stateless.  p = {'g': [c], 'beta': [c]}.
    """
    axes = tuple(range(x.ndim - 1))
    mu = jnp.mean(x, axis=axes, keepdims=True)
    var = jnp.var(x, axis=axes, keepdims=True)
    xn = (x - mu) * lax.rsqrt(var + eps)
    return xn * p["g"] + p["beta"]


def max_pool(x, size=2, stride=2):
    return lax.reduce_window(
        x, -jnp.inf, lax.max,
        (1, size, size, 1), (1, stride, stride, 1), "VALID")


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


def relu(x):
    return jnp.maximum(x, 0.0)


# ---------------------------------------------------------------------------
# layer geometry record — consumed by the loss weighting (lambda vectors)
# and by the rust accelerator models via the exported meta JSON
# ---------------------------------------------------------------------------

class LayerInfo:
    """Static geometry of one quantized layer (one (n_w, n_a) pair)."""

    def __init__(self, name, kind, weight_elems, act_in_elems, macs,
                 cin, cout, kernel, out_spatial):
        self.name = name
        self.kind = kind                  # 'conv' | 'dwconv' | 'dense'
        self.weight_elems = int(weight_elems)   # per network
        self.act_in_elems = int(act_in_elems)   # per sample
        self.macs = int(macs)                   # per sample
        self.cin = int(cin)
        self.cout = int(cout)
        self.kernel = int(kernel)
        self.out_spatial = int(out_spatial)

    def to_json(self):
        return {
            "name": self.name, "kind": self.kind,
            "weight_elems": self.weight_elems,
            "act_in_elems": self.act_in_elems,
            "macs": self.macs, "cin": self.cin, "cout": self.cout,
            "kernel": self.kernel, "out_spatial": self.out_spatial,
        }
