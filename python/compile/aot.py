"""AOT exporter: lower every (model, variant) to HLO text + meta JSON.

HLO *text* (NOT .serialize()) is the interchange format: jax >= 0.5 emits
HloModuleProtos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids and round-trips cleanly.  See /opt/xla-example/gen_hlo.py.

Artifacts per model (artifacts/):
    <model>_init.hlo.txt    seed(u32) -> flat params
    <model>_train.hlo.txt   see train.TrainGraph.train_step
    <model>_eval.hlo.txt    see train.TrainGraph.eval_step
    <model>_meta.json       shapes/names/layer geometry for the rust side

Plus kernel-level artifacts used by the rust<->python bit-exactness
integration tests:
    fake_quant.hlo.txt      (x[4096], n) -> Q_r(x, n)
    quant_matmul.hlo.txt    (a[64,128], w[128,96], n_a, n_w) -> a_q @ w_q

Python runs ONCE: `make artifacts` is a no-op when inputs are unchanged.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import models, train
from .kernels import ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_fn(fn, specs, path):
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    print(f"  wrote {path} ({len(text) // 1024} KiB)")


def export_model(name: str, batch_size: int, out_dir: str, *,
                 tag: str | None = None, **model_kw):
    m = models.build(name, **model_kw)
    tg = train.TrainGraph(m, batch_size)
    tag = tag or name
    print(f"[{tag}] layers={m.num_quant_layers} params={tg.num_params}")
    export_fn(tg.init_params, tg.init_specs(),
              os.path.join(out_dir, f"{tag}_init.hlo.txt"))
    export_fn(tg.train_step, tg.train_specs(),
              os.path.join(out_dir, f"{tag}_train.hlo.txt"))
    export_fn(tg.eval_step, tg.eval_specs(),
              os.path.join(out_dir, f"{tag}_eval.hlo.txt"))
    meta = tg.meta()
    meta["tag"] = tag
    meta["model_kw"] = {k: v for k, v in model_kw.items() if k != "width_mults"}
    if "width_mults" in model_kw:
        meta["width_mults"] = {str(k): v for k, v in model_kw["width_mults"].items()}
    with open(os.path.join(out_dir, f"{tag}_meta.json"), "w") as f:
        json.dump(meta, f, indent=1)


def export_kernels(out_dir: str):
    """Standalone kernel artifacts for the rust bit-exactness tests."""
    from .quant import fake_quant
    from .kernels.quant_matmul import quant_matmul_pallas

    f32 = jnp.float32
    export_fn(
        lambda x, n: (fake_quant(x, n),),
        (jax.ShapeDtypeStruct((4096,), f32), jax.ShapeDtypeStruct((), f32)),
        os.path.join(out_dir, "fake_quant.hlo.txt"))
    export_fn(
        lambda a, w, na, nw: (quant_matmul_pallas(a, w, na, nw),),
        (jax.ShapeDtypeStruct((64, 128), f32),
         jax.ShapeDtypeStruct((128, 96), f32),
         jax.ShapeDtypeStruct((), f32), jax.ShapeDtypeStruct((), f32)),
        os.path.join(out_dir, "quant_matmul.hlo.txt"))


# Table V (channel-depth ablation): alexnet_s with one conv widened x4 or
# narrowed x0.25.  Conv indices 0..3.
TABLE5_VARIANTS = [(i, m) for i in range(4) for m in (4.0, 0.25)]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--models", nargs="*",
                    default=["mlp", "alexnet_s", "resnet_s", "mobilenet_s"])
    ap.add_argument("--table5", action="store_true",
                    help="also export alexnet_s width variants (Table V)")
    ap.add_argument("--skip-kernels", action="store_true")
    ap.add_argument("--forward", choices=["pallas", "ref"], default="pallas",
                    help="fake-quant forward implementation baked into the "
                         "artifacts: the production pallas kernel, or the "
                         "numerically-identical pure-jnp reference (used by "
                         "the L2 perf comparison, EXPERIMENTS.md §Perf)")
    args = ap.parse_args()

    if args.forward == "ref":
        from . import quant
        quant.USE_PALLAS_FORWARD = False

    os.makedirs(args.out_dir, exist_ok=True)
    if not args.skip_kernels:
        export_kernels(args.out_dir)
    for name in args.models:
        export_model(name, args.batch, args.out_dir)
    if args.table5:
        for idx, mult in TABLE5_VARIANTS:
            mtag = "x4" if mult > 1 else "x025"
            export_model("alexnet_s", args.batch, args.out_dir,
                         tag=f"alexnet_s_w{idx}_{mtag}",
                         width_mults={idx: mult})
    print("done")


if __name__ == "__main__":
    main()
