//! Checkpointing: params / momenta / bitlengths snapshot save + load.
//!
//! Custom little-endian binary format (no serde in the environment):
//!
//! ```text
//! magic "BPCK" | version u32 | n_tensors u32
//! per tensor: name_len u32 | name bytes | rank u32 | dims u32* |
//!             dtype u8 (0=f32,1=i32,2=u32) | payload
//! ```
//!
//! Tensors are stored by name so checkpoints survive reordering; the
//! coordinator stores params as `p/<name>`, momenta as `m/<name>`, and
//! bitlengths as `bits_w` / `bits_a`.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{HostTensor, TensorData};

const MAGIC: &[u8; 4] = b"BPCK";
const VERSION: u32 = 1;

/// A named collection of tensors.
#[derive(Debug, Default, Clone)]
pub struct Checkpoint {
    pub tensors: BTreeMap<String, HostTensor>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, t: HostTensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint missing tensor '{name}'"))
    }

    /// All tensors under a prefix, in lexicographic name order.
    pub fn with_prefix(&self, prefix: &str) -> Vec<(&str, &HostTensor)> {
        self.tensors
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.as_str(), v))
            .collect()
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(t.dims().len() as u32).to_le_bytes());
            for &d in t.dims() {
                buf.extend_from_slice(&(d as u32).to_le_bytes());
            }
            match t.data() {
                TensorData::F32(v) => {
                    buf.push(0);
                    for x in v {
                        buf.extend_from_slice(&x.to_le_bytes());
                    }
                }
                TensorData::I32(v) => {
                    buf.push(1);
                    for x in v {
                        buf.extend_from_slice(&x.to_le_bytes());
                    }
                }
                TensorData::U32(v) => {
                    buf.push(2);
                    for x in v {
                        buf.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating checkpoint '{}'", path.display()))?;
        f.write_all(&buf)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let mut bytes = Vec::new();
        std::fs::File::open(path)
            .with_context(|| format!("opening checkpoint '{}'", path.display()))?
            .read_to_end(&mut bytes)?;
        let mut r = Reader { bytes: &bytes, pos: 0 };

        if r.take(4)? != MAGIC {
            bail!("'{}' is not a bitprune checkpoint", path.display());
        }
        let version = r.u32()?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let count = r.u32()? as usize;
        let mut tensors = BTreeMap::new();
        for _ in 0..count {
            let name_len = r.u32()? as usize;
            let name = String::from_utf8(r.take(name_len)?.to_vec())
                .context("checkpoint tensor name is not UTF-8")?;
            let rank = r.u32()? as usize;
            let mut dims = Vec::with_capacity(rank);
            for _ in 0..rank {
                dims.push(r.u32()? as usize);
            }
            let n: usize = dims.iter().product();
            let dtype = r.take(1)?[0];
            let t = match dtype {
                0 => {
                    let mut v = Vec::with_capacity(n);
                    for _ in 0..n {
                        v.push(f32::from_le_bytes(r.take(4)?.try_into().unwrap()));
                    }
                    HostTensor::f32(&dims, v)?
                }
                1 => {
                    let mut v = Vec::with_capacity(n);
                    for _ in 0..n {
                        v.push(i32::from_le_bytes(r.take(4)?.try_into().unwrap()));
                    }
                    HostTensor::i32(&dims, v)?
                }
                2 => {
                    let mut v = Vec::with_capacity(n);
                    for _ in 0..n {
                        v.push(u32::from_le_bytes(r.take(4)?.try_into().unwrap()));
                    }
                    HostTensor::u32(&dims, v)?
                }
                d => bail!("unknown dtype tag {d}"),
            };
            tensors.insert(name, t);
        }
        Ok(Self { tensors })
    }
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.bytes.len() {
            bail!("truncated checkpoint (at byte {})", self.pos);
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bitprune-ckpt-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let mut c = Checkpoint::new();
        c.insert("p/0/w", HostTensor::f32(&[2, 2], vec![1.0, -2.0, 3.5, 0.25]).unwrap());
        c.insert("bits_w", HostTensor::f32(&[3], vec![2.0, 3.5, 4.0]).unwrap());
        c.insert("y", HostTensor::i32(&[2], vec![-7, 9]).unwrap());
        c.insert("seed", HostTensor::scalar_u32(42));
        let path = tmpfile("roundtrip.bpck");
        c.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.tensors.len(), 4);
        assert_eq!(loaded.get("p/0/w").unwrap(), c.get("p/0/w").unwrap());
        assert_eq!(loaded.get("y").unwrap(), c.get("y").unwrap());
        assert_eq!(loaded.get("seed").unwrap().scalar().unwrap(), 42.0);
    }

    #[test]
    fn prefix_query_ordered() {
        let mut c = Checkpoint::new();
        c.insert("p/1", HostTensor::scalar_f32(1.0));
        c.insert("p/0", HostTensor::scalar_f32(0.0));
        c.insert("m/0", HostTensor::scalar_f32(9.0));
        let ps = c.with_prefix("p/");
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0].0, "p/0");
        assert_eq!(ps[1].0, "p/1");
    }

    #[test]
    fn corrupt_file_rejected() {
        let path = tmpfile("corrupt.bpck");
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        // truncated after header
        let mut good = Vec::new();
        good.extend_from_slice(b"BPCK");
        good.extend_from_slice(&1u32.to_le_bytes());
        good.extend_from_slice(&5u32.to_le_bytes()); // claims 5 tensors
        std::fs::write(&path, &good).unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn missing_tensor_error() {
        let c = Checkpoint::new();
        assert!(c.get("nope").is_err());
    }
}
