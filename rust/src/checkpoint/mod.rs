//! Checkpointing: params / momenta / bitlengths snapshot save + load.
//!
//! Custom little-endian binary format (no serde in the environment):
//!
//! ```text
//! magic "BPCK" | version u32 | n_tensors u32
//! per tensor: name_len u32 | name bytes | rank u32 | dims u32* |
//!             dtype u8 (0=f32,1=i32,2=u32) | payload
//! ```
//!
//! Tensors are stored by name so checkpoints survive reordering; the
//! coordinator stores params as `p/<name>`, momenta as `m/<name>`,
//! bitlengths as `bits_w` / `bits_a`, and (when available) calibrated
//! activation ranges as `cal/act_min` / `cal/act_max` — which is what
//! lets `bitprune export` turn a checkpoint into a batch-invariant
//! BPMA artifact without re-touching the dataset.
//!
//! The loader treats the file as untrusted and goes through the
//! bounded [`crate::util::binio::Reader`] (shared with the BPMA
//! artifact loader): every length/rank/count is validated against the
//! bytes actually present before anything is allocated, and the
//! element product uses `checked_mul` — a truncated or hostile file
//! fails cleanly instead of triggering an OOM-scale `with_capacity`
//! or a wrapped product.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::tensor::{HostTensor, TensorData};
use crate::util::binio::{self, Reader};

const MAGIC: &[u8; 4] = b"BPCK";
const VERSION: u32 = 1;

/// A named collection of tensors.
#[derive(Debug, Default, Clone)]
pub struct Checkpoint {
    pub tensors: BTreeMap<String, HostTensor>,
}

impl Checkpoint {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, t: HostTensor) {
        self.tensors.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&HostTensor> {
        self.tensors
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint missing tensor '{name}'"))
    }

    /// All tensors under a prefix, in lexicographic name order.
    pub fn with_prefix(&self, prefix: &str) -> Vec<(&str, &HostTensor)> {
        self.tensors
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(k, v)| (k.as_str(), v))
            .collect()
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.extend_from_slice(&VERSION.to_le_bytes());
        buf.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for (name, t) in &self.tensors {
            buf.extend_from_slice(&(name.len() as u32).to_le_bytes());
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(&(t.dims().len() as u32).to_le_bytes());
            for &d in t.dims() {
                buf.extend_from_slice(&(d as u32).to_le_bytes());
            }
            match t.data() {
                TensorData::F32(v) => {
                    buf.push(0);
                    for x in v {
                        buf.extend_from_slice(&x.to_le_bytes());
                    }
                }
                TensorData::I32(v) => {
                    buf.push(1);
                    for x in v {
                        buf.extend_from_slice(&x.to_le_bytes());
                    }
                }
                TensorData::U32(v) => {
                    buf.push(2);
                    for x in v {
                        buf.extend_from_slice(&x.to_le_bytes());
                    }
                }
            }
        }
        let mut f = std::fs::File::create(path)
            .with_context(|| format!("creating checkpoint '{}'", path.display()))?;
        f.write_all(&buf)?;
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("opening checkpoint '{}'", path.display()))?;
        Self::from_bytes(&bytes)
            .with_context(|| format!("parsing checkpoint '{}'", path.display()))
    }

    /// Parse the BPCK byte format.  `name_len`, `rank`, every dim and
    /// the tensor count are untrusted: reads are bounded by the bytes
    /// present (nothing is pre-allocated from a claimed count) and the
    /// element product is overflow-checked.
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        let mut r = Reader::new(bytes);
        if r.take(4)? != MAGIC {
            bail!("not a bitprune checkpoint (bad magic)");
        }
        let version = r.u32()?;
        if version != VERSION {
            bail!("unsupported checkpoint version {version}");
        }
        let count = r.u32()? as usize;
        let mut tensors = BTreeMap::new();
        for i in 0..count {
            let name = r
                .str_u32()
                .with_context(|| format!("tensor {i} of {count}: name"))?;
            let rank = r.u32()? as usize;
            let dims: Vec<usize> = r
                .u32_vec(rank)
                .with_context(|| format!("tensor '{name}': {rank} dims"))?
                .into_iter()
                .map(|d| d as usize)
                .collect();
            let n = binio::checked_product(&dims)
                .with_context(|| format!("tensor '{name}': element count"))?;
            let dtype = r.u8()?;
            let t = match dtype {
                0 => HostTensor::f32(
                    &dims,
                    r.f32_vec(n)
                        .with_context(|| format!("tensor '{name}': f32 payload"))?,
                )?,
                1 => HostTensor::i32(
                    &dims,
                    r.i32_vec(n)
                        .with_context(|| format!("tensor '{name}': i32 payload"))?,
                )?,
                2 => HostTensor::u32(
                    &dims,
                    r.u32_vec(n)
                        .with_context(|| format!("tensor '{name}': u32 payload"))?,
                )?,
                d => bail!("tensor '{name}': unknown dtype tag {d}"),
            };
            tensors.insert(name, t);
        }
        if !r.is_empty() {
            bail!("{} trailing bytes after the last tensor", r.remaining());
        }
        Ok(Self { tensors })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmpfile(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("bitprune-ckpt-tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip() {
        let mut c = Checkpoint::new();
        c.insert("p/0/w", HostTensor::f32(&[2, 2], vec![1.0, -2.0, 3.5, 0.25]).unwrap());
        c.insert("bits_w", HostTensor::f32(&[3], vec![2.0, 3.5, 4.0]).unwrap());
        c.insert("y", HostTensor::i32(&[2], vec![-7, 9]).unwrap());
        c.insert("seed", HostTensor::scalar_u32(42));
        let path = tmpfile("roundtrip.bpck");
        c.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.tensors.len(), 4);
        assert_eq!(loaded.get("p/0/w").unwrap(), c.get("p/0/w").unwrap());
        assert_eq!(loaded.get("y").unwrap(), c.get("y").unwrap());
        assert_eq!(loaded.get("seed").unwrap().scalar().unwrap(), 42.0);
    }

    #[test]
    fn prefix_query_ordered() {
        let mut c = Checkpoint::new();
        c.insert("p/1", HostTensor::scalar_f32(1.0));
        c.insert("p/0", HostTensor::scalar_f32(0.0));
        c.insert("m/0", HostTensor::scalar_f32(9.0));
        let ps = c.with_prefix("p/");
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0].0, "p/0");
        assert_eq!(ps[1].0, "p/1");
    }

    #[test]
    fn corrupt_file_rejected() {
        let path = tmpfile("corrupt.bpck");
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        // truncated after header
        let mut good = Vec::new();
        good.extend_from_slice(b"BPCK");
        good.extend_from_slice(&1u32.to_le_bytes());
        good.extend_from_slice(&5u32.to_le_bytes()); // claims 5 tensors
        std::fs::write(&path, &good).unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn missing_tensor_error() {
        let c = Checkpoint::new();
        assert!(c.get("nope").is_err());
    }

    /// A minimal valid header claiming `count` tensors.
    fn header(count: u32) -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(b"BPCK");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&count.to_le_bytes());
        b
    }

    #[test]
    fn hostile_name_len_rejected_without_allocation() {
        // name_len = u32::MAX with 4 bytes of file left: must fail on
        // the bounds check, not allocate 4 GiB.
        let mut b = header(1);
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        b.extend_from_slice(b"abcd");
        let err = Checkpoint::from_bytes(&b).unwrap_err();
        assert!(format!("{err:#}").contains("name"), "{err:#}");
    }

    #[test]
    fn hostile_rank_rejected_without_allocation() {
        // rank = u32::MAX: the dims read is bounded by remaining bytes.
        let mut b = header(1);
        b.extend_from_slice(&1u32.to_le_bytes()); // name_len 1
        b.push(b'x');
        b.extend_from_slice(&u32::MAX.to_le_bytes()); // rank
        assert!(Checkpoint::from_bytes(&b).is_err());
    }

    #[test]
    fn dims_product_overflow_rejected() {
        // Three dims of 2^32-1 each: the usize product would wrap; the
        // loader must error instead of allocating a tiny wrapped size
        // and mis-slicing the payload.
        let mut b = header(1);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.push(b'x');
        b.extend_from_slice(&3u32.to_le_bytes()); // rank 3
        for _ in 0..3 {
            b.extend_from_slice(&u32::MAX.to_le_bytes());
        }
        b.push(0); // dtype f32
        let err = Checkpoint::from_bytes(&b).unwrap_err();
        assert!(format!("{err:#}").contains("element count"), "{err:#}");
    }

    #[test]
    fn huge_claimed_payload_fails_before_allocating() {
        // Plausible rank/dims claiming 10^9 elements against a 4-byte
        // payload: the typed read validates the span first.
        let mut b = header(1);
        b.extend_from_slice(&1u32.to_le_bytes());
        b.push(b'x');
        b.extend_from_slice(&2u32.to_le_bytes()); // rank 2
        b.extend_from_slice(&100_000u32.to_le_bytes());
        b.extend_from_slice(&10_000u32.to_le_bytes());
        b.push(0); // dtype f32
        b.extend_from_slice(&[0u8; 4]); // only one element present
        assert!(Checkpoint::from_bytes(&b).is_err());
    }

    #[test]
    fn trailing_garbage_rejected() {
        let mut c = Checkpoint::new();
        c.insert("x", HostTensor::scalar_f32(1.0));
        let path = tmpfile("trailing.bpck");
        c.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        assert!(Checkpoint::from_bytes(&bytes).is_ok());
        bytes.push(7);
        assert!(Checkpoint::from_bytes(&bytes).is_err());
    }
}
