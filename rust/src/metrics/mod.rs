//! Metric collection: per-step training records, epoch summaries, and
//! CSV/JSON sinks used to regenerate the paper's figures.
//!
//! Figure 1 / Figure 2 (accuracy + average bitlength vs training
//! progress) are emitted as CSV series directly from [`RunRecorder`].
//!
//! This module is *offline* training metrics.  Live serving telemetry
//! (lock-free counters/gauges/histograms, the Prometheus/JSON scrape
//! endpoint, the lifecycle event trace) lives in [`crate::telemetry`].

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{arr, num, obj, s, Json};

/// One recorded training step.
#[derive(Debug, Clone)]
pub struct StepRecord {
    pub step: usize,
    pub phase: &'static str,
    pub lr: f64,
    pub loss: f64,
    pub task_loss: f64,
    pub bit_loss: f64,
    pub train_acc: f64,
    pub mean_bits_w: f64,
    pub mean_bits_a: f64,
}

/// One evaluation point.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub step: usize,
    pub loss: f64,
    pub accuracy: f64,
    pub mean_bits_w: f64,
    pub mean_bits_a: f64,
}

/// Collects the full history of one training run.
#[derive(Debug, Default)]
pub struct RunRecorder {
    pub run_name: String,
    pub steps: Vec<StepRecord>,
    pub evals: Vec<EvalRecord>,
    /// Final per-layer bitlengths (for Fig. 3).
    pub final_bits_w: Vec<f32>,
    pub final_bits_a: Vec<f32>,
}

impl RunRecorder {
    pub fn new(run_name: &str) -> Self {
        Self { run_name: run_name.to_string(), ..Default::default() }
    }

    pub fn record_step(&mut self, r: StepRecord) {
        self.steps.push(r);
    }

    pub fn record_eval(&mut self, r: EvalRecord) {
        self.evals.push(r);
    }

    pub fn best_eval(&self) -> Option<&EvalRecord> {
        self.evals
            .iter()
            .max_by(|a, b| a.accuracy.total_cmp(&b.accuracy))
    }

    pub fn last_eval(&self) -> Option<&EvalRecord> {
        self.evals.last()
    }

    /// Figure 1/2 series: step, eval accuracy, mean weight/act bits.
    pub fn training_curve_csv(&self) -> String {
        let mut out = String::from("step,accuracy,loss,mean_bits_w,mean_bits_a\n");
        for e in &self.evals {
            let _ = writeln!(
                out,
                "{},{:.4},{:.5},{:.4},{:.4}",
                e.step, e.accuracy, e.loss, e.mean_bits_w, e.mean_bits_a
            );
        }
        out
    }

    /// Per-step loss curve (end-to-end driver log).
    pub fn loss_curve_csv(&self) -> String {
        let mut out =
            String::from("step,phase,lr,loss,task_loss,bit_loss,train_acc,bits_w,bits_a\n");
        for r in &self.steps {
            let _ = writeln!(
                out,
                "{},{},{:.6},{:.5},{:.5},{:.5},{:.4},{:.4},{:.4}",
                r.step,
                r.phase,
                r.lr,
                r.loss,
                r.task_loss,
                r.bit_loss,
                r.train_acc,
                r.mean_bits_w,
                r.mean_bits_a
            );
        }
        out
    }

    /// Figure 3 series: per-layer final bitlengths.
    pub fn layer_bits_csv(&self, layer_names: &[String]) -> String {
        let mut out = String::from("layer,name,bits_w,bits_a\n");
        for (i, name) in layer_names.iter().enumerate() {
            let _ = writeln!(
                out,
                "{},{},{:.4},{:.4}",
                i,
                name,
                self.final_bits_w.get(i).copied().unwrap_or(f32::NAN),
                self.final_bits_a.get(i).copied().unwrap_or(f32::NAN)
            );
        }
        out
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("run", s(&self.run_name)),
            (
                "evals",
                arr(self.evals.iter().map(|e| {
                    obj(vec![
                        ("step", num(e.step as f64)),
                        ("accuracy", num(e.accuracy)),
                        ("loss", num(e.loss)),
                        ("bits_w", num(e.mean_bits_w)),
                        ("bits_a", num(e.mean_bits_a)),
                    ])
                })),
            ),
            (
                "final_bits_w",
                arr(self.final_bits_w.iter().map(|&b| num(b as f64))),
            ),
            (
                "final_bits_a",
                arr(self.final_bits_a.iter().map(|&b| num(b as f64))),
            ),
        ])
    }

    pub fn write_csvs(&self, dir: impl AsRef<Path>, layer_names: &[String]) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let base = dir.join(&self.run_name);
        write_file(&base.with_extension("curve.csv"), &self.training_curve_csv())?;
        write_file(&base.with_extension("steps.csv"), &self.loss_curve_csv())?;
        write_file(
            &base.with_extension("layers.csv"),
            &self.layer_bits_csv(layer_names),
        )?;
        write_file(
            &base.with_extension("json"),
            &self.to_json().to_string(),
        )?;
        Ok(())
    }
}

pub fn write_file(path: &Path, content: &str) -> Result<()> {
    std::fs::write(path, content)
        .with_context(|| format!("writing '{}'", path.display()))
}

/// Simple fixed-width table formatter for terminal report output.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "table row arity");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize], out: &mut String| {
            for (i, c) in cells.iter().enumerate() {
                let _ = write!(out, "| {:width$} ", c, width = widths[i]);
            }
            out.push_str("|\n");
        };
        fmt_row(&self.header, &widths, &mut out);
        for (i, w) in widths.iter().enumerate() {
            let _ = write!(out, "|{:-<width$}", "", width = w + 2);
            if i == widths.len() - 1 {
                out.push_str("|\n");
            }
        }
        for row in &self.rows {
            fmt_row(row, &widths, &mut out);
        }
        out
    }

    /// CSV rendering of the same table.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let _ = writeln!(
            out,
            "{}",
            self.header.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for row in &self.rows {
            let _ = writeln!(
                out,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_recorder() -> RunRecorder {
        let mut r = RunRecorder::new("test-run");
        r.record_step(StepRecord {
            step: 0,
            phase: "learn",
            lr: 0.01,
            loss: 2.5,
            task_loss: 2.0,
            bit_loss: 0.5,
            train_acc: 0.1,
            mean_bits_w: 8.0,
            mean_bits_a: 8.0,
        });
        r.record_eval(EvalRecord {
            step: 0,
            loss: 2.4,
            accuracy: 0.12,
            mean_bits_w: 8.0,
            mean_bits_a: 8.0,
        });
        r.record_eval(EvalRecord {
            step: 10,
            loss: 1.2,
            accuracy: 0.55,
            mean_bits_w: 3.5,
            mean_bits_a: 4.2,
        });
        r.final_bits_w = vec![3.0, 4.0];
        r.final_bits_a = vec![4.0, 5.0];
        r
    }

    #[test]
    fn best_and_last_eval() {
        let r = sample_recorder();
        assert_eq!(r.best_eval().unwrap().accuracy, 0.55);
        assert_eq!(r.last_eval().unwrap().step, 10);
    }

    #[test]
    fn csv_emission() {
        let r = sample_recorder();
        let curve = r.training_curve_csv();
        assert!(curve.starts_with("step,accuracy"));
        assert_eq!(curve.lines().count(), 3);
        let layers = r.layer_bits_csv(&["l0".into(), "l1".into()]);
        assert!(layers.contains("0,l0,3.0000,4.0000"));
        let steps = r.loss_curve_csv();
        assert!(steps.contains("learn"));
    }

    #[test]
    fn json_roundtrips() {
        let r = sample_recorder();
        let j = r.to_json().to_string();
        let parsed = crate::util::json::parse(&j).unwrap();
        assert_eq!(
            parsed.get("run").unwrap().as_str().unwrap(),
            "test-run"
        );
        assert_eq!(parsed.get("evals").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn table_render_and_csv() {
        let mut t = Table::new(&["net", "acc", "bits"]);
        t.row(vec!["alexnet_s".into(), "78.3".into(), "3.78".into()]);
        let rendered = t.render();
        assert!(rendered.contains("| alexnet_s |"));
        assert!(rendered.lines().count() == 3);
        let csv = t.to_csv();
        assert!(csv.starts_with("net,acc,bits\n"));
    }

    #[test]
    #[should_panic(expected = "table row arity")]
    fn table_arity_checked() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
