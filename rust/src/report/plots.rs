//! Terminal (ASCII) figure rendering: the paper's figures as quick
//! visual checks directly in the sweep output.
//!
//! * [`line_chart`] — Fig 1/2 style: one or more series over steps.
//! * [`bar_chart`] — Fig 3 style: per-layer bitlengths.

use std::fmt::Write as _;

/// A named series for the line chart.
#[derive(Debug, Clone)]
pub struct Series {
    pub name: String,
    pub points: Vec<(f64, f64)>, // (x, y)
}

impl Series {
    pub fn new(name: &str, points: Vec<(f64, f64)>) -> Self {
        Self { name: name.to_string(), points }
    }
}

const GLYPHS: &[char] = &['*', 'o', '+', 'x', '#', '@'];

/// Render series into a `width`x`height` ASCII grid with axis labels.
pub fn line_chart(series: &[Series], width: usize, height: usize) -> String {
    let pts: Vec<(f64, f64)> = series
        .iter()
        .flat_map(|s| s.points.iter().copied())
        .filter(|(x, y)| x.is_finite() && y.is_finite())
        .collect();
    if pts.is_empty() {
        return "(no data)\n".to_string();
    }
    let (mut x0, mut x1) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut y0, mut y1) = (f64::INFINITY, f64::NEG_INFINITY);
    for (x, y) in &pts {
        x0 = x0.min(*x);
        x1 = x1.max(*x);
        y0 = y0.min(*y);
        y1 = y1.max(*y);
    }
    if (x1 - x0).abs() < 1e-12 {
        x1 = x0 + 1.0;
    }
    if (y1 - y0).abs() < 1e-12 {
        y1 = y0 + 1.0;
    }

    let mut grid = vec![vec![' '; width]; height];
    for (si, s) in series.iter().enumerate() {
        let glyph = GLYPHS[si % GLYPHS.len()];
        for (x, y) in &s.points {
            if !x.is_finite() || !y.is_finite() {
                continue;
            }
            let cx = (((x - x0) / (x1 - x0)) * (width - 1) as f64).round() as usize;
            let cy = (((y - y0) / (y1 - y0)) * (height - 1) as f64).round() as usize;
            grid[height - 1 - cy][cx.min(width - 1)] = glyph;
        }
    }

    let mut out = String::new();
    for (i, row) in grid.iter().enumerate() {
        let label = if i == 0 {
            format!("{y1:>8.2}")
        } else if i == height - 1 {
            format!("{y0:>8.2}")
        } else {
            "        ".to_string()
        };
        let _ = writeln!(out, "{label} |{}", row.iter().collect::<String>());
    }
    let _ = writeln!(out, "         +{}", "-".repeat(width));
    let _ = writeln!(out, "          {:<10} ... {:>10}", format!("{x0:.0}"), format!("{x1:.0}"));
    for (si, s) in series.iter().enumerate() {
        let _ = writeln!(out, "          {} {}", GLYPHS[si % GLYPHS.len()], s.name);
    }
    out
}

/// Horizontal bar chart: one bar per (label, value) up to `max_width`.
pub fn bar_chart(items: &[(String, f64)], max_width: usize) -> String {
    let max = items
        .iter()
        .map(|(_, v)| *v)
        .fold(f64::NEG_INFINITY, f64::max)
        .max(1e-12);
    let label_w = items.iter().map(|(l, _)| l.len()).max().unwrap_or(0);
    let mut out = String::new();
    for (label, v) in items {
        let bar_len = ((v / max) * max_width as f64).round().max(0.0) as usize;
        let _ = writeln!(
            out,
            "{label:>label_w$} | {:<max_width$} {v:.2}",
            "█".repeat(bar_len)
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_chart_renders_bounds() {
        let s = Series::new("acc", (0..50).map(|i| (i as f64, (i as f64).sqrt())).collect());
        let chart = line_chart(&[s], 40, 10);
        assert!(chart.contains('*'));
        assert!(chart.contains("acc"));
        assert!(chart.contains("0.00")); // min label
        assert_eq!(chart.lines().count(), 10 + 3);
    }

    #[test]
    fn line_chart_multi_series_glyphs() {
        let a = Series::new("a", vec![(0.0, 0.0), (1.0, 1.0)]);
        let b = Series::new("b", vec![(0.0, 1.0), (1.0, 0.0)]);
        let chart = line_chart(&[a, b], 20, 6);
        assert!(chart.contains('*') && chart.contains('o'));
    }

    #[test]
    fn line_chart_empty_and_degenerate() {
        assert_eq!(line_chart(&[], 10, 4), "(no data)\n");
        let flat = Series::new("flat", vec![(0.0, 5.0), (1.0, 5.0)]);
        let chart = line_chart(&[flat], 10, 4);
        assert!(chart.contains('*'));
        let nan = Series::new("nan", vec![(f64::NAN, 1.0)]);
        assert_eq!(line_chart(&[nan], 10, 4), "(no data)\n");
    }

    #[test]
    fn bar_chart_scales() {
        let items = vec![
            ("conv0".to_string(), 4.0),
            ("conv1".to_string(), 2.0),
            ("fc".to_string(), 8.0),
        ];
        let chart = bar_chart(&items, 16);
        let lines: Vec<&str> = chart.lines().collect();
        assert_eq!(lines.len(), 3);
        // fc (max) has the longest bar
        let count = |l: &str| l.matches('█').count();
        assert!(count(lines[2]) > count(lines[0]));
        assert!(count(lines[0]) > count(lines[1]));
        assert!(chart.contains("8.00"));
    }
}
