//! Experiment drivers: regenerate every table and figure of the paper's
//! evaluation (DESIGN.md §6 experiment index).
//!
//! Each `table*` function runs the required training sweeps through the
//! coordinator, prints the paper-shaped table, and writes CSV/JSON into
//! the output directory.  Figures are emitted as CSV series (the
//! recorder writes `<run>.curve.csv` for Figs 1-2, `<run>.layers.csv`
//! for Fig 3).

pub mod plots;

use std::path::Path;

use anyhow::Result;

use crate::accel;
use crate::baselines;
use crate::config::{PlanKind, RunConfig};
use crate::coordinator::{run_experiment, RunOutcome, Trainer};
use crate::metrics::{write_file, Table};
use crate::model::ModelMeta;
use crate::quant::{self, Criterion};
use crate::runtime::Runtime;

/// Probe batches used by the post-training searches (profiled / MPDNN):
/// 8 x batch 32 = 256 samples, 0.4% accuracy resolution.
const PROBE_BATCHES: usize = 8;

fn fmt(v: f64, prec: usize) -> String {
    format!("{:.*}", prec, v)
}

fn pct(v: f64) -> String {
    format!("{:.1}", v * 100.0)
}

fn save(out_dir: &str, name: &str, table: &Table) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    write_file(&Path::new(out_dir).join(name), &table.to_csv())
}

fn run_and_dump(rt: &Runtime, cfg: &RunConfig) -> Result<RunOutcome> {
    let outcome = run_experiment(rt, cfg)?;
    let meta = ModelMeta::load(
        rt.artifact_dir().join(format!("{}_meta.json", cfg.model)),
    )?;
    let layer_names: Vec<String> =
        meta.layers.iter().map(|l| l.name.clone()).collect();
    outcome.recorder.write_csvs(&cfg.out_dir, &layer_names)?;
    eprintln!(
        "    {}: acc {} | bits W {:.2} A {:.2} | {:.1}s",
        outcome.name,
        pct(outcome.final_.accuracy),
        outcome.final_.mean_bits_w(),
        outcome.final_.mean_bits_a(),
        outcome.wall_secs
    );
    Ok(outcome)
}

// ---------------------------------------------------------------------------
// Table II — regularizer-strength sweep (+ Fig 1 CSVs as a side effect)
// ---------------------------------------------------------------------------

pub fn table2(
    rt: &Runtime,
    base: &RunConfig,
    models: &[String],
    gammas: &[f64],
) -> Result<Table> {
    let mut t = Table::new(&[
        "network", "regularizer", "acc(non-int)", "W bits", "A bits",
        "acc(final)", "W bits(int)", "A bits(int)",
    ]);
    for model in models {
        // fp32-proxy baseline row.
        let mut cfg = base.clone();
        cfg.model = model.clone();
        let bl = baselines::fp32_proxy_config(&cfg, &format!("t2-{model}-base"));
        let out = run_and_dump(rt, &bl)?;
        t.row(vec![
            model.clone(), "baseline".into(), pct(out.final_.accuracy),
            "16 (fp32-proxy)".into(), "16 (fp32-proxy)".into(),
            pct(out.final_.accuracy), "16".into(), "16".into(),
        ]);
        for &gamma in gammas {
            let mut cfg = base.clone();
            cfg.model = model.clone();
            cfg.gamma = gamma;
            cfg.name = format!("t2-{model}-g{gamma}");
            let out = run_and_dump(rt, &cfg)?;
            let ni = out.noninteger.as_ref();
            t.row(vec![
                model.clone(),
                format!("{gamma}"),
                ni.map_or("-".into(), |s| pct(s.accuracy)),
                ni.map_or("-".into(), |s| fmt(s.mean_bits_w(), 2)),
                ni.map_or("-".into(), |s| fmt(s.mean_bits_a(), 2)),
                pct(out.final_.accuracy),
                fmt(out.final_.mean_bits_w(), 2),
                fmt(out.final_.mean_bits_a(), 2),
            ]);
        }
    }
    save(&base.out_dir, "table2.csv", &t)?;
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table III — other architectures
// ---------------------------------------------------------------------------

pub fn table3(rt: &Runtime, base: &RunConfig, models: &[String]) -> Result<Table> {
    let mut t = Table::new(&[
        "network", "base acc", "quantized acc", "W bits", "A bits", "regularizer",
    ]);
    for model in models {
        let mut cfg = base.clone();
        cfg.model = model.clone();
        // Vector models (1-D input) train on blobs, image models on the
        // base dataset.
        let meta = ModelMeta::load(
            rt.artifact_dir().join(format!("{model}_meta.json")),
        )?;
        if meta.input_shape.len() == 1 {
            cfg.dataset = "blobs".into();
        }
        let bl = baselines::fp32_proxy_config(&cfg, &format!("t3-{model}-base"));
        let base_out = run_and_dump(rt, &bl)?;
        cfg.name = format!("t3-{model}");
        let out = run_and_dump(rt, &cfg)?;
        t.row(vec![
            model.clone(),
            pct(base_out.final_.accuracy),
            pct(out.final_.accuracy),
            fmt(out.final_.mean_bits_w(), 2),
            fmt(out.final_.mean_bits_a(), 2),
            format!("{}", cfg.gamma),
        ]);
    }
    save(&base.out_dir, "table3.csv", &t)?;
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table IV — weighted bit-loss criteria
// ---------------------------------------------------------------------------

pub fn table4(rt: &Runtime, base: &RunConfig, models: &[String]) -> Result<Table> {
    let mut t = Table::new(&[
        "network", "target", "accuracy",
        "BS1 fp(non-int)", "BS128 fp(non-int)", "bitMACs(non-int)",
        "BS1 fp(int)", "BS128 fp(int)", "bitMACs(int)",
    ]);
    let criteria = [
        Criterion::Equal,
        Criterion::FootprintBs1,
        Criterion::FootprintBs128,
        Criterion::MacOps,
    ];
    for model in models {
        let meta = ModelMeta::load(
            rt.artifact_dir().join(format!("{model}_meta.json")),
        )?;
        // Normalize metrics to the 8-bit network so rows are readable
        // "average bits"-like numbers, as in the paper.
        let b8 = vec![8.0f32; meta.num_quant_layers];
        let fp1_8 = quant::total_footprint_bits(&meta, &b8, &b8, 1);
        let fp128_8 = quant::total_footprint_bits(&meta, &b8, &b8, 128);
        let mac_8 = quant::mac_cost(&meta, &b8, &b8);
        for crit in criteria {
            let mut cfg = base.clone();
            cfg.model = model.clone();
            cfg.criterion = crit;
            cfg.name = format!("t4-{model}-{}", crit.name());
            let out = run_and_dump(rt, &cfg)?;
            let metrics = |s: &crate::coordinator::StageResult| {
                (
                    quant::total_footprint_bits(&meta, &s.bits_w, &s.bits_a, 1)
                        / fp1_8 * 8.0,
                    quant::total_footprint_bits(&meta, &s.bits_w, &s.bits_a, 128)
                        / fp128_8 * 8.0,
                    quant::mac_cost(&meta, &s.bits_w, &s.bits_a) / mac_8 * 8.0,
                )
            };
            let (ni1, ni128, nim) = out
                .noninteger
                .as_ref()
                .map(&metrics)
                .unwrap_or((f64::NAN, f64::NAN, f64::NAN));
            let (f1, f128, fm) = metrics(&out.final_);
            t.row(vec![
                model.clone(), crit.name().into(), pct(out.final_.accuracy),
                fmt(ni1, 2), fmt(ni128, 2), fmt(nim, 2),
                fmt(f1, 2), fmt(f128, 2), fmt(fm, 2),
            ]);
        }
    }
    save(&base.out_dir, "table4.csv", &t)?;
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table V — channel-width ablation (needs the table5 artifact variants)
// ---------------------------------------------------------------------------

pub fn table5(rt: &Runtime, base: &RunConfig, variants: &[String]) -> Result<Table> {
    let mut t = Table::new(&[
        "variant", "accuracy", "W bits", "A bits", "W bits(int)", "A bits(int)",
    ]);
    for variant in variants {
        let mut cfg = base.clone();
        cfg.model = variant.clone();
        cfg.name = format!("t5-{variant}");
        let out = run_and_dump(rt, &cfg)?;
        let ni = out.noninteger.as_ref();
        t.row(vec![
            variant.clone(),
            pct(out.final_.accuracy),
            ni.map_or("-".into(), |s| fmt(s.mean_bits_w(), 2)),
            ni.map_or("-".into(), |s| fmt(s.mean_bits_a(), 2)),
            fmt(out.final_.mean_bits_w(), 2),
            fmt(out.final_.mean_bits_a(), 2),
        ]);
    }
    save(&base.out_dir, "table5.csv", &t)?;
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table VI — the "large benchmark" headline (+ Fig 2 CSVs)
// ---------------------------------------------------------------------------

pub fn table6(rt: &Runtime, base: &RunConfig, models: &[String]) -> Result<Table> {
    let mut t = Table::new(&[
        "network", "regularizer", "acc(non-int)", "W bits", "A bits",
        "acc(final)", "W bits(int)", "A bits(int)",
    ]);
    for model in models {
        let mut cfg = base.clone();
        cfg.model = model.clone();
        cfg.dataset = "synthcifar-hard".into();
        let bl = baselines::fp32_proxy_config(&cfg, &format!("t6-{model}-base"));
        let base_out = run_and_dump(rt, &bl)?;
        t.row(vec![
            model.clone(), "baseline".into(), pct(base_out.final_.accuracy),
            "16".into(), "16".into(), pct(base_out.final_.accuracy),
            "16".into(), "16".into(),
        ]);
        cfg.name = format!("t6-{model}");
        let out = run_and_dump(rt, &cfg)?;
        let ni = out.noninteger.as_ref();
        t.row(vec![
            model.clone(),
            format!("{}", cfg.gamma),
            ni.map_or("-".into(), |s| pct(s.accuracy)),
            ni.map_or("-".into(), |s| fmt(s.mean_bits_w(), 2)),
            ni.map_or("-".into(), |s| fmt(s.mean_bits_a(), 2)),
            pct(out.final_.accuracy),
            fmt(out.final_.mean_bits_w(), 2),
            fmt(out.final_.mean_bits_a(), 2),
        ]);
    }
    save(&base.out_dir, "table6.csv", &t)?;
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table VII — vs uniform QAT + profiled baselines
// ---------------------------------------------------------------------------

pub struct Table7Outcome {
    pub table: Table,
    /// (model, trained bits, profiled bits) for Table VIII reuse.
    pub assignments: Vec<(String, (Vec<f32>, Vec<f32>), (Vec<f32>, Vec<f32>))>,
}

pub fn table7(rt: &Runtime, base: &RunConfig, models: &[String]) -> Result<Table7Outcome> {
    let mut t = Table::new(&["network", "method", "accuracy", "W bits", "A bits"]);
    let mut assignments = Vec::new();
    for model in models {
        let mut cfg = base.clone();
        cfg.model = model.clone();

        // Uniform 4-bit QAT (PACT's role in the comparison).
        let pact = baselines::uniform_qat_config(&cfg, 4.0, &format!("t7-{model}-uniform4"));
        let pact_out = run_and_dump(rt, &pact)?;
        t.row(vec![
            model.clone(), "uniform-4b (PACT role)".into(),
            pct(pact_out.final_.accuracy), "4.00".into(), "4.00".into(),
        ]);

        // fp32-proxy training, then profiled post-training selection.
        let fp = baselines::fp32_proxy_config(&cfg, &format!("t7-{model}-fp"));
        let trainer = Trainer::new(rt, &fp)?;
        let fp_out = trainer.run()?;
        let session = trainer.session(&fp_out.final_params);
        let mut probe = |bw: &[f32], ba: &[f32]| {
            session.accuracy(bw, ba, PROBE_BATCHES)
        };
        let prof = baselines::profiled_search(
            trainer.meta().num_quant_layers,
            8.0,
            0.01,
            &mut probe,
        )?;
        let prof_acc = session.accuracy(&prof.bits_w, &prof.bits_a, usize::MAX)?;
        t.row(vec![
            model.clone(), "profiled".into(), pct(prof_acc),
            fmt(quant::mean_bits(&prof.bits_w), 2),
            fmt(quant::mean_bits(&prof.bits_a), 2),
        ]);

        // BitPruning.
        cfg.name = format!("t7-{model}-bitprune");
        let bp_out = run_and_dump(rt, &cfg)?;
        t.row(vec![
            model.clone(), "bitpruning".into(), pct(bp_out.final_.accuracy),
            fmt(bp_out.final_.mean_bits_w(), 2),
            fmt(bp_out.final_.mean_bits_a(), 2),
        ]);

        assignments.push((
            model.clone(),
            (bp_out.final_.bits_w.clone(), bp_out.final_.bits_a.clone()),
            (prof.bits_w.clone(), prof.bits_a.clone()),
        ));
    }
    save(&base.out_dir, "table7.csv", &t)?;
    Ok(Table7Outcome { table: t, assignments })
}

// ---------------------------------------------------------------------------
// MPDNN comparison (§III-B6)
// ---------------------------------------------------------------------------

pub fn mpdnn_compare(rt: &Runtime, base: &RunConfig, models: &[String]) -> Result<Table> {
    let mut t = Table::new(&[
        "network", "method", "accuracy", "weight mem (KiB)", "act mem (KiB)",
    ]);
    for model in models {
        let mut cfg = base.clone();
        cfg.model = model.clone();
        let meta = ModelMeta::load(
            rt.artifact_dir().join(format!("{model}_meta.json")),
        )?;

        // BitPruning run (no memory budget given).
        cfg.name = format!("mpdnn-{model}-bitprune");
        let bp = run_and_dump(rt, &cfg)?;
        let bp_w =
            quant::weight_footprint_bits(&meta, &bp.final_.bits_w) / 8.0 / 1024.0;
        let bp_a =
            quant::act_footprint_bits(&meta, &bp.final_.bits_a, 1) / 8.0 / 1024.0;
        t.row(vec![
            model.clone(), "bitpruning (no budget)".into(),
            pct(bp.final_.accuracy), fmt(bp_w, 1), fmt(bp_a, 2),
        ]);

        // MPDNN-style: fp32-proxy training + budgeted assignment at the
        // budget BitPruning discovered (the "expertly chosen" budget) and
        // at 2x that (the unconstrained accuracy-first setting).
        let fp = baselines::fp32_proxy_config(&cfg, &format!("mpdnn-{model}-fp"));
        let trainer = Trainer::new(rt, &fp)?;
        let fp_out = trainer.run()?;
        let session = trainer.session(&fp_out.final_params);
        let weight_elems: Vec<usize> =
            meta.layers.iter().map(|l| l.weight_elems).collect();
        for (label, factor) in [("mpdnn (expert budget)", 1.0), ("mpdnn (2x budget)", 2.0)] {
            let budget_bits =
                quant::weight_footprint_bits(&meta, &bp.final_.bits_w) * factor;
            let mut probe = |bw: &[f32], ba: &[f32]| {
                session.accuracy(bw, ba, PROBE_BATCHES)
            };
            let r = baselines::mpdnn_assign(&weight_elems, 8.0, budget_bits, &mut probe)?;
            let acc = session.accuracy(&r.bits_w, &r.bits_a, usize::MAX)?;
            let w_kib =
                quant::weight_footprint_bits(&meta, &r.bits_w) / 8.0 / 1024.0;
            let a_kib = quant::act_footprint_bits(&meta, &r.bits_a, 1) / 8.0 / 1024.0;
            t.row(vec![
                model.clone(), label.into(), pct(acc), fmt(w_kib, 1), fmt(a_kib, 2),
            ]);
        }
    }
    save(&base.out_dir, "mpdnn.csv", &t)?;
    Ok(t)
}

// ---------------------------------------------------------------------------
// Table VIII — accelerator benefits, trained vs profiled
// ---------------------------------------------------------------------------

pub fn table8(
    rt: &Runtime,
    out_dir: &str,
    assignments: &[(String, (Vec<f32>, Vec<f32>), (Vec<f32>, Vec<f32>))],
) -> Result<Table> {
    let mut t = Table::new(&[
        "network", "accelerator",
        "perf(trained)", "mem(trained)", "perf(profiled)", "mem(profiled)",
    ]);
    for (model, trained, profiled) in assignments {
        let meta = ModelMeta::load(
            rt.artifact_dir().join(format!("{model}_meta.json")),
        )?;
        let tr = accel::evaluate_all(&meta, &trained.0, &trained.1);
        let pr = accel::evaluate_all(&meta, &profiled.0, &profiled.1);
        for (rt_, rp) in tr.iter().zip(&pr) {
            let f = |s: Option<f64>| s.map_or("-".to_string(), |v| format!("{v:.2}x"));
            t.row(vec![
                model.clone(),
                rt_.accel.into(),
                f(rt_.speedup),
                format!("{:.2}x", rt_.mem_ratio),
                f(rp.speedup),
                format!("{:.2}x", rp.mem_ratio),
            ]);
        }
    }
    save(out_dir, "table8.csv", &t)?;
    Ok(t)
}

// ---------------------------------------------------------------------------
// §III-B4 early selection + §III-B5 warm-start ablations
// ---------------------------------------------------------------------------

pub fn ablation_early_and_warmstart(
    rt: &Runtime,
    base: &RunConfig,
    model: &str,
) -> Result<Table> {
    let mut t = Table::new(&[
        "variant", "accuracy", "W bits(int)", "A bits(int)", "wall secs",
    ]);
    // Standard.
    let mut std_cfg = base.clone();
    std_cfg.model = model.to_string();
    std_cfg.name = format!("abl-{model}-standard");
    let std_out = run_and_dump(rt, &std_cfg)?;
    t.row(vec![
        "standard".into(), pct(std_out.final_.accuracy),
        fmt(std_out.final_.mean_bits_w(), 2), fmt(std_out.final_.mean_bits_a(), 2),
        fmt(std_out.wall_secs, 1),
    ]);

    // Early selection: learn bits for only ~1/5 of the learn budget.
    let mut early = std_cfg.clone();
    early.plan = PlanKind::EarlySelect;
    early.name = format!("abl-{model}-early");
    early.finetune_steps = std_cfg.learn_steps - std_cfg.learn_steps / 5
        + std_cfg.finetune_steps;
    early.learn_steps = std_cfg.learn_steps / 5;
    let early_out = run_and_dump(rt, &early)?;
    t.row(vec![
        "early-select".into(), pct(early_out.final_.accuracy),
        fmt(early_out.final_.mean_bits_w(), 2),
        fmt(early_out.final_.mean_bits_a(), 2),
        fmt(early_out.wall_secs, 1),
    ]);

    // Warm start: pretrain an 8-bit network, then BitPrune from it.
    let pre = baselines::uniform_qat_config(
        &std_cfg, 8.0, &format!("abl-{model}-pretrain"),
    );
    let ckpt_path = format!("{}/abl-{model}-pretrain.bpck", base.out_dir);
    std::fs::create_dir_all(&base.out_dir)?;
    let trainer = Trainer::new(rt, &pre)?;
    let _ = trainer.run_and_checkpoint(Some(&ckpt_path))?;
    let mut warm = std_cfg.clone();
    warm.plan = PlanKind::Warmstart;
    warm.warmstart_ckpt = Some(ckpt_path);
    warm.name = format!("abl-{model}-warmstart");
    let warm_out = run_and_dump(rt, &warm)?;
    t.row(vec![
        "warmstart (from 8b)".into(), pct(warm_out.final_.accuracy),
        fmt(warm_out.final_.mean_bits_w(), 2),
        fmt(warm_out.final_.mean_bits_a(), 2),
        fmt(warm_out.wall_secs, 1),
    ]);

    save(&base.out_dir, "ablations.csv", &t)?;
    Ok(t)
}
