//! Typed view of the exported artifact metadata (`<tag>_meta.json`).
//!
//! The python exporter (python/compile/aot.py) writes one meta file per
//! model variant describing the flattened train/eval signatures and the
//! static per-layer geometry.  Everything the coordinator, the loss
//! weighting and the accelerator models need about a network comes from
//! here — the rust side never hard-codes model structure.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Static geometry of one quantized layer (one (n_w, n_a) bitlength pair).
#[derive(Debug, Clone)]
pub struct LayerGeom {
    pub name: String,
    /// 'conv' | 'dwconv' | 'dense'
    pub kind: String,
    /// Weight elements for the whole network.
    pub weight_elems: usize,
    /// Input-activation elements per sample.
    pub act_in_elems: usize,
    /// MACs per sample.
    pub macs: usize,
    pub cin: usize,
    pub cout: usize,
    pub kernel: usize,
    pub out_spatial: usize,
}

impl LayerGeom {
    fn from_json(v: &Json) -> Result<Self> {
        Ok(Self {
            name: v.get("name")?.as_str()?.to_string(),
            kind: v.get("kind")?.as_str()?.to_string(),
            weight_elems: v.get("weight_elems")?.as_usize()?,
            act_in_elems: v.get("act_in_elems")?.as_usize()?,
            macs: v.get("macs")?.as_usize()?,
            cin: v.get("cin")?.as_usize()?,
            cout: v.get("cout")?.as_usize()?,
            kernel: v.get("kernel")?.as_usize()?,
            out_spatial: v.get("out_spatial")?.as_usize()?,
        })
    }
}

/// Parsed `<tag>_meta.json`.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub tag: String,
    pub model: String,
    pub batch_size: usize,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub num_quant_layers: usize,
    pub num_params: usize,
    pub param_names: Vec<String>,
    pub param_shapes: Vec<Vec<usize>>,
    pub layers: Vec<LayerGeom>,
    pub momentum: f64,
    pub weight_decay: f64,
    pub n_min: f64,
    pub n_max: f64,
}

impl ModelMeta {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading meta '{}'", path.display()))?;
        let v = json::parse(&text)
            .with_context(|| format!("parsing meta '{}'", path.display()))?;
        Self::from_json(&v)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let layers = v
            .get("layers")?
            .as_arr()?
            .iter()
            .map(LayerGeom::from_json)
            .collect::<Result<Vec<_>>>()?;
        let meta = Self {
            tag: v.get("tag")?.as_str()?.to_string(),
            model: v.get("model")?.as_str()?.to_string(),
            batch_size: v.get("batch_size")?.as_usize()?,
            input_shape: v.get("input_shape")?.usize_vec()?,
            num_classes: v.get("num_classes")?.as_usize()?,
            num_quant_layers: v.get("num_quant_layers")?.as_usize()?,
            num_params: v.get("num_params")?.as_usize()?,
            param_names: v.get("param_names")?.str_vec()?,
            param_shapes: v
                .get("param_shapes")?
                .as_arr()?
                .iter()
                .map(|s| s.usize_vec())
                .collect::<Result<Vec<_>>>()?,
            layers,
            momentum: v.get("momentum")?.as_f64()?,
            weight_decay: v.get("weight_decay")?.as_f64()?,
            n_min: v.get("n_min")?.as_f64()?,
            n_max: v.get("n_max")?.as_f64()?,
        };
        meta.validate()?;
        Ok(meta)
    }

    fn validate(&self) -> Result<()> {
        if self.param_names.len() != self.num_params {
            bail!(
                "meta inconsistency: {} param names vs num_params {}",
                self.param_names.len(),
                self.num_params
            );
        }
        if self.param_shapes.len() != self.num_params {
            bail!("meta inconsistency: param_shapes length");
        }
        if self.layers.len() != self.num_quant_layers {
            bail!(
                "meta inconsistency: {} layers vs num_quant_layers {}",
                self.layers.len(),
                self.num_quant_layers
            );
        }
        if self.batch_size == 0 || self.num_classes == 0 {
            bail!("meta inconsistency: zero batch or classes");
        }
        Ok(())
    }

    // ---- artifact names -----------------------------------------------------

    pub fn init_artifact(&self) -> String {
        format!("{}_init", self.tag)
    }

    pub fn train_artifact(&self) -> String {
        format!("{}_train", self.tag)
    }

    pub fn eval_artifact(&self) -> String {
        format!("{}_eval", self.tag)
    }

    // ---- aggregate geometry ---------------------------------------------------

    pub fn total_weight_elems(&self) -> usize {
        self.layers.iter().map(|l| l.weight_elems).sum()
    }

    pub fn total_macs_per_sample(&self) -> usize {
        self.layers.iter().map(|l| l.macs).sum()
    }

    pub fn total_act_elems_per_sample(&self) -> usize {
        self.layers.iter().map(|l| l.act_in_elems).sum()
    }

    /// Largest single activation layer (elements per sample) — the
    /// MPDNN-style activation memory metric (paper §III-B6).
    pub fn max_act_elems_per_sample(&self) -> usize {
        self.layers.iter().map(|l| l.act_in_elems).max().unwrap_or(0)
    }
}

/// Shared test fixture: a tiny two-layer MLP meta (also used by the
/// quant/accel unit tests).
#[cfg(test)]
pub(crate) fn tiny_meta_json() -> String {
    tests::tiny_meta_json()
}

#[cfg(test)]
mod tests {
    use super::*;

    pub(crate) fn tiny_meta_json() -> String {
        r#"{
          "tag": "tiny", "model": "mlp", "batch_size": 4,
          "input_shape": [8], "num_classes": 3,
          "num_quant_layers": 2, "num_params": 4,
          "param_names": ["0/w", "0/b", "1/w", "1/b"],
          "param_shapes": [[8, 16], [16], [16, 3], [3]],
          "layers": [
            {"name": "fc0", "kind": "dense", "weight_elems": 128,
             "act_in_elems": 8, "macs": 128, "cin": 8, "cout": 16,
             "kernel": 1, "out_spatial": 1},
            {"name": "fc1", "kind": "dense", "weight_elems": 48,
             "act_in_elems": 16, "macs": 48, "cin": 16, "cout": 3,
             "kernel": 1, "out_spatial": 1}
          ],
          "momentum": 0.9, "weight_decay": 0.0005,
          "n_min": 1.0, "n_max": 16.0
        }"#
        .to_string()
    }

    #[test]
    fn parse_meta() {
        let v = json::parse(&tiny_meta_json()).unwrap();
        let m = ModelMeta::from_json(&v).unwrap();
        assert_eq!(m.tag, "tiny");
        assert_eq!(m.num_params, 4);
        assert_eq!(m.layers.len(), 2);
        assert_eq!(m.total_weight_elems(), 176);
        assert_eq!(m.total_macs_per_sample(), 176);
        assert_eq!(m.max_act_elems_per_sample(), 16);
        assert_eq!(m.train_artifact(), "tiny_train");
    }

    #[test]
    fn inconsistent_meta_rejected() {
        let bad = tiny_meta_json().replace("\"num_params\": 4", "\"num_params\": 3");
        let v = json::parse(&bad).unwrap();
        assert!(ModelMeta::from_json(&v).is_err());
    }
}
