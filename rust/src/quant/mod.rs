//! Rust mirror of the BitPruning quantizer.
//!
//! Bit-compatible with python/compile/kernels/ref.py (checked by the
//! `artifact_parity` integration test against the exported
//! `fake_quant.hlo.txt`): same clipping bounds, same epsilon guard and
//! round-half-to-even semantics (`f32::round_ties_even` ⇔ `jnp.round`).
//!
//! Used by the coordinator for bitlength selection between phases, by
//! the profiled/MPDNN baselines, and by the report generation (footprint
//! and MAC-cost accounting).

use crate::model::ModelMeta;

/// Paper clips learned bitlengths at 1.0 from below; 16 above (ref.py).
pub const N_MIN: f32 = 1.0;
pub const N_MAX: f32 = 16.0;
const RANGE_EPS: f32 = 1e-12;

/// Clip a learned bitlength into the valid range.
pub fn clip_bits(n: f32) -> f32 {
    n.clamp(N_MIN, N_MAX)
}

/// The integer bitlength a learned (possibly fractional) bitlength
/// deploys at: clip into `[N_MIN, N_MAX]`, then ceil (paper §II-C).
/// The one convention shared by packing, integer inference and the CLI.
pub fn int_bits(n: f32) -> u32 {
    clip_bits(n).ceil() as u32
}

/// Integer accumulator lane width for the GEMM core, narrowest first.
///
/// Ordered so `max` over a set of groups picks the widest (safest)
/// lane, and `<= AccWidth::I32` asks "is a 32-bit lane safe here".
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum AccWidth {
    I16,
    I32,
    I64,
}

impl AccWidth {
    /// Lane width in bits.
    pub fn bits(self) -> u32 {
        match self {
            AccWidth::I16 => 16,
            AccWidth::I32 => 32,
            AccWidth::I64 => 64,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            AccWidth::I16 => "i16",
            AccWidth::I32 => "i32",
            AccWidth::I64 => "i64",
        }
    }
}

/// `ceil(log2(n))` for `n >= 1` (0 for `n <= 1`), overflow-free.
fn ceil_log2(n: usize) -> u32 {
    if n <= 1 {
        0
    } else {
        usize::BITS - (n - 1).leading_zeros()
    }
}

/// Narrowest accumulator lane that provably holds the integer GEMM
/// core `Σ_i a_code[i]·w_code[i]` over `din` terms.
///
/// Codes are unsigned: `a_code ≤ 2^a_bits − 1`, `w_code ≤ 2^w_bits − 1`,
/// so the dot product is bounded by
/// `din·(2^w_bits−1)·(2^a_bits−1) < 2^(w_bits + a_bits + ceil(log2(din)))`.
/// A signed lane of `B` bits holds any value `< 2^(B−1)`, giving the
/// promotion thresholds
///
/// ```text
/// w_bits + a_bits + ceil(log2(din)) <= 15  ->  i16
///                                   <= 31  ->  i32
///                                   else   ->  i64
/// ```
///
/// The same bound covers the shift-add kernels' *intermediate* sums:
/// the rising phase peaks at `rsum·2^(w_bits−1) + Σ adds < rsum·2^w_bits
/// ≤ din·(2^a_bits−1)·2^w_bits`, inside the identical `2^need` envelope.
///
/// Two extra guards keep the selection conservative rather than merely
/// tight: operands wider than 15 bits are forced to `I64` (narrow SIMD
/// lanes multiply the codes as `i16`, so the *operands* must be
/// i16-representable too), and `din == 0` degenerates to the narrowest
/// lane (an empty dot product is 0 everywhere).
pub fn acc_width(w_bits: u32, a_bits: u32, din: usize) -> AccWidth {
    if w_bits > 15 || a_bits > 15 {
        return AccWidth::I64;
    }
    let need = w_bits + a_bits + ceil_log2(din);
    if need <= 15 {
        AccWidth::I16
    } else if need <= 31 {
        AccWidth::I32
    } else {
        AccWidth::I64
    }
}

/// Smallest representable step of an n-bit group over [lmin, lmax].
pub fn scale(lmin: f32, lmax: f32, n: f32) -> f32 {
    let rng = (lmax - lmin).max(RANGE_EPS);
    rng / (n.exp2() - 1.0)
}

/// Q_i: uniform min/max quantization at (integer-valued) bitlength n.
pub fn quantize_int(v: f32, lmin: f32, lmax: f32, n: f32) -> f32 {
    let s = scale(lmin, lmax, n);
    lmin + ((v - lmin) / s).round_ties_even() * s
}

/// Q_r: interpolated non-integer-bitlength quantization (paper eq. 4).
pub fn quantize_interp(v: f32, lmin: f32, lmax: f32, n: f32) -> f32 {
    QuantPlan::new(lmin, lmax, n).quantize(v)
}

/// Group min/max of a slice. Four-lane accumulation for ILP; min/max
/// reassociation is exact, so the result matches the sequential fold.
pub fn group_minmax(xs: &[f32]) -> (f32, f32) {
    let mut mins = [f32::INFINITY; 4];
    let mut maxs = [f32::NEG_INFINITY; 4];
    let mut chunks = xs.chunks_exact(4);
    for ch in &mut chunks {
        for i in 0..4 {
            mins[i] = mins[i].min(ch[i]);
            maxs[i] = maxs[i].max(ch[i]);
        }
    }
    let mut lmin = mins[0].min(mins[1]).min(mins[2].min(mins[3]));
    let mut lmax = maxs[0].max(maxs[1]).max(maxs[2].max(maxs[3]));
    for &x in chunks.remainder() {
        lmin = lmin.min(x);
        lmax = lmax.max(x);
    }
    (lmin, lmax)
}

/// Precomputed per-group quantization parameters: everything `Q_r`
/// needs that does not depend on the element value. Build once per
/// group (amortizing the clip/floor/scale math), then apply to any
/// number of elements or slices over the same range.
///
/// Bit-exact with the scalar reference [`fake_quant_slice_ref`] /
/// `python/compile/kernels/ref.py`: same clipping, same epsilon guard,
/// same round-half-to-even, same operation order. The integer-bitlength
/// case (`alpha == 0`) skips the second grid entirely — `(1-0)·q_b +
/// 0·q_{b+1}` is exactly `q_b` in f32, so the shortcut preserves
/// bit-exactness while halving the work on the deployment path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantPlan {
    /// Group minimum (the grid origin).
    pub lmin: f32,
    /// Step of the floor(n)-bit grid.
    pub s_lo: f32,
    /// Step of the (floor(n)+1)-bit grid.
    pub s_hi: f32,
    /// Interpolation weight `n - floor(n)` in [0, 1).
    pub alpha: f32,
    /// Integer bitlength of the floor grid (what `code()` targets).
    pub bits_lo: u8,
    /// Code-value restriction on the floor grid ([`Codebook::Uniform`]
    /// admits every code — today's behavior, bit-identical).
    pub codebook: Codebook,
}

impl QuantPlan {
    pub fn new(lmin: f32, lmax: f32, n: f32) -> Self {
        Self::new_cbk(lmin, lmax, n, Codebook::Uniform)
    }

    /// Plan with a code restriction.  The grid (origin, steps, alpha)
    /// is exactly [`Self::new`]'s — a codebook never changes the grid,
    /// only which of its codes are representable.
    pub fn new_cbk(lmin: f32, lmax: f32, n: f32, codebook: Codebook) -> Self {
        let n = clip_bits(n);
        let b = n.floor();
        Self {
            lmin,
            s_lo: scale(lmin, lmax, b),
            s_hi: scale(lmin, lmax, b + 1.0),
            alpha: n - b,
            bits_lo: b as u8,
            codebook,
        }
    }

    /// Plan over a slice's own min/max (the per-group convention).
    pub fn from_slice(xs: &[f32], n: f32) -> Self {
        let (lmin, lmax) = group_minmax(xs);
        Self::new(lmin, lmax, n)
    }

    /// [`Self::from_slice`] with a code restriction.
    pub fn from_slice_cbk(xs: &[f32], n: f32, codebook: Codebook) -> Self {
        let (lmin, lmax) = group_minmax(xs);
        Self::new_cbk(lmin, lmax, n, codebook)
    }

    /// Projector onto this plan's codebook at its floor bitlength.
    pub fn projector(&self) -> CodeProjector {
        CodeProjector::new(self.codebook, self.bits_lo as u32)
    }

    /// Quantize one value.  Non-uniform codebooks quantize on the floor
    /// grid only (codebooks are a deployment-side restriction; the
    /// interpolated fractional-bit path is a training construct).
    #[inline]
    pub fn quantize(&self, x: f32) -> f32 {
        if self.codebook != Codebook::Uniform {
            let levels = ((1u32 << self.bits_lo) - 1) as i64;
            let code = self.projector().project_code(self.code(x, levels));
            return self.lmin + code as f32 * self.s_lo;
        }
        let c = x - self.lmin;
        let qb = self.lmin + (c / self.s_lo).round_ties_even() * self.s_lo;
        if self.alpha == 0.0 {
            return qb;
        }
        let qb1 = self.lmin + (c / self.s_hi).round_ties_even() * self.s_hi;
        (1.0 - self.alpha) * qb + self.alpha * qb1
    }

    /// Integer code of `x` on the floor-bitlength grid, clamped to
    /// `[0, levels]` — the packing / integer-inference path.  Codebook
    /// projection is a separate explicit step ([`CodeProjector`]) so
    /// the uniform hot loop stays branch-free.
    #[inline]
    pub fn code(&self, x: f32, levels: i64) -> u32 {
        (((x - self.lmin) / self.s_lo).round_ties_even() as i64).clamp(0, levels) as u32
    }

    /// Apply the plan to a whole slice in place, branch-free in the
    /// element loop (the alpha test is hoisted out).
    pub fn apply(&self, xs: &mut [f32]) {
        let lmin = self.lmin;
        let s_lo = self.s_lo;
        if self.codebook != Codebook::Uniform {
            let proj = self.projector();
            let levels = ((1u32 << self.bits_lo) - 1) as i64;
            for x in xs.iter_mut() {
                let code = proj.project_code(self.code(*x, levels));
                *x = lmin + code as f32 * s_lo;
            }
        } else if self.alpha == 0.0 {
            for x in xs.iter_mut() {
                *x = lmin + ((*x - lmin) / s_lo).round_ties_even() * s_lo;
            }
        } else {
            let a = self.alpha;
            let om = 1.0 - a;
            let s_hi = self.s_hi;
            for x in xs.iter_mut() {
                let c = *x - lmin;
                let qb = lmin + (c / s_lo).round_ties_even() * s_lo;
                let qb1 = lmin + (c / s_hi).round_ties_even() * s_hi;
                *x = om * qb + a * qb1;
            }
        }
    }
}

/// Full fake-quantization of a slice as one group (in place).
/// Fast path: a [`QuantPlan`] built once, applied branch-free.
pub fn fake_quant_slice(xs: &mut [f32], n: f32) {
    if xs.is_empty() {
        return;
    }
    QuantPlan::from_slice(xs, n).apply(xs);
}

/// Retained scalar reference for [`fake_quant_slice`]: recomputes the
/// interpolated blend per element exactly as `kernels/ref.py` writes
/// it. The fast path must stay bit-identical to this (see the
/// `fastpath_parity` tests and `benches/quantizer.rs`).
pub fn fake_quant_slice_ref(xs: &mut [f32], n: f32) {
    if xs.is_empty() {
        return;
    }
    let mut lmin = f32::INFINITY;
    let mut lmax = f32::NEG_INFINITY;
    for &x in xs.iter() {
        lmin = lmin.min(x);
        lmax = lmax.max(x);
    }
    let n = clip_bits(n);
    let b = n.floor();
    let a = n - b;
    let sb = scale(lmin, lmax, b);
    let sb1 = scale(lmin, lmax, b + 1.0);
    for x in xs.iter_mut() {
        let c = *x - lmin;
        let qb = lmin + (c / sb).round_ties_even() * sb;
        let qb1 = lmin + (c / sb1).round_ties_even() * sb1;
        *x = (1.0 - a) * qb + a * qb1;
    }
}

/// Quantization granularity of a weight tensor — the axis the whole
/// stack (quantizer, integer GEMM, bitpacker, BPMA artifacts) is
/// threaded on.  The paper learns bitlengths "at any granularity";
/// these are the two the deployment path implements.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    /// One bitlength + `(lmin, scale)` plan per layer.
    PerLayer,
    /// One bitlength + plan per output channel (each row of the
    /// transposed `[dout, din]` weight-code layout is its own group).
    PerOutputChannel,
}

impl Granularity {
    pub fn name(self) -> &'static str {
        match self {
            Granularity::PerLayer => "layer",
            Granularity::PerOutputChannel => "channel",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "layer" | "per-layer" => Some(Granularity::PerLayer),
            "channel" | "per-channel" => Some(Granularity::PerOutputChannel),
            _ => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Codebooks: sparse-bit code restrictions (shift-add operating point)
// ---------------------------------------------------------------------------

/// Which codes of an n-bit grid a weight group may use — the second
/// axis (after [`Granularity`]) the whole stack is threaded on.
///
/// Codes stay **unsigned grid codes** `c ∈ [0, 2^n − 1]` with
/// `value = lmin + c·scale` whatever the codebook; a non-uniform
/// codebook only restricts `c` to `half + c_s` where `half = 2^(n−1)`
/// and the *signed* part `c_s` has sparse binary magnitude.  That makes
/// every MAC `a·c = a·half + a·c_s` — a shared shift plus at most one
/// (PoT) or two (APoT) shifted adds — while all reconstruction math
/// (affine GEMM terms, dequantization, footprints) is untouched.
///
/// Magnitude sets (mirroring BWN_Shift's `bit_code1`/`bit_code2`): with
/// `emax = max(n,2) − 2`,
/// * [`Codebook::PowerOfTwo`]: `{0} ∪ {2^e : 0 ≤ e ≤ emax}`
///   (at n = 8: `[0,1,2,4,8,16,32,64]` = `bit_code1`),
/// * [`Codebook::AdditivePot2`]: all magnitudes with ≤ 2 set bits whose
///   top bit is ≤ `2^emax` (at n = 8: 29 codes = `bit_code2`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Codebook {
    /// Every grid code — today's uniform quantization, bit-identical.
    Uniform,
    /// Signed magnitudes restricted to powers of two: one shift per MAC.
    PowerOfTwo,
    /// Signed magnitudes with at most two set bits: two shifted adds.
    AdditivePot2,
}

impl Codebook {
    pub fn name(self) -> &'static str {
        match self {
            Codebook::Uniform => "uniform",
            Codebook::PowerOfTwo => "pot",
            Codebook::AdditivePot2 => "apot",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "uniform" => Some(Codebook::Uniform),
            "pot" | "power-of-two" => Some(Codebook::PowerOfTwo),
            "apot" | "additive-pot" => Some(Codebook::AdditivePot2),
            _ => None,
        }
    }

    /// Wire tag (BPMA `CBK0` section).  Stable: never renumber.
    pub fn tag(self) -> u8 {
        match self {
            Codebook::Uniform => 0,
            Codebook::PowerOfTwo => 1,
            Codebook::AdditivePot2 => 2,
        }
    }

    pub fn from_tag(t: u8) -> Option<Self> {
        match t {
            0 => Some(Codebook::Uniform),
            1 => Some(Codebook::PowerOfTwo),
            2 => Some(Codebook::AdditivePot2),
            _ => None,
        }
    }

    pub fn is_uniform(self) -> bool {
        self == Codebook::Uniform
    }
}

/// Largest magnitude exponent a codebook uses at integer bitlength
/// `bits`: `max(bits, 2) − 2`, so the largest single power `2^emax`
/// stays within the signed range `[−half, half−1]` of the grid.
pub fn codebook_emax(bits: u32) -> u32 {
    bits.max(2) - 2
}

/// Sorted signed-magnitude set of a codebook at integer bitlength
/// `bits` (always starts at 0).  Empty for [`Codebook::Uniform`], which
/// admits every magnitude.
pub fn codebook_magnitudes(cbk: Codebook, bits: u32) -> Vec<u32> {
    assert!((1..=16).contains(&bits), "codebook_magnitudes: bits {bits} outside [1,16]");
    let emax = codebook_emax(bits);
    let mut mags = match cbk {
        Codebook::Uniform => return Vec::new(),
        Codebook::PowerOfTwo => {
            let mut m = vec![0u32];
            m.extend((0..=emax).map(|e| 1u32 << e));
            m
        }
        Codebook::AdditivePot2 => {
            let mut m = vec![0u32];
            m.extend((0..=emax).map(|e| 1u32 << e));
            for hi in 1..=emax {
                for lo in 0..hi {
                    m.push((1u32 << hi) | (1u32 << lo));
                }
            }
            m
        }
    };
    mags.sort_unstable();
    mags.dedup();
    mags
}

/// Worst-case shifted **addends per MAC** a codebook costs at learned
/// weight bitlength `n`: a uniform n-bit multiply is n partial sums, a
/// PoT weight is a single shift, an APoT weight at most two.  This is
/// the per-operand compute weight [`mac_cost_cbk`] and
/// [`bit_sparsity_loss`] charge.
pub fn max_addends(cbk: Codebook, n: f32) -> f32 {
    match cbk {
        Codebook::Uniform => clip_bits(n),
        Codebook::PowerOfTwo => 1.0,
        Codebook::AdditivePot2 => clip_bits(n).min(2.0),
    }
}

/// Projection of unsigned grid codes onto a codebook: nearest signed
/// magnitude with **midpoint-up** thresholds (an exactly-between value
/// takes the larger magnitude, matching BWN_Shift's `thr[i] <= q <
/// thr[i+1]` table semantics), sign preserved, positive side clamped so
/// the projected code stays within `[0, 2^n − 1]`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CodeProjector {
    cbk: Codebook,
    bits: u32,
    half: u32,
    /// Sorted magnitudes (empty ⇒ uniform identity).
    mags: Vec<u32>,
    /// Largest magnitude usable on the positive side (`≤ half − 1`).
    max_pos: u32,
}

impl CodeProjector {
    pub fn new(cbk: Codebook, bits: u32) -> Self {
        assert!((1..=16).contains(&bits), "CodeProjector: bits {bits} outside [1,16]");
        let half = 1u32 << (bits - 1);
        let mags = codebook_magnitudes(cbk, bits);
        let max_pos = mags
            .iter()
            .rev()
            .find(|&&m| m <= half - 1)
            .copied()
            .unwrap_or(0);
        Self { cbk, bits, half, mags, max_pos }
    }

    pub fn codebook(&self) -> Codebook {
        self.cbk
    }

    pub fn bits(&self) -> u32 {
        self.bits
    }

    /// The grid code of signed magnitude 0 (`2^(n−1)`).
    pub fn half(&self) -> u32 {
        self.half
    }

    /// Nearest codebook magnitude to `m`, midpoint rounding up.
    fn nearest_mag(&self, m: u32) -> u32 {
        let i = self.mags.partition_point(|&x| x < m);
        if i == self.mags.len() {
            return self.mags[i - 1];
        }
        if self.mags[i] == m || i == 0 {
            return self.mags[i];
        }
        let (lo, hi) = (self.mags[i - 1] as u64, self.mags[i] as u64);
        if 2 * m as u64 >= lo + hi {
            hi as u32
        } else {
            lo as u32
        }
    }

    /// Project one unsigned grid code onto the codebook (identity for
    /// [`Codebook::Uniform`]).
    #[inline]
    pub fn project_code(&self, code: u32) -> u32 {
        if self.mags.is_empty() {
            return code;
        }
        let c_s = code as i64 - self.half as i64;
        if c_s >= 0 {
            self.half + self.nearest_mag(c_s as u32).min(self.max_pos)
        } else {
            self.half - self.nearest_mag((-c_s) as u32)
        }
    }

    /// Signed sparse part of a (projected) grid code: `c_s = c − half`.
    #[inline]
    pub fn signed_part(&self, code: u32) -> i64 {
        code as i64 - self.half as i64
    }

    /// Is this exact grid code representable under the codebook?
    pub fn admits(&self, code: u32) -> bool {
        self.project_code(code) == code
    }
}

/// Fake-quantize a slice as one group under a codebook (in place):
/// project every value's grid code and reconstruct on the floor grid.
/// With [`Codebook::Uniform`] this is exactly [`fake_quant_slice`]
/// (same plan, same apply — bit-identical).
pub fn fake_quant_slice_cbk(xs: &mut [f32], n: f32, cbk: Codebook) {
    if xs.is_empty() {
        return;
    }
    QuantPlan::from_slice_cbk(xs, n, cbk).apply(xs);
}

/// Per-group quantization plans: one [`QuantPlan`] per group, each over
/// its own min/max and bitlength — the per-channel generalization of
/// the single-plan path.  Every plan keeps the `alpha == 0`
/// specialization, so integer-bitlength groups still skip the second
/// grid.
#[derive(Debug, Clone)]
pub struct GroupQuantPlan {
    /// Values per group.
    pub group_size: usize,
    /// One plan per group, group order.
    pub plans: Vec<QuantPlan>,
}

impl GroupQuantPlan {
    /// Build plans for `[groups x group_size]` row-major data, each row
    /// against its own min/max at its own bitlength.
    pub fn from_groups(xs: &[f32], group_size: usize, bits: &[f32]) -> Self {
        Self::from_groups_cbk(xs, group_size, bits, Codebook::Uniform)
    }

    /// [`Self::from_groups`] with one shared codebook across the
    /// groups (a layer's channels share the code restriction; only
    /// range and bitlength vary per channel).
    pub fn from_groups_cbk(
        xs: &[f32],
        group_size: usize,
        bits: &[f32],
        codebook: Codebook,
    ) -> Self {
        assert!(group_size > 0, "group_size must be positive");
        assert_eq!(
            xs.len(),
            group_size * bits.len(),
            "xs len {} != {} groups x {}",
            xs.len(),
            bits.len(),
            group_size
        );
        let plans = xs
            .chunks(group_size)
            .zip(bits)
            .map(|(row, &n)| QuantPlan::from_slice_cbk(row, n, codebook))
            .collect();
        Self { group_size, plans }
    }

    pub fn n_groups(&self) -> usize {
        self.plans.len()
    }

    /// The codebook shared by every group ([`Codebook::Uniform`] for an
    /// empty plan).
    pub fn codebook(&self) -> Codebook {
        self.plans.first().map(|p| p.codebook).unwrap_or(Codebook::Uniform)
    }

    /// Apply every group's plan to its row in place.
    pub fn apply(&self, xs: &mut [f32]) {
        assert_eq!(
            xs.len(),
            self.group_size * self.plans.len(),
            "xs len {} != {} groups x {}",
            xs.len(),
            self.plans.len(),
            self.group_size
        );
        for (row, plan) in xs.chunks_mut(self.group_size).zip(&self.plans) {
            plan.apply(row);
        }
    }
}

/// Group-granularity fake quantization: `xs` is [groups x group_size]
/// row-major; each row quantizes against its own min/max with its own
/// bitlength (mirror of kernels/fake_quant_group.py, the per-channel
/// path).  `bits` is one entry per group.
pub fn fake_quant_groups(xs: &mut [f32], group_size: usize, bits: &[f32]) {
    if xs.is_empty() && bits.is_empty() {
        assert!(group_size > 0, "group_size must be positive");
        return;
    }
    GroupQuantPlan::from_groups(xs, group_size, bits).apply(xs);
}

/// Derive per-output-channel bitlengths from one learned per-layer
/// bitlength.  A channel whose own range is a fraction of the layer's
/// needs correspondingly fewer levels for the **same quantization step**
/// (`steps_ch = range_ch / s_layer`), so
/// `n_ch = clip(ceil(n_layer + log2(range_ch / range_layer)))` — never
/// above `ceil(n_layer)`, clipped at [`N_MIN`] from below.  `w` is the
/// `[din, dout]` row-major weight tensor; one entry per output channel
/// (column) is returned.
pub fn per_channel_bits(w: &[f32], din: usize, dout: usize, layer_bits: f32) -> Vec<f32> {
    assert_eq!(w.len(), din * dout, "per_channel_bits: {} != {din}x{dout}", w.len());
    let (gmin, gmax) = group_minmax(w);
    let grange = ((gmax - gmin) as f64).max(RANGE_EPS as f64);
    let nl = clip_bits(layer_bits) as f64;
    let mut out = Vec::with_capacity(dout);
    for j in 0..dout {
        let mut cmin = f32::INFINITY;
        let mut cmax = f32::NEG_INFINITY;
        for i in 0..din {
            let v = w[i * dout + j];
            cmin = cmin.min(v);
            cmax = cmax.max(v);
        }
        let crange = ((cmax - cmin) as f64).max(RANGE_EPS as f64);
        out.push(clip_bits((nl + (crange / grange).log2()).ceil() as f32));
    }
    out
}

/// Final bitlength selection (paper §II-C): ceil of the learned value.
pub fn select_integer_bits(bits: &[f32]) -> Vec<f32> {
    bits.iter().map(|&n| clip_bits(n).ceil()).collect()
}

/// Average bitlength over groups (paper reports per-layer averages).
pub fn mean_bits(bits: &[f32]) -> f64 {
    if bits.is_empty() {
        return 0.0;
    }
    bits.iter().map(|&b| b as f64).sum::<f64>() / bits.len() as f64
}

/// Average bitlength over every group of every layer (the sub-layer
/// average the per-channel path reports).
pub fn mean_bits_grouped(bits: &[Vec<f32>]) -> f64 {
    let n: usize = bits.iter().map(|g| g.len()).sum();
    if n == 0 {
        return 0.0;
    }
    bits.iter()
        .flat_map(|g| g.iter())
        .map(|&b| b as f64)
        .sum::<f64>()
        / n as f64
}

// ---------------------------------------------------------------------------
// Cost accounting (footprint / MAC criteria — paper §III-A5, Table IV)
// ---------------------------------------------------------------------------

/// All cost-accounting functions take one bitlength entry per layer.
/// Enforced uniformly: a short vector used to panic in [`mac_cost`]
/// (raw indexing) but silently truncate in the footprint functions
/// (`zip` stops early — wrong totals, no error).
fn assert_per_layer(what: &str, got: usize, meta: &ModelMeta) {
    assert_eq!(
        got,
        meta.layers.len(),
        "{what}: {got} bitlength entries for a {}-layer model",
        meta.layers.len()
    );
}

/// Weight-memory footprint in bits for given per-layer weight bitlengths.
pub fn weight_footprint_bits(meta: &ModelMeta, bits_w: &[f32]) -> f64 {
    assert_per_layer("weight_footprint_bits", bits_w.len(), meta);
    meta.layers
        .iter()
        .zip(bits_w)
        .map(|(l, &b)| l.weight_elems as f64 * clip_bits(b) as f64)
        .sum()
}

/// Weight-memory footprint in bits at **per-output-channel**
/// bitlengths: `bits_w[l]` holds one entry per output channel of layer
/// `l` (each channel carries `weight_elems / cout` elements).  With
/// every channel of a layer at that layer's bitlength this reduces
/// exactly to [`weight_footprint_bits`].
pub fn weight_footprint_bits_grouped(meta: &ModelMeta, bits_w: &[Vec<f32>]) -> f64 {
    assert_per_layer("weight_footprint_bits_grouped", bits_w.len(), meta);
    meta.layers
        .iter()
        .zip(bits_w)
        .map(|(l, g)| {
            assert_eq!(
                g.len(),
                l.cout,
                "{}: {} channel bitlengths for {} output channels",
                l.name,
                g.len(),
                l.cout
            );
            let per_ch = l.weight_elems as f64 / l.cout as f64;
            g.iter().map(|&b| per_ch * clip_bits(b) as f64).sum::<f64>()
        })
        .sum()
}

/// Activation footprint in bits for a batch size: per the paper/MPDNN
/// convention, weights count fully while activations count as the
/// *largest* single layer (what must be resident at once).
pub fn act_footprint_bits(meta: &ModelMeta, bits_a: &[f32], batch: usize) -> f64 {
    assert_per_layer("act_footprint_bits", bits_a.len(), meta);
    meta.layers
        .iter()
        .zip(bits_a)
        .map(|(l, &b)| (l.act_in_elems * batch) as f64 * clip_bits(b) as f64)
        .fold(0.0, f64::max)
}

/// Total inference footprint in bits at a given batch size
/// (weights + largest activation layer).
pub fn total_footprint_bits(
    meta: &ModelMeta,
    bits_w: &[f32],
    bits_a: &[f32],
    batch: usize,
) -> f64 {
    weight_footprint_bits(meta, bits_w) + act_footprint_bits(meta, bits_a, batch)
}

/// "Bit-MACs": Σ macs_l · (n_w,l + n_a,l) — the compute-cost proxy the
/// paper's MAC-weighted regularizer minimizes (bit-serial hardware cost
/// scales with operand bitlength).
pub fn mac_cost(meta: &ModelMeta, bits_w: &[f32], bits_a: &[f32]) -> f64 {
    assert_per_layer("mac_cost (weights)", bits_w.len(), meta);
    assert_per_layer("mac_cost (activations)", bits_a.len(), meta);
    meta.layers
        .iter()
        .zip(bits_w.iter().zip(bits_a))
        .map(|(l, (&bw, &ba))| l.macs as f64 * (clip_bits(bw) + clip_bits(ba)) as f64)
        .sum()
}

/// Codebook-aware bit-MACs: the weight operand of each MAC is charged
/// its worst-case shifted addends ([`max_addends`]) instead of its full
/// bitlength — a PoT weight costs one shift whatever its bitlength, an
/// APoT weight at most two.  With every layer at
/// [`Codebook::Uniform`] this is exactly [`mac_cost`] (pinned by
/// tests): `max_addends(Uniform, n) == clip_bits(n)`.
pub fn mac_cost_cbk(
    meta: &ModelMeta,
    bits_w: &[f32],
    bits_a: &[f32],
    codebooks: &[Codebook],
) -> f64 {
    assert_per_layer("mac_cost_cbk (weights)", bits_w.len(), meta);
    assert_per_layer("mac_cost_cbk (activations)", bits_a.len(), meta);
    assert_per_layer("mac_cost_cbk (codebooks)", codebooks.len(), meta);
    meta.layers
        .iter()
        .zip(bits_w.iter().zip(bits_a))
        .zip(codebooks)
        .map(|((l, (&bw, &ba)), &cbk)| {
            l.macs as f64 * (max_addends(cbk, bw) + clip_bits(ba)) as f64
        })
        .sum()
}

/// Per-sample MACs of a Conv2d layer: one multiply-accumulate per
/// output element per kernel tap — `out_h · out_w · cout · kh · kw ·
/// cin`.  This is the HLO analyzer's convolution convention
/// ([`crate::hlo::analyze_text`] scores a convolution at
/// `2 · output elems · kernel spatial · cin`, i.e. FLOPs = 2 · MACs),
/// so `macs` entries in a model meta built from conv geometry
/// cost-account consistently with the static HLO reports.  A 1×1
/// kernel over a 1×1 output plane degenerates to the dense count
/// `din · dout`.
pub fn conv_macs(
    cin: usize,
    kh: usize,
    kw: usize,
    out_h: usize,
    out_w: usize,
    cout: usize,
) -> usize {
    out_h * out_w * cout * kh * kw * cin
}

/// A layer's regularizer weight split evenly over its groups, so the
/// Σ(λ·8) == 1 normalization of [`Criterion::lambdas`] is preserved at
/// any granularity (an all-8-bit network still scores bit-loss 1.0).
pub fn split_lambda(lam_layer: f32, groups: usize) -> f32 {
    assert!(groups > 0, "split_lambda: zero groups");
    lam_layer / groups as f32
}

/// Group-summed bit loss — the per-channel generalization of the
/// paper's Σ λ·n penalty.  Weight bitlengths come per layer **per
/// group** (`bits_w[l]` has one entry per group of layer `l`, with the
/// layer's λ split evenly across them via [`split_lambda`]);
/// activations stay per-layer.  With one group per layer this is
/// exactly the per-layer penalty.
pub fn grouped_bit_loss(
    lam_w: &[f32],
    bits_w: &[Vec<f32>],
    lam_a: &[f32],
    bits_a: &[f32],
) -> f64 {
    assert_eq!(
        lam_w.len(),
        bits_w.len(),
        "grouped_bit_loss: {} weight λ for {} layers",
        lam_w.len(),
        bits_w.len()
    );
    assert_eq!(
        lam_a.len(),
        bits_a.len(),
        "grouped_bit_loss: {} activation λ for {} layers",
        lam_a.len(),
        bits_a.len()
    );
    let w: f64 = lam_w
        .iter()
        .zip(bits_w)
        .map(|(&lam, g)| {
            let lg = split_lambda(lam, g.len()) as f64;
            g.iter().map(|&n| lg * clip_bits(n) as f64).sum::<f64>()
        })
        .sum();
    let a: f64 = lam_a
        .iter()
        .zip(bits_a)
        .map(|(&lam, &n)| lam as f64 * clip_bits(n) as f64)
        .sum();
    w + a
}

/// Bit-**sparsity** regularizer — the codebook companion of the weight
/// term of [`grouped_bit_loss`].  Each weight group is charged its
/// worst-case shifted addends under the layer's codebook
/// ([`max_addends`]) instead of its raw bitlength, with the layer λ
/// split over groups exactly as [`split_lambda`] does.  With every
/// layer at [`Codebook::Uniform`] this equals the weight term of
/// [`grouped_bit_loss`] (pinned by tests), so the optimizer sees the
/// same landscape until a codebook is switched on; under PoT/APoT the
/// penalty saturates, steering spend toward activations and ranges —
/// the paper's "other quantifiable criteria" hook.
pub fn bit_sparsity_loss(
    lam_w: &[f32],
    bits_w: &[Vec<f32>],
    codebooks: &[Codebook],
) -> f64 {
    assert_eq!(
        lam_w.len(),
        bits_w.len(),
        "bit_sparsity_loss: {} weight λ for {} layers",
        lam_w.len(),
        bits_w.len()
    );
    assert_eq!(
        codebooks.len(),
        bits_w.len(),
        "bit_sparsity_loss: {} codebooks for {} layers",
        codebooks.len(),
        bits_w.len()
    );
    lam_w
        .iter()
        .zip(bits_w)
        .zip(codebooks)
        .map(|((&lam, g), &cbk)| {
            let lg = split_lambda(lam, g.len()) as f64;
            g.iter().map(|&n| lg * max_addends(cbk, n) as f64).sum::<f64>()
        })
        .sum()
}

/// λ vectors for the regularizer criteria (paper §II-B / §III-A5).
/// Normalized so an all-8-bit network yields bit-loss 1.0 across the
/// *combined* weight+activation groups, matching the python side.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Criterion {
    /// Every group weighted equally.
    Equal,
    /// Weight by memory footprint at batch size 1 (weight-heavy).
    FootprintBs1,
    /// Weight by memory footprint at a large batch (activation-heavy).
    FootprintBs128,
    /// Weight by MAC count.
    MacOps,
}

impl Criterion {
    pub fn name(self) -> &'static str {
        match self {
            Criterion::Equal => "equal",
            Criterion::FootprintBs1 => "bs1",
            Criterion::FootprintBs128 => "bs128",
            Criterion::MacOps => "mac",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "equal" => Some(Criterion::Equal),
            "bs1" => Some(Criterion::FootprintBs1),
            "bs128" => Some(Criterion::FootprintBs128),
            "mac" => Some(Criterion::MacOps),
            _ => None,
        }
    }

    /// Per-group raw costs (weights groups first, then activations).
    fn costs(self, meta: &ModelMeta) -> (Vec<f64>, Vec<f64>) {
        let nl = meta.layers.len();
        match self {
            Criterion::Equal => (vec![1.0; nl], vec![1.0; nl]),
            Criterion::FootprintBs1 => (
                meta.layers.iter().map(|l| l.weight_elems as f64).collect(),
                meta.layers.iter().map(|l| l.act_in_elems as f64).collect(),
            ),
            Criterion::FootprintBs128 => (
                meta.layers.iter().map(|l| l.weight_elems as f64).collect(),
                meta.layers
                    .iter()
                    .map(|l| (l.act_in_elems * 128) as f64)
                    .collect(),
            ),
            Criterion::MacOps => (
                meta.layers.iter().map(|l| l.macs as f64).collect(),
                meta.layers.iter().map(|l| l.macs as f64).collect(),
            ),
        }
    }

    /// Normalized λ vectors: (lam_w, lam_a) with
    /// Σ(λ · 8) over both vectors == 1.0.
    pub fn lambdas(self, meta: &ModelMeta) -> (Vec<f32>, Vec<f32>) {
        let (cw, ca) = self.costs(meta);
        let total: f64 = cw.iter().chain(ca.iter()).sum();
        let norm = 8.0 * total;
        (
            cw.iter().map(|&c| (c / norm) as f32).collect(),
            ca.iter().map(|&c| (c / norm) as f32).collect(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::{check, close};
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, len: usize) -> Vec<f32> {
        (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    #[test]
    fn acc_width_pins_exact_promotion_thresholds() {
        use AccWidth::*;
        // i16 -> i32 promotion at w + a + ceil(log2(din)) crossing 15:
        // 4+4+7 = 15 at din 128; din 129 rounds the log term up to 8.
        assert_eq!(acc_width(4, 4, 128), I16);
        assert_eq!(acc_width(4, 4, 129), I32);
        // i32 -> i64 promotion at the sum crossing 31.
        assert_eq!(acc_width(8, 8, 1 << 15), I32);
        assert_eq!(acc_width(8, 8, (1 << 15) + 1), I64);
        // ceil(log2) is exact, not floor: din 3 counts as 2 bits, so
        // 8+6+2 = 16 promotes to i32 where floor(log2 3) = 1 would
        // have (unsafely: 3·255·63 = 48195 > i16::MAX) said i16.
        assert_eq!(acc_width(7, 6, 3), I16);
        assert_eq!(acc_width(8, 6, 3), I32);
        // Degenerate din and the 16-bit-operand guard (narrow lanes
        // multiply codes as i16, so >15-bit operands force i64 even
        // when the sum-of-bits test would pass).
        assert_eq!(acc_width(1, 1, 0), I16);
        assert_eq!(acc_width(16, 1, 1), I64);
        assert_eq!(acc_width(1, 16, 1), I64);
    }

    #[test]
    fn acc_width_never_wraps_at_max_magnitude() {
        // Overflow-adversarial sweep: for every (w, a) and a set of
        // boundary fan-ins, the exact worst-case accumulator
        // din·(2^w−1)·(2^a−1) — every code at max magnitude — must fit
        // the selected signed lane.  Computed in i128 so the check
        // itself cannot wrap.
        let dins = [
            1usize,
            2,
            3,
            7,
            8,
            127,
            128,
            129,
            255,
            256,
            1 << 15,
            (1 << 15) + 1,
            1 << 20,
            (1 << 20) + 1,
        ];
        for w in 1..=16u32 {
            for a in 1..=16u32 {
                for &din in &dins {
                    let lane = acc_width(w, a, din);
                    let max_acc = din as i128
                        * ((1i128 << w) - 1)
                        * ((1i128 << a) - 1);
                    let limit = (1i128 << (lane.bits() - 1)) - 1;
                    assert!(
                        max_acc <= limit,
                        "acc_width({w}, {a}, {din}) = {} wraps: \
                         max acc {max_acc} > {limit}",
                        lane.name()
                    );
                }
            }
        }
    }

    #[test]
    fn int_bits_clips_then_ceils() {
        assert_eq!(int_bits(3.2), 4);
        assert_eq!(int_bits(4.0), 4);
        assert_eq!(int_bits(0.1), 1); // clipped to N_MIN first
        assert_eq!(int_bits(-5.0), 1);
        assert_eq!(int_bits(99.0), 16); // clipped to N_MAX
        assert_eq!(int_bits(15.01), 16);
    }

    #[test]
    fn integer_bits_are_idempotent() {
        // Quantizing an already-quantized tensor at the same integer
        // bitlength is a fixed point.
        check(
            "quant-idempotent",
            128,
            |rng| {
                let n = (rng.below(7) + 2) as f32;
                (rand_vec(rng, 64), n)
            },
            |(xs, n)| {
                let mut once = xs.clone();
                fake_quant_slice(&mut once, *n);
                let mut twice = once.clone();
                fake_quant_slice(&mut twice, *n);
                for (a, b) in once.iter().zip(&twice) {
                    // min/max of the quantized tensor may shrink, but the
                    // grid over [min,max] keeps quantized points exactly
                    // representable only when endpoints survive; allow
                    // tiny drift.
                    close(*a as f64, *b as f64, 1e-5, "idempotent")?;
                }
                Ok(())
            },
        );
    }

    #[test]
    fn quantized_values_stay_in_range() {
        check(
            "quant-in-range",
            256,
            |rng| {
                let n = rng.range_f32(1.0, 9.0);
                (rand_vec(rng, 33), n)
            },
            |(xs, n)| {
                let (lmin, lmax) = group_minmax(xs);
                let mut q = xs.clone();
                fake_quant_slice(&mut q, *n);
                for &v in &q {
                    if v < lmin - 1e-4 || v > lmax + 1e-4 {
                        return Err(format!("value {v} outside [{lmin}, {lmax}]"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn more_bits_less_error() {
        // Monotonicity on average: error at n+2 bits <= error at n bits.
        check(
            "quant-monotone",
            64,
            |rng| (rand_vec(rng, 256), (rng.below(6) + 2) as f32),
            |(xs, n)| {
                let err = |bits: f32| {
                    let mut q = xs.clone();
                    fake_quant_slice(&mut q, bits);
                    xs.iter()
                        .zip(&q)
                        .map(|(a, b)| ((a - b) as f64).powi(2))
                        .sum::<f64>()
                };
                if err(*n + 2.0) <= err(*n) + 1e-9 {
                    Ok(())
                } else {
                    Err(format!("error increased from {} to {} bits", n, n + 2.0))
                }
            },
        );
    }

    #[test]
    fn interpolation_endpoints() {
        let xs: Vec<f32> = (0..50).map(|i| (i as f32 * 0.37).sin()).collect();
        let (lmin, lmax) = group_minmax(&xs);
        for &x in &xs {
            // alpha == 0 reduces to the integer quantizer.
            assert_eq!(
                quantize_interp(x, lmin, lmax, 3.0),
                quantize_int(x, lmin, lmax, 3.0)
            );
            // midpoint is the strict blend.
            let mid = quantize_interp(x, lmin, lmax, 3.5);
            let expect = 0.5 * quantize_int(x, lmin, lmax, 3.0)
                + 0.5 * quantize_int(x, lmin, lmax, 4.0);
            assert!((mid - expect).abs() < 1e-6);
        }
    }

    #[test]
    fn group_quant_rows_independent() {
        check(
            "group-quant-independent",
            64,
            |rng| {
                let groups = 1 + rng.below_usize(8);
                let size = 1 + rng.below_usize(64);
                let xs = rand_vec(rng, groups * size);
                let bits: Vec<f32> =
                    (0..groups).map(|_| rng.range_f32(1.0, 9.0)).collect();
                (xs, size, bits)
            },
            |(xs, size, bits)| {
                let mut got = xs.clone();
                fake_quant_groups(&mut got, *size, bits);
                // Must equal quantizing each row separately.
                for (g, (row, &n)) in xs.chunks(*size).zip(bits).enumerate() {
                    let mut want = row.to_vec();
                    fake_quant_slice(&mut want, n);
                    let got_row = &got[g * size..(g + 1) * size];
                    if got_row != want.as_slice() {
                        return Err(format!("group {g} differs"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn group_quant_finer_granularity_lower_error() {
        let mut rng = Rng::new(77);
        // Rows with very different scales: per-group wins.
        let mut xs = Vec::new();
        for g in 0..8 {
            let scale = 10f32.powi(g % 4 - 2);
            xs.extend((0..32).map(|_| rng.normal_f32(0.0, scale)));
        }
        let sse = |q: &[f32]| -> f64 {
            xs.iter().zip(q).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
        };
        let mut per_tensor = xs.clone();
        fake_quant_slice(&mut per_tensor, 4.0);
        let mut per_group = xs.clone();
        fake_quant_groups(&mut per_group, 32, &[4.0; 8]);
        assert!(sse(&per_group) < sse(&per_tensor));
    }

    #[test]
    #[should_panic(expected = "groups x")]
    fn group_quant_len_mismatch_panics() {
        let mut xs = vec![0.0f32; 10];
        fake_quant_groups(&mut xs, 4, &[4.0, 4.0]);
    }

    #[test]
    fn one_bit_two_levels() {
        let xs = [-1.0f32, -0.4, 0.3, 1.0];
        let mut q = xs.to_vec();
        fake_quant_slice(&mut q, 1.0);
        for v in &q {
            assert!(*v == -1.0 || *v == 1.0, "1-bit value {v}");
        }
    }

    #[test]
    fn degenerate_group_is_identity() {
        let mut xs = vec![0.5f32; 16];
        fake_quant_slice(&mut xs, 3.0);
        assert!(xs.iter().all(|&v| (v - 0.5).abs() < 1e-6));
    }

    #[test]
    fn clip_and_ceil_selection() {
        assert_eq!(clip_bits(0.2), 1.0);
        assert_eq!(clip_bits(20.0), 16.0);
        let sel = select_integer_bits(&[1.2, 3.0, 4.01, 0.5]);
        assert_eq!(sel, vec![2.0, 3.0, 5.0, 1.0]);
        // ceil(learned) is within [learned, learned+1]
        check(
            "ceil-bound",
            128,
            |rng| rng.range_f32(1.0, 16.0),
            |&n| {
                let s = select_integer_bits(&[n])[0];
                if s >= n && s < n + 1.0 + 1e-6 {
                    Ok(())
                } else {
                    Err(format!("select {s} not in [{n}, {n}+1]"))
                }
            },
        );
    }

    #[test]
    fn fast_slice_matches_ref_bitwise() {
        // The QuantPlan kernel must be bit-identical to the retained
        // scalar reference at every bitlength, fractional or integer.
        check(
            "quantplan-parity",
            256,
            |rng| {
                let len = 1 + rng.below_usize(200);
                let n = if rng.below(2) == 0 {
                    (1 + rng.below(16)) as f32 // integer (alpha == 0 shortcut)
                } else {
                    rng.range_f32(1.0, 16.0)
                };
                (rand_vec(rng, len), n)
            },
            |(xs, n)| {
                let mut fast = xs.clone();
                fake_quant_slice(&mut fast, *n);
                let mut slow = xs.clone();
                fake_quant_slice_ref(&mut slow, *n);
                for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
                    if f.to_bits() != s.to_bits() {
                        return Err(format!(
                            "elem {i}: fast {f} ({:#x}) vs ref {s} ({:#x}) at n={n}",
                            f.to_bits(),
                            s.to_bits()
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn plan_reuse_matches_per_call() {
        // One plan applied to many slices over the same range must equal
        // per-value quantize_interp with that range.
        let mut rng = Rng::new(99);
        let xs = rand_vec(&mut rng, 256);
        let (lmin, lmax) = group_minmax(&xs);
        for n in [1.0f32, 3.0, 4.7, 8.0, 15.5] {
            let plan = QuantPlan::new(lmin, lmax, n);
            for &x in &xs {
                assert_eq!(
                    plan.quantize(x).to_bits(),
                    quantize_interp(x, lmin, lmax, n).to_bits(),
                    "x={x} n={n}"
                );
            }
        }
    }

    #[test]
    fn plan_codes_match_grid() {
        // code() lands each value on the same grid point quantize() maps
        // it to (integer bits, in-range values).
        let mut rng = Rng::new(7);
        let xs = rand_vec(&mut rng, 128);
        for bits in [1u32, 2, 4, 8, 12, 16] {
            let plan = QuantPlan::from_slice(&xs, bits as f32);
            let levels = ((1u64 << bits) - 1) as i64;
            for &x in &xs {
                let code = plan.code(x, levels);
                assert!(code as i64 <= levels);
                let recon = plan.lmin + code as f32 * plan.s_lo;
                let q = plan.quantize(x);
                assert!(
                    (recon - q).abs() <= 1e-5 * (1.0 + q.abs()),
                    "bits={bits} x={x}: recon {recon} vs quantize {q}"
                );
            }
        }
    }

    #[test]
    fn chunked_minmax_matches_fold() {
        check(
            "minmax-parity",
            128,
            |rng| rand_vec(rng, rng.below_usize(70)),
            |xs| {
                let got = group_minmax(xs);
                let mut want = (f32::INFINITY, f32::NEG_INFINITY);
                for &x in xs {
                    want.0 = want.0.min(x);
                    want.1 = want.1.max(x);
                }
                if got == want {
                    Ok(())
                } else {
                    Err(format!("{got:?} vs {want:?}"))
                }
            },
        );
    }

    #[test]
    fn round_ties_even_matches_numpy_semantics() {
        // jnp.round(0.5) == 0.0, jnp.round(1.5) == 2.0
        assert_eq!(0.5f32.round_ties_even(), 0.0);
        assert_eq!(1.5f32.round_ties_even(), 2.0);
        assert_eq!((-0.5f32).round_ties_even(), 0.0);
        assert_eq!(2.5f32.round_ties_even(), 2.0);
    }

    fn tiny_meta() -> ModelMeta {
        let j = crate::util::json::parse(&crate::model::tiny_meta_json()).unwrap();
        ModelMeta::from_json(&j).unwrap()
    }

    #[test]
    fn lambdas_normalize_to_one_at_8_bits() {
        let meta = tiny_meta();
        for crit in [
            Criterion::Equal,
            Criterion::FootprintBs1,
            Criterion::FootprintBs128,
            Criterion::MacOps,
        ] {
            let (lw, la) = crit.lambdas(&meta);
            let loss: f64 = lw
                .iter()
                .chain(la.iter())
                .map(|&l| l as f64 * 8.0)
                .sum();
            assert!((loss - 1.0).abs() < 1e-6, "{:?}: {}", crit, loss);
        }
    }

    #[test]
    fn footprint_and_mac_costs() {
        let meta = tiny_meta();
        let b8 = vec![8.0f32; 2];
        let b4 = vec![4.0f32; 2];
        // Halving bits halves footprint and MAC cost.
        assert!(
            (weight_footprint_bits(&meta, &b4) * 2.0
                - weight_footprint_bits(&meta, &b8))
            .abs()
                < 1e-9
        );
        assert!((mac_cost(&meta, &b4, &b4) * 2.0 - mac_cost(&meta, &b8, &b8)).abs() < 1e-9);
        // Activation footprint takes the max layer.
        let af = act_footprint_bits(&meta, &b8, 2);
        assert_eq!(af, (16 * 2) as f64 * 8.0);
        assert_eq!(
            total_footprint_bits(&meta, &b8, &b8, 2),
            weight_footprint_bits(&meta, &b8) + af
        );
    }

    #[test]
    #[should_panic(expected = "weight_footprint_bits: 1 bitlength entries")]
    fn weight_footprint_rejects_short_bits() {
        weight_footprint_bits(&tiny_meta(), &[4.0]);
    }

    #[test]
    #[should_panic(expected = "act_footprint_bits: 3 bitlength entries")]
    fn act_footprint_rejects_long_bits() {
        act_footprint_bits(&tiny_meta(), &[4.0, 4.0, 4.0], 1);
    }

    #[test]
    #[should_panic(expected = "mac_cost (activations): 1 bitlength entries")]
    fn mac_cost_rejects_short_bits() {
        mac_cost(&tiny_meta(), &[4.0, 4.0], &[4.0]);
    }

    #[test]
    fn granularity_parse_roundtrip() {
        for g in [Granularity::PerLayer, Granularity::PerOutputChannel] {
            assert_eq!(Granularity::parse(g.name()), Some(g));
        }
        assert_eq!(Granularity::parse("per-channel"), Some(Granularity::PerOutputChannel));
        assert_eq!(Granularity::parse("per-layer"), Some(Granularity::PerLayer));
        assert_eq!(Granularity::parse("tensor"), None);
    }

    #[test]
    fn group_plan_matches_per_row_slices() {
        // GroupQuantPlan::apply must equal quantizing each row alone —
        // including the alpha == 0 shortcut on integer rows.
        let mut rng = Rng::new(0x64B);
        let (groups, size) = (6usize, 17usize);
        let xs = rand_vec(&mut rng, groups * size);
        let bits: Vec<f32> = vec![2.0, 3.5, 4.0, 1.0, 7.25, 16.0];
        let plan = GroupQuantPlan::from_groups(&xs, size, &bits);
        assert_eq!(plan.n_groups(), groups);
        let mut got = xs.clone();
        plan.apply(&mut got);
        for (g, (row, &n)) in xs.chunks(size).zip(&bits).enumerate() {
            let mut want = row.to_vec();
            fake_quant_slice(&mut want, n);
            let got_row = &got[g * size..(g + 1) * size];
            assert!(
                got_row.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "group {g} differs"
            );
        }
    }

    #[test]
    fn per_channel_bits_never_exceed_layer_ceiling() {
        check(
            "per-channel-bits-bound",
            64,
            |rng| {
                let din = 1 + rng.below_usize(24);
                let dout = 1 + rng.below_usize(16);
                let w = rand_vec(rng, din * dout);
                let n = rng.range_f32(1.0, 12.0);
                (w, din, dout, n)
            },
            |(w, din, dout, n)| {
                let bits = per_channel_bits(w, *din, *dout, *n);
                if bits.len() != *dout {
                    return Err("wrong channel count".into());
                }
                let cap = clip_bits(*n).ceil();
                for (j, &b) in bits.iter().enumerate() {
                    if !(N_MIN..=cap).contains(&b) {
                        return Err(format!("channel {j}: {b} outside [1, {cap}]"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn per_channel_bits_shrink_with_channel_range() {
        // A channel spanning 1/4 of the layer range needs 2 fewer bits
        // for the same step; a full-range channel keeps the ceiling.
        let (din, dout) = (8usize, 2usize);
        let mut w = vec![0.0f32; din * dout];
        for i in 0..din {
            let t = i as f32 / (din - 1) as f32; // 0..=1
            w[i * dout] = -2.0 + 4.0 * t; // channel 0: full [-2, 2]
            w[i * dout + 1] = -0.5 + 1.0 * t; // channel 1: quarter range
        }
        let bits = per_channel_bits(&w, din, dout, 6.0);
        assert_eq!(bits[0], 6.0);
        assert_eq!(bits[1], 4.0);
    }

    #[test]
    fn grouped_bit_loss_reduces_to_per_layer_and_normalizes() {
        let meta = tiny_meta();
        for crit in [Criterion::Equal, Criterion::MacOps] {
            let (lw, la) = crit.lambdas(&meta);
            // One group per layer at 8 bits: the normalization contract.
            let b8: Vec<Vec<f32>> = vec![vec![8.0]; 2];
            let a8 = vec![8.0f32; 2];
            let loss = grouped_bit_loss(&lw, &b8, &la, &a8);
            assert!((loss - 1.0).abs() < 1e-6, "{crit:?}: {loss}");
            // Splitting a layer into uniform groups changes nothing.
            let split: Vec<Vec<f32>> = vec![vec![8.0; 5], vec![8.0; 3]];
            let loss2 = grouped_bit_loss(&lw, &split, &la, &a8);
            assert!((loss2 - loss).abs() < 1e-6);
            // Halving one group's bits strictly lowers the loss.
            let mut cheaper = split.clone();
            cheaper[0][2] = 4.0;
            assert!(grouped_bit_loss(&lw, &cheaper, &la, &a8) < loss2);
        }
    }

    #[test]
    fn grouped_footprint_reduces_to_per_layer() {
        let meta = tiny_meta();
        let per_layer = weight_footprint_bits(&meta, &[6.0, 3.0]);
        let grouped: Vec<Vec<f32>> = meta
            .layers
            .iter()
            .zip([6.0f32, 3.0])
            .map(|(l, b)| vec![b; l.cout])
            .collect();
        let g = weight_footprint_bits_grouped(&meta, &grouped);
        assert!((g - per_layer).abs() < 1e-9);
        // Dropping one channel's bits shrinks the footprint.
        let mut cheaper = grouped.clone();
        cheaper[0][0] = 1.0;
        assert!(weight_footprint_bits_grouped(&meta, &cheaper) < g);
        // Mean over flattened groups.
        assert_eq!(mean_bits_grouped(&[]), 0.0);
        let m = mean_bits_grouped(&[vec![2.0, 4.0], vec![6.0]]);
        assert!((m - 4.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "channel bitlengths")]
    fn grouped_footprint_rejects_wrong_channel_count() {
        let meta = tiny_meta();
        let bad: Vec<Vec<f32>> = vec![vec![4.0; 1], vec![4.0; 1]];
        weight_footprint_bits_grouped(&meta, &bad);
    }

    #[test]
    fn conv_macs_pin_dense_and_hlo_conventions() {
        // Dense equivalence: a 1×1 kernel over a 1×1 output plane is
        // exactly a dense layer of din·dout MACs.
        assert_eq!(conv_macs(64, 1, 1, 1, 1, 10), 64 * 10);
        // The HLO analyzer's pinned convolution case: output
        // f32[32,16,16,32], kernel f32[3,3,3,32].  Per-sample MACs =
        // 16·16·32 · 3·3·3 = 221184; the analyzer scores the whole
        // batch at 2·MACs FLOPs.
        let per_sample = conv_macs(3, 3, 3, 16, 16, 32);
        assert_eq!(per_sample, 221_184);
        let batch = 32;
        let hlo = crate::hlo::analyze_text(
            "ENTRY %main {\n  %conv = f32[32,16,16,32]{3,2,1,0} \
             convolution(f32[32,16,16,3]{3,2,1,0} %x, \
             f32[3,3,3,32]{3,2,1,0} %w), window={size=3x3 pad=1_1x1_1}\n}",
        );
        assert_eq!(hlo.matmul_flops, 2.0 * (batch * per_sample) as f64);
        // The integer conv op's own accounting agrees.
        let g = crate::infer::ConvGeom {
            cin: 3,
            h: 16,
            w: 16,
            cout: 32,
            kh: 3,
            kw: 3,
            stride: 1,
            pad: 1,
        };
        assert_eq!(g.macs_per_sample(), conv_macs(3, 3, 3, g.out_h(), g.out_w(), 32));
    }

    #[test]
    fn criterion_parse_roundtrip() {
        for c in [
            Criterion::Equal,
            Criterion::FootprintBs1,
            Criterion::FootprintBs128,
            Criterion::MacOps,
        ] {
            assert_eq!(Criterion::parse(c.name()), Some(c));
        }
        assert_eq!(Criterion::parse("bogus"), None);
    }

    #[test]
    fn codebook_parse_and_tag_roundtrip() {
        for c in [Codebook::Uniform, Codebook::PowerOfTwo, Codebook::AdditivePot2] {
            assert_eq!(Codebook::parse(c.name()), Some(c));
            assert_eq!(Codebook::from_tag(c.tag()), Some(c));
        }
        assert_eq!(Codebook::parse("power-of-two"), Some(Codebook::PowerOfTwo));
        assert_eq!(Codebook::parse("additive-pot"), Some(Codebook::AdditivePot2));
        assert_eq!(Codebook::parse("ternary"), None);
        assert_eq!(Codebook::from_tag(3), None);
        assert!(Codebook::Uniform.is_uniform());
        assert!(!Codebook::PowerOfTwo.is_uniform());
    }

    #[test]
    fn codebook_magnitudes_match_bwn_shift_tables() {
        // At 8 bits the sets are exactly BWN_Shift's bit_code1 /
        // bit_code2 (SNIPPETS.md Snippet 1).
        assert_eq!(
            codebook_magnitudes(Codebook::PowerOfTwo, 8),
            vec![0, 1, 2, 4, 8, 16, 32, 64]
        );
        let apot8 = codebook_magnitudes(Codebook::AdditivePot2, 8);
        assert_eq!(apot8.len(), 29); // zero + 7 singles + C(7,2) pairs
        for &m in &apot8 {
            assert!(m.count_ones() <= 2 && m <= 64 + 32, "mag {m}");
        }
        // Every PoT magnitude is an APoT magnitude.
        for &m in &codebook_magnitudes(Codebook::PowerOfTwo, 8) {
            assert!(apot8.contains(&m));
        }
        // Uniform admits everything — no restriction table.
        assert!(codebook_magnitudes(Codebook::Uniform, 8).is_empty());
        // Low-bit edge: 1- and 2-bit share emax = 0 → mags {0, 1}.
        for bits in [1u32, 2] {
            assert_eq!(codebook_magnitudes(Codebook::PowerOfTwo, bits), vec![0, 1]);
            assert_eq!(codebook_magnitudes(Codebook::AdditivePot2, bits), vec![0, 1]);
        }
    }

    #[test]
    fn projector_midpoint_up_and_range() {
        let p = CodeProjector::new(Codebook::PowerOfTwo, 8);
        let half = 128i64;
        // Exact codebook codes are fixed points.
        for &m in &codebook_magnitudes(Codebook::PowerOfTwo, 8) {
            assert!(p.admits((half + m as i64) as u32));
            assert!(p.admits((half - m as i64) as u32));
        }
        // Midpoint between 4 and 8 is 6 → up to 8; 5 → down to 4.
        assert_eq!(p.project_code((half + 6) as u32), (half + 8) as u32);
        assert_eq!(p.project_code((half + 5) as u32), (half + 4) as u32);
        // Same on the negative side (magnitude midpoints, sign kept).
        assert_eq!(p.project_code((half - 6) as u32), (half - 8) as u32);
        assert_eq!(p.project_code((half - 5) as u32), (half - 4) as u32);
        // Saturation: |c_s| beyond the top magnitude clamps to it.
        assert_eq!(p.project_code(255), (half + 64) as u32);
        assert_eq!(p.project_code(0), (half - 64) as u32);
        // Uniform projector is the identity.
        let u = CodeProjector::new(Codebook::Uniform, 8);
        for c in 0..=255u32 {
            assert_eq!(u.project_code(c), c);
        }
    }

    #[test]
    fn projector_output_always_in_grid_range() {
        // Property: projected codes stay in [0, 2^n − 1] for every
        // bitlength (the n = 1 positive clamp is the sharp edge:
        // half = 1 admits +0 but not +1).
        for cbk in [Codebook::PowerOfTwo, Codebook::AdditivePot2] {
            for bits in 1..=16u32 {
                let p = CodeProjector::new(cbk, bits);
                let max_code = (1u64 << bits) - 1;
                for code in [0u64, 1, max_code / 2, max_code - 1, max_code] {
                    let got = p.project_code(code as u32) as u64;
                    assert!(got <= max_code, "{cbk:?} bits={bits} code={code} -> {got}");
                    // Projection is idempotent.
                    assert_eq!(p.project_code(got as u32) as u64, got);
                }
            }
        }
        // n = 1 pinned: codes {0, 1} both survive (0 → mag −1, 1 → mag 0).
        let p1 = CodeProjector::new(Codebook::PowerOfTwo, 1);
        assert_eq!(p1.project_code(0), 0);
        assert_eq!(p1.project_code(1), 1);
    }

    #[test]
    fn projector_nearest_is_exact_over_all_codes() {
        // Exhaustive at 8 bits: the projected magnitude must be a true
        // nearest element of the table (ties to the larger).
        for cbk in [Codebook::PowerOfTwo, Codebook::AdditivePot2] {
            let p = CodeProjector::new(cbk, 8);
            let mags = codebook_magnitudes(cbk, 8);
            for code in 0..=255u32 {
                let c_s = code as i64 - 128;
                let m = c_s.unsigned_abs() as u32;
                let got = p.project_code(code);
                let got_mag = (got as i64 - 128).unsigned_abs() as u32;
                let best = mags
                    .iter()
                    .copied()
                    .min_by_key(|&t| {
                        let d = (t as i64 - m as i64).unsigned_abs();
                        (d, u32::MAX - t) // ties prefer larger magnitude
                    })
                    .unwrap();
                if c_s >= 0 {
                    assert_eq!(got_mag, best.min(127), "{cbk:?} code {code}");
                } else {
                    assert_eq!(got_mag, best, "{cbk:?} code {code}");
                }
            }
        }
    }

    #[test]
    fn fake_quant_cbk_uniform_is_bit_identical() {
        let mut rng = Rng::new(0xCB0);
        for _ in 0..20 {
            let xs = rand_vec(&mut rng, 1 + rng.below_usize(120));
            let n = (1 + rng.below(16)) as f32;
            let mut a = xs.clone();
            fake_quant_slice(&mut a, n);
            let mut b = xs.clone();
            fake_quant_slice_cbk(&mut b, n, Codebook::Uniform);
            assert!(a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits()));
        }
    }

    #[test]
    fn fake_quant_cbk_lands_on_codebook_codes() {
        let mut rng = Rng::new(0xCB1);
        for cbk in [Codebook::PowerOfTwo, Codebook::AdditivePot2] {
            for bits in [2u32, 4, 8] {
                let xs = rand_vec(&mut rng, 200);
                let mut q = xs.clone();
                fake_quant_slice_cbk(&mut q, bits as f32, cbk);
                let plan = QuantPlan::from_slice_cbk(&xs, bits as f32, cbk);
                let proj = plan.projector();
                let levels = ((1u32 << bits) - 1) as i64;
                for (&x, &v) in xs.iter().zip(&q) {
                    let code = proj.project_code(plan.code(x, levels));
                    let want = plan.lmin + code as f32 * plan.s_lo;
                    assert_eq!(v.to_bits(), want.to_bits());
                    assert!(proj.admits(code));
                }
                // Restriction costs accuracy vs uniform, never gains.
                let mut u = xs.clone();
                fake_quant_slice(&mut u, bits as f32);
                let sse = |q: &[f32]| -> f64 {
                    xs.iter().zip(q).map(|(a, b)| ((a - b) as f64).powi(2)).sum()
                };
                assert!(sse(&u) <= sse(&q) + 1e-9, "{cbk:?} {bits}b");
            }
        }
    }

    #[test]
    fn grouped_cbk_plans_share_codebook() {
        let mut rng = Rng::new(0xCB2);
        let xs = rand_vec(&mut rng, 4 * 16);
        let plan =
            GroupQuantPlan::from_groups_cbk(&xs, 16, &[2.0, 4.0, 8.0, 3.0], Codebook::PowerOfTwo);
        assert_eq!(plan.codebook(), Codebook::PowerOfTwo);
        assert!(plan.plans.iter().all(|p| p.codebook == Codebook::PowerOfTwo));
        // Uniform constructor keeps today's behavior.
        let u = GroupQuantPlan::from_groups(&xs, 16, &[2.0, 4.0, 8.0, 3.0]);
        assert_eq!(u.codebook(), Codebook::Uniform);
        // Per-plan projection applies independently per group.
        let mut got = xs.clone();
        plan.apply(&mut got);
        for (g, row) in xs.chunks(16).enumerate() {
            let mut want = row.to_vec();
            fake_quant_slice_cbk(&mut want, [2.0, 4.0, 8.0, 3.0][g], Codebook::PowerOfTwo);
            let got_row = &got[g * 16..(g + 1) * 16];
            assert!(
                got_row.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "group {g}"
            );
        }
    }

    #[test]
    fn mac_cost_cbk_pins_uniform_and_orders_codebooks() {
        let meta = tiny_meta();
        let bw = vec![6.0f32, 4.0];
        let ba = vec![8.0f32, 8.0];
        // Uniform everywhere == the existing convention, exactly.
        let u2 = vec![Codebook::Uniform; 2];
        assert_eq!(mac_cost_cbk(&meta, &bw, &ba, &u2), mac_cost(&meta, &bw, &ba));
        // PoT < APoT < Uniform at equal bits (> 2).
        let pot = mac_cost_cbk(&meta, &bw, &ba, &[Codebook::PowerOfTwo; 2]);
        let apot = mac_cost_cbk(&meta, &bw, &ba, &[Codebook::AdditivePot2; 2]);
        let uni = mac_cost_cbk(&meta, &bw, &ba, &u2);
        assert!(pot < apot && apot < uni, "{pot} {apot} {uni}");
        // max_addends pins: the per-operand model itself.
        assert_eq!(max_addends(Codebook::Uniform, 6.0), 6.0);
        assert_eq!(max_addends(Codebook::PowerOfTwo, 6.0), 1.0);
        assert_eq!(max_addends(Codebook::AdditivePot2, 6.0), 2.0);
        // At 1 bit APoT can't use two addends.
        assert_eq!(max_addends(Codebook::AdditivePot2, 1.0), 1.0);
    }

    #[test]
    fn bit_sparsity_loss_reduces_to_bit_loss_weight_term() {
        let meta = tiny_meta();
        let (lw, la) = Criterion::MacOps.lambdas(&meta);
        let bits: Vec<Vec<f32>> = vec![vec![6.0, 4.0, 8.0], vec![3.0]];
        let u2 = vec![Codebook::Uniform; 2];
        // All-uniform: exactly grouped_bit_loss with a zeroed act term.
        let want = grouped_bit_loss(&lw, &bits, &la, &[0.0; 2])
            - la.iter().map(|&l| l as f64 * 1.0).sum::<f64>();
        let got = bit_sparsity_loss(&lw, &bits, &u2);
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        // PoT saturates the penalty below uniform.
        let pot = bit_sparsity_loss(&lw, &bits, &[Codebook::PowerOfTwo; 2]);
        assert!(pot < got);
        // And is flat in bits: more bits cost no more addends.
        let more: Vec<Vec<f32>> = vec![vec![16.0, 16.0, 16.0], vec![16.0]];
        let pot_more = bit_sparsity_loss(&lw, &more, &[Codebook::PowerOfTwo; 2]);
        assert!((pot - pot_more).abs() < 1e-12);
    }

    #[test]
    fn per_channel_bits_monotone_in_channel_range() {
        // Widening one channel's range (all else fixed) never lowers
        // its bitlength, and never changes by more than the log2 of
        // the widening factor suggests.
        let (din, dout) = (16usize, 3usize);
        let mut prev = 0.0f32;
        for &spread in &[0.125f32, 0.25, 0.5, 1.0] {
            let mut w = vec![0.0f32; din * dout];
            for i in 0..din {
                let t = i as f32 / (din - 1) as f32;
                w[i * dout] = -2.0 + 4.0 * t; // channel 0 pins layer range
                w[i * dout + 1] = (-2.0 + 4.0 * t) * spread; // scaled copy
                w[i * dout + 2] = 0.25; // constant (degenerate)
            }
            let bits = per_channel_bits(&w, din, dout, 6.0);
            assert!(bits[1] >= prev, "spread {spread}: {} < {prev}", bits[1]);
            prev = bits[1];
        }
        // Full-range channel matches the layer ceiling.
        assert_eq!(prev, 6.0);
    }

    #[test]
    fn per_channel_bits_stable_on_degenerate_channels() {
        // Zero-range channels (constant, including all-zero) must get a
        // finite, clipped bitlength — the RANGE_EPS guard — and be
        // deterministic across calls.
        let (din, dout) = (8usize, 4usize);
        let mut w = vec![0.0f32; din * dout];
        for i in 0..din {
            let t = i as f32 / (din - 1) as f32;
            w[i * dout] = -1.0 + 2.0 * t; // real channel
            w[i * dout + 1] = 0.0; // all-zero
            w[i * dout + 2] = 3.5; // constant nonzero
            w[i * dout + 3] = f32::MIN_POSITIVE * t; // near-degenerate
        }
        let bits = per_channel_bits(&w, din, dout, 8.0);
        assert_eq!(bits, per_channel_bits(&w, din, dout, 8.0));
        for (j, &b) in bits.iter().enumerate() {
            assert!(b.is_finite(), "channel {j}");
            assert!((N_MIN..=N_MAX).contains(&b), "channel {j}: {b}");
        }
        // Degenerate channels bottom out at N_MIN.
        assert_eq!(bits[1], N_MIN);
        assert_eq!(bits[2], N_MIN);
        // An entirely-degenerate layer (range eps / range eps = 1) keeps
        // the layer bitlength rather than exploding.
        let flat = vec![1.0f32; din * 2];
        let fb = per_channel_bits(&flat, din, 2, 5.0);
        assert_eq!(fb, vec![5.0, 5.0]);
    }
}
