//! The serving forward executor: persistent threads + reusable buffers.

use std::sync::Arc;

use crate::infer::{IntNet, NetScratch};
use crate::util::pool::WorkerPool;

/// Owns everything repeated forwards need so the hot loop spawns no
/// threads and reuses its activation/code buffers: a persistent
/// [`WorkerPool`] for the GEMM row blocks and a [`NetScratch`] of
/// ping-pong activation planes (pooled dispatch still boxes O(threads)
/// jobs per large layer).
/// One engine serves one thread of control (forwards take `&mut self`);
/// the batcher in [`super::Server`] owns exactly one.
pub struct ServeEngine {
    net: Arc<IntNet>,
    pool: WorkerPool,
    scratch: NetScratch,
}

impl ServeEngine {
    /// `threads == 0` sizes the pool to the machine.
    pub fn new(net: Arc<IntNet>, threads: usize) -> Self {
        let pool = if threads == 0 {
            WorkerPool::with_default_size()
        } else {
            WorkerPool::new(threads)
        };
        Self { net, pool, scratch: NetScratch::default() }
    }

    pub fn net(&self) -> &IntNet {
        &self.net
    }

    /// Forward a `[n, din]` batch; returns logits `[n, num_classes]`
    /// borrowed from the engine's scratch.  Bit-identical to
    /// `IntNet::forward` on the same net.
    pub fn forward(&mut self, x: &[f32], n: usize) -> &[f32] {
        let Self { net, pool, scratch } = self;
        net.forward_into(x, n, scratch, Some(&*pool))
    }

    /// Classify a batch (same argmax rule as [`IntNet::predict`]).
    pub fn predict(&mut self, x: &[f32], n: usize) -> Vec<usize> {
        let nc = self.net.num_classes;
        let logits = self.forward(x, n);
        crate::infer::argmax_rows(logits, nc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::synthetic_net;
    use crate::util::rng::Rng;

    #[test]
    fn engine_matches_percall_forward_bitwise() {
        let net = Arc::new(synthetic_net(&[12, 31, 5], 0xE6, 4, 6));
        let mut engine = ServeEngine::new(Arc::clone(&net), 2);
        let mut rng = Rng::new(9);
        for &n in &[1usize, 3, 17] {
            let x: Vec<f32> =
                (0..n * 12).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let want = net.forward(&x, n);
            let got = engine.forward(&x, n);
            assert_eq!(got.len(), want.len());
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "engine forward diverged at batch {n}"
            );
        }
        assert_eq!(engine.predict(&[0.1; 12], 1), net.predict(&[0.1; 12], 1));
    }

    #[test]
    fn engine_reuses_buffers_across_batch_sizes() {
        // Growing then shrinking batch sizes must keep shapes right.
        let net = Arc::new(synthetic_net(&[8, 16, 4], 1, 4, 4));
        let mut engine = ServeEngine::new(Arc::clone(&net), 1);
        for &n in &[1usize, 64, 7, 64, 1] {
            let x = vec![0.25f32; n * 8];
            assert_eq!(engine.forward(&x, n).len(), n * 4);
        }
    }
}
