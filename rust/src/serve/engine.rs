//! The serving forward executor: persistent threads + reusable buffers.

use crate::infer::{ForwardProfile, IntNet, NetScratch};
use crate::util::pool::WorkerPool;

/// Owns everything repeated forwards need so the hot loop spawns no
/// threads and reuses its activation/code buffers: a persistent
/// [`WorkerPool`] for the GEMM row blocks and a [`NetScratch`] of
/// ping-pong activation planes (pooled dispatch still boxes O(threads)
/// jobs per large layer).
///
/// The engine is **model-agnostic**: the net to execute is passed per
/// call, which is what lets the batcher in [`super::Server`] resolve a
/// different registry version for each batch while keeping one warm
/// set of buffers across swaps.  One engine serves one thread of
/// control (forwards take `&mut self`); the batcher owns exactly one.
pub struct ServeEngine {
    pool: WorkerPool,
    scratch: NetScratch,
}

impl ServeEngine {
    /// `threads == 0` sizes the pool to the machine.
    pub fn new(threads: usize) -> Self {
        let pool = if threads == 0 {
            WorkerPool::with_default_size()
        } else {
            WorkerPool::new(threads)
        };
        Self { pool, scratch: NetScratch::default() }
    }

    /// [`Self::new`] with a fault injector threaded into the worker
    /// pool (chaos builds only): workers then deterministically die
    /// and panic on schedule, which is how `tests/serve_chaos.rs`
    /// proves respawned pools produce bit-identical forwards.
    #[cfg(feature = "chaos")]
    pub fn with_chaos(
        threads: usize,
        chaos: Option<std::sync::Arc<crate::serve::chaos::Chaos>>,
    ) -> Self {
        let workers = if threads == 0 {
            crate::util::pool::default_workers()
        } else {
            threads
        };
        Self {
            pool: WorkerPool::with_chaos(workers, chaos),
            scratch: NetScratch::default(),
        }
    }

    /// The engine's worker pool (for respawn counters in tests).
    pub fn pool(&self) -> &WorkerPool {
        &self.pool
    }

    /// Forward a `[n, din]` batch through `net`; returns logits
    /// `[n, net.num_classes]` borrowed from the engine's scratch.
    /// Bit-identical to `IntNet::forward` on the same net.
    pub fn forward(&mut self, net: &IntNet, x: &[f32], n: usize) -> &[f32] {
        let Self { pool, scratch } = self;
        net.forward_into(x, n, scratch, Some(&*pool))
    }

    /// [`Self::forward`] with per-layer wall-time/MAC/byte attribution
    /// recorded into `prof` (see [`ForwardProfile`]).  Same buffers,
    /// same pool, bit-identical logits — profiling only adds clock
    /// reads, so it is safe to sample on live traffic.
    pub fn forward_profiled(
        &mut self,
        net: &IntNet,
        x: &[f32],
        n: usize,
        prof: &mut ForwardProfile,
    ) -> &[f32] {
        let Self { pool, scratch } = self;
        net.forward_into_profiled(x, n, scratch, Some(&*pool), prof)
    }

    /// Classify a batch (same argmax rule as [`IntNet::predict`]).
    pub fn predict(&mut self, net: &IntNet, x: &[f32], n: usize) -> Vec<usize> {
        let nc = net.num_classes;
        let logits = self.forward(net, x, n);
        crate::infer::argmax_rows(logits, nc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::synthetic_net;
    use crate::util::rng::Rng;

    #[test]
    fn engine_matches_percall_forward_bitwise() {
        let net = synthetic_net(&[12, 31, 5], 0xE6, 4, 6);
        let mut engine = ServeEngine::new(2);
        let mut rng = Rng::new(9);
        for &n in &[1usize, 3, 17] {
            let x: Vec<f32> =
                (0..n * 12).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let want = net.forward(&x, n);
            let got = engine.forward(&net, &x, n);
            assert_eq!(got.len(), want.len());
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "engine forward diverged at batch {n}"
            );
        }
        assert_eq!(
            engine.predict(&net, &[0.1; 12], 1),
            net.predict(&[0.1; 12], 1)
        );
    }

    #[test]
    fn engine_reuses_buffers_across_batch_sizes_and_nets() {
        // Growing then shrinking batch sizes must keep shapes right,
        // and the same warm buffers must serve a *different* net (the
        // hot-swap path) without contaminating results.
        let a = synthetic_net(&[8, 16, 4], 1, 4, 4);
        let b = synthetic_net(&[8, 16, 4], 2, 4, 4);
        let mut engine = ServeEngine::new(1);
        for &n in &[1usize, 64, 7, 64, 1] {
            let x = vec![0.25f32; n * 8];
            assert_eq!(engine.forward(&a, &x, n).len(), n * 4);
        }
        let x = vec![0.5f32; 3 * 8];
        let from_engine = engine.forward(&b, &x, 3).to_vec();
        let solo = b.forward(&x, 3);
        assert!(
            from_engine.iter().zip(&solo).all(|(p, q)| p.to_bits() == q.to_bits()),
            "swapped-in net must forward exactly as it does standalone"
        );
    }
}
