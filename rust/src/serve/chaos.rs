//! Deterministic fault injection for the serving fleet (feature
//! `chaos`).
//!
//! Production code never probabilistically misbehaves on its own — in
//! chaos builds (`cargo test --features chaos`) a [`Chaos`] instance
//! can be threaded into a `WorkerPool` (worker exits, in-job panics)
//! and a `serve::Server` (queue stalls, forward panics, latency
//! spikes), and `tests/serve_chaos.rs` proves the fleet's failure
//! invariants hold under all of them:
//!
//! * no request is silently lost — every submit resolves to a response
//!   or a typed [`crate::serve::ServeError`];
//! * dead workers are respawned and subsequent batches are
//!   bit-identical;
//! * a corrupted or slow canary is auto-rolled-back before it ever
//!   reaches 100% of traffic.
//!
//! **Determinism.**  Injectors fire on *every-Nth-event* atomic
//! counters, not coin flips: under a pinned seed and fixed trigger
//! periods the injected-fault schedule is a pure function of the event
//! sequence, so the suite asserts exact invariants instead of
//! probabilistic ones.  The seed ([`pinned_seed`], `CHAOS_SEED` env)
//! feeds fixture construction ([`corrupted_twin`]), keeping the whole
//! suite reproducible from one number.
//!
//! The `injected_*` counters here are the *test-facing* ledger of what
//! chaos did; the *serve-visible* consequences (sheds, failed batches,
//! pool respawns, rollbacks) land in the [`crate::telemetry`] registry
//! the server publishes, and `tests/serve_chaos.rs` cross-checks the
//! two ledgers against [`crate::serve::ServeStats`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::infer::IntNet;

/// Trigger periods for each injector; `0` disables that injector.
/// "Every Nth" counts that injector's own checkpoints (worker polls,
/// batches, forwards), so the schedule is deterministic per run.
#[derive(Debug, Clone)]
pub struct ChaosConfig {
    /// Kill the polling worker thread at every Nth poll (between jobs
    /// — a claimed job is never lost).  Exercises pool respawn.
    pub worker_exit_every: u64,
    /// Panic inside every Nth pool job (inside the worker's
    /// catch_unwind — the path a real kernel panic would take).
    pub job_panic_every: u64,
    /// Panic inside every Nth batch forward on the batcher thread.
    pub forward_panic_every: u64,
    /// Stall the batcher for [`Self::stall`] before every Nth dequeue
    /// (simulates a wedged batcher; expired deadlines shed).
    pub stall_every: u64,
    pub stall: Duration,
    /// Sleep [`Self::spike`] inside every Nth forward's timed region
    /// (simulates a latency regression).
    pub spike_every: u64,
    pub spike: Duration,
    /// Restrict spikes to canary sub-batches — the fixture for
    /// latency-triggered canary rollback.
    pub spike_canary_only: bool,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        Self {
            worker_exit_every: 0,
            job_panic_every: 0,
            forward_panic_every: 0,
            stall_every: 0,
            stall: Duration::from_millis(2),
            spike_every: 0,
            spike: Duration::from_millis(2),
            spike_canary_only: false,
        }
    }
}

/// A live injector: shared (via `Arc`) between the component under
/// test and the test making assertions about what was injected.
/// Per-instance state — parallel tests never interfere.
#[derive(Debug, Default)]
pub struct Chaos {
    cfg: ChaosConfig,
    worker_polls: AtomicU64,
    jobs: AtomicU64,
    forwards: AtomicU64,
    batches: AtomicU64,
    spikes: AtomicU64,
    injected_exits: AtomicU64,
    injected_job_panics: AtomicU64,
    injected_forward_panics: AtomicU64,
    injected_stalls: AtomicU64,
    injected_spikes: AtomicU64,
}

/// `counter`'s next tick fires when it lands on a multiple of `every`.
fn fire(counter: &AtomicU64, every: u64) -> bool {
    every != 0 && (counter.fetch_add(1, Ordering::Relaxed) + 1) % every == 0
}

impl Chaos {
    pub fn new(cfg: ChaosConfig) -> Arc<Self> {
        Arc::new(Self { cfg, ..Self::default() })
    }

    /// Pool hook: should the polling worker thread die now?
    pub fn worker_should_exit(&self) -> bool {
        let hit = fire(&self.worker_polls, self.cfg.worker_exit_every);
        if hit {
            self.injected_exits.fetch_add(1, Ordering::Relaxed);
        }
        hit
    }

    /// Pool hook: panics inside the worker's job boundary on the Nth
    /// job.
    pub fn maybe_job_panic(&self) {
        if fire(&self.jobs, self.cfg.job_panic_every) {
            self.injected_job_panics.fetch_add(1, Ordering::Relaxed);
            panic!("chaos: injected job panic");
        }
    }

    /// Batcher hook: panics inside the batch-forward boundary on the
    /// Nth forward.
    pub fn maybe_forward_panic(&self) {
        if fire(&self.forwards, self.cfg.forward_panic_every) {
            self.injected_forward_panics.fetch_add(1, Ordering::Relaxed);
            panic!("chaos: injected forward panic");
        }
    }

    /// Batcher hook: how long to stall before the Nth dequeue.
    pub fn batch_stall(&self) -> Option<Duration> {
        if fire(&self.batches, self.cfg.stall_every) {
            self.injected_stalls.fetch_add(1, Ordering::Relaxed);
            Some(self.cfg.stall)
        } else {
            None
        }
    }

    /// Batcher hook: latency spike to inject into this forward's timed
    /// region.  Spikes key on their own counter; with
    /// `spike_canary_only` non-canary forwards neither spike nor
    /// advance the counter (so "every Nth" means every Nth *canary*
    /// forward).
    pub fn forward_spike(&self, is_canary: bool) -> Option<Duration> {
        if self.cfg.spike_canary_only && !is_canary {
            return None;
        }
        if fire(&self.spikes, self.cfg.spike_every) {
            self.injected_spikes.fetch_add(1, Ordering::Relaxed);
            Some(self.cfg.spike)
        } else {
            None
        }
    }

    pub fn injected_exits(&self) -> u64 {
        self.injected_exits.load(Ordering::Relaxed)
    }
    pub fn injected_job_panics(&self) -> u64 {
        self.injected_job_panics.load(Ordering::Relaxed)
    }
    pub fn injected_forward_panics(&self) -> u64 {
        self.injected_forward_panics.load(Ordering::Relaxed)
    }
    pub fn injected_stalls(&self) -> u64 {
        self.injected_stalls.load(Ordering::Relaxed)
    }
    pub fn injected_spikes(&self) -> u64 {
        self.injected_spikes.load(Ordering::Relaxed)
    }
}

/// The suite's pinned seed: `CHAOS_SEED` env when set (CI pins it),
/// a fixed default otherwise.  Everything derived from it is
/// reproducible from the one number.
pub fn pinned_seed() -> u64 {
    std::env::var("CHAOS_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x20260807)
}

/// A same-endpoint-shape, differently-seeded twin of `net`: passes
/// every registry shape check (the registry keys on flattened
/// in/out features, so a dense twin stands in for a conv net too),
/// serves finite logits — and disagrees with the original on most
/// argmaxes.  The fixture for "corrupted-logit canary must be
/// auto-rolled-back".
pub fn corrupted_twin(net: &IntNet, seed: u64) -> IntNet {
    let mut dims = Vec::with_capacity(net.layers.len() + 1);
    dims.push(net.in_features());
    dims.extend(net.layers.iter().map(|l| l.out_features()));
    super::synthetic_net(&dims, seed, 4, 6)
}
