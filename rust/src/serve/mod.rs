//! The integer serving subsystem: batch-invariant deployment of the
//! learned bitlengths at production request rates.
//!
//! Built on the calibrated quantization semantics in [`crate::infer`]
//! (static per-layer activation ranges ⇒ per-sample logits do not
//! depend on batch composition), this module adds the three pieces a
//! serving loop needs that one-off batch eval does not:
//!
//! * [`ServeEngine`] — a forward executor that owns a persistent
//!   [`crate::util::pool::WorkerPool`] (no per-call thread spawn/join)
//!   and a [`crate::infer::NetScratch`] of ping-pong activation
//!   buffers (no per-layer activation/code-buffer allocation after
//!   warm-up; pooled dispatch still costs O(threads) small job
//!   allocations per large layer).
//! * [`Server`] / [`ServerHandle`] — a dynamic micro-batching request
//!   queue: single-sample requests coalesce until `max_batch` are
//!   waiting or the oldest has waited `batch_window`, whichever comes
//!   first; the flushed batch runs once through the engine and each
//!   caller gets its own logits row back.  Batch-invariance is what
//!   makes this sound: a request's answer is bit-identical whether it
//!   was served alone or coalesced with 63 strangers.
//! * **Registry-resolved models** — the batcher does not own a fixed
//!   `Arc<IntNet>`; it resolves the current version from a
//!   [`crate::deploy::ModelRegistry`] once per batch.  Publishing (or
//!   rolling back) a version on a live server hot-swaps the model
//!   between batches with zero downtime: in-flight batches drain on
//!   the version they resolved, every [`Response`] carries the version
//!   that computed it, and [`ServeStats::swaps`] counts the
//!   transitions.  Frozen `.bpma` artifacts (`crate::deploy::artifact`)
//!   are the shipping form models enter the registry in.
//! * **Failure hardening** — typed [`ServeError`] outcomes for every
//!   request, deadline-aware load shedding ([`ShedPolicy`]), bounded
//!   jittered retry ([`RetryPolicy`]), panic isolation around batch
//!   forwards, and canary traffic splits with auto-rollback
//!   ([`Server::start_canary`], [`CanaryController`]).  A
//!   deterministic fault-injection layer (`serve::chaos`, feature
//!   `chaos`)
//!   proves the invariants in `tests/serve_chaos.rs`.
//! * **Observability** — every server publishes its counters, gauges
//!   and latency/batch-size histograms into a [`crate::telemetry`]
//!   registry ([`Server::telemetry`]); the registry handles *are* the
//!   [`ServeStats`] ledger (one set of atomics behind both views), so
//!   a scrape can never disagree with the stats.  [`Server::start_observed`]
//!   additionally accepts a [`crate::telemetry::TraceWriter`] for a
//!   JSONL lifecycle trace (admit/shed/batch/swap/promote/rollback);
//!   `bitprune serve --metrics-addr` exposes the registry over HTTP.
//! * Synthetic fixtures ([`synthetic_net`] / [`synthetic_mlp`]) — a
//!   calibrated random network on the mlp artifact shapes
//!   (32→256→128→10, python/compile/models.py), so `bitprune serve`,
//!   `benches/serve.rs` and the tests run without AOT artifacts.
//!
//! Entry points: `bitprune serve` (CLI, throughput + latency
//! percentiles, `--model a.bpma --swap-to b.bpma` live-swap demo),
//! `benches/serve.rs` and `benches/deploy.rs` (`BENCH_serve.json` /
//! `BENCH_deploy.json`).

mod canary;
#[cfg(feature = "chaos")]
pub mod chaos;
mod engine;
mod server;

pub use canary::{CanaryConfig, CanaryController, CanaryOutcome, CanaryStatus};
pub use engine::ServeEngine;
pub use server::{
    Response, RetryPolicy, ServeConfig, ServeError, ServeResult, ServeStats, Server,
    ServerHandle, ShedPolicy,
};

use crate::infer::{ConvGeom, IntConv2d, IntDense, IntNet};
use crate::quant::Codebook;
use crate::util::rng::Rng;

/// Build a random dense network over `dims` (e.g. `[32, 256, 128, 10]`:
/// three layers, ReLU between, logits out), quantized at
/// `w_bits`/`a_bits`, **calibrated** on a synthetic batch so forwards
/// are batch-invariant.  Fixture for the serve bench/CLI/tests when no
/// trained artifact is available.
pub fn synthetic_net(dims: &[usize], seed: u64, w_bits: u32, a_bits: u32) -> IntNet {
    assert!(dims.len() >= 2, "synthetic_net needs at least one layer");
    let mut rng = Rng::new(seed);
    let mut layers = Vec::with_capacity(dims.len() - 1);
    for (i, pair) in dims.windows(2).enumerate() {
        let (din, dout) = (pair[0], pair[1]);
        let std = (1.0 / din as f32).sqrt();
        let w: Vec<f32> = (0..din * dout).map(|_| rng.normal_f32(0.0, std)).collect();
        let b: Vec<f32> = (0..dout).map(|_| rng.normal_f32(0.0, 0.01)).collect();
        let relu = i + 2 < dims.len();
        layers.push(
            IntDense::new(&format!("fc{i}"), &w, din, dout, &b, w_bits, a_bits, relu)
                .expect("synthetic layer shapes are consistent")
                .into(),
        );
    }
    let num_classes = *dims.last().unwrap();
    let mut net = IntNet { layers, num_classes };
    let calib_n = 256;
    let calib: Vec<f32> =
        (0..calib_n * dims[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    net.calibrate(&calib, calib_n).expect("calibration batch is well-formed");
    net
}

/// [`synthetic_net`] on the mlp artifact shapes (32→256→128→10).
pub fn synthetic_mlp(seed: u64, w_bits: u32, a_bits: u32) -> IntNet {
    synthetic_net(&[32, 256, 128, 10], seed, w_bits, a_bits)
}

/// [`synthetic_net`] under a weight [`Codebook`]: every layer projects
/// its codes onto `codebook` (shift-add GEMM engaged when non-uniform).
/// Granularities are deliberately mixed — even layers pack per-layer,
/// odd layers per-output-channel with a small bitlength cycle — so one
/// fixture exercises both shift-plan shapes through the serve and
/// deploy suites.  `Codebook::Uniform` reproduces [`synthetic_net`]'s
/// multiply path bit-for-bit on the even layers.
pub fn synthetic_net_cbk(
    dims: &[usize],
    seed: u64,
    w_bits: u32,
    a_bits: u32,
    codebook: Codebook,
) -> IntNet {
    assert!(dims.len() >= 2, "synthetic_net_cbk needs at least one layer");
    let mut rng = Rng::new(seed);
    let mut layers = Vec::with_capacity(dims.len() - 1);
    for (i, pair) in dims.windows(2).enumerate() {
        let (din, dout) = (pair[0], pair[1]);
        let std = (1.0 / din as f32).sqrt();
        let w: Vec<f32> = (0..din * dout).map(|_| rng.normal_f32(0.0, std)).collect();
        let b: Vec<f32> = (0..dout).map(|_| rng.normal_f32(0.0, 0.01)).collect();
        let relu = i + 2 < dims.len();
        let name = format!("fc{i}");
        let layer = if i % 2 == 0 {
            IntDense::new_cbk(&name, &w, din, dout, &b, w_bits, a_bits, relu, codebook)
        } else {
            let cycle = [w_bits.max(2), (w_bits + 2).min(8)];
            let bits: Vec<f32> =
                (0..dout).map(|j| cycle[j % cycle.len()] as f32).collect();
            IntDense::new_grouped_cbk(
                &name, &w, din, dout, &b, &bits, a_bits, relu, codebook,
            )
        }
        .expect("synthetic codebook layer shapes are consistent");
        layers.push(layer.into());
    }
    let num_classes = *dims.last().unwrap();
    let mut net = IntNet { layers, num_classes };
    let calib_n = 256;
    let calib: Vec<f32> =
        (0..calib_n * dims[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    net.calibrate(&calib, calib_n).expect("calibration batch is well-formed");
    net
}

/// [`synthetic_net`] at **per-output-channel** weight granularity:
/// channel bitlengths cycle through `w_bits_cycle` (e.g. `[2, 4, 8]`),
/// so every layer carries genuinely mixed row-varying codes — the
/// fixture the grouped serve/deploy tests, benches and
/// `bitprune export --synthetic --granularity channel` use.  Calibrated
/// like [`synthetic_net`].
pub fn synthetic_net_grouped(
    dims: &[usize],
    seed: u64,
    w_bits_cycle: &[u32],
    a_bits: u32,
) -> IntNet {
    assert!(dims.len() >= 2, "synthetic_net_grouped needs at least one layer");
    assert!(!w_bits_cycle.is_empty(), "empty bitlength cycle");
    let mut rng = Rng::new(seed);
    let mut layers = Vec::with_capacity(dims.len() - 1);
    for (i, pair) in dims.windows(2).enumerate() {
        let (din, dout) = (pair[0], pair[1]);
        let std = (1.0 / din as f32).sqrt();
        let w: Vec<f32> = (0..din * dout).map(|_| rng.normal_f32(0.0, std)).collect();
        let b: Vec<f32> = (0..dout).map(|_| rng.normal_f32(0.0, 0.01)).collect();
        let bits: Vec<f32> = (0..dout)
            .map(|j| w_bits_cycle[j % w_bits_cycle.len()] as f32)
            .collect();
        let relu = i + 2 < dims.len();
        layers.push(
            crate::infer::IntDense::new_grouped(
                &format!("fc{i}"),
                &w,
                din,
                dout,
                &b,
                &bits,
                a_bits,
                relu,
            )
            .expect("synthetic grouped layer shapes are consistent")
            .into(),
        );
    }
    let num_classes = *dims.last().unwrap();
    let mut net = IntNet { layers, num_classes };
    let calib_n = 256;
    let calib: Vec<f32> =
        (0..calib_n * dims[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    net.calibrate(&calib, calib_n).expect("calibration batch is well-formed");
    net
}

/// The synthetic conv fixture topology: a 3×8×8 HWC input through two
/// 3×3 convolutions (stride 1 then stride 2, both padded) into a dense
/// classifier head — 192 → 256 → 256 → 10 flattened features.
fn conv_fixture_geoms() -> (ConvGeom, ConvGeom) {
    (
        ConvGeom { cin: 3, h: 8, w: 8, cout: 4, kh: 3, kw: 3, stride: 1, pad: 1 },
        ConvGeom { cin: 4, h: 8, w: 8, cout: 16, kh: 3, kw: 3, stride: 2, pad: 1 },
    )
}

/// Shared builder for the conv fixtures: `kernel_bits(cout)` returns
/// `None` for a per-layer build at `w_bits`, or the per-output-kernel
/// bitlength vector for a grouped build.
fn synthetic_conv_with(
    seed: u64,
    w_bits: u32,
    a_bits: u32,
    codebook: Codebook,
    kernel_bits: impl Fn(usize) -> Option<Vec<f32>>,
) -> IntNet {
    let (g0, g1) = conv_fixture_geoms();
    let mut rng = Rng::new(seed);
    let mut rand = |n: usize, std: f32| -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, std)).collect()
    };
    let mut layers: Vec<crate::infer::IntLayer> = Vec::with_capacity(3);
    for (name, g) in [("conv0", g0), ("conv1", g1)] {
        let w = rand(g.patch_len() * g.cout, (1.0 / g.patch_len() as f32).sqrt());
        let b = rand(g.cout, 0.01);
        let conv = match kernel_bits(g.cout) {
            None => {
                IntConv2d::new_cbk(name, &w, g, &b, w_bits, a_bits, true, codebook)
            }
            Some(bits) => IntConv2d::new_grouped_cbk(
                name, &w, g, &b, &bits, a_bits, true, codebook,
            ),
        }
        .expect("synthetic conv shapes are consistent");
        layers.push(conv.into());
    }
    let dflat = g1.out_features();
    let w = rand(dflat * 10, (1.0 / dflat as f32).sqrt());
    let b = rand(10, 0.01);
    let head = match kernel_bits(10) {
        None => IntDense::new_cbk(
            "fc", &w, dflat, 10, &b, w_bits, a_bits, false, codebook,
        ),
        Some(bits) => IntDense::new_grouped_cbk(
            "fc", &w, dflat, 10, &b, &bits, a_bits, false, codebook,
        ),
    }
    .expect("synthetic head shapes are consistent");
    layers.push(head.into());
    let mut net = IntNet { layers, num_classes: 10 };
    let calib_n = 256;
    let calib: Vec<f32> =
        (0..calib_n * net.in_features()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    net.calibrate(&calib, calib_n).expect("calibration batch is well-formed");
    net
}

/// A random **convolutional** network (conv 3×3/s1 → conv 3×3/s2 →
/// dense head over a 3×8×8 HWC input), quantized at `w_bits`/`a_bits`
/// and calibrated like [`synthetic_net`] — the conv-artifact fixture
/// for `bitprune export --synthetic --arch conv`, the serve suites and
/// the benches.
pub fn synthetic_conv_net(seed: u64, w_bits: u32, a_bits: u32) -> IntNet {
    synthetic_conv_with(seed, w_bits, a_bits, Codebook::Uniform, |_| None)
}

/// [`synthetic_conv_net`] under a weight [`Codebook`]: both convs and
/// the dense head project onto `codebook` at per-layer granularity —
/// the conv shift-add fixture for the deploy/serve suites and
/// `bitprune export --synthetic --arch conv --codebook pot`.
pub fn synthetic_conv_net_cbk(
    seed: u64,
    w_bits: u32,
    a_bits: u32,
    codebook: Codebook,
) -> IntNet {
    synthetic_conv_with(seed, w_bits, a_bits, codebook, |_| None)
}

/// [`synthetic_conv_net`] at **per-output-kernel** weight granularity:
/// each conv kernel (and each head channel) packs at its own bitlength,
/// cycling through `w_bits_cycle`.
pub fn synthetic_conv_net_grouped(
    seed: u64,
    w_bits_cycle: &[u32],
    a_bits: u32,
) -> IntNet {
    assert!(!w_bits_cycle.is_empty(), "empty bitlength cycle");
    synthetic_conv_with(seed, w_bits_cycle[0], a_bits, Codebook::Uniform, |dout| {
        Some(
            (0..dout)
                .map(|j| w_bits_cycle[j % w_bits_cycle.len()] as f32)
                .collect(),
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_grouped_net_is_calibrated_and_mixed() {
        let net = synthetic_net_grouped(&[12, 20, 6], 3, &[2, 4, 8], 6);
        assert_eq!(net.layers.len(), 2);
        assert!(net.is_calibrated());
        for l in &net.layers {
            assert_eq!(
                l.granularity(),
                crate::quant::Granularity::PerOutputChannel
            );
        }
        // The cycle produces genuinely mixed channel bitlengths.
        let h = net.w_bits_histogram();
        assert!(h[2] > 0 && h[4] > 0 && h[8] > 0);
        // Calibrated ⇒ batch-invariant, grouped codes included.
        let solo = net.forward(&[0.3; 12], 1);
        let mut batch = vec![0.3f32; 12];
        batch.extend(vec![5.0f32; 12]);
        let pair = net.forward(&batch, 2);
        assert!(solo
            .iter()
            .zip(&pair[..6])
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn synthetic_mlp_is_calibrated_and_shaped() {
        let net = synthetic_mlp(7, 4, 8);
        assert_eq!(net.layers.len(), 3);
        assert_eq!(net.in_features(), 32);
        assert_eq!(net.out_features(), 10);
        assert_eq!(net.num_classes, 10);
        assert!(net.is_calibrated());
        assert!(net.layers[0].relu() && !net.layers[2].relu());
    }

    #[test]
    fn synthetic_conv_net_is_calibrated_and_shaped() {
        let net = synthetic_conv_net(9, 4, 6);
        assert_eq!(net.layers.len(), 3);
        assert_eq!(net.in_features(), 3 * 8 * 8);
        assert_eq!(net.out_features(), 10);
        assert!(net.is_calibrated());
        assert!(net.layers[0].conv_geom().is_some());
        assert!(net.layers[1].conv_geom().is_some());
        assert!(net.layers[2].conv_geom().is_none());
        // Padded convs cover 0 in their calibrated range.
        let (lo, hi) = net.layers[0].act_range().unwrap();
        assert!(lo <= 0.0 && hi >= 0.0);
        // Calibrated ⇒ batch-invariant through the conv stack.
        let solo = net.forward(&[0.25; 192], 1);
        let mut batch = vec![0.25f32; 192];
        batch.extend(vec![6.0f32; 192]);
        let pair = net.forward(&batch, 2);
        assert!(solo
            .iter()
            .zip(&pair[..10])
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn synthetic_cbk_fixtures_carry_codebooks_and_stay_invariant() {
        for cbk in [Codebook::PowerOfTwo, Codebook::AdditivePot2] {
            let net = synthetic_net_cbk(&[12, 20, 14, 6], 11, 4, 6, cbk);
            assert!(net.is_calibrated());
            assert_eq!(net.layers.len(), 3);
            // Mixed granularities, all on the requested codebook.
            assert_eq!(
                net.layers[0].granularity(),
                crate::quant::Granularity::PerLayer
            );
            assert_eq!(
                net.layers[1].granularity(),
                crate::quant::Granularity::PerOutputChannel
            );
            for l in &net.layers {
                assert_eq!(l.codebook(), cbk);
            }
            // Batch-invariance survives the shift-add path.
            let solo = net.forward(&[0.3; 12], 1);
            let mut batch = vec![0.3f32; 12];
            batch.extend(vec![5.0f32; 12]);
            let pair = net.forward(&batch, 2);
            assert!(solo
                .iter()
                .zip(&pair[..6])
                .all(|(a, b)| a.to_bits() == b.to_bits()));

            let conv = synthetic_conv_net_cbk(13, 4, 6, cbk);
            assert!(conv.is_calibrated());
            for l in &conv.layers {
                assert_eq!(l.codebook(), cbk);
            }
        }
        // Uniform codebook reproduces the plain fixture bit-for-bit on
        // per-layer layers (odd layers switch granularity, so compare
        // the conv fixture, which is per-layer throughout).
        let plain = synthetic_conv_net(13, 4, 6);
        let uni = synthetic_conv_net_cbk(13, 4, 6, Codebook::Uniform);
        let x = vec![0.2f32; plain.in_features()];
        let (a, b) = (plain.forward(&x, 1), uni.forward(&x, 1));
        assert!(a.iter().zip(&b).all(|(p, q)| p.to_bits() == q.to_bits()));
    }

    #[test]
    fn synthetic_conv_grouped_is_per_kernel_and_mixed() {
        let net = synthetic_conv_net_grouped(9, &[2, 4, 8], 6);
        assert!(net.is_calibrated());
        for l in &net.layers {
            assert_eq!(
                l.granularity(),
                crate::quant::Granularity::PerOutputChannel
            );
        }
        let h = net.w_bits_histogram();
        assert!(h[2] > 0 && h[4] > 0 && h[8] > 0);
        // Each conv group spans one kernel's kh·kw·cin taps.
        let g = net.layers[0].conv_geom().unwrap();
        match net.layers[0].weights() {
            crate::bitpack::WeightCodes::PerChannel(p) => {
                assert_eq!(p.group_size, g.patch_len());
                assert_eq!(p.n_groups(), g.cout);
            }
            _ => panic!("grouped conv fixture must carry per-kernel codes"),
        }
    }
}
