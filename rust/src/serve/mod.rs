//! The integer serving subsystem: batch-invariant deployment of the
//! learned bitlengths at production request rates.
//!
//! Built on the calibrated quantization semantics in [`crate::infer`]
//! (static per-layer activation ranges ⇒ per-sample logits do not
//! depend on batch composition), this module adds the three pieces a
//! serving loop needs that one-off batch eval does not:
//!
//! * [`ServeEngine`] — a forward executor that owns a persistent
//!   [`crate::util::pool::WorkerPool`] (no per-call thread spawn/join)
//!   and a [`crate::infer::NetScratch`] of ping-pong activation
//!   buffers (no per-layer activation/code-buffer allocation after
//!   warm-up; pooled dispatch still costs O(threads) small job
//!   allocations per large layer).
//! * [`Server`] / [`ServerHandle`] — a dynamic micro-batching request
//!   queue: single-sample requests coalesce until `max_batch` are
//!   waiting or the oldest has waited `batch_window`, whichever comes
//!   first; the flushed batch runs once through the engine and each
//!   caller gets its own logits row back.  Batch-invariance is what
//!   makes this sound: a request's answer is bit-identical whether it
//!   was served alone or coalesced with 63 strangers.
//! * **Registry-resolved models** — the batcher does not own a fixed
//!   `Arc<IntNet>`; it resolves the current version from a
//!   [`crate::deploy::ModelRegistry`] once per batch.  Publishing (or
//!   rolling back) a version on a live server hot-swaps the model
//!   between batches with zero downtime: in-flight batches drain on
//!   the version they resolved, every [`Response`] carries the version
//!   that computed it, and [`ServeStats::swaps`] counts the
//!   transitions.  Frozen `.bpma` artifacts (`crate::deploy::artifact`)
//!   are the shipping form models enter the registry in.
//! * **Failure hardening** — typed [`ServeError`] outcomes for every
//!   request, deadline-aware load shedding ([`ShedPolicy`]), bounded
//!   jittered retry ([`RetryPolicy`]), panic isolation around batch
//!   forwards, and canary traffic splits with auto-rollback
//!   ([`Server::start_canary`], [`CanaryController`]).  A
//!   deterministic fault-injection layer (`serve::chaos`, feature
//!   `chaos`)
//!   proves the invariants in `tests/serve_chaos.rs`.
//! * Synthetic fixtures ([`synthetic_net`] / [`synthetic_mlp`]) — a
//!   calibrated random network on the mlp artifact shapes
//!   (32→256→128→10, python/compile/models.py), so `bitprune serve`,
//!   `benches/serve.rs` and the tests run without AOT artifacts.
//!
//! Entry points: `bitprune serve` (CLI, throughput + latency
//! percentiles, `--model a.bpma --swap-to b.bpma` live-swap demo),
//! `benches/serve.rs` and `benches/deploy.rs` (`BENCH_serve.json` /
//! `BENCH_deploy.json`).

mod canary;
#[cfg(feature = "chaos")]
pub mod chaos;
mod engine;
mod server;

pub use canary::{CanaryConfig, CanaryController, CanaryOutcome, CanaryStatus};
pub use engine::ServeEngine;
pub use server::{
    Response, RetryPolicy, ServeConfig, ServeError, ServeResult, ServeStats, Server,
    ServerHandle, ShedPolicy,
};

use crate::infer::{IntDense, IntNet};
use crate::util::rng::Rng;

/// Build a random dense network over `dims` (e.g. `[32, 256, 128, 10]`:
/// three layers, ReLU between, logits out), quantized at
/// `w_bits`/`a_bits`, **calibrated** on a synthetic batch so forwards
/// are batch-invariant.  Fixture for the serve bench/CLI/tests when no
/// trained artifact is available.
pub fn synthetic_net(dims: &[usize], seed: u64, w_bits: u32, a_bits: u32) -> IntNet {
    assert!(dims.len() >= 2, "synthetic_net needs at least one layer");
    let mut rng = Rng::new(seed);
    let mut layers = Vec::with_capacity(dims.len() - 1);
    for (i, pair) in dims.windows(2).enumerate() {
        let (din, dout) = (pair[0], pair[1]);
        let std = (1.0 / din as f32).sqrt();
        let w: Vec<f32> = (0..din * dout).map(|_| rng.normal_f32(0.0, std)).collect();
        let b: Vec<f32> = (0..dout).map(|_| rng.normal_f32(0.0, 0.01)).collect();
        let relu = i + 2 < dims.len();
        layers.push(
            IntDense::new(&format!("fc{i}"), &w, din, dout, &b, w_bits, a_bits, relu)
                .expect("synthetic layer shapes are consistent"),
        );
    }
    let num_classes = *dims.last().unwrap();
    let mut net = IntNet { layers, num_classes };
    let calib_n = 256;
    let calib: Vec<f32> =
        (0..calib_n * dims[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    net.calibrate(&calib, calib_n).expect("calibration batch is well-formed");
    net
}

/// [`synthetic_net`] on the mlp artifact shapes (32→256→128→10).
pub fn synthetic_mlp(seed: u64, w_bits: u32, a_bits: u32) -> IntNet {
    synthetic_net(&[32, 256, 128, 10], seed, w_bits, a_bits)
}

/// [`synthetic_net`] at **per-output-channel** weight granularity:
/// channel bitlengths cycle through `w_bits_cycle` (e.g. `[2, 4, 8]`),
/// so every layer carries genuinely mixed row-varying codes — the
/// fixture the grouped serve/deploy tests, benches and
/// `bitprune export --synthetic --granularity channel` use.  Calibrated
/// like [`synthetic_net`].
pub fn synthetic_net_grouped(
    dims: &[usize],
    seed: u64,
    w_bits_cycle: &[u32],
    a_bits: u32,
) -> IntNet {
    assert!(dims.len() >= 2, "synthetic_net_grouped needs at least one layer");
    assert!(!w_bits_cycle.is_empty(), "empty bitlength cycle");
    let mut rng = Rng::new(seed);
    let mut layers = Vec::with_capacity(dims.len() - 1);
    for (i, pair) in dims.windows(2).enumerate() {
        let (din, dout) = (pair[0], pair[1]);
        let std = (1.0 / din as f32).sqrt();
        let w: Vec<f32> = (0..din * dout).map(|_| rng.normal_f32(0.0, std)).collect();
        let b: Vec<f32> = (0..dout).map(|_| rng.normal_f32(0.0, 0.01)).collect();
        let bits: Vec<f32> = (0..dout)
            .map(|j| w_bits_cycle[j % w_bits_cycle.len()] as f32)
            .collect();
        let relu = i + 2 < dims.len();
        layers.push(
            crate::infer::IntDense::new_grouped(
                &format!("fc{i}"),
                &w,
                din,
                dout,
                &b,
                &bits,
                a_bits,
                relu,
            )
            .expect("synthetic grouped layer shapes are consistent"),
        );
    }
    let num_classes = *dims.last().unwrap();
    let mut net = IntNet { layers, num_classes };
    let calib_n = 256;
    let calib: Vec<f32> =
        (0..calib_n * dims[0]).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    net.calibrate(&calib, calib_n).expect("calibration batch is well-formed");
    net
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_grouped_net_is_calibrated_and_mixed() {
        let net = synthetic_net_grouped(&[12, 20, 6], 3, &[2, 4, 8], 6);
        assert_eq!(net.layers.len(), 2);
        assert!(net.is_calibrated());
        for l in &net.layers {
            assert_eq!(
                l.granularity(),
                crate::quant::Granularity::PerOutputChannel
            );
        }
        // The cycle produces genuinely mixed channel bitlengths.
        let h = net.w_bits_histogram();
        assert!(h[2] > 0 && h[4] > 0 && h[8] > 0);
        // Calibrated ⇒ batch-invariant, grouped codes included.
        let solo = net.forward(&[0.3; 12], 1);
        let mut batch = vec![0.3f32; 12];
        batch.extend(vec![5.0f32; 12]);
        let pair = net.forward(&batch, 2);
        assert!(solo
            .iter()
            .zip(&pair[..6])
            .all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn synthetic_mlp_is_calibrated_and_shaped() {
        let net = synthetic_mlp(7, 4, 8);
        assert_eq!(net.layers.len(), 3);
        assert_eq!(net.layers[0].din, 32);
        assert_eq!(net.layers[2].dout, 10);
        assert_eq!(net.num_classes, 10);
        assert!(net.is_calibrated());
        assert!(net.layers[0].relu && !net.layers[2].relu);
    }
}
