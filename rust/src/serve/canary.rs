//! Canary analysis: weighted traffic splits with online health
//! evaluation, automatic promotion, and automatic rollback.
//!
//! A canary is a staged registry version (see
//! [`crate::deploy::ModelRegistry::begin_canary`]) that receives a
//! deterministic percentage of live traffic while the incumbent keeps
//! serving the rest.  [`CanaryController`] is the pure state machine
//! the batcher drives:
//!
//! * **Routing** — per-request, by hashing the request's id against
//!   the canary version ([`CanaryController::routes_to_canary`]).
//!   Deterministic: the same request id always lands on the same side,
//!   so replays and tests are exact, and the split converges to `pct`
//!   without any shared mutable routing state.
//! * **Agreement** — every canary-routed sub-batch is *shadow-run* on
//!   the incumbent, and argmax agreement between the two answers is
//!   the online accuracy proxy (quantization papers' concern made
//!   operational: a mis-calibrated low-bit artifact disagrees with its
//!   reference, and that is observable without labels).
//! * **Latency** — per-sample forward latency of each side feeds
//!   bounded reservoirs; the canary's p99 is compared against the
//!   incumbent's at each window boundary.
//! * **Windows** — every `window` canary-served requests the
//!   controller closes a health window: agreement below
//!   `min_agreement` or canary p99 above `max_latency_ratio` × the
//!   incumbent's p99 triggers **immediate rollback**; `promote_after`
//!   consecutive healthy windows triggers **promotion**.  Either way
//!   the decision is applied through the registry's atomic swap
//!   machinery, so rollback is as zero-downtime as the hot-swap it
//!   reuses — and a bad canary never reaches 100% of traffic.

use std::collections::VecDeque;
use std::time::Duration;

use crate::util::stats::percentile;

/// Latency reservoirs ignore the ratio check until both sides have
/// this many batch samples (a p99 over two points is noise).
const MIN_LATENCY_SAMPLES: usize = 4;

/// Bounded per-side latency reservoir length (batch-level samples).
const LATENCY_RESERVOIR: usize = 512;

/// Knobs for one canary experiment.
#[derive(Debug, Clone)]
pub struct CanaryConfig {
    /// Percentage of traffic routed to the canary (1..=99 — a canary
    /// at 0% learns nothing and at 100% is not a canary).
    pub pct: u8,
    /// Canary-served requests per health window.
    pub window: usize,
    /// Consecutive healthy windows before promotion.
    pub promote_after: usize,
    /// Minimum argmax agreement with the incumbent per window.
    pub min_agreement: f64,
    /// Canary p99 per-sample latency ceiling, as a multiple of the
    /// incumbent's p99.
    pub max_latency_ratio: f64,
}

impl Default for CanaryConfig {
    fn default() -> Self {
        Self {
            pct: 10,
            window: 64,
            promote_after: 3,
            min_agreement: 0.98,
            max_latency_ratio: 2.0,
        }
    }
}

impl CanaryConfig {
    /// Validate operator input; returns a human-readable complaint.
    pub fn validate(&self) -> Result<(), String> {
        if !(1..=99).contains(&self.pct) {
            return Err(format!("canary pct must be 1..=99, got {}", self.pct));
        }
        if self.window == 0 || self.promote_after == 0 {
            return Err("canary window and promote_after must be at least 1".into());
        }
        if !(0.0..=1.0).contains(&self.min_agreement) {
            return Err(format!(
                "canary min_agreement must be in [0, 1], got {}",
                self.min_agreement
            ));
        }
        if !self.max_latency_ratio.is_finite() || self.max_latency_ratio <= 0.0 {
            return Err(format!(
                "canary max_latency_ratio must be positive, got {}",
                self.max_latency_ratio
            ));
        }
        Ok(())
    }
}

/// How a canary experiment ended.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CanaryOutcome {
    /// Promoted to active after the configured healthy windows.
    Promoted { version: u64 },
    /// Rolled back; the incumbent never stopped being active.
    RolledBack { version: u64, reason: String },
}

/// What the batcher should do after a window closed.
#[derive(Debug, Clone, PartialEq)]
pub enum CanaryDecision {
    Promote,
    Rollback { reason: String },
}

/// Point-in-time observability snapshot of a canary experiment.
#[derive(Debug, Clone)]
pub struct CanaryStatus {
    pub canary_version: u64,
    pub incumbent_version: u64,
    pub pct: u8,
    /// Requests the canary actually served.
    pub served: u64,
    /// Canary answers shadow-compared against the incumbent.
    pub compared: u64,
    /// Of those, how many argmaxes agreed.
    pub agreements: u64,
    pub healthy_windows: usize,
    /// p99 per-sample forward latency, seconds (None until enough
    /// samples).
    pub canary_p99: Option<f64>,
    pub incumbent_p99: Option<f64>,
    /// Set once the experiment resolved.
    pub outcome: Option<CanaryOutcome>,
}

impl CanaryStatus {
    /// Cumulative argmax agreement fraction (None before any
    /// comparison).
    pub fn agreement(&self) -> Option<f64> {
        (self.compared > 0).then(|| self.agreements as f64 / self.compared as f64)
    }
}

/// The per-experiment state machine.  Single-writer by design: only
/// the batcher thread observes and evaluates, so the struct needs no
/// interior synchronization (the server wraps it in its own mutex for
/// status snapshots).
pub struct CanaryController {
    cfg: CanaryConfig,
    canary_version: u64,
    incumbent_version: u64,
    served: u64,
    compared: u64,
    agreements: u64,
    window_served: u64,
    window_compared: u64,
    window_agreements: u64,
    canary_lat: VecDeque<f64>,
    incumbent_lat: VecDeque<f64>,
    healthy: usize,
    outcome: Option<CanaryOutcome>,
}

/// SplitMix64 finalizer — the deterministic request-id → route hash.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl CanaryController {
    pub fn new(canary_version: u64, incumbent_version: u64, cfg: CanaryConfig) -> Self {
        Self {
            cfg,
            canary_version,
            incumbent_version,
            served: 0,
            compared: 0,
            agreements: 0,
            window_served: 0,
            window_compared: 0,
            window_agreements: 0,
            canary_lat: VecDeque::new(),
            incumbent_lat: VecDeque::new(),
            healthy: 0,
            outcome: None,
        }
    }

    pub fn canary_version(&self) -> u64 {
        self.canary_version
    }

    /// Still routing traffic?  False once promoted or rolled back.
    pub fn active(&self) -> bool {
        self.outcome.is_none()
    }

    /// Deterministic per-request split: hash the request id salted by
    /// the canary version (a different canary re-shuffles which
    /// requests land on it) into 0..100 and compare against `pct`.
    pub fn routes_to_canary(&self, request_id: u64) -> bool {
        if self.outcome.is_some() {
            return false;
        }
        mix64(request_id ^ self.canary_version.wrapping_mul(0xD6E8FEB86659FD93)) % 100
            < u64::from(self.cfg.pct)
    }

    /// Record one batch's worth of evidence.  Latencies are per-sample
    /// seconds for whichever sub-batches ran (None when that side had
    /// no rows or its forward failed).
    pub fn observe(
        &mut self,
        incumbent_per_sample: Option<f64>,
        canary_per_sample: Option<f64>,
        canary_served: u64,
        agreements: u64,
        compared: u64,
    ) {
        if self.outcome.is_some() {
            return;
        }
        if let Some(s) = incumbent_per_sample {
            push_bounded(&mut self.incumbent_lat, s);
        }
        if let Some(s) = canary_per_sample {
            push_bounded(&mut self.canary_lat, s);
        }
        self.served += canary_served;
        self.window_served += canary_served;
        self.compared += compared;
        self.agreements += agreements;
        self.window_compared += compared;
        self.window_agreements += agreements;
    }

    /// Close any full windows and return the decision, if one fell
    /// out.  Rollback fires on the first unhealthy window; promotion
    /// after `promote_after` consecutive healthy ones.
    pub fn evaluate(&mut self) -> Option<CanaryDecision> {
        if self.outcome.is_some() {
            return None;
        }
        while self.window_served >= self.cfg.window as u64 {
            // Agreement check (skipped when nothing was comparable —
            // e.g. every shadow forward failed; latency still gates).
            if self.window_compared > 0 {
                let agreement =
                    self.window_agreements as f64 / self.window_compared as f64;
                if agreement < self.cfg.min_agreement {
                    return Some(CanaryDecision::Rollback {
                        reason: format!(
                            "disagreement: window argmax agreement {:.4} < required {:.4} \
                             ({}/{} compared)",
                            agreement,
                            self.cfg.min_agreement,
                            self.window_agreements,
                            self.window_compared
                        ),
                    });
                }
            }
            // Latency check, once both reservoirs are meaningful.
            if let (Some(cp99), Some(ip99)) = (self.canary_p99(), self.incumbent_p99())
            {
                if ip99 > 0.0 && cp99 > self.cfg.max_latency_ratio * ip99 {
                    return Some(CanaryDecision::Rollback {
                        reason: format!(
                            "latency: canary p99 {:.1}us > {:.1}x incumbent p99 {:.1}us",
                            cp99 * 1e6,
                            self.cfg.max_latency_ratio,
                            ip99 * 1e6
                        ),
                    });
                }
            }
            self.healthy += 1;
            self.window_served -= self.cfg.window as u64;
            self.window_compared = 0;
            self.window_agreements = 0;
            if self.healthy >= self.cfg.promote_after {
                return Some(CanaryDecision::Promote);
            }
        }
        None
    }

    /// Record how the experiment ended (the batcher calls this after
    /// applying the decision through the registry).
    pub fn resolve(&mut self, outcome: CanaryOutcome) {
        self.outcome = Some(outcome);
    }

    pub fn outcome(&self) -> Option<&CanaryOutcome> {
        self.outcome.as_ref()
    }

    /// Cumulative argmax agreement fraction without materialising a
    /// full [`CanaryStatus`] (the batcher refreshes the
    /// `canary_agreement` telemetry gauge per batch, and
    /// [`Self::status`] sorts both latency reservoirs — too heavy for
    /// that cadence).
    pub fn agreement(&self) -> Option<f64> {
        (self.compared > 0).then(|| self.agreements as f64 / self.compared as f64)
    }

    fn canary_p99(&self) -> Option<f64> {
        p99_of(&self.canary_lat)
    }

    fn incumbent_p99(&self) -> Option<f64> {
        p99_of(&self.incumbent_lat)
    }

    pub fn status(&self) -> CanaryStatus {
        CanaryStatus {
            canary_version: self.canary_version,
            incumbent_version: self.incumbent_version,
            pct: self.cfg.pct,
            served: self.served,
            compared: self.compared,
            agreements: self.agreements,
            healthy_windows: self.healthy,
            canary_p99: self.canary_p99(),
            incumbent_p99: self.incumbent_p99(),
            outcome: self.outcome.clone(),
        }
    }
}

fn push_bounded(buf: &mut VecDeque<f64>, v: f64) {
    if buf.len() == LATENCY_RESERVOIR {
        buf.pop_front();
    }
    buf.push_back(v);
}

fn p99_of(buf: &VecDeque<f64>) -> Option<f64> {
    if buf.len() < MIN_LATENCY_SAMPLES {
        return None;
    }
    let mut sorted: Vec<f64> = buf.iter().copied().collect();
    sorted.sort_by(|a, b| a.total_cmp(b));
    Some(percentile(&sorted, 99.0))
}

/// Per-sample seconds from one sub-batch forward.
pub(crate) fn per_sample_secs(total: Duration, rows: usize) -> Option<f64> {
    (rows > 0).then(|| total.as_secs_f64() / rows as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(pct: u8, window: usize, promote_after: usize) -> CanaryConfig {
        CanaryConfig {
            pct,
            window,
            promote_after,
            min_agreement: 0.9,
            max_latency_ratio: 2.0,
        }
    }

    #[test]
    fn config_validation_catches_operator_errors() {
        assert!(CanaryConfig::default().validate().is_ok());
        assert!(CanaryConfig { pct: 0, ..CanaryConfig::default() }.validate().is_err());
        assert!(CanaryConfig { pct: 100, ..CanaryConfig::default() }
            .validate()
            .is_err());
        assert!(CanaryConfig { window: 0, ..CanaryConfig::default() }
            .validate()
            .is_err());
        assert!(CanaryConfig { promote_after: 0, ..CanaryConfig::default() }
            .validate()
            .is_err());
        assert!(CanaryConfig { min_agreement: 1.5, ..CanaryConfig::default() }
            .validate()
            .is_err());
        assert!(
            CanaryConfig { max_latency_ratio: 0.0, ..CanaryConfig::default() }
                .validate()
                .is_err()
        );
    }

    #[test]
    fn routing_is_deterministic_and_converges_to_pct() {
        let ctrl = CanaryController::new(7, 1, cfg(20, 64, 3));
        let hits: usize = (0..10_000).filter(|&id| ctrl.routes_to_canary(id)).count();
        // Exactly reproducible...
        let hits2: usize = (0..10_000).filter(|&id| ctrl.routes_to_canary(id)).count();
        assert_eq!(hits, hits2);
        // ...and close to the requested split.
        assert!((1500..2500).contains(&hits), "20% split routed {hits}/10000");
        // A different canary version reshuffles the assignment but
        // keeps the rate.
        let other = CanaryController::new(8, 1, cfg(20, 64, 3));
        let overlap = (0..10_000)
            .filter(|&id| ctrl.routes_to_canary(id) && other.routes_to_canary(id))
            .count();
        assert!(overlap < hits, "different canaries must not share one split");
    }

    #[test]
    fn healthy_windows_promote() {
        let mut ctrl = CanaryController::new(2, 1, cfg(50, 10, 3));
        // Two full healthy windows: no decision yet.
        for _ in 0..2 {
            ctrl.observe(Some(10e-6), Some(11e-6), 10, 10, 10);
            assert_eq!(ctrl.evaluate(), None);
        }
        assert_eq!(ctrl.status().healthy_windows, 2);
        // Third closes the deal.
        ctrl.observe(Some(10e-6), Some(11e-6), 10, 10, 10);
        assert_eq!(ctrl.evaluate(), Some(CanaryDecision::Promote));
        ctrl.resolve(CanaryOutcome::Promoted { version: 2 });
        assert!(!ctrl.active());
        assert!(!ctrl.routes_to_canary(0), "resolved canary routes nothing");
        assert_eq!(ctrl.evaluate(), None);
    }

    #[test]
    fn disagreement_rolls_back_at_first_window() {
        let mut ctrl = CanaryController::new(2, 1, cfg(50, 10, 3));
        // 6/10 agreement < 0.9 — one window is enough to kill it.
        ctrl.observe(Some(10e-6), Some(10e-6), 10, 6, 10);
        match ctrl.evaluate() {
            Some(CanaryDecision::Rollback { reason }) => {
                assert!(reason.contains("disagreement"), "{reason}");
            }
            other => panic!("expected rollback, got {other:?}"),
        }
    }

    #[test]
    fn latency_regression_rolls_back_once_measurable() {
        let mut ctrl = CanaryController::new(2, 1, cfg(50, 4, 100));
        // Perfect agreement, but the canary is 10x slower.  Below
        // MIN_LATENCY_SAMPLES the ratio check abstains (windows pass);
        // once both reservoirs fill it trips.
        let mut decision = None;
        for _ in 0..MIN_LATENCY_SAMPLES + 1 {
            ctrl.observe(Some(10e-6), Some(100e-6), 4, 4, 4);
            if let Some(d) = ctrl.evaluate() {
                decision = Some(d);
                break;
            }
        }
        match decision {
            Some(CanaryDecision::Rollback { reason }) => {
                assert!(reason.contains("latency"), "{reason}");
            }
            other => panic!("expected latency rollback, got {other:?}"),
        }
    }

    #[test]
    fn windows_span_batches_and_partial_windows_wait() {
        let mut ctrl = CanaryController::new(2, 1, cfg(50, 10, 1));
        // 9 served: no window closes, no decision.
        ctrl.observe(None, Some(10e-6), 9, 9, 9);
        assert_eq!(ctrl.evaluate(), None);
        // 1 more completes the window; promote_after=1 promotes.
        ctrl.observe(None, Some(10e-6), 1, 1, 1);
        assert_eq!(ctrl.evaluate(), Some(CanaryDecision::Promote));
    }
}
