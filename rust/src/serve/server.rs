//! Dynamic micro-batching request queue over a versioned model
//! registry.
//!
//! Requests are single samples; a dedicated batcher thread coalesces
//! them into batches (flushing when `max_batch` are waiting or the
//! oldest request has waited `batch_window`, whichever comes first),
//! resolves the **current registry version once per batch**, runs the
//! batch through a [`ServeEngine`], and answers every caller with its
//! own logits row tagged with the version that produced it.
//!
//! Hot-swap semantics follow directly: a `ModelRegistry::publish`
//! between batches retargets the *next* batch while the in-flight one
//! completes on the `Arc` it already resolved (drain — no request is
//! dropped, mixed across versions, or served by a half-swapped model).
//! Because every published net carries calibrated activation ranges,
//! each answer is bit-identical to the sample's solo forward on that
//! version, however it was batched.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::engine::ServeEngine;
use crate::deploy::ModelRegistry;
use crate::infer::IntNet;

/// Knobs for the micro-batching serving loop.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// GEMM pool workers; `0` sizes to the machine.
    pub threads: usize,
    /// Flush as soon as this many requests are queued.
    pub max_batch: usize,
    /// Flush once the oldest queued request has waited this long since
    /// it was enqueued (the latency deadline).
    pub batch_window: Duration,
    /// Backpressure bound: submissions are rejected while this many
    /// requests are already queued (otherwise sustained overload grows
    /// the queue — and memory, and tail latency — without limit).
    pub max_queue: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            threads: 0,
            max_batch: 64,
            batch_window: Duration::from_micros(500),
            max_queue: 4096,
        }
    }
}

/// Counters the batcher maintains while serving.
#[derive(Debug, Clone, Copy)]
pub struct ServeStats {
    pub batches: u64,
    pub requests: u64,
    /// Times the batcher observed a different registry version than
    /// the previous batch (publishes *and* rollbacks land here).
    pub swaps: u64,
}

impl ServeStats {
    /// Mean coalesced batch size (0 if nothing served yet).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }
}

/// One answered request: the logits row plus the registry version of
/// the model that computed it (the hot-swap observability hook).
#[derive(Debug, Clone)]
pub struct Response {
    pub version: u64,
    pub logits: Vec<f32>,
}

struct Request {
    x: Vec<f32>,
    resp: Sender<Response>,
    /// When the request entered the queue — the batch-window deadline
    /// counts from here, not from when the batcher gets around to it.
    enqueued: Instant,
}

struct Shared {
    queue: Mutex<VecDeque<Request>>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// Backpressure bound (ServeConfig::max_queue), fixed at start.
    max_queue: usize,
    batches: AtomicU64,
    requests: AtomicU64,
    swaps: AtomicU64,
}

/// The serving endpoint: owns the batcher thread and resolves its
/// model through a [`ModelRegistry`] once per batch.  Dropping (or
/// calling [`Server::shutdown`]) drains the queue and joins the
/// batcher; requests still queued at shutdown are served, requests
/// submitted after it are rejected.
pub struct Server {
    shared: Arc<Shared>,
    registry: Arc<ModelRegistry>,
    din: usize,
    out_dim: usize,
    batcher: Option<JoinHandle<()>>,
}

/// Cheap cloneable submission handle (safe to share across client
/// threads).
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
    din: usize,
}

impl Server {
    /// Convenience for single-model serving: wrap `net` in a fresh
    /// one-version registry and start.  The net should carry
    /// calibrated activation ranges ([`IntNet::is_calibrated`]);
    /// serving an uncalibrated net works but answers then depend on
    /// batch composition, which micro-batching makes nondeterministic.
    pub fn start(net: Arc<IntNet>, cfg: ServeConfig) -> Result<Self> {
        let registry = Arc::new(ModelRegistry::new(net, "initial")?);
        Self::start_registry(registry, cfg)
    }

    /// Spin up the batcher over an existing registry.  The registry
    /// stays shared: publishing to it while this server runs hot-swaps
    /// the model between batches with zero downtime.
    pub fn start_registry(registry: Arc<ModelRegistry>, cfg: ServeConfig) -> Result<Self> {
        if cfg.max_batch == 0 || cfg.max_queue == 0 {
            bail!("serve: max_batch and max_queue must be at least 1");
        }
        let din = registry.input_dim();
        let out_dim = registry.out_dim();
        let engine = ServeEngine::new(cfg.threads);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            max_queue: cfg.max_queue,
            batches: AtomicU64::new(0),
            requests: AtomicU64::new(0),
            swaps: AtomicU64::new(0),
        });
        let shared2 = Arc::clone(&shared);
        let registry2 = Arc::clone(&registry);
        let batcher = std::thread::Builder::new()
            .name("bitprune-batcher".into())
            .spawn(move || batcher_loop(shared2, registry2, engine, cfg, out_dim))
            .map_err(|e| anyhow!("serve: spawning batcher thread: {e}"))?;
        Ok(Self { shared, registry, din, out_dim, batcher: Some(batcher) })
    }

    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shared: Arc::clone(&self.shared), din: self.din }
    }

    /// The registry this server resolves its model through — publish
    /// or roll back here to hot-swap what subsequent batches run.
    pub fn registry(&self) -> Arc<ModelRegistry> {
        Arc::clone(&self.registry)
    }

    /// Input dimensionality one request must carry.
    pub fn input_dim(&self) -> usize {
        self.din
    }

    /// Logits dimensionality one response carries.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    pub fn stats(&self) -> ServeStats {
        ServeStats {
            batches: self.shared.batches.load(Ordering::Relaxed),
            requests: self.shared.requests.load(Ordering::Relaxed),
            swaps: self.shared.swaps.load(Ordering::Relaxed),
        }
    }

    /// Stop accepting work, serve what is queued, join the batcher.
    pub fn shutdown(mut self) -> ServeStats {
        self.stop();
        self.stats()
    }

    fn stop(&mut self) {
        // Flip the flag while holding the queue lock so the batcher
        // cannot check-then-sleep between our store and the notify.
        {
            let guard = self.shared.queue.lock();
            self.shared.shutdown.store(true, Ordering::SeqCst);
            drop(guard);
        }
        self.shared.cv.notify_all();
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

impl ServerHandle {
    /// Enqueue one sample; returns the channel the versioned logits
    /// row arrives on.  Fails fast on wrong input length, a shut-down
    /// server, or a full queue (backpressure — see
    /// [`ServeConfig::max_queue`]).
    pub fn submit(&self, x: Vec<f32>) -> Result<Receiver<Response>> {
        if x.len() != self.din {
            bail!("serve: request has {} values, model wants {}", x.len(), self.din);
        }
        let (tx, rx) = channel();
        {
            let mut q = self
                .shared
                .queue
                .lock()
                .map_err(|_| anyhow!("serve: request queue poisoned"))?;
            // Check shutdown *under the queue lock*: stop() flips the
            // flag under this lock, so a request enqueued here is
            // guaranteed to be seen by the batcher's drain pass — no
            // window where a request slips in after the batcher exited
            // and blocks its caller forever.
            if self.shared.shutdown.load(Ordering::SeqCst) {
                bail!("serve: server is shut down");
            }
            if q.len() >= self.shared.max_queue {
                bail!(
                    "serve: queue full ({} requests) — backpressure, retry later",
                    q.len()
                );
            }
            q.push_back(Request { x, resp: tx, enqueued: Instant::now() });
        }
        self.shared.cv.notify_all();
        Ok(rx)
    }

    /// Submit and block for the answer.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.infer_versioned(x).map(|(_, logits)| logits)
    }

    /// Submit and block for the answer plus the registry version of
    /// the model that computed it (what the hot-swap tests and the
    /// `--swap-to` CLI demo key on).
    pub fn infer_versioned(&self, x: Vec<f32>) -> Result<(u64, Vec<f32>)> {
        let r = self
            .submit(x)?
            .recv()
            .map_err(|_| anyhow!("serve: server dropped the request"))?;
        Ok((r.version, r.logits))
    }
}

/// Marks the server dead when the batcher exits for *any* reason —
/// including a panic unwinding out of the forward (e.g. a worker-pool
/// job panicked).  Sets the shutdown flag, drops every queued request
/// (their response Senders drop, so blocked `infer` callers get a
/// clean error instead of hanging) and wakes everyone.
struct BatcherGuard(Arc<Shared>);

impl Drop for BatcherGuard {
    fn drop(&mut self) {
        self.0.shutdown.store(true, Ordering::SeqCst);
        match self.0.queue.lock() {
            Ok(mut q) => q.clear(),
            Err(poisoned) => poisoned.into_inner().clear(),
        }
        self.0.cv.notify_all();
    }
}

fn batcher_loop(
    shared: Arc<Shared>,
    registry: Arc<ModelRegistry>,
    mut engine: ServeEngine,
    cfg: ServeConfig,
    out_dim: usize,
) {
    let _guard = BatcherGuard(Arc::clone(&shared));
    let mut gather: Vec<f32> = Vec::new();
    let mut batch: Vec<Request> = Vec::new();
    let mut last_version = 0u64;
    loop {
        batch.clear();
        {
            let mut q = match shared.queue.lock() {
                Ok(g) => g,
                Err(_) => return,
            };
            // Wait for the first request; exit only when shut down AND
            // drained (late-queued requests still get served).
            while q.is_empty() {
                if shared.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = match shared.cv.wait(q) {
                    Ok(g) => g,
                    Err(_) => return,
                };
            }
            // Dynamic micro-batching: flush at max_batch or when the
            // *oldest* request's latency deadline (its enqueue time
            // plus batch_window) expires — requests that queued while a
            // previous batch was computing have already burned part of
            // their window.
            let deadline = q
                .front()
                .map(|r| r.enqueued + cfg.batch_window)
                .expect("queue is non-empty here");
            while q.len() < cfg.max_batch && !shared.shutdown.load(Ordering::SeqCst) {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                q = match shared.cv.wait_timeout(q, deadline - now) {
                    Ok((g, _)) => g,
                    Err(_) => return,
                };
            }
            let take = q.len().min(cfg.max_batch);
            batch.extend(q.drain(..take));
        } // queue unlocked before the forward: submitters never block on compute
        let n = batch.len();
        gather.clear();
        for r in &batch {
            gather.extend_from_slice(&r.x);
        }
        // Resolve the model once per batch: the whole batch runs on one
        // version, and holding the Arc is what gives a concurrent
        // publish its drain semantics.
        let mv = registry.current();
        if last_version != 0 && mv.version != last_version {
            shared.swaps.fetch_add(1, Ordering::Relaxed);
        }
        last_version = mv.version;
        let logits = engine.forward(&mv.net, &gather, n);
        for (row, r) in logits.chunks_exact(out_dim).zip(&batch) {
            // A client that gave up (dropped its Receiver) is not an
            // error for the batch.
            let _ = r
                .resp
                .send(Response { version: mv.version, logits: row.to_vec() });
        }
        shared.batches.fetch_add(1, Ordering::Relaxed);
        shared.requests.fetch_add(n as u64, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::synthetic_net;
    use crate::util::rng::Rng;

    fn small_net() -> Arc<IntNet> {
        Arc::new(synthetic_net(&[6, 14, 3], 0x5EED, 4, 6))
    }

    #[test]
    fn served_answers_match_solo_forward_bitwise() {
        // The heart of the batch-invariance guarantee at the server
        // level: whatever coalescing happens inside, each answer equals
        // the sample's solo forward, bit for bit.
        let net = small_net();
        let server = Server::start(
            Arc::clone(&net),
            ServeConfig {
                threads: 2,
                max_batch: 8,
                batch_window: Duration::from_millis(5),
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let handle = server.handle();
        let mut rng = Rng::new(42);
        let samples: Vec<Vec<f32>> = (0..40)
            .map(|_| (0..6).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let pending: Vec<_> = samples
            .iter()
            .map(|s| handle.submit(s.clone()).unwrap())
            .collect();
        for (s, rx) in samples.iter().zip(pending) {
            let got = rx.recv().unwrap();
            assert_eq!(got.version, 1, "single-model server serves version 1");
            let want = net.forward(s, 1);
            assert_eq!(got.logits.len(), want.len());
            assert!(
                got.logits
                    .iter()
                    .zip(&want)
                    .all(|(a, b)| a.to_bits() == b.to_bits()),
                "served answer differs from solo forward"
            );
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 40);
        assert!(stats.batches >= 5, "max_batch 8 over 40 requests => >= 5 batches");
        assert!(stats.mean_batch() >= 1.0);
        assert_eq!(stats.swaps, 0);
    }

    #[test]
    fn window_flush_serves_partial_batches() {
        // Fewer requests than max_batch must still be answered once the
        // latency deadline passes.
        let server = Server::start(
            small_net(),
            ServeConfig {
                threads: 1,
                max_batch: 64,
                batch_window: Duration::from_millis(2),
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let handle = server.handle();
        let out = handle.infer(vec![0.5; 6]).unwrap();
        assert_eq!(out.len(), 3);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.batches, 1);
    }

    #[test]
    fn degenerate_inputs_are_served() {
        // Constant batches and all-zero (post-ReLU-like) inputs must
        // not divide by zero or poison the batcher.
        let server = Server::start(small_net(), ServeConfig::default()).unwrap();
        let handle = server.handle();
        for x in [vec![0.0f32; 6], vec![1.0f32; 6], vec![-7.5f32; 6]] {
            let out = handle.infer(x).unwrap();
            assert_eq!(out.len(), 3);
            assert!(out.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn submit_validates_and_shutdown_rejects() {
        let server = Server::start(small_net(), ServeConfig::default()).unwrap();
        let handle = server.handle();
        assert!(handle.submit(vec![0.0; 5]).is_err(), "wrong input length");
        server.shutdown();
        assert!(handle.infer(vec![0.0; 6]).is_err(), "server is gone");
    }

    #[test]
    fn start_rejects_bad_configs() {
        let empty = Arc::new(IntNet { layers: vec![], num_classes: 0 });
        assert!(Server::start(empty, ServeConfig::default()).is_err());
        let cfg = ServeConfig { max_batch: 0, ..ServeConfig::default() };
        assert!(Server::start(small_net(), cfg).is_err());
        let cfg = ServeConfig { max_queue: 0, ..ServeConfig::default() };
        assert!(Server::start(small_net(), cfg).is_err());
    }

    #[test]
    fn queue_backpressure_rejects_overflow() {
        // max_batch and window both out of reach: nothing drains until
        // shutdown, so the 9th submission must hit the max_queue bound
        // deterministically instead of growing the queue without limit.
        let server = Server::start(
            small_net(),
            ServeConfig {
                threads: 1,
                max_batch: 64,
                batch_window: Duration::from_secs(30),
                max_queue: 8,
            },
        )
        .unwrap();
        let handle = server.handle();
        let _pending: Vec<_> = (0..8)
            .map(|_| handle.submit(vec![0.1; 6]).unwrap())
            .collect();
        let err = handle.submit(vec![0.1; 6]).unwrap_err();
        assert!(err.to_string().contains("queue full"), "{err}");
        // Shutdown still drains and answers the queued 8 without
        // waiting out the 30s window.
        let stats = server.shutdown();
        assert_eq!(stats.requests, 8);
    }

    #[test]
    fn registry_publish_retargets_subsequent_requests() {
        // Sequential requests around a publish: answers before the
        // swap carry version 1 and match net A; answers after carry
        // version 2 and match net B (the post-drain property, in its
        // deterministic single-threaded form — the concurrent version
        // lives in tests/deploy_hotswap.rs).
        let a = small_net();
        let b = Arc::new(synthetic_net(&[6, 14, 3], 0xB0B, 4, 6));
        let registry =
            Arc::new(crate::deploy::ModelRegistry::new(Arc::clone(&a), "a").unwrap());
        let server = Server::start_registry(
            Arc::clone(&registry),
            ServeConfig {
                threads: 1,
                max_batch: 4,
                batch_window: Duration::from_millis(1),
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let handle = server.handle();
        let x = vec![0.3f32; 6];

        let (v, logits) = handle.infer_versioned(x.clone()).unwrap();
        assert_eq!(v, 1);
        let want_a = a.forward(&x, 1);
        assert!(logits.iter().zip(&want_a).all(|(p, q)| p.to_bits() == q.to_bits()));

        registry.publish(Arc::clone(&b), "b").unwrap();
        let (v, logits) = handle.infer_versioned(x.clone()).unwrap();
        assert_eq!(v, 2, "post-publish requests must run on the new version");
        let want_b = b.forward(&x, 1);
        assert!(logits.iter().zip(&want_b).all(|(p, q)| p.to_bits() == q.to_bits()));

        // Rollback retargets again.
        registry.rollback(1).unwrap();
        let (v, logits) = handle.infer_versioned(x.clone()).unwrap();
        assert_eq!(v, 1);
        assert!(logits.iter().zip(&want_a).all(|(p, q)| p.to_bits() == q.to_bits()));

        let stats = server.shutdown();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.swaps, 2, "publish + rollback each count as one swap");
    }
}
