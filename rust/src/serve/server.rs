//! Dynamic micro-batching request queue over a versioned model
//! registry, hardened for operation under failure.
//!
//! Requests are single samples; a dedicated batcher thread coalesces
//! them into batches (flushing when `max_batch` are waiting or the
//! oldest request has waited `batch_window`, whichever comes first),
//! resolves the **current registry version once per batch**, runs the
//! batch through a [`ServeEngine`], and answers every caller with its
//! own logits row tagged with the version that produced it.
//!
//! Hot-swap semantics follow directly: a `ModelRegistry::publish`
//! between batches retargets the *next* batch while the in-flight one
//! completes on the `Arc` it already resolved (drain — no request is
//! dropped, mixed across versions, or served by a half-swapped model).
//! Because every published net carries calibrated activation ranges,
//! each answer is bit-identical to the sample's solo forward on that
//! version, however it was batched.
//!
//! Failure hardening, on top of that:
//!
//! * **Typed outcomes** — every submit resolves to exactly one
//!   [`ServeResult`]: the logits, or a [`ServeError`] saying *why* not
//!   and whether retrying can help.  Nothing is silently dropped.
//! * **Deadlines + load shedding** — a request may carry an absolute
//!   deadline ([`ServerHandle::submit_with_deadline`], or the
//!   server-wide [`ServeConfig::deadline`] default).  The batcher
//!   sheds expired requests at dequeue with
//!   [`ServeError::DeadlineExpired`] instead of burning a batch slot
//!   on an answer nobody is waiting for.  Admission at a full queue
//!   follows [`ServeConfig::shed_policy`]: reject the newcomer
//!   ([`ShedPolicy::RejectNewest`]) or first evict already-expired
//!   entries to make room ([`ShedPolicy::DropExpired`]).  Every shed
//!   is counted in [`ServeStats`].
//! * **Retry** — [`ServerHandle::infer_with_retry`] retries retryable
//!   rejections (queue full, a panicked batch) with bounded, jittered
//!   exponential backoff ([`RetryPolicy`]).
//! * **Panic isolation** — a panic unwinding out of a batch forward is
//!   caught; the affected requests get [`ServeError::WorkerPanic`]
//!   (retryable) and the batcher keeps serving subsequent batches.
//! * **Canary splits** — [`Server::start_canary`] stages a candidate
//!   version behind a deterministic per-request traffic split with
//!   shadow-compare against the incumbent; a
//!   [`super::canary::CanaryController`] promotes it after consecutive
//!   healthy windows or auto-rolls it back on disagreement/latency
//!   regression, through the registry's atomic swap.  See
//!   [`super::canary`].

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::canary::{
    per_sample_secs, CanaryConfig, CanaryController, CanaryDecision, CanaryOutcome,
    CanaryStatus,
};
use super::engine::ServeEngine;
use crate::deploy::ModelRegistry;
use crate::infer::{argmax_rows, IntNet};
use crate::telemetry::{Counter, Gauge, Histogram, Registry as TelemetryRegistry, TraceWriter, Tv};
use crate::util::rng::Rng;

/// Why a request was not served.  Every failed submit or response
/// resolves to one of these — the contract that makes "no request
/// silently lost" checkable.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ServeError {
    /// Request length does not match the endpoint's input dim.
    BadInput { got: usize, want: usize },
    /// Admission refused: the queue is at `max_queue`.  Retryable —
    /// backpressure, not failure.
    QueueFull { queued: usize },
    /// Shed: the request's deadline expired while it waited in the
    /// queue (`waited` = time from enqueue to shed).
    DeadlineExpired { waited: Duration },
    /// The server is shut down (or shutting down took the request
    /// with it).
    ShuttingDown,
    /// The batch this request was in panicked mid-forward.  The
    /// server survives; the request is retryable.
    WorkerPanic,
    /// The server dropped the response channel without answering —
    /// only possible if the batcher died abnormally.
    Disconnected,
}

impl ServeError {
    /// Worth retrying with backoff?  True for transient conditions
    /// (backpressure, a panicked batch); false for caller errors and
    /// terminal states.
    pub fn is_retryable(&self) -> bool {
        matches!(self, Self::QueueFull { .. } | Self::WorkerPanic)
    }

    /// Was this a load-shed (counted in [`ServeStats`] shed counters)?
    pub fn is_shed(&self) -> bool {
        matches!(self, Self::QueueFull { .. } | Self::DeadlineExpired { .. })
    }
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::BadInput { got, want } => {
                write!(f, "serve: request has {got} values, model wants {want}")
            }
            Self::QueueFull { queued } => write!(
                f,
                "serve: queue full ({queued} requests) — backpressure, retry later"
            ),
            Self::DeadlineExpired { waited } => write!(
                f,
                "serve: deadline expired after {:.1}us in queue — request shed",
                waited.as_secs_f64() * 1e6
            ),
            Self::ShuttingDown => write!(f, "serve: server is shut down"),
            Self::WorkerPanic => {
                write!(f, "serve: batch forward panicked — request not served (retryable)")
            }
            Self::Disconnected => write!(f, "serve: server dropped the request"),
        }
    }
}

impl std::error::Error for ServeError {}

/// One request's terminal outcome.
pub type ServeResult = std::result::Result<Response, ServeError>;

/// What to do when a submission meets a full queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ShedPolicy {
    /// Reject the incoming request ([`ServeError::QueueFull`]); queued
    /// requests keep their slots.  FIFO-fair; the default.
    #[default]
    RejectNewest,
    /// First shed queued requests whose deadline already expired
    /// (they'd be shed at dequeue anyway), then admit if that made
    /// room.  Keeps the queue full of *answerable* work under
    /// sustained overload.
    DropExpired,
}

impl ShedPolicy {
    /// Parse an operator string (`reject-newest` / `drop-expired`).
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "reject-newest" => Some(Self::RejectNewest),
            "drop-expired" => Some(Self::DropExpired),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::RejectNewest => "reject-newest",
            Self::DropExpired => "drop-expired",
        }
    }
}

/// Knobs for the micro-batching serving loop.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// GEMM pool workers; `0` sizes to the machine.
    pub threads: usize,
    /// Flush as soon as this many requests are queued.
    pub max_batch: usize,
    /// Flush once the oldest queued request has waited this long since
    /// it was enqueued (the latency deadline).
    pub batch_window: Duration,
    /// Backpressure bound: admissions hit [`ServeConfig::shed_policy`]
    /// while this many requests are already queued (otherwise
    /// sustained overload grows the queue — and memory, and tail
    /// latency — without limit).
    pub max_queue: usize,
    /// Default per-request deadline, measured from enqueue (`None` =
    /// requests wait indefinitely).  Explicit
    /// [`ServerHandle::submit_with_deadline`] deadlines override it.
    pub deadline: Option<Duration>,
    /// Admission behavior at a full queue.
    pub shed_policy: ShedPolicy,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            threads: 0,
            max_batch: 64,
            batch_window: Duration::from_micros(500),
            max_queue: 4096,
            deadline: None,
            shed_policy: ShedPolicy::RejectNewest,
        }
    }
}

/// Bounded retry with jittered exponential backoff for retryable
/// rejections ([`ServeError::is_retryable`]).  Deterministic: the
/// jitter is a pure function of `seed` and the attempt number.
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total attempts, including the first (>= 1).
    pub max_attempts: u32,
    /// Backoff before retry k (0-based) is `base * 2^k`, capped at
    /// [`Self::cap`], scaled by a jitter factor in [0.5, 1.5).
    pub base: Duration,
    pub cap: Duration,
    /// Jitter seed; vary per client to de-synchronize retry storms.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            max_attempts: 4,
            base: Duration::from_micros(500),
            cap: Duration::from_millis(20),
            seed: 0x8E7247,
        }
    }
}

impl RetryPolicy {
    /// Backoff before 0-based retry `attempt`.
    pub fn backoff(&self, attempt: u32) -> Duration {
        let exp = self.base.saturating_mul(1u32 << attempt.min(16)).min(self.cap);
        let mut rng = Rng::new(self.seed ^ u64::from(attempt).wrapping_mul(0x9E3779B97F4A7C15));
        exp.mul_f64(0.5 + rng.uniform())
    }
}

/// Counters the batcher maintains while serving.
#[derive(Debug, Clone, Copy)]
pub struct ServeStats {
    pub batches: u64,
    /// Requests answered with logits (sheds and failures not
    /// included).
    pub requests: u64,
    /// Times the batcher observed a different registry version than
    /// the previous batch (publishes *and* rollbacks land here).
    pub swaps: u64,
    /// Admissions refused at a full queue
    /// ([`ServeError::QueueFull`]).
    pub shed_queue_full: u64,
    /// Requests shed because their deadline expired in the queue
    /// ([`ServeError::DeadlineExpired`]).
    pub shed_expired: u64,
    /// Requests answered [`ServeError::WorkerPanic`] because their
    /// batch's forward panicked.
    pub failed: u64,
    /// Requests served by an in-flight canary version.
    pub canary_requests: u64,
    /// Canary experiments promoted / rolled back on this server.
    pub promotions: u64,
    pub rollbacks: u64,
}

impl ServeStats {
    /// Mean coalesced batch size (0 if nothing served yet).
    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.requests as f64 / self.batches as f64
        }
    }

    /// Total requests shed (admission + deadline).
    pub fn shed(&self) -> u64 {
        self.shed_queue_full + self.shed_expired
    }
}

/// One answered request: the logits row plus the registry version of
/// the model that computed it (the hot-swap observability hook).
#[derive(Debug, Clone)]
pub struct Response {
    pub version: u64,
    pub logits: Vec<f32>,
}

struct Request {
    /// Server-assigned sequence number — the deterministic canary
    /// routing key.
    id: u64,
    x: Vec<f32>,
    resp: Sender<ServeResult>,
    /// When the request entered the queue — the batch-window deadline
    /// counts from here, not from when the batcher gets around to it.
    enqueued: Instant,
    /// Absolute shed deadline, if any.
    deadline: Option<Instant>,
}

/// The server's handles into its [`TelemetryRegistry`].
///
/// The [`ServeStats`] ledger counters *are* these registry counters —
/// one set of atomics behind both surfaces — so the metrics endpoint
/// and `Server::stats()` cannot disagree (asserted under full chaos in
/// `tests/serve_chaos.rs`). Histogram/gauge handles are cloned `Arc`s;
/// recording is a relaxed atomic RMW on the batcher's path.
struct ServeMetrics {
    registry: Arc<TelemetryRegistry>,
    batches: Arc<Counter>,
    requests: Arc<Counter>,
    swaps: Arc<Counter>,
    shed_queue_full: Arc<Counter>,
    shed_expired: Arc<Counter>,
    failed: Arc<Counter>,
    canary_requests: Arc<Counter>,
    promotions: Arc<Counter>,
    rollbacks: Arc<Counter>,
    /// Client-side retry attempts (`infer_with_retry` backoffs).
    retries: Arc<Counter>,
    /// Queue length, updated at admission and batch drain.
    queue_depth: Arc<Gauge>,
    /// Coalesced batch sizes.
    batch_size: Arc<Histogram>,
    /// Enqueue-to-delivery latency (seconds) of answered requests.
    e2e_latency: Arc<Histogram>,
    /// Worker-pool thread respawns (published by the pool itself).
    respawns: Arc<Counter>,
}

impl ServeMetrics {
    fn new(registry: Arc<TelemetryRegistry>) -> Self {
        ServeMetrics {
            batches: registry.counter("serve_batches_total", &[]),
            requests: registry.counter("serve_requests_total", &[]),
            swaps: registry.counter("serve_swaps_total", &[]),
            shed_queue_full: registry
                .counter("serve_shed_total", &[("reason", "queue_full")]),
            shed_expired: registry.counter("serve_shed_total", &[("reason", "expired")]),
            failed: registry.counter("serve_failed_total", &[]),
            canary_requests: registry.counter("serve_canary_requests_total", &[]),
            promotions: registry.counter("serve_promotions_total", &[]),
            rollbacks: registry.counter("serve_rollbacks_total", &[]),
            retries: registry.counter("serve_retries_total", &[]),
            queue_depth: registry.gauge("serve_queue_depth", &[]),
            batch_size: registry.histogram("serve_batch_size", &[], 1.0),
            e2e_latency: registry.histogram("serve_request_latency_seconds", &[], 1e-9),
            respawns: registry.counter("pool_respawns_total", &[]),
            registry,
        }
    }
}

struct Shared {
    queue: Mutex<VecDeque<Request>>,
    cv: Condvar,
    shutdown: AtomicBool,
    /// Backpressure bound (ServeConfig::max_queue), fixed at start.
    max_queue: usize,
    /// Server-wide default deadline (ServeConfig::deadline).
    default_deadline: Option<Duration>,
    shed_policy: ShedPolicy,
    /// Request id sequence (canary routing key).
    seq: AtomicU64,
    /// Registry-backed counters — the single source of truth behind
    /// both [`ServeStats`] and the metrics endpoint.
    metrics: ServeMetrics,
    /// Lifecycle event trace (`--trace-out`), if enabled.
    trace: Option<Arc<TraceWriter>>,
    /// The in-flight canary experiment, if any.  Locked briefly by the
    /// batcher (routing + observation) and by status snapshots; never
    /// held across a forward.
    canary: Mutex<Option<CanaryController>>,
}

impl Shared {
    fn shed_expired_requests(&self, q: &mut VecDeque<Request>, now: Instant) {
        if !q.iter().any(|r| matches!(r.deadline, Some(d) if now >= d)) {
            return; // common case: nothing expired, no churn
        }
        let pending: Vec<Request> = q.drain(..).collect();
        for r in pending {
            match r.deadline {
                Some(d) if now >= d => {
                    let waited = now.saturating_duration_since(r.enqueued);
                    let _ = r.resp.send(Err(ServeError::DeadlineExpired { waited }));
                    self.metrics.shed_expired.inc();
                    if let Some(t) = &self.trace {
                        t.emit(
                            "shed",
                            &[
                                ("id", Tv::U(r.id)),
                                ("reason", Tv::S("expired")),
                                ("waited_us", Tv::U(waited.as_micros() as u64)),
                            ],
                        );
                    }
                }
                _ => q.push_back(r),
            }
        }
    }
}

/// Runtime fault injectors threaded through the batcher.  Empty (and
/// every hook a no-op) outside chaos builds.
#[derive(Default)]
pub(crate) struct Injectors {
    #[cfg(feature = "chaos")]
    pub(crate) chaos: Option<Arc<super::chaos::Chaos>>,
}

impl Injectors {
    fn batch_stall(&self) -> Option<Duration> {
        #[cfg(feature = "chaos")]
        if let Some(c) = &self.chaos {
            return c.batch_stall();
        }
        None
    }

    fn forward_spike(&self, _is_canary: bool) -> Option<Duration> {
        #[cfg(feature = "chaos")]
        if let Some(c) = &self.chaos {
            return c.forward_spike(_is_canary);
        }
        None
    }

    fn maybe_forward_panic(&self) {
        #[cfg(feature = "chaos")]
        if let Some(c) = &self.chaos {
            c.maybe_forward_panic();
        }
    }
}

/// The serving endpoint: owns the batcher thread and resolves its
/// model through a [`ModelRegistry`] once per batch.  Dropping (or
/// calling [`Server::shutdown`]) drains the queue and joins the
/// batcher; requests still queued at shutdown are served, requests
/// submitted after it are rejected.
pub struct Server {
    shared: Arc<Shared>,
    registry: Arc<ModelRegistry>,
    din: usize,
    out_dim: usize,
    batcher: Option<JoinHandle<()>>,
}

/// Cheap cloneable submission handle (safe to share across client
/// threads).
#[derive(Clone)]
pub struct ServerHandle {
    shared: Arc<Shared>,
    din: usize,
}

impl Server {
    /// Convenience for single-model serving: wrap `net` in a fresh
    /// one-version registry and start.  The net should carry
    /// calibrated activation ranges ([`IntNet::is_calibrated`]);
    /// serving an uncalibrated net works but answers then depend on
    /// batch composition, which micro-batching makes nondeterministic.
    pub fn start(net: Arc<IntNet>, cfg: ServeConfig) -> Result<Self> {
        let registry = Arc::new(ModelRegistry::new(net, "initial")?);
        Self::start_registry(registry, cfg)
    }

    /// Spin up the batcher over an existing registry.  The registry
    /// stays shared: publishing to it while this server runs hot-swaps
    /// the model between batches with zero downtime.
    pub fn start_registry(registry: Arc<ModelRegistry>, cfg: ServeConfig) -> Result<Self> {
        Self::start_inner(
            registry,
            cfg,
            Injectors::default(),
            Arc::new(TelemetryRegistry::new()),
            None,
        )
    }

    /// [`Self::start_registry`] publishing into a caller-owned
    /// [`TelemetryRegistry`] (for sharing one scrape endpoint across
    /// servers) and optionally emitting lifecycle events into `trace`.
    pub fn start_observed(
        registry: Arc<ModelRegistry>,
        cfg: ServeConfig,
        telemetry: Arc<TelemetryRegistry>,
        trace: Option<Arc<TraceWriter>>,
    ) -> Result<Self> {
        Self::start_inner(registry, cfg, Injectors::default(), telemetry, trace)
    }

    /// [`Self::start_registry`] with a fault injector wired into the
    /// batcher and the engine's worker pool (chaos builds only).
    #[cfg(feature = "chaos")]
    pub fn start_chaos(
        registry: Arc<ModelRegistry>,
        cfg: ServeConfig,
        chaos: Arc<super::chaos::Chaos>,
    ) -> Result<Self> {
        Self::start_inner(
            registry,
            cfg,
            Injectors { chaos: Some(chaos) },
            Arc::new(TelemetryRegistry::new()),
            None,
        )
    }

    fn start_inner(
        registry: Arc<ModelRegistry>,
        cfg: ServeConfig,
        inj: Injectors,
        telemetry: Arc<TelemetryRegistry>,
        trace: Option<Arc<TraceWriter>>,
    ) -> Result<Self> {
        if cfg.max_batch == 0 || cfg.max_queue == 0 {
            bail!("serve: max_batch and max_queue must be at least 1");
        }
        let din = registry.input_dim();
        let out_dim = registry.out_dim();
        #[cfg(feature = "chaos")]
        let engine = ServeEngine::with_chaos(cfg.threads, inj.chaos.clone());
        #[cfg(not(feature = "chaos"))]
        let engine = ServeEngine::new(cfg.threads);
        let metrics = ServeMetrics::new(telemetry);
        engine.pool().publish_respawns(Arc::clone(&metrics.respawns));
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
            max_queue: cfg.max_queue,
            default_deadline: cfg.deadline,
            shed_policy: cfg.shed_policy,
            seq: AtomicU64::new(0),
            metrics,
            trace,
            canary: Mutex::new(None),
        });
        let shared2 = Arc::clone(&shared);
        let registry2 = Arc::clone(&registry);
        let batcher = std::thread::Builder::new()
            .name("bitprune-batcher".into())
            .spawn(move || batcher_loop(shared2, registry2, engine, cfg, out_dim, inj))
            .map_err(|e| anyhow!("serve: spawning batcher thread: {e}"))?;
        Ok(Self { shared, registry, din, out_dim, batcher: Some(batcher) })
    }

    pub fn handle(&self) -> ServerHandle {
        ServerHandle { shared: Arc::clone(&self.shared), din: self.din }
    }

    /// The registry this server resolves its model through — publish
    /// or roll back here to hot-swap what subsequent batches run.
    pub fn registry(&self) -> Arc<ModelRegistry> {
        Arc::clone(&self.registry)
    }

    /// Input dimensionality one request must carry.
    pub fn input_dim(&self) -> usize {
        self.din
    }

    /// Logits dimensionality one response carries.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// Stage `net` as a canary receiving `cfg.pct`% of traffic (see
    /// [`super::canary`]).  Returns the staged version id; the
    /// experiment then runs inside the batcher until it promotes or
    /// rolls back (watch [`Self::canary_status`] /
    /// [`ServeStats::promotions`] / [`ServeStats::rollbacks`]).
    pub fn start_canary(
        &self,
        net: Arc<IntNet>,
        label: &str,
        cfg: CanaryConfig,
    ) -> Result<u64> {
        cfg.validate().map_err(|m| anyhow!("serve: {m}"))?;
        let incumbent = self.registry.active_version();
        // begin_canary holds the canary slot in the *registry*; only
        // then install the controller (no canary mutex is held while
        // touching the registry, so lock order is batcher-compatible).
        let version = self.registry.begin_canary(net, label)?;
        let mut slot = self.shared.canary.lock().unwrap_or_else(|p| p.into_inner());
        *slot = Some(CanaryController::new(version, incumbent, cfg));
        Ok(version)
    }

    /// Snapshot of the current (or last resolved) canary experiment.
    pub fn canary_status(&self) -> Option<CanaryStatus> {
        self.shared
            .canary
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .as_ref()
            .map(|c| c.status())
    }

    pub fn stats(&self) -> ServeStats {
        let m = &self.shared.metrics;
        ServeStats {
            batches: m.batches.get(),
            requests: m.requests.get(),
            swaps: m.swaps.get(),
            shed_queue_full: m.shed_queue_full.get(),
            shed_expired: m.shed_expired.get(),
            failed: m.failed.get(),
            canary_requests: m.canary_requests.get(),
            promotions: m.promotions.get(),
            rollbacks: m.rollbacks.get(),
        }
    }

    /// The telemetry registry this server publishes into — hand it to a
    /// [`crate::telemetry::MetricsServer`] to expose `/metrics`, or
    /// snapshot it directly.  The [`ServeStats`] counters and the
    /// registry counters are the same atomics.
    pub fn telemetry(&self) -> Arc<TelemetryRegistry> {
        Arc::clone(&self.shared.metrics.registry)
    }

    /// Stop accepting work, serve what is queued, join the batcher.
    pub fn shutdown(mut self) -> ServeStats {
        self.stop();
        self.stats()
    }

    fn stop(&mut self) {
        // Flip the flag while holding the queue lock so the batcher
        // cannot check-then-sleep between our store and the notify.
        {
            let guard = self.shared.queue.lock();
            self.shared.shutdown.store(true, Ordering::SeqCst);
            drop(guard);
        }
        self.shared.cv.notify_all();
        if let Some(h) = self.batcher.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop();
    }
}

impl ServerHandle {
    /// Enqueue one sample; returns the channel its [`ServeResult`]
    /// arrives on.  Fails fast (typed) on wrong input length, a
    /// shut-down server, or a full queue.  The deadline is the
    /// server-wide default, if one is configured.
    pub fn submit(&self, x: Vec<f32>) -> std::result::Result<Receiver<ServeResult>, ServeError> {
        self.submit_inner(x, None)
    }

    /// [`Self::submit`] with an explicit absolute deadline: if the
    /// request is still queued at `deadline`, it is shed with
    /// [`ServeError::DeadlineExpired`] instead of served late.
    pub fn submit_with_deadline(
        &self,
        x: Vec<f32>,
        deadline: Instant,
    ) -> std::result::Result<Receiver<ServeResult>, ServeError> {
        self.submit_inner(x, Some(deadline))
    }

    fn submit_inner(
        &self,
        x: Vec<f32>,
        explicit_deadline: Option<Instant>,
    ) -> std::result::Result<Receiver<ServeResult>, ServeError> {
        if x.len() != self.din {
            return Err(ServeError::BadInput { got: x.len(), want: self.din });
        }
        let (tx, rx) = channel();
        {
            let mut q = self
                .shared
                .queue
                .lock()
                .map_err(|_| ServeError::Disconnected)?;
            // Check shutdown *under the queue lock*: stop() flips the
            // flag under this lock, so a request enqueued here is
            // guaranteed to be seen by the batcher's drain pass — no
            // window where a request slips in after the batcher exited
            // and blocks its caller forever.
            if self.shared.shutdown.load(Ordering::SeqCst) {
                return Err(ServeError::ShuttingDown);
            }
            let now = Instant::now();
            if q.len() >= self.shared.max_queue {
                // DropExpired: evict already-dead queue entries first;
                // they would be shed at dequeue anyway, and the slot
                // is better spent on an answerable request.
                if self.shared.shed_policy == ShedPolicy::DropExpired {
                    self.shared.shed_expired_requests(&mut q, now);
                }
                if q.len() >= self.shared.max_queue {
                    self.shared.metrics.shed_queue_full.inc();
                    if let Some(t) = &self.shared.trace {
                        t.emit(
                            "shed",
                            &[
                                ("reason", Tv::S("queue_full")),
                                ("queued", Tv::U(q.len() as u64)),
                            ],
                        );
                    }
                    return Err(ServeError::QueueFull { queued: q.len() });
                }
            }
            let deadline = explicit_deadline
                .or_else(|| self.shared.default_deadline.map(|d| now + d));
            let id = self.shared.seq.fetch_add(1, Ordering::Relaxed);
            q.push_back(Request { id, x, resp: tx, enqueued: now, deadline });
            self.shared.metrics.queue_depth.set(q.len() as f64);
            if let Some(t) = &self.shared.trace {
                t.emit("admit", &[("id", Tv::U(id)), ("queued", Tv::U(q.len() as u64))]);
            }
        }
        self.shared.cv.notify_all();
        Ok(rx)
    }

    /// Submit and block for the answer.
    pub fn infer(&self, x: Vec<f32>) -> std::result::Result<Vec<f32>, ServeError> {
        self.infer_versioned(x).map(|(_, logits)| logits)
    }

    /// Submit and block for the answer plus the registry version of
    /// the model that computed it (what the hot-swap tests and the
    /// `--swap-to` CLI demo key on).
    pub fn infer_versioned(
        &self,
        x: Vec<f32>,
    ) -> std::result::Result<(u64, Vec<f32>), ServeError> {
        match self.submit(x)?.recv() {
            Ok(Ok(r)) => Ok((r.version, r.logits)),
            Ok(Err(e)) => Err(e),
            Err(_) => Err(ServeError::Disconnected),
        }
    }

    /// [`Self::infer_versioned`] with bounded retry: retryable errors
    /// ([`ServeError::is_retryable`]) back off and try again up to
    /// `policy.max_attempts` total attempts; everything else returns
    /// immediately.
    pub fn infer_with_retry(
        &self,
        x: Vec<f32>,
        policy: &RetryPolicy,
    ) -> std::result::Result<(u64, Vec<f32>), ServeError> {
        let attempts = policy.max_attempts.max(1);
        let mut attempt = 0u32;
        loop {
            match self.infer_versioned(x.clone()) {
                Ok(r) => return Ok(r),
                Err(e) if e.is_retryable() && attempt + 1 < attempts => {
                    self.shared.metrics.retries.inc();
                    std::thread::sleep(policy.backoff(attempt));
                    attempt += 1;
                }
                Err(e) => return Err(e),
            }
        }
    }
}

/// Marks the server dead when the batcher exits for *any* reason —
/// including a panic unwinding out of the loop itself.  Sets the
/// shutdown flag, answers every still-queued request with a typed
/// [`ServeError::ShuttingDown`] (no caller blocks forever) and wakes
/// everyone.
struct BatcherGuard(Arc<Shared>);

impl Drop for BatcherGuard {
    fn drop(&mut self) {
        self.0.shutdown.store(true, Ordering::SeqCst);
        let mut q = match self.0.queue.lock() {
            Ok(q) => q,
            Err(poisoned) => poisoned.into_inner(),
        };
        for r in q.drain(..) {
            let _ = r.resp.send(Err(ServeError::ShuttingDown));
        }
        drop(q);
        self.0.cv.notify_all();
    }
}

/// One sub-batch forward: gather the rows, run (with chaos spikes /
/// injected panics applied inside the timed + caught region), return
/// the logits and wall time — or `None` if the forward panicked.
fn run_leg(
    engine: &mut ServeEngine,
    net: &IntNet,
    batch: &[Request],
    idxs: &[usize],
    gather: &mut Vec<f32>,
    inj: &Injectors,
    is_canary: bool,
) -> Option<(Vec<f32>, Duration)> {
    gather.clear();
    for &i in idxs {
        gather.extend_from_slice(&batch[i].x);
    }
    let t0 = Instant::now();
    if let Some(d) = inj.forward_spike(is_canary) {
        std::thread::sleep(d);
    }
    let out = catch_unwind(AssertUnwindSafe(|| {
        inj.maybe_forward_panic();
        engine.forward(net, gather, idxs.len()).to_vec()
    }));
    out.ok().map(|v| (v, t0.elapsed()))
}

/// Send `logits` rows back to the requests at `idxs`, tagged
/// `version`; returns how many were delivered.  Each delivery records
/// its enqueue-to-answer latency into the e2e histogram.
fn deliver(
    batch: &[Request],
    idxs: &[usize],
    logits: &[f32],
    out_dim: usize,
    version: u64,
    metrics: &ServeMetrics,
) -> u64 {
    for (row, &i) in logits.chunks_exact(out_dim).zip(idxs) {
        let r = &batch[i];
        metrics.e2e_latency.observe_secs(r.enqueued.elapsed().as_secs_f64());
        // A client that gave up (dropped its Receiver) is not an
        // error for the batch.
        let _ = r.resp.send(Ok(Response { version, logits: row.to_vec() }));
    }
    idxs.len() as u64
}

/// Answer the requests at `idxs` with a typed failure.
fn fail(batch: &[Request], idxs: &[usize], err: ServeError) {
    for &i in idxs {
        let _ = batch[i].resp.send(Err(err.clone()));
    }
}

fn batcher_loop(
    shared: Arc<Shared>,
    registry: Arc<ModelRegistry>,
    mut engine: ServeEngine,
    cfg: ServeConfig,
    out_dim: usize,
    inj: Injectors,
) {
    let _guard = BatcherGuard(Arc::clone(&shared));
    let mut gather: Vec<f32> = Vec::new();
    let mut batch: Vec<Request> = Vec::new();
    let mut last_version = 0u64;
    // Cached per-version canary agreement gauge (re-resolved on version
    // change only, so the steady state never locks the registry).
    let mut agree_gauge: Option<(u64, Arc<Gauge>)> = None;
    loop {
        batch.clear();
        // Chaos: a wedged batcher — requests age (and deadlines
        // expire) while it stalls.
        if let Some(d) = inj.batch_stall() {
            std::thread::sleep(d);
        }
        {
            let mut q = match shared.queue.lock() {
                Ok(g) => g,
                Err(_) => return,
            };
            // Wait for the first *live* request; exit only when shut
            // down AND drained (late-queued requests still get
            // served).  Expired requests are shed — typed, counted —
            // right here at dequeue, before they cost a batch slot.
            loop {
                while q.is_empty() {
                    if shared.shutdown.load(Ordering::SeqCst) {
                        return;
                    }
                    q = match shared.cv.wait(q) {
                        Ok(g) => g,
                        Err(_) => return,
                    };
                }
                shared.shed_expired_requests(&mut q, Instant::now());
                if !q.is_empty() {
                    break;
                }
            }
            // Dynamic micro-batching: flush at max_batch or when the
            // *oldest* request's latency deadline (its enqueue time
            // plus batch_window) expires — requests that queued while a
            // previous batch was computing have already burned part of
            // their window.
            let window_deadline = q
                .front()
                .map(|r| r.enqueued + cfg.batch_window)
                .expect("queue is non-empty here");
            while q.len() < cfg.max_batch && !shared.shutdown.load(Ordering::SeqCst) {
                let now = Instant::now();
                if now >= window_deadline {
                    break;
                }
                q = match shared.cv.wait_timeout(q, window_deadline - now) {
                    Ok((g, _)) => g,
                    Err(_) => return,
                };
            }
            // Deadlines may have expired during the coalescing wait.
            shared.shed_expired_requests(&mut q, Instant::now());
            let take = q.len().min(cfg.max_batch);
            batch.extend(q.drain(..take));
            shared.metrics.queue_depth.set(q.len() as f64);
        } // queue unlocked before the forward: submitters never block on compute
        if batch.is_empty() {
            continue; // everything shed while coalescing
        }
        shared.metrics.batch_size.observe(batch.len() as u64);
        // Resolve the model once per batch: the whole batch runs on one
        // version, and holding the Arc is what gives a concurrent
        // publish its drain semantics.
        let active = registry.current();
        if last_version != 0 && active.version != last_version {
            shared.metrics.swaps.inc();
            if let Some(t) = &shared.trace {
                t.emit(
                    "swap",
                    &[("from", Tv::U(last_version)), ("to", Tv::U(active.version))],
                );
            }
        }
        last_version = active.version;

        // Canary routing: partition the batch by hashed request id.
        // The slot lock is held only for the partition (and later the
        // observation) — never across a forward.
        let mut canary_idx: Vec<usize> = Vec::new();
        let canary_split: Option<(u64, Arc<IntNet>)> = {
            let slot = shared.canary.lock().unwrap_or_else(|p| p.into_inner());
            slot.as_ref().filter(|c| c.active()).and_then(|c| {
                registry.get(c.canary_version()).ok().map(|mv| {
                    canary_idx.extend(
                        batch
                            .iter()
                            .enumerate()
                            .filter(|(_, r)| c.routes_to_canary(r.id))
                            .map(|(i, _)| i),
                    );
                    (c.canary_version(), Arc::clone(&mv.net))
                })
            })
        };
        let incumbent_idx: Vec<usize> = (0..batch.len())
            .filter(|i| !canary_idx.contains(i))
            .collect();

        let mut served = 0u64;
        // Incumbent leg.
        let mut incumbent_lat = None;
        if !incumbent_idx.is_empty() {
            match run_leg(
                &mut engine,
                &active.net,
                &batch,
                &incumbent_idx,
                &mut gather,
                &inj,
                false,
            ) {
                Some((logits, dur)) => {
                    served += deliver(
                        &batch,
                        &incumbent_idx,
                        &logits,
                        out_dim,
                        active.version,
                        &shared.metrics,
                    );
                    incumbent_lat = per_sample_secs(dur, incumbent_idx.len());
                }
                None => {
                    fail(&batch, &incumbent_idx, ServeError::WorkerPanic);
                    shared.metrics.failed.add(incumbent_idx.len() as u64);
                }
            }
        }
        // Canary leg + incumbent shadow for agreement.
        let mut canary_lat = None;
        let mut canary_served = 0u64;
        let mut agreements = 0u64;
        let mut compared = 0u64;
        if let Some((cv, cnet)) = &canary_split {
            let cv = *cv;
            if !canary_idx.is_empty() {
                match run_leg(&mut engine, cnet, &batch, &canary_idx, &mut gather, &inj, true)
                {
                    Some((clogits, cdur)) => {
                        canary_served = deliver(
                            &batch,
                            &canary_idx,
                            &clogits,
                            out_dim,
                            cv,
                            &shared.metrics,
                        );
                        served += canary_served;
                        shared.metrics.canary_requests.add(canary_served);
                        canary_lat = per_sample_secs(cdur, canary_idx.len());
                        // Shadow the same rows on the incumbent for
                        // online agreement.  Its latency feeds the
                        // incumbent reservoir too (same work, same
                        // side); a shadow panic just skips agreement
                        // for this batch — the clients already have
                        // their canary answers.
                        if let Some((slogits, sdur)) = run_leg(
                            &mut engine,
                            &active.net,
                            &batch,
                            &canary_idx,
                            &mut gather,
                            &inj,
                            false,
                        ) {
                            let want = argmax_rows(&slogits, out_dim);
                            let got = argmax_rows(&clogits, out_dim);
                            compared = got.len() as u64;
                            agreements =
                                got.iter().zip(&want).filter(|(a, b)| a == b).count()
                                    as u64;
                            if incumbent_lat.is_none() {
                                incumbent_lat = per_sample_secs(sdur, canary_idx.len());
                            }
                        }
                    }
                    None => {
                        fail(&batch, &canary_idx, ServeError::WorkerPanic);
                        shared.metrics.failed.add(canary_idx.len() as u64);
                    }
                }
            }
        }
        // Feed the controller and apply any promotion/rollback through
        // the registry's atomic swap (lock order: canary slot, then
        // registry — nothing takes them in the other order while
        // nested).
        if canary_split.is_some() {
            let mut slot = shared.canary.lock().unwrap_or_else(|p| p.into_inner());
            if let Some(ctrl) = slot.as_mut().filter(|c| c.active()) {
                ctrl.observe(
                    incumbent_lat,
                    canary_lat,
                    canary_served,
                    agreements,
                    compared,
                );
                let version = ctrl.canary_version();
                // Publish the running argmax agreement as a per-version
                // gauge; the handle is cached while the version is
                // stable so the registry lock is only taken on change.
                if let Some(agreement) = ctrl.agreement() {
                    if agree_gauge.as_ref().map(|(v, _)| *v) != Some(version) {
                        agree_gauge = Some((
                            version,
                            shared.metrics.registry.gauge(
                                "canary_agreement",
                                &[("version", &version.to_string())],
                            ),
                        ));
                    }
                    if let Some((_, g)) = &agree_gauge {
                        g.set(agreement);
                    }
                }
                match ctrl.evaluate() {
                    Some(CanaryDecision::Promote) => match registry.promote_canary(version) {
                        Ok(()) => {
                            shared.metrics.promotions.inc();
                            if let Some(t) = &shared.trace {
                                t.emit("promote", &[("version", Tv::U(version))]);
                            }
                            ctrl.resolve(CanaryOutcome::Promoted { version });
                        }
                        Err(e) => {
                            // Registry refused (raced with an operator
                            // action): end the experiment safely on
                            // the incumbent.
                            let _ = registry.end_canary(version);
                            let reason = format!("promotion refused: {e}");
                            shared.metrics.rollbacks.inc();
                            if let Some(t) = &shared.trace {
                                t.emit(
                                    "rollback",
                                    &[("version", Tv::U(version)), ("reason", Tv::S(&reason))],
                                );
                            }
                            ctrl.resolve(CanaryOutcome::RolledBack { version, reason });
                        }
                    },
                    Some(CanaryDecision::Rollback { reason }) => {
                        let _ = registry.end_canary(version);
                        shared.metrics.rollbacks.inc();
                        if let Some(t) = &shared.trace {
                            t.emit(
                                "rollback",
                                &[("version", Tv::U(version)), ("reason", Tv::S(&reason))],
                            );
                        }
                        ctrl.resolve(CanaryOutcome::RolledBack { version, reason });
                    }
                    None => {}
                }
            }
        }
        shared.metrics.batches.inc();
        shared.metrics.requests.add(served);
        if let Some(t) = &shared.trace {
            t.emit(
                "batch",
                &[
                    ("size", Tv::U(batch.len() as u64)),
                    ("served", Tv::U(served)),
                    ("version", Tv::U(active.version)),
                    ("canary_served", Tv::U(canary_served)),
                ],
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::synthetic_net;
    use crate::util::rng::Rng;

    fn small_net() -> Arc<IntNet> {
        Arc::new(synthetic_net(&[6, 14, 3], 0x5EED, 4, 6))
    }

    fn same(a: &[f32], b: &[f32]) -> bool {
        a.len() == b.len() && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn served_answers_match_solo_forward_bitwise() {
        // The heart of the batch-invariance guarantee at the server
        // level: whatever coalescing happens inside, each answer equals
        // the sample's solo forward, bit for bit.
        let net = small_net();
        let server = Server::start(
            Arc::clone(&net),
            ServeConfig {
                threads: 2,
                max_batch: 8,
                batch_window: Duration::from_millis(5),
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let handle = server.handle();
        let mut rng = Rng::new(42);
        let samples: Vec<Vec<f32>> = (0..40)
            .map(|_| (0..6).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let pending: Vec<_> = samples
            .iter()
            .map(|s| handle.submit(s.clone()).unwrap())
            .collect();
        for (s, rx) in samples.iter().zip(pending) {
            let got = rx.recv().unwrap().expect("request served");
            assert_eq!(got.version, 1, "single-model server serves version 1");
            let want = net.forward(s, 1);
            assert_eq!(got.logits.len(), want.len());
            assert!(
                same(&got.logits, &want),
                "served answer differs from solo forward"
            );
        }
        let stats = server.shutdown();
        assert_eq!(stats.requests, 40);
        assert!(stats.batches >= 5, "max_batch 8 over 40 requests => >= 5 batches");
        assert!(stats.mean_batch() >= 1.0);
        assert_eq!(stats.swaps, 0);
        assert_eq!(stats.shed(), 0);
        assert_eq!(stats.failed, 0);
    }

    #[test]
    fn window_flush_serves_partial_batches() {
        // Fewer requests than max_batch must still be answered once the
        // latency deadline passes.
        let server = Server::start(
            small_net(),
            ServeConfig {
                threads: 1,
                max_batch: 64,
                batch_window: Duration::from_millis(2),
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let handle = server.handle();
        let out = handle.infer(vec![0.5; 6]).unwrap();
        assert_eq!(out.len(), 3);
        let stats = server.shutdown();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.batches, 1);
    }

    #[test]
    fn degenerate_inputs_are_served() {
        // Constant batches and all-zero (post-ReLU-like) inputs must
        // not divide by zero or poison the batcher.
        let server = Server::start(small_net(), ServeConfig::default()).unwrap();
        let handle = server.handle();
        for x in [vec![0.0f32; 6], vec![1.0f32; 6], vec![-7.5f32; 6]] {
            let out = handle.infer(x).unwrap();
            assert_eq!(out.len(), 3);
            assert!(out.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn submit_validates_and_shutdown_rejects_typed() {
        let server = Server::start(small_net(), ServeConfig::default()).unwrap();
        let handle = server.handle();
        assert_eq!(
            handle.submit(vec![0.0; 5]).err(),
            Some(ServeError::BadInput { got: 5, want: 6 })
        );
        server.shutdown();
        assert_eq!(
            handle.infer(vec![0.0; 6]).err(),
            Some(ServeError::ShuttingDown)
        );
        assert!(!ServeError::ShuttingDown.is_retryable());
    }

    #[test]
    fn start_rejects_bad_configs() {
        let empty = Arc::new(IntNet { layers: vec![], num_classes: 0 });
        assert!(Server::start(empty, ServeConfig::default()).is_err());
        let cfg = ServeConfig { max_batch: 0, ..ServeConfig::default() };
        assert!(Server::start(small_net(), cfg).is_err());
        let cfg = ServeConfig { max_queue: 0, ..ServeConfig::default() };
        assert!(Server::start(small_net(), cfg).is_err());
    }

    #[test]
    fn queue_backpressure_rejects_overflow() {
        // max_batch and window both out of reach: nothing drains until
        // shutdown, so the 9th submission must hit the max_queue bound
        // deterministically instead of growing the queue without limit.
        let server = Server::start(
            small_net(),
            ServeConfig {
                threads: 1,
                max_batch: 64,
                batch_window: Duration::from_secs(30),
                max_queue: 8,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let handle = server.handle();
        let _pending: Vec<_> = (0..8)
            .map(|_| handle.submit(vec![0.1; 6]).unwrap())
            .collect();
        let err = handle.submit(vec![0.1; 6]).unwrap_err();
        assert_eq!(err, ServeError::QueueFull { queued: 8 });
        assert!(err.is_retryable() && err.is_shed());
        assert!(err.to_string().contains("queue full"), "{err}");
        assert_eq!(server.stats().shed_queue_full, 1);
        // Shutdown still drains and answers the queued 8 without
        // waiting out the 30s window.
        let stats = server.shutdown();
        assert_eq!(stats.requests, 8);
    }

    #[test]
    fn expired_requests_are_shed_typed_not_served() {
        // A long stall (no batcher progress possible: the window is
        // long and max_batch unreachable) lets a deadline lapse; the
        // batcher must shed it at dequeue, typed and counted, while
        // serving the live request that follows.
        let server = Server::start(
            small_net(),
            ServeConfig {
                threads: 1,
                max_batch: 64,
                batch_window: Duration::from_millis(30),
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let handle = server.handle();
        // Deadline already in the past: shed deterministically.
        let rx = handle
            .submit_with_deadline(vec![0.2; 6], Instant::now() - Duration::from_millis(1))
            .unwrap();
        match rx.recv().unwrap() {
            Err(ServeError::DeadlineExpired { .. }) => {}
            other => panic!("expected deadline shed, got {other:?}"),
        }
        // A live request right behind it is served normally.
        let out = handle.infer(vec![0.2; 6]).unwrap();
        assert_eq!(out.len(), 3);
        let stats = server.shutdown();
        assert_eq!(stats.shed_expired, 1);
        assert_eq!(stats.requests, 1);
    }

    #[test]
    fn default_deadline_applies_to_plain_submits() {
        // With a server-wide deadline shorter than the batch window,
        // a queued request expires before the window flush and is
        // shed; stats count it.
        let server = Server::start(
            small_net(),
            ServeConfig {
                threads: 1,
                max_batch: 64,
                batch_window: Duration::from_millis(200),
                deadline: Some(Duration::from_millis(5)),
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let handle = server.handle();
        let rx = handle.submit(vec![0.3; 6]).unwrap();
        match rx.recv().unwrap() {
            Err(ServeError::DeadlineExpired { waited }) => {
                assert!(waited >= Duration::from_millis(5));
            }
            other => panic!("expected deadline shed, got {other:?}"),
        }
        let stats = server.shutdown();
        assert_eq!(stats.shed_expired, 1);
        assert_eq!(stats.requests, 0);
    }

    #[test]
    fn drop_expired_policy_makes_room_at_admission() {
        // Queue full of already-expired requests: RejectNewest would
        // bounce the newcomer, DropExpired sheds the dead entries and
        // admits it.
        let server = Server::start(
            small_net(),
            ServeConfig {
                threads: 1,
                max_batch: 64,
                batch_window: Duration::from_secs(30),
                max_queue: 4,
                shed_policy: ShedPolicy::DropExpired,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let handle = server.handle();
        let dead = Instant::now() - Duration::from_millis(1);
        let pending: Vec<_> = (0..4)
            .map(|_| handle.submit_with_deadline(vec![0.1; 6], dead).unwrap())
            .collect();
        // 5th submission: admission evicts the 4 expired entries.
        let live = handle.submit(vec![0.1; 6]).unwrap();
        for rx in pending {
            match rx.recv().unwrap() {
                Err(ServeError::DeadlineExpired { .. }) => {}
                other => panic!("expected shed, got {other:?}"),
            }
        }
        let stats = server.stats();
        assert_eq!(stats.shed_expired, 4);
        assert_eq!(stats.shed_queue_full, 0);
        drop(live);
        server.shutdown();
    }

    #[test]
    fn retry_policy_backoff_is_bounded_and_deterministic() {
        let p = RetryPolicy::default();
        for k in 0..8 {
            let d = p.backoff(k);
            assert_eq!(d, p.backoff(k), "jitter must be deterministic per attempt");
            // Cap × max jitter bounds every backoff.
            assert!(d <= p.cap.mul_f64(1.5), "attempt {k}: {d:?}");
            assert!(d >= p.base.mul_f64(0.5), "attempt {k}: {d:?}");
        }
        // Exponential growth before the cap bites.
        assert!(p.backoff(3) > p.backoff(0));
    }

    #[test]
    fn infer_with_retry_recovers_from_backpressure() {
        // Tiny queue + steady drain: direct submits can hit QueueFull,
        // but the retrying client always lands.
        let server = Server::start(
            small_net(),
            ServeConfig {
                threads: 1,
                max_batch: 2,
                batch_window: Duration::from_micros(100),
                max_queue: 2,
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let handle = server.handle();
        let policy = RetryPolicy { max_attempts: 16, ..RetryPolicy::default() };
        std::thread::scope(|scope| {
            for _ in 0..4 {
                let h = handle.clone();
                let p = policy.clone();
                scope.spawn(move || {
                    for _ in 0..25 {
                        let (_, logits) =
                            h.infer_with_retry(vec![0.4; 6], &p).expect("retry exhausted");
                        assert_eq!(logits.len(), 3);
                    }
                });
            }
        });
        let stats = server.shutdown();
        assert_eq!(stats.requests, 100, "every retried request eventually served");
    }

    #[test]
    fn shed_policy_parses_operator_strings() {
        assert_eq!(ShedPolicy::parse("reject-newest"), Some(ShedPolicy::RejectNewest));
        assert_eq!(ShedPolicy::parse("drop-expired"), Some(ShedPolicy::DropExpired));
        assert_eq!(ShedPolicy::parse("bogus"), None);
        assert_eq!(ShedPolicy::RejectNewest.name(), "reject-newest");
        assert_eq!(ShedPolicy::default(), ShedPolicy::RejectNewest);
    }

    #[test]
    fn registry_publish_retargets_subsequent_requests() {
        // Sequential requests around a publish: answers before the
        // swap carry version 1 and match net A; answers after carry
        // version 2 and match net B (the post-drain property, in its
        // deterministic single-threaded form — the concurrent version
        // lives in tests/deploy_hotswap.rs).
        let a = small_net();
        let b = Arc::new(synthetic_net(&[6, 14, 3], 0xB0B, 4, 6));
        let registry =
            Arc::new(crate::deploy::ModelRegistry::new(Arc::clone(&a), "a").unwrap());
        let server = Server::start_registry(
            Arc::clone(&registry),
            ServeConfig {
                threads: 1,
                max_batch: 4,
                batch_window: Duration::from_millis(1),
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let handle = server.handle();
        let x = vec![0.3f32; 6];

        let (v, logits) = handle.infer_versioned(x.clone()).unwrap();
        assert_eq!(v, 1);
        let want_a = a.forward(&x, 1);
        assert!(same(&logits, &want_a));

        registry.publish(Arc::clone(&b), "b").unwrap();
        let (v, logits) = handle.infer_versioned(x.clone()).unwrap();
        assert_eq!(v, 2, "post-publish requests must run on the new version");
        let want_b = b.forward(&x, 1);
        assert!(same(&logits, &want_b));

        // Rollback retargets again.
        registry.rollback(1).unwrap();
        let (v, logits) = handle.infer_versioned(x.clone()).unwrap();
        assert_eq!(v, 1);
        assert!(same(&logits, &want_a));

        let stats = server.shutdown();
        assert_eq!(stats.requests, 3);
        assert_eq!(stats.swaps, 2, "publish + rollback each count as one swap");
    }

    #[test]
    fn canary_split_tags_versions_and_bitwise_matches_each_side() {
        // A 50% canary over async traffic: every response is tagged
        // with the version whose solo forward it bit-matches, both
        // sides serve, and the split follows the deterministic hash.
        let a = small_net();
        let b = Arc::new(synthetic_net(&[6, 14, 3], 0xCAFE, 4, 6));
        let registry =
            Arc::new(crate::deploy::ModelRegistry::new(Arc::clone(&a), "a").unwrap());
        let server = Server::start_registry(
            Arc::clone(&registry),
            ServeConfig {
                threads: 1,
                max_batch: 8,
                batch_window: Duration::from_micros(200),
                ..ServeConfig::default()
            },
        )
        .unwrap();
        let cv = server
            .start_canary(
                Arc::clone(&b),
                "candidate",
                CanaryConfig {
                    pct: 50,
                    window: 1_000_000, // never closes: pure split test
                    ..CanaryConfig::default()
                },
            )
            .unwrap();
        assert_eq!(cv, 2);
        assert_eq!(registry.active_version(), 1, "canary is staged, not active");
        let handle = server.handle();
        let mut rng = Rng::new(0xD0);
        let samples: Vec<Vec<f32>> = (0..64)
            .map(|_| (0..6).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let pending: Vec<_> = samples
            .iter()
            .map(|s| handle.submit(s.clone()).unwrap())
            .collect();
        let mut on_canary = 0u64;
        for (s, rx) in samples.iter().zip(pending) {
            let got = rx.recv().unwrap().expect("served");
            match got.version {
                1 => assert!(same(&got.logits, &a.forward(s, 1))),
                2 => {
                    assert!(same(&got.logits, &b.forward(s, 1)));
                    on_canary += 1;
                }
                v => panic!("impossible version {v}"),
            }
        }
        assert!(on_canary > 0, "canary must see traffic at 50%");
        assert!(on_canary < 64, "canary must not see all traffic");
        let status = server.canary_status().expect("experiment in flight");
        assert_eq!(status.served, on_canary);
        assert_eq!(status.canary_version, 2);
        assert!(status.outcome.is_none());
        let stats = server.shutdown();
        assert_eq!(stats.requests, 64);
        assert_eq!(stats.canary_requests, on_canary);
    }

    #[test]
    fn second_canary_while_active_is_refused() {
        let a = small_net();
        let server = Server::start(Arc::clone(&a), ServeConfig::default()).unwrap();
        server
            .start_canary(Arc::clone(&a), "c1", CanaryConfig::default())
            .unwrap();
        assert!(server
            .start_canary(Arc::clone(&a), "c2", CanaryConfig::default())
            .is_err());
        // Bad operator config is refused before touching the registry.
        let sv = Server::start(Arc::clone(&a), ServeConfig::default()).unwrap();
        assert!(sv
            .start_canary(a, "bad", CanaryConfig { pct: 0, ..CanaryConfig::default() })
            .is_err());
        assert_eq!(sv.registry().canary_version(), None);
    }
}
