//! Bit-packed tensor storage: the memory half of the paper's claims,
//! made concrete.
//!
//! The accelerator table (Table VIII) credits designs like Proteus [15]
//! with storing each layer at its learned bitlength.  This module *is*
//! that storage layer: it encodes a fake-quantized f32 tensor into
//! `n`-bit integer codes (LSB-first contiguous bit stream, no padding
//! between values) plus the `(lmin, scale)` dequantization header, and
//! decodes it back bit-exactly.
//!
//! Lossless property: for a tensor that is *already* n-bit quantized
//! (the output of `quant::fake_quant_slice` at integer n), pack → unpack
//! reproduces the input exactly (up to f32 rounding in the affine map,
//! verified ≤ 1 ulp-scale epsilon in tests).  This is what lets the
//! coordinator checkpoint quantized networks at their true footprint,
//! and what the Proteus row of Table VIII measures.
//!
//! Fast path: [`pack`] fuses quantization and packing in one pass over
//! a word-level (u64 accumulator) bit stream, and [`unpack`] /
//! [`unpack_codes`] extract each value from a single 64-bit load
//! (`bits <= 16` always fits).  The original byte-at-a-time scalar
//! implementations are retained as [`pack_ref`] / [`unpack_ref`] /
//! [`unpack_codes_ref`]; the parity tests pin the fast path to them
//! bit-for-bit.

use anyhow::{bail, Result};

use crate::quant;
use crate::quant::Codebook;

/// A bit-packed quantized tensor.
///
/// With a non-uniform [`Codebook`] the payload stores **(sign,
/// exponent) fields** instead of raw grid codes — see [`field_bits`] —
/// but `bits`, `lmin`, `scale` keep their grid meaning: decoding a
/// field always yields an unsigned grid code `c ∈ [0, 2^bits − 1]`
/// with `value = lmin + c·scale`.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedTensor {
    /// Grid bitlength (1..=16) — the code range, not the stored width.
    pub bits: u32,
    /// Code restriction; decides the stored field encoding.
    pub codebook: Codebook,
    /// Number of encoded values.
    pub len: usize,
    /// Dequantization: value = lmin + code * scale.
    pub lmin: f32,
    pub scale: f32,
    /// LSB-first packed codes (uniform) or codebook fields.
    pub data: Vec<u8>,
}

/// Serialized header of one packed tensor: bits, len, lmin, scale
/// (4 × 4 bytes).  Every footprint number in the crate uses the same
/// convention: payload **plus** this header ([`PackedTensor::stored_bytes`]).
pub const HEADER_BYTES: usize = 16;

// ---------------------------------------------------------------------------
// codebook field encoding: (sign, exponent) / (sign, exp1, exp2)
// ---------------------------------------------------------------------------

/// Bits of one exponent index at grid bitlength `bits`: indices run
/// `0` (magnitude 0) through `emax + 1` (magnitude `2^emax`), so
/// `ceil(log2(emax + 2))` bits.
pub fn idx_bits(bits: u32) -> u32 {
    let values = quant::codebook_emax(bits) + 2;
    32 - (values - 1).leading_zeros()
}

/// Stored width of one value under a codebook: raw grid codes for
/// [`Codebook::Uniform`], `[sign | idx]` for PoT, `[sign | idx1 |
/// idx2]` for APoT.  At 8 grid bits a PoT field is 4 bits (2× denser
/// than uniform); an APoT field at 4 grid bits is 5 (> 4 — APoT is a
/// *compute* win, not always a storage win).
pub fn field_bits(cbk: Codebook, bits: u32) -> u32 {
    match cbk {
        Codebook::Uniform => bits,
        Codebook::PowerOfTwo => 1 + idx_bits(bits),
        Codebook::AdditivePot2 => 1 + 2 * idx_bits(bits),
    }
}

/// Magnitude of one exponent index (`0 → 0`, `k → 2^(k−1)`).
#[inline]
fn idx_mag(idx: u32) -> u32 {
    if idx == 0 {
        0
    } else {
        1 << (idx - 1)
    }
}

/// Encode a **codebook-admissible** grid code as a storage field.
/// Layout (LSB-first): PoT `[idx | sign]`→ `(sign << ib) | idx`; APoT
/// `(sign << 2·ib) | (idx1 << ib) | idx2` with the canonical form
/// `idx2 == 0 || idx2 < idx1` (a single power never encodes as a
/// doubled smaller one).  Callers must project first; debug-asserted.
fn encode_field(cbk: Codebook, bits: u32, code: u32) -> u64 {
    let half = 1u32 << (bits - 1);
    let c_s = code as i64 - half as i64;
    let sign = (c_s < 0) as u64;
    let m = c_s.unsigned_abs() as u32;
    let ib = idx_bits(bits);
    match cbk {
        Codebook::Uniform => code as u64,
        Codebook::PowerOfTwo => {
            debug_assert!(m == 0 || m.is_power_of_two(), "non-PoT magnitude {m}");
            let idx = if m == 0 { 0 } else { m.trailing_zeros() + 1 } as u64;
            (sign << ib) | idx
        }
        Codebook::AdditivePot2 => {
            debug_assert!(m.count_ones() <= 2, "non-APoT magnitude {m}");
            let (i1, i2) = if m == 0 {
                (0u64, 0u64)
            } else {
                let hi = 31 - m.leading_zeros();
                let rest = m - (1 << hi);
                let lo = if rest == 0 { 0 } else { rest.trailing_zeros() + 1 };
                ((hi + 1) as u64, lo as u64)
            };
            (sign << (2 * ib)) | (i1 << ib) | i2
        }
    }
}

/// Decode one storage field back to an unsigned grid code, validating
/// every invariant (index ranges, canonical APoT form, sign-of-zero,
/// code range) — `None` marks a hostile or corrupt field.
fn decode_field(cbk: Codebook, bits: u32, field: u64) -> Option<u32> {
    let half = 1i64 << (bits - 1);
    let emax = quant::codebook_emax(bits);
    let ib = idx_bits(bits);
    let (sign, mag) = match cbk {
        Codebook::Uniform => return Some(field as u32),
        Codebook::PowerOfTwo => {
            let idx = (field & ((1 << ib) - 1)) as u32;
            if idx > emax + 1 {
                return None;
            }
            ((field >> ib) & 1, idx_mag(idx))
        }
        Codebook::AdditivePot2 => {
            let i2 = (field & ((1 << ib) - 1)) as u32;
            let i1 = ((field >> ib) & ((1 << ib) - 1)) as u32;
            if i1 > emax + 1 || i2 > emax + 1 {
                return None;
            }
            // Canonical: a second exponent must be strictly smaller
            // (i1 == i2 would alias the doubled power 2^(i1−1+1)).
            if i2 != 0 && i2 >= i1 {
                return None;
            }
            ((field >> (2 * ib)) & 1, idx_mag(i1) + idx_mag(i2))
        }
    };
    if mag == 0 && sign != 0 {
        return None; // negative zero is non-canonical
    }
    let c_s = if sign != 0 { -(mag as i64) } else { mag as i64 };
    if c_s < -half || c_s > half - 1 {
        return None; // would fall outside the grid (n = 1 positive edge)
    }
    Some((half + c_s) as u32)
}

impl PackedTensor {
    /// Reassemble a packed tensor from **untrusted** stored parts (the
    /// BPMA artifact loader): validates the bitlength range, that
    /// `len * bits` does not overflow, that the payload is exactly the
    /// implied size (the unpackers zero-pad short buffers rather than
    /// panic, which would silently decode truncated codes as zeros),
    /// and that the dequantization header is finite with positive step.
    pub fn from_raw(
        bits: u32,
        len: usize,
        lmin: f32,
        scale: f32,
        data: Vec<u8>,
    ) -> Result<Self> {
        Self::from_raw_cbk(bits, Codebook::Uniform, len, lmin, scale, data)
    }

    /// [`Self::from_raw`] under a codebook: the payload is sized in
    /// [`field_bits`]-wide fields, and for a non-uniform codebook
    /// **every field is walked and validated** (index ranges, canonical
    /// APoT form, sign-of-zero, grid range) — a spliced or bit-flipped
    /// payload is rejected here, not decoded into silent garbage.
    pub fn from_raw_cbk(
        bits: u32,
        codebook: Codebook,
        len: usize,
        lmin: f32,
        scale: f32,
        data: Vec<u8>,
    ) -> Result<Self> {
        // Validate the header fields for empty tensors too — an
        // out-of-range `bits` or NaN plan must never enter the crate,
        // whatever the length says.
        if !(1..=16).contains(&bits) {
            bail!("packed tensor: bits must be in [1,16], got {bits}");
        }
        if !lmin.is_finite() || !scale.is_finite() || scale <= 0.0 {
            bail!("packed tensor: bad dequant header (lmin {lmin}, scale {scale})");
        }
        if len == 0 {
            if !data.is_empty() {
                bail!("packed tensor: empty tensor with {} payload bytes", data.len());
            }
            return Ok(Self { bits, codebook, len, lmin, scale, data });
        }
        let fb = field_bits(codebook, bits);
        let total_bits = len
            .checked_mul(fb as usize)
            .ok_or_else(|| anyhow::anyhow!("packed tensor: {len} x {fb} bits overflows"))?;
        let want = total_bits.div_ceil(8);
        if data.len() != want {
            bail!(
                "packed tensor: payload is {} bytes, {len} x {fb}-bit fields need {want}",
                data.len()
            );
        }
        if codebook != Codebook::Uniform {
            let mask = (1u64 << fb) - 1;
            for i in 0..len {
                let bitpos = i * fb as usize;
                let field = (load_word(&data, bitpos >> 3) >> (bitpos & 7)) & mask;
                if decode_field(codebook, bits, field).is_none() {
                    bail!(
                        "packed tensor: field {i} ({field:#x}) is not a valid \
                         {} code at {bits} bits",
                        codebook.name()
                    );
                }
            }
            // Trailing pad bits past the last field must be zero — a
            // corrupted tail is corruption even when unused.
            let used = total_bits % 8;
            if used != 0 && data[want - 1] >> used != 0 {
                bail!("packed tensor: nonzero pad bits after the last field");
            }
        }
        Ok(Self { bits, codebook, len, lmin, scale, data })
    }

    /// Packed payload size in bytes (excluding the fixed header).
    pub fn payload_bytes(&self) -> usize {
        self.data.len()
    }

    /// Stored size in bytes: payload plus the [`HEADER_BYTES`] header —
    /// the single footprint convention shared with
    /// [`pack_network`] and `infer::IntDense::packed_bytes`.
    pub fn stored_bytes(&self) -> usize {
        self.data.len() + HEADER_BYTES
    }

    /// Compression ratio vs f32 storage, header included (same
    /// convention as [`Self::stored_bytes`]).
    pub fn ratio_vs_f32(&self) -> f64 {
        (self.len * 4) as f64 / self.stored_bytes() as f64
    }
}

/// Quantize (min/max uniform, integer bitlength) and pack in one
/// **fused single pass**: the code math (plan-hoisted scale, one
/// division + round per element) streams straight into a 64-bit
/// accumulator that is flushed as whole little-endian words, so no
/// intermediate code buffer and no per-value byte read-modify-write.
///
/// Byte-stream layout is identical to [`pack_ref`] (LSB-first,
/// contiguous, no padding between values) — checked bit-for-bit by the
/// `fastpath_parity` tests.
///
/// Returns the packed tensor; `xs` is not modified.  `bits` must be an
/// integer in [1, 16] — packing interpolated non-integer bitlengths is
/// meaningless (inference hardware stores integer codes; §II-C).
pub fn pack(xs: &[f32], bits: u32) -> Result<PackedTensor> {
    if !(1..=16).contains(&bits) {
        bail!("pack: bits must be in [1,16], got {bits}");
    }
    let cbk = Codebook::Uniform;
    if xs.is_empty() {
        return Ok(PackedTensor { bits, codebook: cbk, len: 0, lmin: 0.0, scale: 1.0, data: vec![] });
    }
    let (lmin, lmax) = quant::group_minmax(xs);
    let plan = quant::QuantPlan::new(lmin, lmax, bits as f32);
    let levels = ((1u32 << bits) - 1) as i64;

    let total_bits = xs.len() * bits as usize;
    let mut data = vec![0u8; total_bits.div_ceil(8)];
    let mut acc = 0u64;
    let mut fill = 0u32;
    let mut out = 0usize; // next byte to write
    for &x in xs {
        let code = plan.code(x, levels) as u64;
        acc |= code << fill;
        fill += bits;
        if fill >= 64 {
            data[out..out + 8].copy_from_slice(&acc.to_le_bytes());
            out += 8;
            fill -= 64;
            acc = if fill > 0 { code >> (bits - fill) } else { 0 };
        }
    }
    if fill > 0 {
        let nbytes = fill.div_ceil(8) as usize;
        data[out..out + nbytes].copy_from_slice(&acc.to_le_bytes()[..nbytes]);
    }
    Ok(PackedTensor { bits, codebook: cbk, len: xs.len(), lmin: plan.lmin, scale: plan.s_lo, data })
}

/// Codebook-aware fused pack: quantize to the grid, **project** each
/// code onto the codebook, encode it as a (sign, exponent) field and
/// stream the fields through the same word-level accumulator as
/// [`pack`].  `Uniform` delegates to [`pack`] — byte-identical output.
pub fn pack_cbk(xs: &[f32], bits: u32, cbk: Codebook) -> Result<PackedTensor> {
    if cbk == Codebook::Uniform {
        return pack(xs, bits);
    }
    if !(1..=16).contains(&bits) {
        bail!("pack: bits must be in [1,16], got {bits}");
    }
    if xs.is_empty() {
        return Ok(PackedTensor { bits, codebook: cbk, len: 0, lmin: 0.0, scale: 1.0, data: vec![] });
    }
    let (lmin, lmax) = quant::group_minmax(xs);
    let plan = quant::QuantPlan::new_cbk(lmin, lmax, bits as f32, cbk);
    let proj = plan.projector();
    let levels = ((1u32 << bits) - 1) as i64;
    let fb = field_bits(cbk, bits);

    let total_bits = xs.len() * fb as usize;
    let mut data = vec![0u8; total_bits.div_ceil(8)];
    let mut acc = 0u64;
    let mut fill = 0u32;
    let mut out = 0usize;
    for &x in xs {
        let code = proj.project_code(plan.code(x, levels));
        let field = encode_field(cbk, bits, code);
        acc |= field << fill;
        fill += fb;
        if fill >= 64 {
            data[out..out + 8].copy_from_slice(&acc.to_le_bytes());
            out += 8;
            fill -= 64;
            acc = if fill > 0 { field >> (fb - fill) } else { 0 };
        }
    }
    if fill > 0 {
        let nbytes = fill.div_ceil(8) as usize;
        data[out..out + nbytes].copy_from_slice(&acc.to_le_bytes()[..nbytes]);
    }
    Ok(PackedTensor { bits, codebook: cbk, len: xs.len(), lmin: plan.lmin, scale: plan.s_lo, data })
}

/// Load up to 8 bytes at `byte` as a little-endian u64, zero-padding
/// past the end of the buffer.
#[inline]
fn load_word(data: &[u8], byte: usize) -> u64 {
    if byte + 8 <= data.len() {
        u64::from_le_bytes(data[byte..byte + 8].try_into().unwrap())
    } else {
        let mut buf = [0u8; 8];
        let n = data.len() - byte;
        buf[..n].copy_from_slice(&data[byte..]);
        u64::from_le_bytes(buf)
    }
}

/// Stream `n` packed fields of width `fb` out of `data` with a rolling
/// 64-bit register window — the in-register unpack that feeds the SIMD
/// GEMM kernels.  One word load services `⌊(64 - 7) / fb⌋` extractions
/// instead of one load per field: the window reloads only when the next
/// field would spill past bit 64, re-anchoring to the byte holding the
/// current bit cursor (`byte += used >> 3; used &= 7`), so after a
/// refill the cursor sits below 8 and any `fb <= 16` field fits
/// (`used + fb <= 23`).  [`load_word`] zero-pads past the end of the
/// buffer, which is exactly the packer's tail semantics.  Bit-identical
/// to [`unpack_fields_ref`] by the `miri_`-prefixed parity tests, which
/// also UB-check the window arithmetic under miri.
#[inline]
fn unpack_fields_into(data: &[u8], fb: u32, n: usize, mut emit: impl FnMut(u64)) {
    if n == 0 {
        return;
    }
    debug_assert!((1..=16).contains(&fb), "field width {fb} out of range");
    let mask = (1u64 << fb) - 1;
    let mut byte = 0usize;
    let mut used = 0u32;
    let mut window = load_word(data, 0);
    for _ in 0..n {
        if used + fb > 64 {
            byte += (used >> 3) as usize;
            used &= 7;
            window = load_word(data, byte);
        }
        emit((window >> used) & mask);
        used += fb;
    }
}

/// Scalar reference for [`unpack_fields_into`]: byte-at-a-time
/// [`read_bits_ref`] per field, no window state.  Kept as the semantic
/// baseline the rolling-window unpacker must match bit-for-bit.
fn unpack_fields_ref(data: &[u8], fb: u32, n: usize, mut emit: impl FnMut(u64)) {
    let mut bitpos = 0usize;
    for _ in 0..n {
        emit(read_bits_ref(data, bitpos, fb) as u64);
        bitpos += fb as usize;
    }
}

/// Unpack to dequantized f32 values (word-level, branchless extract:
/// every field width `<= 16` sits inside one 64-bit load).  Codebook
/// fields decode to grid codes first; the affine map is unchanged.
pub fn unpack(p: &PackedTensor) -> Vec<f32> {
    unpack_codes(p)
        .into_iter()
        .map(|code| p.lmin + code as f32 * p.scale)
        .collect()
}

/// Unpack the raw integer **grid codes** (what integer inference
/// consumes), whatever the stored encoding.  Fields were validated at
/// construction ([`PackedTensor::from_raw_cbk`] or the packer), so
/// decoding here cannot fail.
pub fn unpack_codes(p: &PackedTensor) -> Vec<u32> {
    debug_assert!((1..=16).contains(&p.bits) || p.len == 0);
    let fb = field_bits(p.codebook, p.bits);
    let mut out = Vec::with_capacity(p.len);
    if p.codebook == Codebook::Uniform {
        unpack_fields_into(&p.data, fb, p.len, |field| out.push(field as u32));
    } else {
        unpack_fields_into(&p.data, fb, p.len, |field| {
            out.push(
                decode_field(p.codebook, p.bits, field)
                    .expect("packed tensor field validated at construction"),
            )
        });
    }
    out
}

// ---------------------------------------------------------------------------
// retained scalar reference paths (parity tests + before/after benches)
// ---------------------------------------------------------------------------

/// Scalar reference for [`pack`]: per-value code math + byte-at-a-time
/// bit writes. Kept as the semantic baseline the word-level packer must
/// match bit-for-bit.
pub fn pack_ref(xs: &[f32], bits: u32) -> Result<PackedTensor> {
    if !(1..=16).contains(&bits) {
        bail!("pack: bits must be in [1,16], got {bits}");
    }
    if xs.is_empty() {
        return Ok(PackedTensor {
            bits,
            codebook: Codebook::Uniform,
            len: 0,
            lmin: 0.0,
            scale: 1.0,
            data: vec![],
        });
    }
    let (lmin, lmax) = quant::group_minmax(xs);
    let levels = (1u32 << bits) - 1;
    let scale = quant::scale(lmin, lmax, bits as f32);

    let total_bits = xs.len() * bits as usize;
    let mut data = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &x in xs {
        let code = (((x - lmin) / scale).round_ties_even() as i64)
            .clamp(0, levels as i64) as u32;
        write_bits_ref(&mut data, bitpos, bits, code);
        bitpos += bits as usize;
    }
    Ok(PackedTensor { bits, codebook: Codebook::Uniform, len: xs.len(), lmin, scale, data })
}

/// Scalar reference for [`pack_cbk`]: per-value min/max fold, explicit
/// projection and byte-at-a-time field writes — the semantic baseline
/// the fused codebook packer must match bit-for-bit.
pub fn pack_cbk_ref(xs: &[f32], bits: u32, cbk: Codebook) -> Result<PackedTensor> {
    if cbk == Codebook::Uniform {
        return pack_ref(xs, bits);
    }
    if !(1..=16).contains(&bits) {
        bail!("pack: bits must be in [1,16], got {bits}");
    }
    if xs.is_empty() {
        return Ok(PackedTensor { bits, codebook: cbk, len: 0, lmin: 0.0, scale: 1.0, data: vec![] });
    }
    let mut lmin = f32::INFINITY;
    let mut lmax = f32::NEG_INFINITY;
    for &x in xs {
        lmin = lmin.min(x);
        lmax = lmax.max(x);
    }
    let levels = ((1u32 << bits) - 1) as i64;
    let scale = quant::scale(lmin, lmax, bits as f32);
    let proj = quant::CodeProjector::new(cbk, bits);
    let fb = field_bits(cbk, bits);

    let total_bits = xs.len() * fb as usize;
    let mut data = vec![0u8; total_bits.div_ceil(8)];
    let mut bitpos = 0usize;
    for &x in xs {
        let code = (((x - lmin) / scale).round_ties_even() as i64).clamp(0, levels) as u32;
        let field = encode_field(cbk, bits, proj.project_code(code));
        write_bits_ref(&mut data, bitpos, fb, field as u32);
        bitpos += fb as usize;
    }
    Ok(PackedTensor { bits, codebook: cbk, len: xs.len(), lmin, scale, data })
}

/// Scalar reference for [`unpack`].
pub fn unpack_ref(p: &PackedTensor) -> Vec<f32> {
    unpack_codes_ref(p)
        .into_iter()
        .map(|code| p.lmin + code as f32 * p.scale)
        .collect()
}

/// Scalar reference for [`unpack_codes`] (byte-at-a-time field reads +
/// the same validated decode).
pub fn unpack_codes_ref(p: &PackedTensor) -> Vec<u32> {
    let fb = field_bits(p.codebook, p.bits);
    let mut out = Vec::with_capacity(p.len);
    let mut bitpos = 0usize;
    for _ in 0..p.len {
        let field = read_bits_ref(&p.data, bitpos, fb);
        out.push(
            decode_field(p.codebook, p.bits, field as u64)
                .expect("packed tensor field validated at construction"),
        );
        bitpos += fb as usize;
    }
    out
}

fn write_bits_ref(data: &mut [u8], bitpos: usize, bits: u32, value: u32) {
    let mut v = value as u64;
    let mut pos = bitpos;
    let mut remaining = bits;
    while remaining > 0 {
        let byte = pos / 8;
        let off = (pos % 8) as u32;
        let take = remaining.min(8 - off);
        let mask = ((1u64 << take) - 1) as u8;
        data[byte] |= (((v & mask as u64) as u8) << off) & (mask << off);
        v >>= take;
        pos += take as usize;
        remaining -= take;
    }
}

fn read_bits_ref(data: &[u8], bitpos: usize, bits: u32) -> u32 {
    let mut out = 0u64;
    let mut got = 0u32;
    let mut pos = bitpos;
    while got < bits {
        let byte = pos / 8;
        let off = (pos % 8) as u32;
        let take = (bits - got).min(8 - off);
        let mask = ((1u32 << take) - 1) as u8;
        let chunk = (data[byte] >> off) & mask;
        out |= (chunk as u64) << got;
        got += take;
        pos += take as usize;
    }
    out as u32
}

// ---------------------------------------------------------------------------
// group-boundary-aligned packing (the per-output-channel path)
// ---------------------------------------------------------------------------

/// Stored header bytes per group in the footprint convention: bits u32
/// + lmin f32 + scale f32 (the group length is implied by the shared
/// `group_size`).
pub const GROUP_HEADER_BYTES: usize = 12;

/// One group's slot in a [`PackedGroups`] buffer: its own bitlength and
/// `(lmin, scale)` dequantization plan, plus the byte offset of its
/// first code.  Every group starts on a **byte boundary**, so groups
/// decode independently and the spans double as the wire-format layout.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GroupSpan {
    /// Bitlength of this group's codes (1..=16).
    pub bits: u32,
    /// Dequantization: value = lmin + code * scale.
    pub lmin: f32,
    pub scale: f32,
    /// Byte offset of the group's first code in `PackedGroups::data`.
    pub start: usize,
}

/// A bit-packed tensor at **group granularity**: `n_groups` rows of
/// `group_size` values, each row packed LSB-first at its own bitlength
/// against its own min/max, each starting at a byte-aligned offset of
/// one shared buffer.  For weight tensors a group is one output
/// channel of the transposed `[dout, din]` layout.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedGroups {
    /// Values per group.
    pub group_size: usize,
    /// Code restriction shared by every group (ranges and bitlengths
    /// stay per-group; the codebook is a layer-level axis).
    pub codebook: Codebook,
    /// One span per group, in group order (`start` strictly increasing).
    pub spans: Vec<GroupSpan>,
    /// All groups' packed codes, concatenated at byte-aligned starts.
    pub data: Vec<u8>,
}

/// Packed payload bytes one group occupies at a stored field width.
fn group_bytes(group_size: usize, fb: u32) -> usize {
    (group_size * fb as usize).div_ceil(8)
}

impl PackedGroups {
    /// Reassemble from **untrusted** stored parts (the BPMA `GRP0`
    /// loader): per-group `(bits, lmin, scale)` headers are validated
    /// like [`PackedTensor::from_raw`], the spans are rebuilt from the
    /// shared `group_size`, and the payload must be exactly the implied
    /// total size.
    pub fn from_raw(
        group_size: usize,
        params: &[(u32, f32, f32)],
        data: Vec<u8>,
    ) -> Result<Self> {
        Self::from_raw_cbk(group_size, Codebook::Uniform, params, data)
    }

    /// [`Self::from_raw`] under a codebook: span sizes are computed at
    /// the stored [`field_bits`] width and every group's fields are
    /// walked and validated, exactly like
    /// [`PackedTensor::from_raw_cbk`].
    pub fn from_raw_cbk(
        group_size: usize,
        codebook: Codebook,
        params: &[(u32, f32, f32)],
        data: Vec<u8>,
    ) -> Result<Self> {
        if group_size == 0 {
            bail!("packed groups: group_size must be positive");
        }
        let mut spans = Vec::with_capacity(params.len());
        let mut start = 0usize;
        for (g, &(bits, lmin, scale)) in params.iter().enumerate() {
            if !(1..=16).contains(&bits) {
                bail!("packed groups: group {g} bits must be in [1,16], got {bits}");
            }
            if !lmin.is_finite() || !scale.is_finite() || scale <= 0.0 {
                bail!(
                    "packed groups: group {g} bad dequant header (lmin {lmin}, scale {scale})"
                );
            }
            spans.push(GroupSpan { bits, lmin, scale, start });
            start = start
                .checked_add(group_bytes(group_size, field_bits(codebook, bits)))
                .ok_or_else(|| anyhow::anyhow!("packed groups: payload size overflows"))?;
        }
        if data.len() != start {
            bail!(
                "packed groups: payload is {} bytes, {} groups x {group_size} codes need {start}",
                data.len(),
                params.len()
            );
        }
        if codebook != Codebook::Uniform {
            for (g, span) in spans.iter().enumerate() {
                let fb = field_bits(codebook, span.bits);
                let mask = (1u64 << fb) - 1;
                for i in 0..group_size {
                    let bitpos = i * fb as usize;
                    let word = load_word(&data, span.start + (bitpos >> 3));
                    let field = (word >> (bitpos & 7)) & mask;
                    if decode_field(codebook, span.bits, field).is_none() {
                        bail!(
                            "packed groups: group {g} field {i} ({field:#x}) is not \
                             a valid {} code at {} bits",
                            codebook.name(),
                            span.bits
                        );
                    }
                }
                let used = (group_size * fb as usize) % 8;
                let last = span.start + group_bytes(group_size, fb) - 1;
                if used != 0 && data[last] >> used != 0 {
                    bail!("packed groups: group {g} has nonzero pad bits");
                }
            }
        }
        Ok(Self { group_size, codebook, spans, data })
    }

    pub fn n_groups(&self) -> usize {
        self.spans.len()
    }

    /// Total encoded values across every group.
    pub fn len(&self) -> usize {
        self.group_size * self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Packed payload size in bytes (excluding the per-group headers).
    pub fn payload_bytes(&self) -> usize {
        self.data.len()
    }

    /// Stored size: payload plus one [`GROUP_HEADER_BYTES`] header per
    /// group — the grouped arm of the one footprint convention.
    pub fn stored_bytes(&self) -> usize {
        self.data.len() + self.spans.len() * GROUP_HEADER_BYTES
    }

    /// Compression ratio vs f32 storage, headers included.
    pub fn ratio_vs_f32(&self) -> f64 {
        (self.len() * 4) as f64 / self.stored_bytes().max(1) as f64
    }

    /// Largest group bitlength (what accumulator-width sizing cares
    /// about).
    pub fn max_bits(&self) -> u32 {
        self.spans.iter().map(|s| s.bits).max().unwrap_or(0)
    }

    /// Mean group bitlength — the paper's sub-layer average.
    pub fn mean_bits(&self) -> f64 {
        if self.spans.is_empty() {
            return 0.0;
        }
        self.spans.iter().map(|s| s.bits as f64).sum::<f64>() / self.spans.len() as f64
    }

    /// Unpack one group's raw integer **grid codes** (word-level
    /// single-load extract — the byte-aligned span makes the group
    /// independent), decoding codebook fields when present.
    pub fn group_codes(&self, g: usize) -> Vec<u32> {
        let span = self.spans[g];
        let fb = field_bits(self.codebook, span.bits);
        // Groups start byte-aligned, so the rolling window runs over
        // the group's own subslice; zero-padding past `data.len()` only
        // ever pads the final group's tail, exactly as before.
        let tail = &self.data[span.start..];
        let mut out = Vec::with_capacity(self.group_size);
        if self.codebook == Codebook::Uniform {
            unpack_fields_into(tail, fb, self.group_size, |field| out.push(field as u32));
        } else {
            unpack_fields_into(tail, fb, self.group_size, |field| {
                out.push(
                    decode_field(self.codebook, span.bits, field)
                        .expect("packed groups field validated at construction"),
                )
            });
        }
        out
    }

    /// Scalar reference for [`Self::group_codes`] (byte-at-a-time).
    pub fn group_codes_ref(&self, g: usize) -> Vec<u32> {
        let span = self.spans[g];
        let fb = field_bits(self.codebook, span.bits);
        let mut out = Vec::with_capacity(self.group_size);
        let mut bitpos = span.start * 8;
        for _ in 0..self.group_size {
            let field = read_bits_ref(&self.data, bitpos, fb);
            out.push(
                decode_field(self.codebook, span.bits, field as u64)
                    .expect("packed groups field validated at construction"),
            );
            bitpos += fb as usize;
        }
        out
    }

    /// Dequantize every group back to f32, group-major order.
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.len());
        for (g, span) in self.spans.iter().enumerate() {
            out.extend(
                self.group_codes(g)
                    .into_iter()
                    .map(|c| span.lmin + c as f32 * span.scale),
            );
        }
        out
    }
}

/// Quantize and pack `[n_groups x group_size]` row-major data, each
/// group fused word-level at its own integer bitlength against its own
/// min/max (the per-output-channel weight path).  Group boundaries are
/// byte-aligned: each group's stream starts on a fresh byte, so the
/// per-group word accumulator logic is exactly [`pack`]'s.
pub fn pack_groups(xs: &[f32], group_size: usize, bits: &[u32]) -> Result<PackedGroups> {
    pack_groups_cbk(xs, group_size, bits, Codebook::Uniform)
}

/// Codebook-aware grouped fused pack: each group quantizes against its
/// own min/max at its own bitlength, projects onto the shared codebook
/// and streams (sign, exponent) fields word-level.  `Uniform` output
/// is byte-identical to the pre-codebook [`pack_groups`].
pub fn pack_groups_cbk(
    xs: &[f32],
    group_size: usize,
    bits: &[u32],
    cbk: Codebook,
) -> Result<PackedGroups> {
    if group_size == 0 {
        bail!("pack_groups: group_size must be positive");
    }
    if xs.len() != group_size * bits.len() {
        bail!(
            "pack_groups: {} values is not {} groups x {group_size}",
            xs.len(),
            bits.len()
        );
    }
    let mut spans = Vec::with_capacity(bits.len());
    let mut total = 0usize;
    for (g, &b) in bits.iter().enumerate() {
        if !(1..=16).contains(&b) {
            bail!("pack_groups: group {g} bits must be in [1,16], got {b}");
        }
        spans.push(GroupSpan { bits: b, lmin: 0.0, scale: 1.0, start: total });
        total += group_bytes(group_size, field_bits(cbk, b));
    }
    let mut data = vec![0u8; total];
    for ((row, &b), span) in xs.chunks_exact(group_size).zip(bits).zip(&mut spans) {
        let plan = quant::QuantPlan::from_slice_cbk(row, b as f32, cbk);
        let proj = plan.projector();
        let levels = ((1u32 << b) - 1) as i64;
        let fb = field_bits(cbk, b);
        let mut acc = 0u64;
        let mut fill = 0u32;
        let mut out = span.start;
        for &x in row {
            let field = if cbk == Codebook::Uniform {
                plan.code(x, levels) as u64
            } else {
                encode_field(cbk, b, proj.project_code(plan.code(x, levels)))
            };
            acc |= field << fill;
            fill += fb;
            if fill >= 64 {
                data[out..out + 8].copy_from_slice(&acc.to_le_bytes());
                out += 8;
                fill -= 64;
                acc = if fill > 0 { field >> (fb - fill) } else { 0 };
            }
        }
        if fill > 0 {
            let nbytes = fill.div_ceil(8) as usize;
            data[out..out + nbytes].copy_from_slice(&acc.to_le_bytes()[..nbytes]);
        }
        span.lmin = plan.lmin;
        span.scale = plan.s_lo;
    }
    Ok(PackedGroups { group_size, codebook: cbk, spans, data })
}

/// Scalar reference for [`pack_groups`]: per-group min/max fold and
/// byte-at-a-time bit writes, the semantic baseline the fused packer
/// must match bit-for-bit (pinned by the parity tests).
pub fn pack_groups_ref(xs: &[f32], group_size: usize, bits: &[u32]) -> Result<PackedGroups> {
    pack_groups_cbk_ref(xs, group_size, bits, Codebook::Uniform)
}

/// Scalar reference for [`pack_groups_cbk`] (and, at `Uniform`, for
/// [`pack_groups`]): per-group min/max fold, explicit projection and
/// byte-at-a-time field writes — pinned bit-for-bit by the parity
/// tests.
pub fn pack_groups_cbk_ref(
    xs: &[f32],
    group_size: usize,
    bits: &[u32],
    cbk: Codebook,
) -> Result<PackedGroups> {
    if group_size == 0 {
        bail!("pack_groups: group_size must be positive");
    }
    if xs.len() != group_size * bits.len() {
        bail!(
            "pack_groups: {} values is not {} groups x {group_size}",
            xs.len(),
            bits.len()
        );
    }
    let mut spans = Vec::with_capacity(bits.len());
    let mut total = 0usize;
    for (g, &b) in bits.iter().enumerate() {
        if !(1..=16).contains(&b) {
            bail!("pack_groups: group {g} bits must be in [1,16], got {b}");
        }
        spans.push(GroupSpan { bits: b, lmin: 0.0, scale: 1.0, start: total });
        total += group_bytes(group_size, field_bits(cbk, b));
    }
    let mut data = vec![0u8; total];
    for ((row, &b), span) in xs.chunks_exact(group_size).zip(bits).zip(&mut spans) {
        let mut lmin = f32::INFINITY;
        let mut lmax = f32::NEG_INFINITY;
        for &x in row {
            lmin = lmin.min(x);
            lmax = lmax.max(x);
        }
        let levels = (1u32 << b) - 1;
        let scale = quant::scale(lmin, lmax, b as f32);
        let proj = quant::CodeProjector::new(cbk, b);
        let fb = field_bits(cbk, b);
        let mut bitpos = span.start * 8;
        for &x in row {
            let code = (((x - lmin) / scale).round_ties_even() as i64)
                .clamp(0, levels as i64) as u32;
            let field = if cbk == Codebook::Uniform {
                code as u64
            } else {
                encode_field(cbk, b, proj.project_code(code))
            };
            write_bits_ref(&mut data, bitpos, fb, field as u32);
            bitpos += fb as usize;
        }
        span.lmin = lmin;
        span.scale = scale;
    }
    Ok(PackedGroups { group_size, codebook: cbk, spans, data })
}

/// Packed weight codes at either granularity — what `infer::IntDense`
/// stores and the BPMA artifact ships.
#[derive(Debug, Clone, PartialEq)]
pub enum WeightCodes {
    /// One bitlength + plan for the whole `[din, dout]` tensor
    /// (row-major, the original path).
    PerLayer(PackedTensor),
    /// One bitlength + plan per output channel over the **transposed**
    /// `[dout, din]` layout (group = channel, group_size = din).
    PerChannel(PackedGroups),
}

impl WeightCodes {
    pub fn granularity(&self) -> quant::Granularity {
        match self {
            WeightCodes::PerLayer(_) => quant::Granularity::PerLayer,
            WeightCodes::PerChannel(_) => quant::Granularity::PerOutputChannel,
        }
    }

    /// The code restriction the payload is stored under.
    pub fn codebook(&self) -> Codebook {
        match self {
            WeightCodes::PerLayer(p) => p.codebook,
            WeightCodes::PerChannel(g) => g.codebook,
        }
    }

    /// Total encoded values.
    pub fn len(&self) -> usize {
        match self {
            WeightCodes::PerLayer(p) => p.len,
            WeightCodes::PerChannel(g) => g.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Raw packed payload (the BPMA `WCT0` bytes).
    pub fn payload(&self) -> &[u8] {
        match self {
            WeightCodes::PerLayer(p) => &p.data,
            WeightCodes::PerChannel(g) => &g.data,
        }
    }

    /// Stored footprint: payload + headers, one convention either way.
    pub fn stored_bytes(&self) -> usize {
        match self {
            WeightCodes::PerLayer(p) => p.stored_bytes(),
            WeightCodes::PerChannel(g) => g.stored_bytes(),
        }
    }

    /// Largest bitlength any code is stored at.
    pub fn max_bits(&self) -> u32 {
        match self {
            WeightCodes::PerLayer(p) => p.bits,
            WeightCodes::PerChannel(g) => g.max_bits(),
        }
    }

    /// Mean bitlength over groups (per-layer = one group).
    pub fn mean_bits(&self) -> f64 {
        match self {
            WeightCodes::PerLayer(p) => p.bits as f64,
            WeightCodes::PerChannel(g) => g.mean_bits(),
        }
    }

    /// Group count at bitlength n, indexed 1..=16 (index 0 unused) —
    /// the per-channel bit histogram `bitprune inspect` reports.  A
    /// per-layer tensor is a single group.
    pub fn bits_histogram(&self) -> [usize; 17] {
        let mut h = [0usize; 17];
        match self {
            WeightCodes::PerLayer(p) => h[p.bits as usize] += 1,
            WeightCodes::PerChannel(g) => {
                for s in &g.spans {
                    h[s.bits as usize] += 1;
                }
            }
        }
        h
    }
}

// ---------------------------------------------------------------------------
// network-level packing
// ---------------------------------------------------------------------------

/// Footprint report for packing a whole network at learned bitlengths.
#[derive(Debug, Clone)]
pub struct PackReport {
    pub total_f32_bytes: usize,
    pub total_packed_bytes: usize,
    pub per_layer: Vec<(String, usize, usize)>, // (name, f32, packed)
}

impl PackReport {
    pub fn ratio(&self) -> f64 {
        self.total_f32_bytes as f64 / self.total_packed_bytes.max(1) as f64
    }
}

/// Pack a set of named weight tensors at their per-layer bitlengths.
pub fn pack_network(
    tensors: &[(String, &[f32])],
    bits: &[f32],
) -> Result<(Vec<PackedTensor>, PackReport)> {
    if tensors.len() != bits.len() {
        bail!("pack_network: {} tensors vs {} bitlengths", tensors.len(), bits.len());
    }
    let mut packed = Vec::with_capacity(tensors.len());
    let mut per_layer = Vec::new();
    let mut total_f32 = 0;
    let mut total_packed = 0;
    for ((name, xs), &b) in tensors.iter().zip(bits) {
        let ib = quant::int_bits(b);
        let p = pack(xs, ib)?;
        let f32_bytes = xs.len() * 4;
        let packed_bytes = p.stored_bytes();
        per_layer.push((name.clone(), f32_bytes, packed_bytes));
        total_f32 += f32_bytes;
        total_packed += packed_bytes;
        packed.push(p);
    }
    Ok((packed, PackReport { total_f32_bytes: total_f32, total_packed_bytes: total_packed, per_layer }))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_codes_every_bitlength() {
        check(
            "bitpack-roundtrip",
            128,
            |rng: &mut Rng| {
                let bits = 1 + rng.below(16) as u32;
                let len = 1 + rng.below_usize(300);
                let xs: Vec<f32> =
                    (0..len).map(|_| rng.normal_f32(0.0, 2.0)).collect();
                (xs, bits)
            },
            |(xs, bits)| {
                let p = pack(xs, *bits).map_err(|e| e.to_string())?;
                // Unpacked values must equal the n-bit quantized input.
                let mut want = xs.clone();
                quant::fake_quant_slice(&mut want, *bits as f32);
                let got = unpack(&p);
                if got.len() != xs.len() {
                    return Err("length mismatch".into());
                }
                let (lmin, lmax) = quant::group_minmax(xs);
                let tol = 1e-5 * (lmax - lmin).abs().max(1e-5);
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    if (g - w).abs() > tol {
                        return Err(format!("elem {i}: {g} vs {w} at {bits} bits"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn word_packer_matches_ref_bitstream() {
        // The fused word-level packer and both unpackers must agree
        // bit-for-bit with the retained scalar reference at every
        // bitlength and unaligned length.
        check(
            "bitpack-word-parity",
            256,
            |rng: &mut Rng| {
                let bits = 1 + rng.below(16) as u32;
                let len = 1 + rng.below_usize(130);
                let xs: Vec<f32> =
                    (0..len).map(|_| rng.normal_f32(0.0, 2.0)).collect();
                (xs, bits)
            },
            |(xs, bits)| {
                let fast = pack(xs, *bits).map_err(|e| e.to_string())?;
                let slow = pack_ref(xs, *bits).map_err(|e| e.to_string())?;
                if fast != slow {
                    return Err(format!("packed tensors differ at {bits} bits"));
                }
                if unpack_codes(&fast) != unpack_codes_ref(&fast) {
                    return Err("code unpack differs".into());
                }
                let (f, r) = (unpack(&fast), unpack_ref(&fast));
                if f.iter().zip(&r).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err("value unpack differs".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn word_packer_every_bitlength_edge_lengths() {
        // Deterministic sweep over the bit widths x awkward lengths that
        // stress word boundaries (1, 7, 8, 9, 63, 64, 65, ...).
        let mut rng = Rng::new(0xB175);
        for bits in 1..=16u32 {
            for &len in &[1usize, 3, 7, 8, 9, 31, 63, 64, 65, 127, 200] {
                let xs: Vec<f32> =
                    (0..len).map(|_| rng.normal_f32(0.0, 1.5)).collect();
                let fast = pack(&xs, bits).unwrap();
                let slow = pack_ref(&xs, bits).unwrap();
                assert_eq!(fast, slow, "bits={bits} len={len}");
                assert_eq!(
                    unpack_codes(&fast),
                    unpack_codes_ref(&slow),
                    "bits={bits} len={len}"
                );
            }
        }
    }

    #[test]
    fn payload_size_is_exact() {
        let xs = vec![0.5f32; 100];
        for bits in [1u32, 3, 7, 8, 13] {
            let p = pack(&xs, bits).unwrap();
            assert_eq!(p.payload_bytes(), (100 * bits as usize).div_ceil(8));
        }
    }

    #[test]
    fn codes_within_range() {
        check(
            "bitpack-code-range",
            64,
            |rng: &mut Rng| {
                let bits = 1 + rng.below(8) as u32;
                let xs: Vec<f32> = (0..64).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                (xs, bits)
            },
            |(xs, bits)| {
                let p = pack(xs, *bits).map_err(|e| e.to_string())?;
                let max_code = (1u32 << bits) - 1;
                for c in unpack_codes(&p) {
                    if c > max_code {
                        return Err(format!("code {c} exceeds {max_code}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn extremes_map_to_end_codes() {
        let xs = vec![-2.0f32, 0.0, 3.0];
        let p = pack(&xs, 4).unwrap();
        let codes = unpack_codes(&p);
        assert_eq!(codes[0], 0);
        assert_eq!(codes[2], 15);
    }

    #[test]
    fn compression_ratio() {
        let xs = vec![1.0f32; 1000];
        let p = pack(&xs, 4).unwrap();
        // 4000 f32 bytes vs 500 payload + 16 header: one convention,
        // header included, everywhere.
        let want = 4000.0 / (500.0 + HEADER_BYTES as f64);
        assert!((p.ratio_vs_f32() - want).abs() < 1e-12);
        assert!(p.ratio_vs_f32() > 7.5); // ~32/4 once the header amortizes
    }

    #[test]
    fn footprint_convention_is_consistent() {
        // stored_bytes == payload + header, and pack_network's totals
        // are exactly the sum of stored_bytes — no second convention.
        let xs = vec![0.25f32; 300];
        let p = pack(&xs, 3).unwrap();
        assert_eq!(p.stored_bytes(), p.payload_bytes() + HEADER_BYTES);
        let tensors = vec![("a".to_string(), xs.as_slice()), ("b".to_string(), xs.as_slice())];
        let (packed, report) = pack_network(&tensors, &[3.0, 5.0]).unwrap();
        let sum: usize = packed.iter().map(|p| p.stored_bytes()).sum();
        assert_eq!(report.total_packed_bytes, sum);
        for (p, (_, _, stored)) in packed.iter().zip(&report.per_layer) {
            assert_eq!(p.stored_bytes(), *stored);
        }
        // Empty tensors still carry their header.
        assert_eq!(pack(&[], 4).unwrap().stored_bytes(), HEADER_BYTES);
    }

    #[test]
    fn word_accumulator_exact_fill_boundaries() {
        // Lengths where the u64 accumulator lands on exactly 64 filled
        // bits (the `fill == 64` flush with no carry) — for every
        // bitlength that divides 64 — plus the surrounding lengths.
        let mut rng = Rng::new(0xF111);
        for &bits in &[1u32, 2, 4, 8, 16] {
            let per_word = (64 / bits) as usize;
            for words in [1usize, 2, 3] {
                for delta in [-1isize, 0, 1] {
                    let len = (per_word * words) as isize + delta;
                    if len < 1 {
                        continue;
                    }
                    let xs: Vec<f32> =
                        (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                    let fast = pack(&xs, bits).unwrap();
                    let slow = pack_ref(&xs, bits).unwrap();
                    assert_eq!(fast, slow, "bits={bits} len={len}");
                    assert_eq!(
                        unpack_codes(&fast),
                        unpack_codes_ref(&slow),
                        "bits={bits} len={len}"
                    );
                }
            }
        }
    }

    #[test]
    fn sixteen_bit_odd_lengths() {
        // bits=16 is the widest code: words straddle at every odd
        // length, and the tail flush writes 2, 4 or 6 bytes.
        let mut rng = Rng::new(0x16B1);
        for len in [1usize, 3, 5, 7, 9, 11, 13, 15, 17] {
            let xs: Vec<f32> =
                (0..len).map(|_| rng.normal_f32(0.0, 3.0)).collect();
            let fast = pack(&xs, 16).unwrap();
            let slow = pack_ref(&xs, 16).unwrap();
            assert_eq!(fast, slow, "len={len}");
            assert_eq!(fast.payload_bytes(), len * 2);
            assert_eq!(unpack_codes(&fast).len(), len);
        }
    }

    #[test]
    fn pack_unpack_code_roundtrip_property() {
        // pack -> unpack_codes reproduces exactly the codes the
        // quantization plan assigns, for random bitlengths and lengths.
        check(
            "bitpack-code-roundtrip",
            256,
            |rng: &mut Rng| {
                let bits = 1 + rng.below(16) as u32;
                let len = 1 + rng.below_usize(200);
                let xs: Vec<f32> =
                    (0..len).map(|_| rng.normal_f32(0.0, 2.0)).collect();
                (xs, bits)
            },
            |(xs, bits)| {
                let plan = quant::QuantPlan::from_slice(xs, *bits as f32);
                let levels = ((1u64 << bits) - 1) as i64;
                let want: Vec<u32> =
                    xs.iter().map(|&x| plan.code(x, levels)).collect();
                let got = unpack_codes(&pack(xs, *bits).map_err(|e| e.to_string())?);
                if got != want {
                    return Err(format!("codes diverge at {bits} bits"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn empty_and_invalid() {
        assert_eq!(pack(&[], 4).unwrap().len, 0);
        assert!(pack(&[1.0], 0).is_err());
        assert!(pack(&[1.0], 17).is_err());
    }

    #[test]
    fn from_raw_validates_untrusted_parts() {
        let mut rng = Rng::new(0xF40);
        let xs: Vec<f32> = (0..37).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let p = pack(&xs, 5).unwrap();
        // Faithful parts reassemble to an identical tensor.
        let re = PackedTensor::from_raw(p.bits, p.len, p.lmin, p.scale, p.data.clone())
            .unwrap();
        assert_eq!(re, p);
        // Wrong payload size (both directions), bad bits, hostile
        // len*bits overflow, non-finite / non-positive headers.
        let short = p.data[..p.data.len() - 1].to_vec();
        assert!(PackedTensor::from_raw(p.bits, p.len, p.lmin, p.scale, short).is_err());
        let mut long = p.data.clone();
        long.push(0);
        assert!(PackedTensor::from_raw(p.bits, p.len, p.lmin, p.scale, long).is_err());
        assert!(PackedTensor::from_raw(0, p.len, p.lmin, p.scale, p.data.clone()).is_err());
        assert!(PackedTensor::from_raw(17, p.len, p.lmin, p.scale, p.data.clone()).is_err());
        assert!(
            PackedTensor::from_raw(16, usize::MAX / 2, p.lmin, p.scale, p.data.clone())
                .is_err()
        );
        assert!(
            PackedTensor::from_raw(p.bits, p.len, f32::NAN, p.scale, p.data.clone())
                .is_err()
        );
        assert!(
            PackedTensor::from_raw(p.bits, p.len, p.lmin, 0.0, p.data.clone()).is_err()
        );
        assert!(
            PackedTensor::from_raw(p.bits, p.len, p.lmin, f32::INFINITY, p.data.clone())
                .is_err()
        );
        // Empty tensors: no payload allowed, and they reassemble — but
        // the header fields are still validated.
        assert!(PackedTensor::from_raw(4, 0, 0.0, 1.0, vec![0]).is_err());
        assert_eq!(PackedTensor::from_raw(4, 0, 0.0, 1.0, vec![]).unwrap().len, 0);
        assert!(PackedTensor::from_raw(99, 0, 0.0, 1.0, vec![]).is_err());
        assert!(PackedTensor::from_raw(4, 0, f32::NAN, -1.0, vec![]).is_err());
    }

    #[test]
    fn grouped_packer_matches_ref_bitstream() {
        // The fused per-group word packer and both group unpackers must
        // agree bit-for-bit with the scalar reference at random group
        // shapes and mixed bitlengths.
        check(
            "bitpack-group-parity",
            128,
            |rng: &mut Rng| {
                let groups = 1 + rng.below_usize(10);
                let size = 1 + rng.below_usize(90);
                let xs: Vec<f32> =
                    (0..groups * size).map(|_| rng.normal_f32(0.0, 2.0)).collect();
                let bits: Vec<u32> =
                    (0..groups).map(|_| 1 + rng.below(16) as u32).collect();
                (xs, size, bits)
            },
            |(xs, size, bits)| {
                let fast = pack_groups(xs, *size, bits).map_err(|e| e.to_string())?;
                let slow =
                    pack_groups_ref(xs, *size, bits).map_err(|e| e.to_string())?;
                if fast != slow {
                    return Err("grouped byte streams differ".into());
                }
                for g in 0..fast.n_groups() {
                    if fast.group_codes(g) != fast.group_codes_ref(g) {
                        return Err(format!("group {g} unpack differs"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn grouped_pack_group_size_one_and_single_group() {
        let mut rng = Rng::new(0x6501);
        // group_size == 1: every value is its own group (degenerate
        // ranges — the epsilon guard keeps scales finite).
        let xs: Vec<f32> = (0..9).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let bits: Vec<u32> = (0..9).map(|i| 1 + (i % 16) as u32).collect();
        let p = pack_groups(&xs, 1, &bits).unwrap();
        assert_eq!(p.n_groups(), 9);
        assert_eq!(p.len(), 9);
        for g in 0..9 {
            let codes = p.group_codes(g);
            assert_eq!(codes.len(), 1);
            assert_eq!(codes, p.group_codes_ref(g));
        }
        assert_eq!(p, pack_groups_ref(&xs, 1, &bits).unwrap());

        // One group spanning the whole tensor (group == layer): the
        // byte stream must equal the per-layer packer's.
        let xs: Vec<f32> = (0..77).map(|_| rng.normal_f32(0.0, 1.5)).collect();
        let one = pack_groups(&xs, 77, &[5]).unwrap();
        let flat = pack(&xs, 5).unwrap();
        assert_eq!(one.data, flat.data);
        assert_eq!(one.spans[0].lmin, flat.lmin);
        assert_eq!(one.spans[0].scale, flat.scale);
        assert_eq!(one.group_codes(0), unpack_codes(&flat));
    }

    #[test]
    fn grouped_pack_odd_sizes_at_word_boundaries() {
        // Group sizes that land the per-group u64 accumulator exactly
        // on, just under and just over 64-bit fills, for every
        // bitlength that divides 64 plus awkward ones.
        let mut rng = Rng::new(0x6502);
        for &bits in &[1u32, 3, 4, 7, 8, 13, 16] {
            let per_word = (64 / bits) as usize;
            for &size in
                &[1usize, per_word - 1, per_word, per_word + 1, 2 * per_word + 3]
            {
                if size == 0 {
                    continue;
                }
                let groups = 3usize;
                let xs: Vec<f32> = (0..groups * size)
                    .map(|_| rng.normal_f32(0.0, 1.0))
                    .collect();
                let bv = vec![bits; groups];
                let fast = pack_groups(&xs, size, &bv).unwrap();
                let slow = pack_groups_ref(&xs, size, &bv).unwrap();
                assert_eq!(fast, slow, "bits={bits} size={size}");
                // Spans are byte-aligned and exactly sized.
                for (g, s) in fast.spans.iter().enumerate() {
                    assert_eq!(
                        s.start,
                        g * (size * bits as usize).div_ceil(8),
                        "bits={bits} size={size} group {g}"
                    );
                }
                assert_eq!(
                    fast.payload_bytes(),
                    groups * (size * bits as usize).div_ceil(8)
                );
                // Group codes match a standalone per-group pack.
                for (g, row) in xs.chunks(size).enumerate() {
                    let solo = pack(row, bits).unwrap();
                    assert_eq!(
                        fast.group_codes(g),
                        unpack_codes(&solo),
                        "bits={bits} size={size} group {g}"
                    );
                }
            }
        }
    }

    #[test]
    fn grouped_unpack_dequantizes_per_group() {
        // unpack() must equal per-group fake quantization (each row on
        // its own grid), not a shared-layer grid.
        let mut rng = Rng::new(0x6503);
        let (groups, size) = (5usize, 23usize);
        let xs: Vec<f32> =
            (0..groups * size).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let bits = [2u32, 4, 8, 3, 6];
        let p = pack_groups(&xs, size, &bits).unwrap();
        let got = p.unpack();
        let bits_f: Vec<f32> = bits.iter().map(|&b| b as f32).collect();
        let mut want = xs.clone();
        quant::fake_quant_groups(&mut want, size, &bits_f);
        let (lmin, lmax) = quant::group_minmax(&xs);
        let tol = 1e-5 * (lmax - lmin).abs().max(1e-5);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert!((g - w).abs() <= tol, "elem {i}: {g} vs {w}");
        }
    }

    #[test]
    fn grouped_from_raw_validates_untrusted_parts() {
        let mut rng = Rng::new(0x6504);
        let xs: Vec<f32> = (0..4 * 19).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let bits = [3u32, 5, 1, 16];
        let p = pack_groups(&xs, 19, &bits).unwrap();
        let params: Vec<(u32, f32, f32)> =
            p.spans.iter().map(|s| (s.bits, s.lmin, s.scale)).collect();
        // Faithful parts reassemble identically.
        let re = PackedGroups::from_raw(19, &params, p.data.clone()).unwrap();
        assert_eq!(re, p);
        // Wrong payload size, zero group size, bad bits, non-finite
        // headers, non-positive scale: all clean errors.
        let short = p.data[..p.data.len() - 1].to_vec();
        assert!(PackedGroups::from_raw(19, &params, short).is_err());
        let mut long = p.data.clone();
        long.push(0);
        assert!(PackedGroups::from_raw(19, &params, long).is_err());
        assert!(PackedGroups::from_raw(0, &params, p.data.clone()).is_err());
        let mut bad = params.clone();
        bad[1].0 = 17;
        assert!(PackedGroups::from_raw(19, &bad, p.data.clone()).is_err());
        let mut bad = params.clone();
        bad[2].1 = f32::NAN;
        assert!(PackedGroups::from_raw(19, &bad, p.data.clone()).is_err());
        let mut bad = params.clone();
        bad[0].2 = 0.0;
        assert!(PackedGroups::from_raw(19, &bad, p.data.clone()).is_err());
        // Empty groups: allowed only with an empty payload.
        assert!(PackedGroups::from_raw(4, &[], vec![0]).is_err());
        assert_eq!(PackedGroups::from_raw(4, &[], vec![]).unwrap().len(), 0);
    }

    #[test]
    fn grouped_footprint_convention() {
        let xs = vec![0.5f32; 6 * 40];
        let bits = [4u32, 4, 2, 2, 8, 1];
        let p = pack_groups(&xs, 40, &bits).unwrap();
        let payload: usize =
            bits.iter().map(|&b| (40 * b as usize).div_ceil(8)).sum();
        assert_eq!(p.payload_bytes(), payload);
        assert_eq!(p.stored_bytes(), payload + 6 * GROUP_HEADER_BYTES);
        assert!(p.ratio_vs_f32() > 1.0);
        assert_eq!(p.max_bits(), 8);
        assert!((p.mean_bits() - (4 + 4 + 2 + 2 + 8 + 1) as f64 / 6.0).abs() < 1e-12);

        // WeightCodes mirrors the same convention on both arms.
        let per_layer = WeightCodes::PerLayer(pack(&xs, 4).unwrap());
        assert_eq!(per_layer.stored_bytes(), (6 * 40 * 4).div_ceil(8) + HEADER_BYTES);
        assert_eq!(per_layer.granularity(), quant::Granularity::PerLayer);
        assert_eq!(per_layer.max_bits(), 4);
        assert_eq!(per_layer.bits_histogram()[4], 1);
        let grouped = WeightCodes::PerChannel(p.clone());
        assert_eq!(grouped.stored_bytes(), p.stored_bytes());
        assert_eq!(grouped.len(), 240);
        assert_eq!(grouped.granularity(), quant::Granularity::PerOutputChannel);
        let h = grouped.bits_histogram();
        assert_eq!((h[1], h[2], h[4], h[8]), (1, 2, 2, 1));
        assert!((grouped.mean_bits() - p.mean_bits()).abs() < 1e-12);
    }

    #[test]
    fn grouped_pack_rejects_bad_shapes() {
        let xs = vec![0.0f32; 12];
        assert!(pack_groups(&xs, 0, &[4]).is_err());
        assert!(pack_groups(&xs, 5, &[4, 4]).is_err());
        assert!(pack_groups(&xs, 6, &[4, 17]).is_err());
        assert!(pack_groups(&xs, 6, &[0, 4]).is_err());
        assert!(pack_groups_ref(&xs, 5, &[4, 4]).is_err());
    }

    #[test]
    fn pack_network_accounts_footprint() {
        let a = vec![0.5f32; 256];
        let b = vec![-1.0f32; 128];
        let tensors = vec![("l0".to_string(), a.as_slice()), ("l1".to_string(), b.as_slice())];
        let (packed, report) = pack_network(&tensors, &[4.0, 2.0]).unwrap();
        assert_eq!(packed.len(), 2);
        assert_eq!(report.total_f32_bytes, (256 + 128) * 4);
        // 256*4 bits + 128*2 bits = 128 + 32 bytes + 2 headers
        assert_eq!(report.total_packed_bytes, 128 + 32 + 32);
        assert!(report.ratio() > 1.0);
        // Non-integer learned bits are ceiled.
        let (_, r2) = pack_network(&tensors, &[3.2, 1.7]).unwrap();
        assert_eq!(r2.per_layer[0].2, 256 / 2 + 16); // 4 bits
    }

    #[test]
    fn mismatched_lengths_rejected() {
        let a = vec![0.0f32; 8];
        let tensors = vec![("x".to_string(), a.as_slice())];
        assert!(pack_network(&tensors, &[4.0, 4.0]).is_err());
    }

    #[test]
    fn cbk_field_widths_pinned() {
        // idx space: emax + 2 values → ceil(log2) bits.
        assert_eq!(idx_bits(8), 3); // 8 index values
        assert_eq!(idx_bits(4), 2);
        assert_eq!(idx_bits(1), 1);
        assert_eq!(idx_bits(16), 4);
        assert_eq!(field_bits(Codebook::Uniform, 8), 8);
        assert_eq!(field_bits(Codebook::PowerOfTwo, 8), 4); // 2x denser
        assert_eq!(field_bits(Codebook::AdditivePot2, 8), 7);
        assert_eq!(field_bits(Codebook::AdditivePot2, 4), 5); // > 4: compute win, not storage
        assert_eq!(field_bits(Codebook::PowerOfTwo, 1), 2);
        // Every width fits one 64-bit load like the uniform path.
        for bits in 1..=16u32 {
            for cbk in [Codebook::Uniform, Codebook::PowerOfTwo, Codebook::AdditivePot2] {
                assert!(field_bits(cbk, bits) <= 16, "{cbk:?} {bits}");
            }
        }
    }

    #[test]
    fn cbk_field_roundtrips_every_admissible_code() {
        // encode → decode is the identity on exactly the projected
        // code set, for every bitlength.
        for cbk in [Codebook::PowerOfTwo, Codebook::AdditivePot2] {
            for bits in 1..=16u32 {
                let proj = quant::CodeProjector::new(cbk, bits);
                let max_code = (1u64 << bits) - 1;
                let probes = [0u64, 1, max_code / 3, max_code / 2, max_code - 1, max_code];
                for &c in &probes {
                    let code = proj.project_code(c as u32);
                    let field = encode_field(cbk, bits, code);
                    assert!(field < 1 << field_bits(cbk, bits));
                    assert_eq!(
                        decode_field(cbk, bits, field),
                        Some(code),
                        "{cbk:?} bits={bits} code={code}"
                    );
                }
            }
        }
        // Exhaustive at 8 bits.
        for cbk in [Codebook::PowerOfTwo, Codebook::AdditivePot2] {
            let proj = quant::CodeProjector::new(cbk, 8);
            for c in 0..=255u32 {
                let code = proj.project_code(c);
                assert_eq!(decode_field(cbk, 8, encode_field(cbk, 8, code)), Some(code));
            }
        }
    }

    #[test]
    fn cbk_pack_uniform_delegates_byte_identical() {
        let mut rng = Rng::new(0xCBC0);
        for _ in 0..16 {
            let bits = 1 + rng.below(16) as u32;
            let xs: Vec<f32> =
                (0..1 + rng.below_usize(150)).map(|_| rng.normal_f32(0.0, 2.0)).collect();
            assert_eq!(pack_cbk(&xs, bits, Codebook::Uniform).unwrap(), pack(&xs, bits).unwrap());
            assert_eq!(
                pack_cbk_ref(&xs, bits, Codebook::Uniform).unwrap(),
                pack_ref(&xs, bits).unwrap()
            );
        }
    }

    #[test]
    fn cbk_word_packer_matches_ref_bitstream() {
        // Fused codebook packer vs scalar reference, bit-for-bit, over
        // random bitlengths / lengths / codebooks — and both unpackers
        // agree on the decoded grid codes.
        check(
            "bitpack-cbk-parity",
            256,
            |rng: &mut Rng| {
                let bits = 1 + rng.below(16) as u32;
                let len = 1 + rng.below_usize(130);
                let cbk = if rng.below(2) == 0 {
                    Codebook::PowerOfTwo
                } else {
                    Codebook::AdditivePot2
                };
                let xs: Vec<f32> =
                    (0..len).map(|_| rng.normal_f32(0.0, 2.0)).collect();
                (xs, bits, cbk)
            },
            |(xs, bits, cbk)| {
                let fast = pack_cbk(xs, *bits, *cbk).map_err(|e| e.to_string())?;
                let slow = pack_cbk_ref(xs, *bits, *cbk).map_err(|e| e.to_string())?;
                if fast != slow {
                    return Err(format!("packed tensors differ at {bits} bits {cbk:?}"));
                }
                let codes = unpack_codes(&fast);
                if codes != unpack_codes_ref(&fast) {
                    return Err("code unpack differs".into());
                }
                // Every decoded code is codebook-admissible and in grid
                // range.
                let proj = quant::CodeProjector::new(*cbk, *bits);
                let max_code = (1u64 << bits) - 1;
                for &c in &codes {
                    if c as u64 > max_code || !proj.admits(c) {
                        return Err(format!("code {c} inadmissible at {bits}b {cbk:?}"));
                    }
                }
                let (f, r) = (unpack(&fast), unpack_ref(&fast));
                if f.iter().zip(&r).any(|(a, b)| a.to_bits() != b.to_bits()) {
                    return Err("value unpack differs".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn cbk_from_raw_roundtrips_and_rejects_hostile() {
        let mut rng = Rng::new(0xCBC1);
        let xs: Vec<f32> = (0..53).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for cbk in [Codebook::PowerOfTwo, Codebook::AdditivePot2] {
            let p = pack_cbk(&xs, 8, cbk).unwrap();
            let re = PackedTensor::from_raw_cbk(
                p.bits, p.codebook, p.len, p.lmin, p.scale, p.data.clone(),
            )
            .unwrap();
            assert_eq!(re, p);
            // Wrong codebook: payload sized for cbk fields never fits
            // uniform 8-bit codes (4- or 7-bit fields vs 8).
            assert!(PackedTensor::from_raw(p.bits, p.len, p.lmin, p.scale, p.data.clone())
                .is_err());
            // Truncated / extended payloads.
            let short = p.data[..p.data.len() - 1].to_vec();
            assert!(PackedTensor::from_raw_cbk(8, cbk, p.len, p.lmin, p.scale, short).is_err());
            let mut long = p.data.clone();
            long.push(0);
            assert!(PackedTensor::from_raw_cbk(8, cbk, p.len, p.lmin, p.scale, long).is_err());
        }
        // Hostile field contents, PoT at 8 bits (fb = 4): sign = 1 with
        // idx = 0 is non-canonical negative zero.
        let neg_zero = vec![0x88u8]; // two fields, both 0b1000
        assert!(
            PackedTensor::from_raw_cbk(8, Codebook::PowerOfTwo, 2, 0.0, 1.0, neg_zero).is_err()
        );
        // APoT at 8 bits (fb = 7): i1 == i2 != 0 aliases a single power.
        let alias = (1u64 << 3) | 1; // i1 = 1, i2 = 1
        assert!(decode_field(Codebook::AdditivePot2, 8, alias).is_none());
        // i2 > i1 is non-canonical too.
        assert!(decode_field(Codebook::AdditivePot2, 8, (1 << 3) | 2).is_none());
        // n = 1: +1 falls off the grid (half = 1), and the packer never
        // emits it — but a hostile payload might.
        assert!(decode_field(Codebook::PowerOfTwo, 1, 0b01).is_none());
        assert!(decode_field(Codebook::PowerOfTwo, 1, 0b10).is_none()); // negative zero
        assert_eq!(decode_field(Codebook::PowerOfTwo, 1, 0b00), Some(1));
        assert_eq!(decode_field(Codebook::PowerOfTwo, 1, 0b11), Some(0));
        // Out-of-range exponent index: at 5 grid bits emax = 3, so the
        // 3-bit index space holds 0..=4 — raw indices 5..7 are hostile.
        assert_eq!(idx_bits(5), 3);
        for idx in 5..=7u64 {
            assert!(decode_field(Codebook::PowerOfTwo, 5, idx).is_none(), "idx {idx}");
        }
        assert_eq!(decode_field(Codebook::PowerOfTwo, 5, 4), Some(16 + 8)); // 2^3 + half
        // Nonzero pad bits after the last field are corruption.
        let p = pack_cbk(&xs[..3], 8, Codebook::PowerOfTwo).unwrap(); // 12 bits → 2 bytes
        let mut padded = p.data.clone();
        *padded.last_mut().unwrap() |= 0xF0;
        assert!(
            PackedTensor::from_raw_cbk(8, Codebook::PowerOfTwo, 3, p.lmin, p.scale, padded)
                .is_err()
        );
    }

    #[test]
    fn cbk_grouped_packer_matches_ref_and_roundtrips() {
        check(
            "bitpack-cbk-group-parity",
            128,
            |rng: &mut Rng| {
                let groups = 1 + rng.below_usize(8);
                let size = 1 + rng.below_usize(70);
                let cbk = if rng.below(2) == 0 {
                    Codebook::PowerOfTwo
                } else {
                    Codebook::AdditivePot2
                };
                let xs: Vec<f32> =
                    (0..groups * size).map(|_| rng.normal_f32(0.0, 2.0)).collect();
                let bits: Vec<u32> =
                    (0..groups).map(|_| 1 + rng.below(16) as u32).collect();
                (xs, size, bits, cbk)
            },
            |(xs, size, bits, cbk)| {
                let fast =
                    pack_groups_cbk(xs, *size, bits, *cbk).map_err(|e| e.to_string())?;
                let slow =
                    pack_groups_cbk_ref(xs, *size, bits, *cbk).map_err(|e| e.to_string())?;
                if fast != slow {
                    return Err("grouped byte streams differ".into());
                }
                for g in 0..fast.n_groups() {
                    let codes = fast.group_codes(g);
                    if codes != fast.group_codes_ref(g) {
                        return Err(format!("group {g} unpack differs"));
                    }
                    let proj = quant::CodeProjector::new(*cbk, bits[g]);
                    if codes.iter().any(|&c| !proj.admits(c)) {
                        return Err(format!("group {g} has inadmissible codes"));
                    }
                }
                // Wire roundtrip through the untrusted loader.
                let params: Vec<(u32, f32, f32)> =
                    fast.spans.iter().map(|s| (s.bits, s.lmin, s.scale)).collect();
                let re =
                    PackedGroups::from_raw_cbk(*size, *cbk, &params, fast.data.clone())
                        .map_err(|e| e.to_string())?;
                if re != fast {
                    return Err("from_raw_cbk roundtrip differs".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn cbk_grouped_from_raw_rejects_hostile() {
        let mut rng = Rng::new(0xCBC2);
        let xs: Vec<f32> = (0..3 * 21).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let bits = [8u32, 4, 8];
        let p = pack_groups_cbk(&xs, 21, &bits, Codebook::PowerOfTwo).unwrap();
        let params: Vec<(u32, f32, f32)> =
            p.spans.iter().map(|s| (s.bits, s.lmin, s.scale)).collect();
        // Mismatched codebook: span sizing changes, payload length fails.
        assert!(PackedGroups::from_raw(21, &params, p.data.clone()).is_err());
        // Corrupt one field of group 0 into negative zero (0b1000).
        let mut bad = p.data.clone();
        bad[0] = 0x88;
        assert!(
            PackedGroups::from_raw_cbk(21, Codebook::PowerOfTwo, &params, bad).is_err()
        );
        // Pad-bit corruption inside a group span: size 21 at fb 4 →
        // 84 bits → 11 bytes, 4 pad bits in the last byte of group 0.
        let mut pad = p.data.clone();
        pad[p.spans[1].start - 1] |= 0xF0;
        assert!(
            PackedGroups::from_raw_cbk(21, Codebook::PowerOfTwo, &params, pad).is_err()
        );
        // Faithful parts still load.
        assert_eq!(
            PackedGroups::from_raw_cbk(21, Codebook::PowerOfTwo, &params, p.data.clone())
                .unwrap(),
            p
        );
    }

    #[test]
    fn cbk_weightcodes_surface() {
        let mut rng = Rng::new(0xCBC3);
        let xs: Vec<f32> = (0..4 * 32).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let pl = WeightCodes::PerLayer(pack_cbk(&xs, 8, Codebook::PowerOfTwo).unwrap());
        assert_eq!(pl.codebook(), Codebook::PowerOfTwo);
        assert_eq!(pl.max_bits(), 8); // grid bits, not field bits
        let pc = WeightCodes::PerChannel(
            pack_groups_cbk(&xs, 32, &[4, 8, 2, 8], Codebook::AdditivePot2).unwrap(),
        );
        assert_eq!(pc.codebook(), Codebook::AdditivePot2);
        let uni = WeightCodes::PerLayer(pack(&xs, 8).unwrap());
        assert_eq!(uni.codebook(), Codebook::Uniform);
        // PoT per-layer payload is half the uniform one at 8 bits.
        assert_eq!(pl.payload().len(), uni.payload().len().div_ceil(2));
    }

    #[test]
    fn miri_rolling_window_unpack_matches_ref() {
        // The in-register rolling-window unpacker vs the byte-at-a-time
        // reference, over every field width and awkward lengths (window
        // refills land at different phases for co-prime fb/len).  The
        // miri_ prefix routes this through the CI `cargo miri test`
        // job, UB-checking the window arithmetic.
        let mut rng = Rng::new(0x33AA);
        for fb in 1u32..=16 {
            for &n in &[0usize, 1, 2, 7, 8, 9, 63, 64, 65, 129, 257] {
                let total_bits = n * fb as usize;
                let mut data = vec![0u8; total_bits.div_ceil(8)];
                for b in data.iter_mut() {
                    *b = rng.next_u64() as u8;
                }
                let mut fast = Vec::with_capacity(n);
                let mut slow = Vec::with_capacity(n);
                unpack_fields_into(&data, fb, n, |f| fast.push(f));
                unpack_fields_ref(&data, fb, n, |f| slow.push(f));
                assert_eq!(fast, slow, "fb={fb} n={n}");
            }
        }
    }

    #[test]
    fn miri_unpack_codes_roundtrip_all_widths() {
        // End-to-end over the public surface the GEMM consumes: pack,
        // then the rolling-window unpack_codes must match the scalar
        // reference — uniform and codebook fields, per-layer and
        // grouped (byte-aligned subslice windows).
        let mut rng = Rng::new(0x7B1D);
        for bits in 1u32..=16 {
            let xs: Vec<f32> = (0..77).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let p = pack(&xs, bits).unwrap();
            assert_eq!(unpack_codes(&p), unpack_codes_ref(&p), "uniform bits={bits}");
            let pc = pack_cbk(&xs, bits, Codebook::PowerOfTwo).unwrap();
            assert_eq!(unpack_codes(&pc), unpack_codes_ref(&pc), "pot bits={bits}");
        }
        let xs: Vec<f32> = (0..5 * 19).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let g = pack_groups(&xs, 19, &[1, 4, 7, 13, 16]).unwrap();
        for i in 0..5 {
            assert_eq!(g.group_codes(i), g.group_codes_ref(i), "group {i}");
        }
        let gc = pack_groups_cbk(&xs, 19, &[2, 4, 6, 8, 5], Codebook::AdditivePot2).unwrap();
        for i in 0..5 {
            assert_eq!(gc.group_codes(i), gc.group_codes_ref(i), "apot group {i}");
        }
    }
}
