//! Runtime kernel dispatch for the integer GEMM.
//!
//! The hot multiply kernels come in three flavors selected once per
//! process from the CPU actually running:
//!
//! - **AVX2** (x86_64): `_mm256_madd_epi16` i32-lane dot kernels, plus
//!   a 16-lane `_mm256_mullo_epi16`/`_mm256_add_epi16` i16 kernel for
//!   layers whose whole dot product fits an i16
//!   ([`crate::quant::AccWidth::I16`]).
//! - **NEON** (aarch64): `vmlal_s16` widening multiply-accumulate into
//!   i32 lanes.
//! - **Portable**: scalar Rust with i32 accumulators (no `std::arch`),
//!   the fallback every other path must match bit-for-bit.
//!
//! Detection runs exactly once ([`OnceLock`]); `BITPRUNE_FORCE_PORTABLE=1`
//! in the environment pins the portable fallback for a whole process
//! (the CI dispatch matrix uses this), and [`force_portable`] pins it
//! from inside a process (benches, parity tests).  Narrow lanes are
//! only *dispatched* when the layer's stored [`crate::quant::acc_width`]
//! proves the accumulator cannot wrap, so every kernel here computes
//! the exact same integer sum as the scalar i64 reference — dispatch
//! can change speed, never results.
//!
//! Under miri every `std::arch` intrinsic is cfg'd out and detection
//! resolves to `Portable`, so the UB checker exercises the portable
//! kernels and the in-register unpack helpers without hitting
//! unsupported vendor intrinsics.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Which kernel family the dispatcher resolved to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelPath {
    /// x86_64 AVX2 `std::arch` kernels.
    Avx2,
    /// aarch64 NEON `std::arch` kernels.
    Neon,
    /// Scalar Rust fallback (also the miri and forced-portable path).
    Portable,
}

impl KernelPath {
    /// Short cpu-feature string ("avx2" / "neon" / "portable") — what
    /// the bench JSONL and serve startup logs emit.
    pub fn name(self) -> &'static str {
        match self {
            KernelPath::Avx2 => "avx2",
            KernelPath::Neon => "neon",
            KernelPath::Portable => "portable",
        }
    }
}

static DETECTED: OnceLock<KernelPath> = OnceLock::new();
/// 1 = portable pinned via [`force_portable`]; 0 = use detection.
static FORCED: AtomicU8 = AtomicU8::new(0);

fn detect() -> KernelPath {
    #[cfg(not(miri))]
    {
        let forced_env = std::env::var("BITPRUNE_FORCE_PORTABLE")
            .map(|v| !matches!(v.as_str(), "" | "0"))
            .unwrap_or(false);
        if forced_env {
            return KernelPath::Portable;
        }
        #[cfg(target_arch = "x86_64")]
        if std::arch::is_x86_feature_detected!("avx2") {
            return KernelPath::Avx2;
        }
        #[cfg(target_arch = "aarch64")]
        if std::arch::is_aarch64_feature_detected!("neon") {
            return KernelPath::Neon;
        }
    }
    KernelPath::Portable
}

/// The once-detected path for this process (environment override
/// included, [`force_portable`] excluded).
pub fn detected_path() -> KernelPath {
    *DETECTED.get_or_init(detect)
}

/// The path the GEMM dispatch actually uses right now.
#[inline]
pub fn kernel_path() -> KernelPath {
    if FORCED.load(Ordering::Relaxed) != 0 {
        KernelPath::Portable
    } else {
        detected_path()
    }
}

/// Pin the portable scalar fallback (`true`) or restore detection
/// (`false`).  Process-global; used by the benches and the dispatch
/// parity tests to compare paths inside one process.  Every kernel is
/// bit-identical, so flipping this mid-flight can only change speed,
/// never results.
pub fn force_portable(on: bool) {
    FORCED.store(on as u8, Ordering::Relaxed);
}

/// Human-readable dispatch description for logs: active path, arch,
/// and whether the portable fallback was forced.
pub fn describe() -> String {
    let active = kernel_path();
    let detected = detected_path();
    let arch = std::env::consts::ARCH;
    if active == detected {
        format!("{} (arch {arch})", active.name())
    } else {
        format!(
            "{} (arch {arch}, detected {}, portable forced)",
            active.name(),
            detected.name()
        )
    }
}

/// Portable narrow-lane kernel: `[Σ a·w0, Σ a·w1, Σ a·w2, Σ a·w3]`
/// with scalar i32 accumulators (auto-vectorizable; no `std::arch`).
///
/// Contract (guaranteed by [`crate::quant::acc_width`] selection at
/// layer construction): each dot product fits an i32, so the i32
/// accumulation cannot wrap and the result equals the i64 reference
/// exactly.
pub(crate) fn dot4_i32_portable(
    a: &[u16],
    w0: &[u16],
    w1: &[u16],
    w2: &[u16],
    w3: &[u16],
) -> [i64; 4] {
    let (mut s0, mut s1, mut s2, mut s3) = (0i32, 0i32, 0i32, 0i32);
    for ((((&av, &x0), &x1), &x2), &x3) in
        a.iter().zip(w0).zip(w1).zip(w2).zip(w3)
    {
        let av = av as i32;
        s0 += av * x0 as i32;
        s1 += av * x1 as i32;
        s2 += av * x2 as i32;
        s3 += av * x3 as i32;
    }
    [s0 as i64, s1 as i64, s2 as i64, s3 as i64]
}

#[cfg(all(target_arch = "x86_64", not(miri)))]
mod x86 {
    use std::arch::x86_64::*;

    /// Horizontal sum of 8 i32 lanes, widened to i64 before adding so
    /// the reduction itself cannot wrap.
    #[target_feature(enable = "avx2")]
    unsafe fn sum_i32_lanes(v: __m256i) -> i64 {
        let mut tmp = [0i32; 8];
        _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, v);
        tmp.iter().map(|&x| x as i64).sum()
    }

    /// Horizontal sum of 16 i16 lanes, widened to i64.
    #[target_feature(enable = "avx2")]
    unsafe fn sum_i16_lanes(v: __m256i) -> i64 {
        let mut tmp = [0i16; 16];
        _mm256_storeu_si256(tmp.as_mut_ptr() as *mut __m256i, v);
        tmp.iter().map(|&x| x as i64).sum()
    }

    /// i32-lane AVX2 dot kernel over four weight rows.
    ///
    /// `_mm256_madd_epi16` multiplies adjacent i16 pairs and sums each
    /// pair into an i32 lane.  Contract (from `AccWidth <= I32`
    /// selection): every code `<= 2^15 − 1` and the whole dot product
    /// fits an i32 — so each pair-sum `< 2^31` and each lane's running
    /// total (a subset of the nonnegative full sum) cannot wrap.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot4_i32(
        a: &[u16],
        w0: &[u16],
        w1: &[u16],
        w2: &[u16],
        w3: &[u16],
    ) -> [i64; 4] {
        let n = a.len();
        debug_assert!(
            w0.len() == n && w1.len() == n && w2.len() == n && w3.len() == n
        );
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        let mut acc2 = _mm256_setzero_si256();
        let mut acc3 = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 16 <= n {
            let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
            let v0 = _mm256_loadu_si256(w0.as_ptr().add(i) as *const __m256i);
            let v1 = _mm256_loadu_si256(w1.as_ptr().add(i) as *const __m256i);
            let v2 = _mm256_loadu_si256(w2.as_ptr().add(i) as *const __m256i);
            let v3 = _mm256_loadu_si256(w3.as_ptr().add(i) as *const __m256i);
            acc0 = _mm256_add_epi32(acc0, _mm256_madd_epi16(va, v0));
            acc1 = _mm256_add_epi32(acc1, _mm256_madd_epi16(va, v1));
            acc2 = _mm256_add_epi32(acc2, _mm256_madd_epi16(va, v2));
            acc3 = _mm256_add_epi32(acc3, _mm256_madd_epi16(va, v3));
            i += 16;
        }
        let mut out = [
            sum_i32_lanes(acc0),
            sum_i32_lanes(acc1),
            sum_i32_lanes(acc2),
            sum_i32_lanes(acc3),
        ];
        while i < n {
            let av = a[i] as i64;
            out[0] += av * w0[i] as i64;
            out[1] += av * w1[i] as i64;
            out[2] += av * w2[i] as i64;
            out[3] += av * w3[i] as i64;
            i += 1;
        }
        out
    }

    /// 16-lane i16 AVX2 dot kernel for `AccWidth::I16` layers.
    ///
    /// Contract: the *whole* dot product fits an i16.  All products are
    /// nonnegative, so every per-lane partial sum is a subset of the
    /// full sum and stays `< 2^15` (no i16 wrap), and each product is
    /// `<` the full sum so `_mm256_mullo_epi16`'s low 16 bits are the
    /// exact product.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn dot4_i16(
        a: &[u16],
        w0: &[u16],
        w1: &[u16],
        w2: &[u16],
        w3: &[u16],
    ) -> [i64; 4] {
        let n = a.len();
        debug_assert!(
            w0.len() == n && w1.len() == n && w2.len() == n && w3.len() == n
        );
        let mut acc0 = _mm256_setzero_si256();
        let mut acc1 = _mm256_setzero_si256();
        let mut acc2 = _mm256_setzero_si256();
        let mut acc3 = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 16 <= n {
            let va = _mm256_loadu_si256(a.as_ptr().add(i) as *const __m256i);
            let v0 = _mm256_loadu_si256(w0.as_ptr().add(i) as *const __m256i);
            let v1 = _mm256_loadu_si256(w1.as_ptr().add(i) as *const __m256i);
            let v2 = _mm256_loadu_si256(w2.as_ptr().add(i) as *const __m256i);
            let v3 = _mm256_loadu_si256(w3.as_ptr().add(i) as *const __m256i);
            acc0 = _mm256_add_epi16(acc0, _mm256_mullo_epi16(va, v0));
            acc1 = _mm256_add_epi16(acc1, _mm256_mullo_epi16(va, v1));
            acc2 = _mm256_add_epi16(acc2, _mm256_mullo_epi16(va, v2));
            acc3 = _mm256_add_epi16(acc3, _mm256_mullo_epi16(va, v3));
            i += 16;
        }
        let mut out = [
            sum_i16_lanes(acc0),
            sum_i16_lanes(acc1),
            sum_i16_lanes(acc2),
            sum_i16_lanes(acc3),
        ];
        while i < n {
            let av = a[i] as i64;
            out[0] += av * w0[i] as i64;
            out[1] += av * w1[i] as i64;
            out[2] += av * w2[i] as i64;
            out[3] += av * w3[i] as i64;
            i += 1;
        }
        out
    }
}

#[cfg(all(target_arch = "aarch64", not(miri)))]
mod arm {
    use std::arch::aarch64::*;

    /// i32-lane NEON dot kernel over four weight rows: `vmlal_s16`
    /// widening multiply-accumulate.  Contract (from `AccWidth <= I32`
    /// selection): every code `<= 2^15 − 1` and the whole dot product
    /// fits an i32, so each lane's running total (a subset of the
    /// nonnegative full sum) cannot wrap; `vaddlvq_s32` widens to i64
    /// during the final reduction.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn dot4_i32(
        a: &[u16],
        w0: &[u16],
        w1: &[u16],
        w2: &[u16],
        w3: &[u16],
    ) -> [i64; 4] {
        let n = a.len();
        debug_assert!(
            w0.len() == n && w1.len() == n && w2.len() == n && w3.len() == n
        );
        let mut acc0 = vdupq_n_s32(0);
        let mut acc1 = vdupq_n_s32(0);
        let mut acc2 = vdupq_n_s32(0);
        let mut acc3 = vdupq_n_s32(0);
        let mut i = 0usize;
        while i + 8 <= n {
            let va = vreinterpretq_s16_u16(vld1q_u16(a.as_ptr().add(i)));
            let v0 = vreinterpretq_s16_u16(vld1q_u16(w0.as_ptr().add(i)));
            let v1 = vreinterpretq_s16_u16(vld1q_u16(w1.as_ptr().add(i)));
            let v2 = vreinterpretq_s16_u16(vld1q_u16(w2.as_ptr().add(i)));
            let v3 = vreinterpretq_s16_u16(vld1q_u16(w3.as_ptr().add(i)));
            acc0 = vmlal_s16(acc0, vget_low_s16(va), vget_low_s16(v0));
            acc0 = vmlal_high_s16(acc0, va, v0);
            acc1 = vmlal_s16(acc1, vget_low_s16(va), vget_low_s16(v1));
            acc1 = vmlal_high_s16(acc1, va, v1);
            acc2 = vmlal_s16(acc2, vget_low_s16(va), vget_low_s16(v2));
            acc2 = vmlal_high_s16(acc2, va, v2);
            acc3 = vmlal_s16(acc3, vget_low_s16(va), vget_low_s16(v3));
            acc3 = vmlal_high_s16(acc3, va, v3);
            i += 8;
        }
        let mut out = [
            vaddlvq_s32(acc0),
            vaddlvq_s32(acc1),
            vaddlvq_s32(acc2),
            vaddlvq_s32(acc3),
        ];
        while i < n {
            let av = a[i] as i64;
            out[0] += av * w0[i] as i64;
            out[1] += av * w1[i] as i64;
            out[2] += av * w2[i] as i64;
            out[3] += av * w3[i] as i64;
            i += 1;
        }
        out
    }
}

/// Narrow-lane 4-column dot product, dispatched over `path`.
///
/// `i16_lanes` requests the 16-lane i16 kernel (only meaningful on
/// AVX2; other paths run their i32 kernel, which is also exact for
/// I16-lane layers).  Contract: callers pass a `path` obtained from
/// [`kernel_path`] (so a SIMD path implies the feature is present) and
/// only dispatch layers whose [`crate::quant::AccWidth`] is at most
/// `I32` (`I16` when `i16_lanes`).
#[allow(unused_variables)] // `i16_lanes` is only read on x86_64 non-miri builds
#[inline]
pub(crate) fn dot4(
    path: KernelPath,
    i16_lanes: bool,
    a: &[u16],
    w0: &[u16],
    w1: &[u16],
    w2: &[u16],
    w3: &[u16],
) -> [i64; 4] {
    match path {
        #[cfg(all(target_arch = "x86_64", not(miri)))]
        KernelPath::Avx2 => unsafe {
            if i16_lanes {
                x86::dot4_i16(a, w0, w1, w2, w3)
            } else {
                x86::dot4_i32(a, w0, w1, w2, w3)
            }
        },
        #[cfg(all(target_arch = "aarch64", not(miri)))]
        KernelPath::Neon => unsafe { arm::dot4_i32(a, w0, w1, w2, w3) },
        _ => dot4_i32_portable(a, w0, w1, w2, w3),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn dot4_i64_ref(
        a: &[u16],
        w0: &[u16],
        w1: &[u16],
        w2: &[u16],
        w3: &[u16],
    ) -> [i64; 4] {
        let mut out = [0i64; 4];
        for (i, &av) in a.iter().enumerate() {
            let av = av as i64;
            out[0] += av * w0[i] as i64;
            out[1] += av * w1[i] as i64;
            out[2] += av * w2[i] as i64;
            out[3] += av * w3[i] as i64;
        }
        out
    }

    fn rand_codes(rng: &mut Rng, n: usize, bits: u32) -> Vec<u16> {
        (0..n).map(|_| rng.below(1u64 << bits) as u16).collect()
    }

    /// Portable narrow-lane kernel vs the i64 scalar reference — the
    /// miri leg of the unsafe-code gate runs this (pure safe Rust).
    #[test]
    fn miri_portable_dot4_matches_i64_reference() {
        let mut rng = Rng::new(0x51_3D);
        for case in 0..64 {
            let n = (case * 7) % 100;
            // 8+8+ceil(log2(100)) = 23 <= 31: i32 accumulation safe.
            let a = rand_codes(&mut rng, n, 8);
            let w: Vec<Vec<u16>> =
                (0..4).map(|_| rand_codes(&mut rng, n, 8)).collect();
            assert_eq!(
                dot4_i32_portable(&a, &w[0], &w[1], &w[2], &w[3]),
                dot4_i64_ref(&a, &w[0], &w[1], &w[2], &w[3]),
            );
        }
    }

    /// The dispatched kernel (whatever this host resolves to) is
    /// bit-identical to the scalar i64 reference, for both lane hints,
    /// across remainder lengths.
    #[test]
    fn dispatched_dot4_matches_i64_reference_both_lane_hints() {
        let mut rng = Rng::new(0xD15_9A7C);
        let path = kernel_path();
        for case in 0..128 {
            let n = (case * 13) % 200;
            // i16-hint inputs: 4+4+ceil(log2(200)) = 16 > 15, so cap n
            // at 120 with 3-bit codes: 3+3+7 = 13 <= 15.
            let n16 = n.min(120);
            let a16 = rand_codes(&mut rng, n16, 3);
            let w16: Vec<Vec<u16>> =
                (0..4).map(|_| rand_codes(&mut rng, n16, 3)).collect();
            assert_eq!(
                dot4(path, true, &a16, &w16[0], &w16[1], &w16[2], &w16[3]),
                dot4_i64_ref(&a16, &w16[0], &w16[1], &w16[2], &w16[3]),
            );
            let a = rand_codes(&mut rng, n, 8);
            let w: Vec<Vec<u16>> =
                (0..4).map(|_| rand_codes(&mut rng, n, 8)).collect();
            assert_eq!(
                dot4(path, false, &a, &w[0], &w[1], &w[2], &w[3]),
                dot4_i64_ref(&a, &w[0], &w[1], &w[2], &w[3]),
            );
        }
    }

    /// Adversarial max-magnitude codes right at the lane boundary: the
    /// i16 kernel at the largest sum that still fits i16, the i32
    /// kernels at a 31-bit-boundary shape.
    #[test]
    fn dot4_at_lane_boundaries_max_magnitude() {
        let path = kernel_path();
        // 4+4+7 = 15: din 128 of all-max 4-bit codes, acc = 128·225.
        let a = vec![15u16; 128];
        let w = vec![15u16; 128];
        let expect = [128i64 * 225; 4];
        assert_eq!(dot4(path, true, &a, &w, &w, &w, &w), expect);
        assert_eq!(dot4_i32_portable(&a, &w, &w, &w, &w), expect);
        // 11+11+9 = 31: din 512 of all-max 11-bit codes fits i32.
        let a = vec![2047u16; 512];
        let w = vec![2047u16; 512];
        let expect = [512i64 * 2047 * 2047; 4];
        assert_eq!(dot4(path, false, &a, &w, &w, &w, &w), expect);
        assert_eq!(dot4_i32_portable(&a, &w, &w, &w, &w), expect);
    }

    /// `force_portable` pins the fallback and restores cleanly.  (Only
    /// this test toggles the hook inside the lib test binary, so the
    /// restore assertion cannot race.)
    #[test]
    fn miri_force_portable_pins_and_restores() {
        force_portable(true);
        assert_eq!(kernel_path(), KernelPath::Portable);
        force_portable(false);
        assert_eq!(kernel_path(), detected_path());
    }

    /// The CI dispatch matrix runs the suites with
    /// `BITPRUNE_FORCE_PORTABLE=1` and with `-C target-feature=+avx2`;
    /// this pins what each leg must resolve to.
    #[cfg(not(miri))]
    #[test]
    fn env_and_build_flags_resolve_expected_path() {
        let forced_env = std::env::var("BITPRUNE_FORCE_PORTABLE")
            .map(|v| !matches!(v.as_str(), "" | "0"))
            .unwrap_or(false);
        if forced_env {
            assert_eq!(detected_path(), KernelPath::Portable);
        } else if cfg!(all(target_arch = "x86_64", target_feature = "avx2")) {
            // Compiled with AVX2 statically enabled: runtime detection
            // on the same machine must agree.
            assert_eq!(detected_path(), KernelPath::Avx2);
        }
        // Whatever was resolved, the describe string carries the
        // cpu-feature token the bench JSONL embeds.
        let d = describe();
        assert!(
            d.starts_with(kernel_path().name()),
            "describe() = {d:?} should lead with the active path"
        );
    }
}
