//! Pure-integer inference engine: proof that the learned bitlengths
//! deploy on real fixed-point hardware.
//!
//! The training stack fake-quantizes in f32 (Q_r returns floats on the
//! quantization grid).  Deployment hardware stores `n`-bit integer
//! codes and accumulates in wide integers.  This module executes a
//! trained dense network that way:
//!
//! ```text
//! a = a_min + a_code·a_s          (activation codes; calibrated or batch min/max)
//! w = w_min + w_code·w_s          (weight codes packed at n_w bits)
//! Σ a·w = a_s·w_s·Σ a_code·w_code            <- i64 integer core
//!       + a_s·w_min·Σ a_code                 <- i64 row sum
//!       + w_s·a_min·Σ w_code                 <- precomputed column sum
//!       + K·a_min·w_min
//! ```
//!
//! The integration test checks that logits and accuracy match the
//! compiled XLA eval artifact at the same (integer) bitlengths — i.e.
//! the affine-decomposed integer path and the float fake-quant path are
//! the same computation.
//!
//! Fast path: at construction the weight codes are **tiled** into a
//! transposed `[dout, din]` layout so the GEMM inner loop streams both
//! operands contiguously (the packed row-major `[din, dout]` layout
//! walks column-wise with a `dout`-stride — cache-hostile).  `forward`
//! register-blocks four output columns per pass over an activation row,
//! hoists the affine-reconstruction terms out of the inner loop into
//! per-row / per-column f64 tables, and splits large batches across
//! `std::thread::scope` workers.  The original `(r, j, c)` triple loop
//! is retained as [`IntDense::forward_ref`]; because the i64 core is
//! exact under reassociation and the reconstruction expression is
//! shared, the two paths are bit-identical (pinned by the
//! `fastpath_parity` tests).
//!
//! Activation ranges: by default each batch quantizes against its own
//! min/max (the training-time convention, paper §II-A) — which makes a
//! sample's logits depend on what else shares its batch.  Deployment
//! instead uses **static calibrated ranges** (one `(lo, hi)` per layer,
//! e.g. aggregated over the test set by the trainer's eval pass):
//! attach them via [`IntNet::from_trained`] / [`IntNet::set_act_ranges`]
//! / [`IntNet::calibrate`] and per-sample logits become **bit-identical
//! for every batch composition** — the batch-invariance guarantee the
//! `serve` subsystem builds on.  The dynamic per-batch fallback stays
//! available (and applies to both `forward` and `forward_ref`, which
//! share `quantize_acts`, so fast/ref parity holds either way).
//!
//! ## Layer ops
//!
//! The network is a sequence of [`IntLayer`] ops — today
//! `Dense(IntDense)` and `Conv2d(IntConv2d)`.  Every consumer
//! ([`IntNet`], [`NetScratch`]/`forward_into`, the serve engine,
//! `deploy::freeze`/`instantiate`) operates on the op enum, not on
//! `IntDense` directly, so new layer kinds slot in behind one match.
//!
//! [`IntConv2d`] lowers to the *same* blocked/grouped integer GEMM via
//! an im2col packing stage: the `[n, h, w, cin]` activation plane is
//! expanded into `[n·out_h·out_w, kh·kw·cin]` patch rows and fed to an
//! inner [`IntDense`] core whose `din` is the patch length and whose
//! `dout` is the output-channel count.  Per-output-channel weight
//! granularity therefore becomes **per-output-kernel** granularity for
//! free (each group spans one kernel's `kh·kw·cin` taps).  The scratch
//! path keeps a reusable im2col buffer in [`LayerScratch`], so serving
//! does not allocate per forward after warm-up, and a scalar
//! `forward_ref` gather is retained bit-exact against the fast packing
//! (the expanded values are identical, and the core GEMM is already
//! pinned fast-vs-ref).

pub mod simd;

use std::time::Instant;

use anyhow::{bail, Result};

use crate::bitpack::{
    pack, pack_cbk, pack_groups, pack_groups_cbk, unpack_codes, PackedGroups, PackedTensor,
    WeightCodes,
};
use crate::model::ModelMeta;
use crate::quant::{self, AccWidth, Codebook, Granularity};
use crate::tensor::HostTensor;
use crate::util::pool::WorkerPool;

/// Below this many MACs per call the GEMM stays single-threaded (the
/// spawn cost would dominate).
const PAR_MIN_MACS: usize = 1 << 20;

/// One integer-quantized dense layer.
///
/// Weight codes are stored at either [`Granularity`]:
/// **PerLayer** (one bitlength + `(lmin, scale)` plan for the whole
/// tensor, the original path) or **PerOutputChannel** (each output
/// channel packed at its own learned bitlength against its own range —
/// the sub-layer granularity the paper's "at any granularity" claim
/// needs).  Both granularities share the tiled `codes_t` layout, the
/// activation quantizer and the blocked i64 GEMM structure; only the
/// affine reconstruction differs (scalar vs per-column tables).
pub struct IntDense {
    pub name: String,
    pub din: usize,
    pub dout: usize,
    /// Packed weight codes at their stored granularity.
    pub weights: WeightCodes,
    /// Tiled (transposed) codes, [dout, din]: row `j` holds output
    /// column j's weights contiguously — what the blocked GEMM streams
    /// (u16 is enough for <=16 bits). The row-major layout is not
    /// cached; [`Self::forward_ref`] re-unpacks it on demand.
    codes_t: Vec<u16>,
    /// Σ over din of w_code for each output column (i64 per dout).
    col_code_sum: Vec<i64>,
    pub bias: Vec<f32>,
    /// Activation bitlength for this layer's input.
    pub a_bits: u32,
    pub relu: bool,
    /// Calibrated activation range for this layer's input.  `None`
    /// falls back to each batch's own min/max (batch-dependent logits).
    act_range: Option<(f32, f32)>,
    /// Shift-add execution plan, present iff the weight codebook is
    /// non-uniform.  Built once at construction from the tiled codes;
    /// the GEMM dispatch prefers it over the multiply kernels.
    shift: Option<ShiftPlan>,
    /// Narrowest provably-safe accumulator lane for this layer's
    /// integer core ([`quant::acc_width`] from the stored plan bits;
    /// the widest group's, for grouped layers).  `I64` keeps the
    /// original wide kernels; narrower lanes dispatch the SIMD /
    /// portable-i32 kernels.
    lane: AccWidth,
    /// Per-output-channel lane widths for grouped layers (each group
    /// packs at its own bitlength, so each earns its own width); empty
    /// for per-layer granularity.
    group_lanes: Vec<AccWidth>,
}

/// Shift-add execution plan for a non-uniform-codebook layer.
///
/// Under a sparse-bit codebook every stored code is `half + c_s` with
/// `half = 2^(bits-1)` and `c_s` a signed magnitude whose absolute
/// value has at most two set bits (one for [`Codebook::PowerOfTwo`],
/// two for [`Codebook::AdditivePot2`]).  The i64 GEMM core therefore
/// decomposes exactly:
///
/// ```text
/// Σ_i a[i]·code[i,j] = half_j·(Σ_i a[i])  +  Σ ±(a[i] << e)
/// ```
///
/// — the per-row activation code sum (already computed by the
/// quantizer for the affine terms) carries the `half` offset, and the
/// residual is a short CSR list of shift-adds with **no multiplies**.
/// Since i64 addition is exact under reassociation, the shift kernels
/// produce the *same integer accumulator* as the multiply kernels, so
/// fast-vs-ref stays bit-identical (pinned by the parity tests).
#[derive(Debug, Default)]
struct ShiftPlan {
    /// Per output column: `(start, mid, end)` into `entries` —
    /// `entries[start..mid]` add, `entries[mid..end]` subtract.
    col: Vec<(u32, u32, u32)>,
    /// `(input index, shift)` terms; an APoT weight contributes up to
    /// two entries, a PoT weight at most one, a zero weight none.
    entries: Vec<(u32, u8)>,
    /// Per output column: `bits_j - 1`, the shift applying the `half`
    /// offset (`half_j·rsum = rsum << (bits_j - 1)` — row code sums are
    /// non-negative, so even this term is multiply-free).
    half_sh: Vec<u8>,
}

impl ShiftPlan {
    /// Build from the tiled `[dout, din]` codes; `bits_of(j)` is
    /// output column j's stored bitlength.  Decomposing each code's
    /// signed part bit-by-bit is codebook-agnostic (correct for any
    /// codes), but only sparse-bit codebooks keep the entry list short
    /// enough to beat the multiply kernel.
    fn build(codes_t: &[u16], din: usize, dout: usize, bits_of: impl Fn(usize) -> u32) -> Self {
        let mut col = Vec::with_capacity(dout);
        let mut entries = Vec::new();
        let mut half_sh = Vec::with_capacity(dout);
        for j in 0..dout {
            let b = bits_of(j);
            let hu = 1u16 << (b - 1);
            half_sh.push((b - 1) as u8);
            let codes = &codes_t[j * din..(j + 1) * din];
            let start = entries.len() as u32;
            for (i, &c) in codes.iter().enumerate() {
                if c > hu {
                    let mut m = (c - hu) as u32;
                    while m != 0 {
                        entries.push((i as u32, m.trailing_zeros() as u8));
                        m &= m - 1;
                    }
                }
            }
            let mid = entries.len() as u32;
            for (i, &c) in codes.iter().enumerate() {
                if c < hu {
                    let mut m = (hu - c) as u32;
                    while m != 0 {
                        entries.push((i as u32, m.trailing_zeros() as u8));
                        m &= m - 1;
                    }
                }
            }
            let end = entries.len() as u32;
            col.push((start, mid, end));
        }
        Self { col, entries, half_sh }
    }

    /// One column's shift-add accumulation over one activation row:
    /// exactly `Σ_i a_row[i]·code[i,j]` as the multiply kernel computes
    /// it, with zero multiplies.
    #[inline]
    fn col_acc(&self, j: usize, a_row: &[u16], row_code_sum: i64) -> i64 {
        let (start, mid, end) = self.col[j];
        let mut acc = row_code_sum << self.half_sh[j];
        for &(idx, sh) in &self.entries[start as usize..mid as usize] {
            acc += (a_row[idx as usize] as i64) << sh;
        }
        for &(idx, sh) in &self.entries[mid as usize..end as usize] {
            acc -= (a_row[idx as usize] as i64) << sh;
        }
        acc
    }
}

/// Hoisted per-output-channel affine tables for the grouped GEMM, all
/// len `dout`: `s[j] = w_scale_j·a_scale`, `awmin[j] = a_scale·w_min_j`
/// (multiplies the per-row code sum), `kwmin[j] = (K·a_min)·w_min_j`,
/// and `u[j]` folding the column code sum and bias.  The f64
/// association of every product mirrors the per-layer
/// [`IntDense::affine_terms`] exactly, which is what makes
/// uniform-plan grouped layers bit-identical to per-layer ones.
#[derive(Debug, Default)]
struct GroupedCols {
    s: Vec<f64>,
    awmin: Vec<f64>,
    kwmin: Vec<f64>,
    u: Vec<f64>,
}

/// Reusable per-layer scratch for [`IntDense::forward_scratch`]: the
/// activation codes, row code sums and hoisted affine tables that
/// `forward` otherwise allocates fresh on every call.  The `g*` fields
/// are the per-output-channel tables of the grouped path.
#[derive(Debug, Default)]
pub struct LayerScratch {
    codes: Vec<u16>,
    row_sum: Vec<i64>,
    t: Vec<f64>,
    u: Vec<f64>,
    gcols: GroupedCols,
    /// im2col patch-row buffer for [`IntConv2d::forward_scratch`]
    /// (empty for dense layers).
    im2col: Vec<f32>,
}

/// Reusable whole-network buffers for [`IntNet::forward_into`]:
/// ping-pong activation planes plus one [`LayerScratch`].  After the
/// first call at a given batch size the activation/code/affine buffers
/// are all reused; the only remaining per-call allocations are the
/// O(threads) job boxes when a layer is large enough to dispatch onto
/// the worker pool.
#[derive(Debug, Default)]
pub struct NetScratch {
    ping: Vec<f32>,
    pong: Vec<f32>,
    layer: LayerScratch,
}

/// Where one layer's forward time went, plus its static cost pricing.
///
/// Filled by [`IntNet::forward_into_profiled`]. Times are wall-clock
/// seconds; `macs` uses the same per-layer pricing as the training
/// regularizer ([`crate::quant::conv_macs`] for convs, `din·dout` for
/// dense), and `bytes` is the traffic a forward actually touches:
/// packed weight codes + f32 input and output planes.
#[derive(Debug, Clone, Default)]
pub struct LayerProfile {
    pub name: String,
    /// Total wall time of this layer's `forward_scratch`.
    pub total_s: f64,
    /// im2col patch expansion time (0 for dense layers).
    pub im2col_s: f64,
    /// Integer-GEMM core time (quantize + GEMM + reconstruction).
    pub gemm_s: f64,
    /// Integer multiply-accumulates for the profiled batch.
    pub macs: u64,
    /// Bytes touched: packed codes + f32 activations in/out.
    pub bytes: u64,
}

/// Per-layer timing + cost attribution for one profiled forward.
///
/// Produced by [`IntNet::forward_into_profiled`]; the buffer is reused
/// across calls (`layers` keeps its capacity). The non-profiled
/// [`IntNet::forward_into`] never constructs one and never calls
/// `Instant::now` — the hot path stays allocation-free and
/// bit-identical (pinned by `profiled_forward_is_bit_identical`).
#[derive(Debug, Clone, Default)]
pub struct ForwardProfile {
    /// Batch size of the profiled call.
    pub batch: usize,
    /// End-to-end wall time of the whole forward.
    pub total_s: f64,
    pub layers: Vec<LayerProfile>,
}

impl ForwardProfile {
    pub fn new() -> Self {
        Self::default()
    }

    fn reset(&mut self, batch: usize) {
        self.batch = batch;
        self.total_s = 0.0;
        self.layers.clear();
    }

    /// Total MACs across layers.
    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    /// Total bytes touched across layers.
    pub fn total_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.bytes).sum()
    }

    /// Human-readable per-layer attribution table.
    pub fn report(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "forward profile: batch {}, {:.3} ms, {} MACs, {} bytes",
            self.batch,
            self.total_s * 1e3,
            self.total_macs(),
            self.total_bytes()
        );
        for l in &self.layers {
            let gmacs_s = if l.total_s > 0.0 {
                l.macs as f64 / l.total_s / 1e9
            } else {
                0.0
            };
            let _ = writeln!(
                out,
                "  {:<12} {:>9.3} ms (im2col {:>7.3} ms, gemm {:>7.3} ms) | {:>12} MACs {:>6.2} GMAC/s | {:>10} bytes",
                l.name,
                l.total_s * 1e3,
                l.im2col_s * 1e3,
                l.gemm_s * 1e3,
                l.macs,
                gmacs_s,
                l.bytes
            );
        }
        out
    }
}

impl IntDense {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &str,
        w: &[f32],
        din: usize,
        dout: usize,
        bias: &[f32],
        w_bits: u32,
        a_bits: u32,
        relu: bool,
    ) -> Result<Self> {
        if w.len() != din * dout {
            bail!("{name}: weight len {} != {din}x{dout}", w.len());
        }
        let packed = pack(w, w_bits)?;
        Self::from_packed(name, packed, din, dout, bias.to_vec(), a_bits, relu, None)
    }

    /// [`Self::new`] with an explicit weight [`Codebook`]: codes are
    /// projected onto the codebook at pack time and a non-uniform layer
    /// gets a [`ShiftPlan`] so its GEMM runs multiply-free.
    /// `Codebook::Uniform` is byte- and bit-identical to [`Self::new`].
    #[allow(clippy::too_many_arguments)]
    pub fn new_cbk(
        name: &str,
        w: &[f32],
        din: usize,
        dout: usize,
        bias: &[f32],
        w_bits: u32,
        a_bits: u32,
        relu: bool,
        codebook: Codebook,
    ) -> Result<Self> {
        if w.len() != din * dout {
            bail!("{name}: weight len {} != {din}x{dout}", w.len());
        }
        let packed = pack_cbk(w, w_bits, codebook)?;
        Self::from_packed(name, packed, din, dout, bias.to_vec(), a_bits, relu, None)
    }

    /// Reconstruct a layer from its **stored** packed codes and
    /// dequantization parameters, without touching f32 weights or the
    /// quantizer — the deployment path (`deploy::artifact`).  Because
    /// every forward-path table (`codes_t`, `col_code_sum`) is derived
    /// from the codes alone and the affine terms use only
    /// `(w_min, w_scale, bias, act_range)`, a layer rebuilt from the
    /// exact packed bytes is **bit-identical** to the layer they were
    /// frozen from.  All inputs are treated as untrusted (artifact
    /// files): shapes, bitlengths and the code/geometry agreement are
    /// validated, with `checked_mul` on the element count.
    #[allow(clippy::too_many_arguments)]
    pub fn from_packed(
        name: &str,
        packed: PackedTensor,
        din: usize,
        dout: usize,
        bias: Vec<f32>,
        a_bits: u32,
        relu: bool,
        act_range: Option<(f32, f32)>,
    ) -> Result<Self> {
        let elems = din
            .checked_mul(dout)
            .ok_or_else(|| anyhow::anyhow!("{name}: {din}x{dout} overflows"))?;
        if packed.len != elems {
            bail!("{name}: {} packed codes != {din}x{dout}", packed.len);
        }
        if bias.len() != dout {
            bail!("{name}: bias len {} != {dout}", bias.len());
        }
        if !(1..=16).contains(&packed.bits) {
            bail!("{name}: weight bits {} outside [1,16]", packed.bits);
        }
        if !(1..=16).contains(&a_bits) {
            bail!("{name}: activation bits {a_bits} outside [1,16]");
        }
        if let Some((lo, hi)) = act_range {
            if !lo.is_finite() || !hi.is_finite() || lo > hi {
                bail!("{name}: bad activation range [{lo}, {hi}]");
            }
        }
        let codes = unpack_codes(&packed);
        let mut codes_t = vec![0u16; elems];
        let mut col_code_sum = vec![0i64; dout];
        for i in 0..din {
            for j in 0..dout {
                let c = codes[i * dout + j] as u16;
                codes_t[j * din + i] = c;
                col_code_sum[j] += c as i64;
            }
        }
        let shift = (!packed.codebook.is_uniform())
            .then(|| ShiftPlan::build(&codes_t, din, dout, |_| packed.bits));
        let lane = quant::acc_width(packed.bits, a_bits, din);
        Ok(Self {
            name: name.to_string(),
            din,
            dout,
            weights: WeightCodes::PerLayer(packed),
            codes_t,
            col_code_sum,
            bias,
            a_bits,
            relu,
            act_range,
            shift,
            lane,
            group_lanes: Vec::new(),
        })
    }

    /// Per-output-channel construction: quantize and pack each output
    /// channel (column `j` of the row-major `[din, dout]` weights) at
    /// its own learned bitlength `w_bits[j]` against its own min/max —
    /// the [`Granularity::PerOutputChannel`] path.  Learned fractional
    /// bitlengths deploy at `ceil` per the shared
    /// [`quant::int_bits`] convention.
    #[allow(clippy::too_many_arguments)]
    pub fn new_grouped(
        name: &str,
        w: &[f32],
        din: usize,
        dout: usize,
        bias: &[f32],
        w_bits: &[f32],
        a_bits: u32,
        relu: bool,
    ) -> Result<Self> {
        if w.len() != din * dout {
            bail!("{name}: weight len {} != {din}x{dout}", w.len());
        }
        if w_bits.len() != dout {
            bail!(
                "{name}: {} channel bitlengths for {dout} output channels",
                w_bits.len()
            );
        }
        // Channel-major (transposed) view: group j = output channel j's
        // din weights, contiguous.
        let mut wt = vec![0.0f32; din * dout];
        for i in 0..din {
            for j in 0..dout {
                wt[j * din + i] = w[i * dout + j];
            }
        }
        let bits: Vec<u32> = w_bits.iter().map(|&b| quant::int_bits(b)).collect();
        let groups = pack_groups(&wt, din, &bits)?;
        Self::from_packed_groups(name, groups, din, dout, bias.to_vec(), a_bits, relu, None)
    }

    /// [`Self::new_grouped`] with an explicit weight [`Codebook`]
    /// shared by every channel (the codebook is a layer-level axis;
    /// bitlengths and ranges stay per-channel).
    #[allow(clippy::too_many_arguments)]
    pub fn new_grouped_cbk(
        name: &str,
        w: &[f32],
        din: usize,
        dout: usize,
        bias: &[f32],
        w_bits: &[f32],
        a_bits: u32,
        relu: bool,
        codebook: Codebook,
    ) -> Result<Self> {
        if w.len() != din * dout {
            bail!("{name}: weight len {} != {din}x{dout}", w.len());
        }
        if w_bits.len() != dout {
            bail!(
                "{name}: {} channel bitlengths for {dout} output channels",
                w_bits.len()
            );
        }
        let mut wt = vec![0.0f32; din * dout];
        for i in 0..din {
            for j in 0..dout {
                wt[j * din + i] = w[i * dout + j];
            }
        }
        let bits: Vec<u32> = w_bits.iter().map(|&b| quant::int_bits(b)).collect();
        let groups = pack_groups_cbk(&wt, din, &bits, codebook)?;
        Self::from_packed_groups(name, groups, din, dout, bias.to_vec(), a_bits, relu, None)
    }

    /// Rebuild a per-output-channel layer from its **stored** grouped
    /// codes (the `GRP0` deployment path) — the grouped analogue of
    /// [`Self::from_packed`], with the same bit-identity guarantee and
    /// the same untrusted-input validation posture.
    #[allow(clippy::too_many_arguments)]
    pub fn from_packed_groups(
        name: &str,
        groups: PackedGroups,
        din: usize,
        dout: usize,
        bias: Vec<f32>,
        a_bits: u32,
        relu: bool,
        act_range: Option<(f32, f32)>,
    ) -> Result<Self> {
        if din == 0 || dout == 0 {
            // A grouped layer needs at least one channel with at least
            // one weight — unlike the per-layer path there is no
            // meaningful empty encoding (and LAY0 rejects degenerate
            // shapes on load anyway).
            bail!("{name}: degenerate grouped shape {din}x{dout}");
        }
        if groups.group_size != din {
            bail!(
                "{name}: group size {} != input dim {din}",
                groups.group_size
            );
        }
        if groups.n_groups() != dout {
            bail!(
                "{name}: {} packed channel groups != {dout} output channels",
                groups.n_groups()
            );
        }
        if bias.len() != dout {
            bail!("{name}: bias len {} != {dout}", bias.len());
        }
        if !(1..=16).contains(&a_bits) {
            bail!("{name}: activation bits {a_bits} outside [1,16]");
        }
        if let Some((lo, hi)) = act_range {
            if !lo.is_finite() || !hi.is_finite() || lo > hi {
                bail!("{name}: bad activation range [{lo}, {hi}]");
            }
        }
        let elems = din
            .checked_mul(dout)
            .ok_or_else(|| anyhow::anyhow!("{name}: {din}x{dout} overflows"))?;
        let mut codes_t = vec![0u16; elems];
        let mut col_code_sum = vec![0i64; dout];
        for j in 0..dout {
            let codes = groups.group_codes(j);
            let mut sum = 0i64;
            for (dst, c) in codes_t[j * din..(j + 1) * din].iter_mut().zip(codes) {
                *dst = c as u16;
                sum += c as i64;
            }
            col_code_sum[j] = sum;
        }
        let shift = (!groups.codebook.is_uniform())
            .then(|| ShiftPlan::build(&codes_t, din, dout, |j| groups.spans[j].bits));
        let group_lanes: Vec<AccWidth> = groups
            .spans
            .iter()
            .map(|sp| quant::acc_width(sp.bits, a_bits, din))
            .collect();
        let lane = group_lanes.iter().copied().max().unwrap_or(AccWidth::I64);
        Ok(Self {
            name: name.to_string(),
            din,
            dout,
            weights: WeightCodes::PerChannel(groups),
            codes_t,
            col_code_sum,
            bias,
            a_bits,
            relu,
            act_range,
            shift,
            lane,
            group_lanes,
        })
    }

    /// Weight-quantization granularity of this layer.
    pub fn granularity(&self) -> Granularity {
        self.weights.granularity()
    }

    /// Weight codebook of this layer (layer-level axis; uniform layers
    /// run the multiply kernels, non-uniform layers the shift-add
    /// kernels).
    pub fn codebook(&self) -> Codebook {
        self.weights.codebook()
    }

    /// Whether the fast path runs the shift-add GEMM (iff the codebook
    /// is non-uniform; the scalar `forward_ref` stays multiply-based
    /// either way, which is what makes parity a real cross-check).
    pub fn uses_shift_gemm(&self) -> bool {
        self.shift.is_some()
    }

    /// Narrowest provably-safe accumulator lane for this layer's
    /// integer core (the widest group's, for grouped layers) — what
    /// the multiply-kernel dispatch keys on.
    pub fn acc_lane(&self) -> AccWidth {
        self.lane
    }

    /// The per-layer packed tensor, when this layer is PerLayer.
    pub fn packed_per_layer(&self) -> Option<&PackedTensor> {
        match &self.weights {
            WeightCodes::PerLayer(p) => Some(p),
            WeightCodes::PerChannel(_) => None,
        }
    }

    /// The per-channel packed groups, when this layer is PerOutputChannel.
    pub fn packed_groups(&self) -> Option<&PackedGroups> {
        match &self.weights {
            WeightCodes::PerLayer(_) => None,
            WeightCodes::PerChannel(g) => Some(g),
        }
    }

    /// Pin this layer's input quantization to a calibrated `[lo, hi]`
    /// range (static/offline calibration — the deployment convention).
    /// Degenerate ranges (`lo == hi`) are safe: the quantizer's epsilon
    /// guard keeps the scale finite.
    pub fn set_act_range(&mut self, lo: f32, hi: f32) {
        self.act_range = Some((lo, hi));
    }

    /// The calibrated input range, if one is set.
    pub fn act_range(&self) -> Option<(f32, f32)> {
        self.act_range
    }

    /// Quantize a batch of activations to integer codes — against the
    /// calibrated range when one is set, else against the batch's own
    /// min/max (the training-time convention, paper §II-A). Returns
    /// `(codes, per-row code sums, a_scale, a_min)`. Shared by the fast
    /// and reference paths so both see identical codes.
    fn quantize_acts(&self, x: &[f32], n: usize) -> (Vec<u16>, Vec<i64>, f32, f32) {
        let mut codes = Vec::new();
        let mut row_sum = Vec::new();
        let (a_scale, a_min) = self.quantize_acts_into(x, n, &mut codes, &mut row_sum);
        (codes, row_sum, a_scale, a_min)
    }

    /// Buffer-reusing core of [`Self::quantize_acts`].
    fn quantize_acts_into(
        &self,
        x: &[f32],
        n: usize,
        codes: &mut Vec<u16>,
        row_sum: &mut Vec<i64>,
    ) -> (f32, f32) {
        let (a_min, a_max) = match self.act_range {
            Some(r) => r,
            None => quant::group_minmax(x),
        };
        let plan = quant::QuantPlan::new(a_min, a_max, self.a_bits as f32);
        let levels = ((1u32 << self.a_bits) - 1) as i64;
        codes.clear();
        codes.resize(n * self.din, 0);
        row_sum.clear();
        row_sum.resize(n, 0);
        for (rs, (row_x, row_c)) in row_sum
            .iter_mut()
            .zip(x.chunks_exact(self.din).zip(codes.chunks_exact_mut(self.din)))
        {
            let mut sum = 0i64;
            for (v, c) in row_x.iter().zip(row_c.iter_mut()) {
                let code = plan.code(*v, levels);
                *c = code as u16;
                sum += code as i64;
            }
            *rs = sum;
        }
        (plan.s_lo, a_min)
    }

    /// Hoisted affine-reconstruction terms: `out = s·acc + t[r] + u[j]`
    /// where `s = w_s·a_s`, `t[r]` folds the row code sum and the
    /// constant `K·a_min·w_min`, and `u[j]` folds the column code sum
    /// and the bias. Shared by both paths (bit-identical by design).
    fn affine_terms(
        &self,
        a_scale: f32,
        a_min: f32,
        row_code_sum: &[i64],
    ) -> (f64, Vec<f64>, Vec<f64>) {
        let mut t = Vec::new();
        let mut u = Vec::new();
        let s = self.affine_terms_into(a_scale, a_min, row_code_sum, &mut t, &mut u);
        (s, t, u)
    }

    /// The per-layer `(w_min, w_scale)` dequantization plan.  Panics on
    /// a grouped layer — the grouped paths use [`Self::grouped_terms_into`].
    fn per_layer_plan(&self) -> (f32, f32) {
        match &self.weights {
            WeightCodes::PerLayer(p) => (p.lmin, p.scale),
            WeightCodes::PerChannel(_) => {
                unreachable!("{}: per-layer affine terms on a grouped layer", self.name)
            }
        }
    }

    /// Buffer-reusing core of [`Self::affine_terms`].
    fn affine_terms_into(
        &self,
        a_scale: f32,
        a_min: f32,
        row_code_sum: &[i64],
        t: &mut Vec<f64>,
        u: &mut Vec<f64>,
    ) -> f64 {
        let (w_min, w_scale) = self.per_layer_plan();
        let ws = w_scale as f64;
        let asc = a_scale as f64;
        let wmin = w_min as f64;
        let amin = a_min as f64;
        let k = self.din as f64;
        t.clear();
        t.extend(
            row_code_sum
                .iter()
                .map(|&rs| asc * wmin * rs as f64 + k * amin * wmin),
        );
        u.clear();
        u.extend(
            self.col_code_sum
                .iter()
                .zip(&self.bias)
                .map(|(&cs, &b)| ws * amin * cs as f64 + b as f64),
        );
        ws * asc
    }

    /// Grouped analogue of [`Self::affine_terms_into`]: fills the
    /// per-row code sums as f64 (`rsf`, what the per-column `awmin`
    /// multiplies) and the per-column tables in `cols`.  Every f64
    /// product keeps the exact association of the per-layer path
    /// (`asc·wmin`, `(k·amin)·wmin`, `(ws·amin)·cs`), so a grouped
    /// layer whose channels all share one plan reconstructs
    /// bit-identically to the per-layer kernel.
    fn grouped_terms_into(
        &self,
        a_scale: f32,
        a_min: f32,
        row_code_sum: &[i64],
        rsf: &mut Vec<f64>,
        cols: &mut GroupedCols,
    ) {
        let WeightCodes::PerChannel(groups) = &self.weights else {
            unreachable!("{}: grouped affine terms on a per-layer layer", self.name)
        };
        let asc = a_scale as f64;
        let amin = a_min as f64;
        let k = self.din as f64;
        let kamin = k * amin;
        rsf.clear();
        rsf.extend(row_code_sum.iter().map(|&rs| rs as f64));
        cols.s.clear();
        cols.awmin.clear();
        cols.kwmin.clear();
        cols.u.clear();
        for ((span, &cs), &b) in groups
            .spans
            .iter()
            .zip(&self.col_code_sum)
            .zip(&self.bias)
        {
            let ws = span.scale as f64;
            let wmin = span.lmin as f64;
            cols.s.push(ws * asc);
            cols.awmin.push(asc * wmin);
            cols.kwmin.push(kamin * wmin);
            cols.u.push(ws * amin * cs as f64 + b as f64);
        }
    }

    /// Grouped blocked i64 GEMM over one block of batch rows: identical
    /// loop structure to [`Self::gemm_block`] (4-column register
    /// blocking over the tiled codes), but the affine reconstruction
    /// reads the per-output-channel tables — each column carries its
    /// own `(s, awmin, kwmin, u)` since each channel has its own
    /// dequantization plan.  `rsf` holds the block's per-row code sums
    /// as f64.
    fn gemm_block_grouped(
        &self,
        a: &[u16],
        rsf: &[f64],
        cols: &GroupedCols,
        out: &mut [f32],
    ) {
        let din = self.din;
        let dout = self.dout;
        let relu = self.relu;
        let codes_t = &self.codes_t;
        for ((a_row, rf), out_row) in a
            .chunks_exact(din)
            .zip(rsf)
            .zip(out.chunks_exact_mut(dout))
        {
            let mut j = 0usize;
            while j + 4 <= dout {
                let w0 = &codes_t[j * din..][..din];
                let w1 = &codes_t[(j + 1) * din..][..din];
                let w2 = &codes_t[(j + 2) * din..][..din];
                let w3 = &codes_t[(j + 3) * din..][..din];
                let (mut s0, mut s1, mut s2, mut s3) = (0i64, 0i64, 0i64, 0i64);
                for (c, &av) in a_row.iter().enumerate() {
                    let av = av as i64;
                    s0 += av * w0[c] as i64;
                    s1 += av * w1[c] as i64;
                    s2 += av * w2[c] as i64;
                    s3 += av * w3[c] as i64;
                }
                for (jj, acc) in [s0, s1, s2, s3].into_iter().enumerate() {
                    let jx = j + jj;
                    let t = cols.awmin[jx] * *rf + cols.kwmin[jx];
                    let v = (cols.s[jx] * acc as f64 + t + cols.u[jx]) as f32;
                    out_row[jx] = if relu { v.max(0.0) } else { v };
                }
                j += 4;
            }
            while j < dout {
                let wj = &codes_t[j * din..][..din];
                let mut acc = 0i64;
                for (&av, &wv) in a_row.iter().zip(wj) {
                    acc += av as i64 * wv as i64;
                }
                let t = cols.awmin[j] * *rf + cols.kwmin[j];
                let v = (cols.s[j] * acc as f64 + t + cols.u[j]) as f32;
                out_row[j] = if relu { v.max(0.0) } else { v };
                j += 1;
            }
        }
    }

    /// Shift-add analogue of [`Self::gemm_block`]: same affine
    /// reconstruction (`s·acc + t[r] + u[j]`), but the i64 accumulator
    /// comes from the [`ShiftPlan`] — `rs` holds the block's per-row
    /// activation code sums, which carry the `half` offset.  The
    /// integer accumulator is exactly the multiply kernel's, so the
    /// two paths are bit-identical.
    #[allow(clippy::too_many_arguments)]
    fn gemm_block_shift(
        &self,
        plan: &ShiftPlan,
        a: &[u16],
        rs: &[i64],
        t: &[f64],
        u: &[f64],
        s: f64,
        out: &mut [f32],
    ) {
        let din = self.din;
        let dout = self.dout;
        let relu = self.relu;
        for (((a_row, &rsum), tr), out_row) in a
            .chunks_exact(din)
            .zip(rs)
            .zip(t)
            .zip(out.chunks_exact_mut(dout))
        {
            for (j, o) in out_row.iter_mut().enumerate() {
                let acc = plan.col_acc(j, a_row, rsum);
                let v = (s * acc as f64 + *tr + u[j]) as f32;
                *o = if relu { v.max(0.0) } else { v };
            }
        }
    }

    /// Shift-add analogue of [`Self::gemm_block_grouped`]: per-column
    /// affine tables, shift-add accumulator.
    fn gemm_block_shift_grouped(
        &self,
        plan: &ShiftPlan,
        a: &[u16],
        rs: &[i64],
        rsf: &[f64],
        cols: &GroupedCols,
        out: &mut [f32],
    ) {
        let din = self.din;
        let dout = self.dout;
        let relu = self.relu;
        for (((a_row, &rsum), rf), out_row) in a
            .chunks_exact(din)
            .zip(rs)
            .zip(rsf)
            .zip(out.chunks_exact_mut(dout))
        {
            for (j, o) in out_row.iter_mut().enumerate() {
                let acc = plan.col_acc(j, a_row, rsum);
                let t = cols.awmin[j] * *rf + cols.kwmin[j];
                let v = (cols.s[j] * acc as f64 + t + cols.u[j]) as f32;
                *o = if relu { v.max(0.0) } else { v };
            }
        }
    }

    /// Batch-row-blocked variant of [`Self::gemm_block_shift`]: four
    /// batch rows share one walk of each column's CSR entry list,
    /// amortizing the entry decode 4x and keeping four independent
    /// accumulators live per column.  Each row's accumulator is the
    /// exact sum [`ShiftPlan::col_acc`] computes (same entries, i64
    /// addition is exact under reassociation), so the variant is
    /// bit-identical to the per-row kernel.
    #[allow(clippy::too_many_arguments)]
    fn gemm_block_shift_rows(
        &self,
        plan: &ShiftPlan,
        a: &[u16],
        rs: &[i64],
        t: &[f64],
        u: &[f64],
        s: f64,
        out: &mut [f32],
    ) {
        let din = self.din;
        let dout = self.dout;
        let relu = self.relu;
        let nrows = t.len();
        let mut r = 0usize;
        while r + 4 <= nrows {
            let a0 = &a[r * din..][..din];
            let a1 = &a[(r + 1) * din..][..din];
            let a2 = &a[(r + 2) * din..][..din];
            let a3 = &a[(r + 3) * din..][..din];
            for j in 0..dout {
                let (start, mid, end) = plan.col[j];
                let hs = plan.half_sh[j];
                let mut c0 = rs[r] << hs;
                let mut c1 = rs[r + 1] << hs;
                let mut c2 = rs[r + 2] << hs;
                let mut c3 = rs[r + 3] << hs;
                for &(idx, sh) in &plan.entries[start as usize..mid as usize] {
                    let i = idx as usize;
                    c0 += (a0[i] as i64) << sh;
                    c1 += (a1[i] as i64) << sh;
                    c2 += (a2[i] as i64) << sh;
                    c3 += (a3[i] as i64) << sh;
                }
                for &(idx, sh) in &plan.entries[mid as usize..end as usize] {
                    let i = idx as usize;
                    c0 -= (a0[i] as i64) << sh;
                    c1 -= (a1[i] as i64) << sh;
                    c2 -= (a2[i] as i64) << sh;
                    c3 -= (a3[i] as i64) << sh;
                }
                for (rr, acc) in [c0, c1, c2, c3].into_iter().enumerate() {
                    let v = (s * acc as f64 + t[r + rr] + u[j]) as f32;
                    out[(r + rr) * dout + j] = if relu { v.max(0.0) } else { v };
                }
            }
            r += 4;
        }
        if r < nrows {
            let (ta, tr, to) = (&a[r * din..], &rs[r..], &mut out[r * dout..]);
            self.gemm_block_shift(plan, ta, tr, &t[r..], u, s, to);
        }
    }

    /// Grouped analogue of [`Self::gemm_block_shift_rows`]: per-column
    /// affine tables, four-row-blocked shift-add accumulation.
    fn gemm_block_shift_grouped_rows(
        &self,
        plan: &ShiftPlan,
        a: &[u16],
        rs: &[i64],
        rsf: &[f64],
        cols: &GroupedCols,
        out: &mut [f32],
    ) {
        let din = self.din;
        let dout = self.dout;
        let relu = self.relu;
        let nrows = rsf.len();
        let mut r = 0usize;
        while r + 4 <= nrows {
            let a0 = &a[r * din..][..din];
            let a1 = &a[(r + 1) * din..][..din];
            let a2 = &a[(r + 2) * din..][..din];
            let a3 = &a[(r + 3) * din..][..din];
            for j in 0..dout {
                let (start, mid, end) = plan.col[j];
                let hs = plan.half_sh[j];
                let mut c0 = rs[r] << hs;
                let mut c1 = rs[r + 1] << hs;
                let mut c2 = rs[r + 2] << hs;
                let mut c3 = rs[r + 3] << hs;
                for &(idx, sh) in &plan.entries[start as usize..mid as usize] {
                    let i = idx as usize;
                    c0 += (a0[i] as i64) << sh;
                    c1 += (a1[i] as i64) << sh;
                    c2 += (a2[i] as i64) << sh;
                    c3 += (a3[i] as i64) << sh;
                }
                for &(idx, sh) in &plan.entries[mid as usize..end as usize] {
                    let i = idx as usize;
                    c0 -= (a0[i] as i64) << sh;
                    c1 -= (a1[i] as i64) << sh;
                    c2 -= (a2[i] as i64) << sh;
                    c3 -= (a3[i] as i64) << sh;
                }
                for (rr, acc) in [c0, c1, c2, c3].into_iter().enumerate() {
                    let tj = cols.awmin[j] * rsf[r + rr] + cols.kwmin[j];
                    let v = (cols.s[j] * acc as f64 + tj + cols.u[j]) as f32;
                    out[(r + rr) * dout + j] = if relu { v.max(0.0) } else { v };
                }
            }
            r += 4;
        }
        if r < nrows {
            self.gemm_block_shift_grouped(
                plan,
                &a[r * din..],
                &rs[r..],
                &rsf[r..],
                cols,
                &mut out[r * dout..],
            );
        }
    }

    /// Narrow-lane / SIMD variant of [`Self::gemm_block`], dispatched
    /// when the stored [`AccWidth`] proves an i16/i32 accumulator
    /// cannot wrap: same 4-column register blocking over the tiled
    /// codes, inner dot product from [`simd::dot4`] (AVX2 / NEON /
    /// portable-i32, resolved once per call).  The integer sums equal
    /// the i64 kernel's exactly and the reconstruction expression is
    /// shared, so every path stays bit-identical to [`forward_ref`].
    ///
    /// [`forward_ref`]: Self::forward_ref
    fn gemm_block_lanes(&self, a: &[u16], t: &[f64], u: &[f64], s: f64, out: &mut [f32]) {
        let din = self.din;
        let dout = self.dout;
        let relu = self.relu;
        let codes_t = &self.codes_t;
        let path = simd::kernel_path();
        let i16_lanes = self.lane == AccWidth::I16;
        for ((a_row, tr), out_row) in a
            .chunks_exact(din)
            .zip(t)
            .zip(out.chunks_exact_mut(dout))
        {
            let mut j = 0usize;
            while j + 4 <= dout {
                let w0 = &codes_t[j * din..][..din];
                let w1 = &codes_t[(j + 1) * din..][..din];
                let w2 = &codes_t[(j + 2) * din..][..din];
                let w3 = &codes_t[(j + 3) * din..][..din];
                let accs = simd::dot4(path, i16_lanes, a_row, w0, w1, w2, w3);
                for (jj, acc) in accs.into_iter().enumerate() {
                    let v = (s * acc as f64 + *tr + u[j + jj]) as f32;
                    out_row[j + jj] = if relu { v.max(0.0) } else { v };
                }
                j += 4;
            }
            while j < dout {
                let wj = &codes_t[j * din..][..din];
                let mut acc = 0i64;
                for (&av, &wv) in a_row.iter().zip(wj) {
                    acc += av as i64 * wv as i64;
                }
                let v = (s * acc as f64 + *tr + u[j]) as f32;
                out_row[j] = if relu { v.max(0.0) } else { v };
                j += 1;
            }
        }
    }

    /// Narrow-lane / SIMD variant of [`Self::gemm_block_grouped`].
    /// Lane selection is **per column block**: a block of four output
    /// channels runs the narrow dot kernel only when all four stored
    /// [`AccWidth`]s permit (each channel packs at its own bitlength,
    /// so each earns its own width); wide blocks and the column
    /// remainder fall back to the scalar i64 accumulation in place.
    fn gemm_block_grouped_lanes(
        &self,
        a: &[u16],
        rsf: &[f64],
        cols: &GroupedCols,
        out: &mut [f32],
    ) {
        let din = self.din;
        let dout = self.dout;
        let relu = self.relu;
        let codes_t = &self.codes_t;
        let path = simd::kernel_path();
        for ((a_row, rf), out_row) in a
            .chunks_exact(din)
            .zip(rsf)
            .zip(out.chunks_exact_mut(dout))
        {
            let mut j = 0usize;
            while j + 4 <= dout {
                let w0 = &codes_t[j * din..][..din];
                let w1 = &codes_t[(j + 1) * din..][..din];
                let w2 = &codes_t[(j + 2) * din..][..din];
                let w3 = &codes_t[(j + 3) * din..][..din];
                let blk = self.group_lanes[j]
                    .max(self.group_lanes[j + 1])
                    .max(self.group_lanes[j + 2])
                    .max(self.group_lanes[j + 3]);
                let accs = if blk <= AccWidth::I32 {
                    simd::dot4(path, blk == AccWidth::I16, a_row, w0, w1, w2, w3)
                } else {
                    let (mut s0, mut s1, mut s2, mut s3) = (0i64, 0i64, 0i64, 0i64);
                    for (c, &av) in a_row.iter().enumerate() {
                        let av = av as i64;
                        s0 += av * w0[c] as i64;
                        s1 += av * w1[c] as i64;
                        s2 += av * w2[c] as i64;
                        s3 += av * w3[c] as i64;
                    }
                    [s0, s1, s2, s3]
                };
                for (jj, acc) in accs.into_iter().enumerate() {
                    let jx = j + jj;
                    let t = cols.awmin[jx] * *rf + cols.kwmin[jx];
                    let v = (cols.s[jx] * acc as f64 + t + cols.u[jx]) as f32;
                    out_row[jx] = if relu { v.max(0.0) } else { v };
                }
                j += 4;
            }
            while j < dout {
                let wj = &codes_t[j * din..][..din];
                let mut acc = 0i64;
                for (&av, &wv) in a_row.iter().zip(wj) {
                    acc += av as i64 * wv as i64;
                }
                let t = cols.awmin[j] * *rf + cols.kwmin[j];
                let v = (cols.s[j] * acc as f64 + t + cols.u[j]) as f32;
                out_row[j] = if relu { v.max(0.0) } else { v };
                j += 1;
            }
        }
    }

    /// Per-layer GEMM over one row block: shift-add kernels when a
    /// [`ShiftPlan`] exists (row-blocked unless the portable fallback
    /// is pinned), narrow-lane/SIMD multiply kernels when the stored
    /// [`AccWidth`] permits, the original wide i64 kernel otherwise.
    /// Every dispatcher (inline, scoped threads, worker pool) goes
    /// through here, so kernel selection lives in exactly one place.
    #[allow(clippy::too_many_arguments)]
    fn gemm_dispatch(
        &self,
        a: &[u16],
        rs: &[i64],
        t: &[f64],
        u: &[f64],
        s: f64,
        out: &mut [f32],
    ) {
        match &self.shift {
            Some(plan) => {
                if simd::kernel_path() == simd::KernelPath::Portable {
                    self.gemm_block_shift(plan, a, rs, t, u, s, out)
                } else {
                    self.gemm_block_shift_rows(plan, a, rs, t, u, s, out)
                }
            }
            None => {
                if self.lane == AccWidth::I64 {
                    self.gemm_block(a, t, u, s, out)
                } else {
                    self.gemm_block_lanes(a, t, u, s, out)
                }
            }
        }
    }

    /// Grouped GEMM dispatch — see [`Self::gemm_dispatch`].  The
    /// narrow kernel engages when *any* channel's stored lane permits
    /// (selection is then per column block inside the kernel).
    fn gemm_dispatch_grouped(
        &self,
        a: &[u16],
        rs: &[i64],
        rsf: &[f64],
        cols: &GroupedCols,
        out: &mut [f32],
    ) {
        match &self.shift {
            Some(plan) => {
                if simd::kernel_path() == simd::KernelPath::Portable {
                    self.gemm_block_shift_grouped(plan, a, rs, rsf, cols, out)
                } else {
                    self.gemm_block_shift_grouped_rows(plan, a, rs, rsf, cols, out)
                }
            }
            None => {
                let any_narrow =
                    self.group_lanes.iter().any(|&l| l <= AccWidth::I32);
                if any_narrow {
                    self.gemm_block_grouped_lanes(a, rsf, cols, out)
                } else {
                    self.gemm_block_grouped(a, rsf, cols, out)
                }
            }
        }
    }

    /// Split matching rows of (activation codes, per-row code sums,
    /// per-row affine terms, output) into per-worker blocks.  Both
    /// parallel dispatchers (`forward`'s scoped threads,
    /// `forward_scratch`'s pool) consume this, so the boundary
    /// invariant — each output chunk lines up with its codes/sum/t
    /// rows — lives in exactly one place.
    fn row_blocks<'a>(
        &self,
        a: &'a [u16],
        rs: &'a [i64],
        t: &'a [f64],
        out: &'a mut [f32],
        threads: usize,
    ) -> Vec<(&'a [u16], &'a [i64], &'a [f64], &'a mut [f32])> {
        let rows_per = t.len().div_ceil(threads);
        let mut blocks = Vec::with_capacity(threads);
        for (idx, out_chunk) in out.chunks_mut(rows_per * self.dout).enumerate() {
            let r0 = idx * rows_per;
            let rows = out_chunk.len() / self.dout;
            blocks.push((
                &a[r0 * self.din..(r0 + rows) * self.din],
                &rs[r0..r0 + rows],
                &t[r0..r0 + rows],
                out_chunk,
            ));
        }
        blocks
    }

    /// How many worker threads the GEMM should use for an `n`-row batch.
    fn gemm_threads(&self, n: usize) -> usize {
        if n * self.din * self.dout < PAR_MIN_MACS {
            return 1;
        }
        std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)
            .min(n)
    }

    /// Blocked i64 GEMM over one block of batch rows. `a` holds
    /// `t.len()` rows of activation codes; `out` the matching rows of
    /// output. Four output columns are register-blocked per pass over
    /// an activation row; both operands stream contiguously thanks to
    /// the tiled `codes_t` layout.
    fn gemm_block(&self, a: &[u16], t: &[f64], u: &[f64], s: f64, out: &mut [f32]) {
        let din = self.din;
        let dout = self.dout;
        let relu = self.relu;
        let codes_t = &self.codes_t;
        for ((a_row, tr), out_row) in a
            .chunks_exact(din)
            .zip(t)
            .zip(out.chunks_exact_mut(dout))
        {
            let mut j = 0usize;
            while j + 4 <= dout {
                let w0 = &codes_t[j * din..][..din];
                let w1 = &codes_t[(j + 1) * din..][..din];
                let w2 = &codes_t[(j + 2) * din..][..din];
                let w3 = &codes_t[(j + 3) * din..][..din];
                let (mut s0, mut s1, mut s2, mut s3) = (0i64, 0i64, 0i64, 0i64);
                for (c, &av) in a_row.iter().enumerate() {
                    let av = av as i64;
                    s0 += av * w0[c] as i64;
                    s1 += av * w1[c] as i64;
                    s2 += av * w2[c] as i64;
                    s3 += av * w3[c] as i64;
                }
                for (jj, acc) in [s0, s1, s2, s3].into_iter().enumerate() {
                    let v = (s * acc as f64 + *tr + u[j + jj]) as f32;
                    out_row[j + jj] = if relu { v.max(0.0) } else { v };
                }
                j += 4;
            }
            while j < dout {
                let wj = &codes_t[j * din..][..din];
                let mut acc = 0i64;
                for (&av, &wv) in a_row.iter().zip(wj) {
                    acc += av as i64 * wv as i64;
                }
                let v = (s * acc as f64 + *tr + u[j]) as f32;
                out_row[j] = if relu { v.max(0.0) } else { v };
                j += 1;
            }
        }
    }

    /// Forward one batch [n, din] -> [n, dout].
    ///
    /// Activations are quantized to `a_bits` codes, then the GEMM runs
    /// entirely in i64 over the codes: blocked over output columns,
    /// streaming the tiled weight layout, parallel over batch rows for
    /// large batches. Bit-identical to [`forward_ref`].
    pub fn forward(&self, x: &[f32], n: usize) -> Vec<f32> {
        assert_eq!(x.len(), n * self.din, "{}: bad input", self.name);
        if n == 0 || self.din == 0 || self.dout == 0 {
            return vec![0.0f32; n * self.dout];
        }
        let (a_codes, row_code_sum, a_scale, a_min) = self.quantize_acts(x, n);
        let mut out = vec![0.0f32; n * self.dout];
        let threads = self.gemm_threads(n);
        match &self.weights {
            WeightCodes::PerLayer(_) => {
                let (s, t, u) = self.affine_terms(a_scale, a_min, &row_code_sum);
                if threads <= 1 {
                    self.gemm_dispatch(&a_codes, &row_code_sum, &t, &u, s, &mut out);
                } else {
                    let u = &u;
                    std::thread::scope(|scope| {
                        for (a, rb, tb, out_chunk) in
                            self.row_blocks(&a_codes, &row_code_sum, &t, &mut out, threads)
                        {
                            scope.spawn(move || self.gemm_dispatch(a, rb, tb, u, s, out_chunk));
                        }
                    });
                }
            }
            WeightCodes::PerChannel(_) => {
                let mut rsf = Vec::new();
                let mut cols = GroupedCols::default();
                self.grouped_terms_into(a_scale, a_min, &row_code_sum, &mut rsf, &mut cols);
                if threads <= 1 {
                    self.gemm_dispatch_grouped(&a_codes, &row_code_sum, &rsf, &cols, &mut out);
                } else {
                    let cols = &cols;
                    std::thread::scope(|scope| {
                        for (a, rb, rf, out_chunk) in
                            self.row_blocks(&a_codes, &row_code_sum, &rsf, &mut out, threads)
                        {
                            scope.spawn(move || {
                                self.gemm_dispatch_grouped(a, rb, rf, cols, out_chunk)
                            });
                        }
                    });
                }
            }
        }
        out
    }

    /// Serving-path forward: same computation as [`Self::forward`]
    /// (bit-identical — the GEMM kernel, quantizer and affine terms are
    /// shared), but writes into a caller-provided `out` slice, reuses
    /// `sc`'s buffers instead of allocating, and dispatches row blocks
    /// onto a persistent [`WorkerPool`] instead of spawning scoped
    /// threads.  With `pool: None` (or below the MAC threshold) the
    /// GEMM runs inline.
    pub fn forward_scratch(
        &self,
        x: &[f32],
        n: usize,
        sc: &mut LayerScratch,
        out: &mut [f32],
        pool: Option<&WorkerPool>,
    ) {
        assert_eq!(x.len(), n * self.din, "{}: bad input", self.name);
        assert_eq!(out.len(), n * self.dout, "{}: bad output", self.name);
        if n == 0 || self.din == 0 || self.dout == 0 {
            out.fill(0.0);
            return;
        }
        let (a_scale, a_min) =
            self.quantize_acts_into(x, n, &mut sc.codes, &mut sc.row_sum);
        let threads = match pool {
            Some(p) if n * self.din * self.dout >= PAR_MIN_MACS => p.workers().min(n),
            _ => 1,
        };
        match &self.weights {
            WeightCodes::PerLayer(_) => {
                let s = self
                    .affine_terms_into(a_scale, a_min, &sc.row_sum, &mut sc.t, &mut sc.u);
                if threads <= 1 {
                    self.gemm_dispatch(&sc.codes, &sc.row_sum, &sc.t, &sc.u, s, out);
                } else {
                    let pool = pool.unwrap();
                    let u = &sc.u;
                    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                        Vec::with_capacity(threads);
                    for (a, rb, tb, out_chunk) in
                        self.row_blocks(&sc.codes, &sc.row_sum, &sc.t, out, threads)
                    {
                        jobs.push(Box::new(move || {
                            self.gemm_dispatch(a, rb, tb, u, s, out_chunk)
                        }));
                    }
                    pool.run_scoped(jobs);
                }
            }
            WeightCodes::PerChannel(_) => {
                self.grouped_terms_into(
                    a_scale,
                    a_min,
                    &sc.row_sum,
                    &mut sc.t,
                    &mut sc.gcols,
                );
                if threads <= 1 {
                    self.gemm_dispatch_grouped(&sc.codes, &sc.row_sum, &sc.t, &sc.gcols, out);
                } else {
                    let pool = pool.unwrap();
                    let cols = &sc.gcols;
                    let mut jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
                        Vec::with_capacity(threads);
                    for (a, rb, rf, out_chunk) in
                        self.row_blocks(&sc.codes, &sc.row_sum, &sc.t, out, threads)
                    {
                        jobs.push(Box::new(move || {
                            self.gemm_dispatch_grouped(a, rb, rf, cols, out_chunk)
                        }));
                    }
                    pool.run_scoped(jobs);
                }
            }
        }
    }

    /// Retained scalar reference: the original cache-hostile `(r, j, c)`
    /// triple loop over the row-major codes (the inner stride walks the
    /// weight matrix column-wise). The i64 core is exact under
    /// reassociation and the affine reconstruction helper is shared, so
    /// this is bit-identical to [`forward`] — pinned by the parity tests
    /// and measured against it in `benches/intnet.rs`.
    ///
    /// Note: both paths use the *hoisted* reconstruction
    /// `s·acc + t[r] + u[j]`; the pre-refactor code summed the five f64
    /// terms left-to-right, so absolute outputs may differ from that
    /// binary by last-ulp f32 amounts (well inside the tolerances every
    /// consumer of this module uses). What is pinned bit-for-bit is
    /// fast vs reference *within* this version.
    ///
    /// The row-major code cache the original kept is reconstructed here
    /// per call (it is no longer stored); the unpack is O(din·dout)
    /// against the O(n·din·dout) loop it feeds.
    pub fn forward_ref(&self, x: &[f32], n: usize) -> Vec<f32> {
        assert_eq!(x.len(), n * self.din, "{}: bad input", self.name);
        if n == 0 || self.din == 0 || self.dout == 0 {
            return vec![0.0f32; n * self.dout];
        }
        let (a_codes, row_code_sum, a_scale, a_min) = self.quantize_acts(x, n);
        let mut out = vec![0.0f32; n * self.dout];
        match &self.weights {
            WeightCodes::PerLayer(packed) => {
                let codes: Vec<u16> =
                    unpack_codes(packed).iter().map(|&c| c as u16).collect();
                let (s, t, u) = self.affine_terms(a_scale, a_min, &row_code_sum);
                for r in 0..n {
                    let a_row = &a_codes[r * self.din..(r + 1) * self.din];
                    for j in 0..self.dout {
                        let mut acc = 0i64;
                        for c in 0..self.din {
                            acc += a_row[c] as i64 * codes[c * self.dout + j] as i64;
                        }
                        let v = (s * acc as f64 + t[r] + u[j]) as f32;
                        out[r * self.dout + j] = if self.relu { v.max(0.0) } else { v };
                    }
                }
            }
            WeightCodes::PerChannel(groups) => {
                // Scalar grouped baseline: per-channel codes from the
                // byte-at-a-time reference unpacker (hoisted once per
                // call, like the per-layer arm's unpack), per-element
                // affine recomputation — no tiled cache, no hoisted
                // tables.
                let codes_by_ch: Vec<Vec<u32>> =
                    (0..self.dout).map(|j| groups.group_codes_ref(j)).collect();
                let asc = a_scale as f64;
                let amin = a_min as f64;
                let k = self.din as f64;
                for r in 0..n {
                    let a_row = &a_codes[r * self.din..(r + 1) * self.din];
                    let rsf = row_code_sum[r] as f64;
                    for j in 0..self.dout {
                        let span = groups.spans[j];
                        let cj = &codes_by_ch[j];
                        let mut acc = 0i64;
                        let mut csum = 0i64;
                        for (&av, &wv) in a_row.iter().zip(cj.iter()) {
                            let wv = wv as i64;
                            acc += av as i64 * wv;
                            csum += wv;
                        }
                        let ws = span.scale as f64;
                        let wmin = span.lmin as f64;
                        let t = asc * wmin * rsf + k * amin * wmin;
                        let u = ws * amin * csum as f64 + self.bias[j] as f64;
                        let v = (ws * asc * acc as f64 + t + u) as f32;
                        out[r * self.dout + j] = if self.relu { v.max(0.0) } else { v };
                    }
                }
            }
        }
        out
    }

    /// Storage of this layer in packed form (bytes): the packed weight
    /// codes at the shared convention (payload + headers,
    /// [`WeightCodes::stored_bytes`]) plus the f32 bias.
    pub fn packed_bytes(&self) -> usize {
        self.weights.stored_bytes() + self.bias.len() * 4
    }
}

/// Geometry of one 2-D convolution over an `[h, w, cin]` HWC input
/// plane: `cout` kernels of `kh x kw` taps, one stride for both axes,
/// symmetric zero padding.  Weights are stored `[kh·kw·cin, cout]`
/// row-major (the flattened `[kh, kw, cin, cout]` kernel), which is
/// exactly the GEMM layout the im2col patch rows multiply.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ConvGeom {
    pub cin: usize,
    pub h: usize,
    pub w: usize,
    pub cout: usize,
    pub kh: usize,
    pub kw: usize,
    pub stride: usize,
    pub pad: usize,
}

impl ConvGeom {
    /// Validate the geometry (artifact inputs are untrusted): nonzero
    /// dims, stride >= 1, a padded plane the kernel fits inside, and
    /// element counts that survive `checked_mul`.
    pub fn validate(&self, name: &str) -> Result<()> {
        let g = self;
        if g.cin == 0 || g.h == 0 || g.w == 0 || g.cout == 0 || g.kh == 0 || g.kw == 0 {
            bail!(
                "{name}: degenerate conv geometry {}x{}x{} k{}x{} cout {}",
                g.cin, g.h, g.w, g.kh, g.kw, g.cout
            );
        }
        if g.stride == 0 {
            bail!("{name}: conv stride must be >= 1");
        }
        let pad2 = g.pad.checked_mul(2);
        let padded_h = pad2.and_then(|p| g.h.checked_add(p));
        let padded_w = pad2.and_then(|p| g.w.checked_add(p));
        match (padded_h, padded_w) {
            (Some(ph), Some(pw)) if ph >= g.kh && pw >= g.kw => {}
            _ => bail!(
                "{name}: kernel {}x{} does not fit the padded {}x{} plane (pad {})",
                g.kh, g.kw, g.h, g.w, g.pad
            ),
        }
        for (what, prod) in [
            ("patch", g.kh.checked_mul(g.kw).and_then(|p| p.checked_mul(g.cin))),
            ("input plane", g.cin.checked_mul(g.h).and_then(|p| p.checked_mul(g.w))),
            (
                "output plane",
                self.out_h()
                    .checked_mul(self.out_w())
                    .and_then(|p| p.checked_mul(g.cout)),
            ),
        ] {
            if prod.is_none() {
                bail!("{name}: conv {what} size overflows");
            }
        }
        Ok(())
    }

    /// Output plane height: `(h + 2·pad - kh) / stride + 1`.
    pub fn out_h(&self) -> usize {
        (self.h + 2 * self.pad - self.kh) / self.stride + 1
    }

    /// Output plane width.
    pub fn out_w(&self) -> usize {
        (self.w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// Taps per kernel — the im2col patch row length and the GEMM `din`.
    pub fn patch_len(&self) -> usize {
        self.kh * self.kw * self.cin
    }

    /// Flattened input features per sample (`cin·h·w`).
    pub fn in_features(&self) -> usize {
        self.cin * self.h * self.w
    }

    /// Flattened output features per sample (`cout·out_h·out_w`).
    pub fn out_features(&self) -> usize {
        self.cout * self.out_h() * self.out_w()
    }

    /// Per-sample MAC count: `out_h·out_w·cout·kh·kw·cin` — the same
    /// convention as the HLO cost pass (`conv FLOPs = 2·MACs`).
    pub fn macs_per_sample(&self) -> usize {
        self.out_features() * self.kh * self.kw * self.cin
    }
}

/// One integer-quantized 2-D convolution layer, lowered onto the dense
/// integer GEMM via im2col.
///
/// The inner [`IntDense`] `core` has `din = kh·kw·cin` (one im2col
/// patch row) and `dout = cout`; its bitlengths, dequantization plans,
/// calibrated activation range, bias and ReLU all apply unchanged.  At
/// [`Granularity::PerOutputChannel`] each *output kernel* is its own
/// quantization group (the `kernel_wise` granularity), reusing the
/// group-size-generic [`PackedGroups`] machinery.
///
/// Activations are `[n, h, w, cin]` HWC row-major; outputs are
/// `[n, out_h, out_w, cout]` — the next conv's input layout, so conv
/// stacks compose without transposes.
pub struct IntConv2d {
    geom: ConvGeom,
    core: IntDense,
}

impl IntConv2d {
    /// Quantize + pack a conv layer at one weight bitlength.  `w` is
    /// `[kh·kw·cin, cout]` row-major (the flattened HWIO kernel).
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        name: &str,
        w: &[f32],
        geom: ConvGeom,
        bias: &[f32],
        w_bits: u32,
        a_bits: u32,
        relu: bool,
    ) -> Result<Self> {
        geom.validate(name)?;
        let core =
            IntDense::new(name, w, geom.patch_len(), geom.cout, bias, w_bits, a_bits, relu)?;
        Ok(Self { geom, core })
    }

    /// Per-output-kernel construction: each kernel (output channel)
    /// packs at its own learned bitlength against its own range.
    #[allow(clippy::too_many_arguments)]
    pub fn new_grouped(
        name: &str,
        w: &[f32],
        geom: ConvGeom,
        bias: &[f32],
        w_bits: &[f32],
        a_bits: u32,
        relu: bool,
    ) -> Result<Self> {
        geom.validate(name)?;
        let core = IntDense::new_grouped(
            name,
            w,
            geom.patch_len(),
            geom.cout,
            bias,
            w_bits,
            a_bits,
            relu,
        )?;
        Ok(Self { geom, core })
    }

    /// [`Self::new`] with an explicit weight [`Codebook`] — the conv
    /// lowers to the dense shift-add core via the same im2col stage.
    #[allow(clippy::too_many_arguments)]
    pub fn new_cbk(
        name: &str,
        w: &[f32],
        geom: ConvGeom,
        bias: &[f32],
        w_bits: u32,
        a_bits: u32,
        relu: bool,
        codebook: Codebook,
    ) -> Result<Self> {
        geom.validate(name)?;
        let core = IntDense::new_cbk(
            name,
            w,
            geom.patch_len(),
            geom.cout,
            bias,
            w_bits,
            a_bits,
            relu,
            codebook,
        )?;
        Ok(Self { geom, core })
    }

    /// [`Self::new_grouped`] with an explicit weight [`Codebook`]
    /// shared by every output kernel.
    #[allow(clippy::too_many_arguments)]
    pub fn new_grouped_cbk(
        name: &str,
        w: &[f32],
        geom: ConvGeom,
        bias: &[f32],
        w_bits: &[f32],
        a_bits: u32,
        relu: bool,
        codebook: Codebook,
    ) -> Result<Self> {
        geom.validate(name)?;
        let core = IntDense::new_grouped_cbk(
            name,
            w,
            geom.patch_len(),
            geom.cout,
            bias,
            w_bits,
            a_bits,
            relu,
            codebook,
        )?;
        Ok(Self { geom, core })
    }

    /// Wrap an already-built GEMM core (the deploy `instantiate` path:
    /// the core is rebuilt bit-identically from stored codes, then this
    /// just attaches the geometry).  Validates the core/geometry
    /// agreement — artifact bytes are untrusted.
    pub fn from_core(geom: ConvGeom, core: IntDense) -> Result<Self> {
        geom.validate(&core.name)?;
        if core.din != geom.patch_len() {
            bail!(
                "{}: core din {} != conv patch len {} (k{}x{} x {} in-channels)",
                core.name, core.din, geom.patch_len(), geom.kh, geom.kw, geom.cin
            );
        }
        if core.dout != geom.cout {
            bail!(
                "{}: core dout {} != conv cout {}",
                core.name, core.dout, geom.cout
            );
        }
        Ok(Self { geom, core })
    }

    pub fn geom(&self) -> &ConvGeom {
        &self.geom
    }

    /// The inner GEMM core (weights `[patch_len, cout]`).
    pub fn core(&self) -> &IntDense {
        &self.core
    }

    pub fn set_act_range(&mut self, lo: f32, hi: f32) {
        self.core.set_act_range(lo, hi);
    }

    /// im2col patch-row count for an `n`-sample batch.
    fn gemm_rows(&self, n: usize) -> usize {
        n * self.geom.out_h() * self.geom.out_w()
    }

    /// Fast im2col: expand `[n, h, w, cin]` into `[n·oh·ow, kh·kw·cin]`
    /// patch rows.  Interior rows copy whole `kw·cin` spans (HWC rows
    /// are contiguous); out-of-plane taps are zero-filled, which is
    /// exactly the zero-padding semantics.  Every element of `col` is
    /// written.
    fn im2col_into(&self, x: &[f32], n: usize, col: &mut [f32]) {
        let g = &self.geom;
        let (h, w, cin) = (g.h, g.w, g.cin);
        let (oh, ow) = (g.out_h(), g.out_w());
        let pl = g.patch_len();
        let pad = g.pad as isize;
        debug_assert_eq!(col.len(), n * oh * ow * pl);
        for s in 0..n {
            let xs = &x[s * g.in_features()..][..g.in_features()];
            for oy in 0..oh {
                for ox in 0..ow {
                    let row = &mut col[((s * oh + oy) * ow + ox) * pl..][..pl];
                    let ix0 = (ox * g.stride) as isize - pad;
                    for ky in 0..g.kh {
                        let iy = (oy * g.stride + ky) as isize - pad;
                        let dst = &mut row[ky * g.kw * cin..][..g.kw * cin];
                        if iy < 0 || iy >= h as isize {
                            dst.fill(0.0);
                            continue;
                        }
                        let iy = iy as usize;
                        if ix0 >= 0 && ix0 as usize + g.kw <= w {
                            // Whole kernel row inside the plane: one
                            // contiguous kw·cin copy.
                            let src = &xs[(iy * w + ix0 as usize) * cin..][..g.kw * cin];
                            dst.copy_from_slice(src);
                        } else {
                            for kx in 0..g.kw {
                                let ix = ix0 + kx as isize;
                                let d = &mut dst[kx * cin..][..cin];
                                if ix < 0 || ix >= w as isize {
                                    d.fill(0.0);
                                } else {
                                    let src = &xs[(iy * w + ix as usize) * cin..][..cin];
                                    d.copy_from_slice(src);
                                }
                            }
                        }
                    }
                }
            }
        }
    }

    /// Forward one batch `[n, h, w, cin]` -> `[n, out_h, out_w, cout]`
    /// (allocating).  Bit-identical to [`Self::forward_ref`]: the two
    /// im2col expansions produce the same values (copies and literal
    /// zeros), and the core GEMM is pinned fast-vs-ref.
    pub fn forward(&self, x: &[f32], n: usize) -> Vec<f32> {
        assert_eq!(
            x.len(),
            n * self.geom.in_features(),
            "{}: bad conv input",
            self.core.name
        );
        let rows = self.gemm_rows(n);
        let mut col = vec![0.0f32; rows * self.geom.patch_len()];
        self.im2col_into(x, n, &mut col);
        self.core.forward(&col, rows)
    }

    /// Serving-path forward: the im2col buffer lives in `sc` and is
    /// reused across calls (no per-forward allocation after warm-up),
    /// and the GEMM dispatches onto the persistent pool.  Bit-identical
    /// to [`Self::forward`].
    pub fn forward_scratch(
        &self,
        x: &[f32],
        n: usize,
        sc: &mut LayerScratch,
        out: &mut [f32],
        pool: Option<&WorkerPool>,
    ) {
        self.forward_scratch_timed(x, n, sc, out, pool, None);
    }

    /// [`Self::forward_scratch`] with an optional im2col/GEMM wall-time
    /// split for the profiler. With `timing == None` (the serve hot
    /// path) no clock is read — the compute is the same either way, so
    /// profiled and unprofiled forwards stay bit-identical.
    pub(crate) fn forward_scratch_timed(
        &self,
        x: &[f32],
        n: usize,
        sc: &mut LayerScratch,
        out: &mut [f32],
        pool: Option<&WorkerPool>,
        timing: Option<&mut (f64, f64)>,
    ) {
        assert_eq!(
            x.len(),
            n * self.geom.in_features(),
            "{}: bad conv input",
            self.core.name
        );
        let rows = self.gemm_rows(n);
        assert_eq!(out.len(), rows * self.geom.cout, "{}: bad conv output", self.core.name);
        // Take the buffer out of the scratch so the core can borrow the
        // scratch mutably alongside it; put it back for the next call.
        let mut col = std::mem::take(&mut sc.im2col);
        col.resize(rows * self.geom.patch_len(), 0.0);
        match timing {
            None => {
                self.im2col_into(x, n, &mut col);
                self.core.forward_scratch(&col, rows, sc, out, pool);
            }
            Some(t) => {
                let t0 = Instant::now();
                self.im2col_into(x, n, &mut col);
                t.0 = t0.elapsed().as_secs_f64();
                let t1 = Instant::now();
                self.core.forward_scratch(&col, rows, sc, out, pool);
                t.1 = t1.elapsed().as_secs_f64();
            }
        }
        sc.im2col = col;
    }

    /// Retained scalar reference: an independent element-at-a-time
    /// im2col gather (no slice copies, no span fast path) feeding the
    /// scalar core.  See `tests/fastpath_parity.rs`.
    pub fn forward_ref(&self, x: &[f32], n: usize) -> Vec<f32> {
        assert_eq!(
            x.len(),
            n * self.geom.in_features(),
            "{}: bad conv input",
            self.core.name
        );
        let g = &self.geom;
        let (oh, ow) = (g.out_h(), g.out_w());
        let pl = g.patch_len();
        let rows = self.gemm_rows(n);
        let mut col = vec![0.0f32; rows * pl];
        for s in 0..n {
            for oy in 0..oh {
                for ox in 0..ow {
                    for ky in 0..g.kh {
                        for kx in 0..g.kw {
                            for c in 0..g.cin {
                                let iy = (oy * g.stride + ky) as isize - g.pad as isize;
                                let ix = (ox * g.stride + kx) as isize - g.pad as isize;
                                let v = if iy >= 0
                                    && (iy as usize) < g.h
                                    && ix >= 0
                                    && (ix as usize) < g.w
                                {
                                    x[((s * g.h + iy as usize) * g.w + ix as usize)
                                        * g.cin
                                        + c]
                                } else {
                                    0.0
                                };
                                col[((s * oh + oy) * ow + ox) * pl
                                    + (ky * g.kw + kx) * g.cin
                                    + c] = v;
                            }
                        }
                    }
                }
            }
        }
        self.core.forward_ref(&col, rows)
    }

    /// Packed storage (bytes): the core's packed codes + bias.
    pub fn packed_bytes(&self) -> usize {
        self.core.packed_bytes()
    }
}

/// One network layer op.  Everything downstream of construction —
/// [`IntNet`], the serve engine, `deploy::freeze`/`instantiate` — works
/// on this enum, so the inference/serving/artifact stack is layer-kind
/// agnostic.
pub enum IntLayer {
    Dense(IntDense),
    Conv2d(IntConv2d),
}

impl From<IntDense> for IntLayer {
    fn from(l: IntDense) -> Self {
        IntLayer::Dense(l)
    }
}

impl From<IntConv2d> for IntLayer {
    fn from(l: IntConv2d) -> Self {
        IntLayer::Conv2d(l)
    }
}

impl IntLayer {
    fn core(&self) -> &IntDense {
        match self {
            IntLayer::Dense(l) => l,
            IntLayer::Conv2d(c) => &c.core,
        }
    }

    fn core_mut(&mut self) -> &mut IntDense {
        match self {
            IntLayer::Dense(l) => l,
            IntLayer::Conv2d(c) => &mut c.core,
        }
    }

    pub fn name(&self) -> &str {
        &self.core().name
    }

    /// Flattened input features per sample (dense `din`; conv
    /// `cin·h·w`) — what the previous layer must emit.
    pub fn in_features(&self) -> usize {
        match self {
            IntLayer::Dense(l) => l.din,
            IntLayer::Conv2d(c) => c.geom.in_features(),
        }
    }

    /// Flattened output features per sample (dense `dout`; conv
    /// `cout·out_h·out_w`).
    pub fn out_features(&self) -> usize {
        match self {
            IntLayer::Dense(l) => l.dout,
            IntLayer::Conv2d(c) => c.geom.out_features(),
        }
    }

    /// Shape of the underlying GEMM: `(din, dout)` for dense,
    /// `(patch_len, cout)` for conv — the weight-tensor shape every
    /// storage path (`WCT0`, footprint accounting) uses.
    pub fn core_dims(&self) -> (usize, usize) {
        let c = self.core();
        (c.din, c.dout)
    }

    /// Conv geometry, when this op is a convolution.
    pub fn conv_geom(&self) -> Option<&ConvGeom> {
        match self {
            IntLayer::Dense(_) => None,
            IntLayer::Conv2d(c) => Some(&c.geom),
        }
    }

    pub fn as_dense(&self) -> Option<&IntDense> {
        match self {
            IntLayer::Dense(l) => Some(l),
            IntLayer::Conv2d(_) => None,
        }
    }

    pub fn as_conv(&self) -> Option<&IntConv2d> {
        match self {
            IntLayer::Dense(_) => None,
            IntLayer::Conv2d(c) => Some(c),
        }
    }

    /// Packed weight codes at their stored granularity.
    pub fn weights(&self) -> &WeightCodes {
        &self.core().weights
    }

    pub fn bias(&self) -> &[f32] {
        &self.core().bias
    }

    pub fn a_bits(&self) -> u32 {
        self.core().a_bits
    }

    pub fn relu(&self) -> bool {
        self.core().relu
    }

    pub fn granularity(&self) -> Granularity {
        self.core().granularity()
    }

    /// Weight codebook of this op's GEMM core.
    pub fn codebook(&self) -> Codebook {
        self.core().codebook()
    }

    pub fn act_range(&self) -> Option<(f32, f32)> {
        self.core().act_range()
    }

    pub fn set_act_range(&mut self, lo: f32, hi: f32) {
        self.core_mut().set_act_range(lo, hi);
    }

    /// Forward one batch of `in_features()`-wide rows (allocating).
    pub fn forward(&self, x: &[f32], n: usize) -> Vec<f32> {
        match self {
            IntLayer::Dense(l) => l.forward(x, n),
            IntLayer::Conv2d(c) => c.forward(x, n),
        }
    }

    /// Serving-path forward into a caller slice of
    /// `n * out_features()`, reusing `sc`.
    pub fn forward_scratch(
        &self,
        x: &[f32],
        n: usize,
        sc: &mut LayerScratch,
        out: &mut [f32],
        pool: Option<&WorkerPool>,
    ) {
        match self {
            IntLayer::Dense(l) => l.forward_scratch(x, n, sc, out, pool),
            IntLayer::Conv2d(c) => c.forward_scratch(x, n, sc, out, pool),
        }
    }

    /// [`Self::forward_scratch`] returning the `(im2col_s, gemm_s)`
    /// wall-time split for the profiler (dense layers report a zero
    /// im2col share). Same computation as the unprofiled path.
    pub(crate) fn forward_scratch_profiled(
        &self,
        x: &[f32],
        n: usize,
        sc: &mut LayerScratch,
        out: &mut [f32],
        pool: Option<&WorkerPool>,
    ) -> (f64, f64) {
        match self {
            IntLayer::Dense(l) => {
                let t0 = Instant::now();
                l.forward_scratch(x, n, sc, out, pool);
                (0.0, t0.elapsed().as_secs_f64())
            }
            IntLayer::Conv2d(c) => {
                let mut split = (0.0, 0.0);
                c.forward_scratch_timed(x, n, sc, out, pool, Some(&mut split));
                split
            }
        }
    }

    /// Integer multiply-accumulates for an `n`-sample forward, priced
    /// with the regularizer's conventions: `n·din·dout` for dense,
    /// `n · `[`crate::quant::conv_macs`] for conv (equal to the lowered
    /// GEMM's `rows·patch_len·cout`).
    pub fn macs(&self, n: usize) -> u64 {
        match self {
            IntLayer::Dense(l) => (n * l.din * l.dout) as u64,
            IntLayer::Conv2d(c) => {
                let g = &c.geom;
                (n * quant::conv_macs(g.cin, g.kh, g.kw, g.out_h(), g.out_w(), g.cout)) as u64
            }
        }
    }

    /// Bytes a forward touches at batch `n`: the packed weight codes
    /// plus the f32 input and output activation planes.
    pub fn bytes_touched(&self, n: usize) -> u64 {
        (self.packed_bytes() + n * (self.in_features() + self.out_features()) * 4) as u64
    }

    /// Retained scalar reference path.
    pub fn forward_ref(&self, x: &[f32], n: usize) -> Vec<f32> {
        match self {
            IntLayer::Dense(l) => l.forward_ref(x, n),
            IntLayer::Conv2d(c) => c.forward_ref(x, n),
        }
    }

    pub fn packed_bytes(&self) -> usize {
        self.core().packed_bytes()
    }

    /// f32 footprint of the same parameters (weights + bias).
    pub fn f32_bytes(&self) -> usize {
        let (din, dout) = self.core_dims();
        (din * dout + dout) * 4
    }
}

/// An integer-quantized network: a sequence of [`IntLayer`] ops.
pub struct IntNet {
    pub layers: Vec<IntLayer>,
    pub num_classes: usize,
}

impl IntNet {
    /// Build from a trained network's flat parameters + integer
    /// bitlengths, using the artifact metadata for the layout.
    ///
    /// `params` are in the artifact's flattened order (`meta.param_names`
    /// e.g. `["0/b", "0/w", "1/b", ...]`); only dense-kind models are
    /// supported.
    ///
    /// `act_ranges` carries calibrated per-layer activation ranges
    /// (`(act_min, act_max)`, one entry per layer — e.g. the trainer's
    /// `EvalOutcome::{act_min, act_max}` aggregated over the test set).
    /// With ranges attached, per-sample logits are **bit-identical for
    /// every batch composition**; `None` keeps the dynamic per-batch
    /// min/max fallback (batch-dependent logits).
    pub fn from_trained(
        meta: &ModelMeta,
        params: &[HostTensor],
        bits_w: &[f32],
        bits_a: &[f32],
        act_ranges: Option<(&[f32], &[f32])>,
    ) -> Result<Self> {
        Self::from_trained_with(meta, params, bits_w, bits_a, act_ranges, Granularity::PerLayer)
    }

    /// [`Self::from_trained`] with an explicit weight granularity.
    ///
    /// `Granularity::PerOutputChannel` refines each layer's learned
    /// bitlength into per-channel bitlengths
    /// ([`quant::per_channel_bits`]: a channel spanning a fraction of
    /// the layer's range keeps the layer's quantization step with fewer
    /// levels) and packs every channel at its own bitlength against its
    /// own range — the aggressive sub-layer deployment the paper's
    /// granularity claim promises.
    pub fn from_trained_with(
        meta: &ModelMeta,
        params: &[HostTensor],
        bits_w: &[f32],
        bits_a: &[f32],
        act_ranges: Option<(&[f32], &[f32])>,
        granularity: Granularity,
    ) -> Result<Self> {
        if meta.layers.iter().any(|l| l.kind != "dense") {
            bail!(
                "IntNet supports dense-only models; '{}' has non-dense layers",
                meta.model
            );
        }
        if params.len() != meta.num_params {
            bail!("params len {} != meta {}", params.len(), meta.num_params);
        }
        let nl = meta.layers.len();
        if bits_w.len() != nl || bits_a.len() != nl {
            bail!(
                "bitlength vectors ({} weight, {} activation entries) do not match {} layers",
                bits_w.len(),
                bits_a.len(),
                nl
            );
        }
        if let Some((lo, hi)) = act_ranges {
            if lo.len() != nl || hi.len() != nl {
                bail!(
                    "act_ranges ({} min, {} max entries) do not match {} layers",
                    lo.len(),
                    hi.len(),
                    nl
                );
            }
        }
        let find = |name: &str| -> Result<&HostTensor> {
            meta.param_names
                .iter()
                .position(|n| n == name)
                .map(|i| &params[i])
                .ok_or_else(|| anyhow::anyhow!("param '{name}' not found"))
        };
        let mut layers = Vec::new();
        let last = meta.layers.len() - 1;
        for (i, geom) in meta.layers.iter().enumerate() {
            let w = find(&format!("{i}/w"))?;
            let b = find(&format!("{i}/b"))?;
            let (din, dout) = (geom.cin, geom.cout);
            let mut layer = match granularity {
                Granularity::PerLayer => IntDense::new(
                    &geom.name,
                    w.as_f32()?,
                    din,
                    dout,
                    b.as_f32()?,
                    quant::int_bits(bits_w[i]),
                    quant::int_bits(bits_a[i]),
                    i != last,
                )?,
                Granularity::PerOutputChannel => {
                    let wf = w.as_f32()?;
                    let ch_bits = quant::per_channel_bits(wf, din, dout, bits_w[i]);
                    IntDense::new_grouped(
                        &geom.name,
                        wf,
                        din,
                        dout,
                        b.as_f32()?,
                        &ch_bits,
                        quant::int_bits(bits_a[i]),
                        i != last,
                    )?
                }
            };
            if let Some((lo, hi)) = act_ranges {
                layer.set_act_range(lo[i], hi[i]);
            }
            layers.push(IntLayer::from(layer));
        }
        Ok(Self { layers, num_classes: meta.num_classes })
    }

    /// Flattened input width the net consumes (first layer's
    /// `in_features`; 0 for an empty net).
    pub fn in_features(&self) -> usize {
        self.layers.first().map(|l| l.in_features()).unwrap_or(0)
    }

    /// Flattened output width the net emits (last layer's
    /// `out_features`; 0 for an empty net).
    pub fn out_features(&self) -> usize {
        self.layers.last().map(|l| l.out_features()).unwrap_or(0)
    }

    /// Attach calibrated per-layer activation ranges to an existing net
    /// (one `(lo, hi)` per layer, layer order).
    pub fn set_act_ranges(&mut self, act_min: &[f32], act_max: &[f32]) -> Result<()> {
        if act_min.len() != self.layers.len() || act_max.len() != self.layers.len() {
            bail!(
                "act ranges ({} min, {} max entries) do not match {} layers",
                act_min.len(),
                act_max.len(),
                self.layers.len()
            );
        }
        for ((layer, &lo), &hi) in self.layers.iter_mut().zip(act_min).zip(act_max) {
            layer.set_act_range(lo, hi);
        }
        Ok(())
    }

    /// Whether every layer has a calibrated activation range (the
    /// precondition for batch-invariant logits).
    pub fn is_calibrated(&self) -> bool {
        self.layers.iter().all(|l| l.act_range().is_some())
    }

    /// Self-calibrate on a representative batch: run it through the net
    /// layer by layer, pinning each layer's input range to the batch's
    /// min/max before forwarding through it (standard offline
    /// post-training calibration).  After this, forwards are
    /// batch-invariant.
    pub fn calibrate(&mut self, x: &[f32], n: usize) -> Result<()> {
        if self.layers.is_empty() {
            return Ok(());
        }
        if n == 0 || x.len() != n * self.in_features() {
            bail!(
                "calibrate: {} values is not a [{n}, {}] batch",
                x.len(),
                self.in_features()
            );
        }
        let mut h = x.to_vec();
        for layer in &mut self.layers {
            let (mut lo, mut hi) = quant::group_minmax(&h);
            // A padded conv injects literal zeros into the im2col rows,
            // so the quantization grid must cover 0 even when the batch
            // itself doesn't.
            if layer.conv_geom().is_some_and(|g| g.pad > 0) {
                lo = lo.min(0.0);
                hi = hi.max(0.0);
            }
            layer.set_act_range(lo, hi);
            h = layer.forward(&h, n);
        }
        Ok(())
    }

    /// Forward a batch, returning logits [n, num_classes].
    pub fn forward(&self, x: &[f32], n: usize) -> Vec<f32> {
        let mut h = x.to_vec();
        for layer in &self.layers {
            h = layer.forward(&h, n);
        }
        h
    }

    /// Serving-path forward: bit-identical to [`Self::forward`], but
    /// reuses `sc`'s ping-pong activation buffers (no per-layer `Vec`
    /// allocation after the first call) and runs each layer's GEMM on
    /// the given persistent [`WorkerPool`] instead of spawning scoped
    /// threads.  Returns the logits slice `[n, num_classes]`, borrowed
    /// from the scratch.
    pub fn forward_into<'s>(
        &self,
        x: &[f32],
        n: usize,
        sc: &'s mut NetScratch,
        pool: Option<&WorkerPool>,
    ) -> &'s [f32] {
        self.forward_into_impl(x, n, sc, pool, None)
    }

    /// [`Self::forward_into`] with per-layer wall-time / MAC / byte
    /// attribution recorded into `prof` (see [`ForwardProfile`]). The
    /// computation is identical — profiling only adds clock reads around
    /// each layer — so logits are bit-identical to the unprofiled path.
    pub fn forward_into_profiled<'s>(
        &self,
        x: &[f32],
        n: usize,
        sc: &'s mut NetScratch,
        pool: Option<&WorkerPool>,
        prof: &mut ForwardProfile,
    ) -> &'s [f32] {
        prof.reset(n);
        let t0 = Instant::now();
        let out = self.forward_into_impl(x, n, sc, pool, Some(prof));
        prof.total_s = t0.elapsed().as_secs_f64();
        out
    }

    fn forward_into_impl<'s>(
        &self,
        x: &[f32],
        n: usize,
        sc: &'s mut NetScratch,
        pool: Option<&WorkerPool>,
        mut prof: Option<&mut ForwardProfile>,
    ) -> &'s [f32] {
        sc.ping.clear();
        sc.ping.extend_from_slice(x);
        for layer in &self.layers {
            sc.pong.resize(n * layer.out_features(), 0.0);
            match prof.as_deref_mut() {
                None => {
                    layer.forward_scratch(&sc.ping, n, &mut sc.layer, &mut sc.pong, pool);
                }
                Some(p) => {
                    let t0 = Instant::now();
                    let (im2col_s, gemm_s) = layer
                        .forward_scratch_profiled(&sc.ping, n, &mut sc.layer, &mut sc.pong, pool);
                    p.layers.push(LayerProfile {
                        name: layer.name().to_string(),
                        total_s: t0.elapsed().as_secs_f64(),
                        im2col_s,
                        gemm_s,
                        macs: layer.macs(n),
                        bytes: layer.bytes_touched(n),
                    });
                }
            }
            std::mem::swap(&mut sc.ping, &mut sc.pong);
        }
        &sc.ping
    }

    /// Classify a batch.
    pub fn predict(&self, x: &[f32], n: usize) -> Vec<usize> {
        argmax_rows(&self.forward(x, n), self.num_classes)
    }

    /// Total packed model size in bytes.
    pub fn packed_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.packed_bytes()).sum()
    }

    /// f32 model size in bytes.
    pub fn f32_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.f32_bytes()).sum()
    }

    /// Mean stored weight bitlength over every group of every layer
    /// (per-layer layers count as one group) — the sub-layer average
    /// the per-channel path reports.
    pub fn mean_w_bits(&self) -> f64 {
        let (mut sum, mut n) = (0.0f64, 0usize);
        for l in &self.layers {
            let h = l.weights().bits_histogram();
            for (bits, &count) in h.iter().enumerate() {
                sum += (bits * count) as f64;
                n += count;
            }
        }
        if n == 0 {
            0.0
        } else {
            sum / n as f64
        }
    }

    /// Aggregate per-channel weight-bit histogram across layers
    /// (index = bitlength, 1..=16).
    pub fn w_bits_histogram(&self) -> [usize; 17] {
        let mut h = [0usize; 17];
        for l in &self.layers {
            for (i, c) in l.weights().bits_histogram().iter().enumerate() {
                h[i] += c;
            }
        }
        h
    }
}

/// Per-row argmax over `[n, nc]` logits — the one classification rule
/// every prediction surface shares ([`IntNet::predict`], the serve
/// engine).  Ties resolve to the highest index, NaN-safe via
/// `total_cmp`.
pub fn argmax_rows(logits: &[f32], nc: usize) -> Vec<usize> {
    logits
        .chunks_exact(nc)
        .map(|row| {
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    /// Float reference: fake-quantize activations + weights, plain GEMM.
    fn float_ref(
        x: &[f32], n: usize, w: &[f32], din: usize, dout: usize,
        bias: &[f32], w_bits: f32, a_bits: f32, relu: bool,
    ) -> Vec<f32> {
        let mut xq = x.to_vec();
        quant::fake_quant_slice(&mut xq, a_bits);
        let mut wq = w.to_vec();
        quant::fake_quant_slice(&mut wq, w_bits);
        let mut out = vec![0.0f32; n * dout];
        for r in 0..n {
            for j in 0..dout {
                let mut acc = 0.0f64;
                for c in 0..din {
                    acc += xq[r * din + c] as f64 * wq[c * dout + j] as f64;
                }
                let v = (acc + bias[j] as f64) as f32;
                out[r * dout + j] = if relu { v.max(0.0) } else { v };
            }
        }
        out
    }

    #[test]
    fn integer_layer_matches_float_fake_quant() {
        let mut rng = Rng::new(4);
        for &(wb, ab) in &[(2u32, 3u32), (4, 4), (8, 8), (1, 1)] {
            let (n, din, dout) = (5, 12, 7);
            let x = rand_vec(&mut rng, n * din);
            let w = rand_vec(&mut rng, din * dout);
            let b = rand_vec(&mut rng, dout);
            let layer =
                IntDense::new("t", &w, din, dout, &b, wb, ab, true).unwrap();
            let got = layer.forward(&x, n);
            let want =
                float_ref(&x, n, &w, din, dout, &b, wb as f32, ab as f32, true);
            for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w_).abs() < 1e-3 * (1.0 + w_.abs()),
                    "bits ({wb},{ab}) elem {i}: int {g} vs float {w_}"
                );
            }
        }
    }

    #[test]
    fn blocked_gemm_matches_ref_bitwise() {
        // Odd shapes: remainder columns (dout % 4 != 0), tiny dims.
        let mut rng = Rng::new(0x6E44);
        for &(n, din, dout, wb, ab, relu) in &[
            (1usize, 1usize, 1usize, 4u32, 4u32, true),
            (3, 5, 7, 2, 3, false),
            (8, 17, 13, 8, 6, true),
            (5, 33, 9, 16, 16, false),
            (16, 64, 10, 1, 1, true),
        ] {
            let x = rand_vec(&mut rng, n * din);
            let w = rand_vec(&mut rng, din * dout);
            let b = rand_vec(&mut rng, dout);
            let layer = IntDense::new("p", &w, din, dout, &b, wb, ab, relu).unwrap();
            let fast = layer.forward(&x, n);
            let slow = layer.forward_ref(&x, n);
            for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
                assert_eq!(
                    f.to_bits(),
                    s.to_bits(),
                    "({n},{din},{dout}) bits ({wb},{ab}) elem {i}: {f} vs {s}"
                );
            }
        }
    }

    #[test]
    fn threaded_gemm_matches_ref_bitwise() {
        // Large enough to cross PAR_MIN_MACS and engage the scoped
        // threads, with n chosen so row chunks split unevenly.
        let mut rng = Rng::new(0x7EAD);
        let (n, din, dout) = (67, 128, 128); // 1.1M MACs > 2^20
        assert!(n * din * dout >= super::PAR_MIN_MACS);
        let x = rand_vec(&mut rng, n * din);
        let w = rand_vec(&mut rng, din * dout);
        let b = rand_vec(&mut rng, dout);
        let layer = IntDense::new("t", &w, din, dout, &b, 4, 4, true).unwrap();
        let fast = layer.forward(&x, n);
        let slow = layer.forward_ref(&x, n);
        assert_eq!(fast.len(), slow.len());
        for (f, s) in fast.iter().zip(&slow) {
            assert_eq!(f.to_bits(), s.to_bits());
        }
    }

    #[test]
    fn from_packed_rebuild_is_bit_identical() {
        // The deploy path: a layer rebuilt from its stored packed codes
        // (no f32 weights, no re-quantization) must forward identically.
        let mut rng = Rng::new(0xF40E);
        let (n, din, dout) = (5usize, 11usize, 9usize);
        let x = rand_vec(&mut rng, n * din);
        let w = rand_vec(&mut rng, din * dout);
        let b = rand_vec(&mut rng, dout);
        let mut src = IntDense::new("fz", &w, din, dout, &b, 3, 5, true).unwrap();
        src.set_act_range(-2.0, 2.0);
        let rebuilt = IntDense::from_packed(
            "fz",
            src.packed_per_layer().unwrap().clone(),
            din,
            dout,
            src.bias.clone(),
            src.a_bits,
            src.relu,
            src.act_range(),
        )
        .unwrap();
        let want = src.forward(&x, n);
        let got = rebuilt.forward(&x, n);
        assert!(want.iter().zip(&got).all(|(p, q)| p.to_bits() == q.to_bits()));
        // Untrusted-input validation: geometry/codes disagreement, bad
        // bias length, out-of-range activation bits.
        let p = src.packed_per_layer().unwrap().clone();
        let bias = src.bias.clone();
        assert!(
            IntDense::from_packed("z", p.clone(), din, dout + 1, bias.clone(), 4, true, None)
                .is_err()
        );
        assert!(
            IntDense::from_packed("z", p.clone(), din, dout, vec![0.0; 3], 4, true, None)
                .is_err()
        );
        assert!(IntDense::from_packed("z", p, din, dout, bias, 0, true, None).is_err());
    }

    fn transpose(w: &[f32], din: usize, dout: usize) -> Vec<f32> {
        let mut wt = vec![0.0f32; din * dout];
        for i in 0..din {
            for j in 0..dout {
                wt[j * din + i] = w[i * dout + j];
            }
        }
        wt
    }

    #[test]
    fn grouped_forward_matches_grouped_ref_bitwise() {
        // Row-varying codes through the blocked GEMM vs the scalar
        // grouped baseline: odd shapes, mixed per-channel bitlengths,
        // remainder columns, calibrated and dynamic ranges.
        let mut rng = Rng::new(0x64E0);
        for &(n, din, dout, calibrated) in &[
            (1usize, 1usize, 1usize, false),
            (3, 5, 7, true),
            (8, 17, 13, false),
            (5, 33, 9, true),
        ] {
            let x = rand_vec(&mut rng, n * din);
            let w = rand_vec(&mut rng, din * dout);
            let b = rand_vec(&mut rng, dout);
            let bits: Vec<f32> =
                (0..dout).map(|j| (1 + (j * 5) % 16) as f32).collect();
            let mut layer =
                IntDense::new_grouped("g", &w, din, dout, &b, &bits, 4, true).unwrap();
            if calibrated {
                layer.set_act_range(-2.0, 2.0);
            }
            assert_eq!(layer.granularity(), Granularity::PerOutputChannel);
            let fast = layer.forward(&x, n);
            let slow = layer.forward_ref(&x, n);
            for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
                assert_eq!(
                    f.to_bits(),
                    s.to_bits(),
                    "({n},{din},{dout}) elem {i}: {f} vs {s}"
                );
            }
        }
    }

    #[test]
    fn grouped_uniform_plan_is_bit_identical_to_per_layer() {
        // The parity pin: a PerOutputChannel layer whose channels all
        // share one bitlength *and one (lmin, scale) plan* must forward
        // bit-identically to the PerLayer layer it mirrors — fast and
        // _ref paths.  din is byte-aligned so the per-layer bitstream
        // of the transposed weights is exactly the group-aligned
        // layout.
        let mut rng = Rng::new(0x64E1);
        for &(n, din, dout, bits) in &[
            (4usize, 8usize, 7usize, 3u32),
            (2, 16, 10, 5),
            (6, 8, 4, 1),
            (3, 24, 6, 16),
        ] {
            let x = rand_vec(&mut rng, n * din);
            let w = rand_vec(&mut rng, din * dout);
            let b = rand_vec(&mut rng, dout);
            let per_layer =
                IntDense::new("pl", &w, din, dout, &b, bits, 6, true).unwrap();
            // Same plan, channel-major codes: pack the transposed
            // weights per-layer (min/max is permutation-invariant), then
            // reinterpret the byte-aligned stream as per-channel spans.
            let flat = pack(&transpose(&w, din, dout), bits).unwrap();
            assert_eq!((din * bits as usize) % 8, 0, "test needs aligned groups");
            let params: Vec<(u32, f32, f32)> =
                vec![(flat.bits, flat.lmin, flat.scale); dout];
            let groups =
                PackedGroups::from_raw(din, &params, flat.data.clone()).unwrap();
            let grouped = IntDense::from_packed_groups(
                "gr", groups, din, dout, b.clone(), 6, true, None,
            )
            .unwrap();
            let want = per_layer.forward(&x, n);
            let got = grouped.forward(&x, n);
            let got_ref = grouped.forward_ref(&x, n);
            for (i, ((a, g), r)) in want.iter().zip(&got).zip(&got_ref).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    g.to_bits(),
                    "({n},{din},{dout},{bits}b) fast elem {i}: {a} vs {g}"
                );
                assert_eq!(
                    a.to_bits(),
                    r.to_bits(),
                    "({n},{din},{dout},{bits}b) ref elem {i}: {a} vs {r}"
                );
            }
        }
    }

    #[test]
    fn grouped_forward_scratch_matches_forward_bitwise() {
        // The serving path consumes row-varying codes too: scratch +
        // pooled dispatch must stay bit-identical, including above the
        // parallel threshold.
        let pool = crate::util::pool::WorkerPool::new(3);
        let mut sc = LayerScratch::default();
        let mut rng = Rng::new(0x64E2);
        for &(n, din, dout) in &[(1usize, 9usize, 5usize), (7, 31, 11), (67, 128, 128)] {
            let x = rand_vec(&mut rng, n * din);
            let w = rand_vec(&mut rng, din * dout);
            let b = rand_vec(&mut rng, dout);
            let bits: Vec<f32> =
                (0..dout).map(|j| (2 + (j * 3) % 7) as f32).collect();
            let mut layer =
                IntDense::new_grouped("gs", &w, din, dout, &b, &bits, 5, true).unwrap();
            layer.set_act_range(-2.5, 2.5);
            let want = layer.forward(&x, n);
            let mut got = vec![0.0f32; n * dout];
            layer.forward_scratch(&x, n, &mut sc, &mut got, Some(&pool));
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "pooled grouped scratch diverged at ({n},{din},{dout})"
            );
            let mut inline = vec![0.0f32; n * dout];
            layer.forward_scratch(&x, n, &mut sc, &mut inline, None);
            assert!(inline.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn grouped_rebuild_from_packed_groups_is_bit_identical() {
        // Deploy path for grouped layers: rebuilding from stored groups
        // must never re-quantize.
        let mut rng = Rng::new(0x64E3);
        let (n, din, dout) = (4usize, 13usize, 6usize);
        let x = rand_vec(&mut rng, n * din);
        let w = rand_vec(&mut rng, din * dout);
        let b = rand_vec(&mut rng, dout);
        let bits = [1.0f32, 4.0, 7.5, 16.0, 2.0, 3.0];
        let mut src =
            IntDense::new_grouped("fzg", &w, din, dout, &b, &bits, 4, false).unwrap();
        src.set_act_range(-1.5, 1.5);
        let groups = src.packed_groups().unwrap().clone();
        let rebuilt = IntDense::from_packed_groups(
            "fzg",
            groups.clone(),
            din,
            dout,
            src.bias.clone(),
            src.a_bits,
            src.relu,
            src.act_range(),
        )
        .unwrap();
        let want = src.forward(&x, n);
        let got = rebuilt.forward(&x, n);
        assert!(want.iter().zip(&got).all(|(p, q)| p.to_bits() == q.to_bits()));
        // Validation: wrong group size / group count / bias, bad a_bits.
        assert!(IntDense::from_packed_groups(
            "z", groups.clone(), din + 1, dout, b.clone(), 4, false, None
        )
        .is_err());
        assert!(IntDense::from_packed_groups(
            "z", groups.clone(), din, dout + 1, b.clone(), 4, false, None
        )
        .is_err());
        assert!(IntDense::from_packed_groups(
            "z", groups.clone(), din, dout, vec![0.0; 2], 4, false, None
        )
        .is_err());
        assert!(
            IntDense::from_packed_groups("z", groups, din, dout, b, 0, false, None)
                .is_err()
        );
        // new_grouped validates the channel-bit count.
        assert!(IntDense::new_grouped("z", &w, din, dout, &src.bias, &[4.0], 4, false)
            .is_err());
    }

    #[test]
    fn grouped_mixed_bits_shrink_footprint() {
        // A mixed-bit grouped layer must cost less than the per-layer
        // layer at the max channel bitlength, and the histogram/mean
        // must reflect the assignment.
        let mut rng = Rng::new(0x64E4);
        let (din, dout) = (64usize, 8usize);
        let w = rand_vec(&mut rng, din * dout);
        let b = vec![0.0f32; dout];
        let bits: Vec<f32> = vec![2.0, 2.0, 2.0, 2.0, 4.0, 4.0, 8.0, 8.0];
        let grouped =
            IntDense::new_grouped("m", &w, din, dout, &b, &bits, 8, true).unwrap();
        let flat8 = IntDense::new("f", &w, din, dout, &b, 8, 8, true).unwrap();
        assert!(grouped.packed_bytes() < flat8.packed_bytes());
        let h = grouped.weights.bits_histogram();
        assert_eq!((h[2], h[4], h[8]), (4, 2, 2));
        assert!((grouped.weights.mean_bits() - 4.0).abs() < 1e-12);
        let net = IntNet { layers: vec![grouped.into()], num_classes: dout };
        assert!((net.mean_w_bits() - 4.0).abs() < 1e-12);
        assert_eq!(net.w_bits_histogram()[2], 4);
    }

    #[test]
    fn packed_size_shrinks_with_bits() {
        let mut rng = Rng::new(5);
        let w = rand_vec(&mut rng, 64 * 32);
        let b = vec![0.0; 32];
        let l8 = IntDense::new("a", &w, 64, 32, &b, 8, 8, true).unwrap();
        let l2 = IntDense::new("b", &w, 64, 32, &b, 2, 8, true).unwrap();
        assert!(l2.packed_bytes() < l8.packed_bytes());
        // 2-bit weights ≈ 1/16 of f32
        assert!(l2.packed_per_layer().unwrap().ratio_vs_f32() > 15.0);
    }

    #[test]
    fn shape_validation() {
        let w = vec![0.0f32; 10];
        assert!(IntDense::new("x", &w, 3, 4, &[0.0; 4], 4, 4, true).is_err());
        assert!(IntDense::new("x", &w, 5, 2, &[0.0; 3], 4, 4, true).is_err());
    }

    #[test]
    fn forward_scratch_matches_forward_bitwise() {
        // The buffer-reusing serving path must be bit-identical to the
        // allocating path — dynamic and calibrated, pooled and inline,
        // across odd shapes, with the scratch reused between calls.
        let mut rng = Rng::new(0x5E41);
        let pool = crate::util::pool::WorkerPool::new(3);
        let mut sc = LayerScratch::default();
        for &(n, din, dout, calibrated) in &[
            (1usize, 1usize, 1usize, false),
            (3, 5, 7, true),
            (8, 17, 13, false),
            (67, 128, 128, true), // crosses PAR_MIN_MACS -> pooled GEMM
        ] {
            let x = rand_vec(&mut rng, n * din);
            let w = rand_vec(&mut rng, din * dout);
            let b = rand_vec(&mut rng, dout);
            let mut layer =
                IntDense::new("sc", &w, din, dout, &b, 4, 4, true).unwrap();
            if calibrated {
                layer.set_act_range(-2.5, 2.5);
            }
            let want = layer.forward(&x, n);
            let mut got = vec![0.0f32; n * dout];
            layer.forward_scratch(&x, n, &mut sc, &mut got, Some(&pool));
            for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
                assert_eq!(
                    g.to_bits(),
                    w_.to_bits(),
                    "({n},{din},{dout}) calibrated={calibrated} elem {i}"
                );
            }
            // Inline (pool-less) dispatch too.
            let mut inline = vec![0.0f32; n * dout];
            layer.forward_scratch(&x, n, &mut sc, &mut inline, None);
            assert!(inline.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn calibrated_layer_is_batch_invariant() {
        // With a pinned range, a sample's output must not depend on its
        // batch neighbours; dynamically the same setup does differ.
        let mut rng = Rng::new(0xCAFE);
        let (din, dout) = (9, 6);
        let w = rand_vec(&mut rng, din * dout);
        let b = rand_vec(&mut rng, dout);
        let sample = rand_vec(&mut rng, din);
        // An outlier neighbour that stretches the dynamic batch range.
        let mut outlier = rand_vec(&mut rng, din);
        outlier[0] = 40.0;
        let mut batch = sample.clone();
        batch.extend_from_slice(&outlier);

        let mut layer = IntDense::new("inv", &w, din, dout, &b, 3, 3, false).unwrap();
        let dyn_solo = layer.forward(&sample, 1);
        let dyn_pair = layer.forward(&batch, 2);
        assert!(
            dyn_solo
                .iter()
                .zip(&dyn_pair[..dout])
                .any(|(a, b)| a.to_bits() != b.to_bits()),
            "dynamic ranges should make logits batch-dependent here"
        );

        layer.set_act_range(-3.0, 3.0);
        let cal_solo = layer.forward(&sample, 1);
        let cal_pair = layer.forward(&batch, 2);
        for (a, b) in cal_solo.iter().zip(&cal_pair[..dout]) {
            assert_eq!(a.to_bits(), b.to_bits(), "calibrated logits must be invariant");
        }
    }

    #[test]
    fn net_calibrate_pins_every_layer() {
        let mut rng = Rng::new(11);
        let l0 = IntDense::new(
            "fc0", &rand_vec(&mut rng, 6 * 10), 6, 10, &vec![0.0; 10], 4, 4, true,
        )
        .unwrap();
        let l1 = IntDense::new(
            "fc1", &rand_vec(&mut rng, 10 * 4), 10, 4, &vec![0.0; 4], 4, 4, false,
        )
        .unwrap();
        let mut net = IntNet { layers: vec![l0.into(), l1.into()], num_classes: 4 };
        assert!(!net.is_calibrated());
        let calib = rand_vec(&mut rng, 32 * 6);
        net.calibrate(&calib, 32).unwrap();
        assert!(net.is_calibrated());
        // Layer 1's input is post-ReLU: its calibrated range starts >= 0.
        let (lo, _) = net.layers[1].act_range().unwrap();
        assert!(lo >= 0.0);
        // Bad calibration shapes are rejected.
        assert!(net.calibrate(&calib, 5).is_err());
        assert!(net.set_act_ranges(&[0.0], &[1.0, 2.0]).is_err());
    }

    #[test]
    fn from_trained_validates_lengths() {
        let j = crate::util::json::parse(&crate::model::tiny_meta_json()).unwrap();
        let meta = ModelMeta::from_json(&j).unwrap();
        let mut rng = Rng::new(3);
        let params = vec![
            HostTensor::f32(&[8, 16], rand_vec(&mut rng, 128)).unwrap(),
            HostTensor::f32(&[16], rand_vec(&mut rng, 16)).unwrap(),
            HostTensor::f32(&[16, 3], rand_vec(&mut rng, 48)).unwrap(),
            HostTensor::f32(&[3], rand_vec(&mut rng, 3)).unwrap(),
        ];
        let bits = vec![4.0f32; 2];
        // Short bitlength vector: error, not a panic or silent truncation.
        assert!(IntNet::from_trained(&meta, &params, &[4.0], &bits, None).is_err());
        // Mismatched calibration vectors: error.
        let short_lo = [0.0f32];
        let hi = [1.0f32, 1.0];
        assert!(IntNet::from_trained(
            &meta,
            &params,
            &bits,
            &bits,
            Some((&short_lo[..], &hi[..]))
        )
        .is_err());
        // Well-formed calibrated build pins every layer.
        let lo = [-1.0f32, 0.0];
        let hi = [1.0f32, 5.0];
        let net = IntNet::from_trained(
            &meta,
            &params,
            &bits,
            &bits,
            Some((&lo[..], &hi[..])),
        )
        .unwrap();
        assert!(net.is_calibrated());
        assert_eq!(net.layers[1].act_range(), Some((0.0, 5.0)));
    }

    #[test]
    fn net_predict_shapes() {
        let mut rng = Rng::new(6);
        let l0 = IntDense::new(
            "fc0", &rand_vec(&mut rng, 8 * 16), 8, 16, &vec![0.0; 16], 4, 4, true,
        )
        .unwrap();
        let l1 = IntDense::new(
            "fc1", &rand_vec(&mut rng, 16 * 3), 16, 3, &vec![0.0; 3], 4, 4, false,
        )
        .unwrap();
        let net = IntNet { layers: vec![l0.into(), l1.into()], num_classes: 3 };
        let x = rand_vec(&mut rng, 4 * 8);
        let preds = net.predict(&x, 4);
        assert_eq!(preds.len(), 4);
        assert!(preds.iter().all(|&p| p < 3));
        assert!(net.packed_bytes() < net.f32_bytes());
    }

    fn geom(
        cin: usize, h: usize, w: usize, cout: usize,
        kh: usize, kw: usize, stride: usize, pad: usize,
    ) -> ConvGeom {
        ConvGeom { cin, h, w, cout, kh, kw, stride, pad }
    }

    #[test]
    fn conv_fast_matches_ref_bitwise() {
        // Span-copying im2col + blocked GEMM vs the element-at-a-time
        // gather + scalar GEMM, across stride/pad combinations that
        // exercise every padding branch (full rows out of plane,
        // partial kernel rows, interior fast copies).
        let mut rng = Rng::new(0xC2D0);
        for &(n, g) in &[
            (2usize, geom(3, 6, 6, 4, 3, 3, 1, 1)),
            (1, geom(1, 5, 7, 3, 3, 3, 2, 0)),
            (3, geom(2, 4, 4, 5, 1, 1, 1, 0)),
            (2, geom(4, 7, 5, 2, 5, 3, 2, 2)),
            (1, geom(2, 3, 3, 2, 3, 3, 1, 2)), // pad > interior reach
        ] {
            let x = rand_vec(&mut rng, n * g.in_features());
            let w = rand_vec(&mut rng, g.patch_len() * g.cout);
            let b = rand_vec(&mut rng, g.cout);
            let conv = IntConv2d::new("cv", &w, g, &b, 4, 5, true).unwrap();
            let fast = conv.forward(&x, n);
            let slow = conv.forward_ref(&x, n);
            assert_eq!(fast.len(), n * g.out_features());
            for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
                assert_eq!(
                    f.to_bits(),
                    s.to_bits(),
                    "{g:?} n={n} elem {i}: {f} vs {s}"
                );
            }
        }
    }

    #[test]
    fn conv_grouped_fast_matches_ref_bitwise() {
        // Per-output-kernel bitlengths through the same im2col lowering.
        let mut rng = Rng::new(0xC2D1);
        let g = geom(3, 6, 6, 5, 3, 3, 1, 1);
        let x = rand_vec(&mut rng, 2 * g.in_features());
        let w = rand_vec(&mut rng, g.patch_len() * g.cout);
        let b = rand_vec(&mut rng, g.cout);
        let bits: Vec<f32> = (0..g.cout).map(|j| (2 + (j * 5) % 9) as f32).collect();
        let mut conv = IntConv2d::new_grouped("cvg", &w, g, &b, &bits, 4, true).unwrap();
        conv.set_act_range(-2.0, 2.0);
        assert_eq!(conv.core().granularity(), Granularity::PerOutputChannel);
        let fast = conv.forward(&x, 2);
        let slow = conv.forward_ref(&x, 2);
        assert!(fast.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn conv_forward_scratch_matches_forward_bitwise() {
        // The serving path: im2col buffer reused across calls (and
        // across layers of different size), pooled and inline dispatch.
        let pool = crate::util::pool::WorkerPool::new(3);
        let mut sc = LayerScratch::default();
        let mut rng = Rng::new(0xC2D2);
        for &(n, g) in &[
            (2usize, geom(3, 6, 6, 4, 3, 3, 1, 1)),
            (4, geom(2, 5, 5, 3, 3, 3, 2, 1)),
            (1, geom(1, 4, 4, 2, 1, 1, 1, 0)),
            (6, geom(8, 16, 16, 16, 3, 3, 1, 1)), // crosses PAR_MIN_MACS
        ] {
            let x = rand_vec(&mut rng, n * g.in_features());
            let w = rand_vec(&mut rng, g.patch_len() * g.cout);
            let b = rand_vec(&mut rng, g.cout);
            let mut conv = IntConv2d::new("cvs", &w, g, &b, 4, 4, true).unwrap();
            conv.set_act_range(-2.5, 2.5);
            let want = conv.forward(&x, n);
            let mut got = vec![0.0f32; n * g.out_features()];
            conv.forward_scratch(&x, n, &mut sc, &mut got, Some(&pool));
            assert!(
                got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()),
                "pooled conv scratch diverged at {g:?}"
            );
            let mut inline = vec![0.0f32; n * g.out_features()];
            conv.forward_scratch(&x, n, &mut sc, &mut inline, None);
            assert!(inline.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
        }
        // The scratch retained its im2col buffer for reuse.
        assert!(!sc.im2col.is_empty());
    }

    #[test]
    fn conv_1x1_stride1_matches_dense_bitwise() {
        // A 1x1/stride-1/pad-0 conv is a dense layer applied per pixel:
        // the im2col expansion is the identity, the patch rows are the
        // pixel vectors, and the dynamic activation range sees the same
        // value multiset — so the lowering must be *bitwise* the dense
        // forward over [n·h·w, cin] rows, at both granularities.
        let mut rng = Rng::new(0xC2D3);
        let g = geom(6, 4, 5, 7, 1, 1, 1, 0);
        let n = 3usize;
        let x = rand_vec(&mut rng, n * g.in_features());
        let w = rand_vec(&mut rng, g.cin * g.cout);
        let b = rand_vec(&mut rng, g.cout);
        let rows = n * g.h * g.w;

        let conv = IntConv2d::new("c1", &w, g, &b, 4, 5, true).unwrap();
        let dense = IntDense::new("d1", &w, g.cin, g.cout, &b, 4, 5, true).unwrap();
        let cv = conv.forward(&x, n);
        let dn = dense.forward(&x, rows);
        assert_eq!(cv.len(), dn.len());
        assert!(cv.iter().zip(&dn).all(|(a, b)| a.to_bits() == b.to_bits()));

        let bits: Vec<f32> = (0..g.cout).map(|j| (1 + (j * 3) % 8) as f32).collect();
        let conv_g =
            IntConv2d::new_grouped("c1g", &w, g, &b, &bits, 5, false).unwrap();
        let dense_g =
            IntDense::new_grouped("d1g", &w, g.cin, g.cout, &b, &bits, 5, false).unwrap();
        let cvg = conv_g.forward(&x, n);
        let dng = dense_g.forward(&x, rows);
        assert!(cvg.iter().zip(&dng).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn conv_geom_validation() {
        let ok = geom(3, 8, 8, 4, 3, 3, 1, 1);
        assert!(ok.validate("t").is_ok());
        assert_eq!(ok.out_h(), 8);
        assert_eq!(ok.patch_len(), 27);
        // Degenerate dims, zero stride, kernel larger than padded plane.
        assert!(geom(0, 8, 8, 4, 3, 3, 1, 1).validate("t").is_err());
        assert!(geom(3, 8, 8, 0, 3, 3, 1, 1).validate("t").is_err());
        assert!(geom(3, 8, 8, 4, 3, 3, 0, 1).validate("t").is_err());
        assert!(geom(3, 2, 2, 4, 5, 5, 1, 1).validate("t").is_err());
        // new() rejects a weight slice that disagrees with the geometry.
        let g = geom(2, 4, 4, 3, 3, 3, 1, 1);
        assert!(IntConv2d::new("t", &[0.0; 10], g, &[0.0; 3], 4, 4, true).is_err());
        // from_core() rejects a core whose GEMM shape mismatches.
        let w = vec![0.1f32; g.patch_len() * g.cout];
        let core_bad =
            IntDense::new("t", &vec![0.1f32; 5 * g.cout], 5, g.cout, &[0.0; 3], 4, 4, true)
                .unwrap();
        assert!(IntConv2d::from_core(g, core_bad).is_err());
        let core_ok =
            IntDense::new("t", &w, g.patch_len(), g.cout, &[0.0; 3], 4, 4, true).unwrap();
        assert!(IntConv2d::from_core(g, core_ok).is_ok());
    }

    #[test]
    fn conv_net_forward_into_matches_forward_bitwise() {
        // A conv->conv->dense IntNet through the serving entry point:
        // calibrate (padded convs must cover 0), then forward_into on a
        // reused scratch must match the allocating forward bitwise.
        let mut rng = Rng::new(0xC2D4);
        let g0 = geom(3, 8, 8, 4, 3, 3, 1, 1); // -> 8x8x4 = 256
        let g1 = geom(4, 8, 8, 6, 3, 3, 2, 1); // -> 4x4x6 = 96
        let c0 = IntConv2d::new(
            "c0",
            &rand_vec(&mut rng, g0.patch_len() * g0.cout),
            g0,
            &rand_vec(&mut rng, g0.cout),
            4,
            4,
            true,
        )
        .unwrap();
        let c1 = IntConv2d::new(
            "c1",
            &rand_vec(&mut rng, g1.patch_len() * g1.cout),
            g1,
            &rand_vec(&mut rng, g1.cout),
            4,
            4,
            true,
        )
        .unwrap();
        let fc = IntDense::new(
            "fc",
            &rand_vec(&mut rng, 96 * 5),
            96,
            5,
            &rand_vec(&mut rng, 5),
            4,
            4,
            false,
        )
        .unwrap();
        let mut net =
            IntNet { layers: vec![c0.into(), c1.into(), fc.into()], num_classes: 5 };
        assert_eq!(net.in_features(), 192);
        assert_eq!(net.out_features(), 5);
        let calib = rand_vec(&mut rng, 16 * 192);
        net.calibrate(&calib, 16).unwrap();
        assert!(net.is_calibrated());
        // Padded conv layers must have pulled 0 into their pinned range.
        for l in &net.layers {
            if l.conv_geom().is_some_and(|g| g.pad > 0) {
                let (lo, hi) = l.act_range().unwrap();
                assert!(lo <= 0.0 && hi >= 0.0);
            }
        }
        let x = rand_vec(&mut rng, 4 * 192);
        let want = net.forward(&x, 4);
        let mut sc = NetScratch::default();
        let got = net.forward_into(&x, 4, &mut sc, None).to_vec();
        assert!(want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()));
        // Second call on the same scratch (warm path) stays identical.
        let again = net.forward_into(&x, 4, &mut sc, None).to_vec();
        assert!(want.iter().zip(&again).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn profiled_forward_is_bit_identical_and_attributes_every_layer() {
        // Conv fixture (conv0 -> conv1 -> fc) through the profiled
        // entry point: logits must match the unprofiled path bitwise,
        // and the profile must carry time + MAC + byte attribution for
        // every layer with the regularizer's MAC pricing.
        let net = crate::serve::synthetic_conv_net(0xBEEF, 4, 4);
        let n = 6;
        let mut rng = Rng::new(0xF00D);
        let x = rand_vec(&mut rng, n * net.in_features());
        let mut sc = NetScratch::default();
        let want = net.forward_into(&x, n, &mut sc, None).to_vec();
        let mut prof = ForwardProfile::new();
        let got = net
            .forward_into_profiled(&x, n, &mut sc, None, &mut prof)
            .to_vec();
        assert!(want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert_eq!(prof.batch, n);
        assert_eq!(prof.layers.len(), net.layers.len());
        assert!(prof.total_s > 0.0);
        for (lp, layer) in prof.layers.iter().zip(&net.layers) {
            assert_eq!(lp.name, layer.name());
            assert!(lp.total_s > 0.0, "{}: zero wall time", lp.name);
            assert!(lp.gemm_s > 0.0, "{}: zero gemm time", lp.name);
            assert!(
                lp.total_s + 1e-9 >= lp.im2col_s + lp.gemm_s,
                "{}: split exceeds total",
                lp.name
            );
            assert_eq!(lp.macs, layer.macs(n), "{}: MAC pricing", lp.name);
            assert_eq!(lp.bytes, layer.bytes_touched(n), "{}", lp.name);
            match layer {
                IntLayer::Dense(d) => {
                    assert_eq!(lp.im2col_s, 0.0);
                    assert_eq!(lp.macs, (n * d.din * d.dout) as u64);
                }
                IntLayer::Conv2d(c) => {
                    assert!(lp.im2col_s > 0.0, "{}: zero im2col time", lp.name);
                    let g = &c.geom;
                    assert_eq!(
                        lp.macs,
                        (n * quant::conv_macs(
                            g.cin,
                            g.kh,
                            g.kw,
                            g.out_h(),
                            g.out_w(),
                            g.cout
                        )) as u64
                    );
                }
            }
        }
        // Profile buffer is reused across calls without growing.
        let layers_cap = prof.layers.capacity();
        net.forward_into_profiled(&x, n, &mut sc, None, &mut prof);
        assert_eq!(prof.layers.len(), net.layers.len());
        assert_eq!(prof.layers.capacity(), layers_cap);
        // report() renders one line per layer plus a header.
        let rep = prof.report();
        assert_eq!(rep.lines().count(), 1 + net.layers.len());
        for layer in &net.layers {
            assert!(rep.contains(layer.name()), "{rep}");
        }
    }

    #[test]
    fn shift_gemm_matches_multiply_ref_bitwise() {
        // The tentpole parity pin: a non-uniform-codebook layer runs
        // the shift-add kernel on the fast path while forward_ref stays
        // the multiply baseline — an actual cross-kernel check.  Odd
        // shapes, both codebooks, edge bitlengths, calibrated and
        // dynamic ranges.
        let mut rng = Rng::new(0x5817);
        for &cbk in &[Codebook::PowerOfTwo, Codebook::AdditivePot2] {
            for &(n, din, dout, wb, ab, calibrated) in &[
                (1usize, 1usize, 1usize, 4u32, 4u32, false),
                (3, 5, 7, 2, 3, true),
                (8, 17, 13, 8, 6, false),
                (5, 33, 9, 16, 16, true),
                (6, 24, 10, 1, 2, false), // 1-bit: max_pos clamp binds
            ] {
                let x = rand_vec(&mut rng, n * din);
                let w = rand_vec(&mut rng, din * dout);
                let b = rand_vec(&mut rng, dout);
                let mut layer =
                    IntDense::new_cbk("sh", &w, din, dout, &b, wb, ab, true, cbk).unwrap();
                if calibrated {
                    layer.set_act_range(-2.0, 2.0);
                }
                assert!(layer.uses_shift_gemm());
                assert_eq!(layer.codebook(), cbk);
                let fast = layer.forward(&x, n);
                let slow = layer.forward_ref(&x, n);
                for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
                    assert_eq!(
                        f.to_bits(),
                        s.to_bits(),
                        "{cbk:?} ({n},{din},{dout}) bits ({wb},{ab}) elem {i}: {f} vs {s}"
                    );
                }
            }
        }
    }

    #[test]
    fn shift_uniform_codebook_is_identical_to_default() {
        // Uniform through new_cbk must be the exact layer new() builds:
        // no shift plan, same packed bytes, bitwise-identical forward.
        let mut rng = Rng::new(0x5818);
        let (n, din, dout) = (4usize, 19usize, 11usize);
        let x = rand_vec(&mut rng, n * din);
        let w = rand_vec(&mut rng, din * dout);
        let b = rand_vec(&mut rng, dout);
        let plain = IntDense::new("u", &w, din, dout, &b, 5, 4, true).unwrap();
        let cbk =
            IntDense::new_cbk("u", &w, din, dout, &b, 5, 4, true, Codebook::Uniform).unwrap();
        assert!(!cbk.uses_shift_gemm());
        assert_eq!(cbk.codebook(), Codebook::Uniform);
        assert_eq!(
            plain.packed_per_layer().unwrap().data,
            cbk.packed_per_layer().unwrap().data
        );
        let a = plain.forward(&x, n);
        let c = cbk.forward(&x, n);
        assert!(a.iter().zip(&c).all(|(p, q)| p.to_bits() == q.to_bits()));
    }

    #[test]
    fn shift_grouped_matches_ref_bitwise() {
        // Per-channel bitlengths under one shared codebook: the shift
        // plan reads each span's bits for its half offset.
        let mut rng = Rng::new(0x5819);
        for &cbk in &[Codebook::PowerOfTwo, Codebook::AdditivePot2] {
            for &(n, din, dout) in &[(1usize, 1usize, 1usize), (3, 5, 7), (8, 17, 13)] {
                let x = rand_vec(&mut rng, n * din);
                let w = rand_vec(&mut rng, din * dout);
                let b = rand_vec(&mut rng, dout);
                let bits: Vec<f32> =
                    (0..dout).map(|j| (1 + (j * 5) % 16) as f32).collect();
                let mut layer =
                    IntDense::new_grouped_cbk("shg", &w, din, dout, &b, &bits, 4, true, cbk)
                        .unwrap();
                layer.set_act_range(-2.0, 2.0);
                assert!(layer.uses_shift_gemm());
                assert_eq!(layer.granularity(), Granularity::PerOutputChannel);
                let fast = layer.forward(&x, n);
                let slow = layer.forward_ref(&x, n);
                for (i, (f, s)) in fast.iter().zip(&slow).enumerate() {
                    assert_eq!(
                        f.to_bits(),
                        s.to_bits(),
                        "{cbk:?} ({n},{din},{dout}) elem {i}: {f} vs {s}"
                    );
                }
            }
        }
    }

    #[test]
    fn shift_threaded_and_scratch_match_bitwise() {
        // Above PAR_MIN_MACS the shift kernel must survive both
        // parallel dispatchers (scoped threads and the worker pool)
        // with the row-sum blocks lining up against the code blocks.
        let pool = crate::util::pool::WorkerPool::new(3);
        let mut sc = LayerScratch::default();
        let mut rng = Rng::new(0x581A);
        let (n, din, dout) = (67usize, 128usize, 128usize);
        assert!(n * din * dout >= super::PAR_MIN_MACS);
        let x = rand_vec(&mut rng, n * din);
        let w = rand_vec(&mut rng, din * dout);
        let b = rand_vec(&mut rng, dout);
        let layer = IntDense::new_cbk(
            "sht", &w, din, dout, &b, 4, 4, true, Codebook::AdditivePot2,
        )
        .unwrap();
        let want = layer.forward_ref(&x, n);
        let fast = layer.forward(&x, n);
        assert!(fast.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
        let mut got = vec![0.0f32; n * dout];
        layer.forward_scratch(&x, n, &mut sc, &mut got, Some(&pool));
        assert!(got.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
        let mut inline = vec![0.0f32; n * dout];
        layer.forward_scratch(&x, n, &mut sc, &mut inline, None);
        assert!(inline.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn conv_cbk_shift_matches_ref_bitwise() {
        // The im2col lowering feeds the shift core unchanged: conv at
        // both granularities under a non-uniform codebook, fast vs the
        // element-at-a-time gather + multiply reference.
        let mut rng = Rng::new(0x581B);
        let g = geom(3, 6, 6, 5, 3, 3, 1, 1);
        let x = rand_vec(&mut rng, 2 * g.in_features());
        let w = rand_vec(&mut rng, g.patch_len() * g.cout);
        let b = rand_vec(&mut rng, g.cout);
        let conv =
            IntConv2d::new_cbk("cs", &w, g, &b, 4, 5, true, Codebook::PowerOfTwo).unwrap();
        assert!(conv.core().uses_shift_gemm());
        let fast = conv.forward(&x, 2);
        let slow = conv.forward_ref(&x, 2);
        assert!(fast.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()));

        let bits: Vec<f32> = (0..g.cout).map(|j| (2 + (j * 5) % 9) as f32).collect();
        let mut cg = IntConv2d::new_grouped_cbk(
            "csg", &w, g, &b, &bits, 4, true, Codebook::AdditivePot2,
        )
        .unwrap();
        cg.set_act_range(-2.0, 2.0);
        let l = IntLayer::from(cg);
        assert_eq!(l.codebook(), Codebook::AdditivePot2);
        let fast = l.forward(&x, 2);
        let slow = l.forward_ref(&x, 2);
        assert!(fast.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn shift_rebuild_from_packed_is_bit_identical() {
        // Deploy path: rebuilding a codebook layer from its stored
        // packed codes must restore the shift plan and forward
        // bit-identically — per-layer and grouped.
        let mut rng = Rng::new(0x581C);
        let (n, din, dout) = (5usize, 11usize, 9usize);
        let x = rand_vec(&mut rng, n * din);
        let w = rand_vec(&mut rng, din * dout);
        let b = rand_vec(&mut rng, dout);
        let mut src =
            IntDense::new_cbk("rz", &w, din, dout, &b, 3, 5, true, Codebook::PowerOfTwo)
                .unwrap();
        src.set_act_range(-2.0, 2.0);
        let rebuilt = IntDense::from_packed(
            "rz",
            src.packed_per_layer().unwrap().clone(),
            din,
            dout,
            src.bias.clone(),
            src.a_bits,
            src.relu,
            src.act_range(),
        )
        .unwrap();
        assert!(rebuilt.uses_shift_gemm());
        let want = src.forward(&x, n);
        let got = rebuilt.forward(&x, n);
        assert!(want.iter().zip(&got).all(|(p, q)| p.to_bits() == q.to_bits()));

        let bits = [1.0f32, 4.0, 7.5, 16.0, 2.0, 3.0, 5.0, 6.0, 2.0];
        let gsrc = IntDense::new_grouped_cbk(
            "rzg", &w, din, dout, &b, &bits, 4, false, Codebook::AdditivePot2,
        )
        .unwrap();
        let grebuilt = IntDense::from_packed_groups(
            "rzg",
            gsrc.packed_groups().unwrap().clone(),
            din,
            dout,
            gsrc.bias.clone(),
            gsrc.a_bits,
            gsrc.relu,
            None,
        )
        .unwrap();
        assert!(grebuilt.uses_shift_gemm());
        let want = gsrc.forward(&x, n);
        let got = grebuilt.forward(&x, n);
        assert!(want.iter().zip(&got).all(|(p, q)| p.to_bits() == q.to_bits()));
    }

    #[test]
    fn stored_lanes_follow_acc_width_rule() {
        // The constructor must store the lane the acc-width rule earns:
        // 4b x 4b @ din=128 sums to exactly 15 bits (i16), one more
        // input column promotes to i32, and 16-bit operands pin i64.
        let mut rng = Rng::new(0x1A5E);
        for &(din, wb, ab, want) in &[
            (128usize, 4u32, 4u32, AccWidth::I16),
            (129, 4, 4, AccWidth::I32),
            (33, 16, 16, AccWidth::I64),
        ] {
            let w = rand_vec(&mut rng, din * 4);
            let b = rand_vec(&mut rng, 4);
            let l = IntDense::new("lane", &w, din, 4, &b, wb, ab, false).unwrap();
            assert_eq!(l.acc_lane(), want, "din={din} wb={wb} ab={ab}");
        }
        // Grouped layers store one lane per output channel; the layer
        // lane is the widest.
        let din = 16usize;
        let dout = 6usize;
        let w = rand_vec(&mut rng, din * dout);
        let b = rand_vec(&mut rng, dout);
        let bits = [2.0f32, 4.0, 6.0, 8.0, 12.0, 16.0];
        let g = IntDense::new_grouped("laneg", &w, din, dout, &b, &bits, 4, false).unwrap();
        assert_eq!(g.group_lanes.len(), dout);
        for (j, &l) in g.group_lanes.iter().enumerate() {
            assert_eq!(l, quant::acc_width(quant::int_bits(bits[j]), 4, din));
        }
        assert_eq!(g.acc_lane(), *g.group_lanes.iter().max().unwrap());
    }

    #[test]
    fn narrow_lane_parity_at_max_magnitude_boundary() {
        // Overflow-adversarial: drive every weight and activation code
        // to its maximum at the exact din where the i16 lane saturates
        // (4b x 4b @ din=128: acc = 128*15*15 = 28800 < 2^15), then one
        // past it on the i32 lane.  One weight/activation is pinned to
        // the range minimum so codes hit the full [0, 2^b-1] span.  The
        // narrow kernels must stay bit-identical to forward_ref.
        for &din in &[128usize, 129] {
            let dout = 5usize;
            let n = 6usize;
            let mut w = vec![1.0f32; din * dout];
            for j in 0..dout {
                w[j] = -1.0; // row 0: every channel sees the min weight
            }
            let b = vec![0.25f32; dout];
            let mut x = vec![1.0f32; n * din];
            for r in 0..n {
                x[r * din] = -1.0;
            }
            let mut l = IntDense::new("adv", &w, din, dout, &b, 4, 4, false).unwrap();
            l.set_act_range(-1.0, 1.0);
            let want_lane = if din == 128 { AccWidth::I16 } else { AccWidth::I32 };
            assert_eq!(l.acc_lane(), want_lane);
            let fast = l.forward(&x, n);
            let slow = l.forward_ref(&x, n);
            assert!(
                fast.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()),
                "din={din}"
            );
        }
    }

    #[test]
    fn mixed_lane_grouped_parity_bitwise() {
        // Grouped layers mix narrow and wide channels inside one GEMM
        // call; the per-block lane selection must leave every channel
        // bit-identical to the scalar reference.  dout=11 also leaves a
        // 3-column scalar remainder after two 4-column blocks.
        let mut rng = Rng::new(0x9D02);
        let (n, din, dout) = (7usize, 40usize, 11usize);
        let x = rand_vec(&mut rng, n * din);
        let w = rand_vec(&mut rng, din * dout);
        let b = rand_vec(&mut rng, dout);
        let bits = [1.0f32, 16.0, 4.0, 2.0, 16.0, 3.0, 5.0, 16.0, 4.0, 2.0, 16.0];
        let mut l =
            IntDense::new_grouped("mix", &w, din, dout, &b, &bits, 6, true).unwrap();
        l.set_act_range(-2.0, 2.0);
        assert!(l.group_lanes.iter().any(|&la| la <= AccWidth::I32));
        assert!(l.group_lanes.iter().any(|&la| la == AccWidth::I64));
        let fast = l.forward(&x, n);
        let slow = l.forward_ref(&x, n);
        assert!(fast.iter().zip(&slow).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn row_blocked_shift_kernels_match_per_row() {
        // The 4-row-blocked shift kernels must reproduce the per-row
        // kernels bit-for-bit, including the scalar remainder rows
        // (batch sizes straddling the block width).
        let mut rng = Rng::new(0xB10C);
        for &n in &[1usize, 3, 4, 5, 8, 11] {
            let (din, dout) = (24usize, 9usize);
            let x = rand_vec(&mut rng, n * din);
            let w = rand_vec(&mut rng, din * dout);
            let b = rand_vec(&mut rng, dout);
            let mut l =
                IntDense::new_cbk("rb", &w, din, dout, &b, 4, 5, true, Codebook::PowerOfTwo)
                    .unwrap();
            l.set_act_range(-2.0, 2.0);
            assert!(l.uses_shift_gemm());
            // Drive both kernel bodies directly so the test does not
            // depend on which one the runtime dispatch picks.
            let (a_codes, rs, a_scale, a_min) = l.quantize_acts(&x, n);
            let (s, t, u) = l.affine_terms(a_scale, a_min, &rs);
            let plan = l.shift.as_ref().unwrap();
            let mut per_row = vec![0.0f32; n * dout];
            let mut blocked = vec![0.0f32; n * dout];
            l.gemm_block_shift(plan, &a_codes, &rs, &t, &u, s, &mut per_row);
            l.gemm_block_shift_rows(plan, &a_codes, &rs, &t, &u, s, &mut blocked);
            assert!(
                per_row.iter().zip(&blocked).all(|(p, q)| p.to_bits() == q.to_bits()),
                "n={n}"
            );

            let bits = vec![4.0f32; dout];
            let mut g = IntDense::new_grouped_cbk(
                "rbg", &w, din, dout, &b, &bits, 5, false, Codebook::AdditivePot2,
            )
            .unwrap();
            g.set_act_range(-2.0, 2.0);
            let (a_codes, rs, a_scale, a_min) = g.quantize_acts(&x, n);
            let mut rsf = Vec::new();
            let mut cols = GroupedCols::default();
            g.grouped_terms_into(a_scale, a_min, &rs, &mut rsf, &mut cols);
            let plan = g.shift.as_ref().unwrap();
            let mut per_row = vec![0.0f32; n * dout];
            let mut blocked = vec![0.0f32; n * dout];
            g.gemm_block_shift_grouped(plan, &a_codes, &rs, &rsf, &cols, &mut per_row);
            g.gemm_block_shift_grouped_rows(plan, &a_codes, &rs, &rsf, &cols, &mut blocked);
            assert!(
                per_row.iter().zip(&blocked).all(|(p, q)| p.to_bits() == q.to_bits()),
                "grouped n={n}"
            );
        }
    }
}
