//! Pure-integer inference engine: proof that the learned bitlengths
//! deploy on real fixed-point hardware.
//!
//! The training stack fake-quantizes in f32 (Q_r returns floats on the
//! quantization grid).  Deployment hardware stores `n`-bit integer
//! codes and accumulates in wide integers.  This module executes a
//! trained dense network that way:
//!
//! ```text
//! a = a_min + a_code·a_s          (activation codes from batch min/max)
//! w = w_min + w_code·w_s          (weight codes packed at n_w bits)
//! Σ a·w = a_s·w_s·Σ a_code·w_code            <- i64 integer core
//!       + a_s·w_min·Σ a_code                 <- i64 row sum
//!       + w_s·a_min·Σ w_code                 <- precomputed column sum
//!       + K·a_min·w_min
//! ```
//!
//! The integration test checks that logits and accuracy match the
//! compiled XLA eval artifact at the same (integer) bitlengths — i.e.
//! the affine-decomposed integer path and the float fake-quant path are
//! the same computation.
//!
//! Scope: dense (MLP-style) networks — the artifact family whose
//! deployment story is pure GEMM.  Conv models deploy the same way via
//! im2col; see DESIGN.md §future-work.

use anyhow::{bail, Result};

use crate::bitpack::{pack, unpack_codes, PackedTensor};
use crate::model::ModelMeta;
use crate::quant;
use crate::tensor::HostTensor;

/// One integer-quantized dense layer.
pub struct IntDense {
    pub name: String,
    pub din: usize,
    pub dout: usize,
    /// Packed weight codes, row-major [din, dout].
    pub packed: PackedTensor,
    /// Unpacked codes cache (u16 is enough for <=16 bits).
    codes: Vec<u16>,
    pub w_min: f32,
    pub w_scale: f32,
    /// Σ over din of w_code for each output column (i64 per dout).
    col_code_sum: Vec<i64>,
    pub bias: Vec<f32>,
    /// Activation bitlength for this layer's input.
    pub a_bits: u32,
    pub relu: bool,
}

impl IntDense {
    pub fn new(
        name: &str,
        w: &[f32],
        din: usize,
        dout: usize,
        bias: &[f32],
        w_bits: u32,
        a_bits: u32,
        relu: bool,
    ) -> Result<Self> {
        if w.len() != din * dout {
            bail!("{name}: weight len {} != {din}x{dout}", w.len());
        }
        if bias.len() != dout {
            bail!("{name}: bias len {} != {dout}", bias.len());
        }
        let packed = pack(w, w_bits)?;
        let codes: Vec<u16> = unpack_codes(&packed).iter().map(|&c| c as u16).collect();
        let mut col_code_sum = vec![0i64; dout];
        for i in 0..din {
            for j in 0..dout {
                col_code_sum[j] += codes[i * dout + j] as i64;
            }
        }
        Ok(Self {
            name: name.to_string(),
            din,
            dout,
            w_min: packed.lmin,
            w_scale: packed.scale,
            packed,
            codes,
            col_code_sum,
            bias: bias.to_vec(),
            a_bits,
            relu,
        })
    }

    /// Forward one batch [n, din] -> [n, dout].
    ///
    /// Activations are quantized to `a_bits` codes using the batch
    /// min/max (the training-time convention, paper §II-A), then the
    /// GEMM runs entirely in i64 over the codes.
    pub fn forward(&self, x: &[f32], n: usize) -> Vec<f32> {
        assert_eq!(x.len(), n * self.din, "{}: bad input", self.name);
        let (a_min, a_max) = quant::group_minmax(x);
        let a_scale = quant::scale(a_min, a_max, self.a_bits as f32);
        let levels = ((1u32 << self.a_bits) - 1) as i64;

        // Quantize activations to integer codes.
        let mut a_codes = vec![0u16; n * self.din];
        let mut row_code_sum = vec![0i64; n];
        for r in 0..n {
            let mut sum = 0i64;
            for c in 0..self.din {
                let v = x[r * self.din + c];
                let code = (((v - a_min) / a_scale).round_ties_even() as i64)
                    .clamp(0, levels);
                a_codes[r * self.din + c] = code as u16;
                sum += code;
            }
            row_code_sum[r] = sum;
        }

        // Integer GEMM over codes.
        let mut out = vec![0.0f32; n * self.dout];
        let k = self.din as f64;
        for r in 0..n {
            let a_row = &a_codes[r * self.din..(r + 1) * self.din];
            for j in 0..self.dout {
                let mut acc = 0i64;
                for c in 0..self.din {
                    acc += a_row[c] as i64 * self.codes[c * self.dout + j] as i64;
                }
                // Affine reconstruction (f64 for the scalar terms).
                let v = (self.w_scale as f64) * (a_scale as f64) * acc as f64
                    + (a_scale as f64) * (self.w_min as f64) * row_code_sum[r] as f64
                    + (self.w_scale as f64) * (a_min as f64) * self.col_code_sum[j] as f64
                    + k * (a_min as f64) * (self.w_min as f64)
                    + self.bias[j] as f64;
                let v = v as f32;
                out[r * self.dout + j] = if self.relu { v.max(0.0) } else { v };
            }
        }
        out
    }

    /// Storage of this layer in packed form (bytes).
    pub fn packed_bytes(&self) -> usize {
        self.packed.payload_bytes() + 16 + self.bias.len() * 4
    }
}

/// An integer-quantized dense network.
pub struct IntNet {
    pub layers: Vec<IntDense>,
    pub num_classes: usize,
}

impl IntNet {
    /// Build from a trained network's flat parameters + integer
    /// bitlengths, using the artifact metadata for the layout.
    ///
    /// `params` are in the artifact's flattened order (`meta.param_names`
    /// e.g. `["0/b", "0/w", "1/b", ...]`); only dense-kind models are
    /// supported.
    pub fn from_trained(
        meta: &ModelMeta,
        params: &[HostTensor],
        bits_w: &[f32],
        bits_a: &[f32],
    ) -> Result<Self> {
        if meta.layers.iter().any(|l| l.kind != "dense") {
            bail!(
                "IntNet supports dense-only models; '{}' has non-dense layers",
                meta.model
            );
        }
        if params.len() != meta.num_params {
            bail!("params len {} != meta {}", params.len(), meta.num_params);
        }
        let find = |name: &str| -> Result<&HostTensor> {
            meta.param_names
                .iter()
                .position(|n| n == name)
                .map(|i| &params[i])
                .ok_or_else(|| anyhow::anyhow!("param '{name}' not found"))
        };
        let mut layers = Vec::new();
        let last = meta.layers.len() - 1;
        for (i, geom) in meta.layers.iter().enumerate() {
            let w = find(&format!("{i}/w"))?;
            let b = find(&format!("{i}/b"))?;
            let (din, dout) = (geom.cin, geom.cout);
            layers.push(IntDense::new(
                &geom.name,
                w.as_f32()?,
                din,
                dout,
                b.as_f32()?,
                quant::clip_bits(bits_w[i]).ceil() as u32,
                quant::clip_bits(bits_a[i]).ceil() as u32,
                i != last,
            )?);
        }
        Ok(Self { layers, num_classes: meta.num_classes })
    }

    /// Forward a batch, returning logits [n, num_classes].
    pub fn forward(&self, x: &[f32], n: usize) -> Vec<f32> {
        let mut h = x.to_vec();
        for layer in &self.layers {
            h = layer.forward(&h, n);
        }
        h
    }

    /// Classify a batch.
    pub fn predict(&self, x: &[f32], n: usize) -> Vec<usize> {
        let logits = self.forward(x, n);
        (0..n)
            .map(|r| {
                let row = &logits[r * self.num_classes..(r + 1) * self.num_classes];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }

    /// Total packed model size in bytes.
    pub fn packed_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.packed_bytes()).sum()
    }

    /// f32 model size in bytes.
    pub fn f32_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| (l.din * l.dout + l.dout) * 4)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn rand_vec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()
    }

    /// Float reference: fake-quantize activations + weights, plain GEMM.
    fn float_ref(
        x: &[f32], n: usize, w: &[f32], din: usize, dout: usize,
        bias: &[f32], w_bits: f32, a_bits: f32, relu: bool,
    ) -> Vec<f32> {
        let mut xq = x.to_vec();
        quant::fake_quant_slice(&mut xq, a_bits);
        let mut wq = w.to_vec();
        quant::fake_quant_slice(&mut wq, w_bits);
        let mut out = vec![0.0f32; n * dout];
        for r in 0..n {
            for j in 0..dout {
                let mut acc = 0.0f64;
                for c in 0..din {
                    acc += xq[r * din + c] as f64 * wq[c * dout + j] as f64;
                }
                let v = (acc + bias[j] as f64) as f32;
                out[r * dout + j] = if relu { v.max(0.0) } else { v };
            }
        }
        out
    }

    #[test]
    fn integer_layer_matches_float_fake_quant() {
        let mut rng = Rng::new(4);
        for &(wb, ab) in &[(2u32, 3u32), (4, 4), (8, 8), (1, 1)] {
            let (n, din, dout) = (5, 12, 7);
            let x = rand_vec(&mut rng, n * din);
            let w = rand_vec(&mut rng, din * dout);
            let b = rand_vec(&mut rng, dout);
            let layer =
                IntDense::new("t", &w, din, dout, &b, wb, ab, true).unwrap();
            let got = layer.forward(&x, n);
            let want =
                float_ref(&x, n, &w, din, dout, &b, wb as f32, ab as f32, true);
            for (i, (g, w_)) in got.iter().zip(&want).enumerate() {
                assert!(
                    (g - w_).abs() < 1e-3 * (1.0 + w_.abs()),
                    "bits ({wb},{ab}) elem {i}: int {g} vs float {w_}"
                );
            }
        }
    }

    #[test]
    fn packed_size_shrinks_with_bits() {
        let mut rng = Rng::new(5);
        let w = rand_vec(&mut rng, 64 * 32);
        let b = vec![0.0; 32];
        let l8 = IntDense::new("a", &w, 64, 32, &b, 8, 8, true).unwrap();
        let l2 = IntDense::new("b", &w, 64, 32, &b, 2, 8, true).unwrap();
        assert!(l2.packed_bytes() < l8.packed_bytes());
        // 2-bit weights ≈ 1/16 of f32
        assert!(l2.packed.ratio_vs_f32() > 15.0);
    }

    #[test]
    fn shape_validation() {
        let w = vec![0.0f32; 10];
        assert!(IntDense::new("x", &w, 3, 4, &[0.0; 4], 4, 4, true).is_err());
        assert!(IntDense::new("x", &w, 5, 2, &[0.0; 3], 4, 4, true).is_err());
    }

    #[test]
    fn net_predict_shapes() {
        let mut rng = Rng::new(6);
        let l0 = IntDense::new(
            "fc0", &rand_vec(&mut rng, 8 * 16), 8, 16, &vec![0.0; 16], 4, 4, true,
        )
        .unwrap();
        let l1 = IntDense::new(
            "fc1", &rand_vec(&mut rng, 16 * 3), 16, 3, &vec![0.0; 3], 4, 4, false,
        )
        .unwrap();
        let net = IntNet { layers: vec![l0, l1], num_classes: 3 };
        let x = rand_vec(&mut rng, 4 * 8);
        let preds = net.predict(&x, 4);
        assert_eq!(preds.len(), 4);
        assert!(preds.iter().all(|&p| p < 3));
        assert!(net.packed_bytes() < net.f32_bytes());
    }
}
