//! Training schedules: the one-cycle LR policy and the BitPruning phase
//! state machine.
//!
//! The paper trains with fast.ai's one-cycle policy, learns bitlengths
//! jointly with weights, then (a) ceils bitlengths to integers and
//! (b) fine-tunes with bitlengths frozen at 1/10th the learning rate
//! (§II-C, §III-B2).  The coordinator drives each run through the
//! [`PhasePlan`] produced here; `bits_mask` gates the bitlength update
//! inside the exported train step.

use anyhow::{bail, Result};

/// One-cycle learning-rate policy (warmup + cosine annealing).
#[derive(Debug, Clone)]
pub struct OneCycle {
    pub lr_max: f64,
    pub total_steps: usize,
    /// Fraction of steps spent warming up.
    pub pct_start: f64,
    /// lr starts at lr_max / div_start.
    pub div_start: f64,
    /// lr ends at lr_max / div_end.
    pub div_end: f64,
}

impl OneCycle {
    pub fn new(lr_max: f64, total_steps: usize) -> Self {
        // fast.ai defaults: pct_start 0.25, div 25, final_div 1e4.
        Self { lr_max, total_steps, pct_start: 0.25, div_start: 25.0, div_end: 1e4 }
    }

    /// LR at a step in [0, total_steps).
    pub fn lr(&self, step: usize) -> f64 {
        if self.total_steps <= 1 {
            return self.lr_max;
        }
        let warm = ((self.total_steps as f64) * self.pct_start).max(1.0);
        let s = step.min(self.total_steps - 1) as f64;
        let cos_interp = |from: f64, to: f64, t: f64| {
            to + (from - to) * (1.0 + (std::f64::consts::PI * t).cos()) / 2.0
        };
        if s < warm {
            // cosine ramp up from lr_max/div_start
            cos_interp(self.lr_max / self.div_start, self.lr_max, s / warm)
        } else {
            let t = (s - warm) / ((self.total_steps as f64 - warm).max(1.0));
            cos_interp(self.lr_max, self.lr_max / self.div_end, t)
        }
    }
}

/// Constant-LR schedule (fine-tune phases use lr_max/10 flat, per paper).
#[derive(Debug, Clone)]
pub enum LrSchedule {
    OneCycle(OneCycle),
    Constant(f64),
}

impl LrSchedule {
    pub fn lr(&self, step: usize) -> f64 {
        match self {
            LrSchedule::OneCycle(c) => c.lr(step),
            LrSchedule::Constant(v) => *v,
        }
    }
}

// ---------------------------------------------------------------------------
// phase machine
// ---------------------------------------------------------------------------

/// What happens to bitlengths within a phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BitsMode {
    /// Bitlengths receive gradients (bits_mask = 1).
    Learn,
    /// Bitlengths frozen (bits_mask = 0).
    Frozen,
}

/// One phase of a run.
#[derive(Debug, Clone)]
pub struct Phase {
    pub name: &'static str,
    pub steps: usize,
    pub bits: BitsMode,
    pub lr: LrSchedule,
    /// Ceil bitlengths to integers when *entering* this phase (§II-C).
    pub select_integer_on_entry: bool,
}

/// A full training plan: ordered phases.
#[derive(Debug, Clone)]
pub struct PhasePlan {
    pub phases: Vec<Phase>,
}

impl PhasePlan {
    /// The paper's standard recipe: learn bits with one-cycle LR, then
    /// ceil + fine-tune at lr_max/10 with bits frozen.
    pub fn standard(lr_max: f64, learn_steps: usize, finetune_steps: usize) -> Self {
        PhasePlan {
            phases: vec![
                Phase {
                    name: "learn",
                    steps: learn_steps,
                    bits: BitsMode::Learn,
                    lr: LrSchedule::OneCycle(OneCycle::new(lr_max, learn_steps)),
                    select_integer_on_entry: false,
                },
                Phase {
                    name: "finetune",
                    steps: finetune_steps,
                    bits: BitsMode::Frozen,
                    lr: LrSchedule::Constant(lr_max / 10.0),
                    select_integer_on_entry: true,
                },
            ],
        }
    }

    /// Early-selection ablation (§III-B4): learn bits only for a short
    /// prefix, then fix integer bits and train the rest of the budget.
    pub fn early_select(lr_max: f64, learn_steps: usize, rest_steps: usize) -> Self {
        let total = learn_steps + rest_steps;
        PhasePlan {
            phases: vec![
                Phase {
                    name: "learn",
                    steps: learn_steps,
                    bits: BitsMode::Learn,
                    lr: LrSchedule::OneCycle(OneCycle::new(lr_max, total)),
                    select_integer_on_entry: false,
                },
                Phase {
                    name: "fixed-bits",
                    steps: rest_steps,
                    bits: BitsMode::Frozen,
                    lr: LrSchedule::Constant(lr_max / 10.0),
                    select_integer_on_entry: true,
                },
            ],
        }
    }

    /// Fixed-uniform-bitlength QAT (PACT-role baseline, Table VII): bits
    /// never learn, no selection needed.
    pub fn fixed_bits(lr_max: f64, steps: usize) -> Self {
        PhasePlan {
            phases: vec![Phase {
                name: "qat",
                steps,
                bits: BitsMode::Frozen,
                lr: LrSchedule::OneCycle(OneCycle::new(lr_max, steps)),
                select_integer_on_entry: false,
            }],
        }
    }

    /// Fine-tuning a pretrained network with BitPruning (§III-B5):
    /// bits learn from the warm start, then standard select + finetune.
    pub fn warmstart(lr_max: f64, learn_steps: usize, finetune_steps: usize) -> Self {
        // Same structure as standard; the coordinator supplies pretrained
        // params instead of fresh init.
        Self::standard(lr_max, learn_steps, finetune_steps)
    }

    pub fn total_steps(&self) -> usize {
        self.phases.iter().map(|p| p.steps).sum()
    }

    pub fn validate(&self) -> Result<()> {
        if self.phases.is_empty() {
            bail!("phase plan has no phases");
        }
        if self.phases.iter().all(|p| p.steps == 0) {
            bail!("phase plan has zero total steps");
        }
        Ok(())
    }
}

/// Tracks progress through a plan. The coordinator asks it, per global
/// step, for the phase index, within-phase LR, bits mask, and whether an
/// integer-selection boundary was crossed.
#[derive(Debug)]
pub struct PhaseCursor<'a> {
    plan: &'a PhasePlan,
    phase_idx: usize,
    step_in_phase: usize,
    entered_current: bool,
}

/// Per-step directive for the training loop.
#[derive(Debug, Clone, PartialEq)]
pub struct StepDirective {
    pub phase_idx: usize,
    pub phase_name: &'static str,
    pub lr: f64,
    pub bits_mask: f32,
    /// True exactly once, on the first step after a phase boundary that
    /// requires integer selection.
    pub select_integer_bits: bool,
}

impl<'a> PhaseCursor<'a> {
    pub fn new(plan: &'a PhasePlan) -> Self {
        Self { plan, phase_idx: 0, step_in_phase: 0, entered_current: false }
    }

    /// Directive for the next step, or None when the plan is exhausted.
    pub fn next(&mut self) -> Option<StepDirective> {
        // Skip empty phases (but still honor their selection marker).
        let mut pending_select = false;
        loop {
            let phase = self.plan.phases.get(self.phase_idx)?;
            if !self.entered_current {
                pending_select |= phase.select_integer_on_entry;
                self.entered_current = true;
            }
            if self.step_in_phase >= phase.steps {
                self.phase_idx += 1;
                self.step_in_phase = 0;
                self.entered_current = false;
                continue;
            }
            let d = StepDirective {
                phase_idx: self.phase_idx,
                phase_name: phase.name,
                lr: phase.lr.lr(self.step_in_phase),
                bits_mask: match phase.bits {
                    BitsMode::Learn => 1.0,
                    BitsMode::Frozen => 0.0,
                },
                select_integer_bits: pending_select,
            };
            self.step_in_phase += 1;
            return Some(d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    #[test]
    fn one_cycle_shape() {
        let c = OneCycle::new(0.1, 100);
        // starts low, peaks at warmup end, decays to ~0
        assert!(c.lr(0) < 0.02);
        let peak_step = 25;
        assert!((c.lr(peak_step) - 0.1).abs() < 1e-3);
        assert!(c.lr(99) < 1e-4);
        // never exceeds lr_max
        for s in 0..100 {
            assert!(c.lr(s) <= 0.1 + 1e-9, "step {s}");
        }
    }

    #[test]
    fn one_cycle_monotone_segments() {
        check(
            "one-cycle-monotone",
            64,
            |rng: &mut Rng| {
                (rng.range_f64(1e-4, 1.0), 20 + rng.below_usize(400))
            },
            |&(lr_max, steps)| {
                let c = OneCycle::new(lr_max, steps);
                let warm_f = (steps as f64) * c.pct_start;
                let warm_lo = warm_f.floor().max(1.0) as usize;
                let warm_hi = warm_f.ceil() as usize + 1; // skip boundary step
                for s in 1..warm_lo {
                    if c.lr(s) + 1e-12 < c.lr(s - 1) {
                        return Err(format!("warmup not increasing at {s}"));
                    }
                }
                for s in (warm_hi + 1)..steps {
                    if c.lr(s) > c.lr(s - 1) + 1e-12 {
                        return Err(format!("decay not decreasing at {s}"));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn standard_plan_structure() {
        let plan = PhasePlan::standard(0.1, 10, 5);
        plan.validate().unwrap();
        assert_eq!(plan.total_steps(), 15);
        let mut cursor = PhaseCursor::new(&plan);
        let mut directives = Vec::new();
        while let Some(d) = cursor.next() {
            directives.push(d);
        }
        assert_eq!(directives.len(), 15);
        // learn phase: bits train, no selection
        assert!(directives[..10]
            .iter()
            .all(|d| d.bits_mask == 1.0 && !d.select_integer_bits));
        // finetune: first step selects, all frozen, constant lr
        assert!(directives[10].select_integer_bits);
        assert!(directives[11..].iter().all(|d| !d.select_integer_bits));
        assert!(directives[10..].iter().all(|d| d.bits_mask == 0.0));
        assert!(directives[10..].iter().all(|d| (d.lr - 0.01).abs() < 1e-12));
    }

    #[test]
    fn cursor_never_regresses() {
        check(
            "phase-cursor-monotone",
            64,
            |rng: &mut Rng| (1 + rng.below_usize(50), rng.below_usize(50)),
            |&(learn, ft)| {
                let plan = PhasePlan::standard(0.1, learn, ft);
                let mut cursor = PhaseCursor::new(&plan);
                let mut last_phase = 0;
                let mut count = 0;
                let mut selections = 0;
                while let Some(d) = cursor.next() {
                    if d.phase_idx < last_phase {
                        return Err("phase regressed".into());
                    }
                    last_phase = d.phase_idx;
                    count += 1;
                    selections += d.select_integer_bits as usize;
                }
                if count != plan.total_steps() {
                    return Err(format!("{count} != {}", plan.total_steps()));
                }
                // selection boundary crossed at most once
                if ft > 0 && selections != 1 {
                    return Err(format!("{selections} selections"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn fixed_bits_plan_has_no_selection() {
        let plan = PhasePlan::fixed_bits(0.1, 8);
        let mut cursor = PhaseCursor::new(&plan);
        while let Some(d) = cursor.next() {
            assert_eq!(d.bits_mask, 0.0);
            assert!(!d.select_integer_bits);
        }
    }

    #[test]
    fn empty_plan_rejected() {
        assert!(PhasePlan { phases: vec![] }.validate().is_err());
        assert!(PhasePlan::standard(0.1, 0, 0).validate().is_err());
    }
}
