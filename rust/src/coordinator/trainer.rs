//! The per-run training loop.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::checkpoint::Checkpoint;
use crate::config::{PlanKind, RunConfig};
use crate::data::{self, Dataset, Loader, Split};
use crate::metrics::{EvalRecord, RunRecorder, StepRecord};
use crate::model::ModelMeta;
use crate::quant::{self, mean_bits};
use crate::runtime::{Executable, Runtime};
use crate::schedule::{PhaseCursor, PhasePlan};
use crate::tensor::HostTensor;

/// Cap on eval batches for the cheap *periodic* mid-phase evals.
/// Stage-boundary and final evals are uncapped (`usize::MAX`) and run
/// the full test set.
const PERIODIC_EVAL_BATCHES: usize = 8;

/// Accuracy/bits snapshot at a stage boundary (one Table II column set).
#[derive(Debug, Clone)]
pub struct StageResult {
    pub accuracy: f64,
    pub loss: f64,
    pub bits_w: Vec<f32>,
    pub bits_a: Vec<f32>,
}

impl StageResult {
    pub fn mean_bits_w(&self) -> f64 {
        mean_bits(&self.bits_w)
    }

    pub fn mean_bits_a(&self) -> f64 {
        mean_bits(&self.bits_a)
    }
}

/// Full-test-set evaluation outcome.
#[derive(Debug, Clone)]
pub struct EvalOutcome {
    pub loss: f64,
    pub accuracy: f64,
    /// Aggregated per-layer activation ranges (min over batches, max
    /// over batches) — consumed by the profiled baseline.
    pub act_min: Vec<f32>,
    pub act_max: Vec<f32>,
}

/// Everything a finished run reports.
#[derive(Debug)]
pub struct RunOutcome {
    pub name: String,
    pub model: String,
    pub gamma: f64,
    /// Snapshot at the end of the bit-learning phase (non-integer bits),
    /// i.e. the paper's "Non-Integer Bitlengths" columns. None for
    /// fixed-bits plans.
    pub noninteger: Option<StageResult>,
    /// Final snapshot (integer bits + fine-tuning for standard plans).
    pub final_: StageResult,
    pub act_min: Vec<f32>,
    pub act_max: Vec<f32>,
    pub recorder: RunRecorder,
    pub wall_secs: f64,
    /// Final trained parameters, for post-training baselines (profiled,
    /// MPDNN) which probe accuracy at other bitlength assignments.
    pub final_params: Vec<HostTensor>,
}

/// Mutable training state: the artifact's state tensors, in signature
/// order.
struct TrainState {
    params: Vec<HostTensor>,
    momenta: Vec<HostTensor>,
    bits_w: HostTensor,
    bits_a: HostTensor,
}

pub struct Trainer<'a> {
    rt: &'a Runtime,
    cfg: RunConfig,
    meta: ModelMeta,
    train_exe: Arc<Executable>,
    eval_exe: Arc<Executable>,
    dataset: Box<dyn Dataset>,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a Runtime, cfg: &RunConfig) -> Result<Self> {
        let meta_path = rt.artifact_dir().join(format!("{}_meta.json", cfg.model));
        let meta = ModelMeta::load(&meta_path)?;
        let dataset = data::build(&cfg.dataset, cfg.seed)?;

        // Config/artifact/dataset consistency.
        if dataset.input_shape() != meta.input_shape {
            bail!(
                "dataset '{}' shape {:?} does not match artifact '{}' input {:?}",
                cfg.dataset,
                dataset.input_shape(),
                cfg.model,
                meta.input_shape
            );
        }
        if dataset.num_classes() > meta.num_classes {
            bail!(
                "dataset has {} classes but artifact supports {}",
                dataset.num_classes(),
                meta.num_classes
            );
        }

        let train_exe = rt.load(&meta.train_artifact())?;
        let eval_exe = rt.load(&meta.eval_artifact())?;
        Ok(Self { rt, cfg: cfg.clone(), meta, train_exe, eval_exe, dataset })
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    fn plan(&self) -> Result<PhasePlan> {
        let c = &self.cfg;
        let plan = match c.plan {
            PlanKind::Standard => {
                PhasePlan::standard(c.lr_max, c.learn_steps, c.finetune_steps)
            }
            PlanKind::EarlySelect => {
                PhasePlan::early_select(c.lr_max, c.learn_steps, c.finetune_steps)
            }
            PlanKind::FixedBits => {
                PhasePlan::fixed_bits(c.lr_max, c.learn_steps + c.finetune_steps)
            }
            PlanKind::Warmstart => {
                PhasePlan::warmstart(c.lr_max, c.learn_steps, c.finetune_steps)
            }
        };
        plan.validate()?;
        Ok(plan)
    }

    fn init_state(&self) -> Result<TrainState> {
        let nl = self.meta.num_quant_layers;
        let (params, momenta) = match (&self.cfg.warmstart_ckpt, self.cfg.plan) {
            (Some(path), PlanKind::Warmstart) => {
                let ckpt = Checkpoint::load(path)
                    .with_context(|| format!("loading warmstart checkpoint '{path}'"))?;
                let mut params = Vec::with_capacity(self.meta.num_params);
                for name in &self.meta.param_names {
                    params.push(ckpt.get(&format!("p/{name}"))?.clone());
                }
                let momenta = params
                    .iter()
                    .map(|p| HostTensor::zeros_f32(p.dims()))
                    .collect();
                (params, momenta)
            }
            _ => {
                let init_exe = self.rt.load(&self.meta.init_artifact())?;
                let params = init_exe
                    .run(&[HostTensor::scalar_u32(self.cfg.seed as u32)])?;
                if params.len() != self.meta.num_params {
                    bail!(
                        "init artifact produced {} tensors, meta says {}",
                        params.len(),
                        self.meta.num_params
                    );
                }
                let momenta = params
                    .iter()
                    .map(|p| HostTensor::zeros_f32(p.dims()))
                    .collect();
                (params, momenta)
            }
        };
        let b = self.cfg.init_bits as f32;
        Ok(TrainState {
            params,
            momenta,
            bits_w: HostTensor::full_f32(&[nl], b),
            bits_a: HostTensor::full_f32(&[nl], b),
        })
    }

    fn lambdas(&self) -> (HostTensor, HostTensor) {
        let (lw, la) = self.cfg.criterion.lambdas(&self.meta);
        (
            HostTensor::f32(&[lw.len()], lw).unwrap(),
            HostTensor::f32(&[la.len()], la).unwrap(),
        )
    }

    /// One train step; updates `state` in place, returns
    /// (loss, task_loss, bit_loss, correct).
    fn step(
        &self,
        state: &mut TrainState,
        x: &HostTensor,
        y: &HostTensor,
        lam_w: &HostTensor,
        lam_a: &HostTensor,
        lr: f64,
        bits_lr: f64,
        bits_mask: f32,
    ) -> Result<(f64, f64, f64, f64)> {
        let np = self.meta.num_params;
        // Borrowed argument list: no parameter/momentum copies per step.
        let lr_t = HostTensor::scalar_f32(lr as f32);
        let blr_t = HostTensor::scalar_f32(bits_lr as f32);
        let gamma_t = HostTensor::scalar_f32(self.cfg.gamma as f32);
        let mask_t = HostTensor::scalar_f32(bits_mask);
        let mut args: Vec<&HostTensor> = Vec::with_capacity(2 * np + 10);
        args.extend(state.params.iter());
        args.extend(state.momenta.iter());
        args.extend([
            &state.bits_w, &state.bits_a, lam_w, lam_a, x, y,
            &lr_t, &blr_t, &gamma_t, &mask_t,
        ]);

        let mut out = self.train_exe.run_refs(&args)?;
        if out.len() != 2 * np + 6 {
            bail!(
                "train artifact returned {} outputs, expected {}",
                out.len(),
                2 * np + 6
            );
        }
        // Unpack from the back to avoid shifting.
        let correct = out.pop().unwrap().scalar()? as f64;
        let bit_loss = out.pop().unwrap().scalar()? as f64;
        let task_loss = out.pop().unwrap().scalar()? as f64;
        let loss = out.pop().unwrap().scalar()? as f64;
        state.bits_a = out.pop().unwrap();
        state.bits_w = out.pop().unwrap();
        state.momenta = out.split_off(np);
        state.params = out;
        Ok((loss, task_loss, bit_loss, correct))
    }

    /// Evaluate on the test split (at most `max_batches` batches).
    fn eval(&self, state: &TrainState, max_batches: usize) -> Result<EvalOutcome> {
        let mut loader = Loader::new(
            self.dataset.as_ref(),
            Split::Test,
            self.meta.batch_size,
            false,
            self.cfg.seed,
        );
        let nl = self.meta.num_quant_layers;
        let batches = loader.batches_per_epoch().min(max_batches).max(1);
        let mut total_loss = 0.0;
        let mut total_correct = 0.0;
        let mut total_n = 0usize;
        let mut act_min = vec![f32::INFINITY; nl];
        let mut act_max = vec![f32::NEG_INFINITY; nl];

        for _ in 0..batches {
            let batch = loader.next_batch()?;
            let mut args: Vec<&HostTensor> =
                Vec::with_capacity(self.meta.num_params + 4);
            args.extend(state.params.iter());
            args.extend([&state.bits_w, &state.bits_a, &batch.x, &batch.y]);
            let out = self.eval_exe.run_refs(&args)?;
            if out.len() != 4 {
                bail!("eval artifact returned {} outputs, expected 4", out.len());
            }
            total_loss += out[0].scalar()? as f64 * self.meta.batch_size as f64;
            total_correct += out[1].scalar()? as f64;
            total_n += self.meta.batch_size;
            for (dst, src) in act_min.iter_mut().zip(out[2].as_f32()?) {
                *dst = dst.min(*src);
            }
            for (dst, src) in act_max.iter_mut().zip(out[3].as_f32()?) {
                *dst = dst.max(*src);
            }
        }
        Ok(EvalOutcome {
            loss: total_loss / total_n as f64,
            accuracy: total_correct / total_n as f64,
            act_min,
            act_max,
        })
    }

    /// Run the configured plan to completion.
    pub fn run(&self) -> Result<RunOutcome> {
        self.run_inner(None)
    }

    /// Run the plan and additionally save the final state to `ckpt_path`
    /// (used to produce warm starts for the §III-B5 ablation).
    pub fn run_and_checkpoint(&self, ckpt_path: Option<&str>) -> Result<RunOutcome> {
        self.run_inner(ckpt_path)
    }

    fn run_inner(&self, ckpt_path: Option<&str>) -> Result<RunOutcome> {
        let started = std::time::Instant::now();
        let plan = self.plan()?;
        let mut state = self.init_state()?;
        let (lam_w, lam_a) = self.lambdas();
        let mut loader = Loader::new(
            self.dataset.as_ref(),
            Split::Train,
            self.meta.batch_size,
            self.cfg.augment,
            self.cfg.seed,
        );
        let mut recorder = RunRecorder::new(&self.cfg.name);
        let mut cursor = PhaseCursor::new(&plan);
        let mut noninteger: Option<StageResult> = None;
        let mut step_idx = 0usize;

        while let Some(d) = cursor.next() {
            if d.select_integer_bits {
                // Stage boundary (§II-C): full eval with the learned
                // non-integer bits, then ceil.
                let ev = self.eval(&state, usize::MAX)?;
                noninteger = Some(StageResult {
                    accuracy: ev.accuracy,
                    loss: ev.loss,
                    bits_w: state.bits_w.as_f32()?.to_vec(),
                    bits_a: state.bits_a.as_f32()?.to_vec(),
                });
                let nl = self.meta.num_quant_layers;
                state.bits_w = HostTensor::f32(
                    &[nl],
                    quant::select_integer_bits(state.bits_w.as_f32()?),
                )?;
                state.bits_a = HostTensor::f32(
                    &[nl],
                    quant::select_integer_bits(state.bits_a.as_f32()?),
                )?;
            }

            let batch = loader.next_batch()?;
            let (loss, task, bl, correct) = self.step(
                &mut state,
                &batch.x,
                &batch.y,
                &lam_w,
                &lam_a,
                d.lr,
                self.cfg.bits_lr,
                d.bits_mask,
            )?;
            recorder.record_step(StepRecord {
                step: step_idx,
                phase: d.phase_name,
                lr: d.lr,
                loss,
                task_loss: task,
                bit_loss: bl,
                train_acc: correct / self.meta.batch_size as f64,
                mean_bits_w: mean_bits(state.bits_w.as_f32()?),
                mean_bits_a: mean_bits(state.bits_a.as_f32()?),
            });

            if (step_idx + 1) % self.cfg.eval_every == 0 {
                let ev = self.eval(&state, PERIODIC_EVAL_BATCHES)?;
                recorder.record_eval(EvalRecord {
                    step: step_idx,
                    loss: ev.loss,
                    accuracy: ev.accuracy,
                    mean_bits_w: mean_bits(state.bits_w.as_f32()?),
                    mean_bits_a: mean_bits(state.bits_a.as_f32()?),
                });
            }
            step_idx += 1;
        }

        // Final full evaluation.
        let ev = self.eval(&state, usize::MAX)?;
        recorder.record_eval(EvalRecord {
            step: step_idx,
            loss: ev.loss,
            accuracy: ev.accuracy,
            mean_bits_w: mean_bits(state.bits_w.as_f32()?),
            mean_bits_a: mean_bits(state.bits_a.as_f32()?),
        });
        recorder.final_bits_w = state.bits_w.as_f32()?.to_vec();
        recorder.final_bits_a = state.bits_a.as_f32()?.to_vec();

        if let Some(path) = ckpt_path {
            let mut ckpt = Checkpoint::new();
            for (name, p) in self.meta.param_names.iter().zip(&state.params) {
                ckpt.insert(&format!("p/{name}"), p.clone());
            }
            ckpt.insert("bits_w", state.bits_w.clone());
            ckpt.insert("bits_a", state.bits_a.clone());
            // Final-eval calibrated activation ranges ride along so the
            // checkpoint alone can become a batch-invariant deployment
            // artifact (`bitprune export --ckpt ...`) with no dataset.
            let nl = self.meta.num_quant_layers;
            ckpt.insert("cal/act_min", HostTensor::f32(&[nl], ev.act_min.clone())?);
            ckpt.insert("cal/act_max", HostTensor::f32(&[nl], ev.act_max.clone())?);
            ckpt.save(path)?;
        }

        let final_ = StageResult {
            accuracy: ev.accuracy,
            loss: ev.loss,
            bits_w: state.bits_w.as_f32()?.to_vec(),
            bits_a: state.bits_a.as_f32()?.to_vec(),
        };

        Ok(RunOutcome {
            name: self.cfg.name.clone(),
            model: self.cfg.model.clone(),
            gamma: self.cfg.gamma,
            noninteger,
            final_,
            act_min: ev.act_min,
            act_max: ev.act_max,
            recorder,
            wall_secs: started.elapsed().as_secs_f64(),
            final_params: state.params,
        })
    }

    /// Post-training evaluation session over fixed parameters: probes
    /// arbitrary bitlength assignments (profiled / MPDNN baselines).
    pub fn session<'s>(&'s self, params: &'s [HostTensor]) -> EvalSession<'s> {
        EvalSession { trainer: self, params, act_min: None, act_max: None }
    }
}

/// Probes accuracy of fixed trained parameters at arbitrary bitlengths.
pub struct EvalSession<'s> {
    trainer: &'s Trainer<'s>,
    params: &'s [HostTensor],
    /// Calibrated per-layer activation ranges (see
    /// [`Self::with_calibration`]); `None` keeps the dynamic per-batch
    /// convention.
    act_min: Option<Vec<f32>>,
    act_max: Option<Vec<f32>>,
}

impl EvalSession<'_> {
    pub fn num_layers(&self) -> usize {
        self.trainer.meta.num_quant_layers
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.trainer.meta
    }

    /// Accuracy at the given bitlengths over `max_batches` test batches.
    pub fn accuracy(
        &self,
        bits_w: &[f32],
        bits_a: &[f32],
        max_batches: usize,
    ) -> Result<f64> {
        let nl = self.trainer.meta.num_quant_layers;
        let state = TrainState {
            params: self.params.to_vec(),
            momenta: vec![],
            bits_w: HostTensor::f32(&[nl], bits_w.to_vec())?,
            bits_a: HostTensor::f32(&[nl], bits_a.to_vec())?,
        };
        Ok(self.trainer.eval(&state, max_batches)?.accuracy)
    }

    /// Attach calibrated per-layer activation ranges — typically the
    /// trainer's full-test-set aggregates
    /// (`RunOutcome::{act_min, act_max}` /
    /// `EvalOutcome::{act_min, act_max}`).  [`Self::int_net`] then
    /// builds batch-invariant deployment nets (static ranges, the
    /// serving convention) instead of dynamic per-batch ones.
    pub fn with_calibration(mut self, act_min: Vec<f32>, act_max: Vec<f32>) -> Self {
        self.act_min = Some(act_min);
        self.act_max = Some(act_max);
        self
    }

    /// Build the pure-integer deployment net ([`crate::infer::IntNet`])
    /// for this session's trained parameters at the given (ceiled)
    /// bitlengths. Dense models only.  Carries the calibrated ranges
    /// when [`Self::with_calibration`] supplied them.
    pub fn int_net(&self, bits_w: &[f32], bits_a: &[f32]) -> Result<crate::infer::IntNet> {
        self.int_net_with(bits_w, bits_a, quant::Granularity::PerLayer)
    }

    /// [`Self::int_net`] at an explicit weight granularity:
    /// `PerOutputChannel` refines each layer's learned bitlength into
    /// per-channel assignments (`quant::per_channel_bits`) and packs
    /// every output channel at its own bitlength — the sub-layer
    /// deployment the paper's granularity claim targets.
    pub fn int_net_with(
        &self,
        bits_w: &[f32],
        bits_a: &[f32],
        granularity: quant::Granularity,
    ) -> Result<crate::infer::IntNet> {
        let ranges = match (&self.act_min, &self.act_max) {
            (Some(lo), Some(hi)) => Some((lo.as_slice(), hi.as_slice())),
            _ => None,
        };
        crate::infer::IntNet::from_trained_with(
            &self.trainer.meta,
            self.params,
            bits_w,
            bits_a,
            ranges,
            granularity,
        )
    }

    /// Accuracy of the **pure-integer deployment path** at the given
    /// bitlengths over `max_batches` test batches — no PJRT round trip,
    /// so post-training probes (profiled / MPDNN baselines) can run at
    /// deployment speed on dense models.
    pub fn int_accuracy(
        &self,
        bits_w: &[f32],
        bits_a: &[f32],
        max_batches: usize,
    ) -> Result<f64> {
        let net = self.int_net(bits_w, bits_a)?;
        self.int_net_accuracy(&net, max_batches)
    }

    /// Like [`Self::int_accuracy`], but over a prebuilt net (avoids
    /// re-packing and re-tiling every layer when the caller already
    /// constructed one, e.g. for footprint reporting).
    pub fn int_net_accuracy(
        &self,
        net: &crate::infer::IntNet,
        max_batches: usize,
    ) -> Result<f64> {
        let bs = self.trainer.meta.batch_size;
        let mut loader = Loader::new(
            self.trainer.dataset.as_ref(),
            Split::Test,
            bs,
            false,
            self.trainer.cfg.seed,
        );
        let batches = loader.batches_per_epoch().min(max_batches).max(1);
        let mut correct = 0usize;
        let mut total = 0usize;
        for _ in 0..batches {
            let b = loader.next_batch()?;
            let y = b.y;
            let preds = net.predict(&b.x.into_f32()?, bs);
            for (p, label) in preds.iter().zip(y.as_i32()?) {
                correct += (*p as i32 == *label) as usize;
                total += 1;
            }
        }
        Ok(correct as f64 / total as f64)
    }
}

/// Run one experiment end to end.
pub fn run_experiment(rt: &Runtime, cfg: &RunConfig) -> Result<RunOutcome> {
    Trainer::new(rt, cfg)?.run()
}
