//! Experiment scheduler: a work-stealing job queue over OS threads.
//!
//! Sweeps (Tables II-VI) are embarrassingly parallel across runs; on a
//! multi-core host the scheduler fans configs out to worker threads,
//! each with its own PJRT executable cache.  Results return in
//! submission order regardless of completion order, so table rows stay
//! deterministic.
//!
//! The testbed here has one core (workers default to
//! `available_parallelism`), but the scheduler is exercised by unit
//! tests with synthetic jobs and by the sweep drivers with `--workers`.

use std::collections::BTreeMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

/// A scheduled job: index + closure.
type Job<T> = (usize, Box<dyn FnOnce() -> Result<T> + Send>);

/// Outcome of a sweep: per-job results in submission order.
pub struct SweepResults<T> {
    results: Vec<Result<T>>,
}

impl<T> SweepResults<T> {
    /// All successes, failing on the first error (with its job index).
    pub fn into_all(self) -> Result<Vec<T>> {
        self.results
            .into_iter()
            .enumerate()
            .map(|(i, r)| r.map_err(|e| anyhow!("job {i}: {e}")))
            .collect()
    }

    /// Successes and errors separately.
    pub fn partition(self) -> (Vec<(usize, T)>, Vec<(usize, String)>) {
        let mut ok = Vec::new();
        let mut err = Vec::new();
        for (i, r) in self.results.into_iter().enumerate() {
            match r {
                Ok(v) => ok.push((i, v)),
                Err(e) => err.push((i, format!("{e:#}"))),
            }
        }
        (ok, err)
    }
}

/// Run `jobs` on `workers` threads; returns results in submission order.
pub fn run_jobs<T: Send + 'static>(
    jobs: Vec<Box<dyn FnOnce() -> Result<T> + Send>>,
    workers: usize,
) -> SweepResults<T> {
    let n = jobs.len();
    if n == 0 {
        return SweepResults { results: vec![] };
    }
    let workers = workers.clamp(1, n);

    if workers == 1 {
        // Fast path: in-place, no threads (the single-core testbed).
        let results = jobs.into_iter().map(|j| j()).collect();
        return SweepResults { results };
    }

    let queue: Arc<Mutex<Vec<Job<T>>>> = Arc::new(Mutex::new(
        jobs.into_iter().enumerate().map(|(i, j)| (i, j)).collect(),
    ));
    let (tx, rx) = mpsc::channel::<(usize, Result<T>)>();

    let mut handles = Vec::new();
    for _ in 0..workers {
        let queue = queue.clone();
        let tx = tx.clone();
        handles.push(std::thread::spawn(move || loop {
            let job = queue.lock().unwrap().pop();
            match job {
                Some((idx, f)) => {
                    // A panicking job poisons nothing: catch and report.
                    let result = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(f),
                    )
                    .unwrap_or_else(|p| {
                        Err(anyhow!("job panicked: {}", panic_msg(&p)))
                    });
                    if tx.send((idx, result)).is_err() {
                        return;
                    }
                }
                None => return,
            }
        }));
    }
    drop(tx);

    let mut collected: BTreeMap<usize, Result<T>> = BTreeMap::new();
    for (idx, result) in rx {
        collected.insert(idx, result);
    }
    for h in handles {
        let _ = h.join();
    }
    // Any job lost to a worker crash is reported as an error.
    let results = (0..n)
        .map(|i| {
            collected
                .remove(&i)
                .unwrap_or_else(|| Err(anyhow!("job {i} was lost (worker died)")))
        })
        .collect();
    SweepResults { results }
}

fn panic_msg(p: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        s.to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic".to_string()
    }
}

/// Default worker count: one per core, capped by job count.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn jobs_from<T: Send + 'static>(
        fns: Vec<impl FnOnce() -> Result<T> + Send + 'static>,
    ) -> Vec<Box<dyn FnOnce() -> Result<T> + Send>> {
        fns.into_iter()
            .map(|f| Box::new(f) as Box<dyn FnOnce() -> Result<T> + Send>)
            .collect()
    }

    #[test]
    fn results_in_submission_order() {
        for workers in [1, 4] {
            let jobs = jobs_from(
                (0..16)
                    .map(|i| {
                        move || {
                            // Vary runtimes to scramble completion order.
                            std::thread::sleep(std::time::Duration::from_millis(
                                (16 - i) as u64,
                            ));
                            Ok(i * 10)
                        }
                    })
                    .collect::<Vec<_>>(),
            );
            let out = run_jobs(jobs, workers).into_all().unwrap();
            assert_eq!(out, (0..16).map(|i| i * 10).collect::<Vec<_>>());
        }
    }

    #[test]
    fn all_jobs_execute_exactly_once() {
        let counter = Arc::new(AtomicUsize::new(0));
        let jobs = jobs_from(
            (0..32)
                .map(|_| {
                    let c = counter.clone();
                    move || {
                        c.fetch_add(1, Ordering::SeqCst);
                        Ok(())
                    }
                })
                .collect::<Vec<_>>(),
        );
        run_jobs(jobs, 4).into_all().unwrap();
        assert_eq!(counter.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn errors_are_indexed_not_fatal() {
        let jobs = jobs_from(vec![
            (|| Ok(1)) as fn() -> Result<i32>,
            || Err(anyhow!("boom")),
            || Ok(3),
        ]);
        let (ok, err) = run_jobs(jobs, 2).partition();
        assert_eq!(ok, vec![(0, 1), (2, 3)]);
        assert_eq!(err.len(), 1);
        assert_eq!(err[0].0, 1);
        assert!(err[0].1.contains("boom"));
    }

    #[test]
    fn panicking_job_is_contained() {
        let jobs = jobs_from(vec![
            (|| Ok(1)) as fn() -> Result<i32>,
            || panic!("kaboom"),
            || Ok(3),
        ]);
        let (ok, err) = run_jobs(jobs, 2).partition();
        assert_eq!(ok.len(), 2);
        assert!(err[0].1.contains("kaboom"));
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<Box<dyn FnOnce() -> Result<()> + Send>> = vec![];
        assert!(run_jobs(empty, 4).into_all().unwrap().is_empty());
        let one = jobs_from(vec![|| Ok(42)]);
        assert_eq!(run_jobs(one, 8).into_all().unwrap(), vec![42]);
    }

    #[test]
    fn into_all_propagates_first_error() {
        let jobs = jobs_from(vec![
            (|| Ok(1)) as fn() -> Result<i32>,
            || Err(anyhow!("x")),
        ]);
        assert!(run_jobs(jobs, 1).into_all().is_err());
    }
}
