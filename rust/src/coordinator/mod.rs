//! The training coordinator: drives BitPruning runs through the
//! AOT-compiled train/eval artifacts.
//!
//! Everything the paper's method does at training time happens here, in
//! rust, against PJRT executables — python never runs:
//!
//! * phase state machine (learn bits → ceil to integers → fine-tune),
//! * one-cycle LR fed per step,
//! * batch staging from the synthetic datasets,
//! * bitlength selection between phases (quant::select_integer_bits),
//! * metric recording for the figure/table regeneration,
//! * checkpointing (incl. warm starts for the §III-B5 ablation).

pub mod scheduler;
mod trainer;

pub use trainer::{
    run_experiment, EvalOutcome, EvalSession, RunOutcome, StageResult, Trainer,
};

use crate::config::RunConfig;
use crate::runtime::Runtime;

/// Convenience: run a list of configs sequentially against one runtime,
/// returning all outcomes (the sweep drivers in report/ use this).
pub fn run_all(
    rt: &Runtime,
    configs: &[RunConfig],
    quiet: bool,
) -> anyhow::Result<Vec<RunOutcome>> {
    let mut outcomes = Vec::with_capacity(configs.len());
    for (i, cfg) in configs.iter().enumerate() {
        if !quiet {
            eprintln!(
                "[{}/{}] {} (model={}, gamma={}, plan={})",
                i + 1,
                configs.len(),
                cfg.name,
                cfg.model,
                cfg.gamma,
                cfg.plan.name()
            );
        }
        outcomes.push(run_experiment(rt, cfg)?);
    }
    Ok(outcomes)
}

/// Run configs across worker threads (each worker owns its own PJRT
/// client — the xla handles are not Send).  Results keep config order.
pub fn run_all_parallel(
    configs: &[RunConfig],
    workers: usize,
) -> anyhow::Result<Vec<RunOutcome>> {
    let jobs: Vec<Box<dyn FnOnce() -> anyhow::Result<RunOutcome> + Send>> = configs
        .iter()
        .cloned()
        .map(|cfg| {
            Box::new(move || {
                let rt = Runtime::cpu(&cfg.artifact_dir)?;
                run_experiment(&rt, &cfg)
            }) as Box<dyn FnOnce() -> anyhow::Result<RunOutcome> + Send>
        })
        .collect();
    scheduler::run_jobs(jobs, workers).into_all()
}
