//! Comparison baselines (paper Table VII + the MPDNN discussion,
//! §III-B6).
//!
//! All three are implemented as *pure search logic* over an accuracy
//! probe `Fn(&bits_w, &bits_a) -> accuracy`, so they are unit-testable
//! against synthetic accuracy surfaces and run, in production, against a
//! trained network through [`coordinator::EvalSession`] — either the
//! XLA fake-quant probe (`accuracy`) or, for dense models, the much
//! cheaper pure-integer fast path (`int_accuracy`, backed by the
//! blocked i64 GEMM in [`crate::infer`]).
//!
//! * **Uniform fixed-bitlength QAT** (PACT's role): not a search — a
//!   `PlanKind::FixedBits` run at n bits; helper below builds configs.
//! * **Profiled per-layer selection** (Judd et al. [22], Nikolić et
//!   al. [23]): post-training, per-layer greedy minimum-bitlength search
//!   subject to an accuracy-drop budget.
//! * **MPDNN-style memory-constrained assignment** (Uhlich et al.
//!   [29]): given a weight-memory budget, maximize accuracy — the
//!   contrast being that BitPruning needs no such expertly-chosen
//!   budget.

use anyhow::Result;

use crate::config::{PlanKind, RunConfig};

/// Accuracy probe over a bitlength assignment.
pub type AccProbe<'a> = dyn FnMut(&[f32], &[f32]) -> Result<f64> + 'a;

// ---------------------------------------------------------------------------
// PACT-role uniform QAT
// ---------------------------------------------------------------------------

/// Config for a uniform fixed-bitlength QAT run at `bits`.
pub fn uniform_qat_config(base: &RunConfig, bits: f64, name: &str) -> RunConfig {
    let mut cfg = base.clone();
    cfg.name = name.to_string();
    cfg.plan = PlanKind::FixedBits;
    cfg.init_bits = bits;
    cfg.gamma = 0.0;
    cfg
}

/// Config for the fp32-proxy baseline (16-bit quantization is visually
/// indistinguishable from fp32 for these networks).
pub fn fp32_proxy_config(base: &RunConfig, name: &str) -> RunConfig {
    uniform_qat_config(base, 16.0, name)
}

// ---------------------------------------------------------------------------
// Profiled per-layer selection
// ---------------------------------------------------------------------------

/// Result of a post-training bitlength search.
#[derive(Debug, Clone)]
pub struct SearchResult {
    pub bits_w: Vec<f32>,
    pub bits_a: Vec<f32>,
    pub accuracy: f64,
    /// Number of probe evaluations spent.
    pub probes: usize,
}

/// Profiled per-layer bitlength selection (the Judd et al. [22]
/// "reduced-precision strategies" decision rule).
///
/// Layers are profiled **in order, cumulatively**: while choosing layer
/// l's weight/activation bitlength, the bitlengths already chosen for
/// layers < l stay applied and layers > l remain at `hi_bits`.  A
/// layer's bitlength is lowered one bit at a time while the probed
/// accuracy stays within `budget` of the full-precision accuracy (a
/// single global tolerance, consumed greedily front-to-back).
///
/// This reproduces both properties the paper's Table VII shows for
/// profiled methods: no retraining means the tolerance is spent on
/// *existing* representations, so bit counts stay well above what
/// BitPruning learns; and early layers consume the budget, leaving
/// later layers near the profile ceiling.
pub fn profiled_search(
    num_layers: usize,
    hi_bits: f32,
    budget: f64,
    probe: &mut AccProbe,
) -> Result<SearchResult> {
    let mut probes = 0usize;
    let mut bits_w = vec![hi_bits; num_layers];
    let mut bits_a = vec![hi_bits; num_layers];
    let base_acc = probe(&bits_w, &bits_a)?;
    probes += 1;
    let floor = base_acc - budget;

    for layer in 0..num_layers {
        for which in [0usize, 1] {
            loop {
                let bits = if which == 0 { &mut bits_w } else { &mut bits_a };
                let cur = bits[layer];
                if cur <= 1.0 {
                    break;
                }
                bits[layer] = cur - 1.0;
                let (w, a) = (bits_w.clone(), bits_a.clone());
                let acc = probe(&w, &a)?;
                probes += 1;
                if acc < floor {
                    let bits =
                        if which == 0 { &mut bits_w } else { &mut bits_a };
                    bits[layer] = cur; // revert and move on
                    break;
                }
            }
        }
    }
    let accuracy = probe(&bits_w, &bits_a)?;
    probes += 1;
    Ok(SearchResult { bits_w, bits_a, accuracy, probes })
}

/// Joint greedy search (round-robin): an *oracle-ish* post-training
/// search that measures every reduction jointly.  Stronger than the
/// profiled decision rule (it sees error compounding) but far more
/// probe-hungry; kept as an upper-bound comparator and used by tests.
pub fn greedy_joint_search(
    num_layers: usize,
    start_bits: f32,
    budget: f64,
    probe: &mut AccProbe,
) -> Result<SearchResult> {
    let mut bits_w = vec![start_bits; num_layers];
    let mut bits_a = vec![start_bits; num_layers];
    let mut probes = 0usize;
    let base_acc = probe(&bits_w, &bits_a)?;
    probes += 1;
    let floor_acc = base_acc - budget;

    // Round-robin until a full sweep makes no progress.
    let mut improved = true;
    while improved {
        improved = false;
        for layer in 0..num_layers {
            for which in [0usize, 1] {
                let bits = if which == 0 { &mut bits_w } else { &mut bits_a };
                let cur = bits[layer];
                if cur <= 1.0 {
                    continue;
                }
                bits[layer] = cur - 1.0;
                let (bw, ba) = (bits_w.clone(), bits_a.clone());
                let acc = probe(&bw, &ba)?;
                probes += 1;
                let bits = if which == 0 { &mut bits_w } else { &mut bits_a };
                if acc >= floor_acc {
                    improved = true;
                } else {
                    bits[layer] = cur; // revert
                }
            }
        }
    }
    let accuracy = probe(&bits_w, &bits_a)?;
    probes += 1;
    Ok(SearchResult { bits_w, bits_a, accuracy, probes })
}

// ---------------------------------------------------------------------------
// MPDNN-style memory-constrained assignment
// ---------------------------------------------------------------------------

/// MPDNN-style assignment: maximize accuracy subject to a weight-memory
/// budget (bits).  Greedy: from `start_bits`, repeatedly reduce the
/// layer whose reduction costs the least probed accuracy per bit of
/// memory saved, until the budget is met.
///
/// `weight_elems[l]` weights the memory cost of layer l.
pub fn mpdnn_assign(
    weight_elems: &[usize],
    start_bits: f32,
    budget_bits: f64,
    probe: &mut AccProbe,
) -> Result<SearchResult> {
    let nl = weight_elems.len();
    let mut bits_w = vec![start_bits; nl];
    let bits_a = vec![start_bits; nl];
    let mut probes = 0usize;

    let footprint = |bw: &[f32]| -> f64 {
        bw.iter()
            .zip(weight_elems)
            .map(|(&b, &e)| b as f64 * e as f64)
            .sum()
    };

    while footprint(&bits_w) > budget_bits {
        // Probe each layer's one-bit reduction; pick best acc-per-saving.
        let mut best: Option<(usize, f64)> = None;
        for l in 0..nl {
            if bits_w[l] <= 1.0 {
                continue;
            }
            let mut cand = bits_w.clone();
            cand[l] -= 1.0;
            let acc = probe(&cand, &bits_a)?;
            probes += 1;
            let saving = weight_elems[l] as f64;
            let score = acc + 1e-12 * saving; // acc dominates; saving tie-breaks
            if best.map_or(true, |(_, s)| score > s) {
                best = Some((l, score));
            }
        }
        match best {
            Some((l, _)) => bits_w[l] -= 1.0,
            None => break, // everything at 1 bit; budget unreachable
        }
    }
    let accuracy = probe(&bits_w, &bits_a)?;
    probes += 1;
    Ok(SearchResult { bits_w, bits_a, accuracy, probes })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Synthetic accuracy surface: each layer l tolerates down to
    /// `tol[l]` bits; below that, accuracy drops sharply per missing bit.
    fn surface(tol_w: Vec<f32>, tol_a: Vec<f32>) -> impl FnMut(&[f32], &[f32]) -> Result<f64> {
        move |bw: &[f32], ba: &[f32]| {
            let mut acc = 0.9;
            for (b, t) in bw.iter().zip(&tol_w) {
                if b < t {
                    acc -= 0.2 * (t - b) as f64;
                }
            }
            for (b, t) in ba.iter().zip(&tol_a) {
                if b < t {
                    acc -= 0.2 * (t - b) as f64;
                }
            }
            Ok(acc.max(0.0))
        }
    }

    #[test]
    fn profiled_finds_per_layer_tolerances() {
        // The surface is separable, so isolation probing recovers each
        // layer's exact tolerance.
        let tol_w = vec![3.0, 5.0, 2.0];
        let tol_a = vec![4.0, 4.0, 6.0];
        let mut probe = surface(tol_w.clone(), tol_a.clone());
        let r = profiled_search(3, 8.0, 0.01, &mut probe).unwrap();
        assert_eq!(r.bits_w, tol_w);
        assert_eq!(r.bits_a, tol_a);
        assert!((r.accuracy - 0.9).abs() < 1e-9);
        assert!(r.probes > 6);
    }

    #[test]
    fn profiled_budget_consumed_front_to_back() {
        // With tolerance to spare, early layers dip below their natural
        // tolerance first and later layers stay at the ceiling — the
        // cumulative profile's characteristic skew.
        let mut probe = surface(vec![4.0, 4.0], vec![4.0, 4.0]);
        let r = profiled_search(2, 8.0, 0.25, &mut probe).unwrap();
        assert_eq!(r.bits_w[0], 3.0); // ate the budget (0.2 drop)
        assert_eq!(r.bits_a[0], 4.0); // next group couldn't afford more
        assert_eq!(r.bits_w[1], 4.0);
        assert_eq!(r.bits_a[1], 4.0);
        // Accuracy stays within the global budget on the probe surface.
        assert!(r.accuracy >= 0.9 - 0.25 - 1e-9);
    }

    #[test]
    fn profiled_never_below_one_bit() {
        let mut probe = |_: &[f32], _: &[f32]| Ok(1.0);
        let r = profiled_search(2, 3.0, 1.0, &mut probe).unwrap();
        assert!(r.bits_w.iter().chain(&r.bits_a).all(|&b| b >= 1.0));
    }

    #[test]
    fn greedy_joint_respects_budget() {
        let mut probe = surface(vec![4.0, 2.0], vec![3.0, 3.0]);
        let r = greedy_joint_search(2, 8.0, 0.01, &mut probe).unwrap();
        assert_eq!(r.bits_w, vec![4.0, 2.0]);
        assert_eq!(r.bits_a, vec![3.0, 3.0]);
        assert!((r.accuracy - 0.9).abs() < 1e-9);
    }

    #[test]
    fn greedy_joint_never_below_one_bit() {
        let mut probe = |_: &[f32], _: &[f32]| Ok(1.0);
        let r = greedy_joint_search(2, 3.0, 1.0, &mut probe).unwrap();
        assert!(r.bits_w.iter().chain(&r.bits_a).all(|&b| b >= 1.0));
    }

    #[test]
    fn mpdnn_meets_budget() {
        let elems = vec![1000usize, 100, 10];
        let mut probe = surface(vec![2.0, 4.0, 6.0], vec![8.0, 8.0, 8.0]);
        // budget: half the 8-bit footprint
        let full: f64 = elems.iter().map(|&e| e as f64 * 8.0).sum();
        let r = mpdnn_assign(&elems, 8.0, full / 2.0, &mut probe).unwrap();
        let fp: f64 = r
            .bits_w
            .iter()
            .zip(&elems)
            .map(|(&b, &e)| b as f64 * e as f64)
            .sum();
        assert!(fp <= full / 2.0 + 1e-9);
        // The big, tolerant layer should shrink the most.
        assert!(r.bits_w[0] < r.bits_w[2]);
    }

    #[test]
    fn mpdnn_unreachable_budget_stops_at_one_bit() {
        let elems = vec![10usize, 10];
        let mut probe = |_: &[f32], _: &[f32]| Ok(0.5);
        let r = mpdnn_assign(&elems, 4.0, 1.0, &mut probe).unwrap();
        assert!(r.bits_w.iter().all(|&b| b == 1.0));
    }

    #[test]
    fn profiled_search_with_integer_probe() {
        // End-to-end over a *real* accuracy surface: the probe rebuilds
        // the pure-integer net (blocked i64 GEMM) at each candidate
        // assignment and scores agreement with the 8-bit reference
        // predictions. Runs entirely in rust — no artifacts needed.
        use crate::infer::{IntDense, IntNet};
        use crate::util::rng::Rng;

        let mut rng = Rng::new(0xBA5E);
        let (din, hidden, classes, n) = (16usize, 24usize, 4usize, 64usize);
        let rv = |rng: &mut Rng, len: usize| -> Vec<f32> {
            (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect()
        };
        let w0 = rv(&mut rng, din * hidden);
        let b0 = rv(&mut rng, hidden);
        let w1 = rv(&mut rng, hidden * classes);
        let b1 = rv(&mut rng, classes);
        let x = rv(&mut rng, n * din);

        let make = |bw0: u32, ba0: u32, bw1: u32, ba1: u32| -> Result<IntNet> {
            Ok(IntNet {
                layers: vec![
                    IntDense::new("l0", &w0, din, hidden, &b0, bw0, ba0, true)?.into(),
                    IntDense::new("l1", &w1, hidden, classes, &b1, bw1, ba1, false)?.into(),
                ],
                num_classes: classes,
            })
        };
        let labels = make(8, 8, 8, 8).unwrap().predict(&x, n);
        let mut probe = |bw: &[f32], ba: &[f32]| -> Result<f64> {
            let net = make(
                bw[0].ceil() as u32,
                ba[0].ceil() as u32,
                bw[1].ceil() as u32,
                ba[1].ceil() as u32,
            )?;
            let preds = net.predict(&x, n);
            let agree = preds.iter().zip(&labels).filter(|(a, b)| a == b).count();
            Ok(agree as f64 / n as f64)
        };

        let r = profiled_search(2, 8.0, 0.05, &mut probe).unwrap();
        assert!(r.bits_w.iter().chain(&r.bits_a).all(|&b| (1.0..=8.0).contains(&b)));
        assert!(r.probes > 4);
        // Every accepted lowering kept agreement within the budget.
        assert!(r.accuracy >= 1.0 - 0.05 - 1e-9, "accuracy {}", r.accuracy);
    }

    #[test]
    fn uniform_config_builders() {
        let base = RunConfig::default();
        let q = uniform_qat_config(&base, 4.0, "pact4");
        assert_eq!(q.plan, PlanKind::FixedBits);
        assert_eq!(q.init_bits, 4.0);
        assert_eq!(q.gamma, 0.0);
        assert_eq!(q.name, "pact4");
        let f = fp32_proxy_config(&base, "fp32");
        assert_eq!(f.init_bits, 16.0);
    }
}
