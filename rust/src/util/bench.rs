//! Micro-benchmark harness (the offline environment has no criterion).
//!
//! Each `cargo bench` target is a `harness = false` binary that builds a
//! [`Bench`] and registers closures.  The harness warms up, samples until
//! a time budget or sample cap is reached, and prints mean/median/p95
//! plus optional throughput, in a stable machine-greppable format:
//!
//! ```text
//! bench <name> ... mean 12.34us median 12.10us p95 13.99us (n=42) [8.1 Melem/s]
//! ```

use std::time::{Duration, Instant};

use super::stats::percentile;

/// Configuration for one benchmark run.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup_iters: usize,
    pub max_samples: usize,
    pub time_budget: Duration,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            max_samples: 50,
            time_budget: Duration::from_secs(3),
        }
    }
}

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>, // seconds
    pub mean: f64,
    pub median: f64,
    pub p95: f64,
    /// Optional elements-per-iteration for throughput reporting.
    pub elems_per_iter: Option<f64>,
}

impl BenchResult {
    /// Build a result from externally collected duration samples in
    /// seconds (e.g. per-request serving latencies) so ad-hoc harnesses
    /// share the same reporting/JSONL pipeline as [`Bench`].
    pub fn from_samples(name: &str, mut samples: Vec<f64>, elems_per_iter: Option<f64>) -> Self {
        assert!(!samples.is_empty(), "from_samples: no samples for '{name}'");
        samples.sort_by(|a, b| a.total_cmp(b));
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        Self {
            name: name.to_string(),
            mean,
            median: percentile(&samples, 50.0),
            p95: percentile(&samples, 95.0),
            samples,
            elems_per_iter,
        }
    }

    /// Arbitrary percentile over the recorded (sorted) samples.
    pub fn percentile(&self, p: f64) -> f64 {
        percentile(&self.samples, p)
    }

    /// The samples loaded into a nanosecond-bucketed
    /// [`crate::telemetry::Histogram`] reading in seconds — the same
    /// implementation behind the serve endpoint's
    /// `serve_request_latency_seconds`, so bench JSONL percentiles and
    /// scraped percentiles can never drift apart.
    pub fn latency_histogram(&self) -> crate::telemetry::Histogram {
        let h = crate::telemetry::Histogram::with_scale(1e-9);
        for &s in &self.samples {
            h.observe_secs(s);
        }
        h
    }

    pub fn throughput(&self) -> Option<f64> {
        self.elems_per_iter.map(|e| e / self.mean)
    }

    pub fn report(&self) -> String {
        let t = |s: f64| {
            if s >= 1.0 {
                format!("{:.3}s", s)
            } else if s >= 1e-3 {
                format!("{:.2}ms", s * 1e3)
            } else {
                format!("{:.2}us", s * 1e6)
            }
        };
        let mut line = format!(
            "bench {:<40} mean {:>9} median {:>9} p95 {:>9} (n={})",
            self.name,
            t(self.mean),
            t(self.median),
            t(self.p95),
            self.samples.len()
        );
        if let Some(tp) = self.throughput() {
            let (v, unit) = if tp >= 1e9 {
                (tp / 1e9, "Gelem/s")
            } else if tp >= 1e6 {
                (tp / 1e6, "Melem/s")
            } else if tp >= 1e3 {
                (tp / 1e3, "Kelem/s")
            } else {
                (tp, "elem/s")
            };
            line.push_str(&format!(" [{v:.2} {unit}]"));
        }
        line
    }

    /// One-line JSON record (machine-readable; consumed by
    /// `scripts/bench.sh` to build the repo-root perf trajectory).
    pub fn to_json(&self) -> String {
        let esc: String = self
            .name
            .chars()
            .flat_map(|c| match c {
                '"' => vec!['\\', '"'],
                '\\' => vec!['\\', '\\'],
                c => vec![c],
            })
            .collect();
        let elems = self
            .elems_per_iter
            .map_or("null".to_string(), |e| format!("{e}"));
        let tp = self
            .throughput()
            .map_or("null".to_string(), |t| format!("{t:.3}"));
        // mean/median stay sample-exact (`scripts/bench_compare.sh`
        // gates on median_s); the tail percentiles come from the shared
        // telemetry histogram so this record and a scraped
        // `serve_request_latency_seconds` agree to bucket resolution.
        let hist = self.latency_histogram();
        let mut j = String::from("{");
        j.push_str(&format!("\"name\":\"{esc}\","));
        j.push_str(&format!("\"mean_s\":{:.9},", self.mean));
        j.push_str(&format!("\"median_s\":{:.9},", self.median));
        j.push_str(&format!("\"p50_s\":{:.9},", hist.quantile(0.50)));
        j.push_str(&format!("\"p95_s\":{:.9},", hist.quantile(0.95)));
        j.push_str(&format!("\"p99_s\":{:.9},", hist.quantile(0.99)));
        j.push_str(&format!("\"samples\":{},", self.samples.len()));
        j.push_str(&format!("\"elems_per_iter\":{elems},"));
        j.push_str(&format!("\"throughput_elems_per_s\":{tp}"));
        j.push('}');
        j
    }
}

pub struct Bench {
    cfg: BenchConfig,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        // `cargo bench -- --quick` halves budgets.
        let quick = std::env::args().any(|a| a == "--quick");
        let mut cfg = BenchConfig::default();
        if quick {
            cfg.max_samples = 10;
            cfg.time_budget = Duration::from_secs(1);
        }
        Self { cfg, results: Vec::new() }
    }

    pub fn with_config(cfg: BenchConfig) -> Self {
        Self { cfg, results: Vec::new() }
    }

    /// Run one benchmark. `f` is a single iteration; its return value is
    /// black-boxed to prevent dead-code elimination.
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        self.run_with_elems(name, None, move || {
            std::hint::black_box(f());
        })
    }

    /// Like `run`, with an elements-per-iteration count for throughput.
    pub fn run_elems<T>(
        &mut self,
        name: &str,
        elems: f64,
        mut f: impl FnMut() -> T,
    ) -> &BenchResult {
        self.run_with_elems(name, Some(elems), move || {
            std::hint::black_box(f());
        })
    }

    fn run_with_elems(
        &mut self,
        name: &str,
        elems: Option<f64>,
        mut f: impl FnMut(),
    ) -> &BenchResult {
        for _ in 0..self.cfg.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.cfg.max_samples
            && (samples.len() < 5 || start.elapsed() < self.cfg.time_budget)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.total_cmp(b));
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        let result = BenchResult {
            name: name.to_string(),
            mean,
            median: percentile(&samples, 50.0),
            p95: percentile(&samples, 95.0),
            samples,
            elems_per_iter: elems,
        };
        println!("{}", result.report());
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Look up a finished result by exact name.
    pub fn result(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }

    /// If `$BENCH_OUT` is set, append one JSON line per result to that
    /// file (JSONL — every bench target contributes to the same
    /// trajectory file; `scripts/bench.sh` merges it into the
    /// `BENCH_*.json` suite files), preceded by a `meta/kernel_dispatch`
    /// record naming the GEMM kernel path this process resolved
    /// (cpu-feature string).  `bench.sh` lifts the meta record into the
    /// suite file's `dispatch` field so baselines recorded on different
    /// runners never silently compare.
    pub fn flush_jsonl(&self) {
        append_dispatch_meta();
        append_jsonl(&self.results);
    }
}

/// Append the `meta/kernel_dispatch` JSONL record to `$BENCH_OUT`
/// (no-op when unset).  Split out so ad-hoc harnesses that call
/// [`append_jsonl`] directly can stamp their records too.
pub fn append_dispatch_meta() {
    let Ok(path) = std::env::var("BENCH_OUT") else { return };
    if path.is_empty() {
        return;
    }
    use std::io::Write;
    let dispatch = crate::infer::simd::describe();
    match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        Ok(mut f) => {
            let rec = format!("{{\"name\":\"meta/kernel_dispatch\",\"dispatch\":\"{dispatch}\"}}");
            let _ = writeln!(f, "{rec}");
        }
        Err(e) => eprintln!("bench: cannot open BENCH_OUT '{path}': {e}"),
    }
}

/// Append results to `$BENCH_OUT` as JSONL (no-op when unset).  Shared
/// by [`Bench::flush_jsonl`] and harnesses that build [`BenchResult`]s
/// directly (e.g. `bitprune serve`).
pub fn append_jsonl(results: &[BenchResult]) {
    let Ok(path) = std::env::var("BENCH_OUT") else { return };
    if path.is_empty() {
        return;
    }
    use std::io::Write;
    match std::fs::OpenOptions::new().create(true).append(true).open(&path) {
        Ok(mut f) => {
            for r in results {
                let _ = writeln!(f, "{}", r.to_json());
            }
        }
        Err(e) => eprintln!("bench: cannot open BENCH_OUT '{path}': {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_cfg() -> BenchConfig {
        BenchConfig {
            warmup_iters: 1,
            max_samples: 8,
            time_budget: Duration::from_millis(200),
        }
    }

    #[test]
    fn collects_samples_and_stats() {
        let mut b = Bench::with_config(fast_cfg());
        let r = b.run("busy-loop", || {
            let mut acc = 0u64;
            for i in 0..1000 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(r.samples.len() >= 5);
        assert!(r.mean > 0.0);
        assert!(r.median <= r.p95 + 1e-12);
        assert!(r.report().contains("busy-loop"));
    }

    #[test]
    fn throughput_reported() {
        let mut b = Bench::with_config(fast_cfg());
        let r = b.run_elems("tp", 1000.0, || {
            std::hint::black_box((0..1000).sum::<u64>())
        });
        assert!(r.throughput().unwrap() > 0.0);
        assert!(r.report().contains("elem/s"));
    }

    #[test]
    fn results_accumulate() {
        let mut b = Bench::with_config(fast_cfg());
        b.run("a", || 1);
        b.run("b", || 2);
        assert_eq!(b.results().len(), 2);
        assert!(b.result("a").is_some());
        assert!(b.result("zzz").is_none());
    }

    #[test]
    fn from_samples_sorts_and_summarizes() {
        let r = BenchResult::from_samples("lat", vec![3.0, 1.0, 2.0, 4.0], Some(1.0));
        assert_eq!(r.samples, vec![1.0, 2.0, 3.0, 4.0]);
        assert!((r.mean - 2.5).abs() < 1e-12);
        assert!((r.median - 2.5).abs() < 1e-12);
        assert!((r.percentile(0.0) - 1.0).abs() < 1e-12);
        assert!((r.percentile(100.0) - 4.0).abs() < 1e-12);
        assert!(r.report().contains("lat"));
    }

    #[test]
    fn json_record_shape() {
        let r = BenchResult {
            name: "quant/\"odd\"".into(),
            samples: vec![0.5, 1.5],
            mean: 1.0,
            median: 1.0,
            p95: 1.5,
            elems_per_iter: Some(1000.0),
        };
        let j = r.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'), "{j}");
        assert!(j.contains("\"mean_s\":1.000000000"), "{j}");
        assert!(j.contains("\"median_s\":1.000000000"), "{j}");
        assert!(j.contains("\"p50_s\":"), "{j}");
        assert!(j.contains("\"p95_s\":"), "{j}");
        assert!(j.contains("\"p99_s\":"), "{j}");
        assert!(j.contains("\"samples\":2"), "{j}");
        assert!(j.contains("\"elems_per_iter\":1000"), "{j}");
        assert!(j.contains("\"throughput_elems_per_s\":1000.000"), "{j}");
        assert!(j.contains("quant/\\\"odd\\\""), "{j}");
        // No-throughput records serialize nulls.
        let r2 = BenchResult { elems_per_iter: None, ..r };
        assert!(r2.to_json().contains("\"throughput_elems_per_s\":null"));
    }

    #[test]
    fn jsonl_percentiles_share_the_telemetry_histogram() {
        // Identical samples land in one nanosecond bucket, so every
        // histogram-derived percentile must stay inside that bucket's
        // bounds — the bucket-boundary behavior the serve endpoint
        // exhibits, pinned here against the JSONL record.
        use crate::telemetry::{bucket_bounds, bucket_of};
        let s = 1.000e-3; // 1ms -> exactly 1_000_000ns, a bucket lower bound
        let r = BenchResult::from_samples("pin", vec![s; 8], None);
        let h = r.latency_histogram();
        assert_eq!(h.count(), 8);
        let (lo, hi) = bucket_bounds(bucket_of(1_000_000));
        assert!(lo <= 1_000_000 && 1_000_000 < hi);
        for q in [0.50, 0.95, 0.99] {
            let v = h.quantile(q);
            assert!(
                v >= lo as f64 * 1e-9 && v <= hi as f64 * 1e-9,
                "q{q}: {v} outside bucket [{lo}, {hi}]ns"
            );
        }
        // The JSONL record carries those same histogram values.
        let j = r.to_json();
        let field = |key: &str| -> f64 {
            let tail = j.split(&format!("\"{key}\":")).nth(1).unwrap();
            tail.split(&[',', '}'][..]).next().unwrap().parse().unwrap()
        };
        // (to_json prints 9 decimals, so compare at that resolution.)
        assert!((field("p50_s") - h.quantile(0.50)).abs() < 1e-9);
        assert!((field("p95_s") - h.quantile(0.95)).abs() < 1e-9);
        assert!((field("p99_s") - h.quantile(0.99)).abs() < 1e-9);
        // Sample-exact fields are untouched by the histogram.
        assert!((field("median_s") - s).abs() < 1e-12);
    }
}
