//! Bounded little-endian binary I/O shared by every on-disk format in
//! the crate (`checkpoint` BPCK, `deploy::artifact` BPMA).
//!
//! The reading half treats the input as **untrusted**: every length,
//! count and element product in a file is attacker-controlled, so
//!
//! * [`Reader::take`] bounds every read by the bytes actually present
//!   (no `pos + n` overflow — the check is phrased as a subtraction);
//! * the typed vector readers ([`Reader::f32_vec`] & co.) compute the
//!   byte span with `checked_mul` and `take` it **before** allocating,
//!   so a hostile header cannot trigger an OOM-scale
//!   `Vec::with_capacity` or a silent product overflow;
//! * [`Reader::str_u32`] caps name lengths the same way.
//!
//! The writing half is a thin set of `Vec<u8>` extenders mirroring the
//! reader, plus [`crc32`] (IEEE, table-driven, built at compile time)
//! for the per-section checksums of the BPMA artifact format.

use anyhow::{bail, Result};

/// A bounds-checked cursor over untrusted bytes.
pub struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(bytes: &'a [u8]) -> Self {
        Self { bytes, pos: 0 }
    }

    /// Current byte offset (for error context).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Bytes left to read.
    pub fn remaining(&self) -> usize {
        self.bytes.len() - self.pos
    }

    /// Whether the cursor consumed every byte.
    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    /// Take the next `n` bytes. Fails (instead of panicking or
    /// overflowing) when fewer than `n` remain.
    pub fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.remaining() {
            bail!(
                "truncated input: need {n} bytes at offset {}, have {}",
                self.pos,
                self.remaining()
            );
        }
        let s = &self.bytes[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    /// Skip `n` bytes (bounded like [`Self::take`]).
    pub fn skip(&mut self, n: usize) -> Result<()> {
        self.take(n).map(|_| ())
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    /// A `u64` length/count field that must fit in `usize` and is about
    /// to drive a read: validated against the bytes remaining so a
    /// hostile value fails here, not in an allocator.
    pub fn len_u64(&mut self) -> Result<usize> {
        let v = self.u64()?;
        let n = usize::try_from(v).map_err(|_| {
            anyhow::anyhow!("length field {v} does not fit in usize")
        })?;
        if n > self.remaining() {
            bail!(
                "length field {n} at offset {} exceeds the {} bytes remaining",
                self.pos - 8,
                self.remaining()
            );
        }
        Ok(n)
    }

    /// `n` little-endian f32s; the byte span is checked (and consumed)
    /// before the output vector is allocated.
    pub fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let span = checked_span(n, 4)?;
        let s = self.take(span)?;
        Ok(s.chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// `n` little-endian i32s (allocation-bounded like [`Self::f32_vec`]).
    pub fn i32_vec(&mut self, n: usize) -> Result<Vec<i32>> {
        let span = checked_span(n, 4)?;
        let s = self.take(span)?;
        Ok(s.chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// `n` little-endian u32s (allocation-bounded like [`Self::f32_vec`]).
    pub fn u32_vec(&mut self, n: usize) -> Result<Vec<u32>> {
        let span = checked_span(n, 4)?;
        let s = self.take(span)?;
        Ok(s.chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// A `u32` length-prefixed UTF-8 string (the BPCK/BPMA name
    /// encoding). The length is bounded by the bytes present before
    /// anything is copied.
    pub fn str_u32(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        let s = self.take(len)?;
        String::from_utf8(s.to_vec()).map_err(|_| {
            anyhow::anyhow!("string at offset {} is not UTF-8", self.pos - len)
        })
    }
}

/// `count * elem_size` with overflow reported as a parse error.
fn checked_span(count: usize, elem_size: usize) -> Result<usize> {
    count
        .checked_mul(elem_size)
        .ok_or_else(|| anyhow::anyhow!("element count {count} overflows a byte span"))
}

/// Product of untrusted dimensions with overflow reported as an error
/// (`dims.iter().product()` would wrap silently in release builds).
pub fn checked_product(dims: &[usize]) -> Result<usize> {
    dims.iter().try_fold(1usize, |acc, &d| {
        acc.checked_mul(d)
            .ok_or_else(|| anyhow::anyhow!("dimension product overflows: {dims:?}"))
    })
}

// ---------------------------------------------------------------------------
// writer half
// ---------------------------------------------------------------------------

pub fn put_u8(buf: &mut Vec<u8>, v: u8) {
    buf.push(v);
}

pub fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f32(buf: &mut Vec<u8>, v: f32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f32_slice(buf: &mut Vec<u8>, vs: &[f32]) {
    for &v in vs {
        put_f32(buf, v);
    }
}

/// Mirror of [`Reader::str_u32`].
pub fn put_str_u32(buf: &mut Vec<u8>, s: &str) {
    put_u32(buf, s.len() as u32);
    buf.extend_from_slice(s.as_bytes());
}

// ---------------------------------------------------------------------------
// CRC32 (IEEE 802.3, reflected) — the BPMA per-section checksum
// ---------------------------------------------------------------------------

const fn crc32_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0usize;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = crc32_table();

/// CRC32 of `bytes` (IEEE polynomial, the zlib/`cksum -o 3` convention).
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_is_bounded_and_tracks_pos() {
        let mut r = Reader::new(&[1, 2, 3, 4, 5]);
        assert_eq!(r.take(2).unwrap(), &[1, 2]);
        assert_eq!(r.pos(), 2);
        assert_eq!(r.remaining(), 3);
        assert!(r.take(4).is_err());
        // A failed take consumes nothing.
        assert_eq!(r.take(3).unwrap(), &[3, 4, 5]);
        assert!(r.is_empty());
        // usize::MAX must not overflow the bound check.
        let mut r2 = Reader::new(&[0u8; 8]);
        assert!(r2.take(usize::MAX).is_err());
    }

    #[test]
    fn scalar_readers_roundtrip_writers() {
        let mut buf = Vec::new();
        put_u8(&mut buf, 7);
        put_u32(&mut buf, 0xDEAD_BEEF);
        put_u64(&mut buf, u64::MAX - 1);
        put_f32(&mut buf, -1.5);
        put_str_u32(&mut buf, "fc0/w");
        let mut r = Reader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert_eq!(r.str_u32().unwrap(), "fc0/w");
        assert!(r.is_empty());
    }

    #[test]
    fn vec_readers_bound_allocation_by_remaining() {
        // A count field claiming 2^61 elements must fail before any
        // allocation, as must one merely larger than the payload.
        let mut buf = Vec::new();
        put_f32_slice(&mut buf, &[1.0, 2.0, 3.0]);
        let mut r = Reader::new(&buf);
        assert!(r.f32_vec(usize::MAX / 2).is_err());
        assert!(r.f32_vec(4).is_err());
        assert_eq!(r.f32_vec(3).unwrap(), vec![1.0, 2.0, 3.0]);
        let mut r2 = Reader::new(&buf);
        assert!(r2.u32_vec(4).is_err());
        assert!(r2.i32_vec(usize::MAX).is_err());
    }

    #[test]
    fn len_u64_rejects_hostile_lengths() {
        let mut buf = Vec::new();
        put_u64(&mut buf, u64::MAX);
        buf.extend_from_slice(&[0u8; 4]);
        assert!(Reader::new(&buf).len_u64().is_err());
        let mut buf2 = Vec::new();
        put_u64(&mut buf2, 4);
        buf2.extend_from_slice(&[9u8; 4]);
        assert_eq!(Reader::new(&buf2).len_u64().unwrap(), 4);
        // Claims more than remains -> error, not a huge allocation later.
        let mut buf3 = Vec::new();
        put_u64(&mut buf3, 5);
        buf3.extend_from_slice(&[9u8; 4]);
        assert!(Reader::new(&buf3).len_u64().is_err());
    }

    #[test]
    fn checked_product_catches_overflow() {
        assert_eq!(checked_product(&[3, 4, 5]).unwrap(), 60);
        assert_eq!(checked_product(&[]).unwrap(), 1);
        assert_eq!(checked_product(&[7, 0, 9]).unwrap(), 0);
        let big = usize::MAX / 2;
        assert!(checked_product(&[big, 3]).is_err());
        assert!(checked_product(&[big, big, big]).is_err());
    }

    #[test]
    fn str_u32_rejects_bad_utf8_and_truncation() {
        let mut buf = Vec::new();
        put_u32(&mut buf, 2);
        buf.extend_from_slice(&[0xFF, 0xFE]);
        assert!(Reader::new(&buf).str_u32().is_err());
        let mut buf2 = Vec::new();
        put_u32(&mut buf2, 100); // claims 100 bytes, has none
        assert!(Reader::new(&buf2).str_u32().is_err());
    }

    #[test]
    fn crc32_known_vectors() {
        // Standard IEEE test vectors.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
        // Single-bit sensitivity.
        assert_ne!(crc32(b"deploy"), crc32(b"dePloy"));
    }
}
