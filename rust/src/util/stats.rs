//! Small statistics helpers shared by metrics and the bench harness.

/// Online mean/variance (Welford).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile over a sample (linear interpolation). `p` in [0, 100].
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Exponential moving average.
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Self {
        Self { alpha, value: None }
    }

    pub fn push(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => prev + self.alpha * (x - prev),
        };
        self.value = Some(v);
        v
    }

    pub fn value(&self) -> Option<f64> {
        self.value
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        let mean: f64 = xs.iter().sum::<f64>() / xs.len() as f64;
        let var: f64 =
            xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!((w.mean() - mean).abs() < 1e-12);
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn percentile_interp() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&xs, 50.0), 2.5);
    }

    #[test]
    fn ema_converges() {
        let mut e = Ema::new(0.5);
        assert_eq!(e.push(10.0), 10.0);
        let v = e.push(0.0);
        assert_eq!(v, 5.0);
    }
}
