//! Minimal property-testing driver (the offline environment has no
//! proptest crate).
//!
//! `check` runs a property over N seeded random cases; on failure it
//! reports the failing case index and seed so the case can be replayed
//! exactly.  Generators are just closures over [`Rng`]; shrinking is
//! approximated by re-running the failing property with "smaller"
//! parameters when the generator supports a size hint.

use super::rng::Rng;

pub const DEFAULT_CASES: usize = 256;

/// Run `prop` over `cases` random inputs produced by `gen`.
/// Panics with a replayable seed on the first failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base_seed = 0xB17_F00D_u64;
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed:#x}):\n  \
                 input: {input:?}\n  reason: {msg}"
            );
        }
    }
}

/// Assert helper: approximate float equality with context.
pub fn close(a: f64, b: f64, tol: f64, what: &str) -> Result<(), String> {
    if (a - b).abs() <= tol * (1.0 + a.abs().max(b.abs())) {
        Ok(())
    } else {
        Err(format!("{what}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        check("add-commutes", 64, |rng| (rng.uniform(), rng.uniform()), |&(a, b)| {
            close(a + b, b + a, 1e-12, "a+b == b+a")
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails'")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 8, |rng| rng.uniform(), |_| Err("nope".into()));
    }

    #[test]
    fn close_tolerance() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, "x").is_ok());
        assert!(close(1.0, 2.0, 1e-6, "x").is_err());
    }
}
