//! A persistent worker pool for the serving-path GEMM.
//!
//! `IntDense::forward` parallelizes with `std::thread::scope`, which
//! spawns (and joins) fresh OS threads on every call — fine for one-off
//! batch evals, hostile to a serving loop that forwards thousands of
//! micro-batches per second.  [`WorkerPool`] spawns its threads once;
//! [`WorkerPool::run_scoped`] hands them a set of jobs that may borrow
//! from the caller's stack and blocks until every job has finished, so
//! the borrowed data provably outlives the work (the same contract
//! `std::thread::scope` provides, without the per-call spawn/join).
//!
//! Jobs must not call back into `run_scoped` on the same pool: a job
//! waiting on jobs can deadlock once every worker is occupied.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// A queued job plus the completion channel it must signal (`true` if
/// the job ran to completion, `false` if it panicked).
type Job = (Box<dyn FnOnce() + Send + 'static>, Sender<bool>);

pub struct WorkerPool {
    /// `None` only during drop (taking it closes the channel, which
    /// terminates the worker loops).
    tx: Option<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    workers: usize,
}

impl WorkerPool {
    /// Spawn `workers` persistent threads (at least one).
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|i| {
                let rx = Arc::clone(&rx);
                std::thread::Builder::new()
                    .name(format!("bitprune-pool-{i}"))
                    .spawn(move || worker_loop(rx))
                    .expect("spawning worker-pool thread")
            })
            .collect();
        Self { tx: Some(tx), handles, workers }
    }

    /// Pool sized to the machine (`available_parallelism`).
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        Self::new(n)
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run `jobs` on the pool and block until all of them have
    /// completed.  Jobs may borrow data from the caller's stack: because
    /// this method does not return until every job has signalled
    /// completion, those borrows cannot be outlived (the
    /// `std::thread::scope` guarantee).  Panics if any job panicked.
    pub fn run_scoped<'a>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        let njobs = jobs.len();
        if njobs == 0 {
            return;
        }
        let (done_tx, done_rx) = channel::<bool>();
        let tx = self.tx.as_ref().expect("worker pool is shut down");
        for job in jobs {
            // SAFETY: the loop below blocks until every job has sent its
            // completion signal (workers signal even on panic, via
            // catch_unwind), so no job — and no borrow it captured —
            // survives past this call.  Extending the lifetime to
            // 'static is therefore unobservable.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'a>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(job)
            };
            tx.send((job, done_tx.clone()))
                .expect("worker pool channel closed");
        }
        let mut ok = true;
        for _ in 0..njobs {
            // recv cannot Err while we hold `done_tx`; workers always
            // send exactly once per job.
            ok &= done_rx.recv().expect("worker pool completion channel broken");
        }
        assert!(ok, "a worker-pool job panicked");
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<Job>>>) {
    loop {
        // Hold the lock only while waiting for one message; the guard
        // drops at the end of the statement, before the job runs.
        let msg = {
            let guard = match rx.lock() {
                Ok(g) => g,
                Err(_) => return,
            };
            guard.recv()
        };
        let (job, done) = match msg {
            Ok(j) => j,
            Err(_) => return, // pool dropped
        };
        let ok = catch_unwind(AssertUnwindSafe(job)).is_ok();
        let _ = done.send(ok);
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // closes the channel; workers exit their loops
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_jobs_over_borrowed_chunks() {
        let pool = WorkerPool::new(4);
        let mut data = vec![0u64; 1024];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
            .chunks_mut(100)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (i * 100 + j) as u64;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u64);
        }
    }

    #[test]
    fn pool_is_reusable_across_rounds() {
        let pool = WorkerPool::new(2);
        let counter = AtomicUsize::new(0);
        for _ in 0..50 {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(jobs);
        }
        assert_eq!(counter.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn empty_job_list_is_a_noop() {
        let pool = WorkerPool::new(1);
        pool.run_scoped(Vec::new());
    }

    #[test]
    fn at_least_one_worker() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 1);
    }

    #[test]
    fn job_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let boom: Vec<Box<dyn FnOnce() + Send + '_>> =
            vec![Box::new(|| panic!("job boom"))];
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_scoped(boom);
        }));
        assert!(caught.is_err(), "panic should propagate to the caller");
        // The worker that caught the panic keeps serving.
        let flag = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![Box::new(|| {
            flag.store(7, Ordering::Relaxed);
        })];
        pool.run_scoped(jobs);
        assert_eq!(flag.load(Ordering::Relaxed), 7);
    }
}
