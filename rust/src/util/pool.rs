//! A persistent worker pool for the serving-path GEMM.
//!
//! `IntDense::forward` parallelizes with `std::thread::scope`, which
//! spawns (and joins) fresh OS threads on every call — fine for one-off
//! batch evals, hostile to a serving loop that forwards thousands of
//! micro-batches per second.  [`WorkerPool`] spawns its threads once;
//! [`WorkerPool::run_scoped`] hands them a set of jobs that may borrow
//! from the caller's stack and blocks until every job has finished, so
//! the borrowed data provably outlives the work (the same contract
//! `std::thread::scope` provides, without the per-call spawn/join).
//!
//! Failure hardening: a job that panics is caught on the worker
//! (`catch_unwind`) and reported to the dispatcher as a typed
//! [`PoolError`] from [`WorkerPool::try_run_scoped`] — the pool itself
//! is never poisoned and never deadlocks.  A worker *thread* that dies
//! (a panic escaping the catch, or a chaos-injected exit) is respawned
//! with an identical context before the next dispatch, and the wait
//! loop self-heals mid-round: if completions stall, dead workers are
//! replaced and the still-queued jobs drain on the replacements.
//!
//! Jobs must not call back into `run_scoped` on the same pool: a job
//! waiting on jobs can deadlock once every worker is occupied.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// A queued job plus the completion channel it must signal (`true` if
/// the job ran to completion, `false` if it panicked).
type Job = (Box<dyn FnOnce() + Send + 'static>, Sender<bool>);

/// Typed failure from [`WorkerPool::try_run_scoped`].  The pool stays
/// usable after any of these.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PoolError {
    /// `panicked` of the `jobs` dispatched jobs panicked; the rest ran
    /// to completion (every job signalled, so no borrow escaped).
    JobPanicked { panicked: usize, jobs: usize },
}

impl std::fmt::Display for PoolError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::JobPanicked { panicked, jobs } => {
                write!(f, "worker pool: {panicked} of {jobs} job(s) panicked")
            }
        }
    }
}

impl std::error::Error for PoolError {}

/// Everything a worker thread runs with; the pool keeps a copy so dead
/// workers can be respawned with an identical context.
#[derive(Clone)]
struct WorkerCtx {
    rx: Arc<Mutex<Receiver<Job>>>,
    #[cfg(feature = "chaos")]
    chaos: Option<Arc<crate::serve::chaos::Chaos>>,
}

/// How long the completion wait runs before checking for (and
/// replacing) dead workers.  Only paid when a worker actually died
/// mid-round; the healthy path never times out.
const HEAL_INTERVAL: Duration = Duration::from_millis(20);

pub struct WorkerPool {
    /// `None` only during drop (taking it closes the channel, which
    /// terminates the worker loops).
    tx: Option<Sender<Job>>,
    /// Guarded so the dispatcher can swap dead handles for respawns.
    handles: Mutex<Vec<JoinHandle<()>>>,
    ctx: WorkerCtx,
    workers: usize,
    respawns: AtomicU64,
    /// Optional telemetry counter bumped alongside `respawns` (the
    /// serve subsystem publishes it as `pool_respawns_total`).
    respawn_counter: Mutex<Option<Arc<crate::telemetry::Counter>>>,
}

impl WorkerPool {
    /// Spawn `workers` persistent threads (at least one).
    pub fn new(workers: usize) -> Self {
        let (tx, rx) = channel::<Job>();
        let ctx = WorkerCtx {
            rx: Arc::new(Mutex::new(rx)),
            #[cfg(feature = "chaos")]
            chaos: None,
        };
        Self::start(workers, tx, ctx)
    }

    /// [`Self::new`] with a fault injector wired into every worker
    /// (chaos builds only — see `serve::chaos`).
    #[cfg(feature = "chaos")]
    pub fn with_chaos(
        workers: usize,
        chaos: Option<Arc<crate::serve::chaos::Chaos>>,
    ) -> Self {
        let (tx, rx) = channel::<Job>();
        let ctx = WorkerCtx { rx: Arc::new(Mutex::new(rx)), chaos };
        Self::start(workers, tx, ctx)
    }

    fn start(workers: usize, tx: Sender<Job>, ctx: WorkerCtx) -> Self {
        let workers = workers.max(1);
        let handles = (0..workers).map(|i| spawn_worker(i, ctx.clone())).collect();
        Self {
            tx: Some(tx),
            handles: Mutex::new(handles),
            ctx,
            workers,
            respawns: AtomicU64::new(0),
            respawn_counter: Mutex::new(None),
        }
    }

    /// Pool sized to the machine (`available_parallelism`).
    pub fn with_default_size() -> Self {
        Self::new(default_workers())
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// How many dead worker threads have been replaced so far.
    pub fn respawns(&self) -> u64 {
        self.respawns.load(Ordering::Relaxed)
    }

    /// Mirror every future respawn into `counter` (a telemetry handle,
    /// typically registered as `pool_respawns_total`).  The internal
    /// [`Self::respawns`] ledger is unaffected.
    pub fn publish_respawns(&self, counter: Arc<crate::telemetry::Counter>) {
        *self.respawn_counter.lock().unwrap_or_else(|p| p.into_inner()) =
            Some(counter);
    }

    /// Run `jobs` on the pool and block until all of them have
    /// completed.  Jobs may borrow data from the caller's stack: because
    /// this method does not return until every job has signalled
    /// completion, those borrows cannot be outlived (the
    /// `std::thread::scope` guarantee).  Panics if any job panicked —
    /// use [`Self::try_run_scoped`] to handle that as a typed error.
    pub fn run_scoped<'a>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 'a>>) {
        if let Err(e) = self.try_run_scoped(jobs) {
            panic!("{e}");
        }
    }

    /// [`Self::run_scoped`] with job panics surfaced as a typed
    /// [`PoolError`] instead of a propagated panic.  Either way every
    /// job has signalled before this returns, so the scoped-borrow
    /// guarantee is identical.
    pub fn try_run_scoped<'a>(
        &self,
        jobs: Vec<Box<dyn FnOnce() + Send + 'a>>,
    ) -> Result<(), PoolError> {
        let njobs = jobs.len();
        if njobs == 0 {
            return Ok(());
        }
        // Replace any worker that died since the last round *before*
        // queueing: dispatching into a fully-dead pool would strand
        // the jobs (the wait loop below would eventually heal it, but
        // cheaper to not get there).
        self.respawn_dead();
        let (done_tx, done_rx) = channel::<bool>();
        let tx = self.tx.as_ref().expect("worker pool is shut down");
        for job in jobs {
            // SAFETY: the loop below blocks until every job has sent its
            // completion signal (workers signal even on panic, via
            // catch_unwind), so no job — and no borrow it captured —
            // survives past this call.  Extending the lifetime to
            // 'static is therefore unobservable.
            let job: Box<dyn FnOnce() + Send + 'static> = unsafe {
                std::mem::transmute::<
                    Box<dyn FnOnce() + Send + 'a>,
                    Box<dyn FnOnce() + Send + 'static>,
                >(job)
            };
            tx.send((job, done_tx.clone()))
                .expect("worker pool channel closed");
        }
        let mut panicked = 0usize;
        let mut remaining = njobs;
        while remaining > 0 {
            match done_rx.recv_timeout(HEAL_INTERVAL) {
                Ok(ok) => {
                    if !ok {
                        panicked += 1;
                    }
                    remaining -= 1;
                }
                // A stall means a worker died between claiming the
                // round and finishing it, or every worker is dead and
                // jobs sit unclaimed in the channel.  Replacements
                // pick the queued jobs straight back up — the round
                // always completes.
                Err(RecvTimeoutError::Timeout) => self.respawn_dead(),
                // Impossible while we hold `done_tx`.
                Err(RecvTimeoutError::Disconnected) => {
                    unreachable!("worker pool completion channel broken")
                }
            }
        }
        if panicked > 0 {
            Err(PoolError::JobPanicked { panicked, jobs: njobs })
        } else {
            Ok(())
        }
    }

    /// Swap a fresh thread in for every finished (dead) worker.
    fn respawn_dead(&self) {
        let mut handles = self.handles.lock().unwrap_or_else(|p| p.into_inner());
        for (i, h) in handles.iter_mut().enumerate() {
            if h.is_finished() {
                let fresh = spawn_worker(i, self.ctx.clone());
                let dead = std::mem::replace(h, fresh);
                let _ = dead.join();
                self.respawns.fetch_add(1, Ordering::Relaxed);
                if let Some(c) = &*self
                    .respawn_counter
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                {
                    c.inc();
                }
            }
        }
    }
}

pub(crate) fn default_workers() -> usize {
    std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1)
}

fn spawn_worker(i: usize, ctx: WorkerCtx) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("bitprune-pool-{i}"))
        .spawn(move || worker_loop(ctx))
        .expect("spawning worker-pool thread")
}

fn worker_loop(ctx: WorkerCtx) {
    loop {
        // Chaos: a worker may be told to die *between* jobs — never
        // while holding one, so no claimed job is ever lost.
        #[cfg(feature = "chaos")]
        if let Some(c) = &ctx.chaos {
            if c.worker_should_exit() {
                return;
            }
        }
        // Hold the lock only while waiting for one message; the guard
        // drops at the end of the statement, before the job runs.
        let msg = {
            let guard = match ctx.rx.lock() {
                Ok(g) => g,
                Err(_) => return,
            };
            guard.recv()
        };
        let (job, done) = match msg {
            Ok(j) => j,
            Err(_) => return, // pool dropped
        };
        let ok = catch_unwind(AssertUnwindSafe(|| {
            // Chaos: an injected panic *inside* the job boundary —
            // exercises the exact catch/report path a real GEMM
            // kernel panic would take.
            #[cfg(feature = "chaos")]
            if let Some(c) = &ctx.chaos {
                c.maybe_job_panic();
            }
            job()
        }))
        .is_ok();
        let _ = done.send(ok);
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take(); // closes the channel; workers exit their loops
        let mut handles = self.handles.lock().unwrap_or_else(|p| p.into_inner());
        for h in handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_jobs_over_borrowed_chunks() {
        let pool = WorkerPool::new(4);
        let mut data = vec![0u64; 1024];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
            .chunks_mut(100)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (i * 100 + j) as u64;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u64);
        }
    }

    #[test]
    fn pool_is_reusable_across_rounds() {
        let pool = WorkerPool::new(2);
        let counter = AtomicUsize::new(0);
        for _ in 0..50 {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|_| {
                    Box::new(|| {
                        counter.fetch_add(1, Ordering::Relaxed);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.run_scoped(jobs);
        }
        assert_eq!(counter.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn empty_job_list_is_a_noop() {
        let pool = WorkerPool::new(1);
        pool.run_scoped(Vec::new());
        assert_eq!(pool.try_run_scoped(Vec::new()), Ok(()));
    }

    #[test]
    fn at_least_one_worker() {
        let pool = WorkerPool::new(0);
        assert_eq!(pool.workers(), 1);
    }

    #[test]
    fn job_panic_propagates_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let boom: Vec<Box<dyn FnOnce() + Send + '_>> =
            vec![Box::new(|| panic!("job boom"))];
        let caught = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_scoped(boom);
        }));
        assert!(caught.is_err(), "panic should propagate to the caller");
        // The worker that caught the panic keeps serving.
        let flag = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![Box::new(|| {
            flag.store(7, Ordering::Relaxed);
        })];
        pool.run_scoped(jobs);
        assert_eq!(flag.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn try_run_scoped_reports_typed_error_without_poisoning() {
        let pool = WorkerPool::new(2);
        let boom: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
            Box::new(|| panic!("boom 1")),
            Box::new(|| {}),
            Box::new(|| panic!("boom 2")),
        ];
        assert_eq!(
            pool.try_run_scoped(boom),
            Err(PoolError::JobPanicked { panicked: 2, jobs: 3 })
        );
        // No poison, no deadlock: the next round is clean.
        let counter = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|_| {
                Box::new(|| {
                    counter.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        assert_eq!(pool.try_run_scoped(jobs), Ok(()));
        assert_eq!(counter.load(Ordering::Relaxed), 8);
    }

    #[test]
    fn panic_mid_batch_leaves_siblings_and_results_intact() {
        // A panic in one job of a data-parallel batch must not corrupt
        // or skip the sibling jobs: the surviving chunks are
        // bit-identical to a clean run, round after round.
        let pool = WorkerPool::new(3);
        for round in 0..10 {
            let mut data = vec![0u64; 600];
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
                .chunks_mut(100)
                .enumerate()
                .map(|(i, chunk)| {
                    Box::new(move || {
                        if i == 2 {
                            panic!("mid-batch boom");
                        }
                        for (j, v) in chunk.iter_mut().enumerate() {
                            *v = (i * 100 + j) as u64;
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            assert_eq!(
                pool.try_run_scoped(jobs),
                Err(PoolError::JobPanicked { panicked: 1, jobs: 6 }),
                "round {round}"
            );
            for (i, v) in data.iter().enumerate() {
                let chunk = i / 100;
                let want = if chunk == 2 { 0 } else { i as u64 };
                assert_eq!(*v, want, "round {round}: index {i}");
            }
        }
        // Healthy rounds after all that are bit-identical to a fresh
        // pool's output.
        let mut data = vec![0u64; 600];
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = data
            .chunks_mut(100)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (i * 100 + j) as u64;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        for (i, v) in data.iter().enumerate() {
            assert_eq!(*v, i as u64);
        }
        assert_eq!(pool.respawns(), 0, "caught panics never kill workers");
    }

    #[test]
    fn many_panics_across_rounds_never_deadlock() {
        // Regression guard for the old assert!-based dispatcher: a
        // panic on every round, interleaved with healthy jobs, must
        // neither hang run_scoped nor wedge the queue.
        let pool = WorkerPool::new(2);
        let done = AtomicUsize::new(0);
        for _ in 0..25 {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![
                Box::new(|| {
                    done.fetch_add(1, Ordering::Relaxed);
                }),
                Box::new(|| panic!("round boom")),
                Box::new(|| {
                    done.fetch_add(1, Ordering::Relaxed);
                }),
            ];
            let err = pool.try_run_scoped(jobs).unwrap_err();
            assert_eq!(err, PoolError::JobPanicked { panicked: 1, jobs: 3 });
        }
        assert_eq!(done.load(Ordering::Relaxed), 50);
    }
}
