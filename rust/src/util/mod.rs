//! Zero-dependency support utilities: JSON, CLI args, simple RNG,
//! property-test driver, math helpers.
//!
//! The build is fully offline with only `xla` and `anyhow` available, so
//! these substrates are implemented in-tree (DESIGN.md §5).

pub mod args;
pub mod bench;
pub mod binio;
pub mod json;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod stats;
