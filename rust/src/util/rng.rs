//! Deterministic RNG: splitmix64 seeding + xoshiro256** core, plus
//! distribution helpers (uniform, normal, permutation).
//!
//! Every stochastic component of the coordinator (dataset synthesis,
//! shuffling, property tests) draws from this generator so runs are
//! exactly reproducible from a single u64 seed.

/// xoshiro256** PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal from the Box-Muller pair.
    spare_normal: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, spare_normal: None }
    }

    /// Derive an independent stream (for per-worker / per-epoch streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.uniform() * (hi - lo)
    }

    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        self.range_f64(lo as f64, hi as f64) as f32
    }

    /// Uniform integer in [0, n). Uses rejection to stay unbiased.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    pub fn below_usize(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        // u1 in (0,1] to avoid ln(0).
        let u1 = 1.0 - self.uniform();
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare_normal = Some(r * theta.sin());
        r * theta.cos()
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        (self.normal() as f32) * std + mean
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below_usize(i + 1);
            items.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        self.shuffle(&mut v);
        v
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.uniform() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut rng = Rng::new(7);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = rng.uniform();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[rng.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(11);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let v = rng.normal();
            sum += v;
            sq += v * v;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_permutation() {
        let mut rng = Rng::new(5);
        let p = rng.permutation(100);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(9);
        let mut f1 = base.fork(1);
        let mut f2 = base.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }
}
