//! Minimal JSON parser/writer.
//!
//! The build environment has no serde/serde_json, so artifact metadata
//! (python/compile/aot.py writes `<tag>_meta.json`) and report emission
//! go through this small, well-tested implementation.  It supports the
//! full JSON grammar except `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value. Objects use BTreeMap for deterministic iteration.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m
                .get(key)
                .ok_or_else(|| anyhow!("missing JSON key '{key}'")),
            _ => bail!("expected object while looking up '{key}'"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let n = self.as_f64()?;
        if n < 0.0 || n.fract() != 0.0 {
            bail!("expected non-negative integer, got {n}");
        }
        Ok(n as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    pub fn str_vec(&self) -> Result<Vec<String>> {
        self.as_arr()?
            .iter()
            .map(|v| v.as_str().map(str::to_string))
            .collect()
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- writer --------------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

// Convenience constructors for report emission.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

// ---------------------------------------------------------------------------
// parser
// ---------------------------------------------------------------------------

pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        bail!("trailing characters at offset {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow!("unexpected end of JSON at {}", self.pos))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        let got = self.bump()?;
        if got != b {
            bail!(
                "expected '{}' at offset {}, got '{}'",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected JSON byte {other:?} at {}", self.pos),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(m)),
                c => bail!("expected ',' or '}}' at {}, got '{}'", self.pos - 1, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(v)),
                c => bail!("expected ',' or ']' at {}, got '{}'", self.pos - 1, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.bump()?;
            match b {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let h = self.bump()?;
                            code = code * 16
                                + (h as char)
                                    .to_digit(16)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?;
                        }
                        out.push(
                            char::from_u32(code)
                                .ok_or_else(|| anyhow!("bad \\u codepoint {code}"))?,
                        );
                    }
                    c => bail!("bad escape '\\{}'", c as char),
                },
                c => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c)?;
                        let end = start + len;
                        if end > self.bytes.len() {
                            bail!("truncated UTF-8 sequence");
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|e| anyhow!("bad UTF-8 in string: {e}"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text
            .parse()
            .map_err(|e| anyhow!("bad number '{text}': {e}"))?;
        Ok(Json::Num(n))
    }
}

fn utf8_len(first: u8) -> Result<usize> {
    match first {
        0xC0..=0xDF => Ok(2),
        0xE0..=0xEF => Ok(3),
        0xF0..=0xF7 => Ok(4),
        _ => bail!("invalid UTF-8 lead byte {first:#x}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
        assert_eq!(a[2].get("b").unwrap().as_str().unwrap(), "c");
        assert_eq!(v.get("d").unwrap(), &Json::Null);
    }

    #[test]
    fn parse_unicode_and_escapes() {
        let v = parse(r#""café ☕""#).unwrap();
        assert_eq!(v, Json::Str("café ☕".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,"s",false,null],"n":{"x":-3}}"#;
        let v = parse(src).unwrap();
        let emitted = v.to_string();
        assert_eq!(parse(&emitted).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn typed_accessors() {
        let v = parse(r#"{"n": 4, "s": ["a","b"], "u": [1,2,3]}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_usize().unwrap(), 4);
        assert_eq!(v.get("s").unwrap().str_vec().unwrap(), vec!["a", "b"]);
        assert_eq!(v.get("u").unwrap().usize_vec().unwrap(), vec![1, 2, 3]);
        assert!(v.get("missing").is_err());
        assert!(v.get("n").unwrap().as_str().is_err());
    }
}
