//! Tiny CLI argument parser (no clap in the offline environment).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed getters and an auto-generated usage string.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Option names that are known to take values (needed to
    /// disambiguate `--key value` from `--flag positional`).
    value_opts: Vec<String>,
}

impl Args {
    /// Parse raw arguments. `value_opts` lists options that consume a
    /// following value when written in the space-separated form.
    pub fn parse<I: IntoIterator<Item = String>>(raw: I, value_opts: &[&str]) -> Result<Self> {
        let mut out = Args {
            value_opts: value_opts.iter().map(|s| s.to_string()).collect(),
            ..Default::default()
        };
        let mut iter = raw.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if out.value_opts.iter().any(|o| o == name) {
                    let v = iter
                        .next()
                        .ok_or_else(|| anyhow!("option --{name} expects a value"))?;
                    out.options.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    pub fn from_env(value_opts: &[&str]) -> Result<Self> {
        Self::parse(std::env::args().skip(1), value_opts)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    pub fn pos(&self, idx: usize) -> Option<&str> {
        self.positional.get(idx).map(String::as_str)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name) || self.options.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects a number, got '{v}': {e}")),
        }
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects an integer, got '{v}': {e}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow!("--{name} expects an integer, got '{v}': {e}")),
        }
    }

    /// Comma-separated list of floats (e.g. `--gammas 0.5,1,2.5`).
    pub fn get_f64_list(&self, name: &str, default: &[f64]) -> Result<Vec<f64>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|p| {
                    p.trim()
                        .parse()
                        .map_err(|e| anyhow!("--{name} element '{p}': {e}"))
                })
                .collect(),
        }
    }

    pub fn get_str_list(&self, name: &str, default: &[&str]) -> Vec<String> {
        match self.get(name) {
            None => default.iter().map(|s| s.to_string()).collect(),
            Some(v) => v.split(',').map(|p| p.trim().to_string()).collect(),
        }
    }

    /// Error on unknown flags to catch typos.
    pub fn check_known(&self, known_flags: &[&str]) -> Result<()> {
        for f in &self.flags {
            if !known_flags.contains(&f.as_str()) && !self.value_opts.iter().any(|o| o == f) {
                bail!("unknown flag --{f}");
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], value_opts: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), value_opts).unwrap()
    }

    #[test]
    fn positional_and_flags() {
        let a = parse(&["train", "--verbose", "x"], &[]);
        assert_eq!(a.pos(0), Some("train"));
        assert_eq!(a.pos(1), Some("x"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn value_options_both_forms() {
        let a = parse(&["--steps", "100", "--lr=0.1"], &["steps", "lr"]);
        assert_eq!(a.get_usize("steps", 0).unwrap(), 100);
        assert_eq!(a.get_f64("lr", 0.0).unwrap(), 0.1);
    }

    #[test]
    fn list_parsing() {
        let a = parse(&["--gammas=0.5,1,2.5"], &["gammas"]);
        assert_eq!(a.get_f64_list("gammas", &[]).unwrap(), vec![0.5, 1.0, 2.5]);
        let b = parse(&[], &["gammas"]);
        assert_eq!(b.get_f64_list("gammas", &[9.0]).unwrap(), vec![9.0]);
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(vec!["--steps".to_string()], &["steps"]).is_err());
    }

    #[test]
    fn bad_number_is_error() {
        let a = parse(&["--steps=abc"], &["steps"]);
        assert!(a.get_usize("steps", 0).is_err());
    }

    #[test]
    fn unknown_flag_detection() {
        let a = parse(&["--vrebose"], &[]);
        assert!(a.check_known(&["verbose"]).is_err());
        let b = parse(&["--verbose"], &[]);
        assert!(b.check_known(&["verbose"]).is_ok());
    }
}
