//! Live telemetry: lock-free metrics core, a named-metric registry, a
//! Prometheus-style scrape endpoint, and a structured JSONL event trace.
//!
//! The paper's argument is that quantization cost must be *quantified*
//! (bits, MACs, footprint) to be traded against accuracy; this module is
//! the serving-side analogue — you cannot steer a low-bit fleet you cannot
//! measure. Three primitives, all safe to hammer from many threads:
//!
//! * [`Counter`] — monotonically increasing `AtomicU64`.
//! * [`Gauge`] — last-write-wins `f64` (bit-cast into an `AtomicU64`).
//! * [`Histogram`] — fixed log-bucket histogram over `u64` units with
//!   exact-by-construction bucket placement and interpolated p50/p95/p99
//!   extraction. Latency histograms record nanoseconds and carry a
//!   `scale` (1e-9) so rendered quantiles read in seconds.
//!
//! A [`Registry`] names metrics (with `{key="value"}` labels), renders a
//! Prometheus text exposition and a JSON snapshot (via [`crate::util::json`]),
//! and is served over HTTP by [`MetricsServer`] (`GET /metrics`,
//! `GET /metrics.json`) on a plain `std::net::TcpListener` — no external
//! dependencies. [`TraceWriter`] appends typed lifecycle events
//! (admit/shed/batch/swap/promote/rollback) as JSONL with monotonic
//! microsecond timestamps; `scripts/trace_summarize.py` consumes them.
//!
//! Recording is wait-free (a handful of `Relaxed` atomic RMWs); rendering
//! and quantile extraction allocate and are meant for scrape paths only.

use std::fmt::Write as _;
use std::io::{Read as _, Write as _};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

// ---------------------------------------------------------------------------
// Metric primitives
// ---------------------------------------------------------------------------

/// Monotonically increasing event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins instantaneous value (queue depth, agreement ratio, ...).
///
/// Stores the `f64` bit pattern in an `AtomicU64` so readers never see a
/// torn value.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Self {
        Gauge(AtomicU64::new(0f64.to_bits()))
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Number of histogram buckets: 4 exact unit buckets (0..4) plus 4
/// sub-buckets per power of two up to `u64::MAX` (exponents 2..=63).
pub const HIST_BUCKETS: usize = 252;

/// Bucket index for a raw value. Values below 4 get exact unit buckets;
/// above that each power-of-two octave is split into 4 sub-buckets keyed
/// by the two bits below the leading one, so relative bucket width is at
/// most 25% everywhere.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    if v < 4 {
        return v as usize;
    }
    let exp = 63 - v.leading_zeros() as usize; // >= 2
    let sub = ((v >> (exp - 2)) & 3) as usize;
    exp * 4 + sub - 4
}

/// Half-open raw-unit range `[lo, hi)` covered by bucket `idx`. The top
/// bucket saturates `hi` at `u64::MAX`.
pub fn bucket_bounds(idx: usize) -> (u64, u64) {
    assert!(idx < HIST_BUCKETS, "bucket index {idx} out of range");
    if idx < 4 {
        return (idx as u64, idx as u64 + 1);
    }
    let exp = (idx + 4) / 4;
    let sub = (idx + 4) % 4;
    let width = 1u64 << (exp - 2);
    let lo = ((4 + sub) as u64) << (exp - 2);
    (lo, lo.saturating_add(width))
}

/// Fixed log-bucket histogram over `u64` units.
///
/// Recording is a pair of relaxed `fetch_add`s — no locks, no allocation.
/// `scale` converts raw units to display units at read time (latency
/// histograms record nanoseconds with `scale = 1e-9` so quantiles and
/// sums render in seconds; size histograms use the default scale of 1).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    scale: f64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::with_scale(1.0)
    }

    pub fn with_scale(scale: f64) -> Self {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            scale,
        }
    }

    /// Record one raw-unit observation.
    #[inline]
    pub fn observe(&self, v: u64) {
        self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record a duration in seconds as integer nanoseconds. Pair with
    /// `with_scale(1e-9)` so rendered values read back in seconds.
    #[inline]
    pub fn observe_secs(&self, secs: f64) {
        self.observe((secs.max(0.0) * 1e9) as u64);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of observations in display units (`raw_sum * scale`).
    pub fn sum(&self) -> f64 {
        self.sum.load(Ordering::Relaxed) as f64 * self.scale
    }

    pub fn scale(&self) -> f64 {
        self.scale
    }

    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() / n as f64
        }
    }

    /// Quantile `q` in `[0, 1]`, in display units. Walks cumulative bucket
    /// counts to the target rank `max(1, ceil... q*n)` and interpolates
    /// linearly inside the landing bucket; exact for values < 4 raw units
    /// and within one sub-bucket (<= 25% relative) everywhere else.
    /// Returns 0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        self.quantile_raw(q) * self.scale
    }

    fn quantile_raw(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * n as f64).max(1.0);
        let mut cum = 0u64;
        for idx in 0..HIST_BUCKETS {
            let c = self.buckets[idx].load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if (cum + c) as f64 >= target {
                let (lo, hi) = bucket_bounds(idx);
                let frac = (target - cum as f64) / c as f64;
                return lo as f64 + (hi - lo) as f64 * frac;
            }
            cum += c;
        }
        // Rounding pushed the target past the last populated bucket.
        bucket_bounds(HIST_BUCKETS - 1).1 as f64
    }
}

// ---------------------------------------------------------------------------
// Registry
// ---------------------------------------------------------------------------

#[derive(Debug, Clone)]
enum Kind {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

impl Kind {
    fn type_name(&self) -> &'static str {
        match self {
            Kind::Counter(_) => "counter",
            Kind::Gauge(_) => "gauge",
            Kind::Histogram(_) => "histogram",
        }
    }
}

#[derive(Debug)]
struct Entry {
    name: String,
    labels: Vec<(String, String)>,
    kind: Kind,
}

/// Point-in-time value of one registered metric.
#[derive(Debug, Clone, PartialEq)]
pub enum SampleValue {
    Counter(u64),
    Gauge(f64),
    /// Histogram summary in display units (`sum`, quantiles scaled).
    Histogram {
        count: u64,
        sum: f64,
        p50: f64,
        p95: f64,
        p99: f64,
    },
}

/// One metric in a [`Registry::snapshot`].
#[derive(Debug, Clone)]
pub struct Sample {
    pub name: String,
    pub labels: Vec<(String, String)>,
    pub value: SampleValue,
}

/// Named metrics with optional `{key="value"}` labels.
///
/// `counter`/`gauge`/`histogram` are get-or-register: the same
/// (name, labels) pair always returns the same `Arc` handle, so callers
/// keep cheap clones on their hot paths and the registry is only locked
/// at registration and scrape time. Registering the same (name, labels)
/// under a different metric type panics — that is always a bug.
#[derive(Debug, Default)]
pub struct Registry {
    inner: Mutex<Vec<Entry>>,
}

fn labels_eq(have: &[(String, String)], want: &[(&str, &str)]) -> bool {
    have.len() == want.len()
        && have
            .iter()
            .zip(want)
            .all(|((hk, hv), (wk, wv))| hk == wk && hv == wv)
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Counter> {
        match self.get_or_register(name, labels, || Kind::Counter(Arc::new(Counter::new()))) {
            Kind::Counter(c) => c,
            other => panic!(
                "telemetry: '{name}' already registered as {}",
                other.type_name()
            ),
        }
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Arc<Gauge> {
        match self.get_or_register(name, labels, || Kind::Gauge(Arc::new(Gauge::new()))) {
            Kind::Gauge(g) => g,
            other => panic!(
                "telemetry: '{name}' already registered as {}",
                other.type_name()
            ),
        }
    }

    /// Register (or fetch) a histogram. `scale` applies on the *first*
    /// registration; later fetches reuse the existing histogram.
    pub fn histogram(&self, name: &str, labels: &[(&str, &str)], scale: f64) -> Arc<Histogram> {
        match self.get_or_register(name, labels, || {
            Kind::Histogram(Arc::new(Histogram::with_scale(scale)))
        }) {
            Kind::Histogram(h) => h,
            other => panic!(
                "telemetry: '{name}' already registered as {}",
                other.type_name()
            ),
        }
    }

    fn get_or_register(
        &self,
        name: &str,
        labels: &[(&str, &str)],
        make: impl FnOnce() -> Kind,
    ) -> Kind {
        let mut inner = self.inner.lock().unwrap();
        if let Some(e) = inner
            .iter()
            .find(|e| e.name == name && labels_eq(&e.labels, labels))
        {
            return e.kind.clone();
        }
        let kind = make();
        inner.push(Entry {
            name: name.to_string(),
            labels: labels
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
            kind: kind.clone(),
        });
        kind
    }

    /// Point-in-time snapshot of every registered metric, sorted by
    /// (name, labels) for deterministic rendering.
    pub fn snapshot(&self) -> Vec<Sample> {
        let inner = self.inner.lock().unwrap();
        let mut out: Vec<Sample> = inner
            .iter()
            .map(|e| Sample {
                name: e.name.clone(),
                labels: e.labels.clone(),
                value: match &e.kind {
                    Kind::Counter(c) => SampleValue::Counter(c.get()),
                    Kind::Gauge(g) => SampleValue::Gauge(g.get()),
                    Kind::Histogram(h) => SampleValue::Histogram {
                        count: h.count(),
                        sum: h.sum(),
                        p50: h.quantile(0.50),
                        p95: h.quantile(0.95),
                        p99: h.quantile(0.99),
                    },
                },
            })
            .collect();
        out.sort_by(|a, b| (&a.name, &a.labels).cmp(&(&b.name, &b.labels)));
        out
    }

    /// Prometheus text exposition. Histograms render as summaries
    /// (`{quantile="0.5"}` series plus `_sum`/`_count`) rather than 252
    /// `_bucket` lines.
    pub fn render_prometheus(&self) -> String {
        let samples = self.snapshot();
        let mut out = String::new();
        let mut last_name = "";
        for s in &samples {
            if s.name != last_name {
                let ty = match s.value {
                    SampleValue::Counter(_) => "counter",
                    SampleValue::Gauge(_) => "gauge",
                    SampleValue::Histogram { .. } => "summary",
                };
                let _ = writeln!(out, "# TYPE {} {}", s.name, ty);
            }
            match &s.value {
                SampleValue::Counter(v) => {
                    let _ = writeln!(out, "{}{} {}", s.name, prom_labels(&s.labels, None), v);
                }
                SampleValue::Gauge(v) => {
                    let _ = writeln!(out, "{}{} {}", s.name, prom_labels(&s.labels, None), v);
                }
                SampleValue::Histogram {
                    count,
                    sum,
                    p50,
                    p95,
                    p99,
                } => {
                    for (q, v) in [("0.5", p50), ("0.95", p95), ("0.99", p99)] {
                        let _ = writeln!(
                            out,
                            "{}{} {}",
                            s.name,
                            prom_labels(&s.labels, Some(q)),
                            v
                        );
                    }
                    let _ =
                        writeln!(out, "{}_sum{} {}", s.name, prom_labels(&s.labels, None), sum);
                    let _ = writeln!(
                        out,
                        "{}_count{} {}",
                        s.name,
                        prom_labels(&s.labels, None),
                        count
                    );
                }
            }
            last_name = &s.name;
        }
        out
    }

    /// JSON snapshot: `{"metrics": [{name, labels, type, ...}, ...]}`.
    pub fn render_json(&self) -> Json {
        let metrics = self.snapshot().into_iter().map(|smp| {
            let labels = Json::Obj(
                smp.labels
                    .iter()
                    .map(|(k, v)| (k.clone(), json::s(v)))
                    .collect(),
            );
            let mut pairs = vec![("name", json::s(&smp.name)), ("labels", labels)];
            match smp.value {
                SampleValue::Counter(v) => {
                    pairs.push(("type", json::s("counter")));
                    pairs.push(("value", json::num(v as f64)));
                }
                SampleValue::Gauge(v) => {
                    pairs.push(("type", json::s("gauge")));
                    pairs.push(("value", json::num(v)));
                }
                SampleValue::Histogram {
                    count,
                    sum,
                    p50,
                    p95,
                    p99,
                } => {
                    pairs.push(("type", json::s("histogram")));
                    pairs.push(("count", json::num(count as f64)));
                    pairs.push(("sum", json::num(sum)));
                    pairs.push(("p50", json::num(p50)));
                    pairs.push(("p95", json::num(p95)));
                    pairs.push(("p99", json::num(p99)));
                }
            }
            json::obj(pairs)
        });
        json::obj(vec![("metrics", json::arr(metrics))])
    }
}

/// Escape a label value per the Prometheus exposition rules.
fn prom_escape(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn prom_labels(labels: &[(String, String)], quantile: Option<&str>) -> String {
    if labels.is_empty() && quantile.is_none() {
        return String::new();
    }
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", prom_escape(v)))
        .collect();
    if let Some(q) = quantile {
        parts.push(format!("quantile=\"{q}\""));
    }
    format!("{{{}}}", parts.join(","))
}

// ---------------------------------------------------------------------------
// HTTP scrape endpoint
// ---------------------------------------------------------------------------

/// Minimal HTTP/1.1 scrape endpoint over `std::net::TcpListener`.
///
/// Routes: `GET /metrics` (Prometheus text) and `GET /metrics.json`
/// (JSON snapshot). One request per connection, `Connection: close`,
/// explicit `Content-Length`. The accept loop polls a non-blocking
/// listener every 10ms so `shutdown()` returns promptly.
pub struct MetricsServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9188`; port 0 picks a free port) and
    /// serve `registry` until shutdown/drop.
    pub fn start(addr: &str, registry: Arc<Registry>) -> Result<Self> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("metrics: bind {addr}"))?;
        let local = listener.local_addr().context("metrics: local_addr")?;
        listener
            .set_nonblocking(true)
            .context("metrics: set_nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("bitprune-metrics".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => serve_conn(stream, &registry),
                        Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(10));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(10)),
                    }
                }
            })
            .context("metrics: spawn")?;
        Ok(MetricsServer {
            addr: local,
            stop,
            handle: Some(handle),
        })
    }

    /// Actual bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn serve_conn(mut stream: TcpStream, registry: &Registry) {
    let _ = stream.set_read_timeout(Some(Duration::from_millis(500)));
    let _ = stream.set_write_timeout(Some(Duration::from_secs(5)));
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    // Read until end of request headers (we ignore any body).
    loop {
        match stream.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => {
                buf.extend_from_slice(&chunk[..n]);
                if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > 8192 {
                    break;
                }
            }
            Err(_) => break,
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let mut parts = head.lines().next().unwrap_or("").split_whitespace();
    let method = parts.next().unwrap_or("");
    let raw_path = parts.next().unwrap_or("");
    let path = raw_path.split('?').next().unwrap_or("");
    let (status, ctype, body) = match (method, path) {
        ("GET", "/metrics") => (
            "200 OK",
            "text/plain; version=0.0.4",
            registry.render_prometheus(),
        ),
        ("GET", "/metrics.json") => (
            "200 OK",
            "application/json",
            registry.render_json().to_string(),
        ),
        ("GET", _) => ("404 Not Found", "text/plain", "not found\n".to_string()),
        _ => (
            "405 Method Not Allowed",
            "text/plain",
            "method not allowed\n".to_string(),
        ),
    };
    let _ = write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    let _ = stream.write_all(body.as_bytes());
    let _ = stream.flush();
}

/// One-shot HTTP GET against a [`MetricsServer`]-style endpoint; returns
/// the response body. Used by `bitprune metrics` and the endpoint tests.
pub fn http_get(addr: &str, path: &str) -> Result<String> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("metrics: connect {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    write!(
        stream,
        "GET {path} HTTP/1.1\r\nHost: {addr}\r\nConnection: close\r\n\r\n"
    )?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)?;
    let (head, body) = resp
        .split_once("\r\n\r\n")
        .context("metrics: malformed HTTP response")?;
    let status_line = head.lines().next().unwrap_or("");
    if !status_line.contains(" 200 ") {
        bail!("metrics: GET {path} -> {status_line}");
    }
    Ok(body.to_string())
}

// ---------------------------------------------------------------------------
// JSONL event trace
// ---------------------------------------------------------------------------

/// A typed trace field value.
#[derive(Debug, Clone)]
pub enum Tv<'a> {
    U(u64),
    F(f64),
    S(&'a str),
    B(bool),
}

/// Append-only JSONL event trace with monotonic microsecond timestamps.
///
/// Each line is a flat JSON object: `{"event": "...", "t_us": N, ...}`.
/// Events are serialized under a mutex through a `BufWriter`; `emit` is
/// intended for lifecycle edges (admit/shed/batch/swap/promote/rollback),
/// not per-MAC hot paths, and tracing is opt-in via `--trace-out`.
pub struct TraceWriter {
    out: Mutex<std::io::BufWriter<std::fs::File>>,
    origin: Instant,
}

impl TraceWriter {
    pub fn create(path: &Path) -> Result<Self> {
        let file = std::fs::File::create(path)
            .with_context(|| format!("trace: create {}", path.display()))?;
        Ok(TraceWriter {
            out: Mutex::new(std::io::BufWriter::new(file)),
            origin: Instant::now(),
        })
    }

    /// Microseconds since this writer was created.
    pub fn now_us(&self) -> u64 {
        self.origin.elapsed().as_micros() as u64
    }

    pub fn emit(&self, event: &str, fields: &[(&str, Tv)]) {
        let mut pairs: Vec<(&str, Json)> = Vec::with_capacity(fields.len() + 2);
        pairs.push(("event", json::s(event)));
        pairs.push(("t_us", json::num(self.now_us() as f64)));
        for (k, v) in fields {
            let jv = match v {
                Tv::U(n) => json::num(*n as f64),
                Tv::F(x) => json::num(*x),
                Tv::S(s) => json::s(s),
                Tv::B(b) => Json::Bool(*b),
            };
            pairs.push((k, jv));
        }
        let line = json::obj(pairs).to_string();
        let mut out = self.out.lock().unwrap();
        let _ = out.write_all(line.as_bytes());
        let _ = out.write_all(b"\n");
    }

    pub fn flush(&self) {
        let _ = self.out.lock().unwrap().flush();
    }
}

impl Drop for TraceWriter {
    fn drop(&mut self) {
        self.flush();
    }
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries_are_pinned() {
        // Exact unit buckets below 4.
        for v in 0u64..4 {
            assert_eq!(bucket_of(v), v as usize);
            assert_eq!(bucket_bounds(v as usize), (v, v + 1));
        }
        // First octaves: 4 sub-buckets per power of two.
        let pins: &[(u64, usize)] = &[
            (4, 4),
            (5, 5),
            (6, 6),
            (7, 7),
            (8, 8),
            (9, 8),
            (10, 9),
            (11, 9),
            (12, 10),
            (15, 11),
            (16, 12),
            (19, 12),
            (20, 13),
            (1 << 20, 4 * 20 - 4),
        ];
        for &(v, idx) in pins {
            assert_eq!(bucket_of(v), idx, "bucket_of({v})");
        }
        assert_eq!(bucket_bounds(8), (8, 10));
        assert_eq!(bucket_bounds(12), (16, 20));
        // Top bucket saturates rather than overflowing.
        assert_eq!(bucket_of(u64::MAX), HIST_BUCKETS - 1);
        let (lo, hi) = bucket_bounds(HIST_BUCKETS - 1);
        assert!(lo < hi && hi == u64::MAX);
    }

    #[test]
    fn every_value_lands_inside_its_bucket_bounds() {
        let mut vals: Vec<u64> = (0..200).collect();
        for e in 2..63 {
            let b = 1u64 << e;
            vals.extend_from_slice(&[b - 1, b, b + 1, b + (b >> 1)]);
        }
        vals.push(u64::MAX);
        for v in vals {
            let idx = bucket_of(v);
            let (lo, hi) = bucket_bounds(idx);
            assert!(
                lo <= v && (v < hi || hi == u64::MAX),
                "v={v} idx={idx} bounds=({lo},{hi})"
            );
        }
    }

    #[test]
    fn quantiles_interpolate_within_buckets() {
        let h = Histogram::new();
        for v in 1..=100u64 {
            h.observe(v);
        }
        assert_eq!(h.count(), 100);
        assert!((h.sum() - 5050.0).abs() < 1e-9);
        // p50 rank lands among values ~50; bucket [48,56) interpolated.
        let p50 = h.quantile(0.50);
        assert!((45.0..=56.0).contains(&p50), "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!((90.0..=104.0).contains(&p99), "p99={p99}");
        // Quantiles are monotone in q.
        let qs: Vec<f64> = [0.0, 0.25, 0.5, 0.75, 0.95, 0.99, 1.0]
            .iter()
            .map(|&q| h.quantile(q))
            .collect();
        assert!(qs.windows(2).all(|w| w[0] <= w[1]), "{qs:?}");
        // Empty histogram reports zeros.
        let empty = Histogram::new();
        assert_eq!(empty.quantile(0.99), 0.0);
        assert_eq!(empty.count(), 0);
    }

    #[test]
    fn single_bucket_quantiles_stay_in_bounds() {
        let h = Histogram::new();
        for _ in 0..1000 {
            h.observe(42);
        }
        let (lo, hi) = bucket_bounds(bucket_of(42));
        for q in [0.01, 0.5, 0.99, 1.0] {
            let v = h.quantile(q);
            assert!(
                v >= lo as f64 && v <= hi as f64,
                "q={q} v={v} bounds=({lo},{hi})"
            );
        }
    }

    #[test]
    fn scaled_histogram_reads_in_seconds() {
        let h = Histogram::with_scale(1e-9);
        h.observe_secs(0.001); // 1ms = 1_000_000 ns
        assert_eq!(h.count(), 1);
        let p50 = h.quantile(0.5);
        // Within one sub-bucket (<=25%) of 1ms.
        assert!((0.0008..=0.0013).contains(&p50), "p50={p50}");
        assert!((h.sum() - 0.001).abs() < 1e-6);
    }

    #[test]
    fn registry_get_or_register_returns_same_handle() {
        let r = Registry::new();
        let a = r.counter("requests_total", &[("version", "1")]);
        let b = r.counter("requests_total", &[("version", "1")]);
        let c = r.counter("requests_total", &[("version", "2")]);
        a.inc();
        b.add(2);
        c.inc();
        assert_eq!(a.get(), 3);
        assert_eq!(c.get(), 1);
        assert_eq!(r.snapshot().len(), 2);
    }

    #[test]
    fn prometheus_exposition_golden() {
        let r = Registry::new();
        r.counter("serve_requests_total", &[]).add(42);
        r.gauge("serve_queue_depth", &[]).set(3.0);
        r.counter("serve_shed_total", &[("reason", "queue_full")]).inc();
        let h = r.histogram("serve_batch_size", &[], 1.0);
        for _ in 0..4 {
            h.observe(2);
        }
        let text = r.render_prometheus();
        let expected = "\
# TYPE serve_batch_size summary
serve_batch_size{quantile=\"0.5\"} 2.5
serve_batch_size{quantile=\"0.95\"} 2.95
serve_batch_size{quantile=\"0.99\"} 2.99
serve_batch_size_sum 8
serve_batch_size_count 4
# TYPE serve_queue_depth gauge
serve_queue_depth 3
# TYPE serve_requests_total counter
serve_requests_total 42
# TYPE serve_shed_total counter
serve_shed_total{reason=\"queue_full\"} 1
";
        assert_eq!(text, expected);
    }

    #[test]
    fn prometheus_label_escaping() {
        let r = Registry::new();
        r.counter("weird_total", &[("path", "a\\b\"c\nd")]).inc();
        let text = r.render_prometheus();
        assert!(
            text.contains("weird_total{path=\"a\\\\b\\\"c\\nd\"} 1"),
            "{text}"
        );
    }

    #[test]
    fn json_snapshot_roundtrips_through_util_json() {
        let r = Registry::new();
        r.counter("requests_total", &[("version", "3")]).add(7);
        r.gauge("agreement", &[]).set(0.5);
        let h = r.histogram("latency_seconds", &[], 1e-9);
        h.observe_secs(0.002);
        let text = r.render_json().to_string();
        let parsed = json::parse(&text).unwrap();
        let metrics = parsed.get("metrics").unwrap().as_arr().unwrap();
        assert_eq!(metrics.len(), 3);
        let by_name = |n: &str| {
            metrics
                .iter()
                .find(|m| m.get("name").unwrap().as_str().unwrap() == n)
                .unwrap()
        };
        let req = by_name("requests_total");
        assert_eq!(req.get("type").unwrap().as_str().unwrap(), "counter");
        assert_eq!(req.get("value").unwrap().as_f64().unwrap(), 7.0);
        assert_eq!(
            req.get("labels")
                .unwrap()
                .get("version")
                .unwrap()
                .as_str()
                .unwrap(),
            "3"
        );
        let lat = by_name("latency_seconds");
        assert_eq!(lat.get("count").unwrap().as_f64().unwrap(), 1.0);
        assert!(lat.get("p50").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(by_name("agreement").get("value").unwrap().as_f64().unwrap(), 0.5);
    }

    #[test]
    fn endpoint_serves_text_and_json() {
        let registry = Arc::new(Registry::new());
        registry.counter("up_total", &[]).inc();
        let mut srv = MetricsServer::start("127.0.0.1:0", registry.clone()).unwrap();
        let addr = srv.addr().to_string();
        let text = http_get(&addr, "/metrics").unwrap();
        assert!(text.contains("up_total 1"), "{text}");
        let body = http_get(&addr, "/metrics.json").unwrap();
        let parsed = json::parse(&body).unwrap();
        assert_eq!(
            parsed.get("metrics").unwrap().as_arr().unwrap().len(),
            1
        );
        assert!(http_get(&addr, "/nope").is_err());
        srv.shutdown();
        // After shutdown the port stops accepting (bind a fresh one to
        // prove shutdown released the listener thread).
        let again = MetricsServer::start("127.0.0.1:0", registry).unwrap();
        drop(again);
    }

    #[test]
    fn trace_writer_emits_parseable_jsonl() {
        let dir = std::env::temp_dir().join("bitprune_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("trace.jsonl");
        {
            let tw = TraceWriter::create(&path).unwrap();
            tw.emit("admit", &[("id", Tv::U(1))]);
            tw.emit(
                "shed",
                &[("id", Tv::U(2)), ("reason", Tv::S("queue_full"))],
            );
            tw.emit(
                "batch",
                &[
                    ("size", Tv::U(8)),
                    ("version", Tv::U(1)),
                    ("canary", Tv::B(false)),
                    ("forward_s", Tv::F(0.001)),
                ],
            );
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let mut last_t = 0.0;
        for line in &lines {
            let v = json::parse(line).unwrap();
            let t = v.get("t_us").unwrap().as_f64().unwrap();
            assert!(t >= last_t, "timestamps must be monotone");
            last_t = t;
            v.get("event").unwrap().as_str().unwrap();
        }
        let shed = json::parse(lines[1]).unwrap();
        assert_eq!(shed.get("reason").unwrap().as_str().unwrap(), "queue_full");
        std::fs::remove_file(&path).ok();
    }
}
