//! # bitprune
//!
//! Production reproduction of *BitPruning: Learning Bitlengths for
//! Aggressive and Accurate Quantization* (Nikolić et al., 2020) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** — Pallas fake-quantization kernels (python/compile/kernels),
//!   AOT-lowered into the model HLO.
//! * **L2** — JAX quantized models + BitPruning loss + train/eval steps
//!   (python/compile), exported once as HLO-text artifacts.
//! * **L3** — this crate: the training coordinator, experiment
//!   scheduler, datasets, baselines, accelerator performance models and
//!   report generation.  Python never runs on the training path; the
//!   binary drives everything through PJRT.
//!
//! See DESIGN.md for the full system inventory and experiment index.

pub mod accel;
pub mod baselines;
pub mod bitpack;
pub mod checkpoint;
pub mod deploy;
pub mod hlo;
pub mod infer;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod metrics;
pub mod model;
pub mod quant;
pub mod report;
pub mod runtime;
pub mod schedule;
pub mod serve;
pub mod telemetry;
pub mod tensor;
pub mod util;
