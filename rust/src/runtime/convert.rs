//! HostTensor ⇄ xla::Literal conversions.

use anyhow::{anyhow, bail, Result};

use crate::tensor::{HostTensor, TensorData};

/// Convert a host tensor into an XLA literal (host staging buffer).
pub fn tensor_to_literal(t: &HostTensor) -> Result<xla::Literal> {
    let dims: Vec<i64> = t.dims().iter().map(|&d| d as i64).collect();
    let lit = match t.data() {
        TensorData::F32(v) => xla::Literal::vec1(v.as_slice()),
        TensorData::I32(v) => xla::Literal::vec1(v.as_slice()),
        TensorData::U32(v) => xla::Literal::vec1(v.as_slice()),
    };
    // vec1 produces a rank-1 literal; reshape restores the true dims.
    // Rank-0 scalars reshape to [].
    lit.reshape(&dims)
        .map_err(|e| anyhow!("reshaping literal to {:?}: {e}", t.dims()))
}

/// Convert an XLA literal back into a host tensor.
pub fn literal_to_tensor(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit
        .array_shape()
        .map_err(|e| anyhow!("literal has non-array shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.element_type() {
        xla::ElementType::F32 => {
            let v = lit.to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
            HostTensor::f32(&dims, v)
        }
        xla::ElementType::S32 => {
            let v = lit.to_vec::<i32>().map_err(|e| anyhow!("{e}"))?;
            HostTensor::i32(&dims, v)
        }
        xla::ElementType::U32 => {
            let v = lit.to_vec::<u32>().map_err(|e| anyhow!("{e}"))?;
            HostTensor::u32(&dims, v)
        }
        other => bail!("unsupported literal element type {:?}", other),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = HostTensor::f32(&[2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn roundtrip_scalar() {
        let t = HostTensor::scalar_f32(3.25);
        let lit = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&lit).unwrap();
        assert_eq!(back.scalar().unwrap(), 3.25);
        assert_eq!(back.rank(), 0);
    }

    #[test]
    fn roundtrip_i32() {
        let t = HostTensor::i32(&[4], vec![1, -2, 3, -4]).unwrap();
        let back = literal_to_tensor(&tensor_to_literal(&t).unwrap()).unwrap();
        assert_eq!(t, back);
    }
}
