//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! Wraps the `xla` crate (PJRT C API): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format — see python/compile/aot.py and
//! /opt/xla-example/README.md for why serialized protos do not work.
//!
//! The runtime is the only module that touches the `xla` crate; the rest
//! of the coordinator works in terms of [`HostTensor`].

mod convert;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::HostTensor;
pub use convert::{literal_to_tensor, tensor_to_literal};

/// Cumulative execution statistics for one executable.
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    pub executions: u64,
    pub total_exec_nanos: u64,
    pub total_transfer_nanos: u64,
    pub compile_nanos: u64,
}

impl ExecStats {
    pub fn mean_exec_micros(&self) -> f64 {
        if self.executions == 0 {
            return 0.0;
        }
        self.total_exec_nanos as f64 / self.executions as f64 / 1000.0
    }
}

/// A compiled artifact, ready to execute.
pub struct Executable {
    name: String,
    exe: xla::PjRtLoadedExecutable,
    stats: Mutex<ExecStats>,
}

impl Executable {
    /// Execute with host tensors; returns the decomposed output tuple.
    ///
    /// The exported artifacts are lowered with `return_tuple=True`, so
    /// PJRT hands back a single tuple buffer which we copy to host and
    /// decompose.  Transfer time is tracked separately from execution.
    pub fn run(&self, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let refs: Vec<&HostTensor> = args.iter().collect();
        self.run_refs(&refs)
    }

    /// Like [`run`], but borrowing the arguments — the trainer hot loop
    /// uses this to avoid cloning multi-megabyte parameter tensors every
    /// step just to build the argument vector (§Perf L3 iteration 1).
    pub fn run_refs(&self, args: &[&HostTensor]) -> Result<Vec<HostTensor>> {
        let t0 = Instant::now();
        let literals: Vec<xla::Literal> =
            args.iter().map(|t| tensor_to_literal(t)).collect::<Result<_>>()?;
        let t1 = Instant::now();
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing artifact '{}'", self.name))?;
        let t2 = Instant::now();
        let buffer = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("artifact '{}' produced no outputs", self.name))?;
        let tuple = buffer.to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        let out: Vec<HostTensor> =
            parts.iter().map(literal_to_tensor).collect::<Result<_>>()?;
        let t3 = Instant::now();

        let mut s = self.stats.lock().unwrap();
        s.executions += 1;
        s.total_exec_nanos += (t2 - t1).as_nanos() as u64;
        s.total_transfer_nanos +=
            ((t1 - t0).as_nanos() + (t3 - t2).as_nanos()) as u64;
        Ok(out)
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn stats(&self) -> ExecStats {
        self.stats.lock().unwrap().clone()
    }
}

/// Owns the PJRT client and a cache of compiled executables.
pub struct Runtime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    cache: Mutex<HashMap<String, std::sync::Arc<Executable>>>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime rooted at an artifact directory.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e}"))?;
        let dir = artifact_dir.as_ref().to_path_buf();
        if !dir.is_dir() {
            bail!(
                "artifact directory '{}' does not exist — run `make artifacts` first",
                dir.display()
            );
        }
        Ok(Self { client, artifact_dir: dir, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.artifact_dir
    }

    /// Path of a named artifact file (`<name>.hlo.txt`).
    pub fn artifact_path(&self, name: &str) -> PathBuf {
        self.artifact_dir.join(format!("{name}.hlo.txt"))
    }

    /// Load + compile an artifact by name, with caching.
    pub fn load(&self, name: &str) -> Result<std::sync::Arc<Executable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let path = self.artifact_path(name);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(|e| {
            anyhow!("parsing HLO text '{}': {e}", path.display())
        })?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling artifact '{name}': {e}"))?;
        let compile_nanos = t0.elapsed().as_nanos() as u64;
        let exe = std::sync::Arc::new(Executable {
            name: name.to_string(),
            exe,
            stats: Mutex::new(ExecStats { compile_nanos, ..Default::default() }),
        });
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Names of all artifacts present in the directory.
    pub fn list_artifacts(&self) -> Result<Vec<String>> {
        let mut names = Vec::new();
        for entry in std::fs::read_dir(&self.artifact_dir)? {
            let name = entry?.file_name().to_string_lossy().into_owned();
            if let Some(stem) = name.strip_suffix(".hlo.txt") {
                names.push(stem.to_string());
            }
        }
        names.sort();
        Ok(names)
    }
}
