//! Experiment configuration: TOML files + CLI overrides -> a validated
//! [`RunConfig`] consumed by the coordinator.

pub mod toml;

use anyhow::{bail, Result};

use crate::quant::Criterion;
use crate::util::args::Args;
use toml::TomlDoc;

/// Which phase plan shape to run (see schedule::PhasePlan).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlanKind {
    /// Learn bits -> ceil -> finetune (the paper's main recipe).
    Standard,
    /// Short bit-learning prefix, then fixed integer bits (§III-B4).
    EarlySelect,
    /// Bits frozen at `init_bits` for the whole run (uniform QAT /
    /// PACT-role baseline, and the `init_bits = 16` fp32-proxy baseline).
    FixedBits,
    /// Standard plan but starting from a pretrained checkpoint (§III-B5).
    Warmstart,
}

impl PlanKind {
    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "standard" => PlanKind::Standard,
            "early" => PlanKind::EarlySelect,
            "fixed" => PlanKind::FixedBits,
            "warmstart" => PlanKind::Warmstart,
            other => bail!("unknown plan '{other}' (standard|early|fixed|warmstart)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            PlanKind::Standard => "standard",
            PlanKind::EarlySelect => "early",
            PlanKind::FixedBits => "fixed",
            PlanKind::Warmstart => "warmstart",
        }
    }
}

/// Fully-resolved configuration for one training run.
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Run identifier (used in output file names).
    pub name: String,
    /// Artifact tag (e.g. "resnet_s", "alexnet_s_w1_x4").
    pub model: String,
    /// Dataset name (data::build).
    pub dataset: String,
    pub seed: u64,
    /// Regularizer strength γ.
    pub gamma: f64,
    /// Loss-weighting criterion (λ vectors).
    pub criterion: Criterion,
    pub plan: PlanKind,
    pub lr_max: f64,
    /// Bitlength learning rate (paper uses the model LR; a separate knob
    /// stabilizes small-step runs).
    pub bits_lr: f64,
    pub learn_steps: usize,
    pub finetune_steps: usize,
    /// Initial (or fixed, for PlanKind::FixedBits) bitlength.
    pub init_bits: f64,
    /// Evaluate every N steps.
    pub eval_every: usize,
    /// Train-time augmentation.
    pub augment: bool,
    pub artifact_dir: String,
    pub out_dir: String,
    /// Optional checkpoint to warm start from.
    pub warmstart_ckpt: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        Self {
            name: "run".into(),
            model: "resnet_s".into(),
            dataset: "synthcifar".into(),
            seed: 42,
            gamma: 1.0,
            criterion: Criterion::Equal,
            plan: PlanKind::Standard,
            lr_max: 0.05,
            // The paper uses the model LR for bitlengths over ~100k
            // steps; our runs are a few hundred steps, so the bitlength
            // LR is scaled up to cover the same bit-distance (see
            // EXPERIMENTS.md "bits_lr calibration").
            bits_lr: 6.0,
            learn_steps: 300,
            finetune_steps: 100,
            init_bits: 8.0,
            eval_every: 25,
            augment: true,
            artifact_dir: "artifacts".into(),
            out_dir: "reports".into(),
            warmstart_ckpt: None,
        }
    }
}

impl RunConfig {
    /// Load from a TOML document (missing keys keep defaults).
    pub fn from_toml(doc: &TomlDoc) -> Result<Self> {
        let d = RunConfig::default();
        let criterion_name = doc.str_or("run.criterion", "equal")?;
        let criterion = Criterion::parse(&criterion_name)
            .ok_or_else(|| anyhow::anyhow!("unknown criterion '{criterion_name}'"))?;
        let cfg = Self {
            name: doc.str_or("run.name", &d.name)?,
            model: doc.str_or("run.model", &d.model)?,
            dataset: doc.str_or("run.dataset", &d.dataset)?,
            seed: doc.u64_or("run.seed", d.seed)?,
            gamma: doc.f64_or("run.gamma", d.gamma)?,
            criterion,
            plan: PlanKind::parse(&doc.str_or("run.plan", d.plan.name())?)?,
            lr_max: doc.f64_or("train.lr_max", d.lr_max)?,
            bits_lr: doc.f64_or("train.bits_lr", d.bits_lr)?,
            learn_steps: doc.usize_or("train.learn_steps", d.learn_steps)?,
            finetune_steps: doc.usize_or("train.finetune_steps", d.finetune_steps)?,
            init_bits: doc.f64_or("train.init_bits", d.init_bits)?,
            eval_every: doc.usize_or("train.eval_every", d.eval_every)?,
            augment: doc.bool_or("train.augment", d.augment)?,
            artifact_dir: doc.str_or("paths.artifacts", &d.artifact_dir)?,
            out_dir: doc.str_or("paths.out", &d.out_dir)?,
            warmstart_ckpt: doc.get("run.warmstart_ckpt").map(|v| v.as_str().map(str::to_string)).transpose()?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply CLI overrides on top (flags win over file).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(v) = args.get("name") {
            self.name = v.to_string();
        }
        if let Some(v) = args.get("model") {
            self.model = v.to_string();
        }
        if let Some(v) = args.get("dataset") {
            self.dataset = v.to_string();
        }
        if let Some(v) = args.get("criterion") {
            self.criterion = Criterion::parse(v)
                .ok_or_else(|| anyhow::anyhow!("unknown criterion '{v}'"))?;
        }
        if let Some(v) = args.get("plan") {
            self.plan = PlanKind::parse(v)?;
        }
        if let Some(v) = args.get("warmstart-ckpt") {
            self.warmstart_ckpt = Some(v.to_string());
        }
        self.seed = args.get_u64("seed", self.seed)?;
        self.gamma = args.get_f64("gamma", self.gamma)?;
        self.lr_max = args.get_f64("lr-max", self.lr_max)?;
        self.bits_lr = args.get_f64("bits-lr", self.bits_lr)?;
        self.learn_steps = args.get_usize("learn-steps", self.learn_steps)?;
        self.finetune_steps = args.get_usize("finetune-steps", self.finetune_steps)?;
        self.init_bits = args.get_f64("init-bits", self.init_bits)?;
        self.eval_every = args.get_usize("eval-every", self.eval_every)?;
        if args.flag("no-augment") {
            self.augment = false;
        }
        if let Some(v) = args.get("artifacts") {
            self.artifact_dir = v.to_string();
        }
        if let Some(v) = args.get("out") {
            self.out_dir = v.to_string();
        }
        self.validate()
    }

    pub fn validate(&self) -> Result<()> {
        if self.gamma < 0.0 {
            bail!("gamma must be >= 0, got {}", self.gamma);
        }
        if self.lr_max <= 0.0 || self.bits_lr < 0.0 {
            bail!("learning rates must be positive");
        }
        if self.learn_steps + self.finetune_steps == 0 {
            bail!("zero total steps");
        }
        if !(1.0..=16.0).contains(&self.init_bits) {
            bail!("init_bits {} outside [1, 16]", self.init_bits);
        }
        if self.eval_every == 0 {
            bail!("eval_every must be > 0");
        }
        if self.plan == PlanKind::Warmstart && self.warmstart_ckpt.is_none() {
            bail!("plan = warmstart requires warmstart_ckpt");
        }
        Ok(())
    }

    /// The CLI value-taking option names this config understands.
    pub fn cli_value_opts() -> Vec<&'static str> {
        vec![
            "name", "model", "dataset", "criterion", "plan", "seed", "gamma",
            "lr-max", "bits-lr", "learn-steps", "finetune-steps", "init-bits",
            "eval-every", "artifacts", "out", "config", "warmstart-ckpt",
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn toml_roundtrip() {
        let doc = TomlDoc::parse(
            r#"
            [run]
            name = "t2-alex-g05"
            model = "alexnet_s"
            gamma = 0.5
            criterion = "mac"
            plan = "early"
            [train]
            lr_max = 0.01
            learn_steps = 40
            finetune_steps = 10
            init_bits = 6
            [paths]
            artifacts = "a"
            out = "o"
            "#,
        )
        .unwrap();
        let cfg = RunConfig::from_toml(&doc).unwrap();
        assert_eq!(cfg.name, "t2-alex-g05");
        assert_eq!(cfg.gamma, 0.5);
        assert_eq!(cfg.criterion, Criterion::MacOps);
        assert_eq!(cfg.plan, PlanKind::EarlySelect);
        assert_eq!(cfg.learn_steps, 40);
        assert_eq!(cfg.init_bits, 6.0);
        assert_eq!(cfg.artifact_dir, "a");
    }

    #[test]
    fn cli_overrides_file() {
        let doc = TomlDoc::parse("[run]\ngamma = 1.0").unwrap();
        let mut cfg = RunConfig::from_toml(&doc).unwrap();
        let args = Args::parse(
            vec!["--gamma=2.5".to_string(), "--no-augment".to_string()],
            &RunConfig::cli_value_opts(),
        )
        .unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.gamma, 2.5);
        assert!(!cfg.augment);
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut cfg = RunConfig::default();
        cfg.gamma = -1.0;
        assert!(cfg.validate().is_err());

        let mut cfg = RunConfig::default();
        cfg.learn_steps = 0;
        cfg.finetune_steps = 0;
        assert!(cfg.validate().is_err());

        let mut cfg = RunConfig::default();
        cfg.init_bits = 0.5;
        assert!(cfg.validate().is_err());

        let mut cfg = RunConfig::default();
        cfg.plan = PlanKind::Warmstart;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn plan_parse() {
        assert!(PlanKind::parse("nope").is_err());
        for p in [PlanKind::Standard, PlanKind::EarlySelect, PlanKind::FixedBits] {
            assert_eq!(PlanKind::parse(p.name()).unwrap(), p);
        }
    }
}
