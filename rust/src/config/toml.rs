//! Minimal TOML-subset parser for experiment config files.
//!
//! Supported: `[section]` headers, `key = value` with string, integer,
//! float, boolean and homogeneous-array values, `#` comments, and dotted
//! access (`section.key`).  This covers every config the repo ships;
//! unsupported TOML constructs produce a parse error rather than
//! silently wrong values.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(f) => Ok(*f),
            TomlValue::Int(i) => Ok(*i as f64),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as usize),
            other => bail!("expected non-negative integer, got {other:?}"),
        }
    }

    pub fn as_u64(&self) -> Result<u64> {
        match self {
            TomlValue::Int(i) if *i >= 0 => Ok(*i as u64),
            other => bail!("expected non-negative integer, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }

    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        match self {
            TomlValue::Array(v) => v.iter().map(|x| x.as_f64()).collect(),
            other => bail!("expected array, got {other:?}"),
        }
    }
}

/// Flat table of `section.key` (or bare `key`) to value.
#[derive(Debug, Default, Clone)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<Self> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected 'key = value'", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let parsed = parse_value(value.trim())
                .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
            if doc.values.insert(full_key.clone(), parsed).is_some() {
                bail!("line {}: duplicate key '{full_key}'", lineno + 1);
            }
        }
        Ok(doc)
    }

    pub fn load(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading config '{}': {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> Result<String> {
        match self.get(key) {
            None => Ok(default.to_string()),
            Some(v) => Ok(v.as_str()?.to_string()),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_f64(),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_usize(),
        }
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_u64(),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_bool(),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(String::as_str)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<TomlValue> {
    if text.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest
            .strip_suffix('"')
            .ok_or_else(|| anyhow!("unterminated string"))?;
        if inner.contains('"') {
            bail!("escaped quotes are not supported");
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if let Some(rest) = text.strip_prefix('[') {
        let inner = rest
            .strip_suffix(']')
            .ok_or_else(|| anyhow!("unterminated array"))?
            .trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(vec![]));
        }
        let items = inner
            .split(',')
            .map(|p| parse_value(p.trim()))
            .collect::<Result<Vec<_>>>()?;
        return Ok(TomlValue::Array(items));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    if !text.contains('.') && !text.contains('e') && !text.contains('E') {
        if let Ok(i) = text.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value '{text}'")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            # experiment
            name = "table2"          # trailing comment
            seed = 42
            [train]
            lr_max = 0.05
            steps = 300
            augment = true
            gammas = [0.5, 1.0, 2.5]
            models = ["alexnet_s", "resnet_s"]
            "#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", "").unwrap(), "table2");
        assert_eq!(doc.u64_or("seed", 0).unwrap(), 42);
        assert_eq!(doc.f64_or("train.lr_max", 0.0).unwrap(), 0.05);
        assert_eq!(doc.usize_or("train.steps", 0).unwrap(), 300);
        assert!(doc.bool_or("train.augment", false).unwrap());
        assert_eq!(
            doc.get("train.gammas").unwrap().as_f64_vec().unwrap(),
            vec![0.5, 1.0, 2.5]
        );
    }

    #[test]
    fn defaults_apply() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.f64_or("missing", 1.5).unwrap(), 1.5);
        assert_eq!(doc.str_or("missing", "x").unwrap(), "x");
    }

    #[test]
    fn errors_are_informative() {
        assert!(TomlDoc::parse("[unterminated").is_err());
        assert!(TomlDoc::parse("novalue").is_err());
        assert!(TomlDoc::parse("k = ").is_err());
        assert!(TomlDoc::parse("k = \"open").is_err());
        let dup = TomlDoc::parse("a = 1\na = 2");
        assert!(dup.is_err());
    }

    #[test]
    fn type_mismatches_caught() {
        let doc = TomlDoc::parse("k = 1.5").unwrap();
        assert!(doc.get("k").unwrap().as_usize().is_err());
        assert!(doc.get("k").unwrap().as_str().is_err());
        assert_eq!(doc.get("k").unwrap().as_f64().unwrap(), 1.5);
    }

    #[test]
    fn hash_inside_string_is_kept() {
        let doc = TomlDoc::parse(r##"k = "a#b" # comment"##).unwrap();
        assert_eq!(doc.str_or("k", "").unwrap(), "a#b");
    }

    #[test]
    fn negative_numbers() {
        let doc = TomlDoc::parse("a = -3\nb = -0.5").unwrap();
        assert_eq!(doc.get("a").unwrap().as_f64().unwrap(), -3.0);
        assert!(doc.get("a").unwrap().as_usize().is_err());
        assert_eq!(doc.get("b").unwrap().as_f64().unwrap(), -0.5);
    }
}
