//! BPMA — the **B**it**P**runing **M**odel **A**rtifact: a single-file
//! frozen representation of a quantized network, the thing `bitprune
//! export` ships and `bitprune serve` loads.
//!
//! Everything inference needs travels inside: per-layer geometry, the
//! learned weight/activation bitlengths, the `QuantPlan` dequantization
//! parameters `(lmin, scale)` per weight group, the bit-packed weight
//! codes themselves, f32 biases, and the calibrated activation ranges.
//! [`Artifact::instantiate`] rebuilds an [`IntNet`] from those parts
//! **bit-identically** to the net [`freeze`] captured — no dataset, no
//! trainer, no PJRT runtime (see `IntDense::from_packed`).
//!
//! ## Wire format (little-endian)
//!
//! ```text
//! magic "BPMA" | version u32 | flags u32 | section_count u32
//! per section:  tag [u8;4] | payload_len u64 | payload | crc32 u32
//! ```
//!
//! Sections are a length-prefixed table: readers **skip sections whose
//! tag they do not know** (after verifying the checksum), so old
//! binaries load artifacts written by newer ones that append sections.
//! Version-1 sections:
//!
//! | tag    | payload |
//! |--------|---------|
//! | `MET0` | model name (u32-prefixed str), `num_classes` u32, `n_layers` u32 |
//! | `LAY0` | per layer: name, din u64, dout u64, w_bits u32 (0 for grouped layers), a_bits u32, flags u8 (b0 relu, b1 has act range, b2 grouped), w_lmin f32, w_scale f32, \[act_lo f32, act_hi f32\] |
//! | `WCT0` | per layer: payload_len u64, bit-packed weight codes |
//! | `BIA0` | per layer: dout f32 biases |
//! | `GRP0` | written only when a layer is grouped: n_layers u32, then per layer a u8 grouped flag and, when set, n_groups u32 + per group (bits u32, lmin f32, scale f32) — the per-output-channel plan table; `WCT0` then carries that layer's group-boundary-aligned per-channel codes |
//! | `CNV0` | written only when a layer is a convolution: n_layers u32, then per layer a u8 kind (0 dense, 1 conv) and, for conv, cin u64, h u64, w u64, kh u32, kw u32, stride u32, pad u32 — the im2col geometry (`cout` is the layer's LAY0 dout) |
//! | `CBK0` | written only when a layer has a non-uniform weight codebook: n_layers u32, then per layer a u8 codebook tag (0 uniform, 1 power-of-two, 2 additive-PoT) and, for a non-uniform layer, its true bitlengths — one u32 for a per-layer layer, or n_groups u32 + per group bits u32 for a grouped layer (the poisoned LAY0/GRP0 bits fields resolve from here) |
//!
//! Per-layer artifacts never write `GRP0`, so their bytes are identical
//! to pre-`GRP0` writers; readers that predate the tag skip it by the
//! unknown-tag rule and reject grouped artifacts at the LAY0 `w_bits`
//! range check (grouped layers write the field as 0) with a clean
//! error — the payload size alone can coincide with the per-layer
//! expectation, so the poisoned field carries the rejection.
//!
//! `CNV0` follows the same pattern: dense-only artifacts never write
//! it (their bytes stay identical to pre-`CNV0` writers), and conv
//! layers **poison their LAY0 `din` as 0** — a pre-`CNV0` reader skips
//! the unknown section and then fails its degenerate-shape check with
//! a clean error instead of multiplying flattened activations through
//! a dense layer whose real `din` is the im2col patch length.  The
//! new reader derives `din = kh·kw·cin` from the geometry.
//!
//! `CBK0` is the third instance of the pattern: uniform-codebook
//! artifacts never write it (bytes identical to pre-`CBK0` writers),
//! and a non-uniform layer **poisons its bits fields as 0** — LAY0
//! `w_bits` for a per-layer layer, every GRP0 span `bits` for a
//! grouped layer — with the true bitlengths riding in `CBK0`.  A
//! pre-`CBK0` reader skips the section and fails its `[1,16]` bits
//! range check with a clean error instead of mis-decoding
//! (sign, exponent) fields as uniform codes; the new reader
//! cross-checks the per-layer codebook flag against the section both
//! ways and restores the bits before handing the payload to the
//! codebook-aware `from_raw_cbk` validators.
//!
//! The loader treats every byte as hostile: all reads go through the
//! bounded [`crate::util::binio::Reader`] (shared with the checkpoint
//! loader), counts never pre-allocate, element products use
//! `checked_mul`, payload sizes must match the geometry exactly, and a
//! flipped bit anywhere in a payload fails its section CRC.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::bitpack::{PackedGroups, PackedTensor, WeightCodes};
use crate::infer::{ConvGeom, IntConv2d, IntDense, IntLayer, IntNet};
use crate::quant::{Codebook, Granularity};
use crate::util::binio::{self, Reader};

pub const MAGIC: &[u8; 4] = b"BPMA";
pub const VERSION: u32 = 1;

const TAG_META: &[u8; 4] = b"MET0";
const TAG_LAYERS: &[u8; 4] = b"LAY0";
const TAG_WCODES: &[u8; 4] = b"WCT0";
const TAG_BIASES: &[u8; 4] = b"BIA0";
/// Per-output-channel group table (added after v1 shipped; readers that
/// predate it skip the tag — see the forward-compat note below).
const TAG_GROUPS: &[u8; 4] = b"GRP0";
/// Conv-layer geometry table (same forward-compat pattern as `GRP0`;
/// see the module docs for the poisoned-`din` rejection story).
const TAG_CONV: &[u8; 4] = b"CNV0";
/// Per-layer weight-codebook table (same forward-compat pattern; see
/// the module docs for the poisoned-bits rejection story).
const TAG_CODEBOOK: &[u8; 4] = b"CBK0";

const LAYER_FLAG_RELU: u8 = 1 << 0;
const LAYER_FLAG_ACT_RANGE: u8 = 1 << 1;
/// The layer's `WCT0` payload is group-boundary-aligned per-channel
/// codes; its plans live in the `GRP0` section and its LAY0 `w_bits`
/// field is written as 0.  Pre-`GRP0` readers ignore unknown flag bits
/// and unknown sections, so the poisoned bits field is what makes them
/// reject the artifact at the `[1,16]` range check — a clean error,
/// never a silent mis-decode of channel-major codes.
const LAYER_FLAG_GROUPED: u8 = 1 << 2;
/// The layer is a convolution: its geometry lives in the `CNV0`
/// section and its LAY0 `din` field is written as 0.  Pre-`CNV0`
/// readers skip the section and reject the artifact at their
/// degenerate-shape check — a clean error, never a dense mis-forward
/// of an im2col layer.
const LAYER_FLAG_CONV: u8 = 1 << 3;
/// The layer's weight codes are stored under a non-uniform codebook:
/// its true bitlengths live in the `CBK0` section and its LAY0
/// `w_bits` (per-layer) or GRP0 span `bits` (grouped) fields are
/// written as 0.  Pre-`CBK0` readers skip the section and reject the
/// artifact at their `[1,16]` bits range check — a clean error, never
/// a uniform mis-decode of (sign, exponent) fields.
const LAYER_FLAG_CODEBOOK: u8 = 1 << 4;

/// One frozen layer: geometry, learned bitlengths, quantization
/// parameters, packed codes, bias, calibrated input range.
#[derive(Debug, Clone)]
pub struct LayerRecord {
    pub name: String,
    /// GEMM input width: the dense `din`, or the im2col patch length
    /// `kh·kw·cin` for a conv layer.
    pub din: usize,
    /// GEMM output width: the dense `dout`, or the conv `cout`.
    pub dout: usize,
    /// Activation (input) bitlength.
    pub a_bits: u32,
    pub relu: bool,
    /// Calibrated input activation range; `None` means the layer will
    /// quantize against each batch's own min/max (batch-dependent).
    pub act_range: Option<(f32, f32)>,
    /// Packed weight codes at their stored granularity — one
    /// `(bits, lmin, scale)` plan per layer or per output channel
    /// (per output *kernel* for conv layers).
    pub weights: WeightCodes,
    pub bias: Vec<f32>,
    /// Conv geometry when this layer is a convolution (`CNV0`);
    /// `None` for dense layers.
    pub conv: Option<ConvGeom>,
}

impl LayerRecord {
    /// Flattened input features per sample — what the previous layer
    /// must emit (dense `din`; conv `cin·h·w`).
    pub fn in_features(&self) -> usize {
        match &self.conv {
            Some(g) => g.in_features(),
            None => self.din,
        }
    }

    /// Flattened output features per sample (dense `dout`; conv
    /// `cout·out_h·out_w`).
    pub fn out_features(&self) -> usize {
        match &self.conv {
            Some(g) => g.out_features(),
            None => self.dout,
        }
    }

    /// Largest weight bitlength this layer stores any code at (for a
    /// per-layer record, *the* bitlength).
    pub fn w_bits(&self) -> u32 {
        self.weights.max_bits()
    }

    /// Mean stored weight bitlength over this layer's groups.
    pub fn w_bits_mean(&self) -> f64 {
        self.weights.mean_bits()
    }

    /// Weight-quantization granularity of this layer.
    pub fn granularity(&self) -> Granularity {
        self.weights.granularity()
    }

    /// Weight codebook of this layer (`CBK0`; uniform layers carry no
    /// section entry beyond the zero tag).
    pub fn codebook(&self) -> Codebook {
        self.weights.codebook()
    }

    /// Stored footprint (packed payload + plan headers + f32 bias) —
    /// same convention as `IntDense::packed_bytes`.
    pub fn stored_bytes(&self) -> usize {
        self.weights.stored_bytes() + self.bias.len() * 4
    }
}

/// A frozen model: the in-memory form of one `.bpma` file.
#[derive(Debug, Clone)]
pub struct Artifact {
    pub model: String,
    pub num_classes: usize,
    pub layers: Vec<LayerRecord>,
}

/// Freeze a live [`IntNet`] into its shippable artifact form.  Pure
/// copy — the packed codes, dequantization parameters, biases and
/// calibrated ranges are taken verbatim, which is what makes
/// [`Artifact::instantiate`] bit-identical.
pub fn freeze(net: &IntNet, model: &str) -> Artifact {
    let layers = net
        .layers
        .iter()
        .map(|l| {
            let (din, dout) = l.core_dims();
            LayerRecord {
                name: l.name().to_string(),
                din,
                dout,
                a_bits: l.a_bits(),
                relu: l.relu(),
                act_range: l.act_range(),
                weights: l.weights().clone(),
                bias: l.bias().to_vec(),
                conv: l.conv_geom().copied(),
            }
        })
        .collect();
    Artifact { model: model.to_string(), num_classes: net.num_classes, layers }
}

impl Artifact {
    /// Rebuild the integer network this artifact froze.  Bit-identical
    /// to the source net: the packed codes and every affine parameter
    /// are restored verbatim (`IntDense::from_packed`), so logits match
    /// to the last bit — pinned by `tests/deploy_artifact.rs`.
    pub fn instantiate(&self) -> Result<IntNet> {
        let mut layers: Vec<IntLayer> = Vec::with_capacity(self.layers.len());
        for rec in &self.layers {
            let core = match &rec.weights {
                WeightCodes::PerLayer(p) => IntDense::from_packed(
                    &rec.name,
                    p.clone(),
                    rec.din,
                    rec.dout,
                    rec.bias.clone(),
                    rec.a_bits,
                    rec.relu,
                    rec.act_range,
                )?,
                WeightCodes::PerChannel(g) => IntDense::from_packed_groups(
                    &rec.name,
                    g.clone(),
                    rec.din,
                    rec.dout,
                    rec.bias.clone(),
                    rec.a_bits,
                    rec.relu,
                    rec.act_range,
                )?,
            };
            layers.push(match rec.conv {
                None => core.into(),
                Some(geom) => IntConv2d::from_core(geom, core)?.into(),
            });
        }
        Ok(IntNet { layers, num_classes: self.num_classes })
    }

    /// Whether any layer stores per-output-channel weight codes.
    pub fn is_grouped(&self) -> bool {
        self.layers
            .iter()
            .any(|l| l.granularity() == Granularity::PerOutputChannel)
    }

    /// Whether any layer is a convolution (the artifact then carries a
    /// `CNV0` section).
    pub fn is_conv(&self) -> bool {
        self.layers.iter().any(|l| l.conv.is_some())
    }

    /// Whether any layer stores codes under a non-uniform codebook
    /// (the artifact then carries a `CBK0` section).
    pub fn has_codebook(&self) -> bool {
        self.layers.iter().any(|l| !l.codebook().is_uniform())
    }

    /// Aggregate per-channel weight-bit histogram (index = bitlength,
    /// 1..=16; per-layer records count as one group).
    pub fn w_bits_histogram(&self) -> [usize; 17] {
        let mut h = [0usize; 17];
        for l in &self.layers {
            for (i, c) in l.weights.bits_histogram().iter().enumerate() {
                h[i] += c;
            }
        }
        h
    }

    /// Whether every layer carries a calibrated activation range (the
    /// batch-invariant-serving precondition).
    pub fn is_calibrated(&self) -> bool {
        self.layers.iter().all(|l| l.act_range.is_some())
    }

    /// Total stored model footprint in bytes (packed convention).
    pub fn packed_bytes(&self) -> usize {
        self.layers.iter().map(|l| l.stored_bytes()).sum()
    }

    /// The f32 footprint of the same parameters, for the ratio.
    pub fn f32_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| (l.din * l.dout + l.dout) * 4)
            .sum()
    }

    /// Mean learned weight bitlength over every group of every layer
    /// (group-count weighted — the paper's sub-layer average, and the
    /// same weighting as `IntNet::mean_w_bits`, so the CLI reports one
    /// number for a model whichever form it is in).
    pub fn mean_w_bits(&self) -> f64 {
        let h = self.w_bits_histogram();
        let n: usize = h.iter().sum();
        if n == 0 {
            return 0.0;
        }
        h.iter()
            .enumerate()
            .map(|(bits, &count)| (bits * count) as f64)
            .sum::<f64>()
            / n as f64
    }

    /// Mean learned activation bitlength across layers.
    pub fn mean_a_bits(&self) -> f64 {
        mean(self.layers.iter().map(|l| l.a_bits as f64))
    }

    // -- serialization ------------------------------------------------------

    /// Serialize to the BPMA wire format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut meta = Vec::new();
        binio::put_str_u32(&mut meta, &self.model);
        binio::put_u32(&mut meta, self.num_classes as u32);
        binio::put_u32(&mut meta, self.layers.len() as u32);

        let mut lay = Vec::new();
        for l in &self.layers {
            binio::put_str_u32(&mut lay, &l.name);
            // Conv layers poison din as 0: their real GEMM din is
            // derivable only from the CNV0 geometry, and a pre-CNV0
            // reader must fail its degenerate-shape check rather than
            // forward flattened activations through a dense layer of
            // patch-length width.
            let din_field = if l.conv.is_some() { 0 } else { l.din as u64 };
            binio::put_u64(&mut lay, din_field);
            binio::put_u64(&mut lay, l.dout as u64);
            // Grouped layers store their real plans in GRP0; LAY0's
            // w_bits is **deliberately 0** for them.  A pre-GRP0
            // reader ignores the unknown flag bit and the unknown
            // section, and for uniform channel bitlengths with
            // byte-aligned groups the WCT0 payload size can coincide
            // with the per-layer expectation — the poisoned bits field
            // is what guarantees it fails its [1,16] range check
            // instead of silently mis-decoding channel-major codes as
            // row-major ones.
            // Non-uniform-codebook layers extend the poisoning to the
            // bits field itself: the stored payload is (sign, exponent)
            // fields, so a reader that would decode it at `bits` wide
            // uniform codes must be stopped at the [1,16] range check.
            // The true bitlength rides in CBK0.
            let poison_cbk = !l.codebook().is_uniform();
            let (w_bits, w_lmin, w_scale) = match &l.weights {
                WeightCodes::PerLayer(p) => {
                    (if poison_cbk { 0 } else { p.bits }, p.lmin, p.scale)
                }
                WeightCodes::PerChannel(g) => match g.spans.first() {
                    Some(s0) => (0, s0.lmin, s0.scale),
                    // Zero-channel groups can't come from the grouped
                    // constructors; keep serialization panic-free for
                    // hand-built records (every loader rejects the
                    // degenerate dout anyway).
                    None => (0, 0.0, 1.0),
                },
            };
            binio::put_u32(&mut lay, w_bits);
            binio::put_u32(&mut lay, l.a_bits);
            let mut flags = 0u8;
            if l.relu {
                flags |= LAYER_FLAG_RELU;
            }
            if l.act_range.is_some() {
                flags |= LAYER_FLAG_ACT_RANGE;
            }
            if l.granularity() == Granularity::PerOutputChannel {
                flags |= LAYER_FLAG_GROUPED;
            }
            if l.conv.is_some() {
                flags |= LAYER_FLAG_CONV;
            }
            if poison_cbk {
                flags |= LAYER_FLAG_CODEBOOK;
            }
            binio::put_u8(&mut lay, flags);
            binio::put_f32(&mut lay, w_lmin);
            binio::put_f32(&mut lay, w_scale);
            if let Some((lo, hi)) = l.act_range {
                binio::put_f32(&mut lay, lo);
                binio::put_f32(&mut lay, hi);
            }
        }

        let mut wct = Vec::new();
        for l in &self.layers {
            let payload = l.weights.payload();
            binio::put_u64(&mut wct, payload.len() as u64);
            wct.extend_from_slice(payload);
        }

        let mut bia = Vec::new();
        for l in &self.layers {
            binio::put_f32_slice(&mut bia, &l.bias);
        }

        let mut sections: Vec<(&[u8; 4], Vec<u8>)> = vec![
            (TAG_META, meta),
            (TAG_LAYERS, lay),
            (TAG_WCODES, wct),
            (TAG_BIASES, bia),
        ];
        // GRP0 rides along only when a layer actually is grouped, so
        // per-layer artifacts stay byte-identical to pre-GRP0 writers.
        if self.is_grouped() {
            let mut grp = Vec::new();
            binio::put_u32(&mut grp, self.layers.len() as u32);
            for l in &self.layers {
                match &l.weights {
                    WeightCodes::PerLayer(_) => binio::put_u8(&mut grp, 0),
                    WeightCodes::PerChannel(g) => {
                        binio::put_u8(&mut grp, 1);
                        binio::put_u32(&mut grp, g.n_groups() as u32);
                        // Non-uniform grouped layers poison every span
                        // bits field (the grouped analogue of the LAY0
                        // w_bits poison); CBK0 carries the real values.
                        let poison = !g.codebook.is_uniform();
                        for s in &g.spans {
                            binio::put_u32(&mut grp, if poison { 0 } else { s.bits });
                            binio::put_f32(&mut grp, s.lmin);
                            binio::put_f32(&mut grp, s.scale);
                        }
                    }
                }
            }
            sections.push((TAG_GROUPS, grp));
        }
        // CNV0 rides along only when a layer actually is a conv, so
        // dense artifacts stay byte-identical to pre-CNV0 writers.
        if self.is_conv() {
            let mut cnv = Vec::new();
            binio::put_u32(&mut cnv, self.layers.len() as u32);
            for l in &self.layers {
                match &l.conv {
                    None => binio::put_u8(&mut cnv, 0),
                    Some(g) => {
                        binio::put_u8(&mut cnv, 1);
                        binio::put_u64(&mut cnv, g.cin as u64);
                        binio::put_u64(&mut cnv, g.h as u64);
                        binio::put_u64(&mut cnv, g.w as u64);
                        binio::put_u32(&mut cnv, g.kh as u32);
                        binio::put_u32(&mut cnv, g.kw as u32);
                        binio::put_u32(&mut cnv, g.stride as u32);
                        binio::put_u32(&mut cnv, g.pad as u32);
                    }
                }
            }
            sections.push((TAG_CONV, cnv));
        }
        // CBK0 rides along only when a layer actually stores codes
        // under a non-uniform codebook, so uniform artifacts stay
        // byte-identical to pre-CBK0 writers.
        if self.has_codebook() {
            let mut cbk = Vec::new();
            binio::put_u32(&mut cbk, self.layers.len() as u32);
            for l in &self.layers {
                let cb = l.codebook();
                binio::put_u8(&mut cbk, cb.tag());
                if cb.is_uniform() {
                    continue;
                }
                match &l.weights {
                    WeightCodes::PerLayer(p) => binio::put_u32(&mut cbk, p.bits),
                    WeightCodes::PerChannel(g) => {
                        binio::put_u32(&mut cbk, g.n_groups() as u32);
                        for s in &g.spans {
                            binio::put_u32(&mut cbk, s.bits);
                        }
                    }
                }
            }
            sections.push((TAG_CODEBOOK, cbk));
        }
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        binio::put_u32(&mut out, VERSION);
        binio::put_u32(&mut out, 0); // flags (reserved)
        binio::put_u32(&mut out, sections.len() as u32);
        for (tag, payload) in &sections {
            write_section(&mut out, tag, payload);
        }
        out
    }

    /// Parse a BPMA byte stream (validated, checksummed,
    /// allocation-bounded — see the module docs).
    pub fn from_bytes(bytes: &[u8]) -> Result<Self> {
        // Pass 1: walk the section table, verify checksums, collect the
        // payload slice of each known section.  Unknown tags are
        // skipped — that is the forward-compatibility contract.
        let mut meta_pl: Option<&[u8]> = None;
        let mut lay_pl: Option<&[u8]> = None;
        let mut wct_pl: Option<&[u8]> = None;
        let mut bia_pl: Option<&[u8]> = None;
        let mut grp_pl: Option<&[u8]> = None;
        let mut cnv_pl: Option<&[u8]> = None;
        let mut cbk_pl: Option<&[u8]> = None;
        let mut r = parse_header(bytes)?;
        let n_sections = r.u32()? as usize;
        for _ in 0..n_sections {
            let (tag, payload) = read_section(&mut r)?;
            let slot = match &tag {
                t if t == TAG_META => Some(&mut meta_pl),
                t if t == TAG_LAYERS => Some(&mut lay_pl),
                t if t == TAG_WCODES => Some(&mut wct_pl),
                t if t == TAG_BIASES => Some(&mut bia_pl),
                t if t == TAG_GROUPS => Some(&mut grp_pl),
                t if t == TAG_CONV => Some(&mut cnv_pl),
                t if t == TAG_CODEBOOK => Some(&mut cbk_pl),
                _ => None, // unknown section: checksummed, then skipped
            };
            if let Some(slot) = slot {
                if slot.is_some() {
                    bail!("duplicate '{}' section", tag_str(&tag));
                }
                *slot = Some(payload);
            }
        }
        if !r.is_empty() {
            bail!("{} trailing bytes after the last section", r.remaining());
        }
        let missing = |t: &[u8; 4]| anyhow::anyhow!("missing '{}' section", tag_str(t));
        let meta_pl = meta_pl.ok_or_else(|| missing(TAG_META))?;
        let lay_pl = lay_pl.ok_or_else(|| missing(TAG_LAYERS))?;
        let wct_pl = wct_pl.ok_or_else(|| missing(TAG_WCODES))?;
        let bia_pl = bia_pl.ok_or_else(|| missing(TAG_BIASES))?;

        // Pass 2: decode in logical order (file order of the known
        // sections does not matter).
        let mut mr = Reader::new(meta_pl);
        let model = mr.str_u32().context("model name")?;
        let num_classes = mr.u32()? as usize;
        let n_layers = mr.u32()? as usize;
        if !mr.is_empty() {
            bail!("trailing bytes in '{}' section", tag_str(TAG_META));
        }
        if n_layers == 0 {
            bail!("artifact declares zero layers");
        }
        if num_classes == 0 {
            bail!("artifact declares zero classes");
        }

        // LAY0 — geometry/quant headers.  No pre-allocation from the
        // untrusted count: each iteration consumes bytes, so a hostile
        // n_layers fails on the first missing record.
        struct LayerHeader {
            name: String,
            din: usize,
            dout: usize,
            w_bits: u32,
            a_bits: u32,
            relu: bool,
            grouped: bool,
            conv: bool,
            cbk: bool,
            w_lmin: f32,
            w_scale: f32,
            act_range: Option<(f32, f32)>,
        }
        let mut lr = Reader::new(lay_pl);
        let mut headers: Vec<LayerHeader> = Vec::new();
        for i in 0..n_layers {
            let name = lr.str_u32().with_context(|| format!("layer {i} name"))?;
            let din = usize::try_from(lr.u64()?)
                .map_err(|_| anyhow::anyhow!("layer {i}: din does not fit in usize"))?;
            let dout = usize::try_from(lr.u64()?)
                .map_err(|_| anyhow::anyhow!("layer {i}: dout does not fit in usize"))?;
            let w_bits = lr.u32()?;
            let a_bits = lr.u32()?;
            let flags = lr.u8()?;
            let w_lmin = lr.f32()?;
            let w_scale = lr.f32()?;
            let act_range = if flags & LAYER_FLAG_ACT_RANGE != 0 {
                Some((lr.f32()?, lr.f32()?))
            } else {
                None
            };
            let conv = flags & LAYER_FLAG_CONV != 0;
            // Conv layers poison din as 0 (the real GEMM width comes
            // from CNV0); everything else with a zero dim is broken.
            // This same check is what rejects a conv artifact on a
            // pre-CNV0 reader, which does not know the flag.
            if dout == 0 || (din == 0 && !conv) {
                bail!("layer {i} ('{name}'): degenerate shape {din}x{dout}");
            }
            if conv && din != 0 {
                bail!(
                    "layer {i} ('{name}'): conv layers must write din as 0 \
                     (the GEMM width comes from the 'CNV0' geometry), got {din}"
                );
            }
            if let Some((lo, hi)) = act_range {
                // The one per-layer field PackedTensor::from_raw does
                // not cover: a NaN/inf or inverted range would load
                // fine and then silently quantize every activation to
                // code 0 at serve time.
                if !lo.is_finite() || !hi.is_finite() || lo > hi {
                    bail!("layer {i} ('{name}'): bad activation range [{lo}, {hi}]");
                }
            }
            headers.push(LayerHeader {
                name,
                din,
                dout,
                w_bits,
                a_bits,
                relu: flags & LAYER_FLAG_RELU != 0,
                grouped: flags & LAYER_FLAG_GROUPED != 0,
                conv,
                cbk: flags & LAYER_FLAG_CODEBOOK != 0,
                w_lmin,
                w_scale,
                act_range,
            });
        }
        if !lr.is_empty() {
            bail!("trailing bytes in '{}' section", tag_str(TAG_LAYERS));
        }

        // GRP0 — per-channel plan tables for grouped layers.  A layer
        // flagged grouped in LAY0 without a GRP0 section (or vice
        // versa) is unusable — fail loudly rather than mis-decode.
        let mut group_params: Vec<Option<Vec<(u32, f32, f32)>>> = vec![None; n_layers];
        if let Some(pl) = grp_pl {
            let mut gr = Reader::new(pl);
            let gn = gr.u32()? as usize;
            if gn != n_layers {
                bail!(
                    "'{}' section declares {gn} layers, '{}' declares {n_layers}",
                    tag_str(TAG_GROUPS),
                    tag_str(TAG_META)
                );
            }
            for (i, slot) in group_params.iter_mut().enumerate() {
                let flagged = gr.u8()?;
                if flagged > 1 {
                    bail!("layer {i}: bad group flag {flagged}");
                }
                if flagged == 0 {
                    continue;
                }
                let n_groups = gr.u32()? as usize;
                // No pre-allocation from the untrusted count: each
                // group record consumes 12 bytes, so a hostile count
                // fails on the first missing record.
                let mut params = Vec::new();
                for _ in 0..n_groups {
                    let bits = gr.u32()?;
                    let lmin = gr.f32()?;
                    let scale = gr.f32()?;
                    params.push((bits, lmin, scale));
                }
                *slot = Some(params);
            }
            if !gr.is_empty() {
                bail!("trailing bytes in '{}' section", tag_str(TAG_GROUPS));
            }
        }
        for (i, (h, gp)) in headers.iter().zip(&group_params).enumerate() {
            if h.grouped != gp.is_some() {
                bail!(
                    "layer {i} ('{}'): grouped flag disagrees with the '{}' section \
                     (grouped artifacts need a reader that speaks GRP0)",
                    h.name,
                    tag_str(TAG_GROUPS)
                );
            }
        }

        // CBK0 — per-layer weight codebooks + the true bitlengths the
        // poisoned LAY0/GRP0 fields defer to.  The codebook flag and
        // the section must agree both ways: a flagged layer without an
        // entry (or a non-uniform entry on an unflagged layer) would
        // mis-decode the packed fields — fail loudly.
        let mut codebooks: Vec<(Codebook, Vec<u32>)> =
            vec![(Codebook::Uniform, Vec::new()); n_layers];
        if let Some(pl) = cbk_pl {
            let mut kr = Reader::new(pl);
            let kn = kr.u32()? as usize;
            if kn != n_layers {
                bail!(
                    "'{}' section declares {kn} layers, '{}' declares {n_layers}",
                    tag_str(TAG_CODEBOOK),
                    tag_str(TAG_META)
                );
            }
            let mut any = false;
            for (i, slot) in codebooks.iter_mut().enumerate() {
                let tag = kr.u8()?;
                let cb = Codebook::from_tag(tag)
                    .ok_or_else(|| anyhow::anyhow!("layer {i}: unknown codebook tag {tag}"))?;
                if cb.is_uniform() {
                    continue;
                }
                any = true;
                // Per-layer entries carry one bitlength; grouped
                // entries a count + one per group.  No pre-allocation
                // from the untrusted count (each record consumes 4
                // bytes, so a hostile count fails on the first missing
                // one); values are range-checked by from_raw_cbk.
                let bits = if headers[i].grouped {
                    let ng = kr.u32()? as usize;
                    let mut v = Vec::new();
                    for _ in 0..ng {
                        v.push(kr.u32()?);
                    }
                    v
                } else {
                    vec![kr.u32()?]
                };
                *slot = (cb, bits);
            }
            if !kr.is_empty() {
                bail!("trailing bytes in '{}' section", tag_str(TAG_CODEBOOK));
            }
            if !any {
                bail!(
                    "'{}' section present but every layer is uniform \
                     (writers omit the section entirely)",
                    tag_str(TAG_CODEBOOK)
                );
            }
        }
        for (i, (h, (cb, _))) in headers.iter().zip(&codebooks).enumerate() {
            if h.cbk == cb.is_uniform() {
                bail!(
                    "layer {i} ('{}'): codebook flag disagrees with the '{}' section \
                     (codebook artifacts need a reader that speaks CBK0)",
                    h.name,
                    tag_str(TAG_CODEBOOK)
                );
            }
        }

        // CNV0 — conv geometries.  A layer flagged conv in LAY0 without
        // a CNV0 entry (or vice versa) is unusable: the GEMM width is
        // only derivable from the geometry — fail loudly.
        let mut conv_geoms: Vec<Option<ConvGeom>> = vec![None; n_layers];
        if let Some(pl) = cnv_pl {
            let mut cr = Reader::new(pl);
            let cn = cr.u32()? as usize;
            if cn != n_layers {
                bail!(
                    "'{}' section declares {cn} layers, '{}' declares {n_layers}",
                    tag_str(TAG_CONV),
                    tag_str(TAG_META)
                );
            }
            for (i, slot) in conv_geoms.iter_mut().enumerate() {
                let kind = cr.u8()?;
                if kind > 1 {
                    bail!("layer {i}: bad conv kind {kind}");
                }
                if kind == 0 {
                    continue;
                }
                let as_usize = |v: u64, what: &str| {
                    usize::try_from(v).map_err(|_| {
                        anyhow::anyhow!("layer {i}: conv {what} does not fit in usize")
                    })
                };
                let cin = as_usize(cr.u64()?, "cin")?;
                let h = as_usize(cr.u64()?, "h")?;
                let w = as_usize(cr.u64()?, "w")?;
                let kh = cr.u32()? as usize;
                let kw = cr.u32()? as usize;
                let stride = cr.u32()? as usize;
                let pad = cr.u32()? as usize;
                *slot = Some(ConvGeom {
                    cin,
                    h,
                    w,
                    cout: headers[i].dout,
                    kh,
                    kw,
                    stride,
                    pad,
                });
            }
            if !cr.is_empty() {
                bail!("trailing bytes in '{}' section", tag_str(TAG_CONV));
            }
        }
        for (i, (h, cg)) in headers.iter_mut().zip(&conv_geoms).enumerate() {
            if h.conv != cg.is_some() {
                bail!(
                    "layer {i} ('{}'): conv flag disagrees with the '{}' section \
                     (conv artifacts need a reader that speaks CNV0)",
                    h.name,
                    tag_str(TAG_CONV)
                );
            }
            if let Some(g) = cg {
                g.validate(&h.name)?;
                // The poisoned LAY0 din resolves to the im2col patch
                // length — the GEMM width every payload check uses.
                h.din = g.patch_len();
            }
        }

        // WCT0 + BIA0 — payloads, validated against the geometry.
        let mut wr = Reader::new(wct_pl);
        let mut br = Reader::new(bia_pl);
        let mut layers = Vec::new();
        for (i, (((h, gp), cg), (cb, cb_bits))) in headers
            .into_iter()
            .zip(group_params)
            .zip(conv_geoms)
            .zip(codebooks)
            .enumerate()
        {
            let code_len = wr
                .len_u64()
                .with_context(|| format!("layer {i} ('{}') code length", h.name))?;
            let data = wr.take(code_len)?.to_vec();
            let weights = match gp {
                None => {
                    let elems = binio::checked_product(&[h.din, h.dout])?;
                    // A non-uniform layer must have poisoned its LAY0
                    // bits field; the true bitlength comes from CBK0.
                    let w_bits = if cb.is_uniform() {
                        h.w_bits
                    } else {
                        if h.w_bits != 0 {
                            bail!(
                                "layer {i} ('{}'): non-uniform-codebook layers must \
                                 write LAY0 w_bits as 0 (the bitlength comes from \
                                 '{}'), got {}",
                                h.name,
                                tag_str(TAG_CODEBOOK),
                                h.w_bits
                            );
                        }
                        cb_bits[0]
                    };
                    WeightCodes::PerLayer(
                        PackedTensor::from_raw_cbk(
                            w_bits, cb, elems, h.w_lmin, h.w_scale, data,
                        )
                        .with_context(|| {
                            format!("layer {i} ('{}') weight codes", h.name)
                        })?,
                    )
                }
                Some(mut params) => {
                    if params.len() != h.dout {
                        bail!(
                            "layer {i} ('{}'): {} channel plans for {} output channels",
                            h.name,
                            params.len(),
                            h.dout
                        );
                    }
                    if !cb.is_uniform() {
                        if cb_bits.len() != params.len() {
                            bail!(
                                "layer {i} ('{}'): '{}' declares {} group bitlengths, \
                                 '{}' declares {} groups",
                                h.name,
                                tag_str(TAG_CODEBOOK),
                                cb_bits.len(),
                                tag_str(TAG_GROUPS),
                                params.len()
                            );
                        }
                        for (g, (p, &b)) in params.iter_mut().zip(&cb_bits).enumerate() {
                            if p.0 != 0 {
                                bail!(
                                    "layer {i} ('{}') group {g}: non-uniform-codebook \
                                     layers must write GRP0 bits as 0 (the bitlengths \
                                     come from '{}'), got {}",
                                    h.name,
                                    tag_str(TAG_CODEBOOK),
                                    p.0
                                );
                            }
                            p.0 = b;
                        }
                    }
                    let groups = PackedGroups::from_raw_cbk(h.din, cb, &params, data)
                        .with_context(|| {
                            format!("layer {i} ('{}') grouped weight codes", h.name)
                        })?;
                    WeightCodes::PerChannel(groups)
                }
            };
            let bias = br.f32_vec(h.dout)
                .with_context(|| format!("layer {i} ('{}') bias", h.name))?;
            if let Some(bad) = bias.iter().find(|b| !b.is_finite()) {
                bail!("layer {i} ('{}'): non-finite bias value {bad}", h.name);
            }
            layers.push(LayerRecord {
                name: h.name,
                din: h.din,
                dout: h.dout,
                a_bits: h.a_bits,
                relu: h.relu,
                act_range: h.act_range,
                weights,
                bias,
                conv: cg,
            });
        }
        if !wr.is_empty() {
            bail!("trailing bytes in '{}' section", tag_str(TAG_WCODES));
        }
        if !br.is_empty() {
            bail!("trailing bytes in '{}' section", tag_str(TAG_BIASES));
        }

        // Cross-layer consistency: flattened features chain layer to
        // layer (a conv layer emits `cout·out_h·out_w`, consumes
        // `cin·h·w` — layer-kind agnostic).
        for w in layers.windows(2) {
            if w[0].out_features() != w[1].in_features() {
                bail!(
                    "layer chain broken: '{}' emits {} features, '{}' expects {}",
                    w[0].name,
                    w[0].out_features(),
                    w[1].name,
                    w[1].in_features()
                );
            }
        }
        let last_out = layers.last().unwrap().out_features();
        if last_out != num_classes {
            bail!("final layer emits {last_out} features but artifact declares {num_classes} classes");
        }

        Ok(Self { model, num_classes, layers })
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_bytes())
            .with_context(|| format!("writing artifact '{}'", path.display()))
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading artifact '{}'", path.display()))?;
        Self::from_bytes(&bytes)
            .with_context(|| format!("parsing artifact '{}'", path.display()))
    }
}

fn mean(it: impl Iterator<Item = f64>) -> f64 {
    let (mut sum, mut n) = (0.0, 0usize);
    for v in it {
        sum += v;
        n += 1;
    }
    if n == 0 {
        0.0
    } else {
        sum / n as f64
    }
}

fn tag_str(tag: &[u8; 4]) -> String {
    tag.iter()
        .map(|&b| if b.is_ascii_graphic() { b as char } else { '?' })
        .collect()
}

fn write_section(out: &mut Vec<u8>, tag: &[u8; 4], payload: &[u8]) {
    out.extend_from_slice(tag);
    binio::put_u64(out, payload.len() as u64);
    out.extend_from_slice(payload);
    binio::put_u32(out, binio::crc32(payload));
}

/// Validate magic + version, consume the flags word, and leave the
/// reader positioned at the section count.
fn parse_header(bytes: &[u8]) -> Result<Reader<'_>> {
    let mut r = Reader::new(bytes);
    if r.take(4)? != MAGIC {
        bail!("not a BPMA artifact (bad magic)");
    }
    let version = r.u32()?;
    if version != VERSION {
        bail!("unsupported BPMA version {version} (this reader speaks {VERSION})");
    }
    let _flags = r.u32()?; // reserved; unknown bits are ignored
    Ok(r)
}

/// Walk one `tag | len | payload | crc` frame without judging the
/// checksum — the single place the section framing is parsed, shared
/// by the loader ([`read_section`]) and [`section_table`].
fn read_raw_section<'a>(r: &mut Reader<'a>) -> Result<([u8; 4], &'a [u8], u32)> {
    let tag: [u8; 4] = r.take(4)?.try_into().unwrap();
    let len = r
        .len_u64()
        .with_context(|| format!("'{}' section length", tag_str(&tag)))?;
    let payload = r.take(len)?;
    let stored = r.u32()?;
    Ok((tag, payload, stored))
}

/// Read one section, verifying the CRC.
fn read_section<'a>(r: &mut Reader<'a>) -> Result<([u8; 4], &'a [u8])> {
    let (tag, payload, stored) = read_raw_section(r)?;
    let actual = binio::crc32(payload);
    if stored != actual {
        bail!(
            "'{}' section checksum mismatch (stored {stored:#010x}, computed {actual:#010x})",
            tag_str(&tag)
        );
    }
    Ok((tag, payload))
}

// ---------------------------------------------------------------------------
// inspection (the `bitprune inspect` surface)
// ---------------------------------------------------------------------------

/// One row of the section table, as `bitprune inspect` prints it.
#[derive(Debug, Clone)]
pub struct SectionInfo {
    /// Four-char tag (non-printable bytes shown as `?`).
    pub tag: String,
    /// Byte offset of the payload within the file.
    pub payload_offset: usize,
    pub payload_len: usize,
    pub crc_stored: u32,
    pub crc_ok: bool,
    /// Whether this reader knows the tag (unknown = skipped on load).
    pub known: bool,
}

/// Walk the section table of a BPMA byte stream without decoding the
/// payloads — reports every section's tag, span and checksum status,
/// including sections this version does not understand.
pub fn section_table(bytes: &[u8]) -> Result<Vec<SectionInfo>> {
    let mut r = parse_header(bytes)?;
    let n_sections = r.u32()? as usize;
    let mut out = Vec::new();
    for _ in 0..n_sections {
        let (tag, payload, crc_stored) = read_raw_section(&mut r)?;
        // The cursor now sits just past the 4-byte CRC that follows
        // the payload.
        let payload_offset = r.pos() - 4 - payload.len();
        out.push(SectionInfo {
            tag: tag_str(&tag),
            payload_offset,
            payload_len: payload.len(),
            crc_stored,
            crc_ok: binio::crc32(payload) == crc_stored,
            known: [
                TAG_META,
                TAG_LAYERS,
                TAG_WCODES,
                TAG_BIASES,
                TAG_GROUPS,
                TAG_CONV,
                TAG_CODEBOOK,
            ]
            .iter()
            .any(|t| **t == tag),
        });
    }
    if !r.is_empty() {
        bail!("{} trailing bytes after the last section", r.remaining());
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::synthetic_net;
    use crate::util::binio::{crc32, put_u32, put_u64};
    use crate::util::rng::Rng;

    fn demo_artifact() -> Artifact {
        freeze(&synthetic_net(&[6, 10, 4], 0xA47, 3, 5), "demo")
    }

    #[test]
    fn roundtrip_preserves_every_field() {
        let a = demo_artifact();
        let b = Artifact::from_bytes(&a.to_bytes()).unwrap();
        assert_eq!(a.model, b.model);
        assert_eq!(a.num_classes, b.num_classes);
        assert_eq!(a.layers.len(), b.layers.len());
        for (x, y) in a.layers.iter().zip(&b.layers) {
            assert_eq!(x.name, y.name);
            assert_eq!((x.din, x.dout), (y.din, y.dout));
            assert_eq!(x.a_bits, y.a_bits);
            assert_eq!(x.relu, y.relu);
            assert_eq!(x.act_range, y.act_range);
            assert_eq!(x.weights, y.weights);
            assert_eq!(x.bias, y.bias);
        }
        assert!(b.is_calibrated());
        assert!(b.packed_bytes() < b.f32_bytes());
        assert!(b.mean_w_bits() > 0.0 && b.mean_a_bits() > 0.0);
    }

    #[test]
    fn instantiate_matches_source_net_bitwise() {
        let net = synthetic_net(&[8, 14, 3], 0xFE1, 4, 6);
        let art = freeze(&net, "m");
        let rebuilt = Artifact::from_bytes(&art.to_bytes())
            .unwrap()
            .instantiate()
            .unwrap();
        let mut rng = Rng::new(0x1057);
        for &n in &[1usize, 5, 16] {
            let x: Vec<f32> = (0..n * 8).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let want = net.forward(&x, n);
            let got = rebuilt.forward(&x, n);
            assert_eq!(want.len(), got.len());
            assert!(
                want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "instantiated net diverged at batch {n}"
            );
        }
    }

    #[test]
    fn unknown_sections_are_skipped() {
        // Forward compatibility: a newer writer appends a section this
        // reader does not know — it must load anyway (checksum still
        // verified), and the section table must list it as unknown.
        let a = demo_artifact();
        let mut bytes = a.to_bytes();
        // Bump section_count (offset 12) and append an unknown section.
        let count_off = 12;
        let old = u32::from_le_bytes(bytes[count_off..count_off + 4].try_into().unwrap());
        bytes[count_off..count_off + 4].copy_from_slice(&(old + 1).to_le_bytes());
        let payload = b"future-extension";
        bytes.extend_from_slice(b"XTN9");
        put_u64(&mut bytes, payload.len() as u64);
        bytes.extend_from_slice(payload);
        put_u32(&mut bytes, crc32(payload));

        let b = Artifact::from_bytes(&bytes).unwrap();
        assert_eq!(b.layers.len(), a.layers.len());
        let table = section_table(&bytes).unwrap();
        assert_eq!(table.len(), old as usize + 1);
        let last = table.last().unwrap();
        assert_eq!(last.tag, "XTN9");
        assert!(!last.known);
        assert!(last.crc_ok);
        // A corrupted unknown section still fails the load.
        let n = bytes.len();
        bytes[n - 6] ^= 0x40; // inside the unknown payload
        assert!(Artifact::from_bytes(&bytes).is_err());
    }

    #[test]
    fn header_and_structure_validation() {
        let good = demo_artifact().to_bytes();
        // Bad magic.
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(Artifact::from_bytes(&bad).is_err());
        // Bad version.
        let mut bad = good.clone();
        bad[4..8].copy_from_slice(&99u32.to_le_bytes());
        assert!(Artifact::from_bytes(&bad).is_err());
        // Trailing garbage.
        let mut bad = good.clone();
        bad.push(0);
        assert!(Artifact::from_bytes(&bad).is_err());
        // Empty input.
        assert!(Artifact::from_bytes(&[]).is_err());
    }

    #[test]
    fn uncalibrated_and_empty_edge_cases() {
        // A net without calibrated ranges freezes and roundtrips with
        // act_range = None.
        let mut net = synthetic_net(&[4, 6, 2], 3, 4, 4);
        for l in &mut net.layers {
            // synthetic_net calibrates; strip it via a fresh layer.
            let d = l.as_dense().unwrap();
            let stripped = IntDense::from_packed(
                &d.name,
                d.packed_per_layer().unwrap().clone(),
                d.din,
                d.dout,
                d.bias.clone(),
                d.a_bits,
                d.relu,
                None,
            )
            .unwrap();
            *l = stripped.into();
        }
        let art = freeze(&net, "uncal");
        assert!(!art.is_calibrated());
        let rt = Artifact::from_bytes(&art.to_bytes()).unwrap();
        assert!(!rt.is_calibrated());
        assert!(rt.layers.iter().all(|l| l.act_range.is_none()));
    }

    #[test]
    fn conv_artifact_roundtrips_and_instantiates_bitwise() {
        let net = crate::serve::synthetic_conv_net(0xC047, 4, 5);
        let art = freeze(&net, "convy");
        assert!(art.is_conv());
        let bytes = art.to_bytes();
        // The wire carries a CNV0 section, and conv LAY0 dins are
        // poisoned to 0 on the wire while the decoded record resolves
        // to the im2col patch length.
        let table = section_table(&bytes).unwrap();
        assert!(table.iter().any(|s| s.tag == "CNV0" && s.known && s.crc_ok));
        let rt = Artifact::from_bytes(&bytes).unwrap();
        assert_eq!(rt.layers.len(), art.layers.len());
        for (x, y) in art.layers.iter().zip(&rt.layers) {
            assert_eq!(x.conv, y.conv);
            assert_eq!((x.din, x.dout), (y.din, y.dout));
            assert_eq!(x.weights, y.weights);
        }
        let g0 = rt.layers[0].conv.unwrap();
        assert_eq!(rt.layers[0].din, g0.patch_len());
        // Instantiated net forwards bit-identically to the source.
        let rebuilt = rt.instantiate().unwrap();
        let mut rng = Rng::new(0x1C47);
        let x: Vec<f32> =
            (0..3 * net.in_features()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let want = net.forward(&x, 3);
        let got = rebuilt.forward(&x, 3);
        assert!(want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn dense_artifact_bytes_carry_no_conv_section() {
        // Backward compat: dense models must stay byte-identical to
        // pre-CNV0 writers — no CNV0 section, no poisoned dins.
        let a = demo_artifact();
        assert!(!a.is_conv());
        let table = section_table(&a.to_bytes()).unwrap();
        assert!(table.iter().all(|s| s.tag != "CNV0"));
    }

    /// Locate a section's table entry by tag.
    fn find_section(bytes: &[u8], tag: &str) -> SectionInfo {
        section_table(bytes)
            .unwrap()
            .into_iter()
            .find(|s| s.tag == tag)
            .unwrap_or_else(|| panic!("no '{tag}' section"))
    }

    /// Overwrite one payload byte and recompute the section CRC, so
    /// the tamper reaches the structural validation behind it.
    fn patch_payload(bytes: &mut [u8], tag: &str, off: usize, val: u8) {
        let s = find_section(bytes, tag);
        bytes[s.payload_offset + off] = val;
        let crc = crc32(&bytes[s.payload_offset..s.payload_offset + s.payload_len]);
        let crc_off = s.payload_offset + s.payload_len;
        bytes[crc_off..crc_off + 4].copy_from_slice(&crc.to_le_bytes());
    }

    /// Remove a whole section frame (tag | len | payload | crc) and
    /// decrement the header's section count.
    fn splice_out(bytes: &mut Vec<u8>, tag: &str) {
        let s = find_section(bytes, tag);
        bytes.drain(s.payload_offset - 12..s.payload_offset + s.payload_len + 4);
        let count_off = 12;
        let old =
            u32::from_le_bytes(bytes[count_off..count_off + 4].try_into().unwrap());
        bytes[count_off..count_off + 4].copy_from_slice(&(old - 1).to_le_bytes());
    }

    fn cbk_artifact(cbk: Codebook) -> (crate::infer::IntNet, Vec<u8>) {
        // Even layers per-layer, odd layers grouped — both shift-plan
        // shapes cross the wire.
        let net = crate::serve::synthetic_net_cbk(&[6, 10, 8, 4], 0xCB8, 3, 5, cbk);
        let bytes = freeze(&net, "cbk").to_bytes();
        (net, bytes)
    }

    #[test]
    fn codebook_artifact_roundtrips_and_instantiates_bitwise() {
        for cbk in [Codebook::PowerOfTwo, Codebook::AdditivePot2] {
            let (net, bytes) = cbk_artifact(cbk);
            let table = section_table(&bytes).unwrap();
            assert!(table.iter().any(|s| s.tag == "CBK0" && s.known && s.crc_ok));
            let rt = Artifact::from_bytes(&bytes).unwrap();
            assert_eq!(rt.layers.len(), net.layers.len());
            for (l, src) in rt.layers.iter().zip(&net.layers) {
                assert_eq!(l.codebook(), cbk);
                assert_eq!(l.weights, *src.weights());
            }
            // The instantiated net re-engages the shift-add GEMM and
            // forwards bit-identically to the source.
            let rebuilt = rt.instantiate().unwrap();
            for l in &rebuilt.layers {
                assert_eq!(l.codebook(), cbk);
                assert!(l.as_dense().unwrap().uses_shift_gemm());
            }
            let mut rng = Rng::new(0x2CB8);
            let x: Vec<f32> = (0..4 * 6).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let want = net.forward(&x, 4);
            let got = rebuilt.forward(&x, 4);
            assert!(
                want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()),
                "codebook {cbk:?} instantiation diverged"
            );
        }
    }

    #[test]
    fn conv_codebook_artifact_carries_both_sections() {
        // CNV0 and CBK0 compose: a PoT conv net roundtrips bitwise with
        // both poisoning schemes (din = 0 and w_bits = 0) in play.
        let net =
            crate::serve::synthetic_conv_net_cbk(0xC0DE, 4, 5, Codebook::PowerOfTwo);
        let bytes = freeze(&net, "convcbk").to_bytes();
        let table = section_table(&bytes).unwrap();
        assert!(table.iter().any(|s| s.tag == "CNV0" && s.known && s.crc_ok));
        assert!(table.iter().any(|s| s.tag == "CBK0" && s.known && s.crc_ok));
        let rebuilt = Artifact::from_bytes(&bytes).unwrap().instantiate().unwrap();
        let mut rng = Rng::new(0x3C0);
        let x: Vec<f32> =
            (0..2 * net.in_features()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let want = net.forward(&x, 2);
        let got = rebuilt.forward(&x, 2);
        assert!(want.iter().zip(&got).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn uniform_artifact_bytes_carry_no_codebook_section() {
        // Backward compat: uniform models must stay byte-identical to
        // pre-CBK0 writers — no CBK0 section, no poisoned bits.
        let a = demo_artifact();
        let table = section_table(&a.to_bytes()).unwrap();
        assert!(table.iter().all(|s| s.tag != "CBK0"));
        // And routing a uniform build through the codebook constructors
        // changes nothing on the wire.
        let plain = freeze(&crate::serve::synthetic_conv_net(0xC047, 4, 5), "m");
        let uni = freeze(
            &crate::serve::synthetic_conv_net_cbk(0xC047, 4, 5, Codebook::Uniform),
            "m",
        );
        assert_eq!(plain.to_bytes(), uni.to_bytes());
    }

    #[test]
    fn codebook_section_tampering_is_rejected() {
        let (_, good) = cbk_artifact(Codebook::PowerOfTwo);

        // Corrupted CBK0 payload byte (stale CRC) fails the checksum.
        let mut bad = good.clone();
        let s = find_section(&bad, "CBK0");
        bad[s.payload_offset + 4] ^= 0x40;
        let err = Artifact::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("checksum"), "unexpected error: {err}");

        // Spliced-out CBK0: the LAY0 flag bit survives, so the loader
        // refuses rather than decoding shift fields as uniform codes.
        let mut bad = good.clone();
        splice_out(&mut bad, "CBK0");
        let err = Artifact::from_bytes(&bad).unwrap_err().to_string();
        assert!(
            err.contains("codebook flag disagrees"),
            "unexpected error: {err}"
        );

        // Unknown codebook tag (layer 0's tag byte, after n_layers u32).
        let mut bad = good.clone();
        patch_payload(&mut bad, "CBK0", 4, 3);
        let err = Artifact::from_bytes(&bad).unwrap_err().to_string();
        assert!(err.contains("unknown codebook tag 3"), "unexpected error: {err}");

        // Un-poisoned LAY0 w_bits on a non-uniform layer.  Layer 0
        // ("fc0", per-layer PoT) stores w_bits at payload offset
        // 4+3 (name) + 8 (din) + 8 (dout) = 23.
        let mut bad = good.clone();
        patch_payload(&mut bad, "LAY0", 23, 3);
        let err = Artifact::from_bytes(&bad).unwrap_err().to_string();
        assert!(
            err.contains("must write LAY0 w_bits as 0"),
            "unexpected error: {err}"
        );

        // Cleared codebook flag with the CBK0 entry still present — the
        // cross-check fires in the other direction.  Layer 0's flags
        // byte sits after w_bits + a_bits, at offset 31.
        let mut bad = good.clone();
        let s = find_section(&bad, "LAY0");
        let flags = bad[s.payload_offset + 31];
        patch_payload(&mut bad, "LAY0", 31, flags & !LAYER_FLAG_CODEBOOK);
        let err = Artifact::from_bytes(&bad).unwrap_err().to_string();
        assert!(
            err.contains("codebook flag disagrees"),
            "unexpected error: {err}"
        );

        // An all-uniform CBK0 forged onto a uniform artifact: writers
        // never emit it, so readers reject it outright.
        let mut bad = demo_artifact().to_bytes();
        let count_off = 12;
        let old =
            u32::from_le_bytes(bad[count_off..count_off + 4].try_into().unwrap());
        bad[count_off..count_off + 4].copy_from_slice(&(old + 1).to_le_bytes());
        let mut payload = Vec::new();
        put_u32(&mut payload, 2); // demo net has two layers
        payload.push(0);
        payload.push(0);
        bad.extend_from_slice(b"CBK0");
        put_u64(&mut bad, payload.len() as u64);
        bad.extend_from_slice(&payload);
        put_u32(&mut bad, crc32(&payload));
        let err = Artifact::from_bytes(&bad).unwrap_err().to_string();
        assert!(
            err.contains("every layer is uniform"),
            "unexpected error: {err}"
        );
    }
}
