//! The deployment subsystem: frozen model artifacts + a versioned
//! registry with zero-downtime hot-swap.
//!
//! Training produces an in-process [`crate::infer::IntNet`]; this
//! module makes that net an *operable asset*:
//!
//! * [`artifact`] — the **BPMA** single-file format.  [`freeze`]
//!   captures a net's packed weight codes, learned bitlengths,
//!   quantization parameters, biases and calibrated activation ranges;
//!   [`Artifact::save`]/[`Artifact::load`] move it through a validated,
//!   checksummed, allocation-bounded byte format; and
//!   [`Artifact::instantiate`] rebuilds the net **bit-identically**
//!   with no dataset, trainer or PJRT runtime in memory.
//! * [`registry`] — [`ModelRegistry`], a versioned store with atomic
//!   publish, drain semantics (in-flight batches finish on the version
//!   they resolved) and rollback to any retained version.  The serving
//!   loop (`serve::Server`) resolves its net through a registry once
//!   per batch, which is what makes a live swap invisible to clients.
//!   Canary staging ([`ModelRegistry::begin_canary`] → promote or
//!   auto-rollback) and endpoint drain mode gate the control plane
//!   with typed [`RegistryError`]s.
//!
//! CLI surface: `bitprune export` (train/checkpoint → `.bpma`),
//! `bitprune inspect` (section table, bitlengths, footprint),
//! `bitprune serve --model a.bpma [--swap-to b.bpma --swap-after N]`
//! (serve an artifact; demonstrate a mid-traffic swap).

pub mod artifact;
pub mod registry;

pub use artifact::{freeze, section_table, Artifact, LayerRecord, SectionInfo};
pub use registry::{ModelRegistry, ModelVersion, RegistryError, DEFAULT_RETAIN};
