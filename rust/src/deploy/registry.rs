//! Versioned model registry: the control plane for zero-downtime
//! serving.
//!
//! A [`ModelRegistry`] maps a serving endpoint onto a sequence of
//! published model versions.  Publishing is **atomic** — a single
//! pointer swap under a write lock — and readers ([`current`]) take a
//! cheap `Arc` clone, so:
//!
//! * a batch that resolved version *N* keeps executing on *N* even if
//!   *N+1* is published mid-forward (the `Arc` keeps the old net alive
//!   until the last in-flight batch drops it — that is the **drain**
//!   semantics: no request is interrupted, dropped or served by a
//!   half-swapped model);
//! * new batch resolutions after the swap see *N+1* immediately;
//! * any retained version can be made current again ([`rollback`]).
//!
//! Shape compatibility is enforced at publish time (same input/output
//! dimensionality as the registry was created with), which is what lets
//! `serve::Server` keep handing out stable request/response dims across
//! swaps.
//!
//! Beyond plain publish/rollback, the registry understands two fleet
//! states that gate the control plane:
//!
//! * **Canary** ([`begin_canary`]): a staged version that receives a
//!   traffic split but is *not* active.  While a canary is in flight,
//!   publish and rollback are refused ([`RegistryError::CanaryActive`])
//!   so the experiment has a stable incumbent to compare against; the
//!   canary resolves via [`promote_canary`] (becomes active) or
//!   [`end_canary`] (rolled back, incumbent untouched).
//! * **Draining** ([`begin_drain`]): the endpoint is shutting down —
//!   [`current`] keeps serving in-flight traffic, but publishing or
//!   staging new versions is refused ([`RegistryError::Draining`]).
//!   Rollback and canary resolution stay allowed: they are how an
//!   operator lands a misbehaving fleet, not new work.
//!
//! All control-plane failures are typed ([`RegistryError`]) so callers
//! can distinguish "retry later" from "operator error".
//!
//! [`current`]: ModelRegistry::current
//! [`rollback`]: ModelRegistry::rollback
//! [`begin_canary`]: ModelRegistry::begin_canary
//! [`promote_canary`]: ModelRegistry::promote_canary
//! [`end_canary`]: ModelRegistry::end_canary
//! [`begin_drain`]: ModelRegistry::begin_drain

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, RwLock};

use crate::infer::IntNet;

/// How many published versions a registry keeps around for rollback
/// when no explicit limit is given.
pub const DEFAULT_RETAIN: usize = 4;

/// Typed control-plane failure.  Everything here is an *operator*
/// outcome, not a serving fault: the active version keeps serving
/// regardless.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RegistryError {
    /// Refusing an empty network.
    EmptyNet,
    /// Zero input or output dimensionality.
    DegenerateShape { din: usize, out_dim: usize },
    /// `retain` must be at least 1.
    BadRetain,
    /// Published model's shape does not match the endpoint's.
    ShapeMismatch { din: usize, out_dim: usize, want_din: usize, want_out: usize },
    /// The requested version was never published or has been trimmed
    /// out of the retention window.
    NotRetained { version: u64, retained: Vec<u64> },
    /// A canary is in flight; publish/rollback would invalidate the
    /// experiment.  Promote or end the canary first.
    CanaryActive { canary: u64 },
    /// The version is not the in-flight canary (or no canary is
    /// active).
    NotCanary { version: u64, canary: Option<u64> },
    /// The endpoint is draining: no new versions are accepted.
    Draining,
}

impl std::fmt::Display for RegistryError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Self::EmptyNet => write!(f, "registry: refusing an empty network"),
            Self::DegenerateShape { din, out_dim } => {
                write!(f, "registry: degenerate network shape ({din} in, {out_dim} out)")
            }
            Self::BadRetain => write!(f, "registry: retain must be at least 1"),
            Self::ShapeMismatch { din, out_dim, want_din, want_out } => write!(
                f,
                "registry: published model is {din}->{out_dim} but this endpoint serves {want_din}->{want_out}"
            ),
            Self::NotRetained { version, retained } => {
                write!(f, "registry: version {version} is not retained (have {retained:?})")
            }
            Self::CanaryActive { canary } => write!(
                f,
                "registry: canary v{canary} is in flight — promote or end it before changing versions"
            ),
            Self::NotCanary { version, canary } => write!(
                f,
                "registry: v{version} is not the in-flight canary (canary: {canary:?})"
            ),
            Self::Draining => {
                write!(f, "registry: endpoint is draining — no new versions accepted")
            }
        }
    }
}

impl std::error::Error for RegistryError {}

/// One published model version (immutable once published).
pub struct ModelVersion {
    /// Monotonically increasing, starting at 1; never reused, even
    /// after rollback (rolling back re-activates the old version id).
    pub version: u64,
    /// Operator-facing label (e.g. the artifact path it came from).
    pub label: String,
    pub net: Arc<IntNet>,
}

struct Inner {
    active: Arc<ModelVersion>,
    /// Every retained version, oldest first (always contains `active`).
    retained: Vec<Arc<ModelVersion>>,
    next_version: u64,
    /// Version id of the in-flight canary, if any.  The canary is
    /// retained but *not* active; trim never removes it.
    canary: Option<u64>,
}

/// Thread-safe name→versioned-model store with atomic hot-swap.
pub struct ModelRegistry {
    /// Input dimensionality every version must accept.
    din: usize,
    /// Output dimensionality every version must emit.
    out_dim: usize,
    retain: usize,
    draining: AtomicBool,
    inner: RwLock<Inner>,
}

impl ModelRegistry {
    /// Create a registry with `net` as version 1.  The net fixes the
    /// endpoint's input/output shape; later publishes must match it.
    pub fn new(net: Arc<IntNet>, label: &str) -> Result<Self, RegistryError> {
        Self::with_retain(net, label, DEFAULT_RETAIN)
    }

    /// [`Self::new`] with an explicit rollback-retention depth
    /// (`retain >= 1`; the active version is always retained).
    pub fn with_retain(
        net: Arc<IntNet>,
        label: &str,
        retain: usize,
    ) -> Result<Self, RegistryError> {
        if retain == 0 {
            return Err(RegistryError::BadRetain);
        }
        let (din, out_dim) = endpoint_shape(&net)?;
        let v1 = Arc::new(ModelVersion { version: 1, label: label.to_string(), net });
        Ok(Self {
            din,
            out_dim,
            retain,
            draining: AtomicBool::new(false),
            inner: RwLock::new(Inner {
                active: Arc::clone(&v1),
                retained: vec![v1],
                next_version: 2,
                canary: None,
            }),
        })
    }

    /// Input dimensionality every served request must carry.
    pub fn input_dim(&self) -> usize {
        self.din
    }

    /// Logits dimensionality every response carries.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// The active version — an `Arc` clone, so the caller's view is
    /// stable for as long as it holds it regardless of later swaps.
    pub fn current(&self) -> Arc<ModelVersion> {
        Arc::clone(&self.read().active)
    }

    /// Look up a retained version by id (canaries resolve here too).
    pub fn get(&self, version: u64) -> Result<Arc<ModelVersion>, RegistryError> {
        let g = self.read();
        g.retained
            .iter()
            .find(|m| m.version == version)
            .map(Arc::clone)
            .ok_or_else(|| RegistryError::NotRetained {
                version,
                retained: g.retained.iter().map(|m| m.version).collect(),
            })
    }

    /// Atomically publish `net` as the new active version; returns the
    /// assigned version id.  In-flight work on the previous version
    /// drains on its own `Arc`; submissions that resolve after this
    /// call see the new version.  Refused while a canary is in flight
    /// or the endpoint is draining.
    pub fn publish(&self, net: Arc<IntNet>, label: &str) -> Result<u64, RegistryError> {
        self.check_shape(&net)?;
        if self.is_draining() {
            return Err(RegistryError::Draining);
        }
        let mut g = self.write();
        if let Some(canary) = g.canary {
            return Err(RegistryError::CanaryActive { canary });
        }
        let version = g.next_version;
        g.next_version += 1;
        let mv = Arc::new(ModelVersion { version, label: label.to_string(), net });
        g.retained.push(Arc::clone(&mv));
        g.active = mv;
        self.trim(&mut g);
        Ok(version)
    }

    /// Re-activate a retained version (atomic, like [`Self::publish`]).
    /// Fails if the version was never published or has been trimmed
    /// out of the retention window, and while a canary is in flight
    /// (end it first — rollback would yank the incumbent out from
    /// under the comparison).
    pub fn rollback(&self, version: u64) -> Result<(), RegistryError> {
        let mut g = self.write();
        if let Some(canary) = g.canary {
            return Err(RegistryError::CanaryActive { canary });
        }
        let Some(mv) = g.retained.iter().find(|m| m.version == version) else {
            return Err(RegistryError::NotRetained {
                version,
                retained: g.retained.iter().map(|m| m.version).collect(),
            });
        };
        g.active = Arc::clone(mv);
        Ok(())
    }

    /// Stage `net` as a canary: retained and addressable via
    /// [`Self::get`], receiving whatever traffic split the serving
    /// layer routes to it, but **not** active.  Exactly one canary can
    /// be in flight; publish/rollback are refused until it resolves
    /// via [`Self::promote_canary`] or [`Self::end_canary`].
    pub fn begin_canary(&self, net: Arc<IntNet>, label: &str) -> Result<u64, RegistryError> {
        self.check_shape(&net)?;
        if self.is_draining() {
            return Err(RegistryError::Draining);
        }
        let mut g = self.write();
        if let Some(canary) = g.canary {
            return Err(RegistryError::CanaryActive { canary });
        }
        let version = g.next_version;
        g.next_version += 1;
        let mv = Arc::new(ModelVersion { version, label: label.to_string(), net });
        g.retained.push(mv);
        g.canary = Some(version);
        self.trim(&mut g);
        Ok(version)
    }

    /// Promote the in-flight canary to active (atomic swap, same drain
    /// semantics as publish) and clear the canary state.
    pub fn promote_canary(&self, version: u64) -> Result<(), RegistryError> {
        let mut g = self.write();
        if g.canary != Some(version) {
            return Err(RegistryError::NotCanary { version, canary: g.canary });
        }
        let Some(mv) = g.retained.iter().find(|m| m.version == version) else {
            // Unreachable by construction (trim never drops the
            // canary), but degrade to a typed error rather than panic.
            g.canary = None;
            return Err(RegistryError::NotRetained {
                version,
                retained: g.retained.iter().map(|m| m.version).collect(),
            });
        };
        g.active = Arc::clone(mv);
        g.canary = None;
        Ok(())
    }

    /// End the in-flight canary *without* promoting it: the incumbent
    /// keeps serving (this is the auto-rollback path).  The canary
    /// stays retained for post-mortem until trimmed.
    pub fn end_canary(&self, version: u64) -> Result<(), RegistryError> {
        let mut g = self.write();
        if g.canary != Some(version) {
            return Err(RegistryError::NotCanary { version, canary: g.canary });
        }
        g.canary = None;
        Ok(())
    }

    /// Version id of the in-flight canary, if any.
    pub fn canary_version(&self) -> Option<u64> {
        self.read().canary
    }

    /// Put the endpoint into drain mode: [`Self::current`] keeps
    /// serving, but publish and canary staging are refused.  One-way
    /// (a draining endpoint is on its way out).
    pub fn begin_drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// The active version id.
    pub fn active_version(&self) -> u64 {
        self.read().active.version
    }

    /// Retained `(version, label)` pairs, oldest first.
    pub fn versions(&self) -> Vec<(u64, String)> {
        self.read()
            .retained
            .iter()
            .map(|m| (m.version, m.label.clone()))
            .collect()
    }

    fn check_shape(&self, net: &IntNet) -> Result<(), RegistryError> {
        let (din, out_dim) = endpoint_shape(net)?;
        if din != self.din || out_dim != self.out_dim {
            return Err(RegistryError::ShapeMismatch {
                din,
                out_dim,
                want_din: self.din,
                want_out: self.out_dim,
            });
        }
        Ok(())
    }

    /// Drop the oldest retained versions beyond the retention depth —
    /// never the active one, never the in-flight canary.
    fn trim(&self, g: &mut Inner) {
        while g.retained.len() > self.retain {
            let Some(idx) = g
                .retained
                .iter()
                .position(|m| m.version != g.active.version && Some(m.version) != g.canary)
            else {
                return; // only active/canary versions are left
            };
            g.retained.remove(idx);
        }
    }

    fn read(&self) -> std::sync::RwLockReadGuard<'_, Inner> {
        self.inner.read().unwrap_or_else(|p| p.into_inner())
    }

    fn write(&self) -> std::sync::RwLockWriteGuard<'_, Inner> {
        self.inner.write().unwrap_or_else(|p| p.into_inner())
    }
}

/// Validate a servable net and return its flattened
/// `(in_features, out_features)` endpoint shape — layer-kind agnostic
/// (a conv net keys on `cin·h·w` in, `num_classes` out like any other).
fn endpoint_shape(net: &IntNet) -> Result<(usize, usize), RegistryError> {
    if net.layers.is_empty() {
        return Err(RegistryError::EmptyNet);
    }
    let din = net.in_features();
    let out_dim = net.out_features();
    if din == 0 || out_dim == 0 {
        return Err(RegistryError::DegenerateShape { din, out_dim });
    }
    Ok((din, out_dim))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::synthetic_net;

    fn net(seed: u64) -> Arc<IntNet> {
        Arc::new(synthetic_net(&[6, 12, 3], seed, 4, 4))
    }

    #[test]
    fn publish_swaps_atomically_and_old_arc_survives() {
        let reg = ModelRegistry::new(net(1), "v1").unwrap();
        assert_eq!(reg.active_version(), 1);
        assert_eq!((reg.input_dim(), reg.out_dim()), (6, 3));

        // An in-flight holder of v1 keeps its view across the swap.
        let held = reg.current();
        let v2 = reg.publish(net(2), "v2").unwrap();
        assert_eq!(v2, 2);
        assert_eq!(held.version, 1);
        assert_eq!(reg.current().version, 2);
        // The held net still forwards fine (drain semantics).
        assert_eq!(held.net.forward(&[0.1; 6], 1).len(), 3);
    }

    #[test]
    fn rollback_to_retained_version() {
        let reg = ModelRegistry::new(net(1), "v1").unwrap();
        reg.publish(net(2), "v2").unwrap();
        reg.publish(net(3), "v3").unwrap();
        assert_eq!(reg.active_version(), 3);
        reg.rollback(1).unwrap();
        assert_eq!(reg.active_version(), 1);
        assert!(matches!(
            reg.rollback(99),
            Err(RegistryError::NotRetained { version: 99, .. })
        ));
        // Version ids are never reused: the next publish is v4.
        assert_eq!(reg.publish(net(4), "v4").unwrap(), 4);
        let versions: Vec<u64> = reg.versions().iter().map(|(v, _)| *v).collect();
        assert_eq!(versions, vec![1, 2, 3, 4]);
    }

    #[test]
    fn retention_trims_oldest_but_never_active() {
        let reg = ModelRegistry::with_retain(net(1), "v1", 2).unwrap();
        reg.publish(net(2), "v2").unwrap();
        reg.publish(net(3), "v3").unwrap();
        let versions: Vec<u64> = reg.versions().iter().map(|(v, _)| *v).collect();
        assert_eq!(versions, vec![2, 3]);
        assert!(reg.rollback(1).is_err(), "v1 was trimmed");
        // Roll back to v2, then publish twice more: v2 stays (active)
        // until it is no longer active.
        reg.rollback(2).unwrap();
        reg.publish(net(4), "v4").unwrap();
        let versions: Vec<u64> = reg.versions().iter().map(|(v, _)| *v).collect();
        assert!(versions.contains(&4));
        assert_eq!(reg.active_version(), 4);
        assert_eq!(versions.len(), 2);
    }

    #[test]
    fn shape_mismatch_and_bad_nets_rejected() {
        let reg = ModelRegistry::new(net(1), "v1").unwrap();
        let wrong = Arc::new(synthetic_net(&[7, 12, 3], 9, 4, 4));
        assert!(matches!(
            reg.publish(wrong, "bad-in"),
            Err(RegistryError::ShapeMismatch { .. })
        ));
        let wrong_out = Arc::new(synthetic_net(&[6, 12, 4], 9, 4, 4));
        assert!(matches!(
            reg.publish(wrong_out, "bad-out"),
            Err(RegistryError::ShapeMismatch { .. })
        ));
        assert_eq!(reg.active_version(), 1, "failed publish must not swap");

        let empty = Arc::new(IntNet { layers: vec![], num_classes: 0 });
        assert!(matches!(
            ModelRegistry::new(empty, "e"),
            Err(RegistryError::EmptyNet)
        ));
        assert!(matches!(
            ModelRegistry::with_retain(net(1), "r", 0),
            Err(RegistryError::BadRetain)
        ));
    }

    #[test]
    fn canary_lifecycle_gates_publish_and_rollback() {
        let reg = ModelRegistry::new(net(1), "v1").unwrap();
        reg.publish(net(2), "v2").unwrap();
        let cv = reg.begin_canary(net(3), "candidate").unwrap();
        assert_eq!(cv, 3);
        assert_eq!(reg.canary_version(), Some(3));
        // Staged, addressable, but not active.
        assert_eq!(reg.active_version(), 2);
        assert_eq!(reg.get(cv).unwrap().version, 3);
        // The control plane is frozen while the experiment runs.
        assert!(matches!(
            reg.publish(net(4), "v4"),
            Err(RegistryError::CanaryActive { canary: 3 })
        ));
        assert!(matches!(
            reg.rollback(1),
            Err(RegistryError::CanaryActive { canary: 3 })
        ));
        assert!(matches!(
            reg.begin_canary(net(5), "second"),
            Err(RegistryError::CanaryActive { canary: 3 })
        ));
        // Ending the canary restores the control plane; incumbent
        // never moved.
        reg.end_canary(cv).unwrap();
        assert_eq!(reg.canary_version(), None);
        assert_eq!(reg.active_version(), 2);
        assert!(matches!(
            reg.end_canary(cv),
            Err(RegistryError::NotCanary { version: 3, canary: None })
        ));
        reg.publish(net(4), "v4").unwrap();
        assert_eq!(reg.active_version(), 4);
    }

    #[test]
    fn promote_canary_swaps_atomically() {
        let reg = ModelRegistry::new(net(1), "v1").unwrap();
        let cv = reg.begin_canary(net(2), "candidate").unwrap();
        assert!(matches!(
            reg.promote_canary(99),
            Err(RegistryError::NotCanary { version: 99, canary: Some(2) })
        ));
        reg.promote_canary(cv).unwrap();
        assert_eq!(reg.active_version(), cv);
        assert_eq!(reg.canary_version(), None);
    }

    #[test]
    fn trim_never_drops_the_canary() {
        let reg = ModelRegistry::with_retain(net(1), "v1", 2).unwrap();
        let cv = reg.begin_canary(net(2), "candidate").unwrap();
        // Resolve + publish after ending: canary survives retention
        // pressure while flagged.
        assert!(reg.get(cv).is_ok());
        reg.end_canary(cv).unwrap();
        reg.publish(net(3), "v3").unwrap();
        reg.publish(net(4), "v4").unwrap();
        let versions: Vec<u64> = reg.versions().iter().map(|(v, _)| *v).collect();
        assert_eq!(versions.len(), 2);
        assert!(versions.contains(&4));
    }

    #[test]
    fn drain_refuses_new_versions_but_keeps_serving() {
        let reg = ModelRegistry::new(net(1), "v1").unwrap();
        reg.publish(net(2), "v2").unwrap();
        reg.begin_drain();
        assert!(reg.is_draining());
        assert!(matches!(reg.publish(net(3), "v3"), Err(RegistryError::Draining)));
        assert!(matches!(
            reg.begin_canary(net(3), "c"),
            Err(RegistryError::Draining)
        ));
        // In-flight traffic and emergency rollback still work.
        assert_eq!(reg.current().version, 2);
        reg.rollback(1).unwrap();
        assert_eq!(reg.active_version(), 1);
    }

    #[test]
    fn concurrent_readers_see_a_consistent_version() {
        // Hammer current() from reader threads while publishing; every
        // observed version must be a value that was actually published,
        // and the sequence each reader sees is monotone non-decreasing
        // (no tearing, no going backwards without a rollback).
        let reg = std::sync::Arc::new(ModelRegistry::new(net(1), "v1").unwrap());
        std::thread::scope(|scope| {
            let mut joins = Vec::new();
            for _ in 0..4 {
                let reg = std::sync::Arc::clone(&reg);
                joins.push(scope.spawn(move || {
                    let mut last = 0u64;
                    for _ in 0..500 {
                        let v = reg.current().version;
                        assert!(v >= last, "version went backwards: {last} -> {v}");
                        last = v;
                    }
                }));
            }
            for v in 2..=5u64 {
                assert_eq!(reg.publish(net(v), &format!("v{v}")).unwrap(), v);
            }
            for j in joins {
                j.join().unwrap();
            }
        });
        assert_eq!(reg.active_version(), 5);
    }
}
