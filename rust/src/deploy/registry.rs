//! Versioned model registry: the control plane for zero-downtime
//! serving.
//!
//! A [`ModelRegistry`] maps a serving endpoint onto a sequence of
//! published model versions.  Publishing is **atomic** — a single
//! pointer swap under a write lock — and readers ([`current`]) take a
//! cheap `Arc` clone, so:
//!
//! * a batch that resolved version *N* keeps executing on *N* even if
//!   *N+1* is published mid-forward (the `Arc` keeps the old net alive
//!   until the last in-flight batch drops it — that is the **drain**
//!   semantics: no request is interrupted, dropped or served by a
//!   half-swapped model);
//! * new batch resolutions after the swap see *N+1* immediately;
//! * any retained version can be made current again ([`rollback`]).
//!
//! Shape compatibility is enforced at publish time (same input/output
//! dimensionality as the registry was created with), which is what lets
//! `serve::Server` keep handing out stable request/response dims across
//! swaps.
//!
//! [`current`]: ModelRegistry::current
//! [`rollback`]: ModelRegistry::rollback

use std::sync::{Arc, RwLock};

use anyhow::{bail, Result};

use crate::infer::IntNet;

/// How many published versions a registry keeps around for rollback
/// when no explicit limit is given.
pub const DEFAULT_RETAIN: usize = 4;

/// One published model version (immutable once published).
pub struct ModelVersion {
    /// Monotonically increasing, starting at 1; never reused, even
    /// after rollback (rolling back re-activates the old version id).
    pub version: u64,
    /// Operator-facing label (e.g. the artifact path it came from).
    pub label: String,
    pub net: Arc<IntNet>,
}

struct Inner {
    active: Arc<ModelVersion>,
    /// Every retained version, oldest first (always contains `active`).
    retained: Vec<Arc<ModelVersion>>,
    next_version: u64,
}

/// Thread-safe name→versioned-model store with atomic hot-swap.
pub struct ModelRegistry {
    /// Input dimensionality every version must accept.
    din: usize,
    /// Output dimensionality every version must emit.
    out_dim: usize,
    retain: usize,
    inner: RwLock<Inner>,
}

impl ModelRegistry {
    /// Create a registry with `net` as version 1.  The net fixes the
    /// endpoint's input/output shape; later publishes must match it.
    pub fn new(net: Arc<IntNet>, label: &str) -> Result<Self> {
        Self::with_retain(net, label, DEFAULT_RETAIN)
    }

    /// [`Self::new`] with an explicit rollback-retention depth
    /// (`retain >= 1`; the active version is always retained).
    pub fn with_retain(net: Arc<IntNet>, label: &str, retain: usize) -> Result<Self> {
        if retain == 0 {
            bail!("registry: retain must be at least 1");
        }
        let (din, out_dim) = endpoint_shape(&net)?;
        let v1 = Arc::new(ModelVersion { version: 1, label: label.to_string(), net });
        Ok(Self {
            din,
            out_dim,
            retain,
            inner: RwLock::new(Inner {
                active: Arc::clone(&v1),
                retained: vec![v1],
                next_version: 2,
            }),
        })
    }

    /// Input dimensionality every served request must carry.
    pub fn input_dim(&self) -> usize {
        self.din
    }

    /// Logits dimensionality every response carries.
    pub fn out_dim(&self) -> usize {
        self.out_dim
    }

    /// The active version — an `Arc` clone, so the caller's view is
    /// stable for as long as it holds it regardless of later swaps.
    pub fn current(&self) -> Arc<ModelVersion> {
        Arc::clone(&self.read().active)
    }

    /// Atomically publish `net` as the new active version; returns the
    /// assigned version id.  In-flight work on the previous version
    /// drains on its own `Arc`; submissions that resolve after this
    /// call see the new version.
    pub fn publish(&self, net: Arc<IntNet>, label: &str) -> Result<u64> {
        let (din, out_dim) = endpoint_shape(&net)?;
        if din != self.din || out_dim != self.out_dim {
            bail!(
                "registry: published model is {din}->{out_dim} but this endpoint serves {}->{}",
                self.din,
                self.out_dim
            );
        }
        let mut g = self.write();
        let version = g.next_version;
        g.next_version += 1;
        let mv = Arc::new(ModelVersion { version, label: label.to_string(), net });
        g.retained.push(Arc::clone(&mv));
        g.active = mv;
        self.trim(&mut g);
        Ok(version)
    }

    /// Re-activate a retained version (atomic, like [`Self::publish`]).
    /// Fails if the version was never published or has been trimmed
    /// out of the retention window.
    pub fn rollback(&self, version: u64) -> Result<()> {
        let mut g = self.write();
        let Some(mv) = g.retained.iter().find(|m| m.version == version) else {
            let have: Vec<u64> = g.retained.iter().map(|m| m.version).collect();
            bail!("registry: version {version} is not retained (have {have:?})");
        };
        g.active = Arc::clone(mv);
        Ok(())
    }

    /// The active version id.
    pub fn active_version(&self) -> u64 {
        self.read().active.version
    }

    /// Retained `(version, label)` pairs, oldest first.
    pub fn versions(&self) -> Vec<(u64, String)> {
        self.read()
            .retained
            .iter()
            .map(|m| (m.version, m.label.clone()))
            .collect()
    }

    /// Drop the oldest retained versions beyond the retention depth —
    /// never the active one.
    fn trim(&self, g: &mut Inner) {
        while g.retained.len() > self.retain {
            let Some(idx) = g
                .retained
                .iter()
                .position(|m| m.version != g.active.version)
            else {
                return; // only the active version is left
            };
            g.retained.remove(idx);
        }
    }

    fn read(&self) -> std::sync::RwLockReadGuard<'_, Inner> {
        self.inner.read().unwrap_or_else(|p| p.into_inner())
    }

    fn write(&self) -> std::sync::RwLockWriteGuard<'_, Inner> {
        self.inner.write().unwrap_or_else(|p| p.into_inner())
    }
}

/// Validate a servable net and return its `(din, out_dim)`.
fn endpoint_shape(net: &IntNet) -> Result<(usize, usize)> {
    let Some(first) = net.layers.first() else {
        bail!("registry: refusing an empty network");
    };
    let din = first.din;
    let out_dim = net.layers.last().unwrap().dout;
    if din == 0 || out_dim == 0 {
        bail!("registry: degenerate network shape ({din} in, {out_dim} out)");
    }
    Ok((din, out_dim))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::synthetic_net;

    fn net(seed: u64) -> Arc<IntNet> {
        Arc::new(synthetic_net(&[6, 12, 3], seed, 4, 4))
    }

    #[test]
    fn publish_swaps_atomically_and_old_arc_survives() {
        let reg = ModelRegistry::new(net(1), "v1").unwrap();
        assert_eq!(reg.active_version(), 1);
        assert_eq!((reg.input_dim(), reg.out_dim()), (6, 3));

        // An in-flight holder of v1 keeps its view across the swap.
        let held = reg.current();
        let v2 = reg.publish(net(2), "v2").unwrap();
        assert_eq!(v2, 2);
        assert_eq!(held.version, 1);
        assert_eq!(reg.current().version, 2);
        // The held net still forwards fine (drain semantics).
        assert_eq!(held.net.forward(&[0.1; 6], 1).len(), 3);
    }

    #[test]
    fn rollback_to_retained_version() {
        let reg = ModelRegistry::new(net(1), "v1").unwrap();
        reg.publish(net(2), "v2").unwrap();
        reg.publish(net(3), "v3").unwrap();
        assert_eq!(reg.active_version(), 3);
        reg.rollback(1).unwrap();
        assert_eq!(reg.active_version(), 1);
        assert!(reg.rollback(99).is_err());
        // Version ids are never reused: the next publish is v4.
        assert_eq!(reg.publish(net(4), "v4").unwrap(), 4);
        let versions: Vec<u64> = reg.versions().iter().map(|(v, _)| *v).collect();
        assert_eq!(versions, vec![1, 2, 3, 4]);
    }

    #[test]
    fn retention_trims_oldest_but_never_active() {
        let reg = ModelRegistry::with_retain(net(1), "v1", 2).unwrap();
        reg.publish(net(2), "v2").unwrap();
        reg.publish(net(3), "v3").unwrap();
        let versions: Vec<u64> = reg.versions().iter().map(|(v, _)| *v).collect();
        assert_eq!(versions, vec![2, 3]);
        assert!(reg.rollback(1).is_err(), "v1 was trimmed");
        // Roll back to v2, then publish twice more: v2 stays (active)
        // until it is no longer active.
        reg.rollback(2).unwrap();
        reg.publish(net(4), "v4").unwrap();
        let versions: Vec<u64> = reg.versions().iter().map(|(v, _)| *v).collect();
        assert!(versions.contains(&4));
        assert_eq!(reg.active_version(), 4);
        assert_eq!(versions.len(), 2);
    }

    #[test]
    fn shape_mismatch_and_bad_nets_rejected() {
        let reg = ModelRegistry::new(net(1), "v1").unwrap();
        let wrong = Arc::new(synthetic_net(&[7, 12, 3], 9, 4, 4));
        assert!(reg.publish(wrong, "bad-in").is_err());
        let wrong_out = Arc::new(synthetic_net(&[6, 12, 4], 9, 4, 4));
        assert!(reg.publish(wrong_out, "bad-out").is_err());
        assert_eq!(reg.active_version(), 1, "failed publish must not swap");

        let empty = Arc::new(IntNet { layers: vec![], num_classes: 0 });
        assert!(ModelRegistry::new(empty, "e").is_err());
        assert!(ModelRegistry::with_retain(net(1), "r", 0).is_err());
    }

    #[test]
    fn concurrent_readers_see_a_consistent_version() {
        // Hammer current() from reader threads while publishing; every
        // observed version must be a value that was actually published,
        // and the sequence each reader sees is monotone non-decreasing
        // (no tearing, no going backwards without a rollback).
        let reg = std::sync::Arc::new(ModelRegistry::new(net(1), "v1").unwrap());
        std::thread::scope(|scope| {
            let mut joins = Vec::new();
            for _ in 0..4 {
                let reg = std::sync::Arc::clone(&reg);
                joins.push(scope.spawn(move || {
                    let mut last = 0u64;
                    for _ in 0..500 {
                        let v = reg.current().version;
                        assert!(v >= last, "version went backwards: {last} -> {v}");
                        last = v;
                    }
                }));
            }
            for v in 2..=5u64 {
                assert_eq!(reg.publish(net(v), &format!("v{v}")).unwrap(), v);
            }
            for j in joins {
                j.join().unwrap();
            }
        });
        assert_eq!(reg.active_version(), 5);
    }
}
