//! HLO-text analyzer: static cost analysis of the exported artifacts.
//!
//! The L2 perf pass (DESIGN.md §8) needs to see what XLA will actually
//! execute — op mix, fusion opportunity, parameter/FLOP/memory totals —
//! without running python.  This module parses the HLO *text* artifacts
//! (the same files the runtime compiles) far enough to answer:
//!
//! * instruction counts per opcode (did a change add redundant work?)
//! * dot/convolution FLOP estimates (compute roofline input)
//! * parameter and output tensor bytes (transfer cost the coordinator
//!   pays per step)
//! * elementwise-chain lengths (fusion opportunity metric)
//!
//! It is a *line-oriented* parser for the subset XLA emits
//! (`%name = type[dims]{layout} opcode(args), metadata`), not a general
//! HLO grammar; unknown constructs degrade to opcode-only counting.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{Context, Result};

/// One parsed instruction.
#[derive(Debug, Clone)]
pub struct Instr {
    pub name: String,
    pub opcode: String,
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl Instr {
    pub fn element_count(&self) -> usize {
        self.dims.iter().product()
    }

    pub fn bytes(&self) -> usize {
        let esize = match self.dtype.as_str() {
            "f64" | "s64" | "u64" | "c64" => 8,
            "f32" | "s32" | "u32" => 4,
            "f16" | "bf16" | "s16" | "u16" => 2,
            "pred" | "s8" | "u8" => 1,
            _ => 4,
        };
        self.element_count() * esize
    }
}

/// Analysis of one HLO module.
#[derive(Debug, Default)]
pub struct HloReport {
    pub module_name: String,
    pub instr_count: usize,
    pub opcode_counts: BTreeMap<String, usize>,
    /// FLOPs of dot/convolution ops (2·prod heuristic; see `dot_flops`).
    pub matmul_flops: f64,
    /// Bytes of entry parameters (per-execution host->device traffic).
    pub parameter_bytes: usize,
    /// Bytes of the root tuple (device->host traffic).
    pub output_bytes: usize,
    /// Total elementwise instruction outputs (fusion-eligible work).
    pub elementwise_elems: f64,
    pub fusion_count: usize,
    pub while_count: usize,
}

impl HloReport {
    pub fn count(&self, opcode: &str) -> usize {
        self.opcode_counts.get(opcode).copied().unwrap_or(0)
    }

    /// Arithmetic intensity proxy: matmul FLOPs per parameter byte.
    pub fn flops_per_param_byte(&self) -> f64 {
        self.matmul_flops / self.parameter_bytes.max(1) as f64
    }

    pub fn summary(&self) -> String {
        let mut top: Vec<(&String, &usize)> = self.opcode_counts.iter().collect();
        top.sort_by(|a, b| b.1.cmp(a.1));
        let tops: Vec<String> = top
            .iter()
            .take(8)
            .map(|(k, v)| format!("{k}:{v}"))
            .collect();
        format!(
            "{}: {} instrs | {:.1} MFLOP (dot/conv) | params {:.1} KiB | out {:.1} KiB | fusions {} | while {} | top [{}]",
            self.module_name,
            self.instr_count,
            self.matmul_flops / 1e6,
            self.parameter_bytes as f64 / 1024.0,
            self.output_bytes as f64 / 1024.0,
            self.fusion_count,
            self.while_count,
            tops.join(" ")
        )
    }
}

const ELEMENTWISE: &[&str] = &[
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "log", "floor", "ceil", "round-nearest-even",
    "round-nearest-afz", "clamp", "select", "compare", "power", "sqrt",
    "rsqrt", "tanh", "convert", "and", "or", "xor", "not",
];

/// Parse HLO text into a report.
pub fn analyze_text(text: &str) -> HloReport {
    let mut report = HloReport::default();
    let mut in_entry = false;

    for line in text.lines() {
        let trimmed = line.trim();
        if let Some(rest) = trimmed.strip_prefix("HloModule ") {
            report.module_name =
                rest.split([',', ' ']).next().unwrap_or("").to_string();
            continue;
        }
        // ENTRY computation marker.
        if trimmed.starts_with("ENTRY ") {
            in_entry = true;
        }
        let Some(instr) = parse_instr(trimmed) else {
            continue;
        };
        report.instr_count += 1;
        *report
            .opcode_counts
            .entry(instr.opcode.clone())
            .or_insert(0) += 1;

        match instr.opcode.as_str() {
            "dot" => report.matmul_flops += dot_flops(&instr, trimmed),
            "convolution" => report.matmul_flops += conv_flops(&instr, trimmed),
            "fusion" => report.fusion_count += 1,
            "while" => report.while_count += 1,
            "parameter" if in_entry => {
                report.parameter_bytes += instr.bytes();
            }
            _ => {}
        }
        if ELEMENTWISE.contains(&instr.opcode.as_str()) {
            report.elementwise_elems += instr.element_count() as f64;
        }
        // Root detection: "ROOT %tuple.N = (..) tuple(..)"
        if trimmed.contains("ROOT") && instr.opcode == "tuple" {
            // dims parsing for tuples is skipped by parse_instr; estimate
            // from the operand list is overkill — measure via runtime
            // stats instead. Count instrs only.
        }
    }
    report
}

/// Analyze an artifact file.
pub fn analyze_file(path: impl AsRef<Path>) -> Result<HloReport> {
    let path = path.as_ref();
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading HLO '{}'", path.display()))?;
    let mut r = analyze_text(&text);
    if r.module_name.is_empty() {
        r.module_name = path
            .file_stem()
            .map(|s| s.to_string_lossy().into_owned())
            .unwrap_or_default();
    }
    Ok(r)
}

/// Parse `%name = f32[2,3]{1,0} opcode(...)` or
/// `name.1 = f32[] constant(0)` style lines.
fn parse_instr(line: &str) -> Option<Instr> {
    let line = line.strip_prefix("ROOT ").unwrap_or(line);
    let (lhs, rhs) = line.split_once(" = ")?;
    let name = lhs.trim().trim_start_matches('%').to_string();
    let rhs = rhs.trim();

    // Type spec: dtype[dims]{layout} — tuples "(f32[..], ...)" skipped.
    let (type_spec, rest) = if rhs.starts_with('(') {
        let close = find_matching_paren(rhs)?;
        (&rhs[..=close], rhs[close + 1..].trim())
    } else {
        let sp = rhs.find(' ')?;
        (&rhs[..sp], rhs[sp + 1..].trim())
    };
    let opcode: String = rest
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '-' || *c == '_')
        .collect();
    if opcode.is_empty() {
        return None;
    }

    let (dtype, dims) = parse_type(type_spec).unwrap_or(("tuple".into(), vec![]));
    Some(Instr { name, opcode, dtype, dims })
}

fn parse_type(spec: &str) -> Option<(String, Vec<usize>)> {
    let open = spec.find('[')?;
    let close = spec[open..].find(']')? + open;
    let dtype = spec[..open].to_string();
    if dtype.contains('(') {
        return None;
    }
    let dims_str = &spec[open + 1..close];
    let dims = if dims_str.is_empty() {
        vec![]
    } else {
        dims_str
            .split(',')
            .filter_map(|d| d.trim().parse::<usize>().ok())
            .collect()
    };
    Some((dtype, dims))
}

fn find_matching_paren(s: &str) -> Option<usize> {
    let mut depth = 0;
    for (i, c) in s.char_indices() {
        match c {
            '(' => depth += 1,
            ')' => {
                depth -= 1;
                if depth == 0 {
                    return Some(i);
                }
            }
            _ => {}
        }
    }
    None
}

/// FLOPs of a dot: 2 · prod(output dims) · contraction size.  The
/// contraction size is inferred from the lhs operand shape in the text
/// (first operand's last dim, the common XLA layout for these graphs);
/// falls back to output-only estimate if unavailable.
fn dot_flops(instr: &Instr, line: &str) -> f64 {
    let out: f64 = instr.element_count() as f64;
    if let Some(k) = first_operand_last_dim(line) {
        2.0 * out * k as f64
    } else {
        2.0 * out
    }
}

/// Convolution FLOPs: 2 · output elems · (kernel spatial · cin) — the
/// kernel shape is the second operand `f32[kh,kw,cin,cout]`.
fn conv_flops(instr: &Instr, line: &str) -> f64 {
    let out = instr.element_count() as f64;
    if let Some(kshape) = operand_shape(line, 1) {
        if kshape.len() == 4 {
            let per_out = kshape[0] * kshape[1] * kshape[2];
            return 2.0 * out * per_out as f64;
        }
    }
    2.0 * out
}

/// Shape of the idx-th operand inside `opcode(f32[a,b] %x, f32[c] %y, ...)`.
fn operand_shape(line: &str, idx: usize) -> Option<Vec<usize>> {
    let args_start = line.find('(')?;
    let args = &line[args_start + 1..];
    let mut shapes = Vec::new();
    let mut rest = args;
    while let Some(open) = rest.find('[') {
        // dtype immediately precedes '['
        let close = rest[open..].find(']')? + open;
        let dims: Vec<usize> = rest[open + 1..close]
            .split(',')
            .filter_map(|d| d.trim().parse().ok())
            .collect();
        shapes.push(dims);
        rest = &rest[close + 1..];
        if shapes.len() > idx {
            break;
        }
    }
    shapes.get(idx).cloned()
}

fn first_operand_last_dim(line: &str) -> Option<usize> {
    operand_shape(line, 0)?.last().copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule jit_fn, entry_computation_layout={(f32[2,3]{1,0})->f32[2,4]{1,0}}

ENTRY %main.5 (Arg_0.1: f32[2,3]) -> f32[2,4] {
  %Arg_0.1 = f32[2,3]{1,0} parameter(0)
  %constant.2 = f32[3,4]{1,0} constant({...})
  %dot.3 = f32[2,4]{1,0} dot(f32[2,3]{1,0} %Arg_0.1, f32[3,4]{1,0} %constant.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %add.9 = f32[2,4]{1,0} add(f32[2,4]{1,0} %dot.3, f32[2,4]{1,0} %dot.3)
  ROOT %multiply.4 = f32[2,4]{1,0} multiply(f32[2,4]{1,0} %add.9, f32[2,4]{1,0} %dot.3)
}
"#;

    #[test]
    fn parses_sample_module() {
        let r = analyze_text(SAMPLE);
        assert_eq!(r.module_name, "jit_fn");
        assert_eq!(r.count("dot"), 1);
        assert_eq!(r.count("add"), 1);
        assert_eq!(r.count("multiply"), 1);
        assert_eq!(r.count("parameter"), 1);
        // dot: 2 * (2*4) * 3 = 48 flops
        assert_eq!(r.matmul_flops, 48.0);
        // parameter bytes: 2*3*4
        assert_eq!(r.parameter_bytes, 24);
        // elementwise: add + multiply outputs = 8 + 8
        assert_eq!(r.elementwise_elems, 16.0);
    }

    #[test]
    fn instr_parsing_edge_cases() {
        let i = parse_instr("%x.1 = f32[] constant(0)").unwrap();
        assert_eq!(i.opcode, "constant");
        assert_eq!(i.dims, Vec::<usize>::new());
        assert_eq!(i.element_count(), 1);

        let i = parse_instr(
            "ROOT %t = (f32[2]{0}, s32[]) tuple(f32[2]{0} %a, s32[] %b)",
        )
        .unwrap();
        assert_eq!(i.opcode, "tuple");
        assert_eq!(i.dtype, "tuple");

        assert!(parse_instr("}").is_none());
        assert!(parse_instr("ENTRY %main").is_none());
    }

    #[test]
    fn bytes_by_dtype() {
        let i = parse_instr("%x = bf16[8]{0} parameter(0)").unwrap();
        assert_eq!(i.bytes(), 16);
        let i = parse_instr("%x = pred[8]{0} compare(...)").unwrap();
        assert_eq!(i.bytes(), 8);
    }

    #[test]
    fn conv_flops_from_kernel_shape() {
        let line = "%conv = f32[32,16,16,32]{3,2,1,0} convolution(f32[32,16,16,3]{3,2,1,0} %x, f32[3,3,3,32]{3,2,1,0} %w), window={size=3x3 pad=1_1x1_1}";
        let i = parse_instr(line).unwrap();
        let f = conv_flops(&i, line);
        // 2 * (32*16*16*32) * (3*3*3)
        assert_eq!(f, 2.0 * 262144.0 * 27.0);
    }

    #[test]
    fn analyzes_real_artifact_if_present() {
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let f = dir.join("mlp_train.hlo.txt");
        if !f.exists() {
            eprintln!("SKIP: artifacts not built");
            return;
        }
        let r = analyze_file(&f).unwrap();
        assert!(r.instr_count > 50, "{}", r.summary());
        assert!(r.matmul_flops > 0.0);
        assert!(r.parameter_bytes > 0);
        assert!(r.count("dot") >= 3, "fwd+bwd dots expected: {}", r.summary());
    }
}
