//! `bitprune` — the L3 coordinator CLI.
//!
//! Subcommands map one-to-one onto the paper's evaluation (DESIGN.md §6):
//!
//! ```text
//! bitprune train   [opts]                 one training run
//! bitprune sweep   --table2|--table3|--table4|--table5|--table6|--ablations
//! bitprune baseline --table7|--mpdnn      comparison baselines
//! bitprune accel   [--model M]            Table VIII accelerator models
//! bitprune parity                         rust quantizer vs fake_quant.hlo
//! bitprune artifacts                      list compiled artifacts
//! bitprune export  --out m.bpma           freeze a model into a BPMA artifact
//! bitprune inspect m.bpma                 section table / bitlengths / footprint
//! bitprune serve   --model m.bpma         serve an artifact (no trainer/dataset);
//!                  [--swap-to b.bpma --swap-after N]  live hot-swap demo
//! ```
//!
//! Common options: --config FILE, --model, --dataset, --gamma, --seed,
//! --learn-steps, --finetune-steps, --lr-max, --bits-lr, --init-bits,
//! --eval-every, --criterion, --plan, --artifacts DIR, --out DIR,
//! --gammas A,B,C, --models a,b,c, --no-augment.

use anyhow::{bail, Result};

use bitprune::config::{toml::TomlDoc, RunConfig};
use bitprune::coordinator::run_experiment;
use bitprune::metrics::Table;
use bitprune::quant;
use bitprune::report;
use bitprune::runtime::Runtime;
use bitprune::tensor::HostTensor;
use bitprune::util::args::Args;
use bitprune::util::rng::Rng;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn base_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_toml(&TomlDoc::load(path)?)?,
        None => RunConfig::default(),
    };
    cfg.apply_args(args)?;
    Ok(cfg)
}

fn run() -> Result<()> {
    let args = Args::from_env(&RunConfig::cli_value_opts_extended())?;
    let cmd = args.pos(0).unwrap_or("help").to_string();
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "baseline" => cmd_baseline(&args),
        "accel" => cmd_accel(&args),
        "parity" => cmd_parity(&args),
        "artifacts" => cmd_artifacts(&args),
        "hlo" => cmd_hlo(&args),
        "pack" => cmd_pack(&args),
        "infer" => cmd_infer(&args),
        "export" => cmd_export(&args),
        "inspect" => cmd_inspect(&args),
        "serve" => cmd_serve(&args),
        "metrics" => cmd_metrics(&args),
        "fig" => cmd_fig(&args),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command '{other}' — try `bitprune help`"),
    }
}

const HELP: &str = "\
bitprune — BitPruning coordinator (learned bitlength quantization)

USAGE: bitprune <command> [options]

COMMANDS:
  train       run one training experiment
  sweep       regenerate paper tables II-VI + ablations
                (--table2 --table3 --table4 --table5 --table6 --ablations)
  baseline    comparison baselines (--table7 --mpdnn)
  accel       accelerator performance models (Table VIII)
  parity      rust quantizer vs compiled fake_quant artifact
  artifacts   list compiled artifacts
  hlo         static cost analysis of the compiled artifacts
  pack        train + bit-pack weights; report real storage footprint
  infer       pure-integer inference vs the compiled eval artifact
  export      freeze a model into a single-file BPMA deployment artifact
                (--out FILE, --synthetic | --ckpt FILE | train)
  inspect     print a BPMA artifact's section table, per-layer
                bitlengths, footprint and checksums
  serve       batched integer serving engine: throughput + latency
                percentiles (--requests N --batch-window USEC
                --max-batch N --clients N --threads N --synthetic);
                --model FILE.bpma serves a frozen artifact with no
                trainer or dataset in memory; --swap-to B.bpma
                --swap-after N hot-swaps mid-traffic via the registry;
                --deadline-ms N --shed-policy reject-newest|drop-expired
                sheds overload with typed errors; --canary B.bpma
                --canary-pct P splits traffic and auto-promotes or
                rolls back on online agreement/latency;
                --metrics-addr H:P exposes a live Prometheus-text +
                JSON scrape endpoint; --trace-out FILE.jsonl writes a
                structured lifecycle event trace; --profile prints a
                per-layer time/MAC/byte profile of the served net
  metrics     pretty-print a running server's telemetry snapshot
                (--addr H:P, the address passed to serve --metrics-addr)
  fig         render figure 1/3 ASCII charts from a reports/<run>.json

OPTIONS (common):
  --config FILE --model M --dataset D --gamma G --seed S
  --learn-steps N --finetune-steps N --lr-max F --bits-lr F
  --init-bits B --eval-every N --criterion equal|bs1|bs128|mac
  --plan standard|early|fixed|warmstart --warmstart-ckpt FILE
  --artifacts DIR --out DIR --gammas A,B,C --models a,b,c --no-augment

OPTIONS (deploy):
  export:  --out FILE.bpma  --synthetic | --ckpt FILE.bpck  --bits B
           --granularity layer|channel   (per-output-channel weight bits)
           --arch mlp|conv               (synthetic fixture: dense or conv/im2col)
           --codebook uniform|pot|apot   (weight codes: uniform grid, powers of
                                          two, or 2-term PoT sums; non-uniform
                                          artifacts carry a CBK0 section and
                                          serve on the shift-add GEMM)
  inspect: <FILE.bpma>                   (reports per-channel bit histograms,
                                          per-layer codebooks, conv geometry
                                          via the CNV0/CBK0 sections)
  serve:   --model FILE.bpma  --swap-to B.bpma  --swap-after N
           --granularity layer|channel  --arch mlp|conv
           --codebook uniform|pot|apot  (for --synthetic)
           --deadline-ms N  --shed-policy reject-newest|drop-expired
           --canary B.bpma --canary-pct P --canary-window N --canary-promote K
           --metrics-addr HOST:PORT  --trace-out FILE.jsonl  --profile
  metrics: --addr HOST:PORT              (scrapes /metrics.json and renders it)
";

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = base_config(args)?;
    let rt = Runtime::cpu(&cfg.artifact_dir)?;
    eprintln!(
        "training {} on {} (platform: {})",
        cfg.model,
        cfg.dataset,
        rt.platform()
    );
    let outcome = run_experiment(&rt, &cfg)?;
    let meta = bitprune::model::ModelMeta::load(
        rt.artifact_dir().join(format!("{}_meta.json", cfg.model)),
    )?;
    let names: Vec<String> = meta.layers.iter().map(|l| l.name.clone()).collect();
    outcome.recorder.write_csvs(&cfg.out_dir, &names)?;

    let mut t = Table::new(&["stage", "accuracy", "W bits", "A bits"]);
    if let Some(ni) = &outcome.noninteger {
        t.row(vec![
            "non-integer".into(),
            format!("{:.2}%", ni.accuracy * 100.0),
            format!("{:.2}", ni.mean_bits_w()),
            format!("{:.2}", ni.mean_bits_a()),
        ]);
    }
    t.row(vec![
        "final".into(),
        format!("{:.2}%", outcome.final_.accuracy * 100.0),
        format!("{:.2}", outcome.final_.mean_bits_w()),
        format!("{:.2}", outcome.final_.mean_bits_a()),
    ]);
    println!("{}", t.render());
    println!("per-layer bits (W): {:?}", outcome.final_.bits_w);
    println!("per-layer bits (A): {:?}", outcome.final_.bits_a);
    println!("wall time: {:.1}s", outcome.wall_secs);
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let base = base_config(args)?;
    let rt = Runtime::cpu(&base.artifact_dir)?;
    let models = args.get_str_list("models", &["alexnet_s", "resnet_s"]);
    let gammas = args.get_f64_list("gammas", &[0.5, 1.0, 2.5, 5.0, 10.0])?;
    let mut ran = false;

    if args.flag("table2") {
        println!("\n== Table II: regularizer sweep ==");
        println!("{}", report::table2(&rt, &base, &models, &gammas)?.render());
        ran = true;
    }
    if args.flag("table3") {
        let m3 = args.get_str_list("models", &["mobilenet_s", "mlp"]);
        println!("\n== Table III: other architectures ==");
        println!("{}", report::table3(&rt, &base, &m3)?.render());
        ran = true;
    }
    if args.flag("table4") {
        println!("\n== Table IV: weighted bit-loss criteria ==");
        println!("{}", report::table4(&rt, &base, &models)?.render());
        ran = true;
    }
    if args.flag("table5") {
        let variants: Vec<String> = rt
            .list_artifacts()?
            .into_iter()
            .filter_map(|a| {
                a.strip_suffix("_meta")
                    .filter(|s| s.starts_with("alexnet_s_w"))
                    .map(str::to_string)
            })
            .collect();
        let variants = if variants.is_empty() {
            // meta files are not artifacts; fall back to scanning metas
            scan_width_variants(&rt)?
        } else {
            variants
        };
        if variants.is_empty() {
            bail!("no alexnet_s width variants found — run `make artifacts-table5`");
        }
        println!("\n== Table V: channel-width ablation ==");
        println!("{}", report::table5(&rt, &base, &variants)?.render());
        ran = true;
    }
    if args.flag("table6") {
        let m6 = args.get_str_list("models", &["alexnet_s", "resnet_s", "mobilenet_s"]);
        println!("\n== Table VI: hard-benchmark headline ==");
        println!("{}", report::table6(&rt, &base, &m6)?.render());
        ran = true;
    }
    if args.flag("ablations") {
        let model = args.get_or("model", "alexnet_s");
        println!("\n== Ablations: early selection + warm start ==");
        println!(
            "{}",
            report::ablation_early_and_warmstart(&rt, &base, model)?.render()
        );
        ran = true;
    }
    if !ran {
        bail!("sweep: pass at least one of --table2..--table6 / --ablations");
    }
    Ok(())
}

fn scan_width_variants(rt: &Runtime) -> Result<Vec<String>> {
    let mut variants = Vec::new();
    for entry in std::fs::read_dir(rt.artifact_dir())? {
        let name = entry?.file_name().to_string_lossy().into_owned();
        if let Some(stem) = name.strip_suffix("_meta.json") {
            if stem.starts_with("alexnet_s_w") {
                variants.push(stem.to_string());
            }
        }
    }
    variants.sort();
    Ok(variants)
}

fn cmd_baseline(args: &Args) -> Result<()> {
    let base = base_config(args)?;
    let rt = Runtime::cpu(&base.artifact_dir)?;
    let models = args.get_str_list("models", &["alexnet_s", "resnet_s"]);
    let mut ran = false;
    if args.flag("table7") {
        println!("\n== Table VII: vs uniform + profiled quantization ==");
        let out = report::table7(&rt, &base, &models)?;
        println!("{}", out.table.render());
        println!("\n== Table VIII: accelerator benefits (same assignments) ==");
        println!(
            "{}",
            report::table8(&rt, &base.out_dir, &out.assignments)?.render()
        );
        ran = true;
    }
    if args.flag("mpdnn") {
        println!("\n== MPDNN comparison (§III-B6) ==");
        println!("{}", report::mpdnn_compare(&rt, &base, &models)?.render());
        ran = true;
    }
    if !ran {
        bail!("baseline: pass --table7 and/or --mpdnn");
    }
    Ok(())
}

fn cmd_accel(args: &Args) -> Result<()> {
    // Standalone accelerator-model evaluation at given uniform bits, no
    // training required — useful for sanity checks and the bench.
    let base = base_config(args)?;
    let rt = Runtime::cpu(&base.artifact_dir)?;
    let model = args.get_or("model", "resnet_s");
    let meta = bitprune::model::ModelMeta::load(
        rt.artifact_dir().join(format!("{model}_meta.json")),
    )?;
    let bits = args.get_f64("bits", 4.0)? as f32;
    let nl = meta.num_quant_layers;
    let bw = vec![bits; nl];
    let ba = vec![bits; nl];
    let mut t = Table::new(&["accelerator", "speedup vs 8b", "memory vs 8b"]);
    for r in bitprune::accel::evaluate_all(&meta, &bw, &ba) {
        t.row(vec![
            r.accel.into(),
            r.speedup.map_or("-".into(), |s| format!("{s:.2}x")),
            format!("{:.2}x", r.mem_ratio),
        ]);
    }
    println!("{model} at uniform {bits} bits:\n{}", t.render());
    Ok(())
}

fn cmd_parity(args: &Args) -> Result<()> {
    // Bit-exactness check: compiled fake_quant artifact vs rust mirror.
    let base = base_config(args)?;
    let rt = Runtime::cpu(&base.artifact_dir)?;
    let exe = rt.load("fake_quant")?;
    let mut rng = Rng::new(base.seed);
    let mut worst = 0.0f32;
    for case in 0..16 {
        let n = rng.range_f32(1.0, 9.0);
        let xs: Vec<f32> = (0..4096).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let out = exe.run(&[
            HostTensor::f32(&[4096], xs.clone())?,
            HostTensor::scalar_f32(n),
        ])?;
        let got = out[0].as_f32()?;
        let mut want = xs.clone();
        quant::fake_quant_slice(&mut want, n);
        for (g, w) in got.iter().zip(&want) {
            worst = worst.max((g - w).abs());
        }
        println!("case {case:2}: n={n:.3} max|Δ|={worst:.2e}");
    }
    if worst > 1e-5 {
        bail!("parity FAILED: max deviation {worst}");
    }
    println!("parity OK (max deviation {worst:.2e})");
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let base = base_config(args)?;
    let rt = Runtime::cpu(&base.artifact_dir)?;
    for name in rt.list_artifacts()? {
        println!("{name}");
    }
    Ok(())
}

fn cmd_hlo(args: &Args) -> Result<()> {
    // Static cost analysis (L2 perf pass): op mix, FLOPs, transfer bytes.
    let base = base_config(args)?;
    let rt = Runtime::cpu(&base.artifact_dir)?;
    let filter = args.get("model");
    let mut names = rt.list_artifacts()?;
    if let Some(f) = filter {
        names.retain(|n| n.starts_with(f));
    }
    for name in names {
        let report = bitprune::hlo::analyze_file(rt.artifact_path(&name))?;
        println!("{}", report.summary());
    }
    Ok(())
}

fn cmd_pack(args: &Args) -> Result<()> {
    // Train quickly (or at the configured budget), then bit-pack the
    // weights at the learned bitlengths: the Proteus row of Table VIII
    // as actual bytes on disk.
    let cfg = base_config(args)?;
    let rt = Runtime::cpu(&cfg.artifact_dir)?;
    let meta = bitprune::model::ModelMeta::load(
        rt.artifact_dir().join(format!("{}_meta.json", cfg.model)),
    )?;
    eprintln!("training {} to learn bitlengths...", cfg.model);
    let out = run_experiment(&rt, &cfg)?;

    // Collect the quantized weight tensors in layer order.
    let mut tensors: Vec<(String, &[f32])> = Vec::new();
    for (i, geom) in meta.layers.iter().enumerate() {
        let idx = meta
            .param_names
            .iter()
            .position(|n| n == &format!("{i}/w"))
            .ok_or_else(|| anyhow::anyhow!("no weight param for layer {i}"))?;
        tensors.push((geom.name.clone(), out.final_params[idx].as_f32()?));
    }
    let (_, report) =
        bitprune::bitpack::pack_network(&tensors, &out.final_.bits_w)?;
    let mut t = Table::new(&["layer", "bits", "f32 KiB", "packed KiB", "ratio"]);
    for ((name, f32b, packb), bits) in report.per_layer.iter().zip(&out.final_.bits_w) {
        t.row(vec![
            name.clone(),
            format!("{bits:.0}"),
            format!("{:.1}", *f32b as f64 / 1024.0),
            format!("{:.2}", *packb as f64 / 1024.0),
            format!("{:.1}x", *f32b as f64 / *packb as f64),
        ]);
    }
    println!("{}", t.render());
    println!(
        "total: {:.1} KiB f32 -> {:.2} KiB packed ({:.1}x, vs 4.0x for uniform 8-bit)",
        report.total_f32_bytes as f64 / 1024.0,
        report.total_packed_bytes as f64 / 1024.0,
        report.ratio()
    );
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    // Integer-arithmetic deployment check on a dense model.
    let mut cfg = base_config(args)?;
    if args.get("model").is_none() {
        cfg.model = "mlp".into();
        cfg.dataset = "blobs".into();
    }
    let rt = Runtime::cpu(&cfg.artifact_dir)?;
    eprintln!("training {} to learn bitlengths...", cfg.model);
    let trainer = bitprune::coordinator::Trainer::new(&rt, &cfg)?;
    let out = trainer.run()?;
    // Build the integer net once (packing + tiling every layer), with
    // the trainer's full-test-set activation ranges as calibration —
    // the deployment convention: logits no longer depend on batch
    // composition.  Reused for footprint reporting and the accuracy
    // pass.
    let session = trainer
        .session(&out.final_params)
        .with_calibration(out.act_min.clone(), out.act_max.clone());
    let net = session.int_net(&out.final_.bits_w, &out.final_.bits_a)?;
    eprintln!("integer net calibrated: batch-invariant logits");

    // Integer path over the full test split (blocked i64 GEMM, no PJRT).
    let int_acc = session.int_net_accuracy(&net, usize::MAX)?;
    println!(
        "integer-arithmetic accuracy: {:.2}% | XLA fake-quant accuracy: {:.2}%",
        int_acc * 100.0,
        out.final_.accuracy * 100.0
    );
    println!(
        "packed model: {:.2} KiB (f32: {:.1} KiB, {:.1}x smaller)",
        net.packed_bytes() as f64 / 1024.0,
        net.f32_bytes() as f64 / 1024.0,
        net.f32_bytes() as f64 / net.packed_bytes() as f64
    );
    let gap = (int_acc - out.final_.accuracy).abs();
    if gap > 0.02 {
        bail!("integer inference deviates {:.2}pp from the XLA path", gap * 100.0);
    }
    println!("INTEGER INFERENCE OK (gap {:.2}pp)", gap * 100.0);
    Ok(())
}

/// Parse the `--granularity layer|channel` option (default per-layer).
fn arg_granularity(args: &Args) -> Result<quant::Granularity> {
    match args.get("granularity") {
        None => Ok(quant::Granularity::PerLayer),
        Some(g) => quant::Granularity::parse(g).ok_or_else(|| {
            anyhow::anyhow!("unknown granularity '{g}' — expected 'layer' or 'channel'")
        }),
    }
}

/// Parse the `--codebook uniform|pot|apot` option (default uniform).
fn arg_codebook(args: &Args) -> Result<quant::Codebook> {
    match args.get("codebook") {
        None => Ok(quant::Codebook::Uniform),
        Some(c) => quant::Codebook::parse(c).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown codebook '{c}' — expected 'uniform', 'pot' or 'apot'"
            )
        }),
    }
}

/// `[bitlength]: channel count` histogram line for grouped models.
fn bits_histogram_line(h: &[usize; 17]) -> String {
    (1..=16usize)
        .filter(|&b| h[b] > 0)
        .map(|b| format!("{b}b:{}", h[b]))
        .collect::<Vec<_>>()
        .join(" ")
}

/// Train (when artifacts permit) and return a calibrated integer net.
fn trained_calibrated_net(
    cfg: &RunConfig,
    granularity: quant::Granularity,
) -> Result<bitprune::infer::IntNet> {
    let rt = Runtime::cpu(&cfg.artifact_dir)?;
    eprintln!("training {} to learn bitlengths...", cfg.model);
    let trainer = bitprune::coordinator::Trainer::new(&rt, cfg)?;
    let out = trainer.run()?;
    let session = trainer
        .session(&out.final_params)
        .with_calibration(out.act_min.clone(), out.act_max.clone());
    session.int_net_with(&out.final_.bits_w, &out.final_.bits_a, granularity)
}

/// Rebuild a calibrated integer net from a saved checkpoint + the
/// model meta — no training, no dataset.  Calibrated activation
/// ranges are taken from the checkpoint's `cal/act_min`/`cal/act_max`
/// tensors when present (the trainer saves them).
fn net_from_checkpoint(
    cfg: &RunConfig,
    ckpt_path: &str,
    granularity: quant::Granularity,
) -> Result<bitprune::infer::IntNet> {
    use bitprune::checkpoint::Checkpoint;
    let ckpt = Checkpoint::load(ckpt_path)?;
    let meta = bitprune::model::ModelMeta::load(
        std::path::Path::new(&cfg.artifact_dir).join(format!("{}_meta.json", cfg.model)),
    )?;
    let mut params = Vec::with_capacity(meta.param_names.len());
    for name in &meta.param_names {
        params.push(ckpt.get(&format!("p/{name}"))?.clone());
    }
    let bits_w = ckpt.get("bits_w")?.as_f32()?.to_vec();
    let bits_a = ckpt.get("bits_a")?.as_f32()?.to_vec();
    let ranges = match (ckpt.tensors.get("cal/act_min"), ckpt.tensors.get("cal/act_max")) {
        (Some(lo), Some(hi)) => Some((lo.as_f32()?.to_vec(), hi.as_f32()?.to_vec())),
        _ => {
            eprintln!(
                "warning: checkpoint '{ckpt_path}' carries no calibrated activation \
                 ranges (cal/act_min, cal/act_max) — the exported artifact will serve \
                 batch-dependent logits"
            );
            None
        }
    };
    bitprune::infer::IntNet::from_trained_with(
        &meta,
        &params,
        &bits_w,
        &bits_a,
        ranges.as_ref().map(|(lo, hi)| (lo.as_slice(), hi.as_slice())),
        granularity,
    )
}

/// Human-readable per-layer summary of a frozen artifact.
fn artifact_summary(art: &bitprune::deploy::Artifact) -> String {
    let mut t = Table::new(&[
        "layer", "shape", "W bits", "codebook", "A bits", "act range", "packed KiB",
    ]);
    for l in &art.layers {
        t.row(vec![
            l.name.clone(),
            match &l.conv {
                Some(g) => format!(
                    "{}x{}x{} k{}x{}s{}p{} ->{}",
                    g.cin, g.h, g.w, g.kh, g.kw, g.stride, g.pad, g.cout
                ),
                None => format!("{}x{}", l.din, l.dout),
            },
            match l.granularity() {
                quant::Granularity::PerLayer => format!("{}", l.w_bits()),
                quant::Granularity::PerOutputChannel => {
                    format!("{:.2} mean/ch (max {})", l.w_bits_mean(), l.w_bits())
                }
            },
            l.codebook().name().to_string(),
            format!("{}", l.a_bits),
            match l.act_range {
                Some((lo, hi)) => format!("[{lo:.3}, {hi:.3}]"),
                None => "dynamic".into(),
            },
            format!("{:.2}", l.stored_bytes() as f64 / 1024.0),
        ]);
    }
    let mut out = t.render();
    out.push_str(&format!(
        "\nmodel '{}': {} classes, mean bits W {:.2} / A {:.2}, \
         {:.2} KiB packed vs {:.1} KiB f32 ({:.1}x), calibrated: {}",
        art.model,
        art.num_classes,
        art.mean_w_bits(),
        art.mean_a_bits(),
        art.packed_bytes() as f64 / 1024.0,
        art.f32_bytes() as f64 / 1024.0,
        art.f32_bytes() as f64 / art.packed_bytes().max(1) as f64,
        art.is_calibrated(),
    ));
    if art.is_grouped() {
        out.push_str(&format!(
            "\ngranularity: per-output-channel | W bits histogram: {}",
            bits_histogram_line(&art.w_bits_histogram())
        ));
    }
    if art.has_codebook() {
        out.push_str(
            "\ncodebook: non-uniform weight codes (CBK0 section; \
             serves on the shift-add GEMM)",
        );
    }
    out
}

fn cmd_export(args: &Args) -> Result<()> {
    // Freeze a model into the single-file BPMA deployment artifact.
    // Sources, in priority order: --synthetic (the calibrated mlp
    // fixture), --ckpt FILE (a saved checkpoint + model meta, no
    // training), or a fresh training run.
    use bitprune::deploy::freeze;

    let mut cfg = base_config(args)?;
    if args.get("model").is_none() {
        cfg.model = "mlp".into();
        cfg.dataset = "blobs".into();
    }
    let out_path = args.get_or("out", "model.bpma").to_string();
    let bits = quant::int_bits(args.get_f64("bits", 4.0)? as f32);
    let gran = arg_granularity(args)?;
    let cbk = arg_codebook(args)?;
    if !cbk.is_uniform() && !args.flag("synthetic") {
        bail!(
            "export: --codebook {} is only wired to the synthetic fixtures for now \
             (trained/checkpoint exports quantize uniform) — add --synthetic",
            cbk.name()
        );
    }

    let arch = arg_arch(args)?;
    let (net, model_name) = if args.flag("synthetic") {
        let tag = if arch == SynthArch::Conv { "conv" } else { "mlp" };
        eprintln!(
            "freezing the synthetic calibrated {tag} fixture ({bits}-bit, {} granularity, {} codebook)",
            gran.name(),
            cbk.name()
        );
        (synthetic_for(arch, gran, cfg.seed, bits, cbk), format!("synthetic-{tag}"))
    } else if let Some(ckpt) = args.get("ckpt") {
        eprintln!("freezing checkpoint '{ckpt}' ({})", cfg.model);
        (net_from_checkpoint(&cfg, ckpt, gran)?, cfg.model.clone())
    } else {
        match trained_calibrated_net(&cfg, gran) {
            Ok(net) => (net, cfg.model.clone()),
            Err(e) => bail!(
                "export: cannot train here ({e:#})\n  \
                 hint: `bitprune export --synthetic --out {out_path}` freezes the \
                 synthetic fixture with no artifacts required, and \
                 `bitprune export --ckpt run.bpck` freezes a saved checkpoint"
            ),
        }
    };

    let art = freeze(&net, &model_name);
    art.save(&out_path)?;
    let file_bytes = std::fs::metadata(&out_path).map(|m| m.len()).unwrap_or(0);
    println!("{}", artifact_summary(&art));
    println!(
        "wrote {out_path} ({:.2} KiB on disk)\nserve it with: bitprune serve --model {out_path}",
        file_bytes as f64 / 1024.0
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    // Validate + describe a BPMA artifact: section table with
    // checksums, then the decoded per-layer bitlengths and footprint.
    use bitprune::deploy::{section_table, Artifact};

    let path = match args.get("model").or_else(|| args.pos(1)) {
        Some(p) => p.to_string(),
        None => bail!("usage: bitprune inspect <artifact.bpma>"),
    };
    let bytes = std::fs::read(&path)
        .map_err(|e| anyhow::anyhow!("reading '{path}': {e}"))?;

    let sections = section_table(&bytes)?;
    let mut t = Table::new(&["section", "offset", "bytes", "crc32", "status"]);
    for s in &sections {
        t.row(vec![
            s.tag.clone(),
            format!("{}", s.payload_offset),
            format!("{}", s.payload_len),
            format!("{:08x}", s.crc_stored),
            match (s.crc_ok, s.known) {
                (false, _) => "CORRUPT".into(),
                (true, false) => "ok (unknown, skipped)".into(),
                (true, true) => "ok".into(),
            },
        ]);
    }
    println!("{path}: BPMA v{}, {} sections", bitprune::deploy::artifact::VERSION, sections.len());
    println!("{}", t.render());

    let art = Artifact::from_bytes(&bytes)?;
    println!("{}", artifact_summary(&art));
    Ok(())
}

/// The synthetic architecture behind `--synthetic`: the calibrated mlp
/// fixture (default) or the conv fixture (`--arch conv`).
#[derive(Clone, Copy, PartialEq, Eq)]
enum SynthArch {
    Mlp,
    Conv,
}

fn arg_arch(args: &Args) -> Result<SynthArch> {
    match args.get("arch") {
        None | Some("mlp") => Ok(SynthArch::Mlp),
        Some("conv") => Ok(SynthArch::Conv),
        Some(a) => bail!("unknown arch '{a}' — expected 'mlp' or 'conv'"),
    }
}

/// The synthetic calibrated fixture at the requested architecture and
/// granularity.  Per-channel (mlp) / per-kernel (conv) weights cycle
/// through `{bits/2, bits, 2·bits}` (clamped to [1,16]) so `--bits`
/// steers the grouped fixtures too — the default `--bits 4` yields the
/// canonical 2/4/8 mix.
fn synthetic_for(
    arch: SynthArch,
    gran: quant::Granularity,
    seed: u64,
    bits: u32,
    cbk: quant::Codebook,
) -> bitprune::infer::IntNet {
    // Non-uniform codebooks select the codebook fixtures: the mlp one
    // deliberately mixes per-layer and per-channel layers (both
    // shift-plan shapes), so --granularity applies to uniform builds.
    if !cbk.is_uniform() {
        return match arch {
            SynthArch::Mlp => bitprune::serve::synthetic_net_cbk(
                &[32, 256, 128, 10],
                seed,
                bits,
                bits,
                cbk,
            ),
            SynthArch::Conv => {
                bitprune::serve::synthetic_conv_net_cbk(seed, bits, bits, cbk)
            }
        };
    }
    let cycle = [(bits / 2).max(1), bits, (bits * 2).min(16)];
    match (arch, gran) {
        (SynthArch::Mlp, quant::Granularity::PerLayer) => {
            bitprune::serve::synthetic_mlp(seed, bits, bits)
        }
        (SynthArch::Mlp, quant::Granularity::PerOutputChannel) => {
            bitprune::serve::synthetic_net_grouped(&[32, 256, 128, 10], seed, &cycle, bits)
        }
        (SynthArch::Conv, quant::Granularity::PerLayer) => {
            bitprune::serve::synthetic_conv_net(seed, bits, bits)
        }
        (SynthArch::Conv, quant::Granularity::PerOutputChannel) => {
            bitprune::serve::synthetic_conv_net_grouped(seed, &cycle, bits)
        }
    }
}

/// Does `--model` name a BPMA artifact file rather than a model tag?
fn looks_like_artifact(m: &str) -> bool {
    if m.ends_with(".bpma") {
        return true;
    }
    std::fs::File::open(m)
        .and_then(|mut f| {
            use std::io::Read;
            let mut magic = [0u8; 4];
            f.read_exact(&mut magic)?;
            Ok(&magic == bitprune::deploy::artifact::MAGIC)
        })
        .unwrap_or(false)
}

fn cmd_serve(args: &Args) -> Result<()> {
    // The batched integer-serving engine under synthetic closed-loop
    // load: N client threads fire single-sample requests, the server
    // micro-batches them (latency-deadline + max-batch flush), and we
    // report throughput plus latency percentiles.  Because the net is
    // calibrated, every answer is bit-identical to the sample's solo
    // forward regardless of how it was batched.
    //
    // With `--model FILE.bpma` the model comes from a frozen artifact:
    // no trainer, no dataset, no PJRT runtime in memory.  With
    // `--swap-to B.bpma [--swap-after N]` a second artifact is
    // published to the registry mid-traffic — the hot-swap demo: zero
    // rejected requests, per-version accounting, and the swap visible
    // only as a version-tag change in the responses.
    use bitprune::deploy::{Artifact, ModelRegistry};
    use bitprune::serve::{
        CanaryConfig, CanaryOutcome, RetryPolicy, ServeConfig, Server, ShedPolicy,
    };
    use bitprune::telemetry::{MetricsServer, Registry, SampleValue, TraceWriter};
    use bitprune::util::bench::{append_jsonl, BenchResult};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let mut cfg = base_config(args)?;
    let model_arg = args.get("model").map(str::to_string);
    let artifact_model = model_arg.as_deref().filter(|m| looks_like_artifact(m));
    if model_arg.is_none() || artifact_model.is_some() {
        cfg.model = "mlp".into();
        cfg.dataset = "blobs".into();
    }
    let requests = args.get_usize("requests", 1024)?;
    if requests == 0 {
        bail!("serve: --requests must be >= 1");
    }
    let window_us = args.get_u64("batch-window", 500)?;
    let max_batch = args.get_usize("max-batch", 64)?;
    let max_queue = args.get_usize("max-queue", 4096)?;
    let clients = args.get_usize("clients", 4)?.max(1);
    let threads = args.get_usize("threads", 0)?;
    let bits = quant::int_bits(args.get_f64("bits", 4.0)? as f32);
    let gran = arg_granularity(args)?;
    let cbk = arg_codebook(args)?;
    let deadline_ms = args.get_u64("deadline-ms", 0)?;
    let deadline = (deadline_ms > 0).then(|| Duration::from_millis(deadline_ms));
    let shed_policy = match args.get("shed-policy") {
        None => ShedPolicy::default(),
        Some(s) => ShedPolicy::parse(s).ok_or_else(|| {
            anyhow::anyhow!(
                "serve: unknown --shed-policy '{s}' (expected reject-newest or drop-expired)"
            )
        })?,
    };

    let (net, label) = if let Some(path) = artifact_model {
        let art = Artifact::load(path)?;
        eprintln!(
            "loaded artifact '{path}': model '{}', {} layers, {:.2} KiB packed, calibrated: {}",
            art.model,
            art.layers.len(),
            art.packed_bytes() as f64 / 1024.0,
            art.is_calibrated(),
        );
        if !art.is_calibrated() {
            eprintln!(
                "warning: artifact has no calibrated activation ranges — logits \
                 will depend on micro-batch composition"
            );
        }
        (art.instantiate()?, path.to_string())
    } else if args.flag("synthetic") {
        let arch = arg_arch(args)?;
        let tag = if arch == SynthArch::Conv { "conv" } else { "mlp" };
        eprintln!(
            "serving the synthetic calibrated {tag} fixture ({bits}-bit, {} granularity, {} codebook)",
            gran.name(),
            cbk.name()
        );
        (synthetic_for(arch, gran, cfg.seed, bits, cbk), format!("synthetic-{tag}"))
    } else {
        match trained_calibrated_net(&cfg, gran) {
            Ok(net) => (net, cfg.model.clone()),
            Err(e) => {
                eprintln!(
                    "no servable model: training is unavailable here ({e:#})\n  \
                     hint: freeze a deployable artifact once and serve it with no \
                     trainer or dataset:\n    \
                     bitprune export --synthetic --out model.bpma\n    \
                     bitprune serve --model model.bpma\n  \
                     falling back to the synthetic calibrated mlp fixture"
                );
                (
                    synthetic_for(SynthArch::Mlp, gran, cfg.seed, bits, cbk),
                    "synthetic-mlp".into(),
                )
            }
        }
    };
    if net
        .layers
        .iter()
        .any(|l| l.granularity() == quant::Granularity::PerOutputChannel)
    {
        eprintln!(
            "per-channel W bits: mean {:.2} | histogram: {}",
            net.mean_w_bits(),
            bits_histogram_line(&net.w_bits_histogram())
        );
    }
    if net.layers.iter().any(|l| !l.codebook().is_uniform()) {
        eprintln!("non-uniform weight codebooks: serving on the shift-add GEMM");
    }
    eprintln!("gemm kernel dispatch: {}", bitprune::infer::simd::describe());
    if args.flag("profile") {
        let mut prof = bitprune::infer::ForwardProfile::new();
        let mut scratch = bitprune::infer::NetScratch::default();
        let n = 16usize;
        let mut rng = Rng::new(0xF11E);
        let x: Vec<f32> =
            (0..n * net.in_features()).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        // Warm the scratch once so the profiled pass reports steady-state
        // time, not first-touch allocation.
        net.forward_into(&x, n, &mut scratch, None);
        net.forward_into_profiled(&x, n, &mut scratch, None, &mut prof);
        println!("{}", prof.report());
    }
    let net = Arc::new(net);
    let din = net.in_features();

    // Load the swap target up front so a bad file fails before traffic.
    let swap_to: Option<(Arc<bitprune::infer::IntNet>, String)> =
        match args.get("swap-to") {
            Some(path) => {
                let art = Artifact::load(path)?;
                Some((Arc::new(art.instantiate()?), path.to_string()))
            }
            None => None,
        };
    let swap_after = args.get_usize("swap-after", requests / 2)?;

    // Canary staging conflicts with the publish-based swap demo: the
    // registry refuses publishes while an experiment is in flight.
    let canary_arg = args.get("canary").map(str::to_string);
    if canary_arg.is_some() && swap_to.is_some() {
        bail!("serve: --canary and --swap-to are mutually exclusive (publish is refused while a canary is in flight)");
    }

    // Observability: the server publishes every counter/gauge/histogram
    // into this registry (the handles *are* the ServeStats ledger);
    // --metrics-addr exposes it over HTTP, --trace-out records the
    // typed lifecycle event stream.
    let telemetry = Arc::new(Registry::new());
    let trace: Option<Arc<TraceWriter>> = match args.get("trace-out") {
        Some(path) => {
            let tw = TraceWriter::create(std::path::Path::new(path))?;
            eprintln!("tracing lifecycle events to {path}");
            Some(Arc::new(tw))
        }
        None => None,
    };
    let mut metrics_http: Option<MetricsServer> = match args.get("metrics-addr") {
        Some(addr) => {
            let srv = MetricsServer::start(addr, Arc::clone(&telemetry))?;
            eprintln!(
                "metrics endpoint live at http://{0}/metrics (text) and \
                 http://{0}/metrics.json (json)",
                srv.addr()
            );
            Some(srv)
        }
        None => None,
    };

    let registry = Arc::new(ModelRegistry::new(Arc::clone(&net), &label)?);
    let server = Server::start_observed(
        Arc::clone(&registry),
        ServeConfig {
            threads,
            max_batch,
            max_queue,
            batch_window: Duration::from_micros(window_us),
            deadline,
            shed_policy,
        },
        Arc::clone(&telemetry),
        trace,
    )?;
    if let Some(path) = &canary_arg {
        let art = Artifact::load(path)?;
        let cnet = Arc::new(art.instantiate()?);
        let ccfg = CanaryConfig {
            pct: args.get_usize("canary-pct", 10)?.min(99) as u8,
            window: args.get_usize("canary-window", 64)?,
            promote_after: args.get_usize("canary-promote", 3)?,
            ..CanaryConfig::default()
        };
        let pct = ccfg.pct;
        let v = server.start_canary(cnet, path, ccfg)?;
        eprintln!(
            "staged canary '{path}' as v{v} at {pct}% of traffic \
             (auto-promotes or rolls back online)"
        );
    }
    eprintln!(
        "serving {requests} requests from {clients} clients \
         (max_batch {max_batch}, window {window_us}us, deadline {}, shed {})...",
        if deadline_ms > 0 {
            format!("{deadline_ms}ms")
        } else {
            "none".into()
        },
        shed_policy.name(),
    );
    if swap_to.is_some() {
        eprintln!("will hot-swap to the --swap-to artifact after ~{swap_after} responses");
    }

    let served = AtomicUsize::new(0);
    let shed = AtomicUsize::new(0);
    let t0 = Instant::now();
    let mut samples: Vec<(u64, f64)> = Vec::with_capacity(requests);
    let mut swap_version: Option<u64> = None;
    std::thread::scope(|scope| -> Result<()> {
        let mut joins = Vec::new();
        for c in 0..clients {
            let handle = server.handle();
            let (served, shed) = (&served, &shed);
            let n_req = requests / clients + usize::from(c < requests % clients);
            joins.push(scope.spawn(move || -> Result<Vec<(u64, f64)>> {
                let mut rng = Rng::new(0xC11E47 + c as u64);
                // Retryable rejections (backpressure, a panicked
                // batch) back off and retry; sheds that survive the
                // retry budget are counted, not fatal.
                let policy =
                    RetryPolicy { seed: 0x8E7247 ^ c as u64, ..RetryPolicy::default() };
                let mut lats = Vec::with_capacity(n_req);
                for _ in 0..n_req {
                    let x: Vec<f32> =
                        (0..din).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                    let t = Instant::now();
                    match handle.infer_with_retry(x, &policy) {
                        Ok((version, _)) => {
                            lats.push((version, t.elapsed().as_secs_f64()));
                            served.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) if e.is_shed() => {
                            shed.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => return Err(e.into()),
                    }
                }
                Ok(lats)
            }));
        }
        // The swapper: wait for the trigger count, then publish —
        // atomically, while the clients keep hammering the server.
        if let Some((swap_net, swap_label)) = &swap_to {
            while served.load(Ordering::Relaxed) < swap_after.min(requests) {
                if joins.iter().all(|j| j.is_finished()) {
                    break; // clients bailed early; don't spin forever
                }
                std::thread::sleep(Duration::from_micros(200));
            }
            let t = Instant::now();
            let v = registry.publish(Arc::clone(swap_net), swap_label)?;
            eprintln!(
                "published '{swap_label}' as v{v} after {} responses ({:.1}us publish)",
                served.load(Ordering::Relaxed),
                t.elapsed().as_secs_f64() * 1e6
            );
            swap_version = Some(v);
        }
        for j in joins {
            samples.extend(j.join().expect("client thread panicked")?);
        }
        Ok(())
    })?;
    let wall = t0.elapsed().as_secs_f64();

    // Post-drain check: once the swap landed, a fresh request must be
    // served by the new version only.
    if let Some(v) = swap_version {
        let handle = server.handle();
        let x: Vec<f32> = vec![0.0; din];
        let (got, _) = handle.infer_versioned(x)?;
        if got != v {
            bail!("post-swap request served by v{got}, expected v{v}");
        }
        println!("post-drain request served by v{v} (the swapped-in model)");
    }
    let canary_status = server.canary_status();
    let stats = server.shutdown();
    if let Some(m) = &mut metrics_http {
        m.shutdown();
    }

    // One formatting path: everything below renders the telemetry
    // snapshot.  `ServeStats` stays the exact ledger — the two cannot
    // disagree because the registry handles *are* the stats atomics,
    // which the asserts here make literal.
    let snap = telemetry.snapshot();
    let counter = |name: &str, label: Option<(&str, &str)>| -> u64 {
        snap.iter()
            .find(|s| {
                s.name == name
                    && label.map_or(true, |(k, v)| {
                        s.labels.iter().any(|(lk, lv)| lk == k && lv == v)
                    })
            })
            .and_then(|s| match s.value {
                SampleValue::Counter(v) => Some(v),
                _ => None,
            })
            .unwrap_or(0)
    };
    let hist = |name: &str| -> Option<(u64, f64, f64, f64, f64)> {
        snap.iter().find(|s| s.name == name).and_then(|s| match s.value {
            SampleValue::Histogram { count, sum, p50, p95, p99 } => {
                Some((count, sum, p50, p95, p99))
            }
            _ => None,
        })
    };
    let requests_total = counter("serve_requests_total", None);
    let shed_queue_full = counter("serve_shed_total", Some(("reason", "queue_full")));
    let shed_expired = counter("serve_shed_total", Some(("reason", "expired")));
    let failed_total = counter("serve_failed_total", None);
    assert_eq!(requests_total, stats.requests, "registry is the ServeStats ledger");
    assert_eq!(shed_queue_full, stats.shed_queue_full);
    assert_eq!(shed_expired, stats.shed_expired);
    assert_eq!(failed_total, stats.failed);

    let latencies: Vec<f64> = samples.iter().map(|(_, l)| *l).collect();
    if latencies.is_empty() {
        println!(
            "served 0 requests — every request was shed \
             ({shed_queue_full} queue-full, {shed_expired} deadline-expired, \
             policy {})",
            shed_policy.name()
        );
        return Ok(());
    }
    let lat = BenchResult::from_samples("serve/request_latency", latencies, None);
    println!("{}", lat.report());
    let (p50, p95, p99) = hist("serve_request_latency_seconds")
        .map(|(_, _, p50, p95, p99)| (p50, p95, p99))
        .unwrap_or((0.0, 0.0, 0.0));
    let batches_total = counter("serve_batches_total", None);
    let mean_batch = hist("serve_batch_size")
        .map(|(count, sum, _, _, _)| if count > 0 { sum / count as f64 } else { 0.0 })
        .unwrap_or(0.0);
    println!(
        "served {} requests in {:.3}s -> {:.0} req/s | \
         p50 {:.0}us p95 {:.0}us p99 {:.0}us | \
         {} batches, mean batch {:.1}, {} swap(s)",
        requests_total,
        wall,
        requests_total as f64 / wall,
        p50 * 1e6,
        p95 * 1e6,
        p99 * 1e6,
        batches_total,
        mean_batch,
        counter("serve_swaps_total", None),
    );
    let shed_total = shed_queue_full + shed_expired;
    if shed_total > 0 || failed_total > 0 || shed.load(Ordering::Relaxed) > 0 {
        println!(
            "shed {shed_total} requests ({shed_queue_full} queue-full, \
             {shed_expired} deadline-expired; policy {}) | \
             {failed_total} failed on panicked batches | {} gave up after retries \
             ({} retry attempts)",
            shed_policy.name(),
            shed.load(Ordering::Relaxed),
            counter("serve_retries_total", None),
        );
    }
    if counter("pool_respawns_total", None) > 0 {
        println!(
            "worker pool respawned {} dead worker(s)",
            counter("pool_respawns_total", None)
        );
    }
    if let Some(status) = &canary_status {
        let agreement = status
            .agreement()
            .map(|a| format!("{:.1}%", a * 100.0))
            .unwrap_or_else(|| "n/a".into());
        match &status.outcome {
            Some(CanaryOutcome::Promoted { version }) => println!(
                "canary v{version} PROMOTED after {} healthy windows \
                 ({} canary requests, agreement {agreement})",
                status.healthy_windows, status.served
            ),
            Some(CanaryOutcome::RolledBack { version, reason }) => println!(
                "canary v{version} ROLLED BACK ({reason}) — incumbent \
                 v{} never stopped serving",
                status.incumbent_version
            ),
            None => println!(
                "canary v{} still in flight: {} requests served at {}%, \
                 agreement {agreement}, {} healthy window(s)",
                status.canary_version, status.served, status.pct, status.healthy_windows
            ),
        }
    }
    if swap_version.is_some() {
        let mut by_version: Vec<(u64, usize)> = Vec::new();
        for &(v, _) in &samples {
            match by_version.iter_mut().find(|(bv, _)| *bv == v) {
                Some((_, n)) => *n += 1,
                None => by_version.push((v, 1)),
            }
        }
        by_version.sort_unstable();
        let counts: Vec<String> =
            by_version.iter().map(|(v, n)| format!("v{v}: {n}")).collect();
        println!(
            "zero rejected requests across the swap | responses by version: {}",
            counts.join(", ")
        );
    }

    // Unbatched per-call baseline (allocating IntNet::forward, batch 1)
    // over a subset, for context in the same report format.
    let probe = requests.min(256);
    let mut rng = Rng::new(0xBA5E);
    let mut base_lats = Vec::with_capacity(probe);
    for _ in 0..probe {
        let x: Vec<f32> = (0..din).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t = Instant::now();
        std::hint::black_box(net.forward(&x, 1));
        base_lats.push(t.elapsed().as_secs_f64());
    }
    let base = BenchResult::from_samples("serve/percall_forward_bs1", base_lats, None);
    println!("{}", base.report());
    append_jsonl(&[lat, base]);
    Ok(())
}

fn cmd_metrics(args: &Args) -> Result<()> {
    // Scrape a running server's `--metrics-addr` endpoint and render
    // the JSON snapshot as a table (histograms show count/sum and the
    // shared-implementation p50/p95/p99).
    let addr = args
        .get("addr")
        .ok_or_else(|| anyhow::anyhow!("usage: bitprune metrics --addr HOST:PORT"))?;
    let body = bitprune::telemetry::http_get(addr, "/metrics.json")?;
    let v = bitprune::util::json::parse(&body)?;
    let metrics = v.get("metrics")?.as_arr()?;
    let mut t = Table::new(&["metric", "type", "value"]);
    for m in metrics {
        let name = m.get("name")?.as_str()?;
        let labels = m.get("labels")?.as_obj()?;
        let series = if labels.is_empty() {
            name.to_string()
        } else {
            let parts: Vec<String> = labels
                .iter()
                .map(|(k, v)| Ok(format!("{k}=\"{}\"", v.as_str()?)))
                .collect::<Result<_>>()?;
            format!("{name}{{{}}}", parts.join(","))
        };
        let ty = m.get("type")?.as_str()?;
        let value = match ty {
            "histogram" => format!(
                "count {} | sum {:.6} | p50 {:.6} p95 {:.6} p99 {:.6}",
                m.get("count")?.as_f64()?,
                m.get("sum")?.as_f64()?,
                m.get("p50")?.as_f64()?,
                m.get("p95")?.as_f64()?,
                m.get("p99")?.as_f64()?,
            ),
            _ => format!("{}", m.get("value")?.as_f64()?),
        };
        t.row(vec![series, ty.to_string(), value]);
    }
    println!("scraped http://{addr}/metrics.json — {} series", metrics.len());
    println!("{}", t.render());
    Ok(())
}

fn cmd_fig(args: &Args) -> Result<()> {
    // Render Fig 1/2 (training curve) and Fig 3 (per-layer bits) from a
    // recorded run JSON.
    use bitprune::report::plots::{bar_chart, line_chart, Series};
    let path = args
        .pos(1)
        .ok_or_else(|| anyhow::anyhow!("usage: bitprune fig reports/<run>.json"))?;
    let text = std::fs::read_to_string(path)?;
    let v = bitprune::util::json::parse(&text)?;
    let evals = v.get("evals")?.as_arr()?;
    let acc: Vec<(f64, f64)> = evals
        .iter()
        .map(|e| {
            Ok((
                e.get("step")?.as_f64()?,
                e.get("accuracy")?.as_f64()? * 100.0,
            ))
        })
        .collect::<Result<_>>()?;
    let bits: Vec<(f64, f64)> = evals
        .iter()
        .map(|e| Ok((e.get("step")?.as_f64()?, e.get("bits_w")?.as_f64()?)))
        .collect::<Result<_>>()?;
    println!("Fig 1/2 — accuracy (%) and mean weight bits vs step:");
    println!(
        "{}",
        line_chart(
            &[Series::new("accuracy %", acc), Series::new("bits (W)", bits)],
            64,
            16
        )
    );
    let bw = v.get("final_bits_w")?.as_arr()?;
    let ba = v.get("final_bits_a")?.as_arr()?;
    let mut items = Vec::new();
    for (i, (w, a)) in bw.iter().zip(ba).enumerate() {
        items.push((format!("L{i} W"), w.as_f64()?));
        items.push((format!("L{i} A"), a.as_f64()?));
    }
    println!("Fig 3 — final per-layer bitlengths:");
    println!("{}", bar_chart(&items, 32));
    Ok(())
}

// Extension trait workaround: keep CLI option list in one place.
trait CliOpts {
    fn cli_value_opts_extended() -> Vec<&'static str>;
}

impl CliOpts for RunConfig {
    fn cli_value_opts_extended() -> Vec<&'static str> {
        let mut v = RunConfig::cli_value_opts();
        v.extend_from_slice(&[
            "gammas",
            "models",
            "bits",
            "requests",
            "batch-window",
            "max-batch",
            "max-queue",
            "clients",
            "threads",
            // deploy subsystem (export / inspect / serve --model X.bpma)
            "ckpt",
            "swap-to",
            "swap-after",
            // failure hardening (serve)
            "deadline-ms",
            "shed-policy",
            "canary",
            "canary-pct",
            "canary-window",
            "canary-promote",
            // weight-quantization granularity (export / serve)
            "granularity",
            // weight codebook (export / serve --synthetic)
            "codebook",
            // synthetic fixture architecture (export / serve --synthetic)
            "arch",
            // observability (serve / metrics)
            "metrics-addr",
            "trace-out",
            "addr",
        ]);
        v
    }
}
