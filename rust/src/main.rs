//! `bitprune` — the L3 coordinator CLI.
//!
//! Subcommands map one-to-one onto the paper's evaluation (DESIGN.md §6):
//!
//! ```text
//! bitprune train   [opts]                 one training run
//! bitprune sweep   --table2|--table3|--table4|--table5|--table6|--ablations
//! bitprune baseline --table7|--mpdnn      comparison baselines
//! bitprune accel   [--model M]            Table VIII accelerator models
//! bitprune parity                         rust quantizer vs fake_quant.hlo
//! bitprune artifacts                      list compiled artifacts
//! ```
//!
//! Common options: --config FILE, --model, --dataset, --gamma, --seed,
//! --learn-steps, --finetune-steps, --lr-max, --bits-lr, --init-bits,
//! --eval-every, --criterion, --plan, --artifacts DIR, --out DIR,
//! --gammas A,B,C, --models a,b,c, --no-augment.

use anyhow::{bail, Result};

use bitprune::config::{toml::TomlDoc, RunConfig};
use bitprune::coordinator::run_experiment;
use bitprune::metrics::Table;
use bitprune::quant;
use bitprune::report;
use bitprune::runtime::Runtime;
use bitprune::tensor::HostTensor;
use bitprune::util::args::Args;
use bitprune::util::rng::Rng;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn base_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => RunConfig::from_toml(&TomlDoc::load(path)?)?,
        None => RunConfig::default(),
    };
    cfg.apply_args(args)?;
    Ok(cfg)
}

fn run() -> Result<()> {
    let args = Args::from_env(&RunConfig::cli_value_opts_extended())?;
    let cmd = args.pos(0).unwrap_or("help").to_string();
    match cmd.as_str() {
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "baseline" => cmd_baseline(&args),
        "accel" => cmd_accel(&args),
        "parity" => cmd_parity(&args),
        "artifacts" => cmd_artifacts(&args),
        "hlo" => cmd_hlo(&args),
        "pack" => cmd_pack(&args),
        "infer" => cmd_infer(&args),
        "serve" => cmd_serve(&args),
        "fig" => cmd_fig(&args),
        "help" | "--help" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command '{other}' — try `bitprune help`"),
    }
}

const HELP: &str = "\
bitprune — BitPruning coordinator (learned bitlength quantization)

USAGE: bitprune <command> [options]

COMMANDS:
  train       run one training experiment
  sweep       regenerate paper tables II-VI + ablations
                (--table2 --table3 --table4 --table5 --table6 --ablations)
  baseline    comparison baselines (--table7 --mpdnn)
  accel       accelerator performance models (Table VIII)
  parity      rust quantizer vs compiled fake_quant artifact
  artifacts   list compiled artifacts
  hlo         static cost analysis of the compiled artifacts
  pack        train + bit-pack weights; report real storage footprint
  infer       pure-integer inference vs the compiled eval artifact
  serve       batched integer serving engine: throughput + latency
                percentiles (--requests N --batch-window USEC
                --max-batch N --clients N --threads N --synthetic)
  fig         render figure 1/3 ASCII charts from a reports/<run>.json

OPTIONS (common):
  --config FILE --model M --dataset D --gamma G --seed S
  --learn-steps N --finetune-steps N --lr-max F --bits-lr F
  --init-bits B --eval-every N --criterion equal|bs1|bs128|mac
  --plan standard|early|fixed|warmstart --warmstart-ckpt FILE
  --artifacts DIR --out DIR --gammas A,B,C --models a,b,c --no-augment
";

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = base_config(args)?;
    let rt = Runtime::cpu(&cfg.artifact_dir)?;
    eprintln!(
        "training {} on {} (platform: {})",
        cfg.model,
        cfg.dataset,
        rt.platform()
    );
    let outcome = run_experiment(&rt, &cfg)?;
    let meta = bitprune::model::ModelMeta::load(
        rt.artifact_dir().join(format!("{}_meta.json", cfg.model)),
    )?;
    let names: Vec<String> = meta.layers.iter().map(|l| l.name.clone()).collect();
    outcome.recorder.write_csvs(&cfg.out_dir, &names)?;

    let mut t = Table::new(&["stage", "accuracy", "W bits", "A bits"]);
    if let Some(ni) = &outcome.noninteger {
        t.row(vec![
            "non-integer".into(),
            format!("{:.2}%", ni.accuracy * 100.0),
            format!("{:.2}", ni.mean_bits_w()),
            format!("{:.2}", ni.mean_bits_a()),
        ]);
    }
    t.row(vec![
        "final".into(),
        format!("{:.2}%", outcome.final_.accuracy * 100.0),
        format!("{:.2}", outcome.final_.mean_bits_w()),
        format!("{:.2}", outcome.final_.mean_bits_a()),
    ]);
    println!("{}", t.render());
    println!("per-layer bits (W): {:?}", outcome.final_.bits_w);
    println!("per-layer bits (A): {:?}", outcome.final_.bits_a);
    println!("wall time: {:.1}s", outcome.wall_secs);
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let base = base_config(args)?;
    let rt = Runtime::cpu(&base.artifact_dir)?;
    let models = args.get_str_list("models", &["alexnet_s", "resnet_s"]);
    let gammas = args.get_f64_list("gammas", &[0.5, 1.0, 2.5, 5.0, 10.0])?;
    let mut ran = false;

    if args.flag("table2") {
        println!("\n== Table II: regularizer sweep ==");
        println!("{}", report::table2(&rt, &base, &models, &gammas)?.render());
        ran = true;
    }
    if args.flag("table3") {
        let m3 = args.get_str_list("models", &["mobilenet_s", "mlp"]);
        println!("\n== Table III: other architectures ==");
        println!("{}", report::table3(&rt, &base, &m3)?.render());
        ran = true;
    }
    if args.flag("table4") {
        println!("\n== Table IV: weighted bit-loss criteria ==");
        println!("{}", report::table4(&rt, &base, &models)?.render());
        ran = true;
    }
    if args.flag("table5") {
        let variants: Vec<String> = rt
            .list_artifacts()?
            .into_iter()
            .filter_map(|a| {
                a.strip_suffix("_meta")
                    .filter(|s| s.starts_with("alexnet_s_w"))
                    .map(str::to_string)
            })
            .collect();
        let variants = if variants.is_empty() {
            // meta files are not artifacts; fall back to scanning metas
            scan_width_variants(&rt)?
        } else {
            variants
        };
        if variants.is_empty() {
            bail!("no alexnet_s width variants found — run `make artifacts-table5`");
        }
        println!("\n== Table V: channel-width ablation ==");
        println!("{}", report::table5(&rt, &base, &variants)?.render());
        ran = true;
    }
    if args.flag("table6") {
        let m6 = args.get_str_list("models", &["alexnet_s", "resnet_s", "mobilenet_s"]);
        println!("\n== Table VI: hard-benchmark headline ==");
        println!("{}", report::table6(&rt, &base, &m6)?.render());
        ran = true;
    }
    if args.flag("ablations") {
        let model = args.get_or("model", "alexnet_s");
        println!("\n== Ablations: early selection + warm start ==");
        println!(
            "{}",
            report::ablation_early_and_warmstart(&rt, &base, model)?.render()
        );
        ran = true;
    }
    if !ran {
        bail!("sweep: pass at least one of --table2..--table6 / --ablations");
    }
    Ok(())
}

fn scan_width_variants(rt: &Runtime) -> Result<Vec<String>> {
    let mut variants = Vec::new();
    for entry in std::fs::read_dir(rt.artifact_dir())? {
        let name = entry?.file_name().to_string_lossy().into_owned();
        if let Some(stem) = name.strip_suffix("_meta.json") {
            if stem.starts_with("alexnet_s_w") {
                variants.push(stem.to_string());
            }
        }
    }
    variants.sort();
    Ok(variants)
}

fn cmd_baseline(args: &Args) -> Result<()> {
    let base = base_config(args)?;
    let rt = Runtime::cpu(&base.artifact_dir)?;
    let models = args.get_str_list("models", &["alexnet_s", "resnet_s"]);
    let mut ran = false;
    if args.flag("table7") {
        println!("\n== Table VII: vs uniform + profiled quantization ==");
        let out = report::table7(&rt, &base, &models)?;
        println!("{}", out.table.render());
        println!("\n== Table VIII: accelerator benefits (same assignments) ==");
        println!(
            "{}",
            report::table8(&rt, &base.out_dir, &out.assignments)?.render()
        );
        ran = true;
    }
    if args.flag("mpdnn") {
        println!("\n== MPDNN comparison (§III-B6) ==");
        println!("{}", report::mpdnn_compare(&rt, &base, &models)?.render());
        ran = true;
    }
    if !ran {
        bail!("baseline: pass --table7 and/or --mpdnn");
    }
    Ok(())
}

fn cmd_accel(args: &Args) -> Result<()> {
    // Standalone accelerator-model evaluation at given uniform bits, no
    // training required — useful for sanity checks and the bench.
    let base = base_config(args)?;
    let rt = Runtime::cpu(&base.artifact_dir)?;
    let model = args.get_or("model", "resnet_s");
    let meta = bitprune::model::ModelMeta::load(
        rt.artifact_dir().join(format!("{model}_meta.json")),
    )?;
    let bits = args.get_f64("bits", 4.0)? as f32;
    let nl = meta.num_quant_layers;
    let bw = vec![bits; nl];
    let ba = vec![bits; nl];
    let mut t = Table::new(&["accelerator", "speedup vs 8b", "memory vs 8b"]);
    for r in bitprune::accel::evaluate_all(&meta, &bw, &ba) {
        t.row(vec![
            r.accel.into(),
            r.speedup.map_or("-".into(), |s| format!("{s:.2}x")),
            format!("{:.2}x", r.mem_ratio),
        ]);
    }
    println!("{model} at uniform {bits} bits:\n{}", t.render());
    Ok(())
}

fn cmd_parity(args: &Args) -> Result<()> {
    // Bit-exactness check: compiled fake_quant artifact vs rust mirror.
    let base = base_config(args)?;
    let rt = Runtime::cpu(&base.artifact_dir)?;
    let exe = rt.load("fake_quant")?;
    let mut rng = Rng::new(base.seed);
    let mut worst = 0.0f32;
    for case in 0..16 {
        let n = rng.range_f32(1.0, 9.0);
        let xs: Vec<f32> = (0..4096).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let out = exe.run(&[
            HostTensor::f32(&[4096], xs.clone())?,
            HostTensor::scalar_f32(n),
        ])?;
        let got = out[0].as_f32()?;
        let mut want = xs.clone();
        quant::fake_quant_slice(&mut want, n);
        for (g, w) in got.iter().zip(&want) {
            worst = worst.max((g - w).abs());
        }
        println!("case {case:2}: n={n:.3} max|Δ|={worst:.2e}");
    }
    if worst > 1e-5 {
        bail!("parity FAILED: max deviation {worst}");
    }
    println!("parity OK (max deviation {worst:.2e})");
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let base = base_config(args)?;
    let rt = Runtime::cpu(&base.artifact_dir)?;
    for name in rt.list_artifacts()? {
        println!("{name}");
    }
    Ok(())
}

fn cmd_hlo(args: &Args) -> Result<()> {
    // Static cost analysis (L2 perf pass): op mix, FLOPs, transfer bytes.
    let base = base_config(args)?;
    let rt = Runtime::cpu(&base.artifact_dir)?;
    let filter = args.get("model");
    let mut names = rt.list_artifacts()?;
    if let Some(f) = filter {
        names.retain(|n| n.starts_with(f));
    }
    for name in names {
        let report = bitprune::hlo::analyze_file(rt.artifact_path(&name))?;
        println!("{}", report.summary());
    }
    Ok(())
}

fn cmd_pack(args: &Args) -> Result<()> {
    // Train quickly (or at the configured budget), then bit-pack the
    // weights at the learned bitlengths: the Proteus row of Table VIII
    // as actual bytes on disk.
    let cfg = base_config(args)?;
    let rt = Runtime::cpu(&cfg.artifact_dir)?;
    let meta = bitprune::model::ModelMeta::load(
        rt.artifact_dir().join(format!("{}_meta.json", cfg.model)),
    )?;
    eprintln!("training {} to learn bitlengths...", cfg.model);
    let out = run_experiment(&rt, &cfg)?;

    // Collect the quantized weight tensors in layer order.
    let mut tensors: Vec<(String, &[f32])> = Vec::new();
    for (i, geom) in meta.layers.iter().enumerate() {
        let idx = meta
            .param_names
            .iter()
            .position(|n| n == &format!("{i}/w"))
            .ok_or_else(|| anyhow::anyhow!("no weight param for layer {i}"))?;
        tensors.push((geom.name.clone(), out.final_params[idx].as_f32()?));
    }
    let (_, report) =
        bitprune::bitpack::pack_network(&tensors, &out.final_.bits_w)?;
    let mut t = Table::new(&["layer", "bits", "f32 KiB", "packed KiB", "ratio"]);
    for ((name, f32b, packb), bits) in report.per_layer.iter().zip(&out.final_.bits_w) {
        t.row(vec![
            name.clone(),
            format!("{bits:.0}"),
            format!("{:.1}", *f32b as f64 / 1024.0),
            format!("{:.2}", *packb as f64 / 1024.0),
            format!("{:.1}x", *f32b as f64 / *packb as f64),
        ]);
    }
    println!("{}", t.render());
    println!(
        "total: {:.1} KiB f32 -> {:.2} KiB packed ({:.1}x, vs 4.0x for uniform 8-bit)",
        report.total_f32_bytes as f64 / 1024.0,
        report.total_packed_bytes as f64 / 1024.0,
        report.ratio()
    );
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    // Integer-arithmetic deployment check on a dense model.
    let mut cfg = base_config(args)?;
    if args.get("model").is_none() {
        cfg.model = "mlp".into();
        cfg.dataset = "blobs".into();
    }
    let rt = Runtime::cpu(&cfg.artifact_dir)?;
    eprintln!("training {} to learn bitlengths...", cfg.model);
    let trainer = bitprune::coordinator::Trainer::new(&rt, &cfg)?;
    let out = trainer.run()?;
    // Build the integer net once (packing + tiling every layer), with
    // the trainer's full-test-set activation ranges as calibration —
    // the deployment convention: logits no longer depend on batch
    // composition.  Reused for footprint reporting and the accuracy
    // pass.
    let session = trainer
        .session(&out.final_params)
        .with_calibration(out.act_min.clone(), out.act_max.clone());
    let net = session.int_net(&out.final_.bits_w, &out.final_.bits_a)?;
    eprintln!("integer net calibrated: batch-invariant logits");

    // Integer path over the full test split (blocked i64 GEMM, no PJRT).
    let int_acc = session.int_net_accuracy(&net, usize::MAX)?;
    println!(
        "integer-arithmetic accuracy: {:.2}% | XLA fake-quant accuracy: {:.2}%",
        int_acc * 100.0,
        out.final_.accuracy * 100.0
    );
    println!(
        "packed model: {:.2} KiB (f32: {:.1} KiB, {:.1}x smaller)",
        net.packed_bytes() as f64 / 1024.0,
        net.f32_bytes() as f64 / 1024.0,
        net.f32_bytes() as f64 / net.packed_bytes() as f64
    );
    let gap = (int_acc - out.final_.accuracy).abs();
    if gap > 0.02 {
        bail!("integer inference deviates {:.2}pp from the XLA path", gap * 100.0);
    }
    println!("INTEGER INFERENCE OK (gap {:.2}pp)", gap * 100.0);
    Ok(())
}

/// Train (when artifacts permit) and return a calibrated integer net.
fn trained_calibrated_net(cfg: &RunConfig) -> Result<bitprune::infer::IntNet> {
    let rt = Runtime::cpu(&cfg.artifact_dir)?;
    eprintln!("training {} to learn bitlengths...", cfg.model);
    let trainer = bitprune::coordinator::Trainer::new(&rt, cfg)?;
    let out = trainer.run()?;
    let session = trainer
        .session(&out.final_params)
        .with_calibration(out.act_min.clone(), out.act_max.clone());
    session.int_net(&out.final_.bits_w, &out.final_.bits_a)
}

fn cmd_serve(args: &Args) -> Result<()> {
    // The batched integer-serving engine under synthetic closed-loop
    // load: N client threads fire single-sample requests, the server
    // micro-batches them (latency-deadline + max-batch flush), and we
    // report throughput plus latency percentiles.  Because the net is
    // calibrated, every answer is bit-identical to the sample's solo
    // forward regardless of how it was batched.
    use bitprune::serve::{ServeConfig, Server};
    use bitprune::util::bench::{append_jsonl, BenchResult};
    use std::sync::Arc;
    use std::time::{Duration, Instant};

    let mut cfg = base_config(args)?;
    if args.get("model").is_none() {
        cfg.model = "mlp".into();
        cfg.dataset = "blobs".into();
    }
    let requests = args.get_usize("requests", 1024)?;
    if requests == 0 {
        bail!("serve: --requests must be >= 1");
    }
    let window_us = args.get_u64("batch-window", 500)?;
    let max_batch = args.get_usize("max-batch", 64)?;
    let max_queue = args.get_usize("max-queue", 4096)?;
    let clients = args.get_usize("clients", 4)?.max(1);
    let threads = args.get_usize("threads", 0)?;
    // Same convention as from_trained/pack: clip, then ceil.
    let bits = quant::clip_bits(args.get_f64("bits", 4.0)? as f32).ceil() as u32;

    let net = if args.flag("synthetic") {
        eprintln!("serving the synthetic calibrated mlp fixture ({bits}-bit)");
        bitprune::serve::synthetic_mlp(cfg.seed, bits, bits)
    } else {
        match trained_calibrated_net(&cfg) {
            Ok(net) => net,
            Err(e) => {
                eprintln!(
                    "training unavailable ({e:#}); \
                     serving the synthetic calibrated mlp fixture instead"
                );
                bitprune::serve::synthetic_mlp(cfg.seed, bits, bits)
            }
        }
    };
    let net = Arc::new(net);
    let din = net.layers.first().map(|l| l.din).unwrap_or(0);

    let server = Server::start(
        Arc::clone(&net),
        ServeConfig {
            threads,
            max_batch,
            max_queue,
            batch_window: Duration::from_micros(window_us),
        },
    )?;
    eprintln!(
        "serving {requests} requests from {clients} clients \
         (max_batch {max_batch}, window {window_us}us)..."
    );

    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::with_capacity(requests);
    std::thread::scope(|scope| -> Result<()> {
        let mut joins = Vec::new();
        for c in 0..clients {
            let handle = server.handle();
            let n_req = requests / clients + usize::from(c < requests % clients);
            joins.push(scope.spawn(move || -> Result<Vec<f64>> {
                let mut rng = Rng::new(0xC11E47 + c as u64);
                let mut lats = Vec::with_capacity(n_req);
                for _ in 0..n_req {
                    let x: Vec<f32> =
                        (0..din).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                    let t = Instant::now();
                    handle.infer(x)?;
                    lats.push(t.elapsed().as_secs_f64());
                }
                Ok(lats)
            }));
        }
        for j in joins {
            latencies.extend(j.join().expect("client thread panicked")?);
        }
        Ok(())
    })?;
    let wall = t0.elapsed().as_secs_f64();
    let stats = server.shutdown();

    let lat = BenchResult::from_samples("serve/request_latency", latencies, None);
    println!("{}", lat.report());
    println!(
        "served {} requests in {:.3}s -> {:.0} req/s | \
         p50 {:.0}us p95 {:.0}us p99 {:.0}us | \
         {} batches, mean batch {:.1}",
        stats.requests,
        wall,
        stats.requests as f64 / wall,
        lat.median * 1e6,
        lat.p95 * 1e6,
        lat.percentile(99.0) * 1e6,
        stats.batches,
        stats.mean_batch(),
    );

    // Unbatched per-call baseline (allocating IntNet::forward, batch 1)
    // over a subset, for context in the same report format.
    let probe = requests.min(256);
    let mut rng = Rng::new(0xBA5E);
    let mut base_lats = Vec::with_capacity(probe);
    for _ in 0..probe {
        let x: Vec<f32> = (0..din).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let t = Instant::now();
        std::hint::black_box(net.forward(&x, 1));
        base_lats.push(t.elapsed().as_secs_f64());
    }
    let base = BenchResult::from_samples("serve/percall_forward_bs1", base_lats, None);
    println!("{}", base.report());
    append_jsonl(&[lat, base]);
    Ok(())
}

fn cmd_fig(args: &Args) -> Result<()> {
    // Render Fig 1/2 (training curve) and Fig 3 (per-layer bits) from a
    // recorded run JSON.
    use bitprune::report::plots::{bar_chart, line_chart, Series};
    let path = args
        .pos(1)
        .ok_or_else(|| anyhow::anyhow!("usage: bitprune fig reports/<run>.json"))?;
    let text = std::fs::read_to_string(path)?;
    let v = bitprune::util::json::parse(&text)?;
    let evals = v.get("evals")?.as_arr()?;
    let acc: Vec<(f64, f64)> = evals
        .iter()
        .map(|e| {
            Ok((
                e.get("step")?.as_f64()?,
                e.get("accuracy")?.as_f64()? * 100.0,
            ))
        })
        .collect::<Result<_>>()?;
    let bits: Vec<(f64, f64)> = evals
        .iter()
        .map(|e| Ok((e.get("step")?.as_f64()?, e.get("bits_w")?.as_f64()?)))
        .collect::<Result<_>>()?;
    println!("Fig 1/2 — accuracy (%) and mean weight bits vs step:");
    println!(
        "{}",
        line_chart(
            &[Series::new("accuracy %", acc), Series::new("bits (W)", bits)],
            64,
            16
        )
    );
    let bw = v.get("final_bits_w")?.as_arr()?;
    let ba = v.get("final_bits_a")?.as_arr()?;
    let mut items = Vec::new();
    for (i, (w, a)) in bw.iter().zip(ba).enumerate() {
        items.push((format!("L{i} W"), w.as_f64()?));
        items.push((format!("L{i} A"), a.as_f64()?));
    }
    println!("Fig 3 — final per-layer bitlengths:");
    println!("{}", bar_chart(&items, 32));
    Ok(())
}

// Extension trait workaround: keep CLI option list in one place.
trait CliOpts {
    fn cli_value_opts_extended() -> Vec<&'static str>;
}

impl CliOpts for RunConfig {
    fn cli_value_opts_extended() -> Vec<&'static str> {
        let mut v = RunConfig::cli_value_opts();
        v.extend_from_slice(&[
            "gammas",
            "models",
            "bits",
            "requests",
            "batch-window",
            "max-batch",
            "max-queue",
            "clients",
            "threads",
        ]);
        v
    }
}
