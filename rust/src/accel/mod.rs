//! Analytical performance models of variable-bitlength accelerators
//! (paper Table VIII).
//!
//! Each model maps per-layer (weight, activation) bitlengths plus the
//! static layer geometry onto relative execution cycles and storage,
//! following the published scaling rule of each design:
//!
//! * **Stripes** (Judd et al., MICRO'16) — bit-serial *activations*:
//!   per-MAC cycles ∝ n_a; weights processed bit-parallel.
//! * **Dpred** (Delmas et al.) — Stripes plus dynamic per-group
//!   precision detection: the serial loop runs at the bits *needed by
//!   the group's actual values*, modeled as a constant detection factor
//!   below the static/learned bitlength.
//! * **BitFusion** (Sharma et al., ISCA'18) — spatially composable 2-bit
//!   PEs for weights *and* activations; supported operand widths are
//!   powers of two, so bitlengths round up to {1,2,4,8,16}.
//! * **Loom** (Sharify et al.) — bit-serial in *both* operands:
//!   per-MAC cycles ∝ n_w · n_a.
//! * **Proteus** (Judd et al., ICS'16) — memory-only: values stored at
//!   reduced precision, compute unchanged.
//!
//! All performance numbers are speedups against the same design running
//! an 8-bit network (the paper's baseline convention), so the *shape* of
//! Table VIII — who gains, by what factor, trained > profiled — is what
//! the model reproduces, not testbed-absolute cycles.

use crate::model::{LayerGeom, ModelMeta};
use crate::quant::clip_bits;

/// Baseline bitlength the speedups are measured against.
pub const BASE_BITS: f64 = 8.0;

/// Dpred's dynamic-precision detection: the fraction of the static
/// bitlength the serial pipeline actually needs on typical value groups
/// (the original paper reports ~2x over static per-layer precision).
pub const DPRED_DYNAMIC_FACTOR: f64 = 0.55;

/// What a design accelerates / compresses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Target {
    Activations,
    WeightsAndActivations,
    MemoryOnly,
}

/// An accelerator performance model.
pub trait AccelModel: Send + Sync {
    fn name(&self) -> &'static str;
    fn target(&self) -> Target;

    /// Relative per-MAC cost (cycles) at the given operand bitlengths;
    /// `None` for memory-only designs.
    fn mac_cost(&self, n_w: f64, n_a: f64) -> Option<f64>;

    /// Per-MAC cost of the 8-bit reference point the speedup is quoted
    /// against.  Defaults to the design itself running an 8/8 network;
    /// Dpred overrides it with the *static* 8-bit serial cost, because
    /// its contribution (dynamic per-group precision detection) applies
    /// to the accelerated run, not the reference (paper Table VIII shows
    /// Dpred gaining even on profiled networks for exactly this reason).
    fn baseline_mac_cost(&self) -> Option<f64> {
        self.mac_cost(BASE_BITS, BASE_BITS)
    }

    /// Storage bits per (weight element, activation element).
    fn storage_bits(&self, n_w: f64, n_a: f64) -> (f64, f64);
}

fn ceil_bits(n: f64) -> f64 {
    clip_bits(n as f32).ceil() as f64
}

fn pow2_bits(n: f64) -> f64 {
    let n = ceil_bits(n);
    let mut p = 1.0;
    while p < n {
        p *= 2.0;
    }
    p
}

// ---------------------------------------------------------------------------

pub struct Stripes;

impl AccelModel for Stripes {
    fn name(&self) -> &'static str {
        "stripes"
    }

    fn target(&self) -> Target {
        Target::Activations
    }

    fn mac_cost(&self, _n_w: f64, n_a: f64) -> Option<f64> {
        Some(ceil_bits(n_a))
    }

    fn storage_bits(&self, _n_w: f64, n_a: f64) -> (f64, f64) {
        // Weights stay at the baseline container; activations shrink.
        (BASE_BITS, ceil_bits(n_a))
    }
}

pub struct Dpred;

impl AccelModel for Dpred {
    fn name(&self) -> &'static str {
        "dpred"
    }

    fn target(&self) -> Target {
        Target::Activations
    }

    fn mac_cost(&self, _n_w: f64, n_a: f64) -> Option<f64> {
        // Dynamic detection runs below the static bitlength but never
        // below 1 bit.
        Some((ceil_bits(n_a) * DPRED_DYNAMIC_FACTOR).max(1.0))
    }

    fn baseline_mac_cost(&self) -> Option<f64> {
        // Static bit-serial reference at 8 bits (see trait docs).
        Some(BASE_BITS)
    }

    fn storage_bits(&self, _n_w: f64, n_a: f64) -> (f64, f64) {
        // Grouped dynamic storage keeps a small per-group width field.
        (BASE_BITS, (ceil_bits(n_a) * DPRED_DYNAMIC_FACTOR).max(1.0) + 0.25)
    }
}

pub struct BitFusion;

impl AccelModel for BitFusion {
    fn name(&self) -> &'static str {
        "bitfusion"
    }

    fn target(&self) -> Target {
        Target::WeightsAndActivations
    }

    fn mac_cost(&self, n_w: f64, n_a: f64) -> Option<f64> {
        // Fused PEs compose in powers of two in each operand.
        Some(pow2_bits(n_w) * pow2_bits(n_a))
    }

    fn storage_bits(&self, n_w: f64, n_a: f64) -> (f64, f64) {
        (pow2_bits(n_w), pow2_bits(n_a))
    }
}

pub struct Loom;

impl AccelModel for Loom {
    fn name(&self) -> &'static str {
        "loom"
    }

    fn target(&self) -> Target {
        Target::WeightsAndActivations
    }

    fn mac_cost(&self, n_w: f64, n_a: f64) -> Option<f64> {
        Some(ceil_bits(n_w) * ceil_bits(n_a))
    }

    fn storage_bits(&self, n_w: f64, n_a: f64) -> (f64, f64) {
        (ceil_bits(n_w), ceil_bits(n_a))
    }
}

pub struct Proteus;

impl AccelModel for Proteus {
    fn name(&self) -> &'static str {
        "proteus"
    }

    fn target(&self) -> Target {
        Target::MemoryOnly
    }

    fn mac_cost(&self, _n_w: f64, _n_a: f64) -> Option<f64> {
        None
    }

    fn storage_bits(&self, n_w: f64, n_a: f64) -> (f64, f64) {
        (ceil_bits(n_w), ceil_bits(n_a))
    }
}

/// All Table VIII designs.
pub fn all_models() -> Vec<Box<dyn AccelModel>> {
    vec![
        Box::new(Stripes),
        Box::new(Dpred),
        Box::new(BitFusion),
        Box::new(Loom),
        Box::new(Proteus),
    ]
}

// ---------------------------------------------------------------------------
// evaluation
// ---------------------------------------------------------------------------

/// Result of evaluating one model on one bitlength assignment.
#[derive(Debug, Clone)]
pub struct AccelReport {
    pub accel: &'static str,
    /// Speedup vs the same design at 8/8 bits; None for memory-only.
    pub speedup: Option<f64>,
    /// Total storage relative to 8-bit containers.
    pub mem_ratio: f64,
}

/// Evaluate an accelerator on a network: cycles weighted by per-layer
/// MACs, storage weighted by element counts (weights network-wide,
/// activations per-sample).
pub fn evaluate(
    model: &dyn AccelModel,
    meta: &ModelMeta,
    bits_w: &[f32],
    bits_a: &[f32],
) -> AccelReport {
    assert_eq!(bits_w.len(), meta.layers.len());
    assert_eq!(bits_a.len(), meta.layers.len());

    let mut cycles = 0.0;
    let mut base_cycles = 0.0;
    let mut bits_total = 0.0;
    let mut base_bits_total = 0.0;

    for (i, l) in meta.layers.iter().enumerate() {
        let (nw, na) = (bits_w[i] as f64, bits_a[i] as f64);
        if let (Some(c), Some(cb)) = (model.mac_cost(nw, na), model.baseline_mac_cost()) {
            cycles += l.macs as f64 * c;
            base_cycles += l.macs as f64 * cb;
        }
        let (wb, ab) = model.storage_bits(nw, na);
        bits_total += l.weight_elems as f64 * wb + l.act_in_elems as f64 * ab;
        base_bits_total += (l.weight_elems + l.act_in_elems) as f64 * BASE_BITS;
    }

    AccelReport {
        accel: model.name(),
        speedup: (cycles > 0.0).then(|| base_cycles / cycles),
        mem_ratio: bits_total / base_bits_total,
    }
}

/// Evaluate every design for one bitlength assignment (one Table VIII
/// column pair).
pub fn evaluate_all(meta: &ModelMeta, bits_w: &[f32], bits_a: &[f32]) -> Vec<AccelReport> {
    all_models()
        .iter()
        .map(|m| evaluate(m.as_ref(), meta, bits_w, bits_a))
        .collect()
}

/// Estimate of layer-wise utilization loss for spatially composable
/// designs: fraction of PE capability wasted when a layer's bitlength
/// does not fill the composed tile.  Reported alongside Table VIII as a
/// model-fidelity diagnostic.
pub fn composition_waste(geom: &LayerGeom, n_bits: f64) -> f64 {
    let used = ceil_bits(n_bits);
    let alloc = pow2_bits(n_bits);
    let _ = geom;
    1.0 - used / alloc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ModelMeta;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn tiny_meta() -> ModelMeta {
        let j = crate::util::json::parse(&crate::model::tiny_meta_json()).unwrap();
        ModelMeta::from_json(&j).unwrap()
    }

    #[test]
    fn baseline_is_identity() {
        let meta = tiny_meta();
        let b8 = vec![8.0f32; 2];
        for r in evaluate_all(&meta, &b8, &b8) {
            // Dpred gains even on an 8-bit network (dynamic detection vs
            // the static reference); everything else is exactly 1.0.
            if r.accel == "dpred" {
                assert!(r.speedup.unwrap() > 1.0);
                assert!(r.mem_ratio < 1.0);
            } else {
                if let Some(s) = r.speedup {
                    assert!((s - 1.0).abs() < 1e-9, "{}: speedup {s}", r.accel);
                }
                assert!((r.mem_ratio - 1.0).abs() < 1e-9, "{}: mem {}", r.accel, r.mem_ratio);
            }
        }
    }

    #[test]
    fn fewer_bits_never_hurt() {
        let meta = tiny_meta();
        check(
            "accel-monotone",
            128,
            |rng: &mut Rng| {
                let b = |rng: &mut Rng| {
                    (0..2).map(|_| rng.range_f32(1.0, 8.0)).collect::<Vec<f32>>()
                };
                (b(rng), b(rng))
            },
            |(bw, ba)| {
                let b8 = vec![8.0f32; 2];
                for m in all_models() {
                    let low = evaluate(m.as_ref(), &meta, bw, ba);
                    let base = evaluate(m.as_ref(), &meta, &b8, &b8);
                    if let (Some(s_low), Some(s_base)) = (low.speedup, base.speedup) {
                        if s_low + 1e-9 < s_base {
                            return Err(format!("{}: slower at fewer bits", m.name()));
                        }
                    }
                    if low.mem_ratio > base.mem_ratio + 1e-9 {
                        return Err(format!("{}: more memory at fewer bits", m.name()));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn stripes_scales_with_activation_bits_only() {
        let meta = tiny_meta();
        let r4 = evaluate(&Stripes, &meta, &[8.0, 8.0], &[4.0, 4.0]);
        assert!((r4.speedup.unwrap() - 2.0).abs() < 1e-9);
        // weight bits are irrelevant to stripes perf
        let r4w = evaluate(&Stripes, &meta, &[2.0, 2.0], &[4.0, 4.0]);
        assert_eq!(r4.speedup, r4w.speedup);
    }

    #[test]
    fn loom_compounds_both_operands() {
        let meta = tiny_meta();
        let r = evaluate(&Loom, &meta, &[4.0, 4.0], &[4.0, 4.0]);
        assert!((r.speedup.unwrap() - 4.0).abs() < 1e-9);
    }

    #[test]
    fn bitfusion_rounds_to_power_of_two() {
        let meta = tiny_meta();
        // 3 bits uses the 4-bit composition: same speedup as 4 bits.
        let r3 = evaluate(&BitFusion, &meta, &[3.0, 3.0], &[3.0, 3.0]);
        let r4 = evaluate(&BitFusion, &meta, &[4.0, 4.0], &[4.0, 4.0]);
        assert_eq!(r3.speedup, r4.speedup);
        // 5 bits pays for 8: no gain over baseline.
        let r5 = evaluate(&BitFusion, &meta, &[5.0, 5.0], &[5.0, 5.0]);
        assert!((r5.speedup.unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn proteus_is_memory_only() {
        let meta = tiny_meta();
        let r = evaluate(&Proteus, &meta, &[4.0, 4.0], &[4.0, 4.0]);
        assert!(r.speedup.is_none());
        assert!((r.mem_ratio - 0.5).abs() < 1e-9);
    }

    #[test]
    fn dpred_beats_stripes() {
        let meta = tiny_meta();
        let bw = vec![3.0f32; 2];
        let ba = vec![4.0f32; 2];
        let s = evaluate(&Stripes, &meta, &bw, &ba).speedup.unwrap();
        let d = evaluate(&Dpred, &meta, &bw, &ba).speedup.unwrap();
        assert!(d > s, "dpred {d} <= stripes {s}");
    }

    #[test]
    fn composition_waste_bounds() {
        let geom = tiny_meta().layers[0].clone();
        assert_eq!(composition_waste(&geom, 4.0), 0.0);
        let w3 = composition_waste(&geom, 3.0);
        assert!(w3 > 0.0 && w3 < 1.0);
    }
}
